"""Docs health check: markdown link check + executable README snippets.

Three stdlib-only checks, run by the CI ``docs`` job and by
``tests/test_docs.py``:

1. **Link check** — every relative markdown link in ``README.md`` and
   ``docs/*.md`` must resolve to an existing file (anchors stripped);
   absolute URLs are only validated for scheme sanity (CI stays
   offline-deterministic).
2. **Snippet parity** — the first fenced ``python`` block in README.md
   must be byte-identical to the marked snippet region of
   ``examples/readme_quickstart.py``, the first block after the
   "Tracing a run" heading to ``examples/readme_tracing.py``, and the
   first block after the "Planet-scale federation" heading to
   ``examples/readme_federation.py``, so the README code cannot drift
   from the files that are actually executed.
3. **Snippet execution** (skippable with ``--no-exec``) — runs
   ``examples/readme_quickstart.py`` with ``PYTHONPATH=src`` and
   requires a SpaceMoE result row on stdout; runs
   ``examples/readme_tracing.py`` in a scratch directory and
   schema-validates the trace it writes via ``tools/check_trace.py``;
   runs ``examples/readme_federation.py`` and requires the pooled
   federation row plus the reroute summary on stdout.

    python tools/check_docs.py [--no-exec]
"""
from __future__ import annotations

import argparse
import os
import pathlib
import re
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SNIPPET_START = "# --8<-- [start:snippet]"
SNIPPET_END = "# --8<-- [end:snippet]"


def iter_doc_files() -> list[pathlib.Path]:
    """README.md plus every markdown page under docs/."""
    return [REPO / "README.md"] + sorted((REPO / "docs").glob("*.md"))


def check_links(errors: list[str]) -> int:
    """Validate every markdown link target; returns the link count."""
    n = 0
    for doc in iter_doc_files():
        if not doc.exists():
            errors.append(f"{doc.relative_to(REPO)}: file missing")
            continue
        for target in LINK_RE.findall(doc.read_text()):
            n += 1
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if target.startswith("#"):          # in-page anchor
                continue
            rel = target.split("#", 1)[0]
            if not (doc.parent / rel).exists():
                errors.append(
                    f"{doc.relative_to(REPO)}: broken link -> {target}")
    return n


def readme_python_block(after_heading: str | None = None) -> str:
    """The first fenced ```python block in README.md (stripped) —
    optionally the first one *after* a given heading."""
    text = (REPO / "README.md").read_text()
    if after_heading is not None:
        idx = text.find(after_heading)
        if idx < 0:
            raise SystemExit(f"README.md lost the {after_heading!r} heading")
        text = text[idx:]
    m = re.search(r"```python\n(.*?)```", text, flags=re.S)
    if not m:
        raise SystemExit("README.md has no ```python block"
                         + (f" after {after_heading!r}" if after_heading
                            else ""))
    return m.group(1).strip()


def snippet_region(example: str = "readme_quickstart.py") -> str:
    """The marked snippet region of an examples/ module."""
    lines = (REPO / "examples" / example).read_text().splitlines()
    try:
        lo = lines.index(SNIPPET_START) + 1
        hi = lines.index(SNIPPET_END)
    except ValueError:
        raise SystemExit(f"{example} lost its snippet markers")
    return "\n".join(lines[lo:hi]).strip()


def check_snippet(errors: list[str]) -> None:
    """Each README python block must equal its executable snippet."""
    if readme_python_block() != snippet_region():
        errors.append(
            "README.md python block != examples/readme_quickstart.py "
            "snippet region — update one to match the other")
    if readme_python_block(after_heading="### Tracing a run") \
            != snippet_region("readme_tracing.py"):
        errors.append(
            "README.md tracing block != examples/readme_tracing.py "
            "snippet region — update one to match the other")
    if readme_python_block(after_heading="### Planet-scale federation") \
            != snippet_region("readme_federation.py"):
        errors.append(
            "README.md federation block != examples/readme_federation.py "
            "snippet region — update one to match the other")


def run_quickstart(errors: list[str]) -> None:
    """Execute the quickstart and require a SpaceMoE row on stdout."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, str(REPO / "examples" / "readme_quickstart.py")],
        capture_output=True, text=True, env=env, timeout=600)
    if proc.returncode != 0:
        errors.append(f"quickstart failed (rc={proc.returncode}):\n"
                      f"{proc.stderr[-2000:]}")
    elif "SpaceMoE" not in proc.stdout:
        errors.append("quickstart ran but printed no SpaceMoE result row")


def run_tracing(errors: list[str]) -> None:
    """Execute the tracing snippet in a scratch dir and schema-validate
    the trace it writes (tools/check_trace.py)."""
    import tempfile
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    with tempfile.TemporaryDirectory() as tmp:
        proc = subprocess.run(
            [sys.executable, str(REPO / "examples" / "readme_tracing.py")],
            capture_output=True, text=True, env=env, timeout=600, cwd=tmp)
        if proc.returncode != 0:
            errors.append(f"tracing snippet failed (rc={proc.returncode}):\n"
                          f"{proc.stderr[-2000:]}")
            return
        if "trace events" not in proc.stdout:
            errors.append("tracing snippet ran but printed no event count")
        trace = pathlib.Path(tmp) / "trace_smoke.json"
        check = subprocess.run(
            [sys.executable, str(REPO / "tools" / "check_trace.py"),
             str(trace), "--require-requests"],
            capture_output=True, text=True, env=env, timeout=120)
        if check.returncode != 0:
            errors.append("tracing snippet's trace failed check_trace:\n"
                          f"{(check.stdout + check.stderr)[-2000:]}")


def run_federation(errors: list[str]) -> None:
    """Execute the federation snippet and require the pooled federation
    row plus the reroute summary on stdout."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, str(REPO / "examples" / "readme_federation.py")],
        capture_output=True, text=True, env=env, timeout=600)
    if proc.returncode != 0:
        errors.append(f"federation snippet failed (rc={proc.returncode}):\n"
                      f"{proc.stderr[-2000:]}")
    elif "federation" not in proc.stdout or "rerouted" not in proc.stdout:
        errors.append("federation snippet ran but printed no pooled "
                      "federation row / reroute summary")


def main(argv: list[str] | None = None) -> int:
    """Run all checks; print a report and return a process exit code."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-exec", action="store_true",
                    help="skip executing the quickstart snippet")
    args = ap.parse_args(argv)

    errors: list[str] = []
    n_links = check_links(errors)
    check_snippet(errors)
    if not args.no_exec:
        run_quickstart(errors)
        run_tracing(errors)
        run_federation(errors)

    docs = ", ".join(str(d.relative_to(REPO)) for d in iter_doc_files())
    if errors:
        print("docs check FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        return 1
    print(f"docs check OK: {n_links} links across [{docs}], README "
          f"snippets in sync"
          + ("" if args.no_exec
             else ", quickstart + tracing + federation snippets executed"))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
