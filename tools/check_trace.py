"""Trace-schema gate: validate exported flight-recorder trace JSON.

CI exports a trace from the serve smoke
(``serve.py --traffic smoke --trace trace_smoke.json``) and then::

    python tools/check_trace.py trace_smoke.json

Every file must parse as JSON and pass
:func:`repro.obs.schema.validate_trace` (the Trace Event Format's
object flavor with this repo's required metadata) — a drifting exporter
fails the job before an un-loadable artifact ships.

Acceptance-style content requirements are opt-in flags::

    python tools/check_trace.py trace_replan.json \
        --require-aimd --require-replan-switch

``--require-aimd`` demands >= 1 AIMD control instant,
``--require-replan-switch`` >= 1 replan switch instant,
``--require-joint-decision`` >= 1 joint control-plane decision instant
(the fused grid's on-device decide telemetry), and
``--require-requests`` >= 1 exported request span — the control-plane
coverage the observability PR pins on the replan scenarios.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

from repro.obs.schema import count_events, validate_trace  # noqa: E402


def check_file(path: str, require_aimd: bool = False,
               require_replan_switch: bool = False,
               require_requests: bool = False,
               require_joint_decision: bool = False) -> list[str]:
    """Validate one trace file; returns a list of problems (empty = ok)."""
    try:
        with open(path) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable trace: {e}"]
    problems = validate_trace(obj)
    if require_aimd and count_events(obj, "aimd", ph="i") < 1:
        problems.append("no AIMD control instants "
                        "(--require-aimd; run with an admission config)")
    if require_replan_switch \
            and count_events(obj, "replan switch", ph="i") < 1:
        problems.append("no replan switch instants "
                        "(--require-replan-switch; run a *-replan "
                        "scenario that actually switches)")
    if require_joint_decision \
            and count_events(obj, "joint", ph="i") < 1:
        problems.append("no joint control-plane decision instants "
                        "(--require-joint-decision; run a replan "
                        "scenario through the fused controller, e.g. "
                        "serve.py --ctrl fused)")
    if require_requests and count_events(obj, "prefill", ph="X") < 1:
        problems.append("no request prefill spans (--require-requests)")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("traces", nargs="+", help="exported trace JSON files")
    ap.add_argument("--require-aimd", action="store_true",
                    help="demand >= 1 AIMD control instant")
    ap.add_argument("--require-replan-switch", action="store_true",
                    help="demand >= 1 replan switch instant")
    ap.add_argument("--require-joint-decision", action="store_true",
                    help="demand >= 1 joint control-plane decision "
                         "instant (fused controller runs)")
    ap.add_argument("--require-requests", action="store_true",
                    help="demand >= 1 exported request span")
    args = ap.parse_args(argv)

    failed = False
    for path in args.traces:
        problems = check_file(path, args.require_aimd,
                              args.require_replan_switch,
                              args.require_requests,
                              args.require_joint_decision)
        if problems:
            failed = True
            print(f"[check_trace] {path}: FAIL")
            for p in problems[:20]:
                print(f"  - {p}")
            if len(problems) > 20:
                print(f"  ... and {len(problems) - 20} more")
        else:
            with open(path) as f:
                n = len(json.load(f).get("traceEvents", []))
            print(f"[check_trace] {path}: ok ({n} events)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
