"""Bench-regression gate: diff fresh BENCH_*.json against committed baselines.

CI runs each benchmark smoke with ``--json-out`` and then::

    python tools/check_bench.py BENCH_traffic.json BENCH_fleet.json ...

Every metric present in the committed baseline
(``benchmarks/baselines/<name>.json``) must still be present in the fresh
artifact and match within tolerance; a missing metric or an
out-of-tolerance numeric deviation fails the job.  *Extra* keys in the
fresh artifact are fine (new metrics don't need a baseline update to
land, but removing or breaking one does).

What gets compared
------------------
Structural and statistical metrics only.  Keys whose path contains a
:data:`SKIP_SUBSTRINGS` fragment are ignored — wall-clock timings,
compile times, speedups, provenance (jax version, table hashes, host
rates) all vary machine to machine and are tracked as artifacts, not
gated.  Numeric leaves compare with a combined bound::

    |fresh - base| <= atol + rtol * |base|

using the loosest (rtol, atol) of the :data:`TOLERANCES` entries whose
substring matches the key path, else :data:`DEFAULT_TOL`.  Booleans and
strings must be equal; ``pass``/``parity_ok`` style flags therefore gate
exactly.

Updating baselines
------------------
Intentional metric changes re-pin with::

    python tools/check_bench.py --update BENCH_traffic.json ...

which copies the fresh artifacts over the committed baselines (commit the
diff).  Baselines were generated with the exact CI smoke flags (see
.github/workflows/ci.yml) — regenerate with the same flags or the gate
will flag spurious shape differences.
"""
from __future__ import annotations

import argparse
import json
import math
import shutil
import sys
from pathlib import Path

BASELINE_DIR = Path(__file__).resolve().parent.parent / "benchmarks" / "baselines"

#: Key-path fragments excluded from the diff (machine-dependent numbers).
SKIP_SUBSTRINGS = (
    "us_per_call", "wall", "compile", "_profile", "speed", "provenance",
    "jax", "table_hash", "host", "measured", "predicted", "ratio",
    "build_s", "sweep_s", "steady_s", "first_s", "stages", "elapsed",
    "ttft", "e2e", "tpot", "wait", "latency", "_ms", "seconds",
    "peak_rss",
)

#: (substring, rtol, atol) — loosest match wins; order is irrelevant.
TOLERANCES = (
    ("goodput", 0.05, 1e-6),
    ("rate", 0.05, 1e-6),
    ("frontier", 0.05, 1e-6),
    ("loss", 0.05, 1e-9),
)

#: Fallback for numeric leaves no TOLERANCES entry matches.
DEFAULT_TOL = (0.01, 1e-9)


def _skip(path: str) -> bool:
    low = path.lower()
    return any(s in low for s in SKIP_SUBSTRINGS)


def _tol_for(path: str) -> tuple[float, float]:
    low = path.lower()
    rtol, atol = DEFAULT_TOL
    for sub, r, a in TOLERANCES:
        if sub in low:
            rtol, atol = max(rtol, r), max(atol, a)
    return rtol, atol


def _close(fresh: float, base: float, rtol: float, atol: float) -> bool:
    if math.isnan(base) or math.isnan(fresh):
        return math.isnan(base) == math.isnan(fresh)
    if math.isinf(base) or math.isinf(fresh):
        return fresh == base
    return abs(fresh - base) <= atol + rtol * abs(base)


def diff(fresh, base, path: str = "") -> list[str]:
    """Recursive baseline-vs-fresh comparison; returns problem strings."""
    if _skip(path):
        return []
    problems: list[str] = []
    if isinstance(base, dict):
        if not isinstance(fresh, dict):
            return [f"{path}: baseline is an object, fresh is "
                    f"{type(fresh).__name__}"]
        for key, bval in base.items():
            sub = f"{path}.{key}" if path else key
            if _skip(sub):
                continue
            if key not in fresh:
                problems.append(f"{sub}: metric missing from fresh artifact")
                continue
            problems += diff(fresh[key], bval, sub)
    elif isinstance(base, list):
        if not isinstance(fresh, list):
            return [f"{path}: baseline is a list, fresh is "
                    f"{type(fresh).__name__}"]
        if len(fresh) != len(base):
            return [f"{path}: length {len(fresh)} != baseline {len(base)}"]
        for i, (fv, bv) in enumerate(zip(fresh, base)):
            problems += diff(fv, bv, f"{path}[{i}]")
    elif isinstance(base, bool) or isinstance(fresh, bool):
        if fresh is not base:
            problems.append(f"{path}: {fresh} != baseline {base}")
    elif isinstance(base, (int, float)) and isinstance(fresh, (int, float)):
        rtol, atol = _tol_for(path)
        if not _close(float(fresh), float(base), rtol, atol):
            problems.append(
                f"{path}: {fresh} deviates from baseline {base} "
                f"(rtol={rtol}, atol={atol})")
    else:
        if fresh != base:
            problems.append(f"{path}: {fresh!r} != baseline {base!r}")
    return problems


def check_file(fresh_path: Path, baseline_dir: Path,
               update: bool = False) -> list[str]:
    """Gate one artifact; with ``update`` re-pin the baseline instead."""
    base_path = baseline_dir / fresh_path.name
    if update:
        baseline_dir.mkdir(parents=True, exist_ok=True)
        shutil.copyfile(fresh_path, base_path)
        print(f"[update] {base_path}")
        return []
    if not base_path.exists():
        return [f"{fresh_path.name}: no committed baseline at {base_path} "
                "(run with --update and commit it)"]
    fresh = json.loads(fresh_path.read_text())
    base = json.loads(base_path.read_text())
    return [f"{fresh_path.name}: {p}"
            for p in diff(fresh, base, path="")]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("artifacts", nargs="+", metavar="BENCH.json",
                    help="fresh --json-out artifacts to gate")
    ap.add_argument("--baseline-dir", default=str(BASELINE_DIR))
    ap.add_argument("--update", action="store_true",
                    help="re-pin the baselines from the fresh artifacts")
    args = ap.parse_args()

    baseline_dir = Path(args.baseline_dir)
    problems: list[str] = []
    for name in args.artifacts:
        path = Path(name)
        if not path.exists():
            problems.append(f"{name}: fresh artifact not found")
            continue
        problems += check_file(path, baseline_dir, update=args.update)
    if problems:
        for p in problems:
            print(f"[REGRESSION] {p}", file=sys.stderr)
        raise SystemExit(1)
    if not args.update:
        print(f"check_bench: {len(args.artifacts)} artifact(s) within "
              "tolerance of committed baselines")


if __name__ == "__main__":
    main()
