"""The README quickstart snippet, executable.

This file IS the python snippet shown in README.md ("Evaluate a sweep
of placement plans..."): `tools/check_docs.py` asserts the two stay
byte-identical (between the SNIPPET markers) and executes this module,
so the documented code path cannot silently rot.

    PYTHONPATH=src python examples/readme_quickstart.py
"""
# --8<-- [start:snippet]
import numpy as np
from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        ServiceModel, baseline_plans, load_table,
                        rank_plans, sample_topology)

cfg = ConstellationConfig.scaled(12, 16, n_slots=10)  # CI-sized world
con = Constellation(cfg)
rng = np.random.default_rng(0)
topo = sample_topology(con, LinkConfig(), rng)
activ = ActivationModel.zipf(n_layers=8, n_experts=4, top_k=2)
plans = baseline_plans(con, topo, activ, rng)    # SpaceMoE + random baselines
ranked = rank_plans(plans, topo, activ, MoEWorkload.llama_moe_3p5b(),
                    ComputeConfig(), rng, n_tokens=200)
for plan, result in ranked:
    print(f"{plan.name:16s} mean={result.mean_s*1e3:7.2f} ms "
          f"p99={result.p99_s*1e3:7.2f} ms drop={result.drop_rate:.3f}")

# Calibrated mode: swap the analytic FLOP constants for the committed
# kernel-measured service table (omit service_model= for bit-identical
# analytic results).
table = load_table("llama-moe-3.5b")
svc = ServiceModel.calibrated(table.workload_obj(), ComputeConfig(), table)
calibrated = rank_plans(plans, topo, activ, table.workload_obj(),
                        ComputeConfig(), np.random.default_rng(0),
                        n_tokens=200, service_model=svc)
best_plan, best = calibrated[0]
print(f"calibrated[{table.table_hash}] best={best_plan.name} "
      f"mean={best.mean_s:.3f} s")
# --8<-- [end:snippet]
