"""End-to-end serving driver (the paper's kind is inference).

Runs the full pipeline on the paper's model family: router calibration ->
Theorem-1 expert->device placement -> batched prefill+decode -> space-
network latency accounting -> elastic failover demo.

    PYTHONPATH=src python examples/serve_spacemoe.py
    PYTHONPATH=src python examples/serve_spacemoe.py --arch deepseek-moe-16b
"""
import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-moe-3.5b")
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()
    serve_main([
        "--arch", args.arch, "--smoke",
        "--batch", "4", "--prompt-len", "32",
        "--decode-tokens", str(args.tokens),
        "--space-sim", "--fail-device", "1",
    ])


if __name__ == "__main__":
    main()
