"""The README "Tracing a run" snippet, executable.

This file IS the python snippet shown in README.md (§ Tracing a run):
`tools/check_docs.py` asserts the two stay byte-identical (between the
SNIPPET markers), executes this module, and validates the trace it
writes with `tools/check_trace.py`, so the documented observability
path cannot silently rot.

    PYTHONPATH=src python examples/readme_tracing.py
"""
# --8<-- [start:snippet]
import numpy as np
from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        rand_intra_cg_plan, sample_topology, spacemoe_plan)
from repro.obs import (ProbeConfig, build_flight_log, summarize_timeseries,
                       write_trace)
from repro.traffic import FleetSim, QueueConfig, sample_requests
from repro.traffic.metrics import format_table

con = Constellation(ConstellationConfig.scaled(8, 12, n_slots=10))
rng = np.random.default_rng(0)
topo = sample_topology(con, LinkConfig(), rng)
activ = ActivationModel.zipf(n_layers=4, n_experts=4, top_k=2)
plans = [spacemoe_plan(con, topo, activ),
         rand_intra_cg_plan(con.cfg, 4, 4, np.random.default_rng(7))]
req = sample_requests(np.random.default_rng(8), rate_rps=2.0,
                      horizon_s=40.0, n_stations=1, prompt_median=4,
                      prompt_max=16, decode_mean=4, decode_max=8)

# probes= is a static flag: omit it (None) and the launch is bitwise
# identical to the probe-free kernel; set it and the fused fixed point
# writes on-device telemetry rings during its final iteration.
sim = FleetSim(plans, topo, activ, MoEWorkload.llama_moe_3p5b(),
               ComputeConfig(), req, np.random.default_rng(5),
               qcfg=QueueConfig(dt_s=0.05, tail_s=30.0),
               probes=ProbeConfig())
res = sim.run()                        # one fused launch, probes ride along

log = build_flight_log(sim, res, scenario="smoke")
trace = write_trace("trace_smoke.json", log)   # open at ui.perfetto.dev
print(format_table(summarize_timeseries(sim.last_probes, n_windows=4)))
print(f"{len(trace['traceEvents'])} trace events, "
      f"{len(log.served())} served requests traced")
# --8<-- [end:snippet]
