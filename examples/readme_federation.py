"""The README "Planet-scale federation" snippet, executable.

This file IS the python snippet shown in README.md (§ Planet-scale
federation): `tools/check_docs.py` asserts the two stay byte-identical
(between the SNIPPET markers) and executes this module, so the
documented federation path cannot silently rot.

    PYTHONPATH=src python examples/readme_federation.py
"""
# --8<-- [start:snippet]
import numpy as np
from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        sample_topology, spacemoe_plan)
from repro.traffic import (AdmissionConfig, FederationConfig, FleetSim,
                           QueueConfig, build_federation,
                           build_ground_segment, sample_requests)
from repro.traffic.metrics import format_table

req = sample_requests(np.random.default_rng(8), rate_rps=4.3,
                      horizon_s=43.0, n_stations=8, prompt_median=4,
                      prompt_max=16, decode_mean=4, decode_max=8)
qcfg = QueueConfig(dt_s=0.05, tail_s=40.0,
                   admission=AdmissionConfig(ttft_target_s=8.0))

def member(seed):            # one independently-planned constellation
    def build(min_bins=0):   # rebuildable on the shared bin grid
        con = Constellation(ConstellationConfig.scaled(8, 12, n_slots=10))
        topo = sample_topology(con, LinkConfig(), np.random.default_rng(seed))
        activ = ActivationModel.zipf(4, 4, 2, seed=1)
        ground = build_ground_segment(con, LinkConfig(),
                                      min_elevation_deg=10.0)
        return FleetSim([spacemoe_plan(con, topo, activ)], topo, activ,
                        MoEWorkload.llama_moe_3p5b(), ComputeConfig(),
                        req, np.random.default_rng(5), qcfg=qcfg,
                        ground=ground, min_bins=min_bins)
    return build

# K member worlds padded to one shape and stacked on the fused kernel's
# plan axis: the whole federation serves in ONE device launch.  Requests
# shed by a member's admission controller retry at the next-best
# constellation (ranked visibility); forward latency is billed into
# their TTFT.
fed = build_federation([member(s) for s in (0, 1, 2)],
                       FederationConfig(overflow=True))
res = fed.run()
print(format_table([res.federated.row()], prefix="federation: "))
print(f"{(res.hops > 0).sum()} rerouted in {res.n_rounds} rounds; "
      f"shed {int(res.federated.shed.sum())}")
# --8<-- [end:snippet]
