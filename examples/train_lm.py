"""Train a language model end-to-end with checkpoint/restart.

Default: a ~10M-param smollm-family config sized for this CPU container
(few hundred steps in minutes).  ``--full-135m`` trains the real
smollm-135m config (sized for accelerators; the production-mesh sharding
for it is proven by the dry-run).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    # fault-tolerance: kill mid-run, then re-run the same command — it
    # resumes from the last checkpoint with no data skipped/repeated.
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import get_config  # noqa: E402
from repro.launch.train import main as train_main  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full-135m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/spacemoe_train_ckpt")
    ap.add_argument("--schedule", default="wsd", choices=["wsd", "cosine"])
    args = ap.parse_args()

    if args.full_135m:
        argv = ["--arch", "smollm-135m", "--steps", str(args.steps),
                "--batch", "4", "--seq", "256"]
    else:
        # ~10M-param same-family config: 6 layers, d=256
        from repro.configs import smollm_135m
        cfg = dataclasses.replace(
            smollm_135m.CONFIG, n_layers=6, d_model=256, n_heads=8,
            n_kv_heads=4, head_dim=32, d_ff=683, vocab_size=8192,
            name="smollm-10m", compute_dtype="float32",
            attn_q_chunk=64, attn_kv_chunk=128,
        )
        # register it so launch.train can find it
        import repro.configs as C
        C.REGISTRY["smollm-10m"] = cfg
        argv = ["--arch", "smollm-10m", "--steps", str(args.steps),
                "--batch", "8", "--seq", "128"]
    argv += ["--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
             "--schedule", args.schedule, "--lr", "1e-3"]
    out = train_main(argv)
    losses = out["losses"]
    if losses:
        print(f"[example] loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
              f"{len(losses)} steps ({out['n_params']/1e6:.1f}M params)")


if __name__ == "__main__":
    main()
