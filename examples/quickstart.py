"""Quickstart: place a MoE over a small constellation and measure latency.

    PYTHONPATH=src python examples/quickstart.py

Builds a 12x16 polar constellation, places an 8-layer x 4-expert MoE with
all four schemes from the paper, and prints the simulated per-token
latency — SpaceMoE should win by ~2-3x even at this toy scale.
"""
import numpy as np

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        rand_intra_cg_plan, rand_intra_plan, rand_place_plan,
                        sample_topology, simulate_token_generation,
                        spacemoe_plan)


def main():
    cfg = ConstellationConfig.scaled(12, 16, n_slots=30)
    con = Constellation(cfg)
    print(f"constellation: {cfg.n_planes}x{cfg.sats_per_plane} "
          f"({cfg.n_sats} satellites), period {cfg.orbital_period_s/60:.1f} min")

    rng = np.random.default_rng(0)
    topo = sample_topology(con, LinkConfig(), rng)
    print(f"ISL availability over {cfg.n_slots} slots: "
          f"{topo.availability():.1%}")

    n_layers, n_experts, top_k = 8, 4, 2
    activ = ActivationModel.zipf(n_layers, n_experts, top_k, seed=1)
    wl = MoEWorkload.llama_moe_3p5b()
    comp = ComputeConfig()

    plans = [
        spacemoe_plan(con, topo, activ, wl, comp),
        rand_place_plan(cfg, n_layers, n_experts, np.random.default_rng(2)),
        rand_intra_plan(cfg, n_layers, n_experts, np.random.default_rng(3)),
        rand_intra_cg_plan(cfg, n_layers, n_experts, np.random.default_rng(4)),
    ]
    print(f"\n{'scheme':14s} {'s/token':>9s} {'p99':>9s}")
    base = None
    for plan in plans:
        res = simulate_token_generation(
            plan, topo, activ, wl, comp, np.random.default_rng(5),
            n_tokens=500,
        )
        if plan.name == "SpaceMoE":
            base = res.mean_s
        print(f"{plan.name:14s} {res.mean_s:9.3f} {res.p99_s:9.3f}"
              + (f"   ({res.mean_s/base:.2f}x SpaceMoE)" if base else ""))


if __name__ == "__main__":
    main()
