"""Elastic failover study: satellite failures, live re-placement, stragglers.

Maps the paper's ISL-outage model (Eq. 3) onto expert-satellite failures
and shows the three recovery layers the repo now has:

1. **failure-storm** (scenario registry): a storm knocks out a fraction
   of every layer's expert satellites mid-horizon; the Theorem-1
   machinery re-places their experts on the survivors via
   ``repro.distributed.elastic`` (multi-expert regime), with the weight
   migration bytes accounted — what used to be a hand-rolled failure
   loop here is now one registry call;
2. **PlanSchedule / replan**: the post-storm fleet keeps re-placing
   *continuously* — the backlog-driven controller of
   ``repro.traffic.replan`` re-ranks the candidate pool each topology
   slot and assembles a time-indexed schedule whose migration bytes
   ride the ISL queues;
3. **straggler mitigation** (device ring): a slow device keeps its
   slots but its inflated cost drains hot experts away (soft failure).

    PYTHONPATH=src python examples/elastic_failover.py
"""
import dataclasses

import numpy as np

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        TorusSpec, baseline_plans, plan_expert_devices,
                        sample_topology)
from repro.distributed import replan_with_stragglers
from repro.traffic import format_table, get_scenario, run_scenario

E, TOP_K = 8, 2


def main():
    # ---- world + candidate pool --------------------------------------
    cfg = ConstellationConfig.scaled(8, 12, n_slots=10, survival_prob=1.0)
    con = Constellation(cfg)
    topo = sample_topology(con, LinkConfig(), np.random.default_rng(0))
    activ = ActivationModel.zipf(4, E, TOP_K, seed=0)
    wl, comp = MoEWorkload.llama_moe_3p5b(), ComputeConfig()
    plans = baseline_plans(con, topo, activ, np.random.default_rng(3),
                           n_random_draws=1)
    print(f"candidate pool: {[p.name for p in plans]}")

    # ---- 1: failure-storm via the scenario registry ------------------
    sc = dataclasses.replace(
        get_scenario("failure-storm"), horizon_s=60.0, tail_s=30.0,
        failure_at_s=30.0, decode_mean=4, decode_max=8, prompt_median=4,
        prompt_max=16)
    out = run_scenario(sc, plans, topo, activ, wl, comp,
                       np.random.default_rng(4), constellation=con,
                       rate_scale=3.0)
    rows = out.result.table(sc.slo, scenario="pre-storm")
    rows += out.post_failure.table(sc.slo, scenario="post-storm")
    print(format_table(rows))
    for name, b in out.storm.migration_bytes.items():
        print(f"  storm re-place {name}: {out.storm.moved_experts[name]} "
              f"experts move, {b/1e6:.0f} MB")

    # ---- 2: continuous re-placement over a PlanSchedule --------------
    sc = dataclasses.replace(
        get_scenario("failure-storm-replan"), horizon_s=60.0, tail_s=30.0,
        failure_at_s=30.0, slot_period_s=15.0, decode_mean=4, decode_max=8,
        prompt_median=4, prompt_max=16)
    out = run_scenario(sc, plans, topo, activ, wl, comp,
                       np.random.default_rng(4), constellation=con,
                       rate_scale=5.0)
    print("\ncontinuous re-placement (backlog mode):")
    for tag, res, rep in (("pre", out.result, out.replan),
                          ("post", out.post_failure, out.post_replan)):
        rp = res.by_name(rep.schedule.name)
        best_static = max((p.goodput_tok_s for p in res.plans
                           if p.plan_name != rep.schedule.name))
        print(f"  {tag}-storm {rep.schedule.name}: "
              f"{rep.n_switches} switch(es), "
              f"{rp.migration_bytes/1e6:.0f} MB migrated in-horizon, "
              f"goodput {rp.goodput_tok_s:.2f} tok/s "
              f"(best static {best_static:.2f})")
        for d in rep.decisions:
            if d.switched:
                cand = rep.candidates[d.chosen]
                print(f"    boundary {d.boundary} (slot {d.slot}): "
                      f"-> {cand.name} ({d.migration_bytes/1e6:.0f} MB)")

    # ---- 3: straggler mitigation (device ring, soft failure) ---------
    print("\nstraggler mitigation (no failure, device 0 slowed 20x):")
    w = ActivationModel.zipf(1, 64, 6, seed=0).weights[0]
    torus = TorusSpec(shape=(4, 4))
    base = plan_expert_devices(w, 6, torus)
    hot_on_0 = [e for e in range(64) if base.device_of_expert(e) == 0]
    slow = replan_with_stragglers(w, 6, torus, {0: 20.0})
    hot_after = [e for e in range(64) if slow.device_of_expert(e) == 0]
    p = ActivationModel(weights=w[None], top_k=6).probs(0)
    print(f"  device-0 expert load before: {p[hot_on_0].sum():.3f}  "
          f"after: {p[hot_after].sum():.3f} (hot experts drained)")


if __name__ == "__main__":
    main()
