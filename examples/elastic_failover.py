"""Elastic failover study: node failures and stragglers during MoE serving.

Maps the paper's ISL-outage model (Eq. 3) onto device failures on the EP
ring: as devices die, the Theorem-1 re-plan concentrates surviving slots
around the dispatch origin, trading weight-migration bytes for expected
dispatch latency (paper Sec. VI-B's multi-expert regime appears
automatically as capacity shrinks).

    PYTHONPATH=src python examples/elastic_failover.py
"""
import numpy as np

from repro.core import (ActivationModel, TorusSpec, expected_dispatch_cost,
                        plan_expert_devices)
from repro.distributed import (migration, replan_on_failure,
                               replan_with_stragglers)

E, TOP_K = 64, 6                      # deepseek-moe-16b MoE geometry
BYTES_PER_EXPERT = 3 * 2048 * 1408 * 2   # bf16 expert weights


def main():
    w = ActivationModel.zipf(1, E, TOP_K, seed=0).weights[0]
    torus = TorusSpec(shape=(4, 4))
    plan = plan_expert_devices(w, TOP_K, torus)
    print(f"initial: {E} experts on {torus.n_devices} devices, "
          f"expected dispatch {expected_dispatch_cost(plan, w, TOP_K)*1e6:.2f} us")

    rng = np.random.default_rng(0)
    failed: set[int] = set()
    for round_i in range(4):
        nxt = int(rng.choice([d for d in range(torus.n_devices)
                              if d not in failed]))
        failed.add(nxt)
        new_plan, survivors = replan_on_failure(w, TOP_K, torus, failed)
        mig = migration(plan, new_plan, BYTES_PER_EXPERT, survivors)
        cost = expected_dispatch_cost(new_plan, w, TOP_K)
        print(f"round {round_i+1}: device {nxt} fails "
              f"({len(survivors)} left, {new_plan.experts_per_device}/dev) -> "
              f"move {len(mig.moved_experts)} experts "
              f"({mig.bytes_moved/1e6:.0f} MB), dispatch {cost*1e6:.2f} us")
        plan = new_plan

    print("\nstraggler mitigation (no failure, device 0 slowed 20x):")
    base = plan_expert_devices(w, TOP_K, torus)
    hot_on_0 = [e for e in range(E) if base.device_of_expert(e) == 0]
    slow = replan_with_stragglers(w, TOP_K, torus, {0: 20.0})
    hot_after = [e for e in range(E) if slow.device_of_expert(e) == 0]
    p = ActivationModel(weights=w[None], top_k=TOP_K).probs(0)
    print(f"  device-0 expert load before: {p[hot_on_0].sum():.3f}  "
          f"after: {p[hot_after].sum():.3f} (hot experts drained)")


if __name__ == "__main__":
    main()
