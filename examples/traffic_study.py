"""Traffic study: how many users can a SpaceMoE constellation serve?

Walks the repro.traffic subsystem end to end on a mid-size world:

  1. build the world (constellation, topology, activation stats, ground
     gateways) and a plan sweep (SpaceMoE vs the random baselines);
  2. run the named scenarios (steady-state, diurnal-peak,
     regional-hotspot) and print the plans x scenarios SLO table;
  3. failure-storm: knock out 25% of the expert satellites mid-run,
     re-place experts on the survivors with the distributed.elastic
     machinery, and compare pre/post SLOs + migration bytes;
  4. saturation sweep: the max request rate each plan sustains under a
     KV-slot budget and latency SLO (the capacity headline).

    PYTHONPATH=src python examples/traffic_study.py [--fast]
"""
import argparse
import dataclasses

import numpy as np

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        rand_intra_cg_plan, rand_place_plan, sample_topology,
                        spacemoe_plan)
from repro.traffic import (SLO, build_ground_segment, format_table,
                           get_scenario, make_sim, run_scenario,
                           saturation_sweep)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()

    # ---- world ---------------------------------------------------------
    if args.fast:
        ccfg = ConstellationConfig.scaled(12, 16, n_slots=10)
        n_layers = 8
    else:
        ccfg = ConstellationConfig.scaled(17, 16, n_slots=20)
        n_layers = 16
    con = Constellation(ccfg)
    link = LinkConfig()
    topo = sample_topology(con, link, np.random.default_rng(0))
    activ = ActivationModel.zipf(n_layers, 8, 2, seed=0)
    wl = MoEWorkload.llama_moe_3p5b()
    comp = ComputeConfig()
    ground = build_ground_segment(con, link, min_elevation_deg=10.0)
    print(f"world: {ccfg.n_sats} sats, L={n_layers}, "
          f"ground coverage {ground.coverage():.0%}")

    plans = [
        spacemoe_plan(con, topo, activ),
        rand_intra_cg_plan(ccfg, n_layers, 8, np.random.default_rng(3)),
        rand_place_plan(ccfg, n_layers, 8, np.random.default_rng(3)),
    ]

    # ---- scenarios -----------------------------------------------------
    rows = []
    for name in ("steady-state", "diurnal-peak", "regional-hotspot"):
        sc = get_scenario(name)
        if args.fast:
            sc = dataclasses.replace(sc, horizon_s=60.0, tail_s=60.0)
        out = run_scenario(sc, plans, topo, activ, wl, comp,
                           np.random.default_rng(11), ground=ground,
                           constellation=con)
        rows += out.result.table(sc.slo, scenario=sc.name)
    print(format_table(rows))

    # ---- failure storm -------------------------------------------------
    sc = get_scenario("failure-storm")
    if args.fast:
        sc = dataclasses.replace(sc, horizon_s=60.0, failure_at_s=30.0,
                                 tail_s=60.0)
    out = run_scenario(sc, plans[:2], topo, activ, wl, comp,
                       np.random.default_rng(12), ground=ground,
                       constellation=con)
    print("\nfailure-storm: "
          f"{sc.failure_frac:.0%} of expert satellites lost at "
          f"t={sc.failure_at_s:.0f}s")
    for name, b in out.storm.migration_bytes.items():
        print(f"  {name}: {out.storm.moved_experts[name]} experts move, "
              f"{b / 1e6:.1f} MB migrated")
    srows = out.result.table(sc.slo, scenario="pre-storm")
    if out.post_failure is not None:
        srows += out.post_failure.table(sc.slo, scenario="post-storm")
    print(format_table(srows))

    # ---- saturation sweep ----------------------------------------------
    sweep_sc = dataclasses.replace(
        get_scenario("smoke"), horizon_s=60.0 if args.fast else 120.0,
        tail_s=60.0, kv_slots=8)
    sim = make_sim(sweep_sc, plans[:2], topo, activ, wl, comp,
                   np.random.default_rng(13), ground=ground,
                   constellation=con, rate_scale=8.0)
    base = sim.run(zero_load=True)
    slo = SLO(ttft_s=3.0 * min(p.quantile("ttft", 0.9) for p in base.plans),
              tpot_s=2.5 * min(p.quantile("tpot", 0.9) for p in base.plans),
              quantile=0.9, max_drop=0.05)
    sat = saturation_sweep(sim, slo, np.random.default_rng(17),
                           fractions=np.linspace(0.1, 1.0, 10))
    print(f"\nsaturation sweep ({slo.describe()}, kv_slots=8):")
    for name, rate in sat.sustained_rps.items():
        print(f"  {name}: sustains {rate:.3f} req/s")
    print(f"  capacity ratio SpaceMoE / RandIntra-CG: "
          f"{sat.capacity_ratio('SpaceMoE', 'RandIntra-CG'):.2f}x")


if __name__ == "__main__":
    main()
