"""Placement study: how network parameters shape SpaceMoE's advantage
(a quick interactive version of paper Fig. 7).

Each configuration evaluates SpaceMoE vs RandIntra-CG in a single
batched ``evaluate_plans`` sweep (one deduped Dijkstra table, common
random numbers across plans).  ``--smoke`` shrinks the sweep and
parity-checks the printed numbers against the legacy per-plan NumPy
simulator.

    PYTHONPATH=src python examples/placement_study.py [--smoke]
"""
import argparse
import dataclasses

import numpy as np

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        evaluate_plans, rand_intra_cg_plan, sample_topology,
                        simulate_token_generation_legacy, spacemoe_plan)

N_LAYERS, N_EXPERTS, TOP_K = 8, 8, 2   # N_y >= L must hold at every size


def latency(ccfg, seed=0, n_tokens=200, check_legacy=False):
    con = Constellation(ccfg)
    topo = sample_topology(con, LinkConfig(), np.random.default_rng(seed))
    activ = ActivationModel.zipf(N_LAYERS, N_EXPERTS, TOP_K, seed=1)
    wl = MoEWorkload.llama_moe_3p5b()
    comp = ComputeConfig()
    plans = [
        spacemoe_plan(con, topo, activ, wl, comp),
        rand_intra_cg_plan(ccfg, N_LAYERS, N_EXPERTS, np.random.default_rng(7)),
    ]
    # One batched sweep; both plans share the rng(5) token stream — the
    # same draws the legacy path consumed per plan.
    sm, cg = evaluate_plans(plans, topo, activ, wl, comp,
                            np.random.default_rng(5), n_tokens=n_tokens)
    if check_legacy:
        for plan, res in zip(plans, (sm, cg)):
            ref = simulate_token_generation_legacy(
                plan, topo, activ, wl, comp, np.random.default_rng(5),
                n_tokens)
            np.testing.assert_allclose(res.mean_s, ref.mean_s, rtol=1e-5)
    return sm.mean_s, cg.mean_s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep + engine/legacy parity check")
    args = ap.parse_args()
    n_tok = 60 if args.smoke else 200
    check = args.smoke

    base = ConstellationConfig.scaled(17, 16, n_slots=30)
    if args.smoke:
        base = ConstellationConfig.scaled(13, 12, n_slots=10)

    print("altitude sweep (s/token):")
    for alt in (350, 550, 800, 1100):
        sm, cg = latency(dataclasses.replace(base, altitude_km=float(alt)),
                         n_tokens=n_tok, check_legacy=check)
        print(f"  {alt:5d} km: SpaceMoE {sm:.3f}  RandIntra-CG {cg:.3f}")
    print("survival-probability sweep:")
    for p in (0.8, 0.9, 0.95, 1.0):
        sm, cg = latency(dataclasses.replace(base, survival_prob=p),
                         n_tokens=n_tok, check_legacy=check)
        print(f"  P_sw={p:.2f}: SpaceMoE {sm:.3f}  RandIntra-CG {cg:.3f}")
    print("constellation-size sweep:")
    sizes = ((13, 12), (17, 16)) if args.smoke else \
        ((13, 12), (17, 16), (25, 24))
    for nx, ny in sizes:
        sm, cg = latency(ConstellationConfig.scaled(
            nx, ny, n_slots=10 if args.smoke else 30),
            n_tokens=n_tok, check_legacy=check)
        print(f"  {nx}x{ny} ({nx*ny} sats): SpaceMoE {sm:.3f}  "
              f"RandIntra-CG {cg:.3f}")
    if args.smoke:
        print("smoke parity: engine numbers match the legacy simulator")


if __name__ == "__main__":
    main()
