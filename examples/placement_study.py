"""Placement study: how network parameters shape SpaceMoE's advantage
(a quick interactive version of paper Fig. 7).

    PYTHONPATH=src python examples/placement_study.py
"""
import dataclasses

import numpy as np

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        rand_intra_cg_plan, sample_topology,
                        simulate_token_generation, spacemoe_plan)

N_LAYERS, N_EXPERTS, TOP_K = 8, 8, 2   # N_y >= L must hold at every size


def latency(ccfg, seed=0, n_tokens=200):
    con = Constellation(ccfg)
    topo = sample_topology(con, LinkConfig(), np.random.default_rng(seed))
    activ = ActivationModel.zipf(N_LAYERS, N_EXPERTS, TOP_K, seed=1)
    wl = MoEWorkload.llama_moe_3p5b()
    comp = ComputeConfig()
    sm = simulate_token_generation(
        spacemoe_plan(con, topo, activ, wl, comp), topo, activ, wl, comp,
        np.random.default_rng(5), n_tokens)
    cg = simulate_token_generation(
        rand_intra_cg_plan(ccfg, N_LAYERS, N_EXPERTS, np.random.default_rng(7)),
        topo, activ, wl, comp, np.random.default_rng(5), n_tokens)
    return sm.mean_s, cg.mean_s


def main():
    base = ConstellationConfig.scaled(17, 16, n_slots=30)
    print("altitude sweep (s/token):")
    for alt in (350, 550, 800, 1100):
        sm, cg = latency(dataclasses.replace(base, altitude_km=float(alt)))
        print(f"  {alt:5d} km: SpaceMoE {sm:.3f}  RandIntra-CG {cg:.3f}")
    print("survival-probability sweep:")
    for p in (0.8, 0.9, 0.95, 1.0):
        sm, cg = latency(dataclasses.replace(base, survival_prob=p))
        print(f"  P_sw={p:.2f}: SpaceMoE {sm:.3f}  RandIntra-CG {cg:.3f}")
    print("constellation-size sweep:")
    for nx, ny in ((13, 12), (17, 16), (25, 24)):
        sm, cg = latency(ConstellationConfig.scaled(nx, ny, n_slots=30))
        print(f"  {nx}x{ny} ({nx*ny} sats): SpaceMoE {sm:.3f}  "
              f"RandIntra-CG {cg:.3f}")


if __name__ == "__main__":
    main()
