"""Config registry: the 10 assigned architectures + the paper's model.

``get_config(arch_id)`` returns the full published config;
``smoke_config(arch_id)`` returns a reduced same-family config for CPU
smoke tests (same pattern/MoE/GQA structure, tiny dims).
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

from . import (deepseek_moe_16b, granite_moe_3b_a800m, jamba_1_5_large_398b,
               llama_moe_3p5b, llava_next_mistral_7b, minicpm_2b,
               mistral_large_123b, musicgen_medium, qwen2_5_3b, smollm_135m,
               xlstm_350m)
from .shapes import SHAPES, ShapeSpec, shape_applies

_MODULES = [
    granite_moe_3b_a800m, deepseek_moe_16b, jamba_1_5_large_398b,
    llava_next_mistral_7b, qwen2_5_3b, minicpm_2b, smollm_135m,
    mistral_large_123b, musicgen_medium, xlstm_350m, llama_moe_3p5b,
]

REGISTRY: dict[str, ModelConfig] = {m.ARCH_ID: m.CONFIG for m in _MODULES}
ASSIGNED: tuple[str, ...] = tuple(m.ARCH_ID for m in _MODULES[:10])


def list_archs(include_paper_model: bool = True) -> list[str]:
    return list(REGISTRY) if include_paper_model else list(ASSIGNED)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch_id]


def smoke_config(arch_id: str) -> ModelConfig:
    """Reduced same-family config: small width/depth, few experts, tiny
    vocab — structure (pattern, GQA ratio, shared experts, frontend,
    first-dense-layer) preserved."""
    cfg = get_config(arch_id)
    n_kv = max(1, round(4 * cfg.n_kv_heads / cfg.n_heads))
    while 4 % n_kv:
        n_kv -= 1
    units = 2 + (1 if cfg.first_layer_dense else 0)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=units * len(cfg.pattern),
        d_model=64,
        n_heads=4,
        n_kv_heads=n_kv,
        head_dim=16,
        d_ff=96 if cfg.d_ff else 0,
        d_ff_expert=32 if cfg.n_experts else 0,
        n_experts=min(cfg.n_experts, 8),
        top_k=min(cfg.top_k, 4),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        first_dense_d_ff=64 if cfg.first_layer_dense else 0,
        vocab_size=512,
        vocab_pad_multiple=16,
        mamba_dt_rank=4,
        attn_q_chunk=16,
        attn_kv_chunk=16,
        compute_dtype="float32",
    )


__all__ = ["REGISTRY", "ASSIGNED", "SHAPES", "ShapeSpec", "shape_applies",
           "list_archs", "get_config", "smoke_config"]
