"""musicgen-medium [audio] — 48L d1536 24H (MHA kv=24) d_ff=6144
vocab 2048; decoder-only over EnCodec tokens.  [arXiv:2306.05284]

The EnCodec tokenizer + codebook-interleaving frontend is a STUB:
``input_specs()`` supplies precomputed frame embeddings; the codec token
ids remain the prediction targets.  (FFN family normalized to SwiGLU
across the zoo; see DESIGN.md.)
"""
from repro.models.config import LayerSpec, ModelConfig

ARCH_ID = "musicgen-medium"

CONFIG = ModelConfig(
    name=ARCH_ID,
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    pattern=(LayerSpec("attn", "dense"),),
    rope_theta=10000.0,
    frontend="audio",
)
