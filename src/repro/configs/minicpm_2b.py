"""minicpm-2b [dense] — 40L d2304 36H (MHA kv=36) d_ff=5760 vocab 122753;
llama-like arch, trained with the WSD schedule (see repro.optim.schedules).
[arXiv:2404.06395]
"""
from repro.models.config import LayerSpec, ModelConfig

ARCH_ID = "minicpm-2b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    pattern=(LayerSpec("attn", "dense"),),
    rope_theta=10000.0,
    tie_embeddings=True,
)
