"""llama-moe-3.5b — the paper's own model (Sec. VII-A2): LLaMA-MoE-3.5B
(2/8), 32 MoE layers x 8 experts, top-2; experts are the LLaMA-2-7B FFN
(d_ff 11008) split 8 ways (d_ff 1376 each).  [arXiv:2406.16554]

This is the model SpaceMoE places over the constellation; it is also a
selectable ``--arch`` like the assigned ten.
"""
from repro.models.config import LayerSpec, ModelConfig

ARCH_ID = "llama-moe-3.5b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=1376,
    vocab_size=32000,
    pattern=(LayerSpec("attn", "moe"),),
    n_experts=8,
    top_k=2,
    d_ff_expert=1376,
    rope_theta=10000.0,
)
