"""Assigned input shapes (the x-axis of the 40-cell dry-run matrix)."""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applies(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason).  long_500k only for sub-quadratic (SSM/hybrid) archs
    — pure full-attention archs skip it (see DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "SKIP(full-attention): 512k dense KV cache is not this arch"
    return True, ""
