"""mistral-large-123b [dense] — 88L d12288 96H (GQA kv=8) d_ff=28672
vocab 32768.  [hf:mistralai/Mistral-Large-Instruct-2407]

The biggest dense arch in the pool — the compute-roofline anchor for the
train_4k cell.
"""
from repro.models.config import LayerSpec, ModelConfig

ARCH_ID = "mistral-large-123b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=32768,
    pattern=(LayerSpec("attn", "dense"),),
    rope_theta=1_000_000.0,
)
