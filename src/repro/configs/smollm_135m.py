"""smollm-135m [dense] — 30L d576 9H (GQA kv=3) d_ff=1536 vocab 49152;
llama-arch small.  [hf:HuggingFaceTB/SmolLM-135M]

Also the arch used by the real end-to-end training driver
(examples/train_smollm.py): ~135M params trains for a few hundred steps on
CPU in this container.
"""
from repro.models.config import LayerSpec, ModelConfig

ARCH_ID = "smollm-135m"

CONFIG = ModelConfig(
    name=ARCH_ID,
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    pattern=(LayerSpec("attn", "dense"),),
    rope_theta=10000.0,
    tie_embeddings=True,
)
