"""jamba-1.5-large-398b [hybrid] — 72L d8192 64H (GQA kv=8) d_ff=24576,
vocab 65536, MoE 16e top-2, Mamba:attention 7:1 interleave.
[arXiv:2403.19887]

Pattern unit of 8 layers (9 scan units): attention at position 4, Mamba
elsewhere; MoE replaces the FFN on every other layer (4 MoE / 4 dense per
unit), matching Jamba's e=2 MoE stride.  Runs long_500k: the Mamba state is
O(1) per token and only 9 attention layers keep KV.
"""
from repro.models.config import LayerSpec, ModelConfig

ARCH_ID = "jamba-1.5-large-398b"

_UNIT = tuple(
    LayerSpec("attn" if i == 4 else "mamba", "moe" if i % 2 == 0 else "dense")
    for i in range(8)
)

CONFIG = ModelConfig(
    name=ARCH_ID,
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    pattern=_UNIT,
    n_experts=16,
    top_k=2,
    d_ff_expert=24576,
    mamba_d_state=16,
    mamba_expand=2,
    mamba_d_conv=4,
    rope_theta=10000.0,
)
