"""granite-moe-3b-a800m [moe] — 32L d1536 24H (GQA kv=8) d_ff=512/expert,
vocab 49155, MoE 40 experts top-8.  [hf:ibm-granite/granite-3.0-3b-a800m]

40 experts do not divide the 16-way model axis, so the sharding rules fall
back to tensor parallelism inside experts (d_ff=512 shards 16-way into 32
columns); SpaceMoE placement still reorders the expert stack (slot order
matters for the serving-latency accounting even under TP).
"""
from repro.models.config import LayerSpec, ModelConfig

ARCH_ID = "granite-moe-3b-a800m"

CONFIG = ModelConfig(
    name=ARCH_ID,
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,                 # per-expert (fine-grained MoE)
    vocab_size=49155,
    pattern=(LayerSpec("attn", "moe"),),
    n_experts=40,
    top_k=8,
    d_ff_expert=512,
    rope_theta=10000.0,
    tie_embeddings=True,
)
