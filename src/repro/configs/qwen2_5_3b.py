"""qwen2.5-3b [dense] — 36L d2048 16H (GQA kv=2) d_ff=11008 vocab 151936;
QKV bias, tied embeddings.  [hf:Qwen/Qwen2.5-3B]
"""
from repro.models.config import LayerSpec, ModelConfig

ARCH_ID = "qwen2.5-3b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    pattern=(LayerSpec("attn", "dense"),),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
