"""llava-next-mistral-7b [vlm] — Mistral-7B backbone: 32L d4096 32H
(GQA kv=8) d_ff=14336 vocab 32000; anyres vision tiling.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]

The CLIP tower + anyres projector are a STUB per the assignment:
``input_specs()`` supplies 576 precomputed patch embeddings (one base
tile) prepended to the text tokens.
"""
from repro.models.config import LayerSpec, ModelConfig

ARCH_ID = "llava-next-mistral-7b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    pattern=(LayerSpec("attn", "dense"),),
    rope_theta=1_000_000.0,
    frontend="vision",
)
