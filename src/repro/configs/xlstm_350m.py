"""xlstm-350m [ssm] — 24L d1024 4H d_ff=0 vocab 50304; sLSTM + mLSTM
blocks (7:1 mLSTM:sLSTM), recurrent O(1) decode state.  [arXiv:2405.04517]

d_ff=0 per the assignment: the xLSTM blocks carry their own up/down
projections (d_inner = 2*d_model); there is no separate FFN sub-block.
Runs long_500k natively.
"""
from repro.models.config import LayerSpec, ModelConfig

ARCH_ID = "xlstm-350m"

_UNIT = tuple(
    LayerSpec("slstm" if i == 7 else "mlstm", "none") for i in range(8)
)

CONFIG = ModelConfig(
    name=ARCH_ID,
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pattern=_UNIT,
    mamba_expand=2,          # d_inner = 2 * d_model for the lstm blocks
    tie_embeddings=False,
)
