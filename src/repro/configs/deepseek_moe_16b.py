"""deepseek-moe-16b [moe] — 28L d2048 16H (MHA kv=16) d_ff=1408/expert,
vocab 102400, 2 shared + 64 routed top-6, fine-grained; first layer dense
(d_ff 10944).  [arXiv:2401.06066]

The hero arch for the paper's technique: fine-grained experts have the most
skewed activation statistics, and 64 experts divide the 16-way model axis
exactly (4 experts/device — the Sec. VI-B multi-expert regime).  Shared
experts are the P_i -> 1 limit of Theorem 1: always active, so they are
pinned (replicated) rather than placed.
"""
from repro.models.config import LayerSpec, ModelConfig

ARCH_ID = "deepseek-moe-16b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,                # per routed expert
    vocab_size=102400,
    pattern=(LayerSpec("attn", "moe"),),
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    d_ff_expert=1408,
    first_layer_dense=True,
    first_dense_d_ff=10944,
    rope_theta=10000.0,
)
