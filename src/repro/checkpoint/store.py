"""Fault-tolerant checkpointing: atomic npz + manifest, retention, resume.

Write protocol (crash-safe at every point):
  1. serialize pytree leaves to ``step_N.tmp.npz``
  2. fsync + atomic ``rename`` to ``step_N.npz``
  3. rewrite ``manifest.json`` (atomic rename) pointing at the new step
A torn write can only ever lose the newest checkpoint, never corrupt an
older one; ``latest_step`` only trusts steps listed in the manifest whose
file exists and passes a length check.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    leaves, treedef = jax.tree.flatten(tree)
    return (
        {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)},
        treedef,
    )


def save_pytree(path: str, tree) -> None:
    arrays, _ = _flatten(tree)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def restore_pytree(path: str, like) -> object:
    leaves, treedef = jax.tree.flatten(like)
    with np.load(path) as data:
        if len(data.files) != len(leaves):
            raise ValueError(
                f"checkpoint {path} has {len(data.files)} leaves, "
                f"expected {len(leaves)}"
            )
        new = [data[f"leaf_{i}"] for i in range(len(leaves))]
    for a, b in zip(new, leaves):
        if a.shape != b.shape:
            raise ValueError(f"leaf shape mismatch: {a.shape} vs {b.shape}")
    return jax.tree.unflatten(treedef, new)


def _manifest_path(ckpt_dir: str) -> str:
    return os.path.join(ckpt_dir, "manifest.json")


def latest_step(ckpt_dir: str) -> int | None:
    mf = _manifest_path(ckpt_dir)
    if not os.path.exists(mf):
        return None
    with open(mf) as f:
        manifest = json.load(f)
    for step in sorted(manifest.get("steps", []), reverse=True):
        if os.path.exists(os.path.join(ckpt_dir, f"step_{step}.npz")):
            return int(step)
    return None


class CheckpointManager:
    """Step-indexed checkpoints with retention and resume."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step}.npz")

    def save(self, step: int, tree) -> None:
        save_pytree(self._path(step), tree)
        mf = _manifest_path(self.dir)
        steps = []
        if os.path.exists(mf):
            with open(mf) as f:
                steps = json.load(f).get("steps", [])
        steps = sorted(set(steps + [step]))
        # retention: drop oldest beyond `keep`
        drop, steps = steps[:-self.keep], steps[-self.keep:]
        tmp = mf + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"steps": steps}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, mf)
        for s in drop:
            try:
                os.remove(self._path(s))
            except FileNotFoundError:
                pass

    def restore_latest(self, like) -> tuple[int, object] | None:
        step = latest_step(self.dir)
        if step is None:
            return None
        return step, restore_pytree(self._path(step), like)
