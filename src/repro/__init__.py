"""SpaceMoE reproduction: core placement + JAX multi-pod framework."""
