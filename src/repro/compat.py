"""Version compatibility shims for the jax API surface this repo uses.

The repo targets a range of jax versions:

- ``shard_map`` graduated from ``jax.experimental.shard_map`` to the
  top-level ``jax.shard_map`` (jax >= 0.6), and its replication-check
  keyword was renamed ``check_rep`` -> ``check_vma`` along the way.
- ``jax.lax.axis_size`` does not exist on 0.4.x (there the static axis
  size comes from ``jax.core.axis_frame``).
- ``Compiled.cost_analysis()`` returned a single-element list on 0.4.x
  and a flat dict on newer jax.

Call sites are written once against the newest spelling and routed
through the shims here.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.6: top-level export
    _shard_map_impl = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:  # jax 0.4.x: experimental module, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map_impl
    _CHECK_KW = "check_rep"


def shard_map(f, mesh, in_specs, out_specs, *,
              check_vma: bool | None = None,
              check_rep: bool | None = None, **kwargs):
    """Map ``f`` over shards of its inputs (see ``jax.shard_map``).

    ``check_vma`` (new name) and ``check_rep`` (pre-0.6 name) are the same
    flag; pass either.  Defaults to the underlying implementation's default
    when both are None.
    """
    if check_vma is not None and check_rep is not None and check_vma != check_rep:
        raise ValueError("check_vma and check_rep are aliases; got conflicting values")
    flag = check_vma if check_vma is not None else check_rep
    if flag is not None:
        kwargs[_CHECK_KW] = flag
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kwargs)


def axis_size(axis_name) -> int:
    """Static size of a mapped axis (``jax.lax.axis_size`` on new jax)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    from jax import core
    frame = core.axis_frame(axis_name)   # 0.4.37 returns the size itself;
    return frame if isinstance(frame, int) else frame.size


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized to one flat dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return cost
