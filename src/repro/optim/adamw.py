"""Minimal sharding-friendly AdamW (pytree-native, optax-free).

Moments mirror the parameter pytree, so any parameter PartitionSpec tree
applies verbatim to the optimizer state (ZeRO-1 style sharding is a spec
change, not a code change).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4                # peak; multiplied by schedule(step)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, params, grads, state, lr_scale):
    """One AdamW step.  ``lr_scale``: schedule value at this step."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * clip, grads)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state["mu"], grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state["nu"], grads)
    c1 = 1 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, m, v):
        step = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "count": count}, gnorm
