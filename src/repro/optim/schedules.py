"""LR schedules: cosine and WSD (warmup-stable-decay, MiniCPM's schedule).

Each returns a function step -> multiplier in [0, 1] (jnp-traceable).
"""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup_steps, 1)
        prog = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        prog = jnp.clip(prog, 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn


def wsd_schedule(warmup_steps: int, total_steps: int,
                 decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup -> long stable plateau -> short (1-decay_frac tail) decay.

    MiniCPM (arXiv:2404.06395) Sec. 4: the stable phase runs at peak LR and
    the final ``decay_frac`` of steps decays exponentially-ish; we use the
    paper's simpler linear-in-log decay to ``final_frac``.
    """
    decay_start = int(total_steps * (1.0 - decay_frac))

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup_steps, 1)
        in_decay = (step - decay_start) / jnp.maximum(total_steps - decay_start, 1)
        in_decay = jnp.clip(in_decay, 0.0, 1.0)
        decay = jnp.exp(jnp.log(jnp.maximum(final_frac, 1e-6)) * in_decay)
        out = jnp.where(step < warmup_steps, warm, 1.0)
        return jnp.where(step >= decay_start, decay, out)
    return fn
