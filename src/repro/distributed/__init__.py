from .elastic import (Migration, migration, replan_on_failure,
                      replan_with_stragglers)
from .sharding import ShardingRules

__all__ = ["Migration", "migration", "replan_on_failure",
           "replan_with_stragglers", "ShardingRules"]
