"""Sharding rules: parameter / cache / batch PartitionSpecs per mesh.

Megatron-style tensor parallelism over the ``model`` axis (attention heads,
FFN hidden, vocab), expert parallelism over the same axis when the expert
count divides it (otherwise experts fall back to TP over d_ff), batch over
``("pod", "data")``.  Every rule is divisibility-guarded: a dimension that
does not divide the axis is replicated instead of erroring, and the
decision is recorded so the dry-run can report it.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig


def _shard(dim: int, axis: str, size: int) -> str | None:
    return axis if (size > 1 and dim % size == 0) else None


class ShardingRules:
    """Builds PartitionSpec pytrees for a (cfg, mesh) pair."""

    def __init__(self, cfg: ModelConfig, mesh, model_axis: str = "model",
                 data_axes: tuple[str, ...] = ("data",),
                 zero_opt: bool = False):
        self.cfg = cfg
        self.mesh = mesh
        self.model_axis = model_axis
        self.data_axes = data_axes
        self.n_model = mesh.shape[model_axis] if mesh is not None else 1
        self.zero_opt = zero_opt      # ZeRO-1: moments sharded over data too
        self.decisions: dict[str, str] = {}

    # ----------------------------------------------------------------- #
    def _m(self, dim: int) -> str | None:
        return _shard(dim, self.model_axis, self.n_model)

    @property
    def batch_axes(self):
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]

    @property
    def n_data(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.data_axes:
            n *= self.mesh.shape[a]
        return n

    def _b(self, dim: int):
        """Batch axes if the dim divides them, else replicate."""
        return self.batch_axes if (self.n_data > 1 and dim % self.n_data == 0) \
            else None

    def _record(self, path: str, spec: P) -> P:
        self.decisions[path] = str(spec)
        return spec

    # ----------------------------------------------------------------- #
    def _mixer_of(self, names: list) -> str:
        """Which mixer family owns this param (from the bN pattern slot)."""
        if "first" in names:
            return self.cfg.pattern[0].mixer
        for n in names:
            if len(n) > 1 and n[0] == "b" and n[1:].isdigit():
                return self.cfg.pattern[int(n[1:])].mixer
        return "attn"

    def param_spec(self, path: tuple, leaf) -> P:
        names = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
        name = names[-1]
        stacked = 1 if names[0] == "units" else 0   # leading unit-scan dim
        shape = leaf.shape[stacked:]
        pre = (None,) * stacked
        cfg = self.cfg

        def out(*spec):
            return self._record("/".join(names), P(*pre, *spec))

        # ---- embeddings / head ----
        if name == "embed":
            return out(self._m(shape[0]), None)
        if name == "head":
            return out(None, self._m(shape[1]))

        # ---- MoE expert stacks: (E, d, f) / (E, f, d) ----
        if "ffn" in names and name in ("w_gate", "w_up", "w_down") \
                and len(shape) == 3:
            e = shape[0]
            if self.n_model > 1 and e % self.n_model == 0:
                return out(self.model_axis, None, None)      # EP
            # TP inside experts: shard the d_ff dimension
            ff_axis = 2 if name in ("w_gate", "w_up") else 1
            spec = [None, None, None]
            spec[ff_axis] = self._m(shape[ff_axis])
            return out(*spec)
        if name == "router":
            return out(None, None)

        # ---- dense FFN (+ shared experts) ----
        if name in ("w_gate", "w_up") and len(shape) == 2:
            return out(None, self._m(shape[1]))
        if name == "w_down" and len(shape) == 2:
            return out(self._m(shape[0]), None)

        # ---- attention ----
        if name in ("w_q", "w_k", "w_v"):
            return out(None, self._m(shape[1]))
        if name in ("b_q", "b_k", "b_v"):
            return out(self._m(shape[0]))
        if name == "w_o":
            return out(self._m(shape[0]), None)

        # ---- mamba ----
        if name == "w_in":
            return out(None, self._m(shape[1]))
        if name in ("conv_w",):
            return out(None, self._m(shape[1]))
        if name in ("conv_b", "d_skip", "dt_bias"):
            return out(self._m(shape[0]))
        if name in ("w_x_proj",):
            return out(self._m(shape[0]), None)
        if name == "w_dt":
            return out(None, self._m(shape[1]))
        if name == "a_log":
            return out(self._m(shape[0]), None)

        # ---- xLSTM ----
        # mLSTM: q/k/v/z column-sharded (head-dim).  The per-step scan then
        # carries many SMALL collectives (k broadcast per step) — bytes are
        # negligible (see EXPERIMENTS.md §Dry-run), but the op COUNT is a
        # real-hardware latency concern; the measured alternatives (full
        # replication; row-sharded matrix memory) are strictly worse on
        # bytes (310s / 119s vs 41s memory+collective) because scan-AD
        # transposes re-reduce per step.  A chunked custom-VJP mLSTM is the
        # production fix (future work, logged in §Perf D).
        # sLSTM: tiny state, block-diagonal recurrence -> data-parallel only.
        if name in ("b_gates", "w_gates", "r_h"):
            return out(*([None] * len(shape)))
        if name in ("w_q_m", "w_k_m", "w_v_m"):
            return out(None, self._m(shape[1]))
        if name == "w_x":                        # slstm input projection
            return out(None, None)
        if name == "w_z":
            return out(None, self._m(shape[1]))
        if name == "w_out":
            mixer = self._mixer_of(names)
            if mixer == "slstm":
                return out(None, None)
            return out(self._m(shape[0]), None)

        # norms, gates, biases, scalars -> replicated
        return out(*([None] * len(shape)))

    def param_specs(self, params) -> object:
        return jax.tree_util.tree_map_with_path(self.param_spec, params)

    # ----------------------------------------------------------------- #
    def cache_spec(self, path: tuple, leaf) -> P:
        names = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
        name = names[-1]
        stacked = 1 if names[0] == "units" else 0
        shape = leaf.shape[stacked:]
        pre = (None,) * stacked
        b = self._b(shape[0])
        if name in ("k", "v"):          # (B, S, Hkv, hd)
            # batch=1 (long-context): context parallelism — shard the cache
            # sequence dim over the batch axes instead.
            s_axis = None
            if b is None and shape[1] % max(self.n_data, 1) == 0:
                s_axis = self.batch_axes
            return P(*pre, b, s_axis, self._m(shape[2]), None)
        if name == "ssm":               # (B, di, N)
            return P(*pre, b, self._m(shape[1]), None)
        if name == "conv":              # (B, dc-1, di)
            return P(*pre, b, None, self._m(shape[2]))
        if name in ("c", "n", "h", "m") and len(shape) >= 2:
            return P(*pre, b, *([None] * (len(shape) - 1)))
        return P(*pre, *([None] * len(shape)))

    def cache_specs(self, cache) -> object:
        return jax.tree_util.tree_map_with_path(self.cache_spec, cache)

    # ----------------------------------------------------------------- #
    def batch_spec(self, batch) -> object:
        def spec(path, leaf):
            if leaf is None:
                return None
            return P(self._b(leaf.shape[0]), *([None] * (leaf.ndim - 1)))

        return jax.tree_util.tree_map_with_path(spec, batch)

    def opt_state_specs(self, opt_state, params_specs) -> object:
        """Moments mirror params; step counter replicated.

        With ``zero_opt`` (ZeRO-1), each moment additionally shards its
        largest unsharded divisible dim over the data axes — XLA then
        reduce-scatters gradients into the moment shards and all-gathers
        the updated params, cutting optimizer memory by |data axes|.
        """
        if not self.zero_opt:
            return {"mu": params_specs, "nu": params_specs, "count": P()}

        def zero(spec: P, leaf) -> P:
            entries = list(spec) + [None] * (leaf.ndim - len(spec))
            best, best_dim = -1, 0
            for i, (e, dim) in enumerate(zip(entries, leaf.shape)):
                if e is None and dim % max(self.n_data, 1) == 0 \
                        and dim > best_dim and self.n_data > 1:
                    best, best_dim = i, dim
            if best >= 0:
                entries[best] = self.batch_axes
            return P(*entries)

        mu_specs = jax.tree.map(
            zero, params_specs, opt_state["mu"],
            is_leaf=lambda s: isinstance(s, P),
        )
        return {"mu": mu_specs, "nu": mu_specs, "count": P()}
