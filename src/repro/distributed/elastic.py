"""Elastic re-placement + straggler mitigation (fault tolerance layer).

The paper's link outages (Eq. 3) map to device/link failures on the TPU
torus.  When the device set degrades, the Theorem-1 machinery re-derives
the expert->device mapping over the survivors; the diff between the old
and new plans is the minimal weight-migration set.  Stragglers are the
soft version: a slow device keeps its slots but its expected cost is
inflated, so the re-plan drains hot experts away from it.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.activation import activation_probs
from repro.core.device_placement import (DevicePlacementPlan, TorusSpec,
                                         hop_cost_s)
from repro.core.placement import theorem1_assignment


@dataclasses.dataclass
class Migration:
    """Weight movement needed to adopt a new placement plan."""

    moved_experts: np.ndarray          # expert ids that change device
    bytes_moved: float
    old_devices: np.ndarray
    new_devices: np.ndarray


def _plan_from_costs(router_weights: np.ndarray, top_k: int,
                     device_cost: np.ndarray, devices: np.ndarray,
                     n_experts: int, origin: int) -> DevicePlacementPlan:
    epd = -(-n_experts // len(devices))          # ceil: multi-expert slots
    slot_cost = np.repeat(device_cost, epd)
    probs = activation_probs(router_weights, top_k)
    assign = theorem1_assignment(probs, slot_cost)       # expert -> slot
    perm = np.full(len(devices) * epd, -1, dtype=np.int64)  # -1 = empty slot
    perm[assign] = np.arange(n_experts)
    return DevicePlacementPlan(
        expert_perm=perm,
        device_cost_s=device_cost,
        experts_per_device=epd,
        origin=origin,
    )


def replan_on_failure(
    router_weights: np.ndarray,
    top_k: int,
    torus: TorusSpec,
    failed_devices: set[int],
    origin: int = 0,
    bytes_per_token: float = 2 * 4096.0,
) -> tuple[DevicePlacementPlan, np.ndarray]:
    """Re-derive placement on the surviving device set.

    Returns (plan, survivor device ids).  Experts per surviving device grows
    to ceil(E / survivors) — the Sec. VI-B multi-expert regime kicks in
    automatically when capacity shrinks.
    """
    survivors = np.array(
        [d for d in range(torus.n_devices) if d not in failed_devices]
    )
    if len(survivors) == 0:
        raise ValueError("no surviving devices")
    if origin in failed_devices:
        origin = int(survivors[0])
    hops = torus.hop_distance(origin)[survivors]
    cost = 2.0 * hop_cost_s(hops, bytes_per_token)
    plan = _plan_from_costs(router_weights, top_k, cost, survivors,
                            len(router_weights), origin)
    return plan, survivors


def replan_with_stragglers(
    router_weights: np.ndarray,
    top_k: int,
    torus: TorusSpec,
    straggler_slowdown: dict[int, float],
    origin: int = 0,
    bytes_per_token: float = 2 * 4096.0,
) -> DevicePlacementPlan:
    """Inflate straggler costs and re-run Theorem 1 (soft mitigation)."""
    devices = np.arange(torus.n_devices)
    hops = torus.hop_distance(origin)
    cost = 2.0 * hop_cost_s(hops, bytes_per_token)
    for dev, slow in straggler_slowdown.items():
        cost[dev] = cost[dev] * slow + 1e-6 * (slow - 1.0)
    return _plan_from_costs(router_weights, top_k, cost, devices,
                            len(router_weights), origin)


def migration(old: DevicePlacementPlan, new: DevicePlacementPlan,
              bytes_per_expert: float,
              new_devices: np.ndarray | None = None) -> Migration:
    """Experts whose hosting device changes between two plans."""
    n_exp = old.n_experts
    old_dev = np.array([old.device_of_expert(e) for e in range(n_exp)])
    dev_ids = (np.arange(len(new.device_cost_s)) if new_devices is None
               else np.asarray(new_devices))
    new_dev = dev_ids[new.inverse_perm[:n_exp] // new.experts_per_device]
    moved = np.where(old_dev != new_dev)[0]
    return Migration(
        moved_experts=moved,
        bytes_moved=float(len(moved) * bytes_per_expert),
        old_devices=old_dev[moved],
        new_devices=new_dev[moved],
    )
