"""TPU adaptation of SpaceMoE: expert->device placement on an ICI torus.

The paper's constellation is a cylindrical 2-D mesh — structurally a TPU
ICI torus.  We transplant the identical machinery:

  satellite            -> TPU chip (a coordinate on the ICI torus)
  laser ISL hop        -> ICI link hop (alpha + bytes/bandwidth)
  gateway satellite    -> the dispatch-origin shard of the MoE layer
  expected path latency tau_bar_s -> expected round-trip hop cost
  Theorem 1            -> expert->device permutation (hot experts near the
                          dispatch origin)

The resulting :class:`DevicePlacementPlan` is consumed by
``repro.models.moe`` as a static permutation of the expert axis, and by the
serving-latency accounting.  The objective value (expected slowest-path
cost, Eq. 33) is computed with the same closed form as the space case.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from .activation import activation_probs
from .objective import layer_latency_closed_form
from .placement import theorem1_assignment

# v5e-class ICI constants (per link); see EXPERIMENTS.md hardware table.
ICI_LINK_GBPS = 50.0
ICI_HOP_LATENCY_US = 1.0     # per-hop switching+serialization alpha


@dataclasses.dataclass(frozen=True)
class TorusSpec:
    """An ICI torus (or mesh) of devices, e.g. (16, 16) per pod."""

    shape: tuple[int, ...]
    wrap: bool = True     # torus (wraparound links) vs open mesh

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.shape))

    def coords(self) -> np.ndarray:
        """(n_devices, ndim) integer coordinates, row-major device order."""
        grids = np.meshgrid(*[np.arange(s) for s in self.shape], indexing="ij")
        return np.stack([g.ravel() for g in grids], axis=1)

    def hop_distance(self, origin: int) -> np.ndarray:
        """Torus Manhattan hop count from ``origin`` to every device."""
        c = self.coords()
        d = np.abs(c - c[origin])
        if self.wrap:
            d = np.minimum(d, np.asarray(self.shape) - d)
        return d.sum(axis=1)

    def all_pair_hops(self) -> np.ndarray:
        c = self.coords()
        d = np.abs(c[:, None, :] - c[None, :, :])
        if self.wrap:
            d = np.minimum(d, np.asarray(self.shape) - d)
        return d.sum(axis=2)


def hop_cost_s(hops: np.ndarray, bytes_per_token: float) -> np.ndarray:
    """Per-destination dispatch cost: alpha*hops + store-and-forward bytes."""
    alpha = ICI_HOP_LATENCY_US * 1e-6
    bw = ICI_LINK_GBPS * 1e9
    return hops * alpha + np.where(hops > 0, bytes_per_token / bw, 0.0) * np.maximum(hops, 1)


@dataclasses.dataclass
class DevicePlacementPlan:
    """Static expert->device map for the EP axis of one MoE layer group.

    ``expert_perm`` reorders the expert axis: ``expert_perm[slot]`` is the
    expert id stored in EP slot ``slot`` (slots are laid out device-major,
    ``experts_per_device`` consecutive slots per device, devices sorted by
    the EP axis order of the mesh).  Slots may outnumber experts after an
    elastic re-plan; empty slots hold -1.
    """

    expert_perm: np.ndarray          # (n_slots,) slot -> expert id or -1
    device_cost_s: np.ndarray        # (n_devices,) expected round-trip cost
    experts_per_device: int
    origin: int

    @property
    def n_experts(self) -> int:
        return int((self.expert_perm >= 0).sum())

    @property
    def inverse_perm(self) -> np.ndarray:
        inv = np.full(self.n_experts, -1, dtype=np.int64)
        for slot, e in enumerate(self.expert_perm):
            if e >= 0:
                inv[e] = slot
        return inv                   # expert id -> slot

    def device_of_expert(self, expert: int) -> int:
        return int(self.inverse_perm[expert] // self.experts_per_device)


def plan_expert_devices(
    router_weights: np.ndarray,
    top_k: int,
    torus: TorusSpec,
    ep_devices: np.ndarray | None = None,
    origin: int = 0,
    bytes_per_token: float = 2 * 4096.0,
) -> DevicePlacementPlan:
    """Theorem-1 placement of E experts onto the EP device group.

    Parameters
    ----------
    router_weights: (E,) importance weights (e.g. softmax-mean gate stats).
    ep_devices:     device ids participating in expert parallelism
                    (default: all torus devices).
    origin:         dispatch-origin device (the paper's gateway analogue —
                    in SPMD all devices dispatch, so we use the EP-group
                    centroid by default; callers may pass the attention
                    shard owner for latency-bound decode).
    """
    devices = np.arange(torus.n_devices) if ep_devices is None else np.asarray(ep_devices)
    n_exp = len(router_weights)
    if n_exp % len(devices) != 0:
        raise ValueError(f"E={n_exp} not divisible by |EP group|={len(devices)}")
    epd = n_exp // len(devices)

    hops = torus.hop_distance(origin)[devices]
    cost = 2.0 * hop_cost_s(hops, bytes_per_token)      # dispatch + combine
    probs = activation_probs(np.asarray(router_weights, dtype=np.float64), top_k)

    # Sec. VI-B slotted rule: each device offers `epd` identical-cost slots.
    slot_cost = np.repeat(cost, epd)
    assign = theorem1_assignment(probs, slot_cost)       # expert -> slot
    perm = np.empty(n_exp, dtype=np.int64)
    perm[assign] = np.arange(n_exp)                      # slot -> expert
    return DevicePlacementPlan(
        expert_perm=perm, device_cost_s=cost, experts_per_device=epd, origin=origin
    )


def expected_dispatch_cost(
    plan: DevicePlacementPlan, router_weights: np.ndarray, top_k: int
) -> float:
    """Expected slowest-path cost (Eq. 33) of a device placement."""
    slot_cost = np.repeat(plan.device_cost_s, plan.experts_per_device)
    occupied = plan.expert_perm >= 0
    slot_cost = slot_cost[occupied]
    experts = plan.expert_perm[occupied]
    order = np.argsort(slot_cost, kind="stable")
    tau_sorted = slot_cost[order]
    # rank_to_expert: rank r holds expert experts[order[r]]
    rank_to_expert = experts[order]
    return layer_latency_closed_form(
        tau_sorted, np.asarray(router_weights, dtype=np.float64),
        rank_to_expert, top_k,
    )


def identity_plan(n_experts: int, torus: TorusSpec,
                  origin: int = 0, bytes_per_token: float = 2 * 4096.0
                  ) -> DevicePlacementPlan:
    """No-placement baseline (expert i on slot i) for A/B comparisons."""
    hops = torus.hop_distance(origin)
    cost = 2.0 * hop_cost_s(hops, bytes_per_token)
    epd = max(1, n_experts // torus.n_devices)
    return DevicePlacementPlan(
        expert_perm=np.arange(n_experts), device_cost_s=cost,
        experts_per_device=epd, origin=origin,
    )
