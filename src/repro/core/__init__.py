"""SpaceMoE core — the paper's contribution.

Constellation + time-varying topology (Sec. II), conditional-Poisson
activation model (Sec. III-C), two-level placement with the Theorem-1
optimal intra-layer rule (Sec. IV-V), E2E latency simulator (Sec. IV-B),
and the TPU transplant (expert->device placement on an ICI torus).
"""
from .activation import (ActivationModel, activation_probs,
                         activation_probs_jax, esp, esp_jax,
                         esp_prefix_table, esp_prefix_table_jax, sample_topk,
                         sample_topk_jax, subset_pmf)
from .calibration import (ServiceModel, ServiceTable, calibrate, load_table,
                          resolve_service_model, save_table, verify_table)
from .constellation import (EARTH_RADIUS_M, SPEED_OF_LIGHT, Constellation,
                            ConstellationConfig)
from .device_placement import (DevicePlacementPlan, TorusSpec,
                               expected_dispatch_cost, identity_plan,
                               plan_expert_devices)
from .engine import (PlanBatch, ScheduleBatch, contention_counts,
                     evaluate_plans, evaluate_schedules, hop_latency,
                     ingress_offsets, schedule_ingress_offsets)
from .latency import (ComputeConfig, LinkConfig, TopologySample,
                      expected_path_latency, gateway_distance_table,
                      sample_topology, source_distance_table)
from .objective import (brute_force_optimal, layer_latency_closed_form,
                        layer_latency_monte_carlo)
from .placement import (MultiExpertPlan, PlacementPlan, baseline_plans,
                        central_gateway, multi_expert_plan,
                        rand_intra_cg_plan, rand_intra_plan, rand_place_plan,
                        rank_plans, ring_subnets, spacemoe_plan,
                        subnet_routing_sets, theorem1_assignment)
from .schedule import (PlanSchedule, ScheduleMigration, as_schedule,
                       migration_between, slot_of_time)
from .simulator import (SimResult, simulate_token_generation,
                        simulate_token_generation_legacy)
from .workload import MoEWorkload

__all__ = [
    "ActivationModel", "activation_probs", "activation_probs_jax", "esp",
    "esp_jax", "esp_prefix_table", "esp_prefix_table_jax", "sample_topk",
    "sample_topk_jax", "subset_pmf",
    "EARTH_RADIUS_M", "SPEED_OF_LIGHT", "Constellation", "ConstellationConfig",
    "DevicePlacementPlan", "TorusSpec", "expected_dispatch_cost",
    "identity_plan", "plan_expert_devices",
    "PlanBatch", "ScheduleBatch", "contention_counts", "evaluate_plans",
    "evaluate_schedules", "hop_latency", "ingress_offsets",
    "schedule_ingress_offsets",
    "PlanSchedule", "ScheduleMigration", "as_schedule", "migration_between",
    "slot_of_time",
    "ComputeConfig", "LinkConfig", "TopologySample", "expected_path_latency",
    "gateway_distance_table", "sample_topology", "source_distance_table",
    "brute_force_optimal", "layer_latency_closed_form",
    "layer_latency_monte_carlo",
    "MultiExpertPlan", "PlacementPlan", "baseline_plans", "central_gateway",
    "multi_expert_plan", "rand_intra_cg_plan", "rand_intra_plan",
    "rand_place_plan", "rank_plans", "ring_subnets", "spacemoe_plan",
    "subnet_routing_sets", "theorem1_assignment",
    "SimResult", "simulate_token_generation",
    "simulate_token_generation_legacy",
    "MoEWorkload",
    "ServiceModel", "ServiceTable", "calibrate", "load_table",
    "resolve_service_model", "save_table", "verify_table",
]
