"""Per-token FLOP + byte accounting for the distributed MoE workload.

The gateway satellite executes attention (+KV cache), layernorm, gating and
aggregation; each expert satellite executes one FFN.  FLOPs = 2*MACs
(Eq. 16 input).  The ``*_bytes`` methods account HBM traffic for the
roofline memory term (``repro.core.calibration``): weight reads are split
from per-token reads (KV cache, activations) because weights amortize over
a decode batch while per-token traffic does not.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoEWorkload:
    """Decode-time FLOPs per token for one MoE layer."""

    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff_expert: int
    n_experts: int
    top_k: int
    vocab_size: int = 32000
    gated_ffn: bool = True      # SwiGLU (3 mats) vs MLP (2 mats)
    dtype_bytes: int = 2        # weight/activation element size (bf16)

    # -- gateway satellite ------------------------------------------------
    def attention_flops(self, ctx_len: int) -> float:
        d, hd = self.d_model, self.head_dim
        q = 2 * d * self.n_heads * hd
        kv = 2 * 2 * d * self.n_kv_heads * hd
        o = 2 * self.n_heads * hd * d
        scores = 2 * self.n_heads * hd * ctx_len
        weighted = 2 * self.n_heads * hd * ctx_len
        return float(q + kv + o + scores + weighted)

    def gating_flops(self) -> float:
        return float(2 * self.d_model * self.n_experts)

    def aggregation_flops(self) -> float:
        return float(self.top_k * self.d_model)

    def gateway_flops(self, ctx_len: int) -> float:
        norms = 4 * self.d_model
        return self.attention_flops(ctx_len) + self.gating_flops() \
            + self.aggregation_flops() + norms

    # -- expert satellite --------------------------------------------------
    @property
    def expert_flops(self) -> float:
        mats = 3 if self.gated_ffn else 2
        return float(2 * mats * self.d_model * self.d_ff_expert)

    # -- head (runs on the last gateway, once per token) -------------------
    @property
    def lm_head_flops(self) -> float:
        return float(2 * self.d_model * self.vocab_size)

    # -- HBM byte accounting (roofline memory term) ------------------------
    @property
    def gateway_weight_bytes(self) -> float:
        """Batch-amortizable gateway reads: attention projections + router."""
        d, hd = self.d_model, self.head_dim
        proj = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        router = d * self.n_experts
        return float((proj + router) * self.dtype_bytes)

    def gateway_token_bytes(self, ctx_len: int) -> float:
        """Per-token gateway reads: the KV cache plus activation vectors."""
        kv = 2 * ctx_len * self.n_kv_heads * self.head_dim
        act = 6 * self.d_model            # residual/q/attn-out/gate/combine
        return float((kv + act) * self.dtype_bytes)

    def gateway_bytes(self, ctx_len: int) -> float:
        """Total gateway bytes per token at batch 1."""
        return self.gateway_weight_bytes + self.gateway_token_bytes(ctx_len)

    @property
    def expert_weight_bytes(self) -> float:
        """Batch-amortizable expert reads: one expert's FFN matrices."""
        mats = 3 if self.gated_ffn else 2
        return float(mats * self.d_model * self.d_ff_expert * self.dtype_bytes)

    @property
    def expert_token_bytes(self) -> float:
        """Per-visit expert reads/writes: in/out rows + hidden activations."""
        mats = 3 if self.gated_ffn else 2
        return float((2 * self.d_model + (mats - 1) * self.d_ff_expert)
                     * self.dtype_bytes)

    @property
    def expert_bytes(self) -> float:
        """Total expert bytes per visit at batch 1."""
        return self.expert_weight_bytes + self.expert_token_bytes

    @property
    def lm_head_weight_bytes(self) -> float:
        """Batch-amortizable head reads: the unembedding matrix."""
        return float(self.d_model * self.vocab_size * self.dtype_bytes)

    @property
    def lm_head_token_bytes(self) -> float:
        """Per-token head traffic: hidden vector in, logits out."""
        return float((self.d_model + self.vocab_size) * self.dtype_bytes)

    @property
    def lm_head_bytes(self) -> float:
        """Total head bytes per token at batch 1."""
        return self.lm_head_weight_bytes + self.lm_head_token_bytes

    @staticmethod
    def from_model_config(cfg) -> "MoEWorkload":
        """Workload view of a registry :class:`~repro.models.config.ModelConfig`.

        Duck-typed (no ``repro.models`` import from core): any object with
        the MoE config fields works.  Raises for dense configs.
        """
        if not getattr(cfg, "n_experts", 0) or not getattr(cfg, "top_k", 0):
            raise ValueError(f"{getattr(cfg, 'name', cfg)!r}: not a MoE config")
        return MoEWorkload(
            d_model=cfg.d_model, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
            d_ff_expert=cfg.d_ff_expert, n_experts=cfg.n_experts,
            top_k=cfg.top_k, vocab_size=cfg.vocab_size,
        )

    @staticmethod
    def llama_moe_3p5b() -> "MoEWorkload":
        """LLaMA-MoE-3.5B (2/8) — paper Sec. VII-A2.

        LLaMA-2-7B FFN (d_ff=11008) split into 8 experts of d_ff=1376;
        32 layers, top-2, d_model=4096.  Active params ~3.5B of 6.7B total.
        """
        return MoEWorkload(
            d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
            d_ff_expert=1376, n_experts=8, top_k=2, vocab_size=32000,
        )
