"""Per-token FLOP accounting for the distributed MoE workload (Eq. 16 input).

The gateway satellite executes attention (+KV cache), layernorm, gating and
aggregation; each expert satellite executes one FFN.  FLOPs = 2*MACs.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoEWorkload:
    """Decode-time FLOPs per token for one MoE layer."""

    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff_expert: int
    n_experts: int
    top_k: int
    vocab_size: int = 32000
    gated_ffn: bool = True      # SwiGLU (3 mats) vs MLP (2 mats)

    # -- gateway satellite ------------------------------------------------
    def attention_flops(self, ctx_len: int) -> float:
        d, hd = self.d_model, self.head_dim
        q = 2 * d * self.n_heads * hd
        kv = 2 * 2 * d * self.n_kv_heads * hd
        o = 2 * self.n_heads * hd * d
        scores = 2 * self.n_heads * hd * ctx_len
        weighted = 2 * self.n_heads * hd * ctx_len
        return float(q + kv + o + scores + weighted)

    def gating_flops(self) -> float:
        return float(2 * self.d_model * self.n_experts)

    def aggregation_flops(self) -> float:
        return float(self.top_k * self.d_model)

    def gateway_flops(self, ctx_len: int) -> float:
        norms = 4 * self.d_model
        return self.attention_flops(ctx_len) + self.gating_flops() \
            + self.aggregation_flops() + norms

    # -- expert satellite --------------------------------------------------
    @property
    def expert_flops(self) -> float:
        mats = 3 if self.gated_ffn else 2
        return float(2 * mats * self.d_model * self.d_ff_expert)

    # -- head (runs on the last gateway, once per token) -------------------
    @property
    def lm_head_flops(self) -> float:
        return float(2 * self.d_model * self.vocab_size)

    @staticmethod
    def llama_moe_3p5b() -> "MoEWorkload":
        """LLaMA-MoE-3.5B (2/8) — paper Sec. VII-A2.

        LLaMA-2-7B FFN (d_ff=11008) split into 8 experts of d_ff=1376;
        32 layers, top-2, d_model=4096.  Active params ~3.5B of 6.7B total.
        """
        return MoEWorkload(
            d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
            d_ff_expert=1376, n_experts=8, top_k=2, vocab_size=32000,
        )
