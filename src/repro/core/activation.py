"""Expert-activation model (paper Sec. III-C).

The top-K active expert set follows the conditional-Poisson distribution
the paper calls PPSWOR:

    Pr(S_hat = U) = prod_{i in U} w_i / e_K(w_1..w_I)        (Eq. 12)

with e_K the K-th elementary symmetric polynomial (Eq. 13) and per-expert
activation probability

    P_i = 1 - e_K(w \\ i) / e_K(w)                            (Eq. 14).

Everything here is exact (dynamic programming over elementary symmetric
polynomials), with a numpy float64 path used by the planner/simulator and
a jax path (``lax.scan``) so the model composes into jit'd programs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------- #
# Elementary symmetric polynomials (numpy, float64)
# --------------------------------------------------------------------- #


def esp(weights: np.ndarray, k_max: int) -> np.ndarray:
    """e_0..e_{k_max} of ``weights`` — Newton DP, O(I*K).

    Weights are pre-scaled by their mean for numerical range; the scaling
    is undone exactly (e_k(c*w) = c^k e_k(w)).
    """
    w = np.asarray(weights, dtype=np.float64)
    scale = w.mean() if w.size else 1.0
    if scale <= 0:
        raise ValueError("importance weights must be positive")
    ws = w / scale
    e = np.zeros(k_max + 1, dtype=np.float64)
    e[0] = 1.0
    for wi in ws:
        e[1 : k_max + 1] = e[1 : k_max + 1] + wi * e[0:k_max]
    return e * scale ** np.arange(k_max + 1)


def esp_prefix_table(weights: np.ndarray, k_max: int) -> np.ndarray:
    """E[i, k] = e_k(w_1..w_i), shape (I+1, K+1) — scaled-stable DP."""
    w = np.asarray(weights, dtype=np.float64)
    scale = w.mean() if w.size else 1.0
    ws = w / scale
    n = len(ws)
    table = np.zeros((n + 1, k_max + 1), dtype=np.float64)
    table[:, 0] = 1.0
    for i in range(1, n + 1):
        table[i, 1:] = table[i - 1, 1:] + ws[i - 1] * table[i - 1, :-1]
    return table * scale ** np.arange(k_max + 1)[None, :]


def activation_probs(weights: np.ndarray, k: int) -> np.ndarray:
    """P_i = Pr(i in S_hat) via Eq. 14: 1 - e_K(w \\ i) / e_K(w).

    Each leave-one-out ESP is computed by a direct DP over the remaining
    I-1 weights (all-positive additions, unconditionally stable; the
    textbook subtractive recurrence cancels catastrophically when one
    weight dominates or K ~ I).  O(I^2 K) — trivial at MoE sizes.

    Properties: sum_i P_i = K; P_i monotone increasing in w_i.
    """
    w = np.asarray(weights, dtype=np.float64)
    n = len(w)
    if k >= n:
        return np.ones(n)
    ws = w / w.mean()
    e_full = esp(ws, k)[k]
    probs = np.empty(n)
    for i in range(n):
        loo = esp(np.delete(ws, i), k)[k]
        probs[i] = 1.0 - loo / e_full
    return probs


def sample_topk(
    weights: np.ndarray, k: int, rng: np.random.Generator, n_draws: int = 1
) -> np.ndarray:
    """Exact conditional-Poisson samples of Eq. 12, shape (n_draws, K).

    Sequential ESP-ratio method: scanning items i = I..1 with ``r`` slots
    remaining, include item i with probability

        w_i * e_{r-1}(w_1..w_{i-1}) / e_r(w_1..w_i),

    which marginalizes exactly to Eq. 12.  Vectorized over draws.
    """
    w = np.asarray(weights, dtype=np.float64)
    n = len(w)
    if not (0 < k <= n):
        raise ValueError(f"need 0 < K <= I, got K={k}, I={n}")
    table = esp_prefix_table(w / w.mean(), k)      # scale cancels in ratios
    ws = w / w.mean()

    remaining = np.full(n_draws, k, dtype=np.int64)
    out = np.zeros((n_draws, k), dtype=np.int64)
    for i in range(n, 0, -1):
        r = remaining
        num = ws[i - 1] * table[i - 1, np.maximum(r - 1, 0)]
        den = table[i, r]
        with np.errstate(invalid="ignore", divide="ignore"):
            p = np.where(r > 0, num / den, 0.0)
        take = rng.random(n_draws) < p
        idx = np.where(take)[0]
        out[idx, remaining[idx] - 1] = i - 1
        remaining = remaining - take.astype(np.int64)
    assert (remaining == 0).all()
    return out


def subset_pmf(weights: np.ndarray, k: int) -> dict[tuple[int, ...], float]:
    """Exact PMF over all size-K subsets (enumeration; small I only)."""
    import itertools

    w = np.asarray(weights, dtype=np.float64)
    denom = esp(w, k)[k]
    return {
        u: float(np.prod(w[list(u)]) / denom)
        for u in itertools.combinations(range(len(w)), k)
    }


# --------------------------------------------------------------------- #
# JAX path — composable into jit'd programs
# --------------------------------------------------------------------- #


def esp_jax(weights: jnp.ndarray, k_max: int) -> jnp.ndarray:
    """e_0..e_{k_max} via lax.scan (same DP as :func:`esp`)."""
    w = jnp.asarray(weights)
    scale = jnp.mean(w)
    ws = w / scale

    def step(e, wi):
        e = e.at[1:].add(wi * e[:-1])
        return e, None

    e0 = jnp.zeros(k_max + 1, dtype=w.dtype).at[0].set(1.0)
    e, _ = jax.lax.scan(step, e0, ws)
    return e * scale ** jnp.arange(k_max + 1)


def activation_probs_jax(weights: jnp.ndarray, k: int) -> jnp.ndarray:
    """JAX version of :func:`activation_probs` (Eq. 14)."""
    w = jnp.asarray(weights)
    ws = w / jnp.mean(w)
    e_full = esp_jax(ws, k)

    def step(loo_prev, ej):
        loo = ej - ws * loo_prev
        return loo, None

    loo0 = jnp.ones_like(ws)
    loo_k, _ = jax.lax.scan(step, loo0, e_full[1 : k + 1])
    return 1.0 - loo_k / e_full[k]


def esp_prefix_table_jax(weights: jnp.ndarray, k_max: int) -> jnp.ndarray:
    """E[i, k] = e_k(w_1..w_i) of mean-scaled weights, shape (I+1, K+1).

    The scale cancels in the sampling ratios, so (unlike the numpy
    :func:`esp_prefix_table`) the scaling is *not* undone here.
    """
    w = jnp.asarray(weights)
    ws = w / jnp.mean(w)

    def step(row, wi):
        row = row.at[1:].add(wi * row[:-1])
        return row, row

    row0 = jnp.zeros(k_max + 1, dtype=w.dtype).at[0].set(1.0)
    _, rows = jax.lax.scan(step, row0, ws)
    return jnp.concatenate([row0[None], rows], axis=0)


def sample_topk_jax(weights: jnp.ndarray, k: int, key,
                    n_draws: int) -> jnp.ndarray:
    """Exact conditional-Poisson samples of Eq. 12 on-device, (n_draws, K).

    Same sequential ESP-ratio method as :func:`sample_topk`, with the item
    scan as ``lax.scan`` and the per-draw state vectorized — composes into
    jit'd programs (the batched plan-evaluation engine's fast path).
    """
    w = jnp.asarray(weights)
    n = w.shape[0]
    if not (0 < k <= n):
        raise ValueError(f"need 0 < K <= I, got K={k}, I={n}")
    table = esp_prefix_table_jax(w, k)
    ws = w / jnp.mean(w)
    u = jax.random.uniform(key, (n, n_draws), dtype=w.dtype)

    def step(carry, xs):
        remaining, out = carry
        i, ui = xs
        num = ws[i - 1] * table[i - 1, jnp.maximum(remaining - 1, 0)]
        den = table[i, remaining]
        p = jnp.where(remaining > 0, num / den, 0.0)
        take = ui < p
        # out[d, remaining[d]-1] = i-1 where taken
        write = take[:, None] & (
            jnp.arange(k, dtype=remaining.dtype)[None] == (remaining - 1)[:, None]
        )
        out = jnp.where(write, i - 1, out)
        remaining = remaining - take.astype(remaining.dtype)
        return (remaining, out), None

    carry0 = (jnp.full((n_draws,), k, dtype=jnp.int32),
              jnp.zeros((n_draws, k), dtype=jnp.int32))
    items = jnp.arange(n, 0, -1, dtype=jnp.int32)
    (_, out), _ = jax.lax.scan(step, carry0, (items, u))
    return out


# --------------------------------------------------------------------- #
# Per-layer activation statistics container
# --------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class ActivationModel:
    """Importance weights per MoE layer, shape (L, I); top-K per Eq. 12."""

    weights: np.ndarray      # (L, I) positive
    top_k: int

    def __post_init__(self):
        if (np.asarray(self.weights) <= 0).any():
            raise ValueError("importance weights must be positive")

    @property
    def n_layers(self) -> int:
        return self.weights.shape[0]

    @property
    def n_experts(self) -> int:
        return self.weights.shape[1]

    def probs(self, layer: int) -> np.ndarray:
        return activation_probs(self.weights[layer], self.top_k)

    def all_probs(self) -> np.ndarray:
        return np.stack([self.probs(l) for l in range(self.n_layers)])

    def sample(self, layer: int, rng: np.random.Generator, n_draws: int = 1) -> np.ndarray:
        return sample_topk(self.weights[layer], self.top_k, rng, n_draws)

    # -- constructors ---------------------------------------------------
    @staticmethod
    def zipf(n_layers: int, n_experts: int, top_k: int, s: float = 1.2,
             seed: int = 0) -> "ActivationModel":
        """Zipf-skewed weights with a per-layer random expert order.

        Real MoE gating statistics are heavy-tailed (a few hot experts per
        layer); the paper estimates them from LLaMA-MoE traces, which we do
        not have offline — Zipf(s) is the standard surrogate.
        """
        rng = np.random.default_rng(seed)
        base = (1.0 + np.arange(n_experts)) ** (-s)
        w = np.stack([rng.permutation(base) for _ in range(n_layers)])
        return ActivationModel(weights=w, top_k=top_k)

    @staticmethod
    def uniform(n_layers: int, n_experts: int, top_k: int) -> "ActivationModel":
        return ActivationModel(
            weights=np.ones((n_layers, n_experts)), top_k=top_k
        )

    @staticmethod
    def from_router_counts(counts: np.ndarray, top_k: int,
                           smoothing: float = 1.0) -> "ActivationModel":
        """Estimate weights from observed expert-selection counts (L, I).

        Activation probabilities are monotone in the weights (Eq. 14), so
        smoothed empirical frequencies are a consistent plug-in.
        """
        counts = np.asarray(counts, dtype=np.float64) + smoothing
        return ActivationModel(weights=counts / counts.sum(axis=1, keepdims=True),
                               top_k=top_k)
