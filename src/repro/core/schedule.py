"""Time-indexed placement: one plan per topology slot (`PlanSchedule`).

The paper derives a placement once for the time-varying graph G(n)
(Sec. II, Eq. 2-3) and holds it for the whole horizon.  This module
makes the plan a first-class *function of the slot index n*: a
:class:`PlanSchedule` maps every topology slot to a placement plan and
carries explicit **migration edges** between consecutive slots — the
experts whose hosting satellite changes at the boundary, with the weight
bytes that transfer (the same accounting
:func:`repro.distributed.elastic.migration` uses on the device ring;
``tests/test_schedule.py`` pins the parity on a hand-checked switch).

A constant schedule (the same plan in every slot) is the degenerate case
and must reproduce the static engine path bit-for-bit — that invariant
is what lets every existing scenario become a re-placement testbed: the
engine (`repro.core.engine.evaluate_schedules`), the fleet simulator
(`repro.traffic.queueing.FleetSim`) and the re-placement controller
(`repro.traffic.replan`) all consume schedules; plain plans are wrapped
by :func:`as_schedule` at the boundary.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .placement import MultiExpertPlan, PlacementPlan


def slot_of_time(t_s: np.ndarray | float, slot_period_s: float,
                 n_slots: int) -> np.ndarray:
    """Topology slot in effect at wall-clock time ``t_s`` (wraps every
    orbital period: slot = floor(t / period) mod N_T)."""
    return (np.asarray(t_s, dtype=np.float64) // slot_period_s
            ).astype(np.int64) % n_slots


@dataclasses.dataclass
class ScheduleMigration:
    """Weight movement across one slot boundary of a schedule.

    Attributes:
        slot: Topology slot being *entered* (the edge is slot-1 -> slot,
            with ring wrap; -1 marks a free-standing plan-to-plan diff).
        layers: (n_moved,) layer of each moved expert.
        experts: (n_moved,) expert index within its layer.
        old_sats: (n_moved,) satellite the expert leaves.
        new_sats: (n_moved,) satellite the expert lands on.
        bytes_moved: Total weight bytes transferred over ISLs.
    """

    slot: int
    layers: np.ndarray
    experts: np.ndarray
    old_sats: np.ndarray
    new_sats: np.ndarray
    bytes_moved: float

    @property
    def n_moved(self) -> int:
        """Number of (layer, expert) pairs that change satellite."""
        return len(self.layers)


def migration_between(old_plan, new_plan, bytes_per_expert: float,
                      slot: int = -1) -> ScheduleMigration:
    """Experts whose hosting satellite changes between two plans.

    The constellation-side face of
    :func:`repro.distributed.elastic.migration`: same rule (an expert
    moves iff its host changes), same byte accounting
    (``n_moved * bytes_per_expert``), applied per layer over the
    (L, I) expert->satellite maps instead of the device ring.
    """
    old_sats = np.asarray(old_plan.expert_sats)
    new_sats = np.asarray(new_plan.expert_sats)
    if old_sats.shape != new_sats.shape:
        raise ValueError("plans disagree on (n_layers, n_experts)")
    layers, experts = np.nonzero(old_sats != new_sats)
    return ScheduleMigration(
        slot=slot, layers=layers, experts=experts,
        old_sats=old_sats[layers, experts],
        new_sats=new_sats[layers, experts],
        bytes_moved=float(len(layers) * bytes_per_expert),
    )


def migration_matrix(plans: list, bytes_per_expert: float,
                     n_stations: int) -> tuple[np.ndarray, np.ndarray]:
    """All-pairs migration accounting for a candidate pool.

    The on-device re-placement controller decides plan switches inside
    one fused launch, so the per-switch quantities —
    :func:`migration_between` applied to every ordered (incumbent,
    successor) pair — must be precomputed as dense tables it can gather
    from.  Entry [i, j] prices the switch plans[i] -> plans[j] with
    exactly the walk's arithmetic (``n_moved * bytes_per_expert`` in one
    float64 product; diagonal entries are zero).

    Args:
        plans: Candidate pool (shared (n_layers, n_experts)).
        bytes_per_expert: Weight bytes one moved expert drags.
        n_stations: Satellite count V (the destination-count axis).

    Returns:
        ``(bytes_mat, dest_count)``: bytes_mat is (C, C) float64 bytes
        moved per ordered pair; dest_count is (C, C, V) float64 — how
        many moved experts land on each destination satellite (the
        per-boundary occupancy multiplier for the migration background
        load).
    """
    C = len(plans)
    bytes_mat = np.zeros((C, C))
    dest_count = np.zeros((C, C, n_stations))
    for i in range(C):
        for j in range(C):
            if i == j:
                continue
            mig = migration_between(plans[i], plans[j], bytes_per_expert)
            bytes_mat[i, j] = mig.bytes_moved
            if mig.n_moved:
                dest_count[i, j] = np.bincount(mig.new_sats,
                                               minlength=n_stations)
    return bytes_mat, dest_count


@dataclasses.dataclass
class PlanSchedule:
    """A per-topology-slot plan sequence with migration edges.

    ``plans`` holds the distinct plans the schedule uses;
    ``slot_plan[n]`` is the index of the plan in effect during topology
    slot n.  All plans must agree on (n_layers, n_experts) so tokens of
    any slot traverse the same station universe.

    Attributes:
        plans: Distinct :class:`~repro.core.placement.PlacementPlan` /
            :class:`~repro.core.placement.MultiExpertPlan` entries.
        slot_plan: (n_slots,) plan index per topology slot.
        name: Display name (one row of a sweep table).
    """

    plans: list
    slot_plan: np.ndarray
    name: str = "schedule"

    def __post_init__(self):
        self.slot_plan = np.asarray(self.slot_plan, dtype=np.int64)
        if not self.plans:
            raise ValueError("empty schedule")
        if self.slot_plan.ndim != 1 or len(self.slot_plan) == 0:
            raise ValueError("slot_plan must be a non-empty 1-D index array")
        if self.slot_plan.min() < 0 or self.slot_plan.max() >= len(self.plans):
            raise ValueError("slot_plan index out of range")
        shapes = {np.asarray(p.expert_sats).shape for p in self.plans}
        if len(shapes) != 1:
            raise ValueError("all plans of a schedule must share "
                             "(n_layers, n_experts)")

    @classmethod
    def constant(cls, plan, n_slots: int,
                 name: str | None = None) -> "PlanSchedule":
        """The degenerate schedule: one plan held for every slot (must
        reproduce the static engine path bit-for-bit)."""
        return cls(plans=[plan], slot_plan=np.zeros(n_slots, dtype=np.int64),
                   name=name or getattr(plan, "name", "plan"))

    @property
    def n_slots(self) -> int:
        """Number of topology slots the schedule covers (N_T)."""
        return len(self.slot_plan)

    @property
    def n_layers(self) -> int:
        """MoE layers shared by every plan of the schedule (L)."""
        return len(self.plans[0].gateways)

    @property
    def n_experts(self) -> int:
        """Experts per layer shared by every plan (I)."""
        return np.asarray(self.plans[0].expert_sats).shape[1]

    @property
    def is_constant(self) -> bool:
        """True iff the same plan is in effect in every slot."""
        return bool((self.slot_plan == self.slot_plan[0]).all())

    def plan_at(self, slot: int):
        """The plan in effect during topology slot ``slot``."""
        return self.plans[int(self.slot_plan[slot])]

    def switch_slots(self) -> np.ndarray:
        """Slots n >= 1 whose plan differs from slot n-1 (the boundaries
        that cost a migration; the 0 -> N_T-1 ring wrap is handled by
        the wall-clock walk in :meth:`migrations_over`)."""
        return 1 + np.flatnonzero(np.diff(self.slot_plan) != 0)

    def migration_edges(self, bytes_per_expert: float
                        ) -> list[ScheduleMigration]:
        """One :class:`ScheduleMigration` per in-sequence plan switch."""
        return [
            migration_between(self.plans[self.slot_plan[n - 1]],
                              self.plans[self.slot_plan[n]],
                              bytes_per_expert, slot=int(n))
            for n in self.switch_slots()
        ]

    def migrations_over(self, horizon_s: float, slot_period_s: float,
                        bytes_per_expert: float
                        ) -> list[tuple[float, ScheduleMigration]]:
        """(boundary time, migration) pairs for every plan switch a
        wall-clock walk of ``[0, horizon_s)`` crosses (slot indices wrap
        every orbital period, so a long horizon replays the sequence)."""
        out: list[tuple[float, ScheduleMigration]] = []
        n_bounds = int(np.floor(horizon_s / slot_period_s))
        for k in range(1, n_bounds + 1):
            prev = int(self.slot_plan[(k - 1) % self.n_slots])
            cur = int(self.slot_plan[k % self.n_slots])
            if prev == cur:
                continue
            out.append((k * slot_period_s,
                        migration_between(self.plans[prev], self.plans[cur],
                                          bytes_per_expert,
                                          slot=k % self.n_slots)))
        return out

    def total_migration_bytes(self, bytes_per_expert: float) -> float:
        """Sum of weight bytes over every in-sequence switch."""
        return float(sum(e.bytes_moved
                         for e in self.migration_edges(bytes_per_expert)))


def as_schedule(plan_or_schedule, n_slots: int) -> PlanSchedule:
    """Normalize a sweep entry: plans become constant schedules, existing
    schedules are validated against the topology's slot count."""
    if isinstance(plan_or_schedule, PlanSchedule):
        if plan_or_schedule.n_slots != n_slots:
            raise ValueError(
                f"schedule covers {plan_or_schedule.n_slots} slots but the "
                f"topology has {n_slots}")
        return plan_or_schedule
    if not isinstance(plan_or_schedule, (PlacementPlan, MultiExpertPlan)):
        raise TypeError(f"not a plan or schedule: {plan_or_schedule!r}")
    return PlanSchedule.constant(plan_or_schedule, n_slots)
