"""Batched, jit-compiled plan-evaluation engine.

Evaluates P placement plans x N_T topology slots x n tokens in one
vectorized pass: ``vmap`` over plans, a fused ``lax.scan`` over layers
(replacing the legacy per-layer Python loop), with the distance-table
gather, conditional-Poisson top-K sampling, the Eq. 43 multi-expert
contention term and the route-staleness penalty all expressed as array
ops.  The per-slot Dijkstra distance table is the only host-side
precompute; a :class:`PlanBatch` dedupes gateway nodes across the whole
sweep so it is built once per sweep, not once per plan.

This is the Monte-Carlo core behind every paper experiment (Figs. 6-7,
Table 2) and the substrate for continuous re-placement: evaluating many
candidate plans per topology slot is exactly the ``evaluate_plans`` sweep
call.  ``repro.core.simulator`` keeps the legacy NumPy implementation as
a golden reference and a thin wrapper with the historical API.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .activation import ActivationModel, sample_topk_jax
from .calibration import ServiceModel, resolve_service_model
from .latency import (ComputeConfig, TopologySample, node_masks_from_sets,
                      source_distance_table)
from .placement import MultiExpertPlan, PlacementPlan
from .schedule import PlanSchedule, as_schedule
from .workload import MoEWorkload

# A stale route whose latency moved by more than one hop (> ~2 ms) — or
# that broke entirely — forces discovery + re-route (see simulator docs).
HOP_SCALE_S = 2e-3


@dataclasses.dataclass
class SimResult:
    """Per-plan Monte-Carlo latency outcome of one engine pass.

    Attributes:
        token_latency_s: (n_tokens,) E2E latency per token — NaN where
            the token was undeliverable in its topology slot.
        layer_latency_s: (n_tokens, L) per-layer latency breakdown.
        plan_name: Name of the placement plan evaluated.
    """

    token_latency_s: np.ndarray
    layer_latency_s: np.ndarray
    plan_name: str

    @property
    def delivered(self) -> np.ndarray:
        """(n_tokens,) bool — token reached the user (finite latency)."""
        return np.isfinite(self.token_latency_s)

    @property
    def mean_s(self) -> float:
        """Mean latency over delivered tokens, seconds."""
        return float(np.nanmean(self.token_latency_s))

    @property
    def p99_s(self) -> float:
        """99th-percentile latency over delivered tokens, seconds."""
        return float(np.nanpercentile(self.token_latency_s, 99))

    @property
    def drop_rate(self) -> float:
        """Fraction of tokens that were undeliverable."""
        return float(1.0 - self.delivered.mean())

    def layer_stats(self) -> tuple[np.ndarray, np.ndarray]:
        """(mean, std) per layer across tokens (Fig. 6a)."""
        return (np.nanmean(self.layer_latency_s, axis=0),
                np.nanstd(self.layer_latency_s, axis=0))


# --------------------------------------------------------------------- #
# Plan batching: stack P plans onto one deduped distance table
# --------------------------------------------------------------------- #


def _node_key(node_sets: list | None) -> tuple | None:
    """Canonical hashable form of a node_sets argument (for batch reuse
    checks)."""
    if node_sets is None:
        return None
    return tuple(tuple(sorted(int(n) for n in np.asarray(nodes).ravel()))
                 for nodes in node_sets)


def _topo_key(topo: TopologySample) -> tuple:
    """Cheap content fingerprint of a topology realization.  A reused
    PlanBatch carries stale Dijkstra rows if the topology was resampled;
    worse, out-of-range slot indices would be silently clamped by the
    jit'd gather instead of raising like NumPy would."""
    return (topo.n_slots, topo.n_sats,
            hash(topo.edge_mask.tobytes()),
            hash(topo.edge_latency.tobytes()))


@dataclasses.dataclass
class PlanBatch:
    """P plans stacked for one engine pass over a shared distance table.

    ``dist`` holds rows for the *unique* (gateway, routing-mask) pairs of
    the sweep; ``g_idx[p, l]`` maps plan p / layer l to its row.  Build
    once with :meth:`from_plans` and reuse across ``evaluate_plans`` calls
    on the same topology.
    """

    dist: np.ndarray          # (N_T, G, V) shared shortest-path table
    g_idx: np.ndarray         # (P, L) row of dist for plan p, layer l
    gateways: np.ndarray      # (P, L) raw gateway node indices
    expert_sats: np.ndarray   # (P, L, I) satellite hosting expert i
    eta: np.ndarray           # (P,) contention efficiency (1.0 = single-expert)
    names: tuple[str, ...]
    node_key: tuple | None    # canonicalized node_sets the table was built with
    topo_key: tuple           # fingerprint of the topology realization
    _device: tuple | None = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def n_plans(self) -> int:
        """Number of plans stacked in the batch (P)."""
        return self.g_idx.shape[0]

    @property
    def n_layers(self) -> int:
        """Number of MoE layers shared by every plan (L)."""
        return self.g_idx.shape[1]

    def device_arrays(self) -> tuple:
        """(dist, g_idx, expert_sats, eta) as device arrays, cached so the
        O(N_T*G*V) host-to-device transfer happens once per batch — the
        hot re-placement loop then ships only slots/draws per call."""
        if self._device is None:
            self._device = (
                jnp.asarray(self.dist, dtype=jnp.float32),
                jnp.asarray(self.g_idx, dtype=jnp.int32),
                jnp.asarray(self.expert_sats, dtype=jnp.int32),
                jnp.asarray(self.eta, dtype=jnp.float32),
            )
        return self._device

    def matches(self, plans: list, topo: TopologySample,
                node_sets: list | None, eta: float) -> bool:
        """True iff this batch was built from exactly these plans, this
        topology realization and these settings (names are not unique, so
        compare the actual placements)."""
        gws = np.stack([np.asarray(p.gateways) for p in plans])
        sats = np.stack([np.asarray(p.expert_sats) for p in plans])
        etas = np.array(
            [eta if isinstance(p, MultiExpertPlan) else 1.0 for p in plans])
        return (gws.shape == self.gateways.shape
                and np.array_equal(gws, self.gateways)
                and sats.shape == self.expert_sats.shape
                and np.array_equal(sats, self.expert_sats)
                and np.array_equal(etas, self.eta)
                and _node_key(node_sets) == self.node_key
                and _topo_key(topo) == self.topo_key)

    @classmethod
    def from_plans(
        cls,
        plans: list[PlacementPlan | MultiExpertPlan],
        topo: TopologySample,
        node_sets: list | None = None,
        eta: float = 1.0,
    ) -> "PlanBatch":
        """Stack plans and build the deduped Dijkstra table.

        ``eta`` is the Eq. 43 compute-sharing efficiency, applied to
        :class:`MultiExpertPlan` entries only (single-expert plans always
        run at q = 1, matching the legacy simulator).
        """
        plans = list(plans)
        if not plans:
            raise ValueError("empty plan sweep")
        n_layers = len(plans[0].gateways)
        masks: list | None = None
        if node_sets is not None:
            masks = node_masks_from_sets(node_sets, topo.n_sats)

        # Dedupe (gateway node, per-layer mask) -> distance-table row.
        row_of: dict[tuple, int] = {}
        sources: list[int] = []
        row_masks: list = []
        g_idx = np.empty((len(plans), n_layers), dtype=np.int64)
        for pi, plan in enumerate(plans):
            if len(plan.gateways) != n_layers:
                raise ValueError("all plans in a sweep must share n_layers")
            for layer, g in enumerate(np.asarray(plan.gateways)):
                key = (int(g), layer if masks is not None else -1)
                if key not in row_of:
                    row_of[key] = len(sources)
                    sources.append(int(g))
                    row_masks.append(masks[layer] if masks is not None else None)
                g_idx[pi, layer] = row_of[key]
        dist = source_distance_table(
            topo, np.asarray(sources, dtype=np.int64),
            row_masks if masks is not None else None,
        )
        gateways = np.stack([np.asarray(p.gateways) for p in plans])
        expert_sats = np.stack([np.asarray(p.expert_sats) for p in plans])
        etas = np.array(
            [eta if isinstance(p, MultiExpertPlan) else 1.0 for p in plans],
            dtype=np.float64,
        )
        names = tuple(getattr(p, "name", "plan") for p in plans)
        return cls(dist=dist, g_idx=g_idx, gateways=gateways,
                   expert_sats=expert_sats, eta=etas, names=names,
                   node_key=_node_key(node_sets), topo_key=_topo_key(topo))


# --------------------------------------------------------------------- #
# Schedule batching: Q time-indexed schedules over one union PlanBatch
# --------------------------------------------------------------------- #


@dataclasses.dataclass
class ScheduleBatch:
    """Q :class:`~repro.core.schedule.PlanSchedule` entries stacked for one
    engine pass.

    The union of every schedule's distinct plans is stacked into one
    :class:`PlanBatch` (the Dijkstra rows dedupe across the whole
    union); ``plan_row[q, n]`` maps schedule q / topology slot n to its
    row of the base batch — the slot -> plan-row gather that replaces the
    static engine path's constant plan index.
    """

    base: PlanBatch           # union-plan batch (deduped Dijkstra table)
    plan_row: np.ndarray      # (Q, N_T) base-batch row per (schedule, slot)
    names: tuple[str, ...]
    _device: object | None = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def n_schedules(self) -> int:
        """Number of schedules stacked in the batch (Q)."""
        return self.plan_row.shape[0]

    @property
    def n_layers(self) -> int:
        """MoE layers shared by every plan of every schedule (L)."""
        return self.base.n_layers

    @property
    def n_sats(self) -> int:
        """Graph nodes of the topology the batch was built on (V)."""
        return self.base.dist.shape[2]

    def plan_row_device(self):
        """``plan_row`` as a cached device array (the base batch caches
        its own arrays separately)."""
        if self._device is None:
            self._device = jnp.asarray(self.plan_row, dtype=jnp.int32)
        return self._device

    def gateways_by_slot(self) -> np.ndarray:
        """(Q, N_T, L) gateway satellite per (schedule, slot, layer)."""
        return self.base.gateways[self.plan_row]

    def expert_sats_by_slot(self) -> np.ndarray:
        """(Q, N_T, L, I) expert satellite per (schedule, slot, layer,
        expert)."""
        return self.base.expert_sats[self.plan_row]

    def eta_by_slot(self) -> np.ndarray:
        """(Q, N_T) Eq. 43 compute-sharing efficiency per (schedule,
        slot)."""
        return self.base.eta[self.plan_row]

    def matches(self, schedules: list, topo: TopologySample,
                node_sets: list | None, eta: float) -> bool:
        """True iff this batch was built from exactly these schedules on
        this topology realization and these settings."""
        union = [p for s in schedules for p in s.plans]
        if len(union) != self.base.n_plans:
            return False
        rows = _schedule_rows(schedules)
        return (rows.shape == self.plan_row.shape
                and np.array_equal(rows, self.plan_row)
                and self.base.matches(union, topo, node_sets, eta))

    @classmethod
    def from_schedules(
        cls,
        schedules: list[PlanSchedule],
        topo: TopologySample,
        node_sets: list | None = None,
        eta: float = 1.0,
    ) -> "ScheduleBatch":
        """Stack schedules onto one union :class:`PlanBatch`."""
        schedules = list(schedules)
        if not schedules:
            raise ValueError("empty schedule sweep")
        for s in schedules:
            if s.n_slots != topo.n_slots:
                raise ValueError(
                    f"schedule {s.name!r} covers {s.n_slots} slots but the "
                    f"topology has {topo.n_slots}")
        union = [p for s in schedules for p in s.plans]
        base = PlanBatch.from_plans(union, topo, node_sets=node_sets, eta=eta)
        return cls(base=base, plan_row=_schedule_rows(schedules),
                   names=tuple(s.name for s in schedules))


def _schedule_rows(schedules: list[PlanSchedule]) -> np.ndarray:
    """(Q, N_T) union-batch row per (schedule, slot)."""
    offsets = np.cumsum([0] + [len(s.plans) for s in schedules[:-1]])
    return np.stack([off + s.slot_plan
                     for off, s in zip(offsets, schedules)])


def schedule_ingress_offsets(batch: ScheduleBatch, slots: np.ndarray,
                             ingress_sats: np.ndarray) -> np.ndarray:
    """Per-token uphill offset D(ingress sat, gateway_0; slot), shape
    (Q, T) — the :func:`ingress_offsets` analog where the layer-0
    gateway row follows the slot's plan instead of being constant."""
    slots = np.asarray(slots)
    ingress_sats = np.asarray(ingress_sats)
    g0 = batch.base.g_idx[batch.plan_row[:, slots], 0]        # (Q, T)
    return batch.base.dist[slots[None, :], g0, ingress_sats[None, :]]


# --------------------------------------------------------------------- #
# The jit kernel
# --------------------------------------------------------------------- #


def hop_latency(dist, slots, stale_slots, g, sats, penalty, stale: bool):
    """Gateway<->expert hop latencies, (T, K), with the staleness penalty.

    With ``stale`` the path was chosen on the topology ``stale_slots`` ago:
    smooth drift is free, but a topology change (detour > ~one hop, or a
    broken route) pays the current shortest path plus ``penalty``.

    Public so downstream subsystems (``repro.traffic``) can reuse the
    exact same hop kernel the engine evaluates plans with.
    """
    cur = dist[slots[:, None], g, sats]
    if not stale:
        return cur
    old = dist[stale_slots[:, None], g, sats]
    broken = (jnp.abs(old - cur) > HOP_SCALE_S) | ~jnp.isfinite(old)
    return cur + penalty * broken


def contention_counts(sats):
    """q[..., k] = number of activated experts sharing satellite ``sats[..., k]``
    (the Eq. 43 colocation count; last axis is the top-K draw axis)."""
    return (sats[..., :, None] == sats[..., None, :]).sum(axis=-1)


def ingress_offsets(batch: "PlanBatch", slots: np.ndarray,
                    ingress_sats: np.ndarray) -> np.ndarray:
    """Per-token uphill offset D(ingress sat, gateway_0; slot), shape (P, T).

    The graph is undirected, so the layer-0 gateway row of the deduped
    Dijkstra table already holds every ingress->gateway distance: no extra
    Dijkstra runs.  Tokens entering via an unreachable ingress satellite
    get +inf (the traffic layer accounts them as drops).
    """
    slots = np.asarray(slots)
    ingress_sats = np.asarray(ingress_sats)
    g0 = batch.g_idx[:, 0]                                   # (P,)
    return batch.dist[slots[None, :], g0[:, None], ingress_sats[None, :]]


def eq43_layer_terms(batch: "ScheduleBatch", sched: int, slots: np.ndarray,
                     draws: np.ndarray, t_gateway: float,
                     t_expert: float = 0.0,
                     expert_sec: np.ndarray | None = None,
                     inv_speed: np.ndarray | None = None) -> dict:
    """Per-(token, layer, branch) decomposition of the Eq. 43 layer cost.

    Host-side numpy mirror of :func:`_evaluate_schedule_batch`'s inner
    indexing (current-slot paths, i.e. ``stale=False``), kept separate so
    the flight recorder (:func:`repro.obs.recorder.eq43_breakdown`) can
    attribute a token's zero-load layer latency to its constituent
    terms — outbound hop, expert service under colocation contention,
    return hop — without re-tracing the jitted kernel.

    Args:
        batch: The :class:`~repro.core.schedule.ScheduleBatch` the run
            evaluated (``base.dist`` (N_T, G, V), ``plan_row`` (Q, N_T)).
        sched: Schedule row q to decompose.
        slots: (T,) topology slot per token.
        draws: (L, T, K) expert draws (the engine's sampled top-K).
        t_gateway: Gateway service seconds per layer.
        t_expert: Analytic per-expert service seconds (used when the
            calibrated tables below are absent).
        expert_sec: Optional (I,) calibrated per-expert service seconds.
        inv_speed: Optional (V,) per-satellite inverse speed factors
            (both given => the calibrated Eq. 43 service term).

    Returns:
        Dict of arrays: ``d_out``/``d_in``/``t_exp`` (T, L, K) seconds,
        ``q`` (T, L, K) colocation counts, ``sats`` (T, L, K) serving
        satellites, and ``layer_s`` (T, L) — ``t_gateway + max_K(d_out +
        t_exp + d_in)`` with unreachable branches as NaN, matching the
        kernel's ``layer_latency_s`` exactly.
    """
    base = batch.base
    slots = np.asarray(slots)
    rows = np.asarray(batch.plan_row)[int(sched), slots]        # (T,)
    g_tok = np.asarray(base.g_idx)[rows]                        # (T, L)
    g_next = np.roll(g_tok, -1, axis=1)   # ring wrap for the last layer
    eta_tok = np.asarray(base.eta)[rows]                        # (T,)
    draws_tlk = np.moveaxis(np.asarray(draws), 0, 1)            # (T, L, K)
    sats = np.take_along_axis(np.asarray(base.expert_sats)[rows],
                              draws_tlk, axis=2)                # (T, L, K)
    dist = np.asarray(base.dist)
    s3 = slots[:, None, None]
    d_out = dist[s3, g_tok[:, :, None], sats]
    d_in = dist[s3, g_next[:, :, None], sats]
    q = contention_counts(sats)
    if expert_sec is not None and inv_speed is not None:
        unit = np.asarray(expert_sec)[draws_tlk] \
            * np.asarray(inv_speed)[sats]
    else:
        unit = t_expert
    t_exp = (np.asarray(q, dtype=dist.dtype)
             / eta_tok[:, None, None]) * unit
    layer = t_gateway + (d_out + t_exp + d_in).max(axis=2)      # (T, L)
    layer = np.where(np.isfinite(layer), layer, np.nan)
    return dict(d_out=d_out, d_in=d_in, q=q, t_exp=t_exp, sats=sats,
                layer_s=layer)


@functools.partial(jax.jit, static_argnames=("stale", "calibrated"))
def _evaluate_batch(dist, g_idx, expert_sats, slots, stale_slots, draws,
                    t_gateway, t_expert, t_head, eta, penalty,
                    expert_sec, inv_speed, stale: bool,
                    calibrated: bool = False):
    """(token_latency (P, T), layer_latency (P, T, L)) for a PlanBatch.

    dist: (N_T, G, V); g_idx: (P, L); expert_sats: (P, L, I);
    slots/stale_slots: (T,); draws: (L, T, K); eta: (P,).

    With ``calibrated`` the scalar ``t_expert`` is replaced by the
    per-expert table ``expert_sec`` (I,) scaled by the hosting
    satellite's ``inv_speed`` (V,) — the kernel-calibrated Eq. 43 service
    term.  The flag is static so the analytic trace is byte-identical to
    the pre-calibration kernel (the dummy arrays are dead code).
    """
    def _one_plan(g_row, sats_li, eta_p):
        g_next = jnp.roll(g_row, -1)      # ring wrap for the last layer

        def _layer_step(_, xs):
            draws_l, g_l, g_n, sats_i = xs
            sats = sats_i[draws_l]                                # (T, K)
            d_out = hop_latency(dist, slots, stale_slots, g_l, sats,
                                penalty, stale)
            d_in = hop_latency(dist, slots, stale_slots, g_n, sats,
                               penalty, stale)
            # Eq. 43 contention: q = activated experts sharing the satellite.
            q = contention_counts(sats)
            if calibrated:
                unit = expert_sec[draws_l] * inv_speed[sats]      # (T, K)
                t_exp = (q.astype(dist.dtype) / eta_p) * unit
            else:
                t_exp = (q.astype(dist.dtype) / eta_p) * t_expert
            lay = t_gateway + (d_out + t_exp + d_in).max(axis=1)
            return None, lay

        _, lat = jax.lax.scan(_layer_step, None,
                              (draws, g_row, g_next, sats_li))
        return lat.T                                              # (T, L)

    layer_lat = jax.vmap(_one_plan)(g_idx, expert_sats, eta)       # (P, T, L)
    # Unreachable satellite in that slot => undeliverable token: count as a
    # drop (NaN), never as infinite latency.
    layer_lat = jnp.where(jnp.isfinite(layer_lat), layer_lat, jnp.nan)
    token_lat = layer_lat.sum(axis=2) + t_head
    return token_lat, layer_lat


@functools.partial(jax.jit, static_argnames=("stale", "calibrated"))
def _evaluate_schedule_batch(dist, g_idx, expert_sats, eta, plan_row,
                             slots, stale_slots, draws,
                             t_gateway, t_expert, t_head, penalty,
                             expert_sec, inv_speed, stale: bool,
                             calibrated: bool = False):
    """(token_latency (Q, T), layer_latency (Q, T, L)) for a ScheduleBatch.

    Identical arithmetic to :func:`_evaluate_batch` except the plan is a
    function of the token's topology slot: ``plan_row[q, slots[t]]``
    selects the row of the union batch, so gateways, expert satellites
    and eta are gathered per token.  With a constant schedule every
    gather returns the static plan's values and the result is bit-for-bit
    the static kernel's (the parity ``tests/test_schedule.py`` pins).
    ``calibrated``/``expert_sec``/``inv_speed`` behave exactly as in
    :func:`_evaluate_batch`.

    dist: (N_T, G, V); g_idx: (P, L); expert_sats: (P, L, I); eta: (P,);
    plan_row: (Q, N_T); slots/stale_slots: (T,); draws: (L, T, K).
    """
    row_tok = plan_row[:, slots]                              # (Q, T)

    def _one_schedule(rows):
        g_tok = g_idx[rows]                                   # (T, L)
        g_next = jnp.roll(g_tok, -1, axis=1)  # ring wrap for the last layer
        sats_tok = expert_sats[rows]                          # (T, L, I)
        eta_tok = eta[rows]                                   # (T,)

        def _layer_step(_, xs):
            draws_l, g_l, g_n, sats_i = xs    # (T, K), (T,), (T,), (T, I)
            sats = jnp.take_along_axis(sats_i, draws_l, axis=1)   # (T, K)
            d_out = hop_latency(dist, slots, stale_slots, g_l[:, None],
                                sats, penalty, stale)
            d_in = hop_latency(dist, slots, stale_slots, g_n[:, None],
                               sats, penalty, stale)
            q = contention_counts(sats)
            if calibrated:
                unit = expert_sec[draws_l] * inv_speed[sats]      # (T, K)
                t_exp = (q.astype(dist.dtype) / eta_tok[:, None]) * unit
            else:
                t_exp = (q.astype(dist.dtype) / eta_tok[:, None]) * t_expert
            lay = t_gateway + (d_out + t_exp + d_in).max(axis=1)
            return None, lay

        _, lat = jax.lax.scan(
            _layer_step, None,
            (draws, g_tok.T, g_next.T, jnp.moveaxis(sats_tok, 1, 0)))
        return lat.T                                          # (T, L)

    layer_lat = jax.vmap(_one_schedule)(row_tok)              # (Q, T, L)
    layer_lat = jnp.where(jnp.isfinite(layer_lat), layer_lat, jnp.nan)
    token_lat = layer_lat.sum(axis=2) + t_head
    return token_lat, layer_lat


@functools.partial(jax.jit, static_argnames=("n_tokens", "top_k"))
def _sample_draws_jax(weights, key, n_tokens: int, top_k: int):
    """(L, T, K) conditional-Poisson draws, one key-split per layer."""
    keys = jax.random.split(key, weights.shape[0])
    return jax.vmap(
        lambda w, k: sample_topk_jax(w, top_k, k, n_tokens)
    )(weights, keys)


# --------------------------------------------------------------------- #
# Public sweep API
# --------------------------------------------------------------------- #


def _service_terms(svc: ServiceModel, topo, ctx_len, include_lm_head):
    """Service constants + calibrated arrays for one engine pass.

    Analytic mode reproduces the legacy scalars exactly (same float ops
    as ``compute.latency_s(workload.*_flops)``); the dummy (1,) arrays it
    ships are dead code under the static ``calibrated=False`` trace.
    """
    t_gateway = svc.gateway_s(ctx_len)
    t_head = svc.head_s if include_lm_head else 0.0
    if svc.per_satellite:
        t_expert = 0.0
        expert_sec = jnp.asarray(svc.expert_s(), dtype=jnp.float32)
        inv_speed = jnp.asarray(svc.inv_speed(topo.n_sats),
                                dtype=jnp.float32)
    else:
        t_expert = svc.expert_scalar
        expert_sec = jnp.zeros((1,), jnp.float32)
        inv_speed = jnp.ones((1,), jnp.float32)
    return t_gateway, t_expert, t_head, expert_sec, inv_speed


def _resolve_slots_draws(topo, activation, rng, n_tokens, slots, draws,
                         sample_backend):
    """Shared host-side sampling for the plan and schedule sweeps: the
    token -> slot assignment and the (L, T, K) expert draws, honoring the
    legacy random stream when neither is pinned by the caller."""
    n_layers = activation.n_layers
    if slots is None:
        slots = rng.integers(0, topo.n_slots, size=n_tokens)
    else:
        slots = np.asarray(slots)
        if slots.shape != (n_tokens,):
            raise ValueError("slots must have shape (n_tokens,)")
        if slots.min() < 0 or slots.max() >= topo.n_slots:
            raise ValueError("slot index out of range for this topology")
    if draws is not None:
        draws = np.asarray(draws)
        if draws.shape != (n_layers, n_tokens, activation.top_k):
            raise ValueError("draws must have shape (n_layers, n_tokens, K)")
    elif sample_backend == "host":
        # Same call order as the legacy simulator: slots, then layer draws.
        draws = np.stack(
            [activation.sample(layer, rng, n_tokens)
             for layer in range(n_layers)]
        )
    elif sample_backend == "jax":
        key = jax.random.PRNGKey(int(rng.integers(0, 2**31 - 1)))
        draws = _sample_draws_jax(
            jnp.asarray(activation.weights, dtype=jnp.float32), key,
            n_tokens, activation.top_k,
        )
    else:
        raise ValueError(f"unknown sample_backend {sample_backend!r}")
    return slots, draws


def evaluate_plans(
    plans: list[PlacementPlan | MultiExpertPlan],
    topo: TopologySample,
    activation: ActivationModel,
    workload: MoEWorkload,
    compute: ComputeConfig,
    rng: np.random.Generator,
    n_tokens: int = 1000,
    ctx_len: int = 1024,
    include_lm_head: bool = True,
    eta: float = 1.0,
    node_sets: list | None = None,
    route_staleness: int = 0,
    reroute_penalty_s: float = 0.0,
    batch: PlanBatch | None = None,
    sample_backend: str = "host",
    slots: np.ndarray | None = None,
    draws: np.ndarray | None = None,
    service_model: ServiceModel | str | None = None,
) -> list[SimResult]:
    """Monte-Carlo E2E latency for a sweep of P plans, one engine pass.

    All plans share the same token draws (common random numbers — the
    right estimator for comparing plans) and slot samples.  With a single
    plan and ``sample_backend="host"`` the random stream matches the
    legacy ``simulate_token_generation`` exactly, so results agree to
    float tolerance (the parity the tier-1 tests pin down).

    ``sample_backend="jax"`` moves conditional-Poisson sampling on-device
    (``sample_topk_jax``); draws then come from a jax PRNG key derived
    from ``rng`` instead of the legacy stream.

    Pass a prebuilt ``batch`` (see :meth:`PlanBatch.from_plans`) to reuse
    the Dijkstra table and its device copies across calls; the call raises
    if ``plans``/``node_sets``/``eta`` differ from what the batch was
    built with.

    ``slots`` (optional, (n_tokens,) int) pins each token to a topology
    slot instead of sampling slots uniformly from ``rng`` — the traffic
    subsystem uses this to tie tokens to wall-clock time.  ``draws``
    (optional, (L, n_tokens, K) int) likewise pins the per-token expert
    draws, so a caller that also needs them (queue-load binning) can
    sample once and share.  The legacy random stream is only reproduced
    when both are None.

    ``service_model`` selects the Eq. 43 service-time source: ``None`` /
    ``"analytic"`` keeps the FLOP-count constants (bit-identical to the
    pre-calibration engine), a calibrated
    :class:`~repro.core.calibration.ServiceModel` activates per-expert,
    per-satellite kernel-calibrated service times.
    """
    plans = list(plans)
    if batch is None:
        batch = PlanBatch.from_plans(plans, topo, node_sets=node_sets, eta=eta)
    if batch.n_plans != len(plans):
        raise ValueError("batch/plans length mismatch")
    if not batch.matches(plans, topo, node_sets, eta):
        raise ValueError(
            "prebuilt batch was built from a different sweep (plan "
            "placements, topology realization, node_sets or eta disagree) "
            "— rebuild it with PlanBatch.from_plans")
    n_layers = activation.n_layers
    if batch.n_layers != n_layers:
        raise ValueError("plan sweep and activation model disagree on n_layers")

    slots, draws = _resolve_slots_draws(topo, activation, rng, n_tokens,
                                        slots, draws, sample_backend)
    stale_slots = (slots - route_staleness) % topo.n_slots

    svc = resolve_service_model(service_model, workload, compute)
    t_gateway, t_expert, t_head, expert_sec, inv_speed = _service_terms(
        svc, topo, ctx_len, include_lm_head)

    dist_d, g_idx_d, sats_d, eta_d = batch.device_arrays()
    token_lat, layer_lat = _evaluate_batch(
        dist_d, g_idx_d, sats_d,
        jnp.asarray(slots, dtype=jnp.int32),
        jnp.asarray(stale_slots, dtype=jnp.int32),
        jnp.asarray(draws, dtype=jnp.int32),
        t_gateway, t_expert, t_head, eta_d,
        reroute_penalty_s,
        expert_sec, inv_speed,
        stale=route_staleness != 0,
        calibrated=svc.per_satellite,
    )
    token_lat = np.asarray(token_lat, dtype=np.float64)
    layer_lat = np.asarray(layer_lat, dtype=np.float64)
    return [
        SimResult(token_latency_s=token_lat[p], layer_latency_s=layer_lat[p],
                  plan_name=batch.names[p])
        for p in range(batch.n_plans)
    ]


def evaluate_schedules(
    schedules: list,
    topo: TopologySample,
    activation: ActivationModel,
    workload: MoEWorkload,
    compute: ComputeConfig,
    rng: np.random.Generator,
    n_tokens: int = 1000,
    ctx_len: int = 1024,
    include_lm_head: bool = True,
    eta: float = 1.0,
    node_sets: list | None = None,
    route_staleness: int = 0,
    reroute_penalty_s: float = 0.0,
    batch: ScheduleBatch | None = None,
    sample_backend: str = "host",
    slots: np.ndarray | None = None,
    draws: np.ndarray | None = None,
    service_model: ServiceModel | str | None = None,
) -> list[SimResult]:
    """Monte-Carlo E2E latency for a sweep of Q time-indexed schedules.

    The time-indexed face of :func:`evaluate_plans`: per token the
    topology slot selects the plan in effect (the ``plan_row`` gather of
    :class:`ScheduleBatch`), so a schedule that switches plans pays each
    slot's own gateways, expert satellites and contention.  Entries may
    be plain plans — they are wrapped into constant schedules, and a
    constant schedule reproduces ``evaluate_plans`` **bit-for-bit**
    (same slots, same draws, same float ops; pinned by
    ``tests/test_schedule.py``).

    Sampling semantics (``slots`` / ``draws`` pinning, the legacy random
    stream, ``sample_backend``) are exactly ``evaluate_plans``'s, as is
    the ``service_model`` switch (analytic bit-parity / calibrated
    per-satellite service).
    """
    schedules = [as_schedule(s, topo.n_slots) for s in schedules]
    if batch is None:
        batch = ScheduleBatch.from_schedules(schedules, topo,
                                             node_sets=node_sets, eta=eta)
    if batch.n_schedules != len(schedules):
        raise ValueError("batch/schedules length mismatch")
    if not batch.matches(schedules, topo, node_sets, eta):
        raise ValueError(
            "prebuilt batch was built from a different sweep (schedule "
            "plans, slot maps, topology realization, node_sets or eta "
            "disagree) — rebuild it with ScheduleBatch.from_schedules")
    if batch.n_layers != activation.n_layers:
        raise ValueError("schedule sweep and activation model disagree on "
                         "n_layers")

    slots, draws = _resolve_slots_draws(topo, activation, rng, n_tokens,
                                        slots, draws, sample_backend)
    stale_slots = (slots - route_staleness) % topo.n_slots

    svc = resolve_service_model(service_model, workload, compute)
    t_gateway, t_expert, t_head, expert_sec, inv_speed = _service_terms(
        svc, topo, ctx_len, include_lm_head)

    dist_d, g_idx_d, sats_d, eta_d = batch.base.device_arrays()
    token_lat, layer_lat = _evaluate_schedule_batch(
        dist_d, g_idx_d, sats_d, eta_d, batch.plan_row_device(),
        jnp.asarray(slots, dtype=jnp.int32),
        jnp.asarray(stale_slots, dtype=jnp.int32),
        jnp.asarray(draws, dtype=jnp.int32),
        t_gateway, t_expert, t_head,
        reroute_penalty_s,
        expert_sec, inv_speed,
        stale=route_staleness != 0,
        calibrated=svc.per_satellite,
    )
    token_lat = np.asarray(token_lat, dtype=np.float64)
    layer_lat = np.asarray(layer_lat, dtype=np.float64)
    return [
        SimResult(token_latency_s=token_lat[q], layer_latency_s=layer_lat[q],
                  plan_name=batch.names[q])
        for q in range(batch.n_schedules)
    ]
