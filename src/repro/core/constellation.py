"""Polar LEO constellation model (paper Sec. II).

Implements the satellite set V (Eq. 1), the time-varying ISL graph
G(n) = {V, E(n)} (Eq. 2-3) and the geometry needed by the latency model
(central angles for Eq. 5, LoS angular rates for the tracking gate).

All geometry is computed in the ECI frame: laser ISLs depend only on the
relative satellite geometry, so Earth rotation is irrelevant here.
Units: meters, seconds, radians.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property

import numpy as np

# Physical constants.
EARTH_RADIUS_M = 6_371_000.0          # R_E, Earth mean radius
MU_EARTH = 3.986004418e14             # standard gravitational parameter [m^3/s^2]
SPEED_OF_LIGHT = 299_792_458.0        # c [m/s]


@dataclasses.dataclass(frozen=True)
class ConstellationConfig:
    """Walker-star polar constellation, paper Sec. VII-A defaults."""

    n_planes: int = 33                 # N_x orbital planes
    sats_per_plane: int = 32           # N_y satellites per plane
    altitude_km: float = 550.0         # H
    inclination_deg: float = 87.0
    phasing: int = 13                  # Walker phasing parameter F
    n_slots: int = 200                 # N_T discrete time slots (one period)
    angular_rate_threshold: float = 0.12   # theta_dot_delta [rad/s]
    survival_prob: float = 0.95        # P^sw, Bernoulli link survival
    cross_seam_isls: bool = True       # include candidate ISLs between the
    #   counter-rotating planes N_x-1 and 0.  The paper's "seam" (Fig. 1)
    #   emerges physically: those partners are usually Earth-occluded or
    #   far apart, and during close passes the ~2v relative motion drives
    #   the PAT slew rate up so the angular-rate gate (Eq. 2) bites, while
    #   co-rotating neighbours slew at ~1e-3 rad/s and always pass.
    grazing_altitude_km: float = 80.0  # atmosphere margin for Earth occlusion

    @property
    def n_sats(self) -> int:
        return self.n_planes * self.sats_per_plane

    @property
    def semi_major_axis_m(self) -> float:
        return EARTH_RADIUS_M + self.altitude_km * 1e3

    @property
    def orbital_period_s(self) -> float:
        a = self.semi_major_axis_m
        return 2.0 * np.pi * np.sqrt(a**3 / MU_EARTH)

    @property
    def orbital_rate(self) -> float:
        """Mean motion [rad/s]."""
        return 2.0 * np.pi / self.orbital_period_s

    @staticmethod
    def scaled(n_planes: int, sats_per_plane: int, **kw) -> "ConstellationConfig":
        """Config with the paper's *relative* phasing (F=13 at 33x32 keeps
        the inter-plane partner offset at ~4.4 deg; preserve that fraction
        when resizing the constellation for sweeps/tests)."""
        frac = 13.0 / (33 * 32)
        phasing = max(1, round(frac * n_planes * sats_per_plane))
        return ConstellationConfig(
            n_planes=n_planes, sats_per_plane=sats_per_plane,
            phasing=phasing, **kw,
        )

    def sat_index(self, x: int, y: int) -> int:
        """Node index of satellite (x, y) — plane-major ordering."""
        return x * self.sats_per_plane + y

    def sat_coord(self, idx: int) -> tuple[int, int]:
        return divmod(idx, self.sats_per_plane)

    def slot_times(self) -> np.ndarray:
        """Slot start times spanning one orbital period."""
        return np.arange(self.n_slots) * (self.orbital_period_s / self.n_slots)


class Constellation:
    """Geometry + static (pre-outage) connectivity of the constellation."""

    def __init__(self, cfg: ConstellationConfig):
        self.cfg = cfg

    # ----------------------------------------------------------------- #
    # Kinematics
    # ----------------------------------------------------------------- #
    def positions(self, t: float | np.ndarray) -> np.ndarray:
        """ECI positions of all satellites at time(s) ``t``.

        Returns array of shape (..., n_sats, 3) in meters.
        """
        cfg = self.cfg
        t = np.asarray(t, dtype=np.float64)
        x = np.arange(cfg.n_planes)
        y = np.arange(cfg.sats_per_plane)

        # Walker-star: RAAN spread over pi; phasing offsets the along-track
        # argument of latitude between adjacent planes.
        raan = np.pi * x / cfg.n_planes                                 # (Nx,)
        phase = (
            2.0 * np.pi * y[None, :] / cfg.sats_per_plane
            + 2.0 * np.pi * cfg.phasing * x[:, None] / (cfg.n_planes * cfg.sats_per_plane)
        )                                                               # (Nx, Ny)

        u = phase[None, ...] + cfg.orbital_rate * t[..., None, None]    # (..., Nx, Ny)
        inc = np.deg2rad(cfg.inclination_deg)
        a = cfg.semi_major_axis_m

        cu, su = np.cos(u), np.sin(u)
        cO, sO = np.cos(raan), np.sin(raan)
        ci, si = np.cos(inc), np.sin(inc)

        # Standard circular-orbit ECI coordinates.
        px = a * (cu * cO[:, None] - su * sO[:, None] * ci)
        py = a * (cu * sO[:, None] + su * cO[:, None] * ci)
        pz = a * (su * si)
        pos = np.stack([px, py, pz], axis=-1)                           # (..., Nx, Ny, 3)
        return pos.reshape(*t.shape, cfg.n_sats, 3) if t.shape else pos.reshape(cfg.n_sats, 3)

    # ----------------------------------------------------------------- #
    # Static edge list (the cylindrical mesh, Fig. 5)
    # ----------------------------------------------------------------- #
    @cached_property
    def edges(self) -> np.ndarray:
        """Static candidate ISLs, shape (n_edges, 2) of node indices.

        Each satellite has up to 4 duplex ISLs: two intra-orbit (ring
        neighbours within the plane) and two inter-orbit (same slot index in
        adjacent planes).  Candidate links across the counter-rotating seam
        (x = N_x-1 <-> x = 0) are included iff ``cfg.cross_seam_isls``; they
        are then gated per-slot by the angular-rate test of Eq. 2.
        """
        cfg = self.cfg
        out: list[tuple[int, int]] = []
        for x in range(cfg.n_planes):
            for y in range(cfg.sats_per_plane):
                u = cfg.sat_index(x, y)
                # intra-orbit ring neighbour
                out.append((u, cfg.sat_index(x, (y + 1) % cfg.sats_per_plane)))
                # inter-orbit neighbour (eastward)
                if x + 1 < cfg.n_planes:
                    out.append((u, cfg.sat_index(x + 1, y)))
                elif cfg.cross_seam_isls:
                    out.append((u, cfg.sat_index(0, y)))
        return np.asarray(out, dtype=np.int64)

    @cached_property
    def intra_orbit_mask(self) -> np.ndarray:
        """Boolean mask over ``edges``: True for intra-orbit ISLs."""
        e = self.edges
        px = e[:, 0] // self.cfg.sats_per_plane
        qx = e[:, 1] // self.cfg.sats_per_plane
        return px == qx

    @cached_property
    def seam_mask(self) -> np.ndarray:
        """Boolean mask over ``edges``: True for cross-seam (counter-rotating)
        candidate ISLs."""
        e = self.edges
        px = e[:, 0] // self.cfg.sats_per_plane
        qx = e[:, 1] // self.cfg.sats_per_plane
        hi = self.cfg.n_planes - 1
        return ((px == hi) & (qx == 0)) | ((px == 0) & (qx == hi))

    # ----------------------------------------------------------------- #
    # Per-slot edge geometry
    # ----------------------------------------------------------------- #
    def central_angles(self, t: float) -> np.ndarray:
        """Central angle theta_{u,v}(t) for every candidate edge (Eq. 5 input)."""
        pos = self.positions(float(t))
        e = self.edges
        pu = pos[e[:, 0]]
        pv = pos[e[:, 1]]
        a = self.cfg.semi_major_axis_m
        cosang = np.einsum("ij,ij->i", pu, pv) / (a * a)
        return np.arccos(np.clip(cosang, -1.0, 1.0))

    def edge_distances(self, t: float) -> np.ndarray:
        """Chord (line-of-sight) distance per candidate edge [m] (Eq. 5)."""
        theta = self.central_angles(t)
        return 2.0 * self.cfg.semi_major_axis_m * np.sin(theta / 2.0)

    def los_angular_rates(self, t: float, dt: float = 1.0) -> np.ndarray:
        """|d/dt| of the LoS direction per candidate edge [rad/s].

        Numerical derivative of the unit LoS vector: the PAT system has to
        slew at this rate to keep the laser pointed (Eq. 2 gate).
        """
        e = self.edges

        def unit_los(tt: float) -> np.ndarray:
            pos = self.positions(float(tt))
            d = pos[e[:, 1]] - pos[e[:, 0]]
            return d / np.linalg.norm(d, axis=-1, keepdims=True)

        e0 = unit_los(t)
        e1 = unit_los(t + dt)
        dot = np.clip(np.einsum("ij,ij->i", e0, e1), -1.0, 1.0)
        return np.arccos(dot) / dt

    # ----------------------------------------------------------------- #
    # Time-varying feasibility (Eq. 2-3)
    # ----------------------------------------------------------------- #
    @property
    def max_central_angle(self) -> float:
        """Largest central angle with an unobstructed LoS (Earth + atmosphere
        grazing): theta_max = 2*arccos((R_E + h_graze) / a)."""
        cfg = self.cfg
        ratio = (EARTH_RADIUS_M + cfg.grazing_altitude_km * 1e3) / cfg.semi_major_axis_m
        return 2.0 * np.arccos(np.clip(ratio, -1.0, 1.0))

    def occlusion_feasible(self, t: float) -> np.ndarray:
        """LoS not blocked by the Earth (relevant only for seam partners;
        adjacent co-rotating neighbours are always within a few degrees)."""
        return self.central_angles(t) <= self.max_central_angle

    def tracking_feasible(self, t: float) -> np.ndarray:
        """Deterministic gates: LoS exists AND theta_dot <= threshold (Eq. 2)."""
        ok = self.los_angular_rates(t) <= self.cfg.angular_rate_threshold
        return ok & self.occlusion_feasible(t)

    def sample_edge_mask(self, t: float, rng: np.random.Generator) -> np.ndarray:
        """One realization of E(n): PAT gate AND Bernoulli survival (Eq. 2-3)."""
        feas = self.tracking_feasible(t)
        xi = rng.random(feas.shape[0]) < self.cfg.survival_prob
        return feas & xi
