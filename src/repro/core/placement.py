"""Two-level MoE placement (paper Sec. IV-C/D + Sec. V Theorem 1).

Level 1 — layer placement: partition the cylindrical mesh into L ring-
aligned subnets (Eq. 17), one MoE layer each; the ring wrap-around matches
the autoregressive layer->layer->first-layer dataflow (Remark 1).

Level 2 — intra-layer placement: central gateway (Eq. 18) and the
Theorem-1 expert->satellite assignment (hot experts on low expected-path-
latency satellites).  Baselines RandPlace / RandIntra / RandIntra-CG from
Sec. VII-A3 and the multi-expert extension of Sec. VI-B are included.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .activation import ActivationModel
from .constellation import Constellation, ConstellationConfig
from .latency import (ComputeConfig, TopologySample, expected_path_latency,
                      gateway_distance_table)
from .workload import MoEWorkload


@dataclasses.dataclass
class PlacementPlan:
    """A full expert/gateway -> satellite mapping for an L-layer MoE."""

    gateways: np.ndarray          # (L,) node index of gateway satellite phi_l
    expert_sats: np.ndarray       # (L, I) node index hosting expert i of layer l
    name: str = "plan"
    # Diagnostics (filled by the optimizer when available):
    tau_bar: np.ndarray | None = None       # (L, I) expected path latency of chosen sats
    expert_rank: np.ndarray | None = None   # (L, I) latency rank of expert i

    @property
    def n_layers(self) -> int:
        return len(self.gateways)

    @property
    def n_experts(self) -> int:
        return self.expert_sats.shape[1]

    def validate(self, n_sats: int) -> None:
        used = np.concatenate([self.gateways, self.expert_sats.ravel()])
        if used.min() < 0 or used.max() >= n_sats:
            raise ValueError("satellite index out of range")
        # one sub-network per satellite (paper Sec. IV-D assumption)
        if len(np.unique(used)) != used.size:
            raise ValueError("a satellite hosts more than one sub-network")


# --------------------------------------------------------------------- #
# Level 1 — ring subnets + central gateways
# --------------------------------------------------------------------- #


def ring_subnets(cfg: ConstellationConfig, n_layers: int) -> list[np.ndarray]:
    """Eq. 17: L disjoint subnets along the ring (intra-orbit) direction."""
    if cfg.sats_per_plane < n_layers:
        raise ValueError(f"need N_y >= L, got N_y={cfg.sats_per_plane}, L={n_layers}")
    y_span = cfg.sats_per_plane // n_layers
    subnets = []
    for layer in range(n_layers):
        ys = np.arange(layer * y_span, (layer + 1) * y_span)
        nodes = (np.arange(cfg.n_planes)[:, None] * cfg.sats_per_plane + ys[None, :])
        subnets.append(nodes.ravel())
    return subnets


def central_gateway(cfg: ConstellationConfig, layer: int, n_layers: int) -> int:
    """Eq. 18: gateway at the subnet centre."""
    y_span = cfg.sats_per_plane // n_layers
    x = cfg.n_planes // 2
    y = layer * y_span + (y_span - 1) // 2
    return cfg.sat_index(x, y)


def subnet_routing_sets(cfg: ConstellationConfig, n_layers: int) -> list:
    """Per-layer node sets emulating intra-subnet-only routing: layer l may
    route over subnets {l-1, l, l+1} (its own plus the adjacent ones its
    dispatch/combine hops touch) instead of the whole constellation.  Used
    for the fidelity study in EXPERIMENTS.md §Paper-claims."""
    subnets = ring_subnets(cfg, n_layers)
    return [
        np.concatenate([subnets[(l - 1) % n_layers], subnets[l],
                        subnets[(l + 1) % n_layers]])
        for l in range(n_layers)
    ]


# --------------------------------------------------------------------- #
# Level 2 — Theorem-1 expert placement
# --------------------------------------------------------------------- #


def theorem1_assignment(
    activation_probs: np.ndarray, tau_bar: np.ndarray
) -> np.ndarray:
    """Theorem 1: expert with i-th highest P -> satellite with i-th lowest tau.

    Parameters
    ----------
    activation_probs: (I,) per-expert activation probabilities.
    tau_bar:          (C,) expected path latency per candidate satellite,
                      C >= I.

    Returns (I,) candidate indices: entry i = candidate hosting expert i.
    """
    n_exp = len(activation_probs)
    if len(tau_bar) < n_exp:
        raise ValueError("fewer candidate satellites than experts")
    # Stable sorts for deterministic tie-breaking.
    expert_order = np.argsort(-np.asarray(activation_probs), kind="stable")
    sat_order = np.argsort(np.asarray(tau_bar), kind="stable")[:n_exp]
    assign = np.empty(n_exp, dtype=np.int64)
    assign[expert_order] = sat_order
    return assign


def _layer_tau_bar(
    dist_table: np.ndarray,
    layer: int,
    n_layers: int,
    candidates: np.ndarray,
    compute_s: float,
) -> np.ndarray:
    tau_all = expected_path_latency(dist_table, layer, n_layers, compute_s)
    return tau_all[candidates]


def spacemoe_plan(
    constellation: Constellation,
    topo: TopologySample,
    activation: ActivationModel,
    workload: MoEWorkload | None = None,
    compute: ComputeConfig | None = None,
    ctx_len: int = 1024,
) -> PlacementPlan:
    """Full SpaceMoE placement: ring subnets + central gateways + Theorem 1."""
    cfg = constellation.cfg
    n_layers, n_experts = activation.n_layers, activation.n_experts
    subnets = ring_subnets(cfg, n_layers)
    gateways = np.array(
        [central_gateway(cfg, l, n_layers) for l in range(n_layers)], dtype=np.int64
    )
    dist = gateway_distance_table(topo, gateways)

    # Constant per-candidate compute offset (does not change the ordering,
    # but keeps tau_bar in true seconds for diagnostics).
    t_cmp = 0.0
    if workload is not None and compute is not None:
        t_cmp = compute.latency_s(workload.gateway_flops(ctx_len)) + \
            compute.latency_s(workload.expert_flops)

    expert_sats = np.empty((n_layers, n_experts), dtype=np.int64)
    tau_chosen = np.empty((n_layers, n_experts), dtype=np.float64)
    ranks = np.empty((n_layers, n_experts), dtype=np.int64)
    for layer in range(n_layers):
        cand = subnets[layer][subnets[layer] != gateways[layer]]
        tau = _layer_tau_bar(dist, layer, n_layers, cand, t_cmp)
        probs = activation.probs(layer)
        assign = theorem1_assignment(probs, tau)
        expert_sats[layer] = cand[assign]
        tau_chosen[layer] = tau[assign]
        order = np.argsort(tau, kind="stable")
        rank_of_candidate = np.empty(len(cand), dtype=np.int64)
        rank_of_candidate[order] = np.arange(len(cand))
        ranks[layer] = rank_of_candidate[assign]

    plan = PlacementPlan(
        gateways=gateways, expert_sats=expert_sats, name="SpaceMoE",
        tau_bar=tau_chosen, expert_rank=ranks,
    )
    plan.validate(cfg.n_sats)
    return plan


# --------------------------------------------------------------------- #
# Benchmark baselines (paper Sec. VII-A3)
# --------------------------------------------------------------------- #


def rand_place_plan(
    cfg: ConstellationConfig, n_layers: int, n_experts: int, rng: np.random.Generator
) -> PlacementPlan:
    """RandPlace: gateways + experts uniformly over the whole constellation."""
    total = n_layers * (1 + n_experts)
    picks = rng.choice(cfg.n_sats, size=total, replace=False)
    gateways = picks[:n_layers]
    experts = picks[n_layers:].reshape(n_layers, n_experts)
    plan = PlacementPlan(gateways=gateways, expert_sats=experts, name="RandPlace")
    plan.validate(cfg.n_sats)
    return plan


def rand_intra_plan(
    cfg: ConstellationConfig, n_layers: int, n_experts: int, rng: np.random.Generator
) -> PlacementPlan:
    """RandIntra: ring subnets, but gateway + experts random within each."""
    subnets = ring_subnets(cfg, n_layers)
    gateways = np.empty(n_layers, dtype=np.int64)
    experts = np.empty((n_layers, n_experts), dtype=np.int64)
    for layer, nodes in enumerate(subnets):
        picks = rng.choice(nodes, size=1 + n_experts, replace=False)
        gateways[layer] = picks[0]
        experts[layer] = picks[1:]
    plan = PlacementPlan(gateways=gateways, expert_sats=experts, name="RandIntra")
    plan.validate(cfg.n_sats)
    return plan


def rand_intra_cg_plan(
    cfg: ConstellationConfig, n_layers: int, n_experts: int, rng: np.random.Generator
) -> PlacementPlan:
    """RandIntra-CG: central gateway (Eq. 18), random experts in the subnet."""
    subnets = ring_subnets(cfg, n_layers)
    gateways = np.array(
        [central_gateway(cfg, l, n_layers) for l in range(n_layers)], dtype=np.int64
    )
    experts = np.empty((n_layers, n_experts), dtype=np.int64)
    for layer, nodes in enumerate(subnets):
        cand = nodes[nodes != gateways[layer]]
        experts[layer] = rng.choice(cand, size=n_experts, replace=False)
    plan = PlacementPlan(gateways=gateways, expert_sats=experts, name="RandIntra-CG")
    plan.validate(cfg.n_sats)
    return plan


# --------------------------------------------------------------------- #
# Sec. VI-B — multi-expert satellites
# --------------------------------------------------------------------- #


@dataclasses.dataclass
class MultiExpertPlan:
    """Expert -> satellite mapping allowing up to N_E experts per satellite."""

    gateways: np.ndarray
    expert_sats: np.ndarray       # (L, I): satellite hosting expert i
    experts_per_sat: int
    name: str = "multi-expert"


def multi_expert_plan(
    constellation: Constellation,
    topo: TopologySample,
    activation: ActivationModel,
    experts_per_sat: int,
    mode: str = "slotted",
    eta: float = 1.0,
    expert_latency_s: float = 0.0,
) -> MultiExpertPlan:
    """Sec. VI-B placement with N_E >= 1 experts per satellite.

    mode="slotted"  (propagation-limited regime): each satellite offers N_E
        identical latency slots; fill ascending-latency slots with experts
        in descending activation order — the natural Theorem-1 extension.
    mode="spread"   (compute-limited regime): assign the I hottest experts
        round-robin across the ceil(I/N_E) lowest-latency satellites so hot
        experts do not contend on the same node (Eq. 43 contention term).
    """
    cfg = constellation.cfg
    n_layers, n_experts = activation.n_layers, activation.n_experts
    subnets = ring_subnets(cfg, n_layers)
    gateways = np.array(
        [central_gateway(cfg, l, n_layers) for l in range(n_layers)], dtype=np.int64
    )
    dist = gateway_distance_table(topo, gateways)

    n_sats_needed = int(np.ceil(n_experts / experts_per_sat))
    expert_sats = np.empty((n_layers, n_experts), dtype=np.int64)
    for layer in range(n_layers):
        cand = subnets[layer][subnets[layer] != gateways[layer]]
        tau = _layer_tau_bar(dist, layer, n_layers, cand, 0.0)
        order = cand[np.argsort(tau, kind="stable")][:n_sats_needed]
        hot_first = np.argsort(-activation.probs(layer), kind="stable")
        if mode == "slotted":
            # expert ranks 0..I-1 fill satellite slots in blocks of N_E
            sat_of_rank = order[np.arange(n_experts) // experts_per_sat]
        elif mode == "spread":
            sat_of_rank = order[np.arange(n_experts) % n_sats_needed]
        else:
            raise ValueError(f"unknown mode {mode!r}")
        expert_sats[layer, hot_first] = sat_of_rank
    return MultiExpertPlan(
        gateways=gateways, expert_sats=expert_sats,
        experts_per_sat=experts_per_sat, name=f"multi-expert/{mode}",
    )


# --------------------------------------------------------------------- #
# Plan sweeps over the batched engine
# --------------------------------------------------------------------- #


def baseline_plans(
    constellation: Constellation,
    topo: TopologySample,
    activation: ActivationModel,
    rng: np.random.Generator,
    n_random_draws: int = 3,
    workload: MoEWorkload | None = None,
    compute: ComputeConfig | None = None,
    ctx_len: int = 1024,
) -> list[PlacementPlan]:
    """The Sec. VII-A3 candidate set as one sweep list: SpaceMoE plus
    ``n_random_draws`` draws of each random baseline, numbered so every
    plan in the sweep has a distinct name."""
    cfg = constellation.cfg
    n_layers, n_experts = activation.n_layers, activation.n_experts
    plans: list[PlacementPlan] = [
        spacemoe_plan(constellation, topo, activation, workload, compute,
                      ctx_len=ctx_len)
    ]
    for maker in (rand_place_plan, rand_intra_plan, rand_intra_cg_plan):
        for draw in range(n_random_draws):
            p = maker(cfg, n_layers, n_experts, rng)
            p.name = f"{p.name}#{draw}"
            plans.append(p)
    return plans


def rank_plans(
    plans: list,
    topo: TopologySample,
    activation: ActivationModel,
    workload: MoEWorkload,
    compute: ComputeConfig,
    rng: np.random.Generator,
    n_tokens: int = 500,
    **kwargs,
) -> list[tuple]:
    """Evaluate a candidate-plan sweep in one batched engine pass and
    return (plan, SimResult) pairs ordered best-first by (drop_rate,
    mean latency): ``mean_s`` excludes undeliverable tokens, so ranking
    on it alone would reward plans that drop their worst tokens —
    delivery comes first, speed second.

    Common random numbers across plans (see ``engine.evaluate_plans``)
    make this the low-variance comparison the continuous-re-placement
    loop needs at every topology slot.
    """
    from .engine import evaluate_plans  # deferred: engine imports this module
    results = evaluate_plans(plans, topo, activation, workload, compute, rng,
                             n_tokens=n_tokens, **kwargs)
    order = sorted(range(len(results)),
                   key=lambda i: (results[i].drop_rate, results[i].mean_s))
    return [(plans[i], results[i]) for i in order]
