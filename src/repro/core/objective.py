"""Layer computation latency (paper Sec. V, Eq. 33/36/37 + Lemma 1-2).

Given I candidate satellites sorted by expected path latency
tau_1 <= ... <= tau_I and a permutation assigning expert e to latency rank
s, the expected layer latency under the conditional-Poisson top-K model is

    tau_c(X) = sum_s (1 - Pr(R_X < s)) * (tau_s - tau_{s-1})     (Lemma 1)
    Pr(R_X < s) = e_K(w~_1..w~_{s-1}) / e_K(w_1..w_I)            (Lemma 2)

with w~_s the importance weight of the expert placed at rank s.  This is
exact and O(I*K) — it is both the optimization objective and the unit-test
oracle for the Monte-Carlo simulator.
"""
from __future__ import annotations

import itertools

import numpy as np

from .activation import esp_prefix_table, sample_topk


def layer_latency_closed_form(
    tau_sorted: np.ndarray, weights: np.ndarray, rank_to_expert: np.ndarray, k: int
) -> float:
    """Exact expected layer latency tau_c for one placement.

    Parameters
    ----------
    tau_sorted:     (I,) expected path latencies of the I used satellites,
                    ascending (rank order).
    weights:        (I,) expert importance weights (expert order).
    rank_to_expert: (I,) permutation; rank_to_expert[s] = expert at rank s.
    k:              top-K.
    """
    tau_sorted = np.asarray(tau_sorted, dtype=np.float64)
    n = len(tau_sorted)
    if np.any(np.diff(tau_sorted) < -1e-12):
        raise ValueError("tau_sorted must be ascending")
    w_perm = np.asarray(weights, dtype=np.float64)[np.asarray(rank_to_expert)]
    table = esp_prefix_table(w_perm, k)            # E[i, k] = e_k(w~_1..i)
    e_total = table[n, k]
    # Pr(R_X < s) for s = 1..I  (prefix of length s-1).
    cdf = table[0:n, k] / e_total
    delta = np.diff(np.concatenate([[0.0], tau_sorted]))
    return float(np.sum((1.0 - cdf) * delta))


def layer_latency_monte_carlo(
    tau_sorted: np.ndarray,
    weights: np.ndarray,
    rank_to_expert: np.ndarray,
    k: int,
    rng: np.random.Generator,
    n_draws: int = 20000,
) -> float:
    """MC estimate of tau_c — cross-validates the closed form."""
    expert_to_rank = np.empty_like(rank_to_expert)
    expert_to_rank[np.asarray(rank_to_expert)] = np.arange(len(rank_to_expert))
    draws = sample_topk(weights, k, rng, n_draws)          # expert ids
    ranks = expert_to_rank[draws]
    return float(np.asarray(tau_sorted)[ranks].max(axis=1).mean())


def brute_force_optimal(
    tau_sorted: np.ndarray, weights: np.ndarray, k: int
) -> tuple[np.ndarray, float]:
    """Exhaustive search over all I! placements (test oracle, I <= 8)."""
    n = len(weights)
    best_perm, best_val = None, np.inf
    for perm in itertools.permutations(range(n)):
        val = layer_latency_closed_form(tau_sorted, weights, np.asarray(perm), k)
        if val < best_val - 1e-15:
            best_perm, best_val = np.asarray(perm), val
    return best_perm, float(best_val)
