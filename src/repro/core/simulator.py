"""E2E token-generation latency simulator (paper Sec. IV-B protocol).

Per token: sample a topology snapshot n ~ U{1..N_T} (as in Sec. VII-A2),
then for each layer l

    tau_l = T_gateway + max_{i in S_hat_l} [ D(phi_l, sat(i); n) + T_expert
                                             + D(sat(i), phi_{l+1}; n) ]

with S_hat_l ~ conditional-Poisson top-K (Eq. 12), and the ring wrap for
the last layer (Eq. 22).  Token latency = sum_l tau_l (+ lm head on the
last gateway).

``simulate_token_generation`` is a thin wrapper over the batched
jit-compiled engine (:mod:`repro.core.engine`), preserving the historical
single-plan API and random stream.  The original NumPy per-layer loop is
kept as ``simulate_token_generation_legacy`` — the golden reference the
engine parity tests (and the ``bench_engine`` speedup numbers) compare
against.
"""
from __future__ import annotations

import numpy as np

from .activation import ActivationModel
from .engine import HOP_SCALE_S, SimResult, evaluate_plans
from .latency import ComputeConfig, TopologySample, gateway_distance_table
from .placement import MultiExpertPlan, PlacementPlan
from .workload import MoEWorkload

__all__ = ["SimResult", "simulate_token_generation",
           "simulate_token_generation_legacy"]


def simulate_token_generation(
    plan: PlacementPlan | MultiExpertPlan,
    topo: TopologySample,
    activation: ActivationModel,
    workload: MoEWorkload,
    compute: ComputeConfig,
    rng: np.random.Generator,
    n_tokens: int = 1000,
    ctx_len: int = 1024,
    include_lm_head: bool = True,
    eta: float = 1.0,
    node_sets: list | None = None,
    route_staleness: int = 0,
    reroute_penalty_s: float = 0.0,
    backend: str = "engine",
) -> SimResult:
    """Monte-Carlo E2E latency under a placement plan.

    For :class:`MultiExpertPlan` the per-satellite contention term of
    Eq. 43 is applied: an activated satellite running q experts pays
    (q/eta) * T_expert.  ``node_sets`` restricts routing per layer
    (intra-subnet mode; see placement.subnet_routing_sets).

    Link-state awareness (paper Sec. VIII open challenge):
    ``route_staleness`` = s > 0 means paths are *chosen* from the topology
    s slots ago but *traversed* on the current one — when the stale choice
    is broken or slower, the token pays the current shortest path plus
    ``reroute_penalty_s`` (discovery/handshake).  s = 0 is the
    link-state-aware ideal the rest of the paper assumes.

    ``backend="engine"`` (default) runs the jit-compiled batched engine
    with P=1; ``backend="numpy"`` runs the legacy float64 reference.
    Both consume the same random stream from ``rng``.
    """
    if backend == "numpy":
        return simulate_token_generation_legacy(
            plan, topo, activation, workload, compute, rng,
            n_tokens=n_tokens, ctx_len=ctx_len,
            include_lm_head=include_lm_head, eta=eta, node_sets=node_sets,
            route_staleness=route_staleness,
            reroute_penalty_s=reroute_penalty_s,
        )
    if backend != "engine":
        raise ValueError(f"unknown backend {backend!r}")
    return evaluate_plans(
        [plan], topo, activation, workload, compute, rng,
        n_tokens=n_tokens, ctx_len=ctx_len,
        include_lm_head=include_lm_head, eta=eta, node_sets=node_sets,
        route_staleness=route_staleness, reroute_penalty_s=reroute_penalty_s,
    )[0]


def simulate_token_generation_legacy(
    plan: PlacementPlan | MultiExpertPlan,
    topo: TopologySample,
    activation: ActivationModel,
    workload: MoEWorkload,
    compute: ComputeConfig,
    rng: np.random.Generator,
    n_tokens: int = 1000,
    ctx_len: int = 1024,
    include_lm_head: bool = True,
    eta: float = 1.0,
    node_sets: list | None = None,
    route_staleness: int = 0,
    reroute_penalty_s: float = 0.0,
) -> SimResult:
    """Reference NumPy implementation (one plan, Python loop over layers)."""
    n_layers = activation.n_layers
    dist = gateway_distance_table(topo, plan.gateways, node_sets)  # (N_T,L,V)

    t_gateway = compute.latency_s(workload.gateway_flops(ctx_len))
    t_expert = compute.latency_s(workload.expert_flops)
    t_head = compute.latency_s(workload.lm_head_flops) if include_lm_head else 0.0

    slots = rng.integers(0, topo.n_slots, size=n_tokens)
    multi = isinstance(plan, MultiExpertPlan)

    stale_slots = (slots - route_staleness) % topo.n_slots

    def hop_latency(layer_idx, sats):
        cur = np.take_along_axis(dist[slots, layer_idx], sats, axis=1)
        if route_staleness == 0:
            return cur
        # Stale routing table: smooth orbital drift is free (the old path
        # still works, its latency just moved), but a *topology* change —
        # the stale route detours by at least one extra hop (>~2 ms) or
        # broke entirely — forces discovery + re-route on the current
        # graph: latency = current shortest path + penalty.
        stale = np.take_along_axis(dist[stale_slots, layer_idx], sats, axis=1)
        broken = (np.abs(stale - cur) > HOP_SCALE_S) | ~np.isfinite(stale)
        return cur + reroute_penalty_s * broken

    layer_lat = np.empty((n_tokens, n_layers), dtype=np.float64)
    for layer in range(n_layers):
        nxt = (layer + 1) % n_layers
        draws = activation.sample(layer, rng, n_tokens)        # (n_tokens, K)
        sats = plan.expert_sats[layer][draws]                  # (n_tokens, K)
        d_out = hop_latency(layer, sats)
        d_in = hop_latency(nxt, sats)
        if multi:
            # contention: q_s = number of activated experts colocated on the
            # same satellite for this token (Eq. 43).
            q = (sats[:, :, None] == sats[:, None, :]).sum(axis=2)
            t_exp = (q / eta) * t_expert
        else:
            t_exp = t_expert
        layer_lat[:, layer] = t_gateway + (d_out + t_exp + d_in).max(axis=1)

    # Tokens whose routing hits an unreachable satellite in that slot are
    # undeliverable: count them as drops (NaN), never as infinite latency.
    layer_lat = np.where(np.isfinite(layer_lat), layer_lat, np.nan)
    token_lat = layer_lat.sum(axis=1) + t_head
    return SimResult(
        token_latency_s=token_lat, layer_latency_s=layer_lat,
        plan_name=getattr(plan, "name", "plan"),
    )
