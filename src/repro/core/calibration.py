"""Model-in-the-loop service times: calibrate Eq. 43 on the real kernels.

Every latency the engine (:mod:`repro.core.engine`) and the fleet
simulator (:mod:`repro.traffic.queueing`) produce rests on per-component
service-time constants.  The analytic mode derives them purely from FLOP
counts (``ComputeConfig.latency_s``); this module replaces them with
numbers anchored to the repo's real MoE kernels:

1. **Measure** the real kernels on the current host — the grouped expert
   matmul (``kernels.moe_gmm`` / its jnp oracle) for the expert FFN, the
   flash-decode attention kernel for the gateway (swept over decode batch
   sizes), and the unembedding matmul for the head.
2. **Cross with the roofline** (:mod:`repro.launch.roofline` max-rule):
   each component's ideal host time is ``max(flops / f_host, bytes /
   bw_host)`` on the *measured arrays*; the ratio ideal / measured is the
   component's achieved **efficiency** (clipped to <= 1).
3. **Project to satellite units**: a satellite's ideal time uses the
   paper's onboard compute (``ComputeConfig.flops_per_s``) and a memory
   bandwidth scaled to the same bytes-per-FLOP balance as the TPU v5e
   roofline constants; dividing by the measured efficiency yields the
   calibrated per-expert / per-batch service times.

The result is a versioned :class:`ServiceTable` (JSON, content-hashed,
memoized under ``calibration_tables/`` so CPU-only CI never re-times) and
a :class:`ServiceModel` facade the engine and ``FleetSim`` consume.  Mode
``"analytic"`` reproduces the pre-calibration constants **bit-for-bit**;
mode ``"calibrated"`` activates per-satellite, per-expert service and
batch-size-dependent decode rates read off the decode-attention roofline:

    gateway_step_s(B) = max(B * flops_tok / f,
                            (weight_bytes + B * token_bytes) / bw) / eff
    decode_rate(B)    = B / gateway_step_s(B)        # monotone in B

The FLOP/byte pairs stored per component double as the energy proxies the
placement layer can weight (compute joules ~ FLOPs, DRAM joules ~ bytes).
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
from pathlib import Path

import numpy as np

from .latency import ComputeConfig
from .workload import MoEWorkload

#: Schema version; bump on any field-meaning change so stale committed
#: tables fail loudly instead of silently mis-predicting.
TABLE_VERSION = 1

#: Committed, versioned tables live inside the package so installed
#: checkouts (and CPU-only CI) resolve them without re-timing.
TABLE_DIR = Path(__file__).resolve().parent / "calibration_tables"

#: Decode batch sizes the gateway kernel is swept over.
DEFAULT_BATCHES = (1, 2, 4, 8, 16, 32)

#: TPU v5e bytes-per-FLOP balance (HBM_BW / PEAK_FLOPS).  Satellite memory
#: bandwidth defaults to the onboard FLOP rate times this balance, keeping
#: the arithmetic-intensity threshold of the satellite roofline identical
#: to the measured accelerator's.
SAT_BYTES_PER_FLOP = 819e9 / 197e12

#: Efficiency floor: a measurement slower than 10000x the roofline ideal
#: is treated as overhead noise, not signal.
MIN_EFFICIENCY = 1e-4

#: Tables loaded this process, name -> content hash (provenance feed for
#: the BENCH JSON emitters).
_LOADED_TABLES: dict[str, str] = {}


def _canonical_json(d: dict) -> str:
    """Stable serialization used for hashing and on-disk storage."""
    return json.dumps(d, sort_keys=True, separators=(",", ":"))


@dataclasses.dataclass(frozen=True)
class ServiceTable:
    """One calibrated (workload x host) service-time table.

    Attributes:
        version: Schema version (:data:`TABLE_VERSION`).
        name: Registry name, usually the model-config arch id.
        jax_version: jax that produced the measurements.
        backend: jax backend the measurements ran on (``cpu``/``tpu``).
        impl: Kernel implementation measured — ``"ref"`` (jnp oracles,
            the off-TPU default) or ``"pallas"`` (Mosaic kernels).
        ctx_len: Attention context the gateway sweep used.
        batches: Decode batch sizes of the gateway sweep.
        workload: ``dataclasses.asdict`` of the :class:`MoEWorkload`.
        host: Probed host rates ``{"flops_per_s", "bw_bytes_per_s"}``.
        sat: Satellite rates the derived times target (same keys).
        energy: Per-component FLOP/byte energy proxies in deployment
            (workload-dtype) units.
        measured_s: Raw kernel wall timings, seconds.
        efficiency: Per-component achieved fraction of the host roofline.
        derived: Satellite-unit service times — ``expert_s`` (one entry
            per expert), ``gateway_s_by_batch`` (per-call step seconds at
            the swept batches), ``head_s``.
        meta: Free-form extras (iteration counts, dry-run attachment).
        table_hash: sha256 of the canonical JSON minus this field.
    """

    version: int
    name: str
    jax_version: str
    backend: str
    impl: str
    ctx_len: int
    batches: tuple[int, ...]
    workload: dict
    host: dict
    sat: dict
    energy: dict
    measured_s: dict
    efficiency: dict
    derived: dict
    meta: dict = dataclasses.field(default_factory=dict)
    table_hash: str = ""

    def to_dict(self) -> dict:
        """Plain-dict form (hash recomputed, lists for tuples)."""
        d = dataclasses.asdict(self)
        d["batches"] = [int(b) for b in self.batches]
        d["table_hash"] = self.compute_hash()
        return d

    def compute_hash(self) -> str:
        """Content hash over every field except ``table_hash`` itself."""
        d = dataclasses.asdict(self)
        d["batches"] = [int(b) for b in self.batches]
        d.pop("table_hash")
        return hashlib.sha256(_canonical_json(d).encode()).hexdigest()[:16]

    @classmethod
    def from_dict(cls, d: dict) -> "ServiceTable":
        """Rebuild from a stored dict, verifying version and hash."""
        d = dict(d)
        if d.get("version") != TABLE_VERSION:
            raise ValueError(
                f"service table {d.get('name')!r} has version "
                f"{d.get('version')}, expected {TABLE_VERSION} — re-run "
                "calibration (benchmarks/bench_calibration.py --refresh)")
        d["batches"] = tuple(int(b) for b in d["batches"])
        table = cls(**d)
        want = table.compute_hash()
        if d.get("table_hash") and d["table_hash"] != want:
            raise ValueError(
                f"service table {d.get('name')!r} content hash mismatch "
                f"({d['table_hash']} != {want}) — the file was edited by "
                "hand or corrupted; re-run calibration")
        return table

    def workload_obj(self) -> MoEWorkload:
        """The :class:`MoEWorkload` the table was calibrated for."""
        return MoEWorkload(**self.workload)


# --------------------------------------------------------------------- #
# Measurement: real kernels, blocked wall time
# --------------------------------------------------------------------- #


@functools.lru_cache(maxsize=1)
def host_probe(n: int = 768, copy_mb: int = 32, iters: int = 5) -> tuple:
    """Probe the host's achievable (flops_per_s, bw_bytes_per_s).

    One f32 ``n x n`` matmul rates the FLOP ceiling and one big-array
    copy rates memory bandwidth; both are the denominators the measured
    kernel efficiencies are computed against, so they only need to be
    *consistent*, not peak-datasheet-accurate.  Memoized per process.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import timed_call

    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (n, n), jnp.float32)
    mm = jax.jit(lambda x, y: x @ y)
    t_mm = timed_call(mm, a, a, iters=iters)
    flops_per_s = 2.0 * n ** 3 / t_mm

    m = (copy_mb * 1 << 20) // 4
    big = jnp.zeros((m,), jnp.float32)
    cp = jax.jit(lambda x: x * np.float32(1.0000001))
    t_cp = timed_call(cp, big, iters=iters)
    bw = 2.0 * 4.0 * m / t_cp            # read + write
    return float(flops_per_s), float(bw)


def _ideal_host(flops: float, nbytes: float, host: tuple) -> float:
    """Roofline max-rule ideal time on the probed host, seconds."""
    f, bw = host
    return max(flops / f, nbytes / bw)


def measure_components(workload: MoEWorkload, ctx_len: int,
                       batches: tuple[int, ...], impl: str,
                       iters: int = 3, rows_per_expert: int = 32) -> dict:
    """Time the real kernels for every service component on this host.

    Returns a dict with the raw wall timings (``measured_s``), the
    FLOP/byte energy of the *measured arrays* (``kernel_energy`` — f32,
    distinct from the deployment-dtype table energy) and the probed host
    rates, i.e. everything :func:`derive_table` needs to be pure.

    Args:
        workload: Shapes to measure (experts, heads, context...).
        ctx_len: KV-cache length for the decode-attention sweep.
        batches: Decode batch sizes to sweep the attention kernel over.
        impl: ``"ref"`` for the jnp oracles (CPU-friendly) or
            ``"pallas"`` for the real Mosaic kernels (TPU; interpret
            mode off-TPU is ~1000x slower and not representative).
        iters: Best-of-N timing iterations per point.
        rows_per_expert: Bucket rows per expert in the gmm measurement
            (amortizes dispatch overhead over E*rows visits).
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref
    from repro.kernels.ops import timed_call

    if impl == "ref":
        gmm_fn, attn_fn = ref.gmm_ref, ref.decode_attention_ref
    elif impl == "pallas":
        gmm_fn, attn_fn = ops.gmm, ops.decode_attention
    else:
        raise ValueError(f"impl must be 'ref' or 'pallas', got {impl!r}")

    w = workload
    key = jax.random.PRNGKey(0)
    kx, kg, ku, kd, kq, kk, kh = jax.random.split(key, 7)
    e, d, f = w.n_experts, w.d_model, w.d_ff_expert
    c = rows_per_expert
    mats = 3 if w.gated_ffn else 2

    # -- expert FFN: the gated gmm chain over (E, C, d) buckets ----------
    xs = jax.random.normal(kx, (e, c, d), jnp.float32)
    wg = jax.random.normal(kg, (e, d, f), jnp.float32)
    wu = jax.random.normal(ku, (e, d, f), jnp.float32)
    wd = jax.random.normal(kd, (e, f, d), jnp.float32)

    if w.gated_ffn:
        def ffn(x, g, u, dn):
            return gmm_fn(jax.nn.silu(gmm_fn(x, g)) * gmm_fn(x, u), dn)
        ffn_args = (xs, wg, wu, wd)
    else:
        def ffn(x, u, dn):
            return gmm_fn(jax.nn.silu(gmm_fn(x, u)), dn)
        ffn_args = (xs, wu, wd)
    t_ffn = timed_call(jax.jit(ffn), *ffn_args, iters=iters)
    exp_visit = t_ffn / (e * c)
    exp_flops = 2.0 * mats * d * f          # per visit
    # Per-call bytes: every expert's weights read once (amortized over its
    # c bucket rows, matching the wide-bucket sharded execution) plus the
    # per-row activations; f32 as measured.
    exp_bytes_call = (mats * d * f * e
                      + (2 * d + (mats - 1) * f) * e * c) * 4.0
    exp_bytes_visit = exp_bytes_call / (e * c)

    # -- gateway: flash-decode attention swept over batch sizes ----------
    hkv, g_rep, hd = w.n_kv_heads, w.n_heads // w.n_kv_heads, w.head_dim
    s = ctx_len
    attn_by_batch: dict[str, float] = {}
    attn_energy: dict[str, dict] = {}
    jit_attn = jax.jit(attn_fn)
    for b in batches:
        q = jax.random.normal(kq, (b, hkv, g_rep, hd), jnp.float32)
        kv = jax.random.normal(kk, (b, hkv, s, hd), jnp.float32)
        pos = jnp.full((b,), s - 1, jnp.int32)
        t = timed_call(jit_attn, q, kv, kv, pos, iters=iters)
        attn_by_batch[str(b)] = t
        attn_energy[str(b)] = {
            "flops": 4.0 * b * w.n_heads * hd * s,
            "bytes": float(q.nbytes + 2 * kv.nbytes + q.nbytes),
        }

    # -- head: the unembedding matmul ------------------------------------
    hb = 8
    xh = jax.random.normal(kh, (hb, d), jnp.float32)
    wh = jax.random.normal(kh, (d, w.vocab_size), jnp.float32)
    t_head = timed_call(jax.jit(lambda x, m: x @ m), xh, wh, iters=iters)
    head_tok = t_head / hb

    return {
        "host": host_probe(),
        "measured_s": {
            "expert_visit": float(exp_visit),
            "gateway_by_batch": attn_by_batch,
            "head_token": float(head_tok),
        },
        "kernel_energy": {
            "expert_visit": {"flops": float(exp_flops),
                             "bytes": float(exp_bytes_visit)},
            "gateway_by_batch": attn_energy,
            "head_token": {
                "flops": 2.0 * d * w.vocab_size,
                "bytes": float((d * w.vocab_size + w.vocab_size + d) * 4.0),
            },
        },
        "impl": impl,
        "iters": int(iters),
    }


# --------------------------------------------------------------------- #
# Derivation: measured / roofline crossing -> satellite-unit table
# --------------------------------------------------------------------- #


def _sat_rates(compute: ComputeConfig, sat_bw: float | None) -> dict:
    """Satellite (flops_per_s, bw) the derived times target."""
    f = compute.flops_per_s
    return {"flops_per_s": float(f),
            "bw_bytes_per_s": float(sat_bw if sat_bw is not None
                                    else f * SAT_BYTES_PER_FLOP)}


def _efficiencies(measured: dict) -> dict:
    """Per-component achieved fraction of the host roofline ideal."""
    host = tuple(measured["host"])
    ms, ke = measured["measured_s"], measured["kernel_energy"]

    def eff(flops, nbytes, t):
        ideal = _ideal_host(flops, nbytes, host)
        return float(np.clip(ideal / max(t, 1e-12), MIN_EFFICIENCY, 1.0))

    e_exp = eff(ke["expert_visit"]["flops"], ke["expert_visit"]["bytes"],
                ms["expert_visit"])
    gw = [eff(ke["gateway_by_batch"][b]["flops"],
              ke["gateway_by_batch"][b]["bytes"],
              ms["gateway_by_batch"][b])
          for b in sorted(ms["gateway_by_batch"], key=int)]
    e_head = eff(ke["head_token"]["flops"], ke["head_token"]["bytes"],
                 ms["head_token"])
    return {"expert": e_exp, "gateway": float(np.median(gw)),
            "head": e_head}


def _step_seconds(flops: float, nbytes: float, rates: dict,
                  eff: float) -> float:
    """Roofline max-rule time at ``rates``, degraded by efficiency."""
    ideal = max(flops / rates["flops_per_s"],
                nbytes / rates["bw_bytes_per_s"])
    return ideal / eff


def derive_table(name: str, workload: MoEWorkload, measured: dict,
                 ctx_len: int, batches: tuple[int, ...],
                 compute: ComputeConfig, sat_bw: float | None = None,
                 jax_version: str | None = None,
                 backend: str | None = None) -> ServiceTable:
    """Deterministically derive a :class:`ServiceTable` from measurements.

    Pure given ``measured`` (the :func:`measure_components` output) —
    calling it twice with the same inputs yields the identical table and
    hash, which the determinism test pins.
    """
    import jax

    w = workload
    sat = _sat_rates(compute, sat_bw)
    eff = _efficiencies(measured)

    energy = {
        "gateway": {"flops_per_token": w.gateway_flops(ctx_len),
                    "weight_bytes": w.gateway_weight_bytes,
                    "token_bytes": w.gateway_token_bytes(ctx_len)},
        "expert": {"flops": w.expert_flops, "bytes": w.expert_bytes},
        "head": {"flops": w.lm_head_flops, "bytes": w.lm_head_bytes},
    }
    exp_s = _step_seconds(w.expert_flops, w.expert_bytes, sat,
                          eff["expert"])
    gw_by_batch = {
        str(b): _step_seconds(
            b * w.gateway_flops(ctx_len),
            w.gateway_weight_bytes + b * w.gateway_token_bytes(ctx_len),
            sat, eff["gateway"])
        for b in batches
    }
    head_s = _step_seconds(w.lm_head_flops, w.lm_head_bytes, sat,
                           eff["head"])

    table = ServiceTable(
        version=TABLE_VERSION,
        name=name,
        jax_version=jax_version if jax_version is not None else jax.__version__,
        backend=backend if backend is not None else jax.default_backend(),
        impl=measured.get("impl", "ref"),
        ctx_len=int(ctx_len),
        batches=tuple(int(b) for b in batches),
        workload=dataclasses.asdict(w),
        host={"flops_per_s": float(measured["host"][0]),
              "bw_bytes_per_s": float(measured["host"][1])},
        sat=sat,
        energy=energy,
        measured_s=measured["measured_s"],
        efficiency=eff,
        derived={"expert_s": [float(exp_s)] * w.n_experts,
                 "gateway_s_by_batch": gw_by_batch,
                 "head_s": float(head_s)},
        meta={"iters": measured.get("iters", 0),
              "kernel_energy": measured["kernel_energy"]},
    )
    return dataclasses.replace(table, table_hash=table.compute_hash())


def calibrate(name: str, workload: MoEWorkload, ctx_len: int = 1024,
              batches: tuple[int, ...] = DEFAULT_BATCHES,
              compute: ComputeConfig | None = None,
              sat_bw: float | None = None, impl: str | None = None,
              iters: int = 3, measured: dict | None = None) -> ServiceTable:
    """Measure the real kernels and derive a calibrated service table.

    ``measured`` may be injected (the :func:`measure_components` output)
    to skip re-timing — the path CI and the determinism tests use.
    """
    import jax

    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if compute is None:
        compute = ComputeConfig()
    if measured is None:
        measured = measure_components(workload, ctx_len, batches, impl,
                                      iters=iters)
    return derive_table(name, workload, measured, ctx_len, batches,
                        compute, sat_bw=sat_bw)


def verify_table(table: ServiceTable,
                 compute: ComputeConfig | None = None) -> bool:
    """Re-derive the table from its own stored measurements and compare.

    True iff the derivation is reproducible (the roofline-determinism
    check): identical efficiency and derived service times, matching
    content hash.  A satellite-rate mismatch (different ``compute``) also
    returns False.
    """
    if compute is None:
        compute = ComputeConfig()
    measured = {
        "host": (table.host["flops_per_s"], table.host["bw_bytes_per_s"]),
        "measured_s": table.measured_s,
        "kernel_energy": table.meta.get("kernel_energy", {}),
        "impl": table.impl,
        "iters": table.meta.get("iters", 0),
    }
    if not measured["kernel_energy"]:
        return False
    redo = derive_table(table.name, table.workload_obj(), measured,
                        table.ctx_len, table.batches, compute,
                        sat_bw=table.sat["bw_bytes_per_s"],
                        jax_version=table.jax_version,
                        backend=table.backend)
    same_eff = all(np.isclose(redo.efficiency[k], table.efficiency[k],
                              rtol=1e-12) for k in table.efficiency)
    same_exp = np.allclose(redo.derived["expert_s"],
                           table.derived["expert_s"], rtol=1e-12)
    same_gw = all(np.isclose(redo.derived["gateway_s_by_batch"][b],
                             table.derived["gateway_s_by_batch"][b],
                             rtol=1e-12)
                  for b in table.derived["gateway_s_by_batch"])
    same_head = np.isclose(redo.derived["head_s"], table.derived["head_s"],
                           rtol=1e-12)
    return bool(same_eff and same_exp and same_gw and same_head
                and redo.compute_hash() == table.compute_hash())


# --------------------------------------------------------------------- #
# Persistence + provenance
# --------------------------------------------------------------------- #


def table_path(name: str, table_dir: Path | str | None = None) -> Path:
    """On-disk location of a named table."""
    base = Path(table_dir) if table_dir is not None else TABLE_DIR
    return base / f"{name}.json"


def save_table(table: ServiceTable,
               table_dir: Path | str | None = None) -> Path:
    """Write a table (canonical JSON, hash included) and return its path."""
    path = table_path(table.name, table_dir)
    path.parent.mkdir(parents=True, exist_ok=True)
    d = table.to_dict()
    path.write_text(json.dumps(d, sort_keys=True, indent=1) + "\n")
    return path


def load_table(name: str,
               table_dir: Path | str | None = None) -> ServiceTable:
    """Load a committed table by name, registering it for provenance."""
    path = table_path(name, table_dir)
    if not path.exists():
        raise FileNotFoundError(
            f"no calibration table {name!r} at {path} — generate one with "
            "benchmarks/bench_calibration.py --refresh")
    table = ServiceTable.from_dict(json.loads(path.read_text()))
    _LOADED_TABLES[table.name] = table.table_hash or table.compute_hash()
    return table


def list_tables(table_dir: Path | str | None = None) -> list[str]:
    """Names of every committed table."""
    base = Path(table_dir) if table_dir is not None else TABLE_DIR
    if not base.exists():
        return []
    return sorted(p.stem for p in base.glob("*.json"))


def provenance() -> dict:
    """Resolved service-model provenance for BENCH JSON artifacts.

    Covers the jax version/backend the process runs and the content hash
    of every calibration table loaded so far, so CI bench diffs compare
    like with like.
    """
    import jax

    return {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "table_version": TABLE_VERSION,
        "tables": dict(_LOADED_TABLES),
    }


def attach_dryrun(table: ServiceTable, record: dict) -> ServiceTable:
    """Fold a ``launch.dryrun`` cell record into the table's metadata.

    Stores the compiled cell's roofline terms (per-chip FLOPs/bytes and
    the bound time) as a cross-check of the analytic energy accounting;
    the content hash is recomputed.  Returns the updated table.
    """
    roof = record.get("roofline", {})
    meta = dict(table.meta)
    meta["dryrun"] = {
        "cell": f"{record.get('arch')}__{record.get('shape')}"
                f"__{record.get('mesh')}",
        "flops_per_chip": roof.get("flops_per_chip"),
        "bytes_per_chip": roof.get("bytes_per_chip"),
        "compute_s": roof.get("compute_s"),
        "memory_s": roof.get("memory_s"),
        "bound_time_s": max(roof.get("compute_s", 0.0) or 0.0,
                            roof.get("memory_s", 0.0) or 0.0),
    }
    out = dataclasses.replace(table, meta=meta)
    return dataclasses.replace(out, table_hash=out.compute_hash())


# --------------------------------------------------------------------- #
# ServiceModel: the facade the engine and FleetSim consume
# --------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class ServiceModel:
    """Resolved service-time source for one engine / fleet pass.

    Mode ``"analytic"`` computes exactly the pre-calibration constants
    (``compute.latency_s`` of the workload FLOPs — bit-identical to the
    legacy path, as the parity tests pin).  Mode ``"calibrated"`` reads a
    :class:`ServiceTable`: per-expert service seconds, per-satellite
    speed multipliers, batch-size-dependent decode rates.

    Attributes:
        workload: FLOP/byte model of the served MoE.
        compute: Satellite FLOPs->seconds conversion (analytic + the
            satellite-unit roofline rates).
        mode: ``"analytic"`` or ``"calibrated"``.
        table: Calibrated table (required in calibrated mode).
        units: ``"satellite"`` projects the table to onboard-compute
            rates; ``"host"`` keeps the measured host's rates (the
            validation harness compares those against real step times).
        sat_speed: Optional per-satellite relative speed multipliers
            (1.0 = nominal); service on satellite v scales by
            ``1 / sat_speed[v]``.
    """

    workload: MoEWorkload
    compute: ComputeConfig
    mode: str = "analytic"
    table: ServiceTable | None = None
    units: str = "satellite"
    sat_speed: tuple | None = None

    @classmethod
    def analytic(cls, workload: MoEWorkload,
                 compute: ComputeConfig) -> "ServiceModel":
        """The bit-parity analytic constants."""
        return cls(workload=workload, compute=compute, mode="analytic")

    @classmethod
    def calibrated(cls, workload: MoEWorkload, compute: ComputeConfig,
                   table: ServiceTable, units: str = "satellite",
                   sat_speed=None) -> "ServiceModel":
        """Kernel-calibrated service times from a :class:`ServiceTable`."""
        if units not in ("satellite", "host"):
            raise ValueError(f"units must be 'satellite' or 'host', "
                             f"got {units!r}")
        if table.workload.get("n_experts") != workload.n_experts:
            raise ValueError(
                f"table {table.name!r} was calibrated for "
                f"{table.workload.get('n_experts')} experts, workload has "
                f"{workload.n_experts}")
        speed = None if sat_speed is None else tuple(float(s)
                                                     for s in sat_speed)
        return cls(workload=workload, compute=compute, mode="calibrated",
                   table=table, units=units, sat_speed=speed)

    def __post_init__(self):
        if self.mode not in ("analytic", "calibrated"):
            raise ValueError(f"unknown service model mode {self.mode!r}")
        if self.mode == "calibrated" and self.table is None:
            raise ValueError("calibrated mode needs a ServiceTable")

    # -- mode predicates -------------------------------------------------
    @property
    def per_satellite(self) -> bool:
        """True when service is per-expert / per-satellite (calibrated)."""
        return self.mode == "calibrated"

    # -- internal rates --------------------------------------------------
    def _rates(self) -> dict:
        if self.units == "host":
            return {"flops_per_s": self.table.host["flops_per_s"],
                    "bw_bytes_per_s": self.table.host["bw_bytes_per_s"]}
        return self.table.sat

    # -- gateway ---------------------------------------------------------
    def gateway_step_s(self, ctx_len: int, batch=1):
        """Gateway step seconds for a decode batch (scalar or array).

        Calibrated satellite units: the decode-attention roofline with
        weight reads amortized over the batch, degraded by the measured
        gateway efficiency.  Host units: the measured kernel timing
        itself where the (ctx, batch) point was swept, the host roofline
        / efficiency otherwise.  Analytic:
        ``batch * latency_s(gateway_flops)``.
        """
        b = np.asarray(batch, dtype=np.float64)
        if self.mode == "analytic":
            return b * self.compute.latency_s(
                self.workload.gateway_flops(ctx_len))
        if self.units == "host":
            out = np.vectorize(
                lambda x: self._host_gateway_step(ctx_len, float(x)))(b)
            return float(out) if np.ndim(batch) == 0 else out
        r, eff = self._rates(), self.table.efficiency["gateway"]
        w = self.workload
        ideal = np.maximum(
            b * w.gateway_flops(ctx_len) / r["flops_per_s"],
            (w.gateway_weight_bytes + b * w.gateway_token_bytes(ctx_len))
            / r["bw_bytes_per_s"])
        return ideal / eff

    def _host_gateway_step(self, ctx_len: int, b: float) -> float:
        """Measured gateway step on the calibration host (exact lookup
        at swept points, roofline/efficiency fallback elsewhere)."""
        ms = self.table.measured_s["gateway_by_batch"]
        if ctx_len == self.table.ctx_len and b == int(b) \
                and str(int(b)) in ms:
            return float(ms[str(int(b))])
        r, eff = self._rates(), self.table.efficiency["gateway"]
        w = self.workload
        ideal = max(b * w.gateway_flops(ctx_len) / r["flops_per_s"],
                    (w.gateway_weight_bytes
                     + b * w.gateway_token_bytes(ctx_len))
                    / r["bw_bytes_per_s"])
        return ideal / eff

    def gateway_s(self, ctx_len: int, batch=1):
        """Per-token amortized gateway service seconds.

        At ``batch=1`` (and analytic mode always) this is the scalar the
        engine adds per layer; larger batches amortize the weight reads.
        """
        if self.mode == "analytic":
            return self.compute.latency_s(self.workload.gateway_flops(ctx_len))
        b = np.asarray(batch, dtype=np.float64)
        out = self.gateway_step_s(ctx_len, batch) / np.maximum(b, 1.0)
        return float(out) if np.ndim(batch) == 0 else out

    def decode_rate(self, batch, ctx_len: int | None = None):
        """Decode tokens/second at a given batch size (monotone in B)."""
        ctx = ctx_len if ctx_len is not None else (
            self.table.ctx_len if self.table is not None else 1024)
        b = np.asarray(batch, dtype=np.float64)
        return b / self.gateway_step_s(ctx, batch)

    def batch_speedup(self, b_max: int, ctx_len: int = 1024) -> np.ndarray:
        """(b_max,) relative per-token decode speedup at batch 1..b_max.

        ``speedup[b-1] = decode_rate(b) / decode_rate(1)``, clamped
        monotone non-decreasing with ``speedup[0] = 1`` exactly — the
        table the continuous-batching queue law interpolates (see
        :mod:`repro.traffic.batching`).  Calibrated mode reads the
        measured decode-attention roofline; analytic mode (whose
        ``decode_rate`` is deliberately flat — the bit-parity constants
        bill ``batch * latency_s``) projects the same roofline shape at
        the satellite-unit byte/FLOP balance (``SAT_BYTES_PER_FLOP``):
        weight reads amortize over the batch until the compute term
        takes over.
        """
        b = np.arange(1, int(b_max) + 1, dtype=np.float64)
        if self.mode == "calibrated":
            rate = np.asarray(self.decode_rate(b, ctx_len),
                              dtype=np.float64)
        else:
            w, f = self.workload, self.compute.flops_per_s
            bw = f * SAT_BYTES_PER_FLOP
            step = np.maximum(
                b * w.gateway_flops(ctx_len) / f,
                (w.gateway_weight_bytes + b * w.gateway_token_bytes(ctx_len))
                / bw)
            rate = b / step
        s = np.maximum.accumulate(np.maximum(rate / rate[0], 1.0))
        s[0] = 1.0
        return s

    # -- experts ---------------------------------------------------------
    def expert_s(self) -> np.ndarray:
        """(n_experts,) per-expert service seconds at nominal speed.

        Host units return the measured per-visit kernel time directly —
        the number the validation harness must predict real step times
        with; satellite units return the roofline-projected table.
        """
        i = self.workload.n_experts
        if self.mode == "analytic":
            return np.full(i, self.expert_scalar, dtype=np.float64)
        if self.units == "host":
            return np.full(i, float(self.table.measured_s["expert_visit"]),
                           dtype=np.float64)
        return np.asarray(self.table.derived["expert_s"], dtype=np.float64)

    @property
    def expert_scalar(self) -> float:
        """Scalar expert service: exact analytic value, or the table mean."""
        if self.mode == "analytic":
            return self.compute.latency_s(self.workload.expert_flops)
        return float(np.mean(self.expert_s()))

    # -- head ------------------------------------------------------------
    @property
    def head_s(self) -> float:
        """LM-head service seconds per token."""
        if self.mode == "analytic":
            return self.compute.latency_s(self.workload.lm_head_flops)
        if self.units == "host":
            return float(self.table.measured_s["head_token"])
        return float(self.table.derived["head_s"])

    # -- satellite heterogeneity -----------------------------------------
    def inv_speed(self, n_sats: int) -> np.ndarray:
        """(n_sats,) per-satellite service multipliers (1 / speed)."""
        if self.sat_speed is None:
            return np.ones(n_sats, dtype=np.float64)
        speed = np.asarray(self.sat_speed, dtype=np.float64)
        if speed.shape != (n_sats,):
            raise ValueError(
                f"sat_speed has {speed.shape[0]} entries for {n_sats} "
                "satellites")
        if np.any(speed <= 0):
            raise ValueError("sat_speed entries must be positive")
        return 1.0 / speed

    # -- energy proxies ---------------------------------------------------
    def energy_per_token(self, ctx_len: int) -> dict:
        """Per-token FLOP/byte energy proxies (gateway + K experts + head)."""
        w = self.workload
        flops = (w.gateway_flops(ctx_len) + w.top_k * w.expert_flops
                 + w.lm_head_flops)
        nbytes = (w.gateway_bytes(ctx_len) + w.top_k * w.expert_bytes
                  + w.lm_head_bytes)
        return {"flops": float(flops), "bytes": float(nbytes)}

    # -- provenance -------------------------------------------------------
    def describe(self) -> dict:
        """Resolved provenance of this model (mode, table hash, units)."""
        d = {"mode": self.mode, "units": self.units}
        if self.table is not None:
            d["table"] = self.table.name
            d["table_hash"] = (self.table.table_hash
                               or self.table.compute_hash())
            d["impl"] = self.table.impl
        return d


def resolve_service_model(service_model, workload: MoEWorkload,
                          compute: ComputeConfig) -> ServiceModel:
    """Normalize the ``service_model=`` argument of the public sweeps.

    ``None`` and ``"analytic"`` resolve to the bit-parity analytic model;
    a :class:`ServiceModel` passes through.  The string ``"calibrated"``
    is rejected with a pointer — a table must be named explicitly.
    """
    if service_model is None or service_model == "analytic":
        return ServiceModel.analytic(workload, compute)
    if isinstance(service_model, ServiceModel):
        return service_model
    if service_model == "calibrated":
        raise ValueError(
            "pass a ServiceModel instance for calibrated mode, e.g. "
            "ServiceModel.calibrated(workload, compute, "
            "load_table('llama-moe-3.5b'))")
    raise TypeError(f"service_model must be None, 'analytic' or a "
                    f"ServiceModel, got {type(service_model).__name__}")
