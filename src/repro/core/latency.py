"""Token communication + computation latency models (paper Sec. II-C, Eq. 16).

Per-hop latency  T_hat = T_pr + T_tx              (Eq. 4-6)
Multi-hop        D_{u,v}(n) = Dijkstra shortest path over G(n)   (Eq. 7)
Computation      T_cmp = W_cmp / f                (Eq. 16)

The per-slot topology realizations are packed into a ``TopologySample``
(edge masks + per-edge latencies) from which distance rows are computed
lazily with scipy's Dijkstra.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp
from scipy.sparse.csgraph import dijkstra

from .constellation import SPEED_OF_LIGHT, Constellation

UNREACHABLE = np.inf


@dataclasses.dataclass(frozen=True)
class LinkConfig:
    """Token transmission parameters (Eq. 6)."""

    token_dim: int = 4096          # M, token-embedding dimension
    bits_per_value: int = 16       # Q_B quantization
    isl_rate_gbps: float = 100.0   # R_{u,v}

    @property
    def tx_latency_s(self) -> float:
        return (self.token_dim * self.bits_per_value) / (self.isl_rate_gbps * 1e9)


@dataclasses.dataclass(frozen=True)
class ComputeConfig:
    """Satellite onboard compute (paper Sec. VII-A: Frontgrade SBC-2A72)."""

    peak_gflops: float = 10.4
    utilization: float = 0.7

    @property
    def flops_per_s(self) -> float:
        return self.peak_gflops * 1e9 * self.utilization  # 7.28 GFLOPS default

    def latency_s(self, work_flops: float) -> float:
        """T_cmp = W_cmp / f  (Eq. 16)."""
        return work_flops / self.flops_per_s


@dataclasses.dataclass
class TopologySample:
    """A realization of the time-varying graph sequence {G(n)}.

    Attributes
    ----------
    edges:        (E, 2) static candidate edge list.
    edge_mask:    (N_T, E) bool — E_{u,v}(n) per slot.
    edge_latency: (N_T, E) float seconds — per-hop T_hat (Eq. 4) per slot.
    n_sats:       number of graph nodes.
    """

    edges: np.ndarray
    edge_mask: np.ndarray
    edge_latency: np.ndarray
    n_sats: int

    @property
    def n_slots(self) -> int:
        return self.edge_mask.shape[0]

    def availability(self) -> float:
        """Fraction of (slot, edge) pairs that are up."""
        return float(self.edge_mask.mean())

    def graph(self, slot: int) -> sp.csr_matrix:
        """Symmetric weighted adjacency for slot n (weights = latency)."""
        m = self.edge_mask[slot]
        e = self.edges[m]
        w = self.edge_latency[slot][m]
        g = sp.coo_matrix(
            (np.concatenate([w, w]),
             (np.concatenate([e[:, 0], e[:, 1]]),
              np.concatenate([e[:, 1], e[:, 0]]))),
            shape=(self.n_sats, self.n_sats),
        )
        return g.tocsr()

    def distances_from(self, slot: int, sources: np.ndarray,
                       node_mask: np.ndarray | None = None) -> np.ndarray:
        """Shortest-path latency rows D_{src, .}(n) (Eq. 7), shape (S, V).

        ``node_mask`` (V,) bool restricts routing to a node subset (used to
        emulate intra-subnet-only routing; see EXPERIMENTS.md §Fidelity).
        """
        g = self.graph(slot)
        if node_mask is not None:
            keep = np.asarray(node_mask)
            diag = sp.diags(keep.astype(np.float64))
            g = (diag @ g @ diag).tocsr()
            g.eliminate_zeros()
        return dijkstra(g, directed=False, indices=np.asarray(sources))


def sample_topology(
    constellation: Constellation,
    link: LinkConfig,
    rng: np.random.Generator,
    slots: np.ndarray | None = None,
) -> TopologySample:
    """Draw one realization of {G(n)}_{n=1..N_T} with per-edge latencies."""
    cfg = constellation.cfg
    times = constellation.cfg.slot_times() if slots is None else slots
    n_slots = len(times)
    edges = constellation.edges
    masks = np.zeros((n_slots, edges.shape[0]), dtype=bool)
    lats = np.zeros((n_slots, edges.shape[0]), dtype=np.float64)
    for n, t in enumerate(times):
        masks[n] = constellation.sample_edge_mask(float(t), rng)
        # T_pr (Eq. 5) + T_tx (Eq. 6)
        lats[n] = constellation.edge_distances(float(t)) / SPEED_OF_LIGHT + link.tx_latency_s
    return TopologySample(edges=edges, edge_mask=masks, edge_latency=lats, n_sats=cfg.n_sats)


def node_masks_from_sets(node_sets: list, n_sats: int) -> list[np.ndarray]:
    """Per-layer node-index lists -> (V,) bool routing masks."""
    masks = []
    for nodes in node_sets:
        m = np.zeros(n_sats, dtype=bool)
        m[np.asarray(nodes)] = True
        masks.append(m)
    return masks


def source_distance_table(
    topo: TopologySample,
    sources: np.ndarray,
    node_masks: list | None = None,
) -> np.ndarray:
    """D[n, s, v]: shortest-path latency from arbitrary source nodes.

    Shape (N_T, S, V).  This is the host-side precompute feeding the
    batched plan-evaluation engine (:mod:`repro.core.engine`): the engine
    dedupes gateway nodes across a whole plan sweep into one ``sources``
    vector, so Dijkstra runs once per (slot, unique gateway) instead of
    once per (slot, plan, layer).

    ``node_masks`` (optional, one (V,) bool mask or None per source)
    restricts routing per source row; sources sharing a mask are batched
    into a single Dijkstra call per slot.
    """
    sources = np.asarray(sources)
    out = np.empty((topo.n_slots, len(sources), topo.n_sats), dtype=np.float64)
    if node_masks is None:
        for n in range(topo.n_slots):
            out[n] = topo.distances_from(n, sources)
        return out
    # Group source rows by identical mask so each (slot, mask) pair costs
    # one batched Dijkstra.
    groups: dict[bytes, list[int]] = {}
    for si, mask in enumerate(node_masks):
        key = b"" if mask is None else np.asarray(mask, dtype=bool).tobytes()
        groups.setdefault(key, []).append(si)
    for rows in groups.values():
        mask = node_masks[rows[0]]
        for n in range(topo.n_slots):
            out[n, rows] = topo.distances_from(n, sources[rows], mask)
    return out


def gateway_distance_table(
    topo: TopologySample, gateways: np.ndarray,
    node_sets: list | None = None,
) -> np.ndarray:
    """D[n, g, v]: shortest-path latency from each gateway to every node.

    Shape (N_T, L, V).  Unreachable pairs are +inf (handled downstream with
    masked means).  The graph is undirected so D(g, v) = D(v, g) and this
    single table serves both the dispatch (gateway->expert) and combine
    (expert->next gateway) hops of Eq. 22.

    ``node_sets`` (one node-index array per layer) restricts layer l's
    routing to those nodes — the paper-style intra-subnet-only mode.
    """
    gateways = np.asarray(gateways)
    if node_sets is None:
        return source_distance_table(topo, gateways)
    masks = node_masks_from_sets(node_sets, topo.n_sats)
    return source_distance_table(topo, gateways, masks)


def expected_path_latency(
    dist_table: np.ndarray,
    layer: int,
    n_layers: int,
    compute_latency_s: np.ndarray | float = 0.0,
) -> np.ndarray:
    """tau_bar_s per candidate satellite for one layer (Eq. 21 + Eq. 27).

    tau_s^(n) = T_cmp + D(phi_l, s; n) + D(s, phi_{l+1}; n), with the ring
    wrap-around for the last layer (Eq. 22); expectation over slots uses a
    masked mean so slots in which s is unreachable do not poison the
    average (rare at survival=0.95).  Satellites unreachable in *every*
    slot get +inf.
    """
    nxt = (layer + 1) % n_layers
    path = dist_table[:, layer, :] + dist_table[:, nxt, :]      # (N_T, V)
    finite = np.isfinite(path)
    cnt = finite.sum(axis=0)
    s = np.where(finite, path, 0.0).sum(axis=0)
    with np.errstate(invalid="ignore"):
        mean = np.where(cnt > 0, s / np.maximum(cnt, 1), UNREACHABLE)
    return mean + compute_latency_s
