"""Discrete-time per-satellite service model for request-level serving.

Every satellite of the constellation is a FIFO work queue (stations are
keyed by satellite id, S = V): a token deposits on the L gateway
satellites (attention + gating + lm-head service) and the per-layer
expert satellites (FFN service) of *the plan its topology slot selects*
— plans are time-indexed :class:`~repro.core.schedule.PlanSchedule`
entries, plain plans riding as constant schedules.  Colocated experts
share their satellite's queue (the queue-theoretic face of the Eq. 43
contention term), and a plan switch at a slot boundary redirects new
deposits while the old plan's backlog drains in place, with the moved
expert weights occupying destination queues as background load.  The
simulator is deliberately split into

1. a **base schedule** — per-token zero-load trajectories straight from
   the batched plan-evaluation engine (``core.engine.evaluate_plans``
   with wall-clock-derived slots and shared expert draws), so at zero
   load the traffic subsystem reproduces the engine exactly;
2. a **fleet queue kernel** — one ``lax.scan`` over time bins with the
   (plans, stations) backlog matrix as carry, vectorized over every
   plan of the sweep.  Backlogs are capped (finite buffers: overflow =
   backpressure drop) and each arrival's waiting time is the backlog it
   finds (exact for Poisson arrivals by PASTA, up to the O(dt) binning
   error the M/D/1 test bounds against Pollaczek-Khinchine);
3. a **closed-loop fixed point** — waits delay a token's delivery, and
   delivery times gate the autoregressive chain, so the schedule and
   the queue state are mutually dependent.  ``run`` iterates
   schedule -> bin -> scan -> gather a configurable number of times
   (``QueueConfig.iterations``): iteration 1 is the open-loop
   approximation, further iterations let congested tokens arrive
   *after* the backlog they caused has drained, which removes the
   open-loop bias of billing one backlog episode to every token of a
   request.  Deposits larger than one bin of service are spread over
   consecutive bins (chunked-prefill semantics, like production
   continuous-batching schedulers).

Two admission regimes guard KV-cache memory and the latency SLO:

* the legacy **static cap** — a request arriving when more than
  ``kv_slots`` requests are in flight is rejected (its offered load
  still occupies the queues: rejection happens at the ingress gateway
  *after* the uplink, the conservative accounting);
* the **latency-target controller** (``QueueConfig.admission`` with
  policy ``"aimd"``, see :mod:`repro.traffic.admission`) — an AIMD loop
  carried through the fleet scan observes the windowed critical-path
  backlog and sheds load *before* the target is crossed.  Rejections
  happen at the ground gateway before the uplink (shed load never
  enters the queues), and rejected requests retry at the next-best
  visible gateway with the retry latency accounted in TTFT/E2E.

``FleetSim`` precomputes everything rate-independent once (engine pass,
station indices, chunk layout) so a saturation sweep replays only the
binning + scan + gather per tested rate — no Python loop over requests
or tokens anywhere on the hot path.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ScheduleBatch, evaluate_schedules,
                        schedule_ingress_offsets)
from repro.core.activation import ActivationModel
from repro.core.latency import ComputeConfig, TopologySample
from repro.core.schedule import as_schedule, slot_of_time
from repro.core.workload import MoEWorkload

from .admission import (AdmissionConfig, admission_queue_scan,
                        control_bin_flags, resolve_admission)
from .ground import GroundSegment
from .metrics import PlanTraffic, TrafficResult
from .requests import RequestBatch


@dataclasses.dataclass(frozen=True)
class QueueConfig:
    """Discrete-time queueing parameters.

    Attributes:
        dt_s: Time-bin width.  Per-visit service times below dt never
            self-queue; the binning error is O(dt).
        buffer_s: Per-station backlog cap in seconds of work; arrivals
            overflowing it are dropped (backpressure).
        kv_slots: Max requests concurrently holding KV cache (0 = no
            admission cap).  Ignored when the adaptive controller is
            active — the controller *replaces* the static cap.
        slot_period_s: Wall-clock seconds per topology slot (ties tokens
            to the constellation's time-varying graph; default is a
            550 km LEO period split over 20 slots).
        tail_s: Extra horizon past the last zero-load completion so
            in-flight requests can drain.  Congestion-stretched
            schedules beyond it clip into the final bin (such runs are
            deep in SLO failure anyway).
        iterations: Schedule<->queue fixed-point iterations (1 = open
            loop).
        admission: Optional :class:`~repro.traffic.admission
            .AdmissionConfig`; policy ``"aimd"`` switches the run loop
            to the latency-target controller with gateway retry.
        migration_bytes_per_expert: Weight bytes one expert drags to a
            new satellite when a :class:`~repro.core.schedule
            .PlanSchedule` switches plans at a slot boundary.
        migration_rate_gbps: ISL share available to weight migration;
            each moved expert occupies its destination satellite's queue
            for ``bytes * 8 / rate`` seconds of background load.
    """

    dt_s: float = 0.05
    buffer_s: float = 10.0
    kv_slots: int = 0
    slot_period_s: float = 300.0
    tail_s: float = 120.0
    iterations: int = 3
    admission: AdmissionConfig | None = None
    migration_bytes_per_expert: float = 1e6
    migration_rate_gbps: float = 10.0


# --------------------------------------------------------------------- #
# The fleet queue kernel
# --------------------------------------------------------------------- #


@jax.jit
def _fleet_queue_scan(work, cap, dt):
    """Scan the (P, S) backlog matrix over T time bins.

    work: (P, S, T) seconds of work arriving per bin.
    cap:  scalar or (S,) backlog cap in seconds.
    Returns (wait, dropped), both (P, S, T): ``wait[..., t]`` is the
    backlog an arrival in bin t finds (work deposited in bin t is seen
    by later bins only); ``dropped`` is the overflow discarded per bin.
    """
    def _step(backlog, w_t):
        wait = backlog
        total = backlog + w_t
        dropped = jnp.maximum(total - cap, 0.0)
        backlog = jnp.maximum(jnp.minimum(total, cap) - dt, 0.0)
        return backlog, (wait, dropped)

    p, s, _ = work.shape
    backlog0 = jnp.zeros((p, s), dtype=work.dtype)
    _, (wait, dropped) = jax.lax.scan(_step, backlog0,
                                      jnp.moveaxis(work, 2, 0))
    return jnp.moveaxis(wait, 0, 2), jnp.moveaxis(dropped, 0, 2)


def station_waiting_times(
    arrival_s: np.ndarray,
    service_s: np.ndarray | float,
    dt_s: float,
    buffer_s: float = np.inf,
    horizon_s: float | None = None,
) -> np.ndarray:
    """Per-arrival waiting times at one FIFO station via the fleet kernel.

    Runs the same discrete-time scan the fleet simulator uses (P=1, S=1)
    and refines the bin-resolution backlog with the exact within-bin
    Lindley correction: an arrival at offset ``delta`` into bin b waits

        max(0, backlog_at_bin_start + work_of_earlier_same_bin_arrivals
               - delta),

    since the server drains continuously through the bin.  This is the
    single-station reference the M/D/1 Pollaczek-Khinchine test checks.

    Args:
        arrival_s: (n,) sorted arrival times, seconds.
        service_s: Scalar or (n,) per-arrival service demand, seconds.
        dt_s: Time-bin width of the underlying scan.
        buffer_s: Backlog cap (overflow is dropped), default unbounded.
        horizon_s: Optional simulation horizon (defaults to the last
            arrival).

    Returns:
        (n,) waiting time each arrival experiences before service.
    """
    t = np.asarray(arrival_s, dtype=np.float64)
    if len(t) and not (np.diff(t) >= 0).all():
        raise ValueError("arrivals must be sorted")
    s = np.broadcast_to(np.asarray(service_s, dtype=np.float64), t.shape)
    horizon = (float(t[-1]) if len(t) else 0.0) \
        if horizon_s is None else horizon_s
    n_bins = int(np.floor(horizon / dt_s)) + 2
    bins = np.minimum((t / dt_s).astype(np.int64), n_bins - 1)

    work = np.bincount(bins, weights=s, minlength=n_bins)[None, None, :]
    wait_bins = np.asarray(
        _fleet_queue_scan(jnp.asarray(work), jnp.asarray(buffer_s), dt_s)[0]
    )[0, 0]

    # Within-bin FIFO: prior work of same-bin arrivals, minus the time
    # already elapsed inside the bin.
    cs = np.cumsum(s)
    first = np.searchsorted(bins, bins, side="left")
    prior = (cs - s) - (cs[first] - s[first])
    delta = t - bins * dt_s
    return np.maximum(wait_bins[bins] + prior - delta, 0.0)


# --------------------------------------------------------------------- #
# Helpers
# --------------------------------------------------------------------- #


def _exclusive_cumsum(a: np.ndarray, axis: int) -> np.ndarray:
    out = np.cumsum(a, axis=axis)
    return out - a


def _segment_any(flags: np.ndarray, seg_ids: np.ndarray,
                 n_seg: int) -> np.ndarray:
    """OR-reduce boolean ``flags`` (P, E) over segments of the last axis."""
    p, _ = flags.shape
    idx = np.arange(p)[:, None] * n_seg + seg_ids[None, :]
    hits = np.bincount(idx.ravel(), weights=flags.ravel().astype(np.float64),
                       minlength=p * n_seg)
    return hits.reshape(p, n_seg) > 0.0


def _station_quantile(values: np.ndarray, ok: np.ndarray,
                      station: np.ndarray, n_stations: int,
                      q: float) -> np.ndarray:
    """(P, G) per-(plan, station) q-quantile of ``values`` (P, R) over
    the requests with ``ok`` set; stations with no valid request fall
    back to the plan-wide quantile (0 when nothing is valid at all)."""
    p = values.shape[0]
    out = np.zeros((p, n_stations))
    overall = np.array([
        np.quantile(values[i][ok[i]], q) if ok[i].any() else 0.0
        for i in range(p)])
    for g in range(n_stations):
        sel = ok & (station[None, :] == g)
        for i in range(p):
            out[i, g] = np.quantile(values[i][sel[i]], q) if sel[i].any() \
                else overall[i]
    return out


# --------------------------------------------------------------------- #
# The fleet simulator
# --------------------------------------------------------------------- #


class FleetSim:
    """Request-level serving simulator for a sweep of placement plans
    *or* time-indexed :class:`~repro.core.schedule.PlanSchedule` entries
    (plain plans are wrapped into constant schedules, which reproduce
    the PR-2 static behavior bit-for-bit).

    Queue stations are keyed by **satellite id** — one FIFO work queue
    per satellite of the constellation (S = V).  Colocated experts share
    their satellite's queue by construction (the queue-theoretic face of
    Eq. 43), and a schedule that switches plans at a topology-slot
    boundary points new deposits at the incoming plan's satellites while
    the outgoing plan's backlog drains where it sits — the mechanism
    that makes live re-placement pay.  The weight bytes a switch moves
    (:meth:`~repro.core.schedule.PlanSchedule.migration_edges`, the
    ``distributed.elastic`` accounting) occupy each moved expert's
    destination-satellite queue as background load.

    Construction does all the rate-independent precompute: one batched
    engine pass over R prefill macro-tokens + N decode tokens (shared
    slots/draws across plans — common random numbers), the zero-load
    per-layer costs, every queue event's (plan, station, request, work)
    and the chunk layout.  ``run`` then iterates the schedule/queue
    fixed point for any request-activity mask — the cheap inner call of
    a saturation sweep.

    When ``qcfg.admission`` enables the AIMD policy, construction also
    precomputes the gateway-retry attempt tables (per attempt: target
    gateway, terrestrial forward + backoff + uplink + ingress-offset
    latency, feasibility) and the controller's zero-load TTFT/TPOT
    references; ``run`` then resolves per-request admission between
    fixed-point iterations from the controller trace the fleet scan
    emits (see :mod:`repro.traffic.admission` for the law).
    """

    def __init__(
        self,
        plans: list,
        topo: TopologySample,
        activation: ActivationModel,
        workload: MoEWorkload,
        compute: ComputeConfig,
        requests: RequestBatch,
        rng: np.random.Generator,
        qcfg: QueueConfig = QueueConfig(),
        ground: GroundSegment | None = None,
        ctx_len: int = 1024,
        eta: float = 1.0,
        include_lm_head: bool = True,
        batch: ScheduleBatch | None = None,
    ):
        """Build the simulator and run every rate-independent precompute.

        Args:
            plans: Sweep entries (P of them): plain
                :class:`~repro.core.placement.PlacementPlan` /
                :class:`~repro.core.placement.MultiExpertPlan` (held for
                the whole horizon) and/or time-indexed
                :class:`~repro.core.schedule.PlanSchedule` rows, mixed
                freely.
            topo: Sampled time-varying topology the engine pass uses.
            activation: Conditional-Poisson expert-activation model.
            workload: Per-component FLOP model of the served MoE.
            compute: FLOPs -> seconds conversion for onboard compute.
            requests: The request trace (R requests, sorted arrivals).
            rng: Source of the engine's expert draws and the admission
                uniforms (consumed at construction; runs are replayable).
            qcfg: Queueing/admission parameters.
            ground: Optional ground segment; enables uplink + ingress
                accounting and (under AIMD admission) gateway retry.
            ctx_len: Attention context length for gateway service time.
            eta: Eq. 43 compute-sharing efficiency for multi-expert plans.
            include_lm_head: Account lm-head service on the last gateway.
            batch: Optional prebuilt :class:`~repro.core.ScheduleBatch`
                to reuse the deduped Dijkstra table across simulators.
        """
        self.plans = list(plans)
        self.schedules = [as_schedule(p, topo.n_slots) for p in self.plans]
        self.requests = requests
        self.qcfg = qcfg
        self.activation = activation

        P = len(self.schedules)
        R = requests.n_requests
        if R == 0:
            raise ValueError("empty request trace")
        L = activation.n_layers
        n_exp = activation.n_experts
        K = activation.top_k
        N = requests.total_decode_tokens
        M = R + N
        self.n_plans, self.n_requests = P, R
        self.n_decode_tokens, self.n_tokens = N, M
        # One FIFO work queue per satellite of the constellation.
        self.n_layers, self.n_stations = L, topo.n_sats
        self.n_topo_slots = topo.n_slots

        tok_req = requests.request_of_token()                    # (N,)
        self.tok_req = tok_req

        # --- slots from wall-clock time (one slot per request: request
        # lifetimes are seconds, a topology slot is minutes) ---------------
        slot_r = slot_of_time(requests.arrival_s, qcfg.slot_period_s,
                              topo.n_slots)
        self.slots = np.concatenate([slot_r, slot_r[tok_req]])   # (M,)

        # --- ingress mapping ----------------------------------------------
        if batch is None:
            batch = ScheduleBatch.from_schedules(self.schedules, topo,
                                                 eta=eta)
        self.batch = batch
        if ground is not None:
            ing_sat, uplink = ground.for_requests(slot_r, requests.station)
            reachable = ing_sat >= 0
            ing_off = schedule_ingress_offsets(
                batch, slot_r, np.where(reachable, ing_sat, 0))
            ing_off = np.where(reachable[None, :], ing_off, np.inf)
        else:
            uplink = np.zeros(R)
            ing_off = np.zeros((P, R))
        self.fail_ingress = ~np.isfinite(ing_off)                 # (P, R)
        self.ingress_extra = uplink[None, :] + np.where(
            self.fail_ingress, 0.0, ing_off)                      # (P, R)

        # --- engine pass: base (zero-load) per-token latencies -------------
        draws = np.stack([activation.sample(layer, rng, M)
                          for layer in range(L)])                 # (L, M, K)
        self.draws = draws
        self.engine_results = evaluate_schedules(
            self.schedules, topo, activation, workload, compute, rng,
            n_tokens=M, ctx_len=ctx_len, include_lm_head=include_lm_head,
            eta=eta, batch=batch, slots=self.slots, draws=draws)
        token_lat = np.stack(
            [r.token_latency_s for r in self.engine_results])     # (P, M)
        layer_lat = np.stack(
            [r.layer_latency_s for r in self.engine_results])     # (P, M, L)

        # Undeliverable tokens (unreachable satellite in that slot) fail
        # the whole request; zero them so the segmented cumsums of the
        # *other* requests sharing the token axis stay finite.
        self.nan_tok = ~np.isfinite(token_lat)
        token_lat = np.where(self.nan_tok, 0.0, token_lat)
        layer_lat = np.where(np.isfinite(layer_lat), layer_lat, 0.0)

        t_gateway = compute.latency_s(workload.gateway_flops(ctx_len))
        t_expert = compute.latency_s(workload.expert_flops)
        t_head = (compute.latency_s(workload.lm_head_flops)
                  if include_lm_head else 0.0)
        self.t_gateway, self.t_expert = t_gateway, t_expert

        # --- zero-load per-layer costs -------------------------------------
        # Prefill macro-token: the engine token plus, per layer, the
        # incremental pipelined compute of the remaining prompt tokens
        # (the batch shares the network hops; experts each absorb a K/I
        # share of the FFN work in parallel).
        incr_layer = t_gateway + t_expert * K / n_exp
        extra_layer = (requests.prompt_len - 1).astype(np.float64) \
            * incr_layer                                          # (R,)

        self.gw_service = np.concatenate([
            requests.prompt_len.astype(np.float64) * t_gateway,
            np.full(N, t_gateway),
        ])                                                        # (M,)
        self.eff_layer = layer_lat.copy()                         # (P, M, L)
        self.eff_layer[:, :R, :] += extra_layer[None, :, None]
        self.tok_base = token_lat.copy()                          # (P, M)
        self.tok_base[:, :R] += L * extra_layer[None, :]
        self.start_pref = requests.arrival_s[None, :] \
            + self.ingress_extra                                  # (P, R)
        self.first_tok = np.cumsum(requests.decode_len) \
            - requests.decode_len                                 # (R,)

        # --- queue events: (plan, station, request, work) ------------------
        # Stations are satellites: each token's deposits land on the
        # satellites its slot's plan routes it through (the slot -> plan
        # gather), so colocated experts share their satellite's queue
        # (Eq. 43) and a mid-horizon plan switch redirects new deposits
        # while the old plan's backlog drains in place.
        self.gateways_slot = batch.gateways_by_slot()         # (P, N_T, L)
        self.expert_sats_slot = batch.expert_sats_by_slot()   # (P,N_T,L,I)
        eta_slot = batch.eta_by_slot()                        # (P, N_T)
        gw_tok = self.gateways_slot[:, self.slots]            # (P, M, L)
        sats_tok = self.expert_sats_slot[:, self.slots]       # (P, M, L, I)
        eta_tok = eta_slot[:, self.slots]                     # (P, M)

        # Gateway work: every token visits every gateway satellite of its
        # slot's plan; lm-head work on the last gateway.
        gw_station = gw_tok
        gw_work = np.broadcast_to(self.gw_service[None, :, None],
                                  (P, M, L)).copy()
        gw_work[:, :, L - 1] += t_head
        gw_req = np.concatenate([np.arange(R), tok_req])          # (M,)

        # Decode expert work: the engine's own draws, scattered onto the
        # drawn expert's satellite; colocation multiplies the deposited
        # work (the Eq. 43 q factor) and eta scales the shared-compute
        # efficiency.
        draws_mlk = np.moveaxis(draws, 0, 1)                      # (M, L, K)
        exp_sat_tok = np.take_along_axis(
            sats_tok, draws_mlk[None], axis=3)                    # (P,M,L,K)
        dec_exp_station = exp_sat_tok[:, R:]                      # (P,N,L,K)
        dec_exp_work = np.broadcast_to(
            (t_expert / eta_tok[:, R:])[..., None, None],
            dec_exp_station.shape)

        # Prefill expert work: the whole prompt hits every expert of the
        # layer in proportion to its activation probability (fluid split
        # of the batch), deposited at the prefill token's expert visit.
        probs = activation.all_probs()                            # (L, I)
        pre_exp_station = sats_tok[:, :R]                         # (P,R,L,I)
        pre_exp_work = np.broadcast_to(
            requests.prompt_len[None, :, None, None]
            * probs[None, None, :, :] * t_expert
            / eta_tok[:, :R, None, None], (P, R, L, n_exp))

        ev_station = np.concatenate([
            gw_station.reshape(P, -1),
            dec_exp_station.reshape(P, -1),
            pre_exp_station.reshape(P, -1),
        ], axis=1)                                                # (P, E)
        ev_work = np.concatenate([
            gw_work.reshape(P, -1),
            dec_exp_work.reshape(P, -1),
            pre_exp_work.reshape(P, -1),
        ], axis=1)                                                # (P, E)
        ev_req = np.concatenate([
            np.broadcast_to(gw_req[:, None], (M, L)).ravel(),
            np.broadcast_to(tok_req[:, None, None], (N, L, K)).ravel(),
            np.broadcast_to(np.arange(R)[:, None, None],
                            (R, L, n_exp)).ravel(),
        ])                                                        # (E,)

        # Wait-gather stations: per (plan, token, layer) the gateway and
        # the K expert branches (max over branches joins the layer
        # critical path, mirroring the engine's max over experts).
        self.gather_gw_station = gw_station                       # (P, M, L)
        self.gather_exp_station = exp_sat_tok                     # (P,M,L,K)

        # Chunked service (continuous-batching semantics): a deposit
        # larger than one bin of capacity is spread over consecutive
        # bins at the service rate, so a long prefill does not
        # head-of-line-block every token behind one bin.  The chunk
        # layout depends only on work, so it is precomputed; per run
        # only the chunk *bins* are recomputed from the schedule.
        dt = qcfg.dt_s
        w_flat = ev_work.ravel()
        n_ch = np.maximum(np.ceil(w_flat / dt).astype(np.int64), 1)
        self._rep = np.repeat(np.arange(w_flat.size), n_ch)
        self._offs = np.arange(self._rep.size) \
            - np.repeat(np.cumsum(n_ch) - n_ch, n_ch)
        self.ev_chunk_work = np.minimum(w_flat[self._rep]
                                        - self._offs * dt, dt)
        self.ev_chunk_station = ev_station.ravel()[self._rep]
        self.ev_chunk_plan = np.broadcast_to(
            np.arange(P)[:, None], ev_work.shape).ravel()[self._rep]
        self.ev_chunk_req = np.broadcast_to(
            ev_req[None, :], ev_work.shape).ravel()[self._rep]
        self._n_events = ev_work.size

        # --- time bins (fixed across runs so the scan compiles once) ------
        start_dec0, _, c00 = self._chain(self.tok_base, self.start_pref)
        end0 = start_dec0 + self.tok_base[:, R:]
        horizon = max(float(requests.arrival_s.max()),
                      float(np.where(np.isfinite(end0), end0, 0.0).max()),
                      float(np.where(np.isfinite(c00), c00, 0.0).max()))
        self.n_bins = int(np.ceil((horizon + qcfg.tail_s) / qcfg.dt_s)) + 1
        if self.n_bins > 2_000_000:
            raise ValueError(
                f"{self.n_bins} time bins — raise dt_s or shrink the horizon")

        # --- migration background load (schedule switches) -----------------
        self._build_migration_load()

        # --- admission controller precompute ------------------------------
        acfg = qcfg.admission
        self.admission_on = acfg is not None and acfg.policy == "aimd"
        if self.admission_on:
            self._build_admission_tables(acfg, ground, slot_r, rng)

        # Filled by ``run``: (plan, satellite, bin) backlog of the last
        # fleet scan (the re-placement controller's observation).
        self.last_wait: np.ndarray | None = None

    # ----------------------------------------------------------------- #

    def _build_migration_load(self) -> None:
        """Precompute the background work a schedule's plan switches
        deposit on the fleet.

        Every slot boundary the wall-clock horizon crosses is checked
        against each row's :class:`~repro.core.schedule.PlanSchedule`;
        per moved expert (the ``distributed.elastic`` diff rule via
        :meth:`~repro.core.schedule.PlanSchedule.migrations_over`) the
        weight transfer occupies the *destination* satellite's queue for
        ``bytes * 8 / migration_rate_gbps`` seconds, chunked into dt
        bins from the boundary — arriving tokens queue behind the
        weights being installed.  Constant schedules deposit nothing, so
        the static path is untouched bit-for-bit.
        """
        qcfg = self.qcfg
        dt, T, S = qcfg.dt_s, self.n_bins, self.n_stations
        sec_per_expert = (qcfg.migration_bytes_per_expert * 8.0
                          / (qcfg.migration_rate_gbps * 1e9))
        flat_parts: list[np.ndarray] = []
        work_parts: list[np.ndarray] = []
        self.migration_bytes = np.zeros(self.n_plans)
        for p, sched in enumerate(self.schedules):
            for t_b, mig in sched.migrations_over(
                    T * dt, qcfg.slot_period_s,
                    qcfg.migration_bytes_per_expert):
                self.migration_bytes[p] += mig.bytes_moved
                if mig.n_moved == 0 or sec_per_expert <= 0.0:
                    continue
                n_ch = max(int(np.ceil(sec_per_expert / dt)), 1)
                bins = np.minimum(int(t_b / dt) + np.arange(n_ch), T - 1)
                w = np.minimum(sec_per_expert - np.arange(n_ch) * dt, dt)
                fl = ((p * S + mig.new_sats[:, None]) * T
                      + bins[None, :]).ravel()
                flat_parts.append(fl)
                work_parts.append(np.broadcast_to(
                    w[None, :], (mig.n_moved, n_ch)).ravel())
        self._mig_flat = (np.concatenate(flat_parts) if flat_parts
                          else np.empty(0, dtype=np.int64))
        self._mig_work = (np.concatenate(work_parts) if work_parts
                          else np.empty(0, dtype=np.float64))

    # ----------------------------------------------------------------- #

    def _build_admission_tables(self, acfg: AdmissionConfig,
                                ground: GroundSegment | None,
                                slot_r: np.ndarray,
                                rng: np.random.Generator) -> None:
        """Precompute the gateway-retry attempt tables and the AIMD
        controller's zero-load references.

        Per attempt a (0 = the original gateway, a >= 1 = the a-th best
        alternative gateway from :meth:`GroundSegment.retry_stations`):
        target gateway, total ingress latency (a * backoff + terrestrial
        forward + uplink + ingress hop) and per-plan feasibility.  An
        alternate gateway enters through the first rank of its
        ranked-visibility table whose ingress route exists for the plan
        in that slot (deeper ranks cover an occluded or unroutable best
        satellite).  When no a-th alternative exists — no ground
        segment, or fewer visible gateways than retries — attempt a is a
        same-gateway backoff retry: the origin is re-attempted after the
        backoff, drawing against the (time-varying) admit state of a
        later bin.  Retries happen within the arrival's topology slot
        (backoff << slot period).
        """
        req = self.requests
        P, R = self.n_plans, self.n_requests
        A = acfg.n_attempts
        self.n_gw_stations = ground.n_stations if ground is not None else 1

        # Without a ground segment there is a single logical gateway.
        station = req.station if ground is not None \
            else np.zeros(R, dtype=np.int64)
        st_att = np.tile(station, (A, 1))                         # (A, R)
        alt_ok = np.zeros((A, R), dtype=bool)
        alt_ok[0] = True
        if ground is not None and acfg.max_retries > 0:
            alts = ground.retry_stations(slot_r, req.station,
                                         acfg.max_retries)        # (R, n_alt)
            n_alt = alts.shape[1]
            for a in range(1, min(A, n_alt + 1)):
                st_att[a] = alts[:, a - 1]
                alt_ok[a] = True

        extra = np.empty((A, P, R))
        feas = np.zeros((A, P, R), dtype=bool)
        extra[0] = self.ingress_extra
        feas[0] = ~self.fail_ingress
        for a in range(1, A):
            if ground is None or not alt_ok[a].any():
                # Same-gateway backoff retry (see docstring).
                extra[a] = self.ingress_extra + a * acfg.retry_backoff_s
                feas[a] = feas[0]
                continue
            gdelay = ground.ground_delay_s[req.station, st_att[a]]
            # Ranked-visibility fallback: per plan, the first rank of
            # the alternate gateway's satellite ranking with a finite
            # ingress route.
            ing_r = ground.ingress_ranked[slot_r, st_att[a]]      # (R, K)
            up_r = ground.uplink_ranked_s[slot_r, st_att[a]]      # (R, K)
            best = np.zeros((P, R))
            best_ok = np.zeros((P, R), dtype=bool)
            for k in range(ground.n_ranked):
                reachable = ing_r[:, k] >= 0
                off = schedule_ingress_offsets(
                    self.batch, slot_r, np.where(reachable, ing_r[:, k], 0))
                ok = reachable[None, :] & np.isfinite(off)
                take = ok & ~best_ok
                best = np.where(take, up_r[None, :, k] + off, best)
                best_ok |= ok
            extra[a] = (a * acfg.retry_backoff_s + gdelay)[None, :] \
                + np.where(best_ok, best, 0.0)
            feas[a] = best_ok & alt_ok[a][None, :]
        self._att_station = st_att
        self._att_extra = extra
        self._att_feasible = feas
        # Attempt a is evaluated at the gateway it targets, after the
        # backoff + terrestrial forward but before the uplink.
        t_att = req.arrival_s[None, :] + np.arange(A)[:, None] \
            * acfg.retry_backoff_s
        if ground is not None:
            t_att = t_att + ground.ground_delay_s[req.station, st_att]
        self._att_bin = np.clip((t_att / self.qcfg.dt_s).astype(np.int64),
                                0, self.n_bins - 1)
        # Common random numbers: one uniform per (attempt, request),
        # shared by every plan and every run() call.
        self._adm_u = rng.random((A, R))

        # Zero-load controller references (see admission module
        # docstring): tail anchors at the configured reference quantile.
        base_ttft = self.ingress_extra + self.tok_base[:, :R]     # (P, R)
        ok = feas[0] & ~_segment_any(self.nan_tok[:, R:], self.tok_req, R) \
            & ~self.nan_tok[:, :R]
        self._adm_ttft0 = _station_quantile(
            base_ttft, ok, station, self.n_gw_stations,
            acfg.reference_quantile)                              # (P, G)
        dec_ok = np.isfinite(self.tok_base[:, R:]) & ~self.nan_tok[:, R:]
        self._adm_tpot0 = np.array([
            np.quantile(self.tok_base[i, R:][dec_ok[i]],
                        acfg.reference_quantile)
            if dec_ok[i].any() else 0.0 for i in range(P)])        # (P,)

        # Slot-dependent critical-path stations for the in-scan
        # controller: per time bin, the bin's topology slot selects each
        # plan's gateway chain and expert satellites — the admission
        # law's qhat follows the schedule through every plan switch.
        slot_of_bin = slot_of_time(np.arange(self.n_bins) * self.qcfg.dt_s,
                                   self.qcfg.slot_period_s,
                                   self.n_topo_slots)
        self._adm_gw_idx = np.ascontiguousarray(np.moveaxis(
            self.gateways_slot[:, slot_of_bin], 1, 0)).astype(np.int32)
        self._adm_exp_idx = np.ascontiguousarray(np.moveaxis(
            self.expert_sats_slot[:, slot_of_bin], 1, 0)).reshape(
                self.n_bins, P, -1).astype(np.int32)

    # ----------------------------------------------------------------- #

    def _chain(self, tok_total: np.ndarray, start_pref: np.ndarray):
        """Autoregressive chaining: (decode token starts (P, N), their
        per-request inclusive cumsums (P, N), prefill completion (P, R))."""
        R = self.n_requests
        dec = tok_total[:, R:]
        cs = np.cumsum(dec, axis=1)
        base = (cs - dec)[:, self.first_tok][:, self.tok_req]
        seg_excl = (cs - dec) - base
        c0 = start_pref + tok_total[:, :R]
        start_dec = c0[:, self.tok_req] + seg_excl
        return start_dec, cs - base, c0

    def _schedule(self, gw_wait: np.ndarray, ex_max: np.ndarray,
                  start_pref: np.ndarray):
        """Wait-augmented schedule: per-(plan, token, layer) gateway and
        expert arrival times, plus per-token total latencies."""
        lay_cost = self.eff_layer + gw_wait + ex_max              # (P, M, L)
        tok_total = self.tok_base + gw_wait.sum(2) + ex_max.sum(2)
        start_dec, seg_incl, c0 = self._chain(tok_total, start_pref)
        start_all = np.concatenate([start_pref, start_dec], axis=1)
        layer_arr = start_all[:, :, None] + _exclusive_cumsum(lay_cost, 2)
        exp_arr = layer_arr + gw_wait + self.gw_service[None, :, None]
        return layer_arr, exp_arr, tok_total, seg_incl, c0

    def _to_bins(self, times: np.ndarray):
        """Clip finite ``times`` to bin indices; returns (bins, finite)."""
        finite = np.isfinite(times)
        b = np.where(
            finite,
            np.clip((np.where(finite, times, 0.0) / self.qcfg.dt_s)
                    .astype(np.int64), 0, self.n_bins - 1), 0)
        return b, finite

    def _bin_work(self, layer_arr, exp_arr, active2d):
        """Offered work (P, S, T) for the current schedule + per-plan
        request-activity mask ``active2d`` (P, R)."""
        P, R = self.n_plans, self.n_requests
        S, T = self.n_stations, self.n_bins
        ev_time = np.concatenate([
            layer_arr.reshape(P, -1),
            np.broadcast_to(
                exp_arr[:, R:, :, None],
                (P, self.n_decode_tokens, self.n_layers,
                 self.activation.top_k)).reshape(P, -1),
            np.broadcast_to(
                exp_arr[:, :R, :, None],
                (P, R, self.n_layers, self.activation.n_experts))
            .reshape(P, -1),
        ], axis=1).ravel()                                        # (P*E,)
        base_bin, finite = self._to_bins(ev_time)
        bins = np.minimum(base_bin[self._rep] + self._offs, T - 1)
        w = self.ev_chunk_work * finite[self._rep] \
            * active2d[self.ev_chunk_plan, self.ev_chunk_req]
        flat = (self.ev_chunk_plan * S + self.ev_chunk_station) * T + bins
        if self._mig_flat.size:
            # Schedule-switch weight migrations ride as background load.
            flat = np.concatenate([flat, self._mig_flat])
            w = np.concatenate([w, self._mig_work])
        return np.bincount(flat, weights=w,
                           minlength=P * S * T).reshape(P, S, T)

    def _gather(self, wait, overload, layer_arr, exp_arr):
        """Per-(plan, token, layer) gateway wait, expert branch-max wait,
        and overload flags, read at the schedule's arrival bins."""
        p_idx = np.arange(self.n_plans)[:, None, None]
        gw_b, gw_fin = self._to_bins(layer_arr)
        gw_wait = np.where(gw_fin,
                           wait[p_idx, self.gather_gw_station, gw_b], 0.0)
        gw_over = gw_fin & overload[p_idx, self.gather_gw_station, gw_b]
        ex_b, ex_fin = self._to_bins(exp_arr)
        ex_b4, ex_f4 = ex_b[..., None], ex_fin[..., None]
        ex_wait = np.where(
            ex_f4, wait[p_idx[..., None], self.gather_exp_station, ex_b4],
            0.0)
        ex_over = ex_f4 & \
            overload[p_idx[..., None], self.gather_exp_station, ex_b4]
        return gw_wait, ex_wait.max(axis=3), gw_over, ex_over.any(axis=3)

    # ----------------------------------------------------------------- #

    def satellite_backlog(self, plan: int, t_s: float) -> np.ndarray:
        """(V,) seconds of backlog per satellite that plan row ``plan``
        observed at wall-clock ``t_s`` in the last ``run`` — the live
        signal the re-placement controller scores candidate plans
        against (zeros before any loaded run)."""
        if self.last_wait is None:
            return np.zeros(self.n_stations)
        b = min(int(t_s / self.qcfg.dt_s), self.n_bins - 1)
        return self.last_wait[plan, :, b]

    # ----------------------------------------------------------------- #

    def run(self, active: np.ndarray | None = None,
            zero_load: bool = False) -> TrafficResult:
        """Simulate with an optional per-request activity mask (Poisson
        thinning for rate sweeps) and return per-plan traffic metrics.

        ``zero_load`` skips the queue scan entirely (all waits zero):
        the infinite-capacity reference whose latencies are exactly the
        engine's — the natural anchor for relative-headroom SLOs.  The
        admission controller (if configured) is also bypassed at zero
        load.

        Args:
            active: Optional (R,) bool participation mask (default: all).
            zero_load: Skip queueing and admission entirely.

        Returns:
            A :class:`~repro.traffic.metrics.TrafficResult` with one
            :class:`~repro.traffic.metrics.PlanTraffic` per plan.
        """
        qcfg = self.qcfg
        acfg = qcfg.admission
        req = self.requests
        P, R = self.n_plans, self.n_requests
        M, L = self.n_tokens, self.n_layers

        if active is None:
            active = np.ones(R, dtype=bool)
        active = np.asarray(active, dtype=bool)

        adm_on = self.admission_on and not zero_load
        shed = np.zeros((P, R), dtype=bool)
        retries = np.zeros((P, R), dtype=np.int64)
        ingress_extra = self.ingress_extra
        start_pref = self.start_pref
        if adm_on:
            ctrl = jnp.asarray(control_bin_flags(self.n_bins, qcfg.dt_s,
                                                 acfg.interval_s))
            admit_floor = np.ones((P, self.n_gw_stations, self.n_bins))
            margin = acfg.target_margin
            ttft0 = jnp.asarray(self._adm_ttft0)
            tpot0 = jnp.asarray(self._adm_tpot0)
            gw_idx = jnp.asarray(self._adm_gw_idx)
            exp_idx = jnp.asarray(self._adm_exp_idx)

        gw_wait = np.zeros((P, M, L))
        ex_max = np.zeros((P, M, L))
        gw_over = np.zeros((P, M, L), dtype=bool)
        ex_over = np.zeros((P, M, L), dtype=bool)
        n_iter = 1 if zero_load else max(1, qcfg.iterations)
        for _ in range(n_iter):
            layer_arr, exp_arr, tok_total, seg_incl, c0 = \
                self._schedule(gw_wait, ex_max, start_pref)
            work = self._bin_work(layer_arr, exp_arr,
                                  active[None, :] & ~shed)
            if zero_load:
                break
            if adm_on:
                wait, dropped, admit = admission_queue_scan(
                    jnp.asarray(work), jnp.asarray(qcfg.buffer_s),
                    qcfg.dt_s, ttft0, tpot0, ctrl, gw_idx, exp_idx,
                    jnp.ones((P, self.n_gw_stations)),
                    margin * acfg.ttft_target_s,
                    margin * acfg.tpot_target_s,
                    acfg.increase, acfg.decrease, acfg.admit_min)
                # Monotone outer iteration: accumulate the trace as a
                # running minimum so the shed set only grows and the
                # fixed point converges from the congested side.
                admit_floor = np.minimum(admit_floor, np.asarray(admit))
                choice, shed = resolve_admission(
                    admit_floor, self._att_bin, self._att_station,
                    self._att_feasible, self._adm_u)
                retries = np.where(shed, 0, choice)
                ingress_extra = np.take_along_axis(
                    np.moveaxis(self._att_extra, 0, 1),     # (P, A, R)
                    retries[:, None, :], axis=1)[:, 0, :]   # (P, R)
                start_pref = req.arrival_s[None, :] + ingress_extra
            else:
                wait, dropped = _fleet_queue_scan(
                    jnp.asarray(work), jnp.asarray(qcfg.buffer_s),
                    qcfg.dt_s)
            wait = np.asarray(wait)
            overload = np.asarray(dropped) > 0.0
            # Exposed for the re-placement controller: the live
            # (plan, satellite, bin) backlog of the last fleet scan.
            self.last_wait = wait
            gw_wait, ex_max, gw_over, ex_over = self._gather(
                wait, overload, layer_arr, exp_arr)
        # Fold the final gather into the schedule once more so reported
        # latencies reflect the waits actually found on the last pass.
        layer_arr, exp_arr, tok_total, seg_incl, c0 = \
            self._schedule(gw_wait, ex_max, start_pref)

        # --- request metrics -----------------------------------------------
        last_tok = self.first_tok + req.decode_len - 1
        ttft = ingress_extra + tok_total[:, :R]                   # (P, R)
        e2e = ttft + seg_incl[:, last_tok]                        # (P, R)

        tok_over = gw_over.any(axis=2) | ex_over.any(axis=2)      # (P, M)
        fail_tok = self.nan_tok | tok_over
        failed = fail_tok[:, :R] \
            | _segment_any(fail_tok[:, R:], self.tok_req, R)      # (P, R)
        if adm_on:
            # Shed requests are accounted separately (not involuntary
            # drops); admitted requests entered via a feasible attempt.
            failed |= shed
        else:
            failed |= self.fail_ingress

        # KV admission cap: reject arrivals that would exceed the
        # in-flight budget (first-order: in-flight counted over all
        # offered requests).  The adaptive controller replaces this cap.
        admitted = np.ones((P, R), dtype=bool)
        if qcfg.kv_slots > 0 and not adm_on:
            comp = req.arrival_s[None, :] + np.nan_to_num(
                e2e, nan=np.inf, posinf=np.inf)
            comp = np.where(active[None, :], comp, -np.inf)
            n_inactive = int((~active).sum())
            arrived = np.cumsum(active)                           # (R,)
            for p in range(P):                                    # P is small
                done = np.searchsorted(np.sort(comp[p]), req.arrival_s,
                                       side="right") - n_inactive
                admitted[p] = (arrived - done) <= qcfg.kv_slots
        failed |= ~admitted

        served = active[None, :] & ~failed                        # (P, R)
        span = max(float(req.arrival_s[active].max()
                         - req.arrival_s[active].min()), qcfg.dt_s) \
            if active.any() else qcfg.dt_s
        # Offered utilization over the arrival window (> 1 = overload).
        util = work.sum(axis=2) / span                            # (P, S)

        plans_out = []
        for p in range(P):
            with np.errstate(invalid="ignore"):
                tpot = (e2e[p] - ttft[p]) / req.decode_len
            plans_out.append(PlanTraffic(
                plan_name=self.batch.names[p],
                active=active.copy(),
                served=served[p],
                ttft_s=np.where(served[p], ttft[p], np.nan),
                tpot_s=np.where(served[p], tpot, np.nan),
                e2e_s=np.where(served[p], e2e[p], np.nan),
                decode_len=req.decode_len,
                station_util=util[p],
                span_s=span,
                token_total_s=tok_total[p],
                shed=(shed[p] & active) if adm_on else None,
                retries=np.where(served[p], retries[p], 0)
                if adm_on else None,
                migration_bytes=float(self.migration_bytes[p]),
            ))
        return TrafficResult(plans=plans_out, requests=req,
                             slots=self.slots, n_bins=self.n_bins,
                             dt_s=qcfg.dt_s)


def simulate_traffic(
    plans: list,
    topo: TopologySample,
    activation: ActivationModel,
    workload: MoEWorkload,
    compute: ComputeConfig,
    requests: RequestBatch,
    rng: np.random.Generator,
    qcfg: QueueConfig = QueueConfig(),
    ground: GroundSegment | None = None,
    **kwargs,
) -> TrafficResult:
    """One-shot convenience wrapper: build a :class:`FleetSim` and run it
    with every request active.

    Args:
        plans: Placement-plan sweep.
        topo: Sampled topology.
        activation: Expert-activation model.
        workload: FLOP model of the served MoE.
        compute: FLOPs -> seconds conversion.
        requests: The request trace.
        rng: Randomness for engine draws / admission uniforms.
        qcfg: Queueing/admission parameters.
        ground: Optional ground segment.
        **kwargs: Forwarded to :class:`FleetSim`.

    Returns:
        The :class:`~repro.traffic.metrics.TrafficResult` of one full run.
    """
    sim = FleetSim(plans, topo, activation, workload, compute, requests,
                   rng, qcfg=qcfg, ground=ground, **kwargs)
    return sim.run()
