"""Discrete-time per-satellite service model for request-level serving.

Every satellite of the constellation is a FIFO work queue (stations are
keyed by satellite id, S = V): a token deposits on the L gateway
satellites (attention + gating + lm-head service) and the per-layer
expert satellites (FFN service) of *the plan its topology slot selects*
— plans are time-indexed :class:`~repro.core.schedule.PlanSchedule`
entries, plain plans riding as constant schedules.  Colocated experts
share their satellite's queue (the queue-theoretic face of the Eq. 43
contention term), and a plan switch at a slot boundary redirects new
deposits while the old plan's backlog drains in place, with the moved
expert weights occupying destination queues as background load.  The
simulator is deliberately split into

1. a **base schedule** — per-token zero-load trajectories straight from
   the batched plan-evaluation engine (``core.engine.evaluate_plans``
   with wall-clock-derived slots and shared expert draws), so at zero
   load the traffic subsystem reproduces the engine exactly;
2. a **fleet queue kernel** — one ``lax.scan`` over time bins with the
   (plans, stations) backlog matrix as carry, vectorized over every
   plan of the sweep.  Backlogs are capped (finite buffers: overflow =
   backpressure drop) and each arrival's waiting time is the backlog it
   finds (exact for Poisson arrivals by PASTA, up to the O(dt) binning
   error the M/D/1 test bounds against Pollaczek-Khinchine);
3. a **closed-loop fixed point** — waits delay a token's delivery, and
   delivery times gate the autoregressive chain, so the schedule and
   the queue state are mutually dependent.  ``run`` iterates
   schedule -> bin -> scan -> gather a configurable number of times
   (``QueueConfig.iterations``): iteration 1 is the open-loop
   approximation, further iterations let congested tokens arrive
   *after* the backlog they caused has drained, which removes the
   open-loop bias of billing one backlog episode to every token of a
   request.  Deposits larger than one bin of service are spread over
   consecutive bins (chunked-prefill semantics, like production
   continuous-batching schedulers).

Two admission regimes guard KV-cache memory and the latency SLO:

* the legacy **static cap** — a request arriving when more than
  ``kv_slots`` requests are in flight is rejected (its offered load
  still occupies the queues: rejection happens at the ingress gateway
  *after* the uplink, the conservative accounting);
* the **latency-target controller** (``QueueConfig.admission`` with
  policy ``"aimd"``, see :mod:`repro.traffic.admission`) — an AIMD loop
  carried through the fleet scan observes the windowed critical-path
  backlog and sheds load *before* the target is crossed.  Rejections
  happen at the ground gateway before the uplink (shed load never
  enters the queues), and rejected requests retry at the next-best
  visible gateway with the retry latency accounted in TTFT/E2E.

``FleetSim`` precomputes everything rate-independent once (engine pass,
station indices, chunk layout) so a saturation sweep replays only the
binning + scan + gather per tested rate — no Python loop over requests
or tokens anywhere on the hot path.

Two execution paths share that precompute:

* the **fused device path** (``run`` / ``run_many``) — the whole
  schedule -> bin -> scan -> gather fixed point is one jitted
  ``lax.fori_loop`` (:func:`_fused_core`): the dense work tensor is
  built on device by a scatter-add deposit (:mod:`repro.kernels.deposit`:
  the one-hot-matmul kernel on TPU, the jnp reference scatter elsewhere,
  with a bitwise-identical row-bucketed ``segment_sum`` variant behind
  ``deposit_impl="segments"``),
  lives time-major, and never crosses the host boundary between
  iterations.  ``run_many`` vmaps the same core over a
  thinning-fraction (or admission-target) axis, so an entire saturation
  sweep is one compile + one launch.  The core is module-level and
  takes every per-simulator tensor as an argument, so fleet runs with
  equal shapes — every ``run_many`` rate, every re-placement
  decide/evaluate round — reuse one compile cache entry.  Dtype policy
  mirrors the host path exactly: schedules/bins/deposits in float64
  (``jax.experimental.enable_x64`` scoped to these launches), the
  backlog scan in float32 — the downcast ``run_legacy``'s jitted scans
  have always applied — so the two paths agree to the last bit in
  practice;
* the **legacy host path** (``run_legacy``) — the original NumPy
  fixed-point loop, kept verbatim as the authoritative semantic anchor.
  ``tests/test_fleet_perf.py`` pins fused<->legacy parity on identical
  served/shed sets and rtol <= 1e-5 latency quantiles.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64 as _x64

from repro.core import (ScheduleBatch, evaluate_schedules,
                        schedule_ingress_offsets)
from repro.obs.probes import (DecisionTrace, ProbeConfig, ProbeRecord,
                              make_buffers)
from repro.kernels import ops as _kernel_ops
from repro.core.activation import ActivationModel
from repro.core.calibration import resolve_service_model
from repro.core.latency import ComputeConfig, TopologySample
from repro.core.schedule import (PlanSchedule, as_schedule,
                                 migration_matrix, slot_of_time)
from repro.core.workload import MoEWorkload

from .admission import (_PID_WINDUP, AdmissionConfig, admission_queue_scan,
                        control_bin_flags, resolve_admission)
from .batching import (BatchingConfig, batch_speedup_at,
                       batched_effective_work, effective_work_np,
                       windowed_counts, windowed_counts_jnp)
from .ground import GroundSegment
from .metrics import PlanTraffic, TrafficResult
from .requests import RequestBatch


@dataclasses.dataclass(frozen=True)
class QueueConfig:
    """Discrete-time queueing parameters.

    Attributes:
        dt_s: Time-bin width.  Per-visit service times below dt never
            self-queue; the binning error is O(dt).
        buffer_s: Per-station backlog cap in seconds of work; arrivals
            overflowing it are dropped (backpressure).
        kv_slots: Max requests concurrently holding KV cache (0 = no
            admission cap).  Ignored when the adaptive controller is
            active — the controller *replaces* the static cap.
        slot_period_s: Wall-clock seconds per topology slot (ties tokens
            to the constellation's time-varying graph; default is a
            550 km LEO period split over 20 slots).
        tail_s: Extra horizon past the last zero-load completion so
            in-flight requests can drain.  Congestion-stretched
            schedules beyond it clip into the final bin (such runs are
            deep in SLO failure anyway).
        iterations: Schedule<->queue fixed-point iterations (1 = open
            loop).
        admission: Optional :class:`~repro.traffic.admission
            .AdmissionConfig`; policy ``"aimd"`` switches the run loop
            to the latency-target controller with gateway retry.
        migration_bytes_per_expert: Weight bytes one expert drags to a
            new satellite when a :class:`~repro.core.schedule
            .PlanSchedule` switches plans at a slot boundary.
        migration_rate_gbps: ISL share available to weight migration;
            each moved expert occupies its destination satellite's queue
            for ``bytes * 8 / rate`` seconds of background load.
    """

    dt_s: float = 0.05
    buffer_s: float = 10.0
    kv_slots: int = 0
    slot_period_s: float = 300.0
    tail_s: float = 120.0
    iterations: int = 3
    admission: AdmissionConfig | None = None
    migration_bytes_per_expert: float = 1e6
    migration_rate_gbps: float = 10.0


# --------------------------------------------------------------------- #
# The fleet queue kernel
# --------------------------------------------------------------------- #


@jax.jit
def _fleet_queue_scan(work, cap, dt):
    """Scan the (P, S) backlog matrix over T time bins.

    work: (P, S, T) seconds of work arriving per bin.
    cap:  scalar or (S,) backlog cap in seconds.
    Returns (wait, dropped), both (P, S, T): ``wait[..., t]`` is the
    backlog an arrival in bin t finds (work deposited in bin t is seen
    by later bins only); ``dropped`` is the overflow discarded per bin.
    """
    def _step(backlog, w_t):
        wait = backlog
        total = backlog + w_t
        dropped = jnp.maximum(total - cap, 0.0)
        backlog = jnp.maximum(jnp.minimum(total, cap) - dt, 0.0)
        return backlog, (wait, dropped)

    p, s, _ = work.shape
    backlog0 = jnp.zeros((p, s), dtype=work.dtype)
    _, (wait, dropped) = jax.lax.scan(_step, backlog0,
                                      jnp.moveaxis(work, 2, 0))
    return jnp.moveaxis(wait, 0, 2), jnp.moveaxis(dropped, 0, 2)


def station_waiting_times(
    arrival_s: np.ndarray,
    service_s: np.ndarray | float,
    dt_s: float,
    buffer_s: float = np.inf,
    horizon_s: float | None = None,
    batching: BatchingConfig | None = None,
) -> np.ndarray:
    """Per-arrival waiting times at one FIFO station via the fleet kernel.

    Runs the same discrete-time scan the fleet simulator uses (P=1, S=1)
    and refines the bin-resolution backlog with the exact within-bin
    Lindley correction: an arrival at offset ``delta`` into bin b waits

        max(0, backlog_at_bin_start + work_of_earlier_same_bin_arrivals
               - delta),

    since the server drains continuously through the bin.  This is the
    single-station reference the M/D/1 Pollaczek-Khinchine test checks.

    Args:
        arrival_s: (n,) sorted arrival times, seconds.
        service_s: Scalar or (n,) per-arrival service demand, seconds.
        dt_s: Time-bin width of the underlying scan.
        buffer_s: Backlog cap (overflow is dropped), default unbounded.
        horizon_s: Optional simulation horizon (defaults to the last
            arrival).
        batching: Optional :class:`~repro.traffic.batching
            .BatchingConfig` — applies the continuous-batching law
            (deposit-time work scaling by the windowed-occupancy
            speedup; see :mod:`repro.traffic.batching`) to this
            station, arrivals counting one occupancy unit each.
            ``None`` is the exact FIFO reference.

    Returns:
        (n,) waiting time each arrival experiences before service.
    """
    t = np.asarray(arrival_s, dtype=np.float64)
    if len(t) and not (np.diff(t) >= 0).all():
        raise ValueError("arrivals must be sorted")
    s = np.broadcast_to(np.asarray(service_s, dtype=np.float64), t.shape)
    horizon = (float(t[-1]) if len(t) else 0.0) \
        if horizon_s is None else horizon_s
    n_bins = int(np.floor(horizon / dt_s)) + 2
    bins = np.minimum((t / dt_s).astype(np.int64), n_bins - 1)

    work = np.bincount(bins, weights=s, minlength=n_bins)
    sp_bin = np.ones(n_bins)
    if batching is not None:
        cnt = np.bincount(bins, minlength=n_bins).astype(np.float64)
        table = batching.resolve_table()
        work, _ = effective_work_np(
            work, work, cnt, table, batching.b_cap,
            batching.window_bins(dt_s))
        sp_bin, _ = batch_speedup_at(
            windowed_counts(cnt, batching.window_bins(dt_s)),
            table, batching.b_cap)
    wait_bins = np.asarray(
        _fleet_queue_scan(jnp.asarray(work[None, None, :]),
                          jnp.asarray(buffer_s), dt_s)[0])[0, 0]

    # Within-bin FIFO: prior work of same-bin arrivals (scaled by the
    # bin's batching speedup when enabled), minus the time already
    # elapsed inside the bin.
    cs = np.cumsum(s)
    first = np.searchsorted(bins, bins, side="left")
    prior = ((cs - s) - (cs[first] - s[first])) / sp_bin[bins]
    delta = t - bins * dt_s
    return np.maximum(wait_bins[bins] + prior - delta, 0.0)


# --------------------------------------------------------------------- #
# Helpers
# --------------------------------------------------------------------- #


def _exclusive_cumsum(a: np.ndarray, axis: int) -> np.ndarray:
    out = np.cumsum(a, axis=axis)
    return out - a


def _segment_any(flags: np.ndarray, seg_ids: np.ndarray,
                 n_seg: int) -> np.ndarray:
    """OR-reduce boolean ``flags`` (P, E) over segments of the last axis."""
    p, _ = flags.shape
    idx = np.arange(p)[:, None] * n_seg + seg_ids[None, :]
    hits = np.bincount(idx.ravel(), weights=flags.ravel().astype(np.float64),
                       minlength=p * n_seg)
    return hits.reshape(p, n_seg) > 0.0


def _station_quantile(values: np.ndarray, ok: np.ndarray,
                      station: np.ndarray, n_stations: int,
                      q: float) -> np.ndarray:
    """(P, G) per-(plan, station) q-quantile of ``values`` (P, R) over
    the requests with ``ok`` set; stations with no valid request fall
    back to the plan-wide quantile (0 when nothing is valid at all)."""
    p = values.shape[0]
    out = np.zeros((p, n_stations))
    overall = np.array([
        np.quantile(values[i][ok[i]], q) if ok[i].any() else 0.0
        for i in range(p)])
    for g in range(n_stations):
        sel = ok & (station[None, :] == g)
        for i in range(p):
            out[i, g] = np.quantile(values[i][sel[i]], q) if sel[i].any() \
                else overall[i]
    return out


# --------------------------------------------------------------------- #
# The fused device fixed point
# --------------------------------------------------------------------- #

#: Incremented once per trace of :func:`_fused_core` — the compilation
#: counter ``tests/test_fleet_perf.py`` pins (a whole rate sweep through
#: ``run_many`` must cost exactly one trace).
FUSED_TRACE_COUNT = 0

#: The compacted chunk table is padded to a multiple of this, so sweeps
#: with similar activity reuse the fused kernel's compile cache.
_CHUNK_BLOCK = 8192


def _fleet_fixed_point(consts, chunks, work0, work0_sum, ttft_target,
                       tpot_target, pbuf, batch, n_iter, n_bins, n_rows,
                       adm_on, deposit_mode, want_wait, probes,
                       batch_window):
    """Single-launch fleet fixed point (the device half of ``FleetSim.run``).

    Rolls the legacy schedule -> bin -> scan -> gather iteration into one
    ``lax.fori_loop`` over device-resident precomputes, batched over an
    explicit sweep axis F, so the dense work tensor never crosses the
    host boundary between iterations.  Pure module-level function: every
    per-simulator tensor arrives via ``consts`` (the pytree built by
    :meth:`FleetSim._device_tables`), so fleet runs with equal shapes
    share one jit cache entry.

    Two compactions keep the device arrays proportional to *offered*
    work rather than to the constellation:

    * **row compaction** — queue rows are the (plan, satellite) pairs
      that can ever receive a deposit, observation or gather
      (``FleetSim._build_row_map``), not all P x V pairs; zero-work
      stations contribute exactly zero in both paths, so dropping them
      is exact;
    * **chunk compaction** — ``chunks`` holds only the (sweep entry,
      chunk) pairs whose request is active (built host-side per launch
      from the masks, padded to a stable block size), so a thinned rate
      sweep deposits only what it offers.

    Layout/dtype policy (pinned by the parity tests): schedules, bins
    and deposits compute in float64 exactly like the host path; the work
    tensor lives **time-major** ``(T, F, rows)`` so the scan consumes it
    with no transposes; the backlog scan itself runs in float32 — the
    same downcast the legacy path's jitted scans have always applied —
    and emits *only* the wait trace (overload flags are recovered at the
    gather points from ``wait + work > cap``, bit-identical to the
    legacy ``dropped > 0``).

    The first fixed-point iteration is **peeled**: its schedule is the
    zero-wait schedule, known at construction, so its offered-work plane
    ``work0`` arrives as a launch input (one host ``np.bincount`` over
    the compacted chunks — not a per-iteration transfer) and the device
    spends its scatter budget only on the congestion-corrected
    iterations 2..n.

    Args:
        consts: Device-resident precompute pytree (see
            :meth:`FleetSim._device_tables` for the keys).
        chunks: Compacted deposit table — ``src`` (gather index into the
            F-flattened [layer_arr | exp_arr] pair), ``offs`` (chunk
            offset in bins), ``work`` (seconds), ``fprow`` (target row
            in the (F * rows) plane), and under admission ``fpr`` (index
            into the (F, P, R) shed mask).  Entries are grouped by row
            (static sort), so the scatter walks the plane row-major.
        work0: (F, rows, T) float32 iteration-1 offered work (migration
            background load already added).
        work0_sum: (F, rows) float64 per-row sum of iteration-1 work
            (utilization reporting when ``n_iter == 1``).
        ttft_target: (F,) margin-scaled TTFT targets (admission only).
        tpot_target: (F,) margin-scaled TPOT targets (admission only).
        n_iter: Static — schedule<->queue fixed-point iterations.
        n_bins: Static — T, the time-bin count.
        n_rows: Static — compacted queue-row count.
        adm_on: Static — run the AIMD admission regime.
        deposit_mode: Static — ``"pallas"`` (the one-hot-matmul TPU
            kernel; f32 accumulation), ``"segments"`` (row-bucketed
            sorted ``segment_sum`` — the non-TPU scatter relief, bitwise
            identical to the reference) or ``"ref"`` (the inline jnp
            scatter-add).
        want_wait: Static — carry and return the final backlog trace
            (the re-placement controller's observation).
        pbuf: Probe ring buffers (:func:`repro.obs.probes.make_buffers`
            pytree; donated by the probed jit wrapper) — an empty dict
            when ``probes`` is None.
        batch: Continuous-batching pytree — an **empty dict** when
            batching is off (the trace then contains no batching ops and
            shares the batching-free compile-cache entry).  When on:
            ``table`` (the padded speedup interpolation table, f64),
            ``bcap`` (scalar admissible-batch bound) and — only for the
            probed ``n_iter == 1`` peel — ``beff0`` (F, rows, T) f32,
            the host-computed iteration-1 batch occupancy the probe
            channel records.  The law itself is deposit-time scaling
            (see :mod:`repro.traffic.batching`): the decode-work and
            occupancy-count planes ride two extra chunk channels
            (``wdec``/``cntw``) through the same scatter, and the scan
            consumes ``work + work_dec * (1/s(B_eff) - 1)``.
        batch_window: Static — occupancy window in bins (0 when batching
            is off; >= 1 when on).
        probes: Static — ``None`` (the probe-free kernel, byte-identical
            to the pre-observability trace) or the resolved
            ``(capacity, stride)`` pair of a
            :class:`~repro.obs.probes.ProbeConfig`.  When set, the
            backlog/admission scans ring-write per-bin fleet state into
            ``pbuf`` via ``dynamic_update_slice`` (each fixed-point
            iteration rewrites the same slots, so the final iteration
            wins) and the output dict gains ``probes`` (the written
            buffers) plus ``probe_gw_wait``/``probe_ex_wait``
            (F, P, M, L) — the final per-token per-layer queue waits the
            flight recorder splices into the Eq. 43 breakdown.

    Returns:
        Dict of outputs with a leading F axis: ``ttft``/``e2e``
        (F, P, R), ``tok_total`` (F, P, M), ``tok_over`` (F, P, M) bool,
        ``shed``/``retries`` (F, P, R), ``work_sum`` (F, rows), iff
        ``want_wait`` — ``wait`` (T, F, rows) float32 — and iff
        ``probes`` the probe outputs described above.
    """
    q = consts
    first_tok, tok_req = q["first_tok"], q["tok_req"]
    F = ttft_target.shape[0]
    R = first_tok.shape[0]
    # Consts arrive plan-leading (shared across the sweep) on the
    # standard path and F-leading (per-sweep-entry gathers, the fused
    # control plane's schedule-row evaluation) on the joint-controller
    # path; ``lead`` gives the closures a broadcastable (F, P, ...) view
    # either way, and the plan-leading branch traces exactly the
    # pre-control-plane computation.
    fb = q["eff_layer"].ndim == 4
    if fb:
        _, P, M, L = q["eff_layer"].shape
    else:
        P, M, L = q["eff_layer"].shape

    def lead(x):
        return x if fb else x[None]

    T, SR = n_bins, n_rows
    dt = q["dt"]
    cap32, dt32 = q["cap32"], q["dt32"]
    f32, f64 = jnp.float32, jnp.float64

    def to_bins(times):
        finite = jnp.isfinite(times)
        b = jnp.clip((jnp.where(finite, times, 0.0) / dt)
                     .astype(jnp.int64), 0, T - 1)
        return jnp.where(finite, b, 0), finite

    if probes is not None:
        p_cap, p_stride = probes

    def probe_write(bufs, t, wait, w_t, drop, qhat=None, admit=None,
                    win=None, beff=None):
        # Ring write via dynamic_update_slice: bin t lands in slot
        # (t // stride) % capacity; bins the stride skips write the
        # sentinel scratch slot (index capacity), so the scan step is
        # branch-free and XLA keeps the buffers aliased in the carry.
        # Under batching a fourth row channel records the per-bin batch
        # occupancy B_eff.
        rec = (t % p_stride) == 0
        slot = jnp.where(rec, (t // p_stride) % p_cap, p_cap)
        chans = [wait, w_t, drop] + ([] if beff is None else [beff])
        out = dict(bufs)
        out["rows"] = jax.lax.dynamic_update_slice(
            bufs["rows"], jnp.stack(chans)[None],
            (slot, 0, 0, 0))
        if qhat is not None:
            out["aimd"] = jax.lax.dynamic_update_slice(
                bufs["aimd"], jnp.stack([qhat, win])[None],
                (slot, 0, 0, 0))
            out["admit"] = jax.lax.dynamic_update_slice(
                bufs["admit"], admit[None], (slot, 0, 0, 0))
        return out

    def schedule(gw_wait, ex_max, start_pref):
        # jnp port of FleetSim._schedule + ._chain (identical math),
        # batched over the leading F axis.
        lay_cost = lead(q["eff_layer"]) + gw_wait + ex_max
        tok_total = lead(q["tok_base"]) + gw_wait.sum(3) + ex_max.sum(3)
        dec = tok_total[:, :, R:]
        cs = jnp.cumsum(dec, axis=2)
        excl = cs - dec
        base = excl[:, :, first_tok][:, :, tok_req]
        c0 = start_pref + tok_total[:, :, :R]
        start_dec = c0[:, :, tok_req] + (excl - base)
        start_all = jnp.concatenate([start_pref, start_dec], axis=2)
        layer_arr = start_all[..., None] \
            + (jnp.cumsum(lay_cost, axis=3) - lay_cost)
        exp_arr = layer_arr + gw_wait + q["gw_service"][None, None, :, None]
        return layer_arr, exp_arr, tok_total, cs - base

    def bin_work(layer_arr, exp_arr, shed):
        # jnp port of FleetSim._bin_work: every active chunk reads its
        # event's arrival time straight from the F-flattened
        # [layer_arr | exp_arr] pair via the precomputed gather index,
        # then scatter-adds the row-major (F * rows, T) plane in f64
        # (chunks are statically row-grouped, so consecutive updates
        # stay within one row's cache-resident T-span).
        flat_t = jnp.concatenate([layer_arr.reshape(F, -1),
                                  exp_arr.reshape(F, -1)],
                                 axis=1).reshape(-1)
        b_ch, fin = to_bins(flat_t[chunks["src"]])
        bins = jnp.minimum(b_ch + chunks["offs"], T - 1)

        def scat(vals):
            if deposit_mode == "pallas":
                # TPU: one-hot-matmul deposit kernel (f32 accumulation —
                # TPUs have no f64; CPU CI parity runs the f64 paths).
                return _kernel_ops.deposit(
                    chunks["fprow"], bins.astype(jnp.int32),
                    vals.astype(f32), F * SR, T).astype(f64)
            if deposit_mode == "segments":
                # Non-TPU scatter relief: the chunk table is statically
                # row-grouped, so the flat ids are row-bucketed and one
                # stable sort feeds the sorted segment reduction —
                # bitwise identical to the reference scatter.
                return _kernel_ops.deposit_segments(
                    chunks["fprow"], bins, vals, F * SR, T)
            # int64 flat index: F * rows * T can exceed 2^31 on large
            # worlds/sweeps (x64 is enabled for every fused launch).
            flat = chunks["fprow"].astype(jnp.int64) * T + bins
            return jnp.zeros(F * SR * T).at[flat].add(
                vals, mode="promise_in_bounds")

        vals = chunks["work"] * fin
        if adm_on:
            # Shed requests stop depositing (the activity compaction
            # already removed thinned-out requests).
            keep = ~shed.reshape(-1)[chunks["fpr"]]
            vals = vals * keep
        work = scat(vals).reshape(F, SR, T)
        if "mig_dense" in q:
            work = work + q["mig_dense"][None]
        elif "mig_dense_f" in q:
            # Joint-controller evaluation: the migration background load
            # depends on the device-decided schedule, so it arrives as a
            # traced (F, rows, T) plane instead of a shared const.
            work = work + q["mig_dense_f"]
        if not batch:
            return work, work, None
        # Continuous batching (deposit-time scaling): the decode-work
        # and occupancy-count channels ride the same scatter, and the
        # scan consumes work + work_dec * (1/s(B_eff) - 1).  The
        # migration background plane stays outside work_dec — it is not
        # batchable decode work.
        vdec, vcnt = chunks["wdec"] * fin, chunks["cntw"] * fin
        if adm_on:
            vdec, vcnt = vdec * keep, vcnt * keep
        work_dec = scat(vdec).reshape(F, SR, T)
        cnt = scat(vcnt).reshape(F, SR, T)
        work_eff, beff = batched_effective_work(
            work, work_dec, windowed_counts_jnp(cnt, batch_window),
            batch["table"], batch["bcap"])
        return work_eff, work, beff

    def fleet_scan(work32, bufs=None, beff_t=None):
        # The _fleet_queue_scan backlog recursion, time-major and
        # wait-only (f32, exactly the legacy downcast).  With ring
        # buffers passed (the probed final iteration only), the scan
        # carry additionally threads them and every stride-th bin
        # records (backlog, offered work, dropped) — the bufs-free
        # branch below is byte-identical to the legacy scan.  With
        # ``beff_t`` (probed batching runs) the ring gains the
        # batch-occupancy channel.
        if bufs is None:
            def step(b, w_t):
                wait = b
                b = jnp.maximum(jnp.minimum(b + w_t, cap32) - dt32, 0.0)
                return b, wait
            _, wait = jax.lax.scan(step, jnp.zeros((F, SR), f32), work32)
            return wait                                   # (T, F, SR)

        def step(carry, xs):
            b, pb = carry
            if beff_t is None:
                (w_t, t), be = xs, None
            else:
                w_t, t, be = xs
            wait = b
            offered = b + w_t
            drop = jnp.maximum(offered - cap32, 0.0)
            pb = probe_write(pb, t, wait, w_t, drop, beff=be)
            b = jnp.maximum(jnp.minimum(offered, cap32) - dt32, 0.0)
            return (b, pb), wait
        xs = (work32, jnp.arange(T))
        if beff_t is not None:
            xs = xs + (beff_t,)
        (_, bufs), wait = jax.lax.scan(
            step, (jnp.zeros((F, SR), f32), bufs), xs)
        return wait, bufs

    def adm_scan(work32, bufs=None, beff_t=None):
        # The admission_queue_scan recursion (bit-identical backlog and
        # AIMD cell), time-major over compacted rows, emitting wait +
        # the admit trace.  With ring buffers passed (the probed final
        # iteration only), the carry also threads them, recording the
        # fleet channels plus the AIMD cell state (backlog estimate
        # qhat, per-gateway admit, window peak); the bufs-free branch
        # is byte-identical to the legacy scan.
        tt32 = ttft_target.astype(f32)[:, None, None]     # (F, 1, 1)
        tp32 = tpot_target.astype(f32)[:, None]           # (F, 1)
        n_layers = q["gw_rows_bin"].shape[-1]
        pid_on = "pid_kp" in q        # static: AIMD trace byte-identical

        def cell(state, w_t, is_ctrl, gw_t, exp_t):
            if pid_on:
                backlog, admit, win, integ, prev = state
            else:
                backlog, admit, win = state
            wait = backlog
            offered = backlog + w_t
            backlog = jnp.maximum(jnp.minimum(offered, cap32) - dt32, 0.0)
            if fb:
                # F-leading station maps: gw_t (F, P, L), exp_t (F, P, LI).
                fi = jnp.arange(F)[:, None, None]
                gw = backlog[fi, gw_t].sum(axis=2)               # (F, P)
                exp = backlog[fi, exp_t] \
                    .reshape(F, P, n_layers, -1).max(axis=3).sum(axis=2)
            else:
                gw = backlog[:, gw_t].sum(axis=2)                # (F, P)
                exp = backlog[:, exp_t] \
                    .reshape(F, P, n_layers, -1).max(axis=3).sum(axis=2)
            win = jnp.maximum(win, gw + exp)
            if pid_on:
                # PID cell (admission module docstring): same formula
                # order as the host scan so the laws agree bitwise.
                h_t = jnp.where(
                    jnp.isfinite(tt32),
                    (tt32 - (lead(q["ttft0"]) + win[..., None])) / tt32,
                    jnp.inf)                                     # (F,P,G)
                h_p = jnp.where(
                    jnp.isfinite(tp32),
                    (tp32 - (lead(q["tpot0"]) + win)) / tp32,
                    jnp.inf)[..., None]                          # (F,P,1)
                err = jnp.minimum(h_t, h_p)
                integ2 = jnp.minimum(
                    jnp.maximum(integ + err, -f32(_PID_WINDUP)),
                    f32(_PID_WINDUP))
                delta = (q["pid_kp"] * err + q["pid_ki"] * integ2
                         + q["pid_kd"] * (err - prev))
                stepped = jnp.minimum(
                    jnp.maximum(admit + q["pid_gain"][None, :, None]
                                * delta, q["admit_min"]), 1.0)
                admit_next = jnp.where(is_ctrl, stepped, admit)
                win_next = jnp.where(is_ctrl, 0.0, win)
                nstate = (backlog, admit_next, win_next,
                          jnp.where(is_ctrl, integ2, integ),
                          jnp.where(is_ctrl, err, prev))
            else:
                over = ((lead(q["ttft0"]) + win[..., None]) > tt32) \
                    | ((lead(q["tpot0"]) + win) > tp32)[..., None]
                stepped = jnp.where(
                    over,
                    jnp.maximum(admit * q["decrease"], q["admit_min"]),
                    jnp.minimum(admit + q["increase"], 1.0))
                admit_next = jnp.where(is_ctrl, stepped, admit)
                win_next = jnp.where(is_ctrl, 0.0, win)
                nstate = (backlog, admit_next, win_next)
            return nstate, wait, offered, gw + exp

        n_gw = q["ttft0"].shape[-1]
        carry0 = (jnp.zeros((F, SR), f32), jnp.ones((F, P, n_gw), f32),
                  jnp.zeros((F, P), f32))
        if pid_on:
            carry0 = carry0 + (jnp.zeros((F, P, n_gw), f32),
                               jnp.zeros((F, P, n_gw), f32))
        if bufs is None:
            def step(state, xs):
                w_t, is_ctrl, gw_t, exp_t = xs
                admit = state[1]
                state, wait, _, _ = cell(state, w_t, is_ctrl, gw_t, exp_t)
                return state, (wait, admit)
            _, (wait, admit) = jax.lax.scan(
                step, carry0,
                (work32, q["ctrl"], q["gw_rows_bin"], q["exp_rows_bin"]))
            return wait, admit             # (T, F, SR), (T, F, P, G)

        def step(carry, xs):
            state, pb = carry[:-1], carry[-1]
            if beff_t is None:
                (w_t, is_ctrl, gw_t, exp_t, t), be = xs, None
            else:
                w_t, is_ctrl, gw_t, exp_t, t, be = xs
            admit = state[1]
            state, wait, offered, qhat = cell(
                state, w_t, is_ctrl, gw_t, exp_t)
            drop = jnp.maximum(offered - cap32, 0.0)
            pb = probe_write(pb, t, wait, w_t, drop, qhat=qhat,
                             admit=state[1], win=state[2], beff=be)
            return state + (pb,), (wait, admit)
        xs = (work32, q["ctrl"], q["gw_rows_bin"], q["exp_rows_bin"],
              jnp.arange(T))
        if beff_t is not None:
            xs = xs + (beff_t,)
        out_carry, (wait, admit) = jax.lax.scan(
            step, carry0 + (bufs,), xs)
        return wait, admit, out_carry[-1]

    def gather(wait_t, work32, gw_b, gw_fin, ex_b, ex_fin):
        # jnp port of FleetSim._gather: wait read from the time-major
        # trace, work from the row-major plane; overload =
        # wait + work > cap is the legacy dropped > 0 flag.
        f_idx = jnp.arange(F)[:, None, None, None]
        gw_rows = lead(q["gw_rows"])                  # (1|F, P, M, L)
        ex_rows = lead(q["ex_rows"])                  # (1|F, P, M, L, K)
        w_g = wait_t[gw_b, f_idx, gw_rows]
        gw_wait = jnp.where(gw_fin, w_g, 0.0).astype(f64)
        gw_over = gw_fin & ((w_g + work32[f_idx, gw_rows, gw_b]) > cap32)
        ex_b5, ex_f5 = ex_b[..., None], ex_fin[..., None]
        f_idx5 = f_idx[..., None]
        w_e = wait_t[ex_b5, f_idx5, ex_rows]
        ex_wait = jnp.where(ex_f5, w_e, 0.0).astype(f64)
        ex_over = ex_f5 & ((w_e + work32[f_idx5, ex_rows, ex_b5]) > cap32)
        return gw_wait, ex_wait.max(axis=4), gw_over, ex_over.any(axis=4)

    def finish_iter(work32, work_sum, gw_b, gw_fin, ex_b, ex_fin, c,
                    record=False, beff=None):
        # Scan + admission resolve + gather for one iteration whose
        # offered work (f32, row-major (F, SR, T)) is already binned;
        # only the scan input is transposed to time-major.  ``record``
        # (static) threads the probe rings through this iteration's
        # scan — set on the peeled *final* iteration only, so the probe
        # cost is paid once per launch, not once per iteration.  Under
        # batching ``work32`` is the *effective* (speedup-scaled) work —
        # gather overload stays consistent with the scan — while
        # ``work_sum`` stays the raw offered sum; ``beff`` feeds the
        # recorded batch-occupancy probe channel.
        work32_t = jnp.moveaxis(work32, 2, 0)             # (T, F, SR)
        beff_t = None
        if record and beff is not None:
            beff_t = jnp.moveaxis(beff.astype(f32), 2, 0)
        pb = c.get("probes")
        if adm_on:
            if not record:
                wait_t, admit = adm_scan(work32_t)
            else:
                wait_t, admit, pb = adm_scan(work32_t, pb, beff_t)
            # Monotone outer iteration (see run_legacy): the admit trace
            # accumulates as a running minimum so the shed set only grows.
            admit_floor = jnp.minimum(c["admit_floor"], admit)
            if q["att_bin"].ndim == 3:
                # Federation lanes: the attempt tables ride a leading F
                # axis (each member constellation's retry gateways and
                # arrival bins follow its own ground visibility), so
                # the admit trace is read per (lane, attempt, request).
                fi = jnp.arange(F)[:, None, None]
                adm = jnp.moveaxis(
                    admit_floor[q["att_bin"], fi, :, q["att_station"]],
                    3, 1)                                 # (F, P, A, R)
            else:
                adm = jnp.transpose(
                    admit_floor[q["att_bin"], :, :, q["att_station"]],
                    (2, 3, 0, 1))                         # (F, P, A, R)
            u = (q["adm_u"][:, None] if q["adm_u"].ndim == 3
                 else q["adm_u"][None, None])
            ok = (u < adm) & lead(q["att_feasible"])
            shed = ~ok.any(axis=2)                        # (F, P, R)
            retries = jnp.where(shed, 0, jnp.argmax(ok, axis=2))
            att_x = q["att_extra"] if fb else jnp.broadcast_to(
                q["att_extra"][None], (F,) + q["att_extra"].shape)
            ingress_extra = jnp.take_along_axis(
                att_x, retries[:, :, None, :], axis=2)[:, :, 0, :]
        else:
            if not record:
                wait_t = fleet_scan(work32_t)
            else:
                wait_t, pb = fleet_scan(work32_t, pb, beff_t)
            shed, retries = c["shed"], c["retries"]
            admit_floor = c["admit_floor"]
            ingress_extra = c["ingress_extra"]
        gw_wait, ex_max, gw_over, ex_over = gather(
            wait_t, work32, gw_b, gw_fin, ex_b, ex_fin)
        nxt = dict(gw_wait=gw_wait, ex_max=ex_max, gw_over=gw_over,
                   ex_over=ex_over, shed=shed, retries=retries,
                   admit_floor=admit_floor, ingress_extra=ingress_extra,
                   work_sum=work_sum)
        if want_wait:
            nxt["wait"] = wait_t
        if record:
            nxt["probes"] = pb
        return nxt

    def body(_, c, record=False):
        start_pref = q["arrival_s"][None, None, :] + c["ingress_extra"]
        layer_arr, exp_arr, _, _ = schedule(c["gw_wait"], c["ex_max"],
                                            start_pref)
        work, work_raw, beff = bin_work(layer_arr, exp_arr,
                                        c["shed"])       # (F, SR, T)
        gw_b, gw_fin = to_bins(layer_arr)
        ex_b, ex_fin = to_bins(exp_arr)
        return finish_iter(work.astype(f32), work_raw.sum(axis=2),
                           gw_b, gw_fin, ex_b, ex_fin, c, record=record,
                           beff=beff)

    n_gw = q["ttft0"].shape[-1] if adm_on else 1
    carry = dict(
        gw_wait=jnp.zeros((F, P, M, L)), ex_max=jnp.zeros((F, P, M, L)),
        gw_over=jnp.zeros((F, P, M, L), bool),
        ex_over=jnp.zeros((F, P, M, L), bool),
        shed=jnp.zeros((F, P, R), bool),
        retries=jnp.zeros((F, P, R), jnp.int64),
        admit_floor=jnp.ones((T, F, P, n_gw), jnp.float32),
        ingress_extra=(q["ingress_extra0"] + 0.0) if fb
        else jnp.broadcast_to(q["ingress_extra0"][None], (F, P, R)) + 0.0,
        work_sum=jnp.zeros((F, SR)),
    )
    if want_wait:
        carry["wait"] = jnp.zeros((T, F, SR), f32)
    # Peeled iteration 1: the zero-wait schedule is static, so its
    # offered work arrives pre-binned (host np.bincount) and its gather
    # bins are construction-time constants.  With probes on, the *last*
    # iteration is peeled too (its probe-recording scan is traced
    # separately), so ring writes happen exactly once per launch.
    if probes is None:
        carry = finish_iter(work0, work0_sum,
                            lead(q["gw_b0"]), lead(q["gw_fin0"]),
                            lead(q["ex_b0"]), lead(q["ex_fin0"]), carry)
        c = jax.lax.fori_loop(0, n_iter - 1, body, carry)
    elif n_iter == 1:
        carry["probes"] = pbuf
        # Peeled-final batching runs ship the host-computed iteration-1
        # occupancy (batch["beff0"]) for the probe channel; work0 itself
        # is already the host-computed effective plane.
        c = finish_iter(work0, work0_sum,
                        lead(q["gw_b0"]), lead(q["gw_fin0"]),
                        lead(q["ex_b0"]), lead(q["ex_fin0"]), carry,
                        record=True, beff=batch.get("beff0"))
    else:
        carry = finish_iter(work0, work0_sum,
                            lead(q["gw_b0"]), lead(q["gw_fin0"]),
                            lead(q["ex_b0"]), lead(q["ex_fin0"]), carry)
        c = jax.lax.fori_loop(0, n_iter - 2, body, carry)
        c["probes"] = pbuf
        c = body(0, c, record=True)
    # Fold the final gather into the schedule once more (see run_legacy).
    start_pref = q["arrival_s"][None, None, :] + c["ingress_extra"]
    _, _, tok_total, seg_incl = schedule(c["gw_wait"], c["ex_max"],
                                         start_pref)
    ttft = c["ingress_extra"] + tok_total[:, :, :R]
    out = dict(ttft=ttft, e2e=ttft + seg_incl[:, :, q["last_tok"]],
               tok_total=tok_total,
               tok_over=c["gw_over"].any(axis=3) | c["ex_over"].any(axis=3),
               shed=c["shed"], retries=c["retries"],
               work_sum=c["work_sum"])
    if want_wait:
        out["wait"] = c["wait"]
    if probes is not None:
        out["probes"] = c["probes"]
        out["probe_gw_wait"] = c["gw_wait"]
        out["probe_ex_wait"] = c["ex_max"]
    return out


def _fused_core(consts, chunks, work0, work0_sum, ttft_target, tpot_target,
                pbuf, batch, n_iter, n_bins, n_rows, adm_on, deposit_mode,
                want_wait, probes, batch_window):
    """Counting wrapper around :func:`_fleet_fixed_point` — the body the
    standalone jits below trace.  The trace counter lives here (not in
    the fixed point itself) so the joint-controller kernel, which embeds
    several fixed points in one program, still counts one trace per
    launch shape."""
    global FUSED_TRACE_COUNT
    FUSED_TRACE_COUNT += 1
    return _fleet_fixed_point(
        consts, chunks, work0, work0_sum, ttft_target, tpot_target, pbuf,
        batch, n_iter, n_bins, n_rows, adm_on, deposit_mode, want_wait,
        probes, batch_window)


#: The jitted fused fixed point.  Statics: (n_iter, n_bins, n_rows,
#: adm_on, deposit_mode, want_wait, probes, batch_window); everything else
#: rides the pytrees, so any fleet run with equal shapes — every rate of
#: a sweep, every re-placement decide/evaluate round — hits one compile
#: cache entry.  Probe-free launches pass ``probes=None`` and an empty
#: pbuf pytree, and batching-free launches an empty ``batch`` pytree
#: with ``batch_window=0``, so their traced computation is byte-identical
#: to the legacy kernel.
_fused_exec = jax.jit(_fused_core,
                      static_argnums=(8, 9, 10, 11, 12, 13, 14, 15))

#: Probed variant: identical statics, but the probe ring buffers
#: (positional arg 6) are donated so XLA updates them in place instead
#: of copying the rings once per scan step.
_fused_exec_probed = jax.jit(_fused_core,
                             static_argnums=(8, 9, 10, 11, 12, 13, 14, 15),
                             donate_argnums=(6,))


class _CtrlMeta(NamedTuple):
    """Static (hashable) configuration of the joint-controller kernel.

    One value per compile-relevant scalar of :func:`_ctrl_core`; grids
    that share a meta share one trace, which is what the
    ``FUSED_TRACE_COUNT`` acceptance pin counts.
    """

    n_iter: int          #: schedule<->queue fixed-point iterations
    n_bins: int          #: T, time bins
    n_rows: int          #: compact (plan, satellite) rows of the probe
    n_rows_sched: int    #: compact satellite rows of the schedule row
    n_cand: int          #: C, candidate-pool size
    n_slots: int         #: N_T, topology slots
    n_bounds: int        #: last decision boundary index (see replan.py)
    n_rounds: int        #: controller decide+evaluate rounds
    adm_on: bool         #: admission regime active
    deposit_mode: str    #: "pallas" | "segments" | "ref" (see _launch)
    mode_backlog: bool   #: backlog-inflated scoring (vs base-only)
    hysteresis: float    #: relative switching threshold
    ref_q: float         #: admission reference quantile (0 if adm off)
    decide_bins: tuple   #: per-boundary backlog observation bin
    n_mig_chunks: int    #: dt-chunks one migration transfer spans
    mig_bounds: tuple    #: (prev_slot, cur_slot, first_bin) per boundary


def _ctrl_core(consts, chunks, work0, work0_sum, ttft_target, tpot_target,
               cc, meta):
    """The joint control plane: probe -> decide -> evaluate in ONE launch.

    Embeds several :func:`_fleet_fixed_point` fixed points in a single
    device program, batched over a leading controller-grid axis F
    (cadence x migration-budget x admission-target cells):

    1. **probe** — the candidate pool's fleet fixed point (exactly the
       ``_fused_core`` computation ``FleetSim.run`` launches), whose
       backlog trace is the controller's observation *and* the shared
       qhat signal the admission scan reads;
    2. **decide** — the pinned re-placement law of
       ``repro.traffic.replan`` (backlog-inflated scores, hysteresis
       gate, migration-cost gate) as array ops over that trace, walking
       the slot boundaries with a per-cell cadence mask;
    3. **evaluate** — a second fixed point over the decided
       schedule row, whose consts are *gathers* of the candidate
       tables by the decided plan-per-slot (tokens of slot n traverse
       plan ``slot_plan[n]``), with the migration background load
       deposited from the decided switch pairs in the same pass.

    Backlog mode refines: rounds 2..n_rounds re-decide against the
    evaluation's own backlog and re-evaluate — the device always runs
    the full ``controller_iterations`` rounds where the host loop may
    break early on a fixed point, which is equivalent because the
    evaluation is a deterministic function of the slot plan.

    Every arithmetic step replicates the host controller bit-for-bit on
    CPU: the score penalty reproduces numpy's pairwise summation, the
    admission anchors reproduce ``np.quantile``'s interpolation, and the
    schedule row's chunk table is ordered event-major so each
    (row, bin) accumulates its float64 deposits in the exact order of a
    host-built evaluation simulator.

    Args:
        consts: The probe's device tables (plan-leading).
        chunks: The probe's all-active compacted chunk table, built at
            the deduplicated admission-cell width F_u (see the probe
            dedup note in the body).
        work0/work0_sum: Probe peeled-iteration planes (F_u-wide).
        ttft_target/tpot_target: (F,) margin-scaled admission targets
            (the evaluation fixed points still need per-cell targets).
        cc: Controller tables pytree (:meth:`FleetSim._ctrl_tables`
            plus per-grid arrays: base scores, decide mask, migration
            weights and priced byte matrix).
        meta: Static :class:`_CtrlMeta`.

    Returns:
        ``slot_plan`` (F, N_T), the decision ``telem`` pytree
        (scores/chosen/switched/mig_bytes over boundaries), and the
        kept outputs of the probe and schedule-row fixed points.
    """
    global FUSED_TRACE_COUNT
    FUSED_TRACE_COUNT += 1
    q = consts
    F = ttft_target.shape[0]
    C, T, SRs = meta.n_cand, meta.n_bins, meta.n_rows_sched
    P, M, L = q["eff_layer"].shape
    R = q["first_tok"].shape[0]
    f32, f64 = jnp.float32, jnp.float64
    f_i = jnp.arange(F)

    # The probe depends on the admission-target axis alone — cells that
    # share a (TTFT, TPOT) target share a probe fixed point.  The host
    # side deduplicated the targets (``probe_ttft``/``probe_tpot``,
    # width F_u <= F) and supplies the inverse map ``probe_gather``:
    # the probe runs F_u-wide and its outputs are gathered back to F,
    # bitwise identical to computing every duplicate (each cell's row
    # is an independent, deterministic batch lane).  A cadence x
    # migration-budget grid with one admission target probes ONCE.
    probe = _fleet_fixed_point(
        q, chunks, work0, work0_sum, cc["probe_ttft"], cc["probe_tpot"],
        {}, {}, meta.n_iter, T, meta.n_rows, meta.adm_on,
        meta.deposit_mode, True, None, 0)
    pg = cc["probe_gather"]
    probe = {k: (v[:, pg] if k == "wait" else v[pg])
             for k, v in probe.items()}

    def np_sum(x):
        # numpy pairwise-summation replica over the last axis (the host
        # score penalty sums float32 backlog slices with np.sum; the
        # parity pin needs the identical partial-sum tree).
        def pair(y, n):
            if n < 8:
                res = jnp.zeros(y.shape[:-1], y.dtype)
                for i in range(n):
                    res = res + y[..., i]
                return res
            if n <= 128:
                r = [y[..., j] for j in range(8)]
                i = 8
                while i + 8 <= n:
                    for j in range(8):
                        r[j] = r[j] + y[..., i + j]
                    i += 8
                res = ((r[0] + r[1]) + (r[2] + r[3])) \
                    + ((r[4] + r[5]) + (r[6] + r[7]))
                while i < n:
                    res = res + y[..., i]
                    i += 1
                return res
            n2 = (n // 2) - ((n // 2) % 8)
            return pair(y[..., :n2], n2) + pair(y[..., n2:], n - n2)
        return pair(x, x.shape[-1])

    zero_col = jnp.zeros((F, 1), f32)

    def penalty(wait_b, rows_gw, rows_ex):
        # replan.backlog_penalty_s: gateway backlog sum + per-layer max
        # expert backlog sum, read off one backlog snapshot.  A sentinel
        # row (== n_rows) indexes the appended zero column — the host's
        # expansion to all satellites reads 0.0 at compacted-out rows.
        w = jnp.concatenate([wait_b, zero_col], axis=1)
        g = w[f_i[:, None, None], rows_gw]                  # (F, C, L)
        e = w[f_i[:, None, None, None], rows_ex]            # (F, C, L, I)
        return (np_sum(g) + np_sum(e.max(axis=3))).astype(f64)

    def decide(wait, rows_gw_of, rows_ex_of):
        # The verbatim decide law of replan.build_replan_schedule,
        # vectorized over grid cells: per boundary k the cell's cadence
        # mask arbitrates whether the (hysteresis + migration-cost)
        # gated argmin replaces the incumbent.
        cur = jnp.zeros(F, dtype=jnp.int64)
        plan_cols, t_sc, t_cur, t_sw, t_mb = [], [], [], [], []
        for k in range(meta.n_bounds + 1):
            scores = jnp.broadcast_to(cc["base_scores"][k][None], (F, C))
            if meta.mode_backlog and k > 0:
                scores = scores + penalty(wait[meta.decide_bins[k]],
                                          rows_gw_of(cur), rows_ex_of(cur))
            best = jnp.argmin(scores, axis=1)
            if k == 0:
                nxt, switched, mb = best, jnp.zeros(F, bool), jnp.zeros(F)
            else:
                sc_cur = scores[f_i, cur]
                gain = sc_cur - scores[f_i, best]
                moved = cc["bytes_mat"][cur, best]
                gate = meta.hysteresis * sc_cur + moved * cc["mig_w"] / 1e6
                switched = (best != cur) & (gain > gate)
                nxt = jnp.where(switched, best, cur)
                mb = jnp.where(switched, moved, 0.0)
            dk = cc["decide_mask"][:, k]
            cur = jnp.where(dk, nxt, cur)
            plan_cols.append(cur)
            t_sc.append(scores)
            t_cur.append(cur)
            t_sw.append(switched & dk)
            t_mb.append(jnp.where(dk, mb, 0.0))
        cols = plan_cols + [cur] * (meta.n_slots - (meta.n_bounds + 1))
        telem = dict(scores=jnp.stack(t_sc, axis=1),
                     chosen=jnp.stack(t_cur, axis=1),
                     switched=jnp.stack(t_sw, axis=1),
                     mig_bytes=jnp.stack(t_mb, axis=1))
        return jnp.stack(cols, axis=1), telem

    def masked_quantile(vals, mask):
        # np.quantile (linear interpolation) over a masked last axis —
        # including numpy's _lerp asymmetry around t = 0.5, which the
        # bitwise admission-anchor parity needs.
        n = vals.shape[-1]
        s = jnp.sort(jnp.where(mask, vals, jnp.inf), axis=-1)
        nv = mask.sum(axis=-1)
        vi = meta.ref_q * (nv - 1).astype(f64)
        lo = jnp.clip(jnp.floor(vi), 0.0, None)
        t = vi - lo
        lo_i = lo.astype(jnp.int64)
        hi_i = jnp.minimum(lo_i + 1, jnp.maximum(nv - 1, 0))
        a = jnp.take_along_axis(s, jnp.clip(lo_i, 0, n - 1)[..., None],
                                axis=-1)[..., 0]
        b = jnp.take_along_axis(s, jnp.clip(hi_i, 0, n - 1)[..., None],
                                axis=-1)[..., 0]
        d = b - a
        out = jnp.where(t >= 0.5, b - d * (1.0 - t), a + d * t)
        return jnp.where(nv > 0, out, 0.0)

    mi = jnp.arange(M)[None]
    ri = jnp.arange(R)[None]

    def eval_consts(sp):
        # Schedule-row device tables: per-token / per-request gathers of
        # the candidate tables by the decided plan of the token's slot
        # (P axis = 1, F-leading — the fixed point's ``fb`` branch).
        pt = sp[:, cc["slot_tok"]]                          # (F, M)
        pr = pt[:, :R]
        eq = dict(dt=q["dt"], cap32=q["cap32"], dt32=q["dt32"],
                  gw_service=q["gw_service"], arrival_s=q["arrival_s"],
                  first_tok=q["first_tok"], tok_req=q["tok_req"],
                  last_tok=q["last_tok"],
                  eff_layer=q["eff_layer"][pt, mi][:, None],
                  tok_base=q["tok_base"][pt, mi][:, None],
                  ingress_extra0=q["ingress_extra0"][pr, ri][:, None],
                  gw_rows=cc["gw_srow"][pt, mi][:, None],
                  ex_rows=cc["ex_srow"][pt, mi][:, None],
                  gw_b0=q["gw_b0"][pt, mi][:, None],
                  gw_fin0=q["gw_fin0"][pt, mi][:, None],
                  ex_b0=q["ex_b0"][pt, mi][:, None],
                  ex_fin0=q["ex_fin0"][pt, mi][:, None])
        if meta.n_mig_chunks and meta.mig_bounds:
            # Migration background load of the decided switches: exact
            # sequential-sum tables per (incumbent, successor) pair,
            # deposited at each boundary's bins.
            plane = jnp.zeros((F, SRs, T))
            for prev_s, cur_s, b0 in meta.mig_bounds:
                pv = cc["mig_plane"][:, sp[:, prev_s], sp[:, cur_s]]
                for j in range(meta.n_mig_chunks):
                    plane = plane.at[:, :, min(b0 + j, T - 1)].add(pv[j])
            eq["mig_dense_f"] = plane
        if meta.adm_on:
            # Re-derive the schedule row's admission anchors (the
            # reference-quantile zero-load latencies) from the decided
            # per-request plan — the joint-controller face of
            # _build_admission_tables.
            G = q["ttft0"].shape[-1]
            ok = cc["adm_ok0"][pr, ri]
            bt = cc["adm_base_ttft"][pr, ri]
            overall = masked_quantile(bt, ok)
            selg = ok[:, None, :] & (cc["adm_station"][None, None, :]
                                     == jnp.arange(G)[None, :, None])
            per_g = masked_quantile(
                jnp.broadcast_to(bt[:, None], (F, G, R)), selg)
            ttft0 = jnp.where(selg.any(axis=2), per_g, overall[:, None])
            ni = jnp.arange(M - R)[None]
            pd = pt[:, R:]
            tpot0 = masked_quantile(cc["adm_dec_vals"][pd, ni],
                                    cc["adm_dec_ok"][pd, ni])
            pb = sp[:, cc["slot_of_bin"]]                   # (F, T)
            ti = jnp.arange(T)[:, None]
            eq.update(
                ttft0=ttft0[:, None].astype(f32),
                tpot0=tpot0[:, None].astype(f32),
                ctrl=q["ctrl"], increase=q["increase"],
                decrease=q["decrease"], admit_min=q["admit_min"],
                att_bin=q["att_bin"], att_station=q["att_station"],
                adm_u=q["adm_u"],
                gw_rows_bin=cc["gw_srow_bin"][ti, pb.T][:, :, None],
                exp_rows_bin=cc["exp_srow_bin"][ti, pb.T][:, :, None],
                att_feasible=jnp.transpose(
                    cc["att_feas_c"][pr, :, ri], (0, 2, 1))[:, None],
                att_extra=jnp.transpose(
                    cc["att_extra_c"][pr, :, ri], (0, 2, 1))[:, None])
            if "pid_kp" in q:
                # Per-plan gains are gated off by run_replan_grid, so
                # the schedule row runs at unit gain like every plan.
                eq.update(pid_kp=q["pid_kp"], pid_ki=q["pid_ki"],
                          pid_kd=q["pid_kd"],
                          pid_gain=jnp.ones((1,), jnp.float32))
        return eq

    n_gate = cc["ch_work"].shape[0]

    def eval_launch(sp):
        # The schedule row's fixed point: the probe's event-major chunk
        # table rides along gated per chunk by "is this chunk's plan the
        # decided plan of its request's slot" — multiplying by the 0/1
        # gate keeps deposits exact (interleaved zero adds are f64
        # no-ops), so the (row, bin) accumulation order matches a
        # host-built evaluation simulator bit for bit.
        eq = eval_consts(sp)
        gate = (sp[:, cc["ch_slot"]] == cc["ch_plan"][None]).astype(f64)
        ech = dict(
            src=(f_i[:, None] * (2 * M * L)
                 + cc["ch_local"][None]).reshape(-1),
            offs=jnp.broadcast_to(cc["ch_offs"][None],
                                  (F, n_gate)).reshape(-1),
            work=(cc["ch_work"][None] * gate).reshape(-1),
            fprow=(f_i[:, None] * SRs
                   + cc["ch_srow"][None]).astype(jnp.int32).reshape(-1))
        if meta.adm_on:
            ech["fpr"] = (f_i[:, None] * R
                          + cc["ch_req"][None]).reshape(-1)
        v0 = ((cc["ch_work"] * cc["ch_fin0"])[None] * gate).reshape(-1)
        bins0 = jnp.broadcast_to(cc["ch_bins0"][None],
                                 (F, n_gate)).reshape(-1)
        if meta.deposit_mode == "pallas":
            plane0 = _kernel_ops.deposit(
                ech["fprow"], bins0.astype(jnp.int32), v0.astype(f32),
                F * SRs, T).astype(f64).reshape(F, SRs, T)
        elif meta.deposit_mode == "segments":
            plane0 = _kernel_ops.deposit_segments(
                ech["fprow"], bins0, v0, F * SRs, T).reshape(F, SRs, T)
        else:
            flat0 = ech["fprow"].astype(jnp.int64) * T + bins0
            plane0 = jnp.zeros(F * SRs * T).at[flat0].add(
                v0, mode="promise_in_bounds").reshape(F, SRs, T)
        if "mig_dense_f" in eq:
            plane0 = plane0 + eq["mig_dense_f"]
        return _fleet_fixed_point(
            eq, ech, plane0.astype(f32), plane0.sum(axis=2),
            ttft_target, tpot_target, {}, {}, meta.n_iter, T, SRs,
            meta.adm_on, meta.deposit_mode, True, None, 0)

    # Round 1 decides against the probe's backlog (per incumbent row);
    # backlog-mode refinement rounds re-decide against the decided
    # schedule's own backlog (incumbent-independent maps).
    sp, telem = decide(probe["wait"],
                       lambda cur: cc["pen1_gw"][cur],
                       lambda cur: cc["pen1_ex"][cur])
    ev = eval_launch(sp)
    for _ in range(meta.n_rounds - 1):
        sp, telem = decide(ev["wait"],
                           lambda cur: cc["pen2_gw"][None],
                           lambda cur: cc["pen2_ex"][None])
        ev = eval_launch(sp)
    keep = ("ttft", "e2e", "tok_total", "tok_over", "shed", "retries",
            "work_sum")
    return dict(slot_plan=sp, telem=telem,
                probe={k: probe[k] for k in keep},
                sched={k: ev[k] for k in keep})


#: The jitted joint-controller kernel.  Exactly one trace per
#: (_CtrlMeta, pytree shape) — a whole cadence x migration-budget x
#: admission-target grid batches the leading axis of one launch.
_ctrl_exec = jax.jit(_ctrl_core, static_argnums=(7,))


# --------------------------------------------------------------------- #
# The fleet simulator
# --------------------------------------------------------------------- #


class FleetSim:
    """Request-level serving simulator for a sweep of placement plans
    *or* time-indexed :class:`~repro.core.schedule.PlanSchedule` entries
    (plain plans are wrapped into constant schedules, which reproduce
    the PR-2 static behavior bit-for-bit).

    Queue stations are keyed by **satellite id** — one FIFO work queue
    per satellite of the constellation (S = V).  Colocated experts share
    their satellite's queue by construction (the queue-theoretic face of
    Eq. 43), and a schedule that switches plans at a topology-slot
    boundary points new deposits at the incoming plan's satellites while
    the outgoing plan's backlog drains where it sits — the mechanism
    that makes live re-placement pay.  The weight bytes a switch moves
    (:meth:`~repro.core.schedule.PlanSchedule.migration_edges`, the
    ``distributed.elastic`` accounting) occupy each moved expert's
    destination-satellite queue as background load.

    Construction does all the rate-independent precompute: one batched
    engine pass over R prefill macro-tokens + N decode tokens (shared
    slots/draws across plans — common random numbers), the zero-load
    per-layer costs, every queue event's (plan, station, request, work)
    and the chunk layout.  ``run`` then iterates the schedule/queue
    fixed point for any request-activity mask — the cheap inner call of
    a saturation sweep.

    When ``qcfg.admission`` enables the AIMD policy, construction also
    precomputes the gateway-retry attempt tables (per attempt: target
    gateway, terrestrial forward + backoff + uplink + ingress-offset
    latency, feasibility) and the controller's zero-load TTFT/TPOT
    references; ``run`` then resolves per-request admission between
    fixed-point iterations from the controller trace the fleet scan
    emits (see :mod:`repro.traffic.admission` for the law).
    """

    def __init__(
        self,
        plans: list,
        topo: TopologySample,
        activation: ActivationModel,
        workload: MoEWorkload,
        compute: ComputeConfig,
        requests: RequestBatch,
        rng: np.random.Generator,
        qcfg: QueueConfig = QueueConfig(),
        ground: GroundSegment | None = None,
        ctx_len: int = 1024,
        eta: float = 1.0,
        include_lm_head: bool = True,
        batch: ScheduleBatch | None = None,
        min_bins: int = 0,
        service_model=None,
        probes: ProbeConfig | None = None,
        batching: BatchingConfig | None = None,
    ):
        """Build the simulator and run every rate-independent precompute.

        Args:
            plans: Sweep entries (P of them): plain
                :class:`~repro.core.placement.PlacementPlan` /
                :class:`~repro.core.placement.MultiExpertPlan` (held for
                the whole horizon) and/or time-indexed
                :class:`~repro.core.schedule.PlanSchedule` rows, mixed
                freely.
            topo: Sampled time-varying topology the engine pass uses.
            activation: Conditional-Poisson expert-activation model.
            workload: Per-component FLOP model of the served MoE.
            compute: FLOPs -> seconds conversion for onboard compute.
            requests: The request trace (R requests, sorted arrivals).
            rng: Source of the engine's expert draws and the admission
                uniforms (consumed at construction; runs are replayable).
            qcfg: Queueing/admission parameters.
            ground: Optional ground segment; enables uplink + ingress
                accounting and (under AIMD admission) gateway retry.
            ctx_len: Attention context length for gateway service time.
            eta: Eq. 43 compute-sharing efficiency for multi-expert plans.
            include_lm_head: Account lm-head service on the last gateway.
            batch: Optional prebuilt :class:`~repro.core.ScheduleBatch`
                to reuse the deduped Dijkstra table across simulators.
            min_bins: Floor on the time-bin count T.  The re-placement
                loop pins consecutive decide/evaluate rounds to one T so
                every round's fleet run reuses the fused fixed point's
                compile cache (a longer natural horizon still wins).
            service_model: Eq. 43 service-time source — ``None`` /
                ``"analytic"`` keeps the FLOP-count constants
                (bit-identical to the pre-calibration simulator), a
                calibrated :class:`~repro.core.calibration.ServiceModel`
                activates kernel-calibrated per-expert / per-satellite
                service and batch-size-dependent decode gateway rates
                (weight reads amortized over the estimated in-flight
                decode batch, read off the decode-attention roofline).
            probes: Optional :class:`~repro.obs.probes.ProbeConfig`.
                When set, every launch writes on-device telemetry rings
                (per-bin backlog / offered work / drops per satellite,
                plus the AIMD cell state under admission) that land in
                :attr:`last_probes` as a
                :class:`~repro.obs.probes.ProbeRecord`.  ``None`` (the
                default) keeps the fused kernel's traced computation
                bit-identical to the probe-free simulator.
            batching: Optional
                :class:`~repro.traffic.batching.BatchingConfig`.  When
                set, per-(plan, satellite) decode queues drain in
                batches of up to ``b_max`` per time bin with service
                time ``B / decode_rate(B)`` and KV-slot occupancy
                bounding the admissible batch (deposit-time scaling —
                see :mod:`repro.traffic.batching`).  ``None`` (the
                default) keeps every execution path bit-identical to
                the FIFO simulator, and so does ``b_max=1``.
        """
        self.plans = list(plans)
        self.schedules = [as_schedule(p, topo.n_slots) for p in self.plans]
        self.requests = requests
        self.qcfg = qcfg
        self.activation = activation
        # Stashed for the joint control plane (``run(replan=...)`` /
        # :meth:`run_replan_grid`): the base-score sweep re-enters the
        # batched plan engine at decision time.
        self.topo = topo
        self.workload = workload
        self.compute = compute

        P = len(self.schedules)
        R = requests.n_requests
        if R == 0:
            raise ValueError("empty request trace")
        L = activation.n_layers
        n_exp = activation.n_experts
        K = activation.top_k
        N = requests.total_decode_tokens
        M = R + N
        self.n_plans, self.n_requests = P, R
        self.n_decode_tokens, self.n_tokens = N, M
        # One FIFO work queue per satellite of the constellation.
        self.n_layers, self.n_stations = L, topo.n_sats
        self.n_topo_slots = topo.n_slots

        tok_req = requests.request_of_token()                    # (N,)
        self.tok_req = tok_req

        # --- slots from wall-clock time (one slot per request: request
        # lifetimes are seconds, a topology slot is minutes) ---------------
        slot_r = slot_of_time(requests.arrival_s, qcfg.slot_period_s,
                              topo.n_slots)
        self.slots = np.concatenate([slot_r, slot_r[tok_req]])   # (M,)

        # --- ingress mapping ----------------------------------------------
        if batch is None:
            batch = ScheduleBatch.from_schedules(self.schedules, topo,
                                                 eta=eta)
        self.batch = batch
        if ground is not None:
            ing_sat, uplink = ground.for_requests(slot_r, requests.station)
            reachable = ing_sat >= 0
            ing_off = schedule_ingress_offsets(
                batch, slot_r, np.where(reachable, ing_sat, 0))
            ing_off = np.where(reachable[None, :], ing_off, np.inf)
        else:
            uplink = np.zeros(R)
            ing_off = np.zeros((P, R))
        self.fail_ingress = ~np.isfinite(ing_off)                 # (P, R)
        self.ingress_extra = uplink[None, :] + np.where(
            self.fail_ingress, 0.0, ing_off)                      # (P, R)

        # --- engine pass: base (zero-load) per-token latencies -------------
        svc = resolve_service_model(service_model, workload, compute)
        self.service_model = svc
        # Continuous-batching statics: the padded speedup table (read
        # off the service model's batch-size-dependent decode rates),
        # the KV-bounded batch cap, and the occupancy window in bins.
        self.batching = batching
        if batching is not None:
            self._batch_table = batching.resolve_table(svc, ctx_len)
            self._batch_cap = float(batching.b_cap)
            self._batch_window = batching.window_bins(qcfg.dt_s)
        else:
            self._batch_table = None
            self._batch_cap = 0.0
            self._batch_window = 0
        draws = np.stack([activation.sample(layer, rng, M)
                          for layer in range(L)])                 # (L, M, K)
        self.draws = draws
        self.engine_results = evaluate_schedules(
            self.schedules, topo, activation, workload, compute, rng,
            n_tokens=M, ctx_len=ctx_len, include_lm_head=include_lm_head,
            eta=eta, batch=batch, slots=self.slots, draws=draws,
            service_model=svc)
        token_lat = np.stack(
            [r.token_latency_s for r in self.engine_results])     # (P, M)
        layer_lat = np.stack(
            [r.layer_latency_s for r in self.engine_results])     # (P, M, L)

        # Undeliverable tokens (unreachable satellite in that slot) fail
        # the whole request; zero them so the segmented cumsums of the
        # *other* requests sharing the token axis stay finite.
        self.nan_tok = ~np.isfinite(token_lat)
        token_lat = np.where(self.nan_tok, 0.0, token_lat)
        layer_lat = np.where(np.isfinite(layer_lat), layer_lat, 0.0)

        t_gateway = svc.gateway_s(ctx_len)
        t_expert = svc.expert_scalar
        t_head = svc.head_s if include_lm_head else 0.0
        self.t_gateway, self.t_expert = t_gateway, t_expert

        # --- zero-load per-layer costs -------------------------------------
        # Prefill macro-token: the engine token plus, per layer, the
        # incremental pipelined compute of the remaining prompt tokens
        # (the batch shares the network hops; experts each absorb a K/I
        # share of the FFN work in parallel).
        incr_layer = t_gateway + t_expert * K / n_exp
        extra_layer = (requests.prompt_len - 1).astype(np.float64) \
            * incr_layer                                          # (R,)

        if svc.per_satellite:
            # Batch-amortized gateway service (calibrated mode): estimate
            # each request's in-flight decode concurrency from the sorted
            # arrivals and the zero-load token latency, then read the
            # per-token decode service off the decode-attention roofline
            # at that batch size; a prefill amortizes the gateway weight
            # reads over its own prompt batch.
            dec_lat = np.where(self.nan_tok[:, R:], np.nan, token_lat[:, R:])
            with np.errstate(invalid="ignore"):
                mean_tok = float(np.nanmean(dec_lat)) if N else 0.0
            if not np.isfinite(mean_tok) or mean_tok <= 0.0:
                mean_tok = L * t_gateway
            dur = requests.decode_len.astype(np.float64) * mean_tok
            arr = requests.arrival_s.astype(np.float64)
            started = np.searchsorted(arr, arr, side="right")
            ended = np.searchsorted(np.sort(arr + dur), arr, side="right")
            conc = np.maximum(started - ended, 1)                 # (R,)
            self.decode_batch_est = conc
            pre_gw = requests.prompt_len.astype(np.float64) \
                * svc.gateway_s(ctx_len, batch=requests.prompt_len)
            dec_gw = svc.gateway_s(ctx_len, batch=conc)[tok_req]
            self.gw_service = np.concatenate([pre_gw, dec_gw])    # (M,)
        else:
            self.decode_batch_est = None
            self.gw_service = np.concatenate([
                requests.prompt_len.astype(np.float64) * t_gateway,
                np.full(N, t_gateway),
            ])                                                    # (M,)
        self.eff_layer = layer_lat.copy()                         # (P, M, L)
        self.eff_layer[:, :R, :] += extra_layer[None, :, None]
        self.tok_base = token_lat.copy()                          # (P, M)
        self.tok_base[:, :R] += L * extra_layer[None, :]
        self.start_pref = requests.arrival_s[None, :] \
            + self.ingress_extra                                  # (P, R)
        self.first_tok = np.cumsum(requests.decode_len) \
            - requests.decode_len                                 # (R,)

        # --- queue events: (plan, station, request, work) ------------------
        # Stations are satellites: each token's deposits land on the
        # satellites its slot's plan routes it through (the slot -> plan
        # gather), so colocated experts share their satellite's queue
        # (Eq. 43) and a mid-horizon plan switch redirects new deposits
        # while the old plan's backlog drains in place.
        self.gateways_slot = batch.gateways_by_slot()         # (P, N_T, L)
        self.expert_sats_slot = batch.expert_sats_by_slot()   # (P,N_T,L,I)
        eta_slot = batch.eta_by_slot()                        # (P, N_T)
        gw_tok = self.gateways_slot[:, self.slots]            # (P, M, L)
        sats_tok = self.expert_sats_slot[:, self.slots]       # (P, M, L, I)
        eta_tok = eta_slot[:, self.slots]                     # (P, M)

        # Gateway work: every token visits every gateway satellite of its
        # slot's plan; lm-head work on the last gateway.
        gw_station = gw_tok
        gw_work = np.broadcast_to(self.gw_service[None, :, None],
                                  (P, M, L)).copy()
        gw_work[:, :, L - 1] += t_head
        gw_req = np.concatenate([np.arange(R), tok_req])          # (M,)

        # Decode expert work: the engine's own draws, scattered onto the
        # drawn expert's satellite; colocation multiplies the deposited
        # work (the Eq. 43 q factor) and eta scales the shared-compute
        # efficiency.
        draws_mlk = np.moveaxis(draws, 0, 1)                      # (M, L, K)
        exp_sat_tok = np.take_along_axis(
            sats_tok, draws_mlk[None], axis=3)                    # (P,M,L,K)
        dec_exp_station = exp_sat_tok[:, R:]                      # (P,N,L,K)
        probs = activation.all_probs()                            # (L, I)
        if svc.per_satellite:
            # Calibrated deposits: each drawn expert's own service
            # seconds, scaled by the hosting satellite's speed — the
            # queue-theoretic face of the calibrated Eq. 43 term.
            exp_sec = np.asarray(svc.expert_s(), dtype=np.float64)  # (I,)
            inv_sp = np.asarray(svc.inv_speed(topo.n_sats),
                                dtype=np.float64)                 # (V,)
            dec_exp_work = (exp_sec[draws_mlk[R:]][None]
                            * inv_sp[dec_exp_station]
                            / eta_tok[:, R:, None, None])
            pre_exp_station = sats_tok[:, :R]                     # (P,R,L,I)
            pre_exp_work = (requests.prompt_len[None, :, None, None]
                            * probs[None, None, :, :]
                            * exp_sec[None, None, None, :]
                            * inv_sp[pre_exp_station]
                            / eta_tok[:, :R, None, None])
        else:
            dec_exp_work = np.broadcast_to(
                (t_expert / eta_tok[:, R:])[..., None, None],
                dec_exp_station.shape)

            # Prefill expert work: the whole prompt hits every expert of
            # the layer in proportion to its activation probability
            # (fluid split of the batch), deposited at the prefill
            # token's expert visit.
            pre_exp_station = sats_tok[:, :R]                     # (P,R,L,I)
            pre_exp_work = np.broadcast_to(
                requests.prompt_len[None, :, None, None]
                * probs[None, None, :, :] * t_expert
                / eta_tok[:, :R, None, None], (P, R, L, n_exp))

        ev_station = np.concatenate([
            gw_station.reshape(P, -1),
            dec_exp_station.reshape(P, -1),
            pre_exp_station.reshape(P, -1),
        ], axis=1)                                                # (P, E)
        ev_work = np.concatenate([
            gw_work.reshape(P, -1),
            dec_exp_work.reshape(P, -1),
            pre_exp_work.reshape(P, -1),
        ], axis=1)                                                # (P, E)
        ev_req = np.concatenate([
            np.broadcast_to(gw_req[:, None], (M, L)).ravel(),
            np.broadcast_to(tok_req[:, None, None], (N, L, K)).ravel(),
            np.broadcast_to(np.arange(R)[:, None, None],
                            (R, L, n_exp)).ravel(),
        ])                                                        # (E,)

        # Wait-gather stations: per (plan, token, layer) the gateway and
        # the K expert branches (max over branches joins the layer
        # critical path, mirroring the engine's max over experts).
        self.gather_gw_station = gw_station                       # (P, M, L)
        self.gather_exp_station = exp_sat_tok                     # (P,M,L,K)

        # Chunked service (continuous-batching semantics): a deposit
        # larger than one bin of capacity is spread over consecutive
        # bins at the service rate, so a long prefill does not
        # head-of-line-block every token behind one bin.  The chunk
        # layout depends only on work, so it is precomputed; per run
        # only the chunk *bins* are recomputed from the schedule.
        dt = qcfg.dt_s
        w_flat = ev_work.ravel()
        n_ch = np.maximum(np.ceil(w_flat / dt).astype(np.int64), 1)
        self._rep = np.repeat(np.arange(w_flat.size), n_ch)
        self._offs = np.arange(self._rep.size) \
            - np.repeat(np.cumsum(n_ch) - n_ch, n_ch)
        self.ev_chunk_work = np.minimum(w_flat[self._rep]
                                        - self._offs * dt, dt)
        self.ev_chunk_station = ev_station.ravel()[self._rep]
        self.ev_chunk_plan = np.broadcast_to(
            np.arange(P)[:, None], ev_work.shape).ravel()[self._rep]
        self.ev_chunk_req = np.broadcast_to(
            ev_req[None, :], ev_work.shape).ravel()[self._rep]
        self._n_events = ev_work.size

        # Fused-path gather indices: each chunk reads its event's arrival
        # time from the flattened [layer_arr | exp_arr] pair, so the
        # device fixed point rebuilds no event concatenations.  The block
        # order mirrors the ev_* concatenation above exactly.
        p_i = np.arange(P)[:, None, None]
        m_i = np.arange(M)[None, :, None]
        l_i = np.arange(L)[None, None, :]
        gw_src = (p_i * M + m_i) * L + l_i                        # (P, M, L)
        exp_src = P * M * L + gw_src                              # exp_arr
        ev_src = np.concatenate([
            gw_src.reshape(P, -1),
            np.broadcast_to(exp_src[:, R:, :, None],
                            (P, N, L, K)).reshape(P, -1),
            np.broadcast_to(exp_src[:, :R, :, None],
                            (P, R, L, n_exp)).reshape(P, -1),
        ], axis=1).ravel()
        self._chunk_src = ev_src[self._rep]
        self._chunk_row = self.ev_chunk_plan * self.n_stations \
            + self.ev_chunk_station
        self._chunk_pr = self.ev_chunk_plan * R + self.ev_chunk_req

        if batching is not None:
            # Continuous-batching chunk channels.  Decode-side events —
            # decode-token gateway visits and the decode expert block —
            # carry their work in ``wdec`` (the batchable subset the
            # speedup scales) and one fractional token visit per chunk
            # in ``cntw`` (a chunk holds work/ev_work of its event's
            # visit, so each decode event deposits exactly one occupancy
            # unit; a satellite hosting several layers of one token
            # counts that token once per visit).  Prefill blocks batch
            # over their own prompt already and count zero.
            ev_dec = np.concatenate([
                np.broadcast_to((np.arange(M) >= R)[:, None],
                                (M, L)).ravel(),
                np.ones(N * L * K, dtype=bool),
                np.zeros(R * L * n_exp, dtype=bool),
            ]).astype(np.float64)                                 # (E,)
            dec_ch = np.broadcast_to(ev_dec[None, :],
                                     ev_work.shape).ravel()[self._rep]
            wf = w_flat[self._rep]
            self._chunk_wdec = self.ev_chunk_work * dec_ch
            self._chunk_cntw = np.where(
                wf > 0.0,
                self.ev_chunk_work / np.where(wf > 0.0, wf, 1.0),
                0.0) * dec_ch
        #: Lazily-built device-resident precompute (see _device_tables).
        self._dev: dict | None = None
        #: Lazily-built joint-control-plane precompute (_ctrl_tables).
        self._ctrl: dict | None = None
        #: Deposit implementation: "auto" (Pallas on TPU, jnp scatter-add
        #: reference elsewhere), "segments" (row-bucketed segment_sum,
        #: bitwise-identical to "ref"), "ref", or "pallas".
        self.deposit_impl = "auto"

        # --- time bins (fixed across runs so the scan compiles once) ------
        start_dec0, _, c00 = self._chain(self.tok_base, self.start_pref)
        end0 = start_dec0 + self.tok_base[:, R:]
        horizon = max(float(requests.arrival_s.max()),
                      float(np.where(np.isfinite(end0), end0, 0.0).max()),
                      float(np.where(np.isfinite(c00), c00, 0.0).max()))
        self.n_bins = max(
            int(np.ceil((horizon + qcfg.tail_s) / qcfg.dt_s)) + 1,
            int(min_bins))
        if self.n_bins > 2_000_000:
            raise ValueError(
                f"{self.n_bins} time bins — raise dt_s or shrink the horizon")

        # --- migration background load (schedule switches) -----------------
        self._build_migration_load()

        # --- admission controller precompute ------------------------------
        acfg = qcfg.admission
        self.admission_on = acfg is not None \
            and acfg.policy in ("aimd", "pid")
        if self.admission_on:
            if acfg.policy == "pid" and acfg.gain_scale is not None \
                    and len(acfg.gain_scale) != len(self.schedules):
                raise ValueError(
                    f"gain_scale has {len(acfg.gain_scale)} entries for "
                    f"{len(self.schedules)} plans")
            self._build_admission_tables(acfg, ground, slot_r, rng)

        # --- fused-path row compaction + static tables --------------------
        self._build_row_map()
        self._build_fused_tables()

        # Filled by ``run``: (plan, satellite, bin) backlog of the last
        # fleet scan (the re-placement controller's observation).
        self.last_wait: np.ndarray | None = None
        # Telemetry: filled by every launch when ``probes`` is set.
        self.probes = probes
        self.last_probes: "ProbeRecord | None" = None

    # ----------------------------------------------------------------- #

    def _build_migration_load(self) -> None:
        """Precompute the background work a schedule's plan switches
        deposit on the fleet.

        Every slot boundary the wall-clock horizon crosses is checked
        against each row's :class:`~repro.core.schedule.PlanSchedule`;
        per moved expert (the ``distributed.elastic`` diff rule via
        :meth:`~repro.core.schedule.PlanSchedule.migrations_over`) the
        weight transfer occupies the *destination* satellite's queue for
        ``bytes * 8 / migration_rate_gbps`` seconds, chunked into dt
        bins from the boundary — arriving tokens queue behind the
        weights being installed.  Constant schedules deposit nothing, so
        the static path is untouched bit-for-bit.
        """
        qcfg = self.qcfg
        dt, T, S = qcfg.dt_s, self.n_bins, self.n_stations
        sec_per_expert = (qcfg.migration_bytes_per_expert * 8.0
                          / (qcfg.migration_rate_gbps * 1e9))
        flat_parts: list[np.ndarray] = []
        work_parts: list[np.ndarray] = []
        self.migration_bytes = np.zeros(self.n_plans)
        for p, sched in enumerate(self.schedules):
            for t_b, mig in sched.migrations_over(
                    T * dt, qcfg.slot_period_s,
                    qcfg.migration_bytes_per_expert):
                self.migration_bytes[p] += mig.bytes_moved
                if mig.n_moved == 0 or sec_per_expert <= 0.0:
                    continue
                n_ch = max(int(np.ceil(sec_per_expert / dt)), 1)
                bins = np.minimum(int(t_b / dt) + np.arange(n_ch), T - 1)
                w = np.minimum(sec_per_expert - np.arange(n_ch) * dt, dt)
                fl = ((p * S + mig.new_sats[:, None]) * T
                      + bins[None, :]).ravel()
                flat_parts.append(fl)
                work_parts.append(np.broadcast_to(
                    w[None, :], (mig.n_moved, n_ch)).ravel())
        self._mig_flat = (np.concatenate(flat_parts) if flat_parts
                          else np.empty(0, dtype=np.int64))
        self._mig_work = (np.concatenate(work_parts) if work_parts
                          else np.empty(0, dtype=np.float64))

    # ----------------------------------------------------------------- #

    def _build_admission_tables(self, acfg: AdmissionConfig,
                                ground: GroundSegment | None,
                                slot_r: np.ndarray,
                                rng: np.random.Generator) -> None:
        """Precompute the gateway-retry attempt tables and the AIMD
        controller's zero-load references.

        Per attempt a (0 = the original gateway, a >= 1 = the a-th best
        alternative gateway from :meth:`GroundSegment.retry_stations`):
        target gateway, total ingress latency (a * backoff + terrestrial
        forward + uplink + ingress hop) and per-plan feasibility.  An
        alternate gateway enters through the first rank of its
        ranked-visibility table whose ingress route exists for the plan
        in that slot (deeper ranks cover an occluded or unroutable best
        satellite).  When no a-th alternative exists — no ground
        segment, or fewer visible gateways than retries — attempt a is a
        same-gateway backoff retry: the origin is re-attempted after the
        backoff, drawing against the (time-varying) admit state of a
        later bin.  Retries happen within the arrival's topology slot
        (backoff << slot period).
        """
        req = self.requests
        P, R = self.n_plans, self.n_requests
        A = acfg.n_attempts
        self.n_gw_stations = ground.n_stations if ground is not None else 1

        # Without a ground segment there is a single logical gateway.
        station = req.station if ground is not None \
            else np.zeros(R, dtype=np.int64)
        st_att = np.tile(station, (A, 1))                         # (A, R)
        alt_ok = np.zeros((A, R), dtype=bool)
        alt_ok[0] = True
        if ground is not None and acfg.max_retries > 0:
            alts = ground.retry_stations(slot_r, req.station,
                                         acfg.max_retries)        # (R, n_alt)
            n_alt = alts.shape[1]
            for a in range(1, min(A, n_alt + 1)):
                st_att[a] = alts[:, a - 1]
                alt_ok[a] = True

        extra = np.empty((A, P, R))
        feas = np.zeros((A, P, R), dtype=bool)
        extra[0] = self.ingress_extra
        feas[0] = ~self.fail_ingress
        for a in range(1, A):
            if ground is None or not alt_ok[a].any():
                # Same-gateway backoff retry (see docstring).
                extra[a] = self.ingress_extra + a * acfg.retry_backoff_s
                feas[a] = feas[0]
                continue
            gdelay = ground.ground_delay_s[req.station, st_att[a]]
            # Ranked-visibility fallback: per plan, the first rank of
            # the alternate gateway's satellite ranking with a finite
            # ingress route.
            ing_r = ground.ingress_ranked[slot_r, st_att[a]]      # (R, K)
            up_r = ground.uplink_ranked_s[slot_r, st_att[a]]      # (R, K)
            best = np.zeros((P, R))
            best_ok = np.zeros((P, R), dtype=bool)
            for k in range(ground.n_ranked):
                reachable = ing_r[:, k] >= 0
                off = schedule_ingress_offsets(
                    self.batch, slot_r, np.where(reachable, ing_r[:, k], 0))
                ok = reachable[None, :] & np.isfinite(off)
                take = ok & ~best_ok
                best = np.where(take, up_r[None, :, k] + off, best)
                best_ok |= ok
            extra[a] = (a * acfg.retry_backoff_s + gdelay)[None, :] \
                + np.where(best_ok, best, 0.0)
            feas[a] = best_ok & alt_ok[a][None, :]
        self._att_station = st_att
        self._att_extra = extra
        self._att_feasible = feas
        # Attempt a is evaluated at the gateway it targets, after the
        # backoff + terrestrial forward but before the uplink.
        t_att = req.arrival_s[None, :] + np.arange(A)[:, None] \
            * acfg.retry_backoff_s
        if ground is not None:
            t_att = t_att + ground.ground_delay_s[req.station, st_att]
        self._att_bin = np.clip((t_att / self.qcfg.dt_s).astype(np.int64),
                                0, self.n_bins - 1)
        # Common random numbers: one uniform per (attempt, request),
        # shared by every plan and every run() call.
        self._adm_u = rng.random((A, R))

        # Zero-load controller references (see admission module
        # docstring): tail anchors at the configured reference quantile.
        base_ttft = self.ingress_extra + self.tok_base[:, :R]     # (P, R)
        ok = feas[0] & ~_segment_any(self.nan_tok[:, R:], self.tok_req, R) \
            & ~self.nan_tok[:, :R]
        self._adm_ttft0 = _station_quantile(
            base_ttft, ok, station, self.n_gw_stations,
            acfg.reference_quantile)                              # (P, G)
        dec_ok = np.isfinite(self.tok_base[:, R:]) & ~self.nan_tok[:, R:]
        self._adm_tpot0 = np.array([
            np.quantile(self.tok_base[i, R:][dec_ok[i]],
                        acfg.reference_quantile)
            if dec_ok[i].any() else 0.0 for i in range(P)])        # (P,)
        # Stashed for the fused control plane: the schedule row's
        # admission anchors are re-derived on device from exactly these
        # masked value tables (gathered per decided plan).
        self._adm_station = station
        self._adm_ok0 = ok
        self._adm_base_ttft = base_ttft
        self._adm_dec_ok = dec_ok

        # Slot-dependent critical-path stations for the in-scan
        # controller: per time bin, the bin's topology slot selects each
        # plan's gateway chain and expert satellites — the admission
        # law's qhat follows the schedule through every plan switch.
        slot_of_bin = slot_of_time(np.arange(self.n_bins) * self.qcfg.dt_s,
                                   self.qcfg.slot_period_s,
                                   self.n_topo_slots)
        self._adm_slot_of_bin = slot_of_bin
        self._adm_gw_idx = np.ascontiguousarray(np.moveaxis(
            self.gateways_slot[:, slot_of_bin], 1, 0)).astype(np.int32)
        self._adm_exp_idx = np.ascontiguousarray(np.moveaxis(
            self.expert_sats_slot[:, slot_of_bin], 1, 0)).reshape(
                self.n_bins, P, -1).astype(np.int32)

    # ----------------------------------------------------------------- #

    def _build_row_map(self) -> None:
        """Compact the (plan, satellite) queue rows the fused path keeps
        dense.

        Only rows that can ever receive a deposit (chunk targets,
        migration destinations) or be read (wait gathers, the admission
        law's per-bin station maps) matter; every other station carries
        exactly zero backlog in both paths, so dropping it from the
        device tensors is exact.  The map scales the fused kernel with
        the *plans'* footprint instead of the constellation size.
        """
        P, S, T = self.n_plans, self.n_stations, self.n_bins
        p_idx = np.arange(P)[:, None, None]
        gw_rows = p_idx * S + self.gather_gw_station              # (P,M,L)
        ex_rows = p_idx[..., None] * S + self.gather_exp_station
        used = [self._chunk_row, gw_rows.ravel(), ex_rows.ravel()]
        if self._mig_flat.size:
            used.append(self._mig_flat // T)
        if self.admission_on:
            pr = np.arange(P, dtype=np.int64)[None, :, None] * S
            used.append((pr + self._adm_gw_idx).ravel())
            used.append((pr + self._adm_exp_idx).ravel())
        rows = np.unique(np.concatenate(used))
        inv = np.full(P * S, -1, dtype=np.int64)
        inv[rows] = np.arange(rows.size)
        self._active_rows = rows
        self._row_inv = inv
        self.n_rows = int(rows.size)
        self._chunk_rowc = inv[self._chunk_row].astype(np.int32)
        self._gw_rowc = inv[gw_rows]                              # (P,M,L)
        self._ex_rowc = inv[ex_rows]                              # (P,M,L,K)
        if self.admission_on:
            self._adm_gw_rowc = inv[pr + self._adm_gw_idx] \
                .astype(np.int32)                                 # (T,P,L)
            self._adm_exp_rowc = inv[pr + self._adm_exp_idx] \
                .astype(np.int32)                                 # (T,P,LI)

    def _expand_rows(self, arr: np.ndarray) -> np.ndarray:
        """Scatter a compact-row array (..., n_rows) back to (..., P, S)."""
        full = np.zeros(arr.shape[:-1] + (self.n_plans * self.n_stations,),
                        dtype=arr.dtype)
        full[..., self._active_rows] = arr
        return full.reshape(arr.shape[:-1]
                            + (self.n_plans, self.n_stations))

    def _build_fused_tables(self) -> None:
        """Static precompute for the fused path's peeled first iteration
        and row-grouped deposits.

        The first fixed-point iteration always runs on the zero-wait
        schedule, so its event times — hence its chunk bins and gather
        bins — are construction-time constants; ``_launch`` turns them
        into the iteration-1 work plane with one host ``np.bincount``.
        The chunk tables are also re-ordered by compact row (stable
        sort), so the device scatter of later iterations walks the
        (row, T) plane row-major instead of hopping across it.
        """
        P, M, L = self.n_plans, self.n_tokens, self.n_layers
        z = np.zeros((P, M, L))
        layer0, exp0, *_ = self._schedule(z, z, self.start_pref)
        self._gw_b0, self._gw_fin0 = self._to_bins(layer0)
        self._ex_b0, self._ex_fin0 = self._to_bins(exp0)
        base0, fin0 = self._to_bins(self._event_times(layer0, exp0))
        bins0 = np.minimum(base0[self._rep] + self._offs, self.n_bins - 1)
        # Event-ordered copies (pre row-sort) — the joint control plane's
        # schedule-row chunk table is assembled in event order so the
        # per-(row, bin) f64 accumulation order matches a host-built
        # evaluation simulator exactly.
        self._chunk_bins0 = bins0
        self._chunk_fin0 = fin0[self._rep]
        perm = np.argsort(self._chunk_rowc, kind="stable")
        self._f_src = self._chunk_src[perm]
        self._f_offs = self._offs[perm]
        self._f_work = self.ev_chunk_work[perm]
        self._f_rowc = self._chunk_rowc[perm]
        self._f_pr = self._chunk_pr[perm]
        self._f_req = self.ev_chunk_req[perm]
        self._f_bins0 = bins0[perm]
        self._f_fin0 = fin0[self._rep][perm]
        if self.batching is not None:
            self._f_wdec = self._chunk_wdec[perm]
            self._f_cntw = self._chunk_cntw[perm]
        if self._mig_flat.size:
            flat = self._row_inv[self._mig_flat // self.n_bins] \
                * self.n_bins + self._mig_flat % self.n_bins
            self._mig_rm = np.bincount(
                flat, weights=self._mig_work,
                minlength=self.n_rows * self.n_bins
            ).reshape(self.n_rows, self.n_bins)
        else:
            self._mig_rm = None

    # ----------------------------------------------------------------- #

    def _chain(self, tok_total: np.ndarray, start_pref: np.ndarray):
        """Autoregressive chaining: (decode token starts (P, N), their
        per-request inclusive cumsums (P, N), prefill completion (P, R))."""
        R = self.n_requests
        dec = tok_total[:, R:]
        cs = np.cumsum(dec, axis=1)
        base = (cs - dec)[:, self.first_tok][:, self.tok_req]
        seg_excl = (cs - dec) - base
        c0 = start_pref + tok_total[:, :R]
        start_dec = c0[:, self.tok_req] + seg_excl
        return start_dec, cs - base, c0

    def _schedule(self, gw_wait: np.ndarray, ex_max: np.ndarray,
                  start_pref: np.ndarray):
        """Wait-augmented schedule: per-(plan, token, layer) gateway and
        expert arrival times, plus per-token total latencies."""
        lay_cost = self.eff_layer + gw_wait + ex_max              # (P, M, L)
        tok_total = self.tok_base + gw_wait.sum(2) + ex_max.sum(2)
        start_dec, seg_incl, c0 = self._chain(tok_total, start_pref)
        start_all = np.concatenate([start_pref, start_dec], axis=1)
        layer_arr = start_all[:, :, None] + _exclusive_cumsum(lay_cost, 2)
        exp_arr = layer_arr + gw_wait + self.gw_service[None, :, None]
        return layer_arr, exp_arr, tok_total, seg_incl, c0

    def _to_bins(self, times: np.ndarray):
        """Clip finite ``times`` to bin indices; returns (bins, finite)."""
        finite = np.isfinite(times)
        b = np.where(
            finite,
            np.clip((np.where(finite, times, 0.0) / self.qcfg.dt_s)
                    .astype(np.int64), 0, self.n_bins - 1), 0)
        return b, finite

    def _event_times(self, layer_arr: np.ndarray,
                     exp_arr: np.ndarray) -> np.ndarray:
        """(P*E,) arrival time of every queue event under a schedule."""
        P, R = self.n_plans, self.n_requests
        return np.concatenate([
            layer_arr.reshape(P, -1),
            np.broadcast_to(
                exp_arr[:, R:, :, None],
                (P, self.n_decode_tokens, self.n_layers,
                 self.activation.top_k)).reshape(P, -1),
            np.broadcast_to(
                exp_arr[:, :R, :, None],
                (P, R, self.n_layers, self.activation.n_experts))
            .reshape(P, -1),
        ], axis=1).ravel()

    def _bin_work(self, layer_arr, exp_arr, active2d):
        """Offered work (P, S, T) for the current schedule + per-plan
        request-activity mask ``active2d`` (P, R)."""
        P = self.n_plans
        S, T = self.n_stations, self.n_bins
        ev_time = self._event_times(layer_arr, exp_arr)           # (P*E,)
        base_bin, finite = self._to_bins(ev_time)
        bins = np.minimum(base_bin[self._rep] + self._offs, T - 1)
        w = self.ev_chunk_work * finite[self._rep] \
            * active2d[self.ev_chunk_plan, self.ev_chunk_req]
        flat = (self.ev_chunk_plan * S + self.ev_chunk_station) * T + bins
        if self._mig_flat.size:
            # Schedule-switch weight migrations ride as background load.
            flat = np.concatenate([flat, self._mig_flat])
            w = np.concatenate([w, self._mig_work])
        return np.bincount(flat, weights=w,
                           minlength=P * S * T).reshape(P, S, T)

    def _bin_work_planes(self, layer_arr, exp_arr, active2d):
        """Decode-work and occupancy-count planes (P, S, T) for the
        legacy path's continuous-batching law (:mod:`.batching`) —
        same bins as :meth:`_bin_work`, decode-side chunk channels,
        no migration background (weights are not batchable decode)."""
        P = self.n_plans
        S, T = self.n_stations, self.n_bins
        ev_time = self._event_times(layer_arr, exp_arr)           # (P*E,)
        base_bin, finite = self._to_bins(ev_time)
        bins = np.minimum(base_bin[self._rep] + self._offs, T - 1)
        act = finite[self._rep] \
            * active2d[self.ev_chunk_plan, self.ev_chunk_req]
        flat = (self.ev_chunk_plan * S + self.ev_chunk_station) * T + bins
        wdec = np.bincount(flat, weights=self._chunk_wdec * act,
                           minlength=P * S * T).reshape(P, S, T)
        cnt = np.bincount(flat, weights=self._chunk_cntw * act,
                          minlength=P * S * T).reshape(P, S, T)
        return wdec, cnt

    def _gather(self, wait, overload, layer_arr, exp_arr):
        """Per-(plan, token, layer) gateway wait, expert branch-max wait,
        and overload flags, read at the schedule's arrival bins."""
        p_idx = np.arange(self.n_plans)[:, None, None]
        gw_b, gw_fin = self._to_bins(layer_arr)
        gw_wait = np.where(gw_fin,
                           wait[p_idx, self.gather_gw_station, gw_b], 0.0)
        gw_over = gw_fin & overload[p_idx, self.gather_gw_station, gw_b]
        ex_b, ex_fin = self._to_bins(exp_arr)
        ex_b4, ex_f4 = ex_b[..., None], ex_fin[..., None]
        ex_wait = np.where(
            ex_f4, wait[p_idx[..., None], self.gather_exp_station, ex_b4],
            0.0)
        ex_over = ex_f4 & \
            overload[p_idx[..., None], self.gather_exp_station, ex_b4]
        return gw_wait, ex_wait.max(axis=3), gw_over, ex_over.any(axis=3)

    # ----------------------------------------------------------------- #

    def satellite_backlog(self, plan: int, t_s: float) -> np.ndarray:
        """(V,) seconds of backlog per satellite that plan row ``plan``
        observed at wall-clock ``t_s`` in the last ``run`` — the live
        signal the re-placement controller scores candidate plans
        against (zeros before any loaded run)."""
        if self.last_wait is None:
            return np.zeros(self.n_stations)
        b = min(int(t_s / self.qcfg.dt_s), self.n_bins - 1)
        return self.last_wait[plan, :, b]

    # ----------------------------------------------------------------- #

    def _device_tables(self) -> dict:
        """Build (once, lazily) the device-resident precompute pytree the
        fused fixed point consumes.

        Everything rate-independent is staged to the device in float64
        (x64 scoped to the transfer): the zero-load schedule tensors, the
        chunk layout + gather indices, the densified migration background
        load, and — when the AIMD controller is on — the admission scan
        tables and retry attempt tables.
        """
        if self._dev is not None:
            return self._dev
        qcfg = self.qcfg
        with _x64():
            d = dict(
                dt=jnp.asarray(float(qcfg.dt_s)),
                cap32=jnp.asarray(float(qcfg.buffer_s), dtype=jnp.float32),
                dt32=jnp.asarray(float(qcfg.dt_s), dtype=jnp.float32),
                eff_layer=jnp.asarray(self.eff_layer),
                tok_base=jnp.asarray(self.tok_base),
                gw_service=jnp.asarray(self.gw_service),
                arrival_s=jnp.asarray(self.requests.arrival_s),
                ingress_extra0=jnp.asarray(self.ingress_extra),
                first_tok=jnp.asarray(self.first_tok),
                tok_req=jnp.asarray(self.tok_req),
                last_tok=jnp.asarray(
                    self.first_tok + self.requests.decode_len - 1),
                gw_rows=jnp.asarray(self._gw_rowc),
                ex_rows=jnp.asarray(self._ex_rowc),
                gw_b0=jnp.asarray(self._gw_b0),
                gw_fin0=jnp.asarray(self._gw_fin0),
                ex_b0=jnp.asarray(self._ex_b0),
                ex_fin0=jnp.asarray(self._ex_fin0),
            )
            if self._mig_rm is not None:
                d["mig_dense"] = jnp.asarray(self._mig_rm)    # (rows, T)
            if self.admission_on:
                acfg = qcfg.admission
                f32 = np.float32
                d.update(
                    ttft0=jnp.asarray(self._adm_ttft0.astype(f32)),
                    tpot0=jnp.asarray(self._adm_tpot0.astype(f32)),
                    ctrl=jnp.asarray(control_bin_flags(
                        self.n_bins, qcfg.dt_s, acfg.interval_s)),
                    gw_rows_bin=jnp.asarray(self._adm_gw_rowc),
                    exp_rows_bin=jnp.asarray(self._adm_exp_rowc),
                    increase=jnp.asarray(f32(acfg.increase)),
                    decrease=jnp.asarray(f32(acfg.decrease)),
                    admit_min=jnp.asarray(f32(acfg.admit_min)),
                    att_bin=jnp.asarray(self._att_bin),
                    att_station=jnp.asarray(self._att_station),
                    att_feasible=jnp.asarray(
                        np.moveaxis(self._att_feasible, 1, 0)),
                    att_extra=jnp.asarray(
                        np.moveaxis(self._att_extra, 0, 1)),
                    adm_u=jnp.asarray(self._adm_u),
                )
                if acfg.policy == "pid":
                    gain = np.ones(len(self.schedules)) \
                        if acfg.gain_scale is None \
                        else np.asarray(acfg.gain_scale, dtype=np.float64)
                    d.update(
                        pid_kp=jnp.asarray(f32(acfg.kp)),
                        pid_ki=jnp.asarray(f32(acfg.ki)),
                        pid_kd=jnp.asarray(f32(acfg.kd)),
                        pid_gain=jnp.asarray(gain.astype(f32)),
                    )
        self._dev = d
        return d

    def _deposit_mode(self) -> str:
        """Resolve the deposit implementation (see ``deposit_impl``).

        ``"auto"`` picks the Pallas one-hot-matmul kernel on TPU and the
        inline ``"ref"`` scatter everywhere else.  The ``"segments"``
        row-bucketed ``segment_sum`` path is bitwise identical to
        ``"ref"`` (so switching never moves a trace) and stays opt-in:
        ``bench_fleet``'s before/after stage timing shows it winning
        only on mid-size shuffled tables — the fleet's row-grouped
        chunk ordering keeps the inline scatter cache-friendly, and
        XLA:CPU's sort constants dominate beyond ~1M chunks.
        """
        if self.deposit_impl == "auto":
            return "pallas" if _kernel_ops.on_tpu() else "ref"
        if self.deposit_impl not in ("pallas", "segments", "ref"):
            raise ValueError(
                f"deposit_impl {self.deposit_impl!r} not in "
                "('auto', 'pallas', 'segments', 'ref')")
        return self.deposit_impl

    def _ctrl_tables(self) -> dict:
        """Host precompute for the joint control plane (lazy, cached).

        Everything here is independent of the controller configuration —
        the schedule row's compact station universe, the event-major
        gated chunk table, the decide walk's penalty row maps and the
        migration tables — so one cache serves every controller grid
        launched over this simulator.
        """
        if self._ctrl is not None:
            return self._ctrl
        qcfg = self.qcfg
        C, S, T = self.n_plans, self.n_stations, self.n_bins
        M, L, R = self.n_tokens, self.n_layers, self.n_requests
        N = self.n_decode_tokens
        K = self.activation.top_k
        dt, period = qcfg.dt_s, qcfg.slot_period_s
        n_slots = self.n_topo_slots

        # Schedule-row station universe: every satellite the schedule
        # row can deposit on, gather from, observe through the admission
        # maps or receive migrated weights at — the union over the
        # candidate pool (superset rows carry exactly-zero work, so the
        # compaction is exact, same argument as _build_row_map).
        gw_all = np.stack([np.asarray(p.gateways) for p in self.plans])
        ex_all = np.stack([np.asarray(p.expert_sats) for p in self.plans])
        used = [self.ev_chunk_station.ravel(), self.gather_gw_station.ravel(),
                self.gather_exp_station.ravel(), gw_all.ravel(),
                ex_all.ravel()]
        if self.admission_on:
            used += [self._adm_gw_idx.ravel(), self._adm_exp_idx.ravel()]
        srows = np.unique(np.concatenate(
            [np.asarray(u, dtype=np.int64) for u in used]))
        srow_inv = np.full(S, -1, dtype=np.int64)
        srow_inv[srows] = np.arange(srows.size)

        # Event-major gated chunk table: the probe's chunks re-sorted
        # (stable) by event, plan within event.  Only one plan's chunks
        # survive the slot gate per event, so the surviving deposits hit
        # each (row, bin) in event order — the accumulation order of a
        # host-built evaluation simulator's row-sorted bincount.
        E = self._n_events // C
        gw1 = np.arange(M)[:, None] * L + np.arange(L)[None, :]
        exp1 = M * L + gw1
        ev1 = np.concatenate([
            gw1.ravel(),
            np.broadcast_to(exp1[R:, :, None], (N, L, K)).ravel(),
            np.broadcast_to(exp1[:R, :, None],
                            (R, L, ex_all.shape[2])).ravel()])
        ev_local = self._rep % E
        perm = np.lexsort((self.ev_chunk_plan, ev_local))
        ct = dict(
            srows=srows, n_rows_sched=int(srows.size),
            ch_local=ev1[ev_local][perm],
            ch_work=self.ev_chunk_work[perm],
            ch_offs=self._offs[perm],
            ch_srow=srow_inv[self.ev_chunk_station[perm]].astype(np.int32),
            ch_plan=self.ev_chunk_plan[perm],
            ch_slot=self.slots[self.ev_chunk_req[perm]],
            ch_req=self.ev_chunk_req[perm],
            ch_bins0=self._chunk_bins0[perm],
            ch_fin0=self._chunk_fin0[perm].astype(np.float64),
        )

        # Decide-walk penalty row maps.  Round 1 reads the probe's
        # compact (plan, satellite) rows per incumbent (missing rows hit
        # the sentinel zero column — the host expansion reads 0.0
        # there); refinement rounds read the schedule row's universe.
        SR = self.n_rows
        pen1_gw = np.empty((C, C, L), dtype=np.int32)
        pen1_ex = np.empty((C, C) + ex_all.shape[1:], dtype=np.int32)
        for cur in range(C):
            rg = self._row_inv[cur * S + gw_all]
            pen1_gw[cur] = np.where(rg >= 0, rg, SR)
            re_ = self._row_inv[cur * S + ex_all]
            pen1_ex[cur] = np.where(re_ >= 0, re_, SR)
        ct["pen1_gw"] = pen1_gw
        ct["pen1_ex"] = pen1_ex
        ct["pen2_gw"] = srow_inv[gw_all].astype(np.int32)
        ct["pen2_ex"] = srow_inv[ex_all].astype(np.int32)

        # Schedule-row gather maps (stations -> compact schedule rows).
        ct["gw_srow"] = srow_inv[self.gather_gw_station].astype(np.int32)
        ct["ex_srow"] = srow_inv[self.gather_exp_station].astype(np.int32)

        # Decision-walk statics: the boundary count and per-boundary
        # backlog observation bin of replan.build_replan_schedule.
        horizon = T * dt
        n_bounds = min(int(np.floor(max(horizon, 0.0) / period)),
                       n_slots - 1)
        ct["n_bounds"] = n_bounds
        ct["decide_bins"] = tuple(
            min(int((k * period) / dt), T - 1) for k in range(n_bounds + 1))

        # Migration tables: all-pairs switch pricing (the decide gate)
        # plus the background-load deposit.  The deposit table holds
        # *sequential* repeated sums of the per-chunk occupancy — n
        # experts landing on one satellite deposit w added n times, not
        # n * w, exactly the host bincount's accumulation.
        n_moved, dest = migration_matrix(self.plans, 1.0, S)
        ct["n_moved"] = n_moved
        sec = (qcfg.migration_bytes_per_expert * 8.0
               / (qcfg.migration_rate_gbps * 1e9))
        if sec > 0.0:
            n_chm = max(int(np.ceil(sec / dt)), 1)
            w_prof = np.minimum(sec - np.arange(n_chm) * dt, dt)
        else:
            w_prof = np.zeros(0)
        max_cnt = int(dest.max())
        rep = np.zeros((len(w_prof), max_cnt + 1))
        for j, w in enumerate(w_prof):
            for n in range(1, max_cnt + 1):
                rep[j, n] = rep[j, n - 1] + w
        ct["n_mig_chunks"] = int(len(w_prof))
        ct["mig_plane"] = rep[:, dest[:, :, srows].astype(np.int64)]
        nbm = int(np.floor(horizon / period))
        ct["mig_bounds"] = tuple(
            (int((k - 1) % n_slots), int(k % n_slots),
             int((k * period) / dt)) for k in range(1, nbm + 1))

        if self.admission_on:
            # Masked admission-anchor inputs for the schedule row's
            # on-device quantiles + per-bin station maps.
            ct["adm_ok0"] = self._adm_ok0
            ct["adm_base_ttft"] = self._adm_base_ttft
            ct["adm_station"] = self._adm_station
            ct["adm_dec_ok"] = self._adm_dec_ok
            ct["adm_dec_vals"] = self.tok_base[:, R:]
            ct["att_feas_c"] = np.moveaxis(self._att_feasible, 1, 0)
            ct["att_extra_c"] = np.moveaxis(self._att_extra, 0, 1)
            ct["gw_srow_bin"] = srow_inv[self._adm_gw_idx].astype(np.int32)
            ct["exp_srow_bin"] = srow_inv[self._adm_exp_idx].astype(np.int32)
            ct["slot_of_bin"] = self._adm_slot_of_bin
        self._ctrl = ct
        return ct

    def _launch(self, masks: np.ndarray, ttft_targets, tpot_targets,
                want_wait: bool) -> dict:
        """One fused device launch over the leading sweep axis F.

        The request-activity masks are folded into a host-built compacted
        chunk table (only active chunks are deposited; padded to
        ``_CHUNK_BLOCK`` so repeated sweeps of the same shape reuse the
        compile cache) — the device sees offered work, not the envelope.

        Args:
            masks: (F, R) bool request-activity masks.
            ttft_targets: Optional (F,) raw TTFT targets (margin applied
                here); None uses the construction-time config.
            tpot_targets: Same for TPOT.
            want_wait: Return the (T, F, rows) backlog trace.

        Returns:
            The :func:`_fused_core` output dict as host arrays, each
            with a leading F axis (``wait`` stays time-major compact).
        """
        acfg = self.qcfg.admission
        F = masks.shape[0]
        if self.admission_on:
            m = acfg.target_margin
            tt = (np.full(F, m * acfg.ttft_target_s) if ttft_targets is None
                  else m * np.asarray(ttft_targets, dtype=np.float64))
            tp = (np.full(F, m * acfg.tpot_target_s) if tpot_targets is None
                  else m * np.asarray(tpot_targets, dtype=np.float64))
        else:
            tt = np.zeros(F)
            tp = np.zeros(F)

        # Host-side chunk compaction: keep (f, chunk) pairs whose
        # request is active, in the static row-grouped order.  Padding
        # rides along with zero work.  The compaction streams one sweep
        # row at a time — peak host memory is O(n_chunks + active), not
        # the O(F * n_chunks) dense activity matrix a 2-D np.nonzero
        # would materialize — with the concatenation preserving the
        # f-major, chunk-ascending order bit-for-bit.
        P, R = self.n_plans, self.n_requests
        T, SR = self.n_bins, self.n_rows
        cids = [np.flatnonzero(masks[f, self._f_req]) for f in range(F)]
        f_id = np.repeat(np.arange(F),
                         np.array([c.size for c in cids], dtype=np.int64))
        cid = (np.concatenate(cids) if cids
               else np.empty(0, dtype=np.int64))
        n = cid.size
        n_pad = max(-(-n // _CHUNK_BLOCK), 1) * _CHUNK_BLOCK
        pml2 = 2 * P * self.n_tokens * self.n_layers
        src = np.zeros(n_pad, dtype=np.int64)
        src[:n] = f_id * pml2 + self._f_src[cid]
        offs = np.zeros(n_pad, dtype=np.int64)
        offs[:n] = self._f_offs[cid]
        work = np.zeros(n_pad)
        work[:n] = self._f_work[cid]
        fprow = np.zeros(n_pad, dtype=np.int32)
        fprow[:n] = f_id.astype(np.int32) * SR + self._f_rowc[cid]
        chunks = dict(src=src, offs=offs, work=work, fprow=fprow)
        if self.admission_on:
            fpr = np.zeros(n_pad, dtype=np.int64)
            fpr[:n] = f_id * (P * R) + self._f_pr[cid]
            chunks["fpr"] = fpr
        if self.batching is not None:
            wdec = np.zeros(n_pad)
            wdec[:n] = self._f_wdec[cid]
            cntw = np.zeros(n_pad)
            cntw[:n] = self._f_cntw[cid]
            chunks["wdec"] = wdec
            chunks["cntw"] = cntw

        # Iteration-1 offered work: the zero-wait schedule's bins are
        # static, so one host bincount over the active chunks builds the
        # peeled iteration's plane (a launch input, not a per-iteration
        # transfer).
        flat0 = (f_id * SR + self._f_rowc[cid]).astype(np.int64) * T \
            + self._f_bins0[cid]
        # astype: bincount of an *empty* chunk set (an all-False sweep
        # row) returns int64 even with weights given.
        plane0 = np.bincount(
            flat0, weights=self._f_work[cid] * self._f_fin0[cid],
            minlength=F * SR * T).reshape(F, SR, T).astype(np.float64)
        if self._mig_rm is not None:
            plane0 += self._mig_rm[None]
        work0_sum = plane0.sum(axis=2)                        # (F, SR)
        beff0 = None
        if self.batching is not None:
            # The peeled iteration's effective work is host-computed in
            # f64 (mirroring the device's f64-scatter-then-f32-downcast
            # policy) from the decode-work and occupancy planes of the
            # same static bins.
            plane0_dec = np.bincount(
                flat0, weights=self._f_wdec[cid] * self._f_fin0[cid],
                minlength=F * SR * T).reshape(F, SR, T)
            cnt0 = np.bincount(
                flat0, weights=self._f_cntw[cid] * self._f_fin0[cid],
                minlength=F * SR * T).reshape(F, SR, T)
            plane0, beff0 = effective_work_np(
                plane0, plane0_dec, cnt0, self._batch_table,
                self._batch_cap, self._batch_window)

        # Telemetry rings: static (capacity, stride) pair + donated
        # zeroed buffers.  probes=None launches pass an empty pytree and
        # trace exactly the legacy kernel.
        if self.probes is not None:
            p_cap, p_stride = self.probes.resolve(self.n_bins)
            static_probes = (p_cap, p_stride)
            n_gw = self._adm_ttft0.shape[1] if self.admission_on else 0
            pbuf = {k: jnp.asarray(v) for k, v in make_buffers(
                p_cap, F, SR,
                (P, n_gw) if self.admission_on else None,
                n_row_channels=4 if self.batching is not None else 3
            ).items()}
            exec_fn = _fused_exec_probed
        else:
            static_probes = None
            pbuf = {}
            exec_fn = _fused_exec
        # Batching pytree: empty when off (the trace then shares the
        # batching-free compile-cache entry); the host-computed beff0
        # ships only for the probed n_iter == 1 peel, which has no
        # device-side occupancy plane to record from.
        batch_np: dict = {}
        batch_window = 0
        if self.batching is not None:
            batch_np = dict(table=self._batch_table,
                            bcap=np.float64(self._batch_cap))
            batch_window = self._batch_window
            if self.probes is not None and max(1, self.qcfg.iterations) == 1:
                batch_np["beff0"] = beff0.astype(np.float32)
        with _x64(), warnings.catch_warnings():
            # CPU jit declines buffer donation with a UserWarning; the
            # request is still the right thing on TPU/GPU.
            warnings.filterwarnings("ignore", message=".*[Dd]onat")
            out = exec_fn(
                self._device_tables(),
                {k: jnp.asarray(v) for k, v in chunks.items()},
                jnp.asarray(plane0.astype(np.float32)),
                jnp.asarray(work0_sum),
                jnp.asarray(tt), jnp.asarray(tp), pbuf,
                {k: jnp.asarray(v) for k, v in batch_np.items()},
                max(1, self.qcfg.iterations), self.n_bins, self.n_rows,
                self.admission_on, self._deposit_mode(), want_wait,
                static_probes, batch_window)
            out = {k: jax.tree_util.tree_map(np.asarray, v)
                   for k, v in out.items()}
        if self.probes is not None:
            # Probe outputs have their own leading axes — ingest and pop
            # them here so run/run_many's per-F slicing stays untouched.
            self.last_probes = ProbeRecord.from_launch(
                out.pop("probes"), out.pop("probe_gw_wait"),
                out.pop("probe_ex_wait"), self.qcfg.dt_s, p_cap, p_stride,
                self.n_bins, self._expand_rows)
        return out

    def run(self, active: np.ndarray | None = None,
            zero_load: bool = False,
            kv_slots: int | None = None, *,
            replan=None, replan_rng=None):
        """Simulate with an optional per-request activity mask (Poisson
        thinning for rate sweeps) and return per-plan traffic metrics.

        The fixed point executes as **one fused device launch** (see
        :func:`_fused_core`); :meth:`run_legacy` is the host-path anchor
        it is pinned against.  ``zero_load`` delegates to the host path
        (the queue scan is skipped entirely there, so the zero-load
        reference stays bitwise equal to the engine).

        Args:
            active: Optional (R,) bool participation mask (default: all).
            zero_load: Skip queueing and admission entirely.
            kv_slots: Optional override of the static KV admission cap
                (the cap is host post-processing, so budget sweeps reuse
                one device launch shape).
            replan: Optional ``repro.traffic.replan.ReplanConfig`` —
                runs the **joint control plane** instead: probe, the
                re-placement decide walk and the decided schedule's
                evaluation execute as one device launch
                (:func:`_ctrl_core`), and the return value becomes a
                ``ReplanOutcome`` (parity anchor:
                ``replan_traffic``).  Composes with no other option.
            replan_rng: RNG for the controller's base candidate scores
                (``replan`` only; default ``np.random.default_rng(0)``).

        Returns:
            A :class:`~repro.traffic.metrics.TrafficResult` with one
            :class:`~repro.traffic.metrics.PlanTraffic` per plan — or a
            ``ReplanOutcome`` when ``replan`` is given.
        """
        if replan is not None:
            if active is not None or zero_load or kv_slots is not None:
                raise ValueError(
                    "run(replan=...) composes with no other run() option")
            from .replan import replan_base_scores
            rng = (np.random.default_rng(0) if replan_rng is None
                   else replan_rng)
            scores = replan_base_scores(
                self.plans, self.topo, self.activation, self.workload,
                self.compute, rng, replan)
            return self.run_replan_grid(replan, base_scores=scores)[0]
        if zero_load:
            return self.run_legacy(active, zero_load=True,
                                   kv_slots=kv_slots)
        if active is None:
            active = np.ones(self.n_requests, dtype=bool)
        active = np.asarray(active, dtype=bool)
        out = self._launch(active[None, :], None, None, want_wait=True)
        # Exposed for the re-placement controller: the live
        # (plan, satellite, bin) backlog of the last fleet scan,
        # expanded from compact rows back to every satellite.
        wait = out.pop("wait")                       # (T, 1, rows)
        self.last_wait = np.moveaxis(
            self._expand_rows(wait[:, 0, :]), 0, 2)  # (P, S, T)
        out = {k: v[0] for k, v in out.items()}
        out["work_sum"] = self._expand_rows(out["work_sum"])
        return self._finalize(active, out, self.admission_on, kv_slots)

    def run_many(self, active: np.ndarray | None = None, *,
                 ttft_targets: np.ndarray | None = None,
                 tpot_targets: np.ndarray | None = None,
                 kv_slots: int | None = None,
                 replan=None, replan_rng=None, base_scores=None,
                 cadences=None, mig_weights=None) -> list:
        """Run a whole sweep as one compile + one device launch.

        The F sweep entries ride a vmapped leading axis of the fused
        fixed point: a saturation sweep batches thinning masks, the
        admission-frontier benchmark batches latency targets — either
        way the fused kernel is traced once (``FUSED_TRACE_COUNT``) and
        the per-entry results come back from a single launch.

        With ``replan`` given the sweep becomes a **controller grid**:
        cadence x migration-budget x admission-target cells batch the
        leading axis of one joint-control-plane launch
        (:meth:`run_replan_grid`) and the return value is one
        ``ReplanOutcome`` per cell.

        Args:
            active: (F, R) bool participation masks (one row per sweep
                entry; rows may repeat when only targets vary).  Must be
                None when ``replan`` is given (the controller grid is
                always all-active).
            ttft_targets: Optional (F,) TTFT targets overriding the
                construction-time admission config (AIMD runs only).
                Under ``replan``: the admission-target grid axis.
            tpot_targets: Optional (F,) TPOT targets, same contract.
            kv_slots: Optional static-cap override (host post-processing).
            replan: Optional ``ReplanConfig`` switching to the joint
                control plane.
            replan_rng: RNG for the controller's base candidate scores
                (used when ``base_scores`` is None).
            base_scores: Optional precomputed (n_slots, C) base score
                table (``replan_base_scores``).
            cadences: Optional replan-cadence grid axis (slots between
                decisions; default: the config's ``period_slots``).
            mig_weights: Optional migration-budget grid axis (s/MB
                switch pricing; default the config's weight).

        Returns:
            One :class:`~repro.traffic.metrics.TrafficResult` per sweep
            entry, in order — or one ``ReplanOutcome`` per grid cell
            (cadence-major, then migration weight, then target) when
            ``replan`` is given.
        """
        if replan is not None:
            if active is not None or kv_slots is not None:
                raise ValueError(
                    "run_many(replan=...) composes only with the "
                    "target/cadence/migration grid axes")
            if base_scores is None:
                from .replan import replan_base_scores
                rng = (np.random.default_rng(0) if replan_rng is None
                       else replan_rng)
                base_scores = replan_base_scores(
                    self.plans, self.topo, self.activation, self.workload,
                    self.compute, rng, replan)
            return self.run_replan_grid(
                replan, base_scores=base_scores, cadences=cadences,
                mig_weights=mig_weights, ttft_targets=ttft_targets,
                tpot_targets=tpot_targets)
        if cadences is not None or mig_weights is not None \
                or base_scores is not None:
            raise ValueError("controller grid axes need replan=...")
        if active is None:
            raise ValueError("run_many needs (F, R) activity masks")
        masks = np.asarray(active, dtype=bool)
        if masks.ndim != 2 or masks.shape[1] != self.n_requests:
            raise ValueError(f"active must be (F, {self.n_requests})")
        if (ttft_targets is not None or tpot_targets is not None) \
                and not self.admission_on:
            raise ValueError(
                "latency-target sweeps need an AIMD admission config")
        out = self._launch(masks, ttft_targets, tpot_targets,
                           want_wait=False)
        out["work_sum"] = self._expand_rows(out["work_sum"])
        return [
            self._finalize(masks[f], {k: v[f] for k, v in out.items()},
                           self.admission_on, kv_slots)
            for f in range(masks.shape[0])
        ]

    def run_replan_grid(self, rcfg, *, base_scores,
                        cadences=None, mig_weights=None,
                        ttft_targets=None, tpot_targets=None) -> list:
        """One joint-control-plane launch over a controller grid.

        Probe, decide walk and schedule-row evaluation execute inside a
        single device program (:func:`_ctrl_core`), batched over the
        grid's leading axis — F = cadences x migration weights x
        admission targets, cell order cadence-major.  The host
        controller (``repro.traffic.replan.replan_traffic``) stays the
        semantic anchor; on CPU the fused controller reproduces its
        switch decisions and served/shed sets bit for bit.  Paths where
        the host controller remains authoritative raise here:
        continuous batching, probe rings, calibrated per-satellite
        service (its decode-batch estimate depends on the evaluated
        plan pool) and candidate pools that already contain schedules.

        Args:
            rcfg: ``ReplanConfig`` (mode/hysteresis/pricing; its
                ``period_slots`` / ``migration_weight_s_per_mb`` seed
                the grid axes when none are given).
            base_scores: (n_topo_slots, C) backlog-free candidate
                scores per slot (``replan_base_scores``) — the decide
                law adds the backlog penalty on device.
            cadences: Iterable of decision cadences in slots (>= 1).
            mig_weights: Iterable of migration prices (s/MB, >= 0).
            ttft_targets: Optional admission-target axis (raw seconds,
                zipped with ``tpot_targets``; admission runs only).
            tpot_targets: Optional TPOT targets (zips with
                ``ttft_targets``).

        Returns:
            One ``ReplanOutcome`` per grid cell: last-round decisions,
            the stitched candidates+schedule ``TrafficResult``, the
            probe result (backlog mode) and this simulator as ``sim``.
        """
        from .replan import (REPLAN_MODES, ReplanDecision, ReplanOutcome,
                             ReplanReport)

        qcfg = self.qcfg
        acfg = qcfg.admission
        if rcfg.mode not in REPLAN_MODES:
            raise ValueError(f"unknown replan mode: {rcfg.mode!r}")
        if self.batching is not None:
            raise NotImplementedError(
                "joint control plane: continuous batching stays on the "
                "host controller (replan_traffic)")
        if self.probes is not None:
            raise NotImplementedError(
                "joint control plane: probe rings are not recorded on "
                "the control launch — use replan_traffic for probed "
                "rounds")
        if self.service_model.per_satellite:
            raise NotImplementedError(
                "joint control plane: calibrated per-satellite service "
                "recomputes its decode-batch estimate per evaluated "
                "plan pool — the host controller is authoritative")
        if any(not s.is_constant for s in self.schedules):
            raise ValueError(
                "run_replan_grid needs a static candidate pool (plain "
                "plans); schedules cannot be re-decided")
        if (ttft_targets is not None or tpot_targets is not None) \
                and not self.admission_on:
            raise ValueError(
                "admission-target axes need an admission config")
        if self.admission_on and getattr(acfg, "gain_scale", None) \
                is not None:
            raise NotImplementedError(
                "joint control plane: per-plan admission gains are "
                "pool-indexed and do not transfer to the decided "
                "schedule row")

        C = self.n_plans
        n_slots = self.n_topo_slots
        T, R, M = self.n_bins, self.n_requests, self.n_tokens
        bs = np.asarray(base_scores, dtype=np.float64)
        if bs.shape != (n_slots, C):
            raise ValueError(f"base_scores must be ({n_slots}, {C})")

        cads = ([int(rcfg.period_slots)] if cadences is None
                else [int(c) for c in cadences])
        migw = ([float(rcfg.migration_weight_s_per_mb)]
                if mig_weights is None
                else [float(w) for w in mig_weights])
        if any(c < 1 for c in cads):
            raise ValueError("cadences must be >= 1")
        if any(w < 0 for w in migw):
            raise ValueError("migration weights must be >= 0")
        tts = [None] if ttft_targets is None else list(ttft_targets)
        tps = [None] * len(tts) if tpot_targets is None \
            else list(tpot_targets)
        if len(tps) != len(tts):
            raise ValueError("ttft_targets and tpot_targets must zip")
        cells = [(c, w, i) for c in cads for w in migw
                 for i in range(len(tts))]
        F = len(cells)

        if self.admission_on:
            m = acfg.target_margin
            tt = np.array([m * (acfg.ttft_target_s if tts[i] is None
                                else tts[i]) for _, _, i in cells])
            tp = np.array([m * (acfg.tpot_target_s if tps[i] is None
                                else tps[i]) for _, _, i in cells])
        else:
            tt, tp = np.zeros(F), np.zeros(F)

        ct = self._ctrl_tables()
        K1 = ct["n_bounds"] + 1
        dmask = np.zeros((F, K1), dtype=bool)
        for f, (cad, _w, _i) in enumerate(cells):
            for k in range(K1):
                dmask[f, k] = (k == 0) or (rcfg.mode != "off"
                                           and k % cad == 0)
        bpe = (qcfg.migration_bytes_per_expert
               if rcfg.bytes_per_expert is None else rcfg.bytes_per_expert)
        cc = dict(
            base_scores=bs[np.arange(K1) % n_slots],
            decide_mask=dmask,
            mig_w=np.array([w for _, w, _ in cells]),
            bytes_mat=ct["n_moved"] * bpe,
            pen1_gw=ct["pen1_gw"], pen1_ex=ct["pen1_ex"],
            pen2_gw=ct["pen2_gw"], pen2_ex=ct["pen2_ex"],
            slot_tok=self.slots,
            gw_srow=ct["gw_srow"], ex_srow=ct["ex_srow"],
            ch_local=ct["ch_local"], ch_work=ct["ch_work"],
            ch_offs=ct["ch_offs"], ch_srow=ct["ch_srow"],
            ch_plan=ct["ch_plan"], ch_slot=ct["ch_slot"],
            ch_bins0=ct["ch_bins0"], ch_fin0=ct["ch_fin0"],
        )
        if ct["n_mig_chunks"] and ct["mig_bounds"]:
            cc["mig_plane"] = ct["mig_plane"]
        if self.admission_on:
            cc.update(
                ch_req=ct["ch_req"], adm_ok0=ct["adm_ok0"],
                adm_base_ttft=ct["adm_base_ttft"],
                adm_station=ct["adm_station"],
                adm_dec_ok=ct["adm_dec_ok"],
                adm_dec_vals=ct["adm_dec_vals"],
                att_feas_c=ct["att_feas_c"],
                att_extra_c=ct["att_extra_c"],
                gw_srow_bin=ct["gw_srow_bin"],
                exp_srow_bin=ct["exp_srow_bin"],
                slot_of_bin=ct["slot_of_bin"])
        n_rounds = (max(1, int(rcfg.controller_iterations))
                    if rcfg.mode == "backlog" else 1)
        meta = _CtrlMeta(
            n_iter=max(1, qcfg.iterations), n_bins=T,
            n_rows=self.n_rows, n_rows_sched=ct["n_rows_sched"],
            n_cand=C, n_slots=n_slots, n_bounds=ct["n_bounds"],
            n_rounds=n_rounds, adm_on=self.admission_on,
            deposit_mode=self._deposit_mode(),
            mode_backlog=(rcfg.mode == "backlog"),
            hysteresis=float(rcfg.hysteresis),
            ref_q=(float(acfg.reference_quantile)
                   if self.admission_on else 0.0),
            decide_bins=ct["decide_bins"],
            n_mig_chunks=ct["n_mig_chunks"],
            mig_bounds=ct["mig_bounds"])

        # Probe chunk table: the all-active compaction of _launch (every
        # grid cell offers the full request set).  The probe fixed point
        # depends on the admission (TTFT, TPOT) target alone — not on
        # cadence or migration budget — so the table is built at the
        # deduplicated admission-cell width Fu and the device gathers
        # the probe back to F (``probe_gather``).  A grid whose cells
        # share one admission target (e.g. a cadence x budget sweep)
        # runs the probe exactly once.
        uniq, inv = np.unique(np.stack([tt, tp], axis=1), axis=0,
                              return_inverse=True)
        Fu = uniq.shape[0]
        cc["probe_ttft"] = uniq[:, 0]
        cc["probe_tpot"] = uniq[:, 1]
        cc["probe_gather"] = inv.astype(np.int64).reshape(F)
        P, SR = self.n_plans, self.n_rows
        nch = self._f_work.size
        f_id = np.repeat(np.arange(Fu), nch)
        cid = np.tile(np.arange(nch), Fu)
        n = cid.size
        n_pad = max(-(-n // _CHUNK_BLOCK), 1) * _CHUNK_BLOCK
        pml2 = 2 * P * M * self.n_layers
        src = np.zeros(n_pad, dtype=np.int64)
        src[:n] = f_id * pml2 + self._f_src[cid]
        offs = np.zeros(n_pad, dtype=np.int64)
        offs[:n] = self._f_offs[cid]
        work = np.zeros(n_pad)
        work[:n] = self._f_work[cid]
        fprow = np.zeros(n_pad, dtype=np.int32)
        fprow[:n] = f_id.astype(np.int32) * SR + self._f_rowc[cid]
        chunks = dict(src=src, offs=offs, work=work, fprow=fprow)
        if self.admission_on:
            fpr = np.zeros(n_pad, dtype=np.int64)
            fpr[:n] = f_id * (P * R) + self._f_pr[cid]
            chunks["fpr"] = fpr
        flat0 = (f_id * SR + self._f_rowc[cid]).astype(np.int64) * T \
            + self._f_bins0[cid]
        plane0 = np.bincount(
            flat0, weights=self._f_work[cid] * self._f_fin0[cid],
            minlength=Fu * SR * T).reshape(Fu, SR, T).astype(np.float64)
        if self._mig_rm is not None:
            plane0 += self._mig_rm[None]

        with _x64():
            out = _ctrl_exec(
                self._device_tables(),
                {k: jnp.asarray(v) for k, v in chunks.items()},
                jnp.asarray(plane0.astype(np.float32)),
                jnp.asarray(plane0.sum(axis=2)),
                jnp.asarray(tt), jnp.asarray(tp),
                {k: jnp.asarray(v) for k, v in cc.items()}, meta)
            out = jax.tree_util.tree_map(np.asarray, out)

        sp_all, telem = out["slot_plan"], out["telem"]
        probe_o, sched_o = out["probe"], out["sched"]
        srows = ct["srows"]

        def expand_srows(a):
            full = np.zeros(a.shape[:-1] + (self.n_stations,), a.dtype)
            full[..., srows] = a
            return full

        names = list(self.batch.names)
        outcomes = []
        for f in range(F):
            schedule = PlanSchedule(plans=self.plans, slot_plan=sp_all[f],
                                    name=f"replan/{rcfg.mode}")
            decisions = [
                ReplanDecision(
                    boundary=k, slot=k % n_slots,
                    chosen=int(telem["chosen"][f, k]),
                    switched=bool(telem["switched"][f, k]),
                    scores=telem["scores"][f, k].copy(),
                    migration_bytes=float(telem["mig_bytes"][f, k]))
                for k in range(K1) if dmask[f, k]
            ]
            # Decision-event channel: the decide loop's device telemetry
            # at this cell's decide boundaries, export-ready.
            dk = np.flatnonzero(dmask[f])
            trace = DecisionTrace(
                period_s=float(qcfg.slot_period_s),
                boundaries=dk.astype(np.int64),
                slots=(dk % n_slots).astype(np.int64),
                scores=telem["scores"][f, dk].astype(np.float64),
                chosen=telem["chosen"][f, dk].astype(np.int64),
                switched=telem["switched"][f, dk].astype(bool),
                migration_bytes=telem["mig_bytes"][f, dk]
                .astype(np.float64))
            report = ReplanReport(schedule=schedule, decisions=decisions,
                                  candidates=list(self.plans),
                                  trace=trace)
            probe_res = None
            if rcfg.mode == "backlog":
                po = {k2: v[f] for k2, v in probe_o.items()}
                po["work_sum"] = self._expand_rows(po["work_sum"])
                probe_res = self._finalize(np.ones(R, dtype=bool), po,
                                           self.admission_on)
            stitched = {
                k2: np.concatenate([probe_o[k2][f], sched_o[k2][f]],
                                   axis=0)
                for k2 in ("ttft", "e2e", "tok_total", "tok_over",
                           "shed", "retries")}
            stitched["work_sum"] = np.concatenate(
                [self._expand_rows(probe_o["work_sum"][f]),
                 expand_srows(sched_o["work_sum"][f])[None]], axis=0)
            plan_tok = sp_all[f][self.slots]
            billed = float(sum(
                mg.bytes_moved for _, mg in schedule.migrations_over(
                    T * qcfg.dt_s, qcfg.slot_period_s,
                    qcfg.migration_bytes_per_expert)))
            res = self._finalize(
                np.ones(R, dtype=bool), stitched, self.admission_on,
                names=names + [schedule.name],
                nan_tok=np.concatenate(
                    [self.nan_tok,
                     self.nan_tok[plan_tok, np.arange(M)][None]]),
                fail_ingress=np.concatenate(
                    [self.fail_ingress,
                     self.fail_ingress[plan_tok[:R],
                                       np.arange(R)][None]]),
                migration_bytes=np.append(self.migration_bytes, billed))
            outcomes.append(ReplanOutcome(report=report, result=res,
                                          probe=probe_res, sim=self))
        return outcomes

    def run_legacy(self, active: np.ndarray | None = None,
                   zero_load: bool = False,
                   kv_slots: int | None = None) -> TrafficResult:
        """Host-path reference fixed point (the pre-fusion ``run``).

        Iterates schedule -> bin -> scan -> gather with the schedule,
        binning and gather steps on the host and only the backlog scan
        on device (whose inputs downcast to float32, as they always
        have — the fused path reproduces exactly that downcast) — the
        authoritative semantic anchor the fused path is parity-pinned
        against in ``tests/test_fleet_perf.py``.

        Args:
            active: Optional (R,) bool participation mask (default: all).
            zero_load: Skip queueing and admission entirely.
            kv_slots: Optional override of the static KV admission cap.

        Returns:
            A :class:`~repro.traffic.metrics.TrafficResult` with one
            :class:`~repro.traffic.metrics.PlanTraffic` per plan.
        """
        qcfg = self.qcfg
        acfg = qcfg.admission
        req = self.requests
        P, R = self.n_plans, self.n_requests
        M, L = self.n_tokens, self.n_layers

        if active is None:
            active = np.ones(R, dtype=bool)
        active = np.asarray(active, dtype=bool)

        adm_on = self.admission_on and not zero_load
        shed = np.zeros((P, R), dtype=bool)
        retries = np.zeros((P, R), dtype=np.int64)
        ingress_extra = self.ingress_extra
        start_pref = self.start_pref
        if adm_on:
            ctrl = jnp.asarray(control_bin_flags(self.n_bins, qcfg.dt_s,
                                                 acfg.interval_s))
            admit_floor = np.ones((P, self.n_gw_stations, self.n_bins))
            margin = acfg.target_margin
            ttft0 = jnp.asarray(self._adm_ttft0)
            tpot0 = jnp.asarray(self._adm_tpot0)
            gw_idx = jnp.asarray(self._adm_gw_idx)
            exp_idx = jnp.asarray(self._adm_exp_idx)

        gw_wait = np.zeros((P, M, L))
        ex_max = np.zeros((P, M, L))
        gw_over = np.zeros((P, M, L), dtype=bool)
        ex_over = np.zeros((P, M, L), dtype=bool)
        n_iter = 1 if zero_load else max(1, qcfg.iterations)
        for _ in range(n_iter):
            layer_arr, exp_arr, tok_total, seg_incl, c0 = \
                self._schedule(gw_wait, ex_max, start_pref)
            work = self._bin_work(layer_arr, exp_arr,
                                  active[None, :] & ~shed)
            if zero_load:
                break
            batch_kw = None
            scan_work = work
            if self.batching is not None:
                wdec, cnt = self._bin_work_planes(
                    layer_arr, exp_arr, active[None, :] & ~shed)
                if adm_on:
                    # The law applies inside the admission jit (the
                    # window sum is pre-applied host-side so the call
                    # carries no static argument).
                    batch_kw = dict(
                        work_dec=jnp.asarray(wdec),
                        cnt_win=jnp.asarray(windowed_counts(
                            cnt, self._batch_window)),
                        table=jnp.asarray(self._batch_table),
                        bcap=jnp.asarray(np.float64(self._batch_cap)))
                else:
                    scan_work, _ = effective_work_np(
                        work, wdec, cnt, self._batch_table,
                        self._batch_cap, self._batch_window)
            if adm_on:
                pid_kw = None
                if acfg.policy == "pid":
                    gain = np.ones(P) if acfg.gain_scale is None \
                        else np.asarray(acfg.gain_scale, dtype=np.float64)
                    pid_kw = dict(kp=jnp.asarray(acfg.kp),
                                  ki=jnp.asarray(acfg.ki),
                                  kd=jnp.asarray(acfg.kd),
                                  gain=jnp.asarray(gain))
                wait, dropped, admit = admission_queue_scan(
                    jnp.asarray(work), jnp.asarray(qcfg.buffer_s),
                    qcfg.dt_s, ttft0, tpot0, ctrl, gw_idx, exp_idx,
                    jnp.ones((P, self.n_gw_stations)),
                    margin * acfg.ttft_target_s,
                    margin * acfg.tpot_target_s,
                    acfg.increase, acfg.decrease, acfg.admit_min,
                    batching=batch_kw, pid=pid_kw)
                # Monotone outer iteration: accumulate the trace as a
                # running minimum so the shed set only grows and the
                # fixed point converges from the congested side.
                admit_floor = np.minimum(admit_floor, np.asarray(admit))
                choice, shed = resolve_admission(
                    admit_floor, self._att_bin, self._att_station,
                    self._att_feasible, self._adm_u)
                retries = np.where(shed, 0, choice)
                ingress_extra = np.take_along_axis(
                    np.moveaxis(self._att_extra, 0, 1),     # (P, A, R)
                    retries[:, None, :], axis=1)[:, 0, :]   # (P, R)
                start_pref = req.arrival_s[None, :] + ingress_extra
            else:
                wait, dropped = _fleet_queue_scan(
                    jnp.asarray(scan_work), jnp.asarray(qcfg.buffer_s),
                    qcfg.dt_s)
            wait = np.asarray(wait)
            overload = np.asarray(dropped) > 0.0
            # Exposed for the re-placement controller: the live
            # (plan, satellite, bin) backlog of the last fleet scan.
            self.last_wait = wait
            gw_wait, ex_max, gw_over, ex_over = self._gather(
                wait, overload, layer_arr, exp_arr)
        # Fold the final gather into the schedule once more so reported
        # latencies reflect the waits actually found on the last pass.
        layer_arr, exp_arr, tok_total, seg_incl, c0 = \
            self._schedule(gw_wait, ex_max, start_pref)

        last_tok = self.first_tok + req.decode_len - 1
        ttft = ingress_extra + tok_total[:, :R]                   # (P, R)
        out = dict(
            ttft=ttft, e2e=ttft + seg_incl[:, last_tok],
            tok_total=tok_total,
            tok_over=gw_over.any(axis=2) | ex_over.any(axis=2),
            shed=shed, retries=retries, work_sum=work.sum(axis=2))
        return self._finalize(active, out, adm_on, kv_slots)

    def _finalize(self, active: np.ndarray, out: dict, adm_on: bool,
                  kv_slots: int | None = None, *,
                  names: list | None = None,
                  nan_tok: np.ndarray | None = None,
                  fail_ingress: np.ndarray | None = None,
                  migration_bytes: np.ndarray | None = None
                  ) -> TrafficResult:
        """Host post-processing shared by every execution path.

        Turns one run's raw outcome tensors (``ttft``/``e2e`` (P, R),
        ``tok_total`` (P, M), ``tok_over`` (P, M), ``shed``/``retries``
        (P, R), ``work_sum`` (P, S)) into per-plan
        :class:`~repro.traffic.metrics.PlanTraffic` rows: delivery
        failure aggregation, the static KV admission cap, spans,
        utilization and the latency quantiles' NaN masking.

        The plan axis P is taken from the outcome tensors (the joint
        control plane stitches a decided schedule row onto the
        candidate rows); the keyword overrides supply that extra row's
        per-plan tables, defaulting to this simulator's own.
        """
        qcfg, req = self.qcfg, self.requests
        R = self.n_requests
        P = out["ttft"].shape[0]
        names = self.batch.names if names is None else names
        nan_tok = self.nan_tok if nan_tok is None else nan_tok
        fail_ingress = (self.fail_ingress if fail_ingress is None
                        else fail_ingress)
        migration_bytes = (self.migration_bytes if migration_bytes is None
                           else migration_bytes)
        kv = qcfg.kv_slots if kv_slots is None else kv_slots
        ttft, e2e = out["ttft"], out["e2e"]
        tok_total, shed, retries = out["tok_total"], out["shed"], \
            out["retries"]

        fail_tok = nan_tok | out["tok_over"]
        failed = fail_tok[:, :R] \
            | _segment_any(fail_tok[:, R:], self.tok_req, R)      # (P, R)
        if adm_on:
            # Shed requests are accounted separately (not involuntary
            # drops); admitted requests entered via a feasible attempt.
            failed = failed | shed
        else:
            failed = failed | fail_ingress

        # KV admission cap: reject arrivals that would exceed the
        # in-flight budget (first-order: in-flight counted over all
        # offered requests).  The adaptive controller replaces this cap.
        admitted = np.ones((P, R), dtype=bool)
        if kv > 0 and not adm_on:
            comp = req.arrival_s[None, :] + np.nan_to_num(
                e2e, nan=np.inf, posinf=np.inf)
            comp = np.where(active[None, :], comp, -np.inf)
            n_inactive = int((~active).sum())
            arrived = np.cumsum(active)                           # (R,)
            # Batched searchsorted: one stable argsort per plan ranks
            # the sorted completion row against the (already sorted)
            # arrivals; completions sort before equal arrivals (stable,
            # first half), reproducing searchsorted side="right".
            keys = np.concatenate([
                np.sort(comp, axis=1),
                np.broadcast_to(req.arrival_s[None, :], (P, R))], axis=1)
            order = np.argsort(keys, axis=1, kind="stable")
            pos = np.empty_like(order)
            np.put_along_axis(pos, order, np.arange(2 * R)[None, :],
                              axis=1)
            done = pos[:, R:] - np.arange(R)[None, :] - n_inactive
            admitted = (arrived[None, :] - done) <= kv
        failed = failed | ~admitted

        served = active[None, :] & ~failed                        # (P, R)
        span = max(float(req.arrival_s[active].max()
                         - req.arrival_s[active].min()), qcfg.dt_s) \
            if active.any() else qcfg.dt_s
        # Offered utilization over the arrival window (> 1 = overload).
        util = out["work_sum"] / span                             # (P, S)

        plans_out = []
        for p in range(P):
            with np.errstate(invalid="ignore"):
                tpot = (e2e[p] - ttft[p]) / req.decode_len
            plans_out.append(PlanTraffic(
                plan_name=names[p],
                active=active.copy(),
                served=served[p],
                ttft_s=np.where(served[p], ttft[p], np.nan),
                tpot_s=np.where(served[p], tpot, np.nan),
                e2e_s=np.where(served[p], e2e[p], np.nan),
                decode_len=req.decode_len,
                station_util=util[p],
                span_s=span,
                token_total_s=tok_total[p],
                shed=(shed[p] & active) if adm_on else None,
                retries=np.where(served[p], retries[p], 0)
                if adm_on else None,
                migration_bytes=float(migration_bytes[p]),
            ))
        return TrafficResult(plans=plans_out, requests=req,
                             slots=self.slots, n_bins=self.n_bins,
                             dt_s=qcfg.dt_s)


def simulate_traffic(
    plans: list,
    topo: TopologySample,
    activation: ActivationModel,
    workload: MoEWorkload,
    compute: ComputeConfig,
    requests: RequestBatch,
    rng: np.random.Generator,
    qcfg: QueueConfig = QueueConfig(),
    ground: GroundSegment | None = None,
    **kwargs,
) -> TrafficResult:
    """One-shot convenience wrapper: build a :class:`FleetSim` and run it
    with every request active.

    Args:
        plans: Placement-plan sweep.
        topo: Sampled topology.
        activation: Expert-activation model.
        workload: FLOP model of the served MoE.
        compute: FLOPs -> seconds conversion.
        requests: The request trace.
        rng: Randomness for engine draws / admission uniforms.
        qcfg: Queueing/admission parameters.
        ground: Optional ground segment.
        **kwargs: Forwarded to :class:`FleetSim`.

    Returns:
        The :class:`~repro.traffic.metrics.TrafficResult` of one full run.
    """
    sim = FleetSim(plans, topo, activation, workload, compute, requests,
                   rng, qcfg=qcfg, ground=ground, **kwargs)
    return sim.run()
