"""Discrete-time per-satellite service model for request-level serving.

Every satellite of the constellation is a FIFO work queue (stations are
keyed by satellite id, S = V): a token deposits on the L gateway
satellites (attention + gating + lm-head service) and the per-layer
expert satellites (FFN service) of *the plan its topology slot selects*
— plans are time-indexed :class:`~repro.core.schedule.PlanSchedule`
entries, plain plans riding as constant schedules.  Colocated experts
share their satellite's queue (the queue-theoretic face of the Eq. 43
contention term), and a plan switch at a slot boundary redirects new
deposits while the old plan's backlog drains in place, with the moved
expert weights occupying destination queues as background load.  The
simulator is deliberately split into

1. a **base schedule** — per-token zero-load trajectories straight from
   the batched plan-evaluation engine (``core.engine.evaluate_plans``
   with wall-clock-derived slots and shared expert draws), so at zero
   load the traffic subsystem reproduces the engine exactly;
2. a **fleet queue kernel** — one ``lax.scan`` over time bins with the
   (plans, stations) backlog matrix as carry, vectorized over every
   plan of the sweep.  Backlogs are capped (finite buffers: overflow =
   backpressure drop) and each arrival's waiting time is the backlog it
   finds (exact for Poisson arrivals by PASTA, up to the O(dt) binning
   error the M/D/1 test bounds against Pollaczek-Khinchine);
3. a **closed-loop fixed point** — waits delay a token's delivery, and
   delivery times gate the autoregressive chain, so the schedule and
   the queue state are mutually dependent.  ``run`` iterates
   schedule -> bin -> scan -> gather a configurable number of times
   (``QueueConfig.iterations``): iteration 1 is the open-loop
   approximation, further iterations let congested tokens arrive
   *after* the backlog they caused has drained, which removes the
   open-loop bias of billing one backlog episode to every token of a
   request.  Deposits larger than one bin of service are spread over
   consecutive bins (chunked-prefill semantics, like production
   continuous-batching schedulers).

Two admission regimes guard KV-cache memory and the latency SLO:

* the legacy **static cap** — a request arriving when more than
  ``kv_slots`` requests are in flight is rejected (its offered load
  still occupies the queues: rejection happens at the ingress gateway
  *after* the uplink, the conservative accounting);
* the **latency-target controller** (``QueueConfig.admission`` with
  policy ``"aimd"``, see :mod:`repro.traffic.admission`) — an AIMD loop
  carried through the fleet scan observes the windowed critical-path
  backlog and sheds load *before* the target is crossed.  Rejections
  happen at the ground gateway before the uplink (shed load never
  enters the queues), and rejected requests retry at the next-best
  visible gateway with the retry latency accounted in TTFT/E2E.

``FleetSim`` precomputes everything rate-independent once (engine pass,
station indices, chunk layout) so a saturation sweep replays only the
binning + scan + gather per tested rate — no Python loop over requests
or tokens anywhere on the hot path.

Two execution paths share that precompute:

* the **fused device path** (``run`` / ``run_many``) — the whole
  schedule -> bin -> scan -> gather fixed point is one jitted
  ``lax.fori_loop`` (:func:`_fused_core`): the dense work tensor is
  built on device by a scatter-add deposit
  (:mod:`repro.kernels.deposit` on TPU, its jnp reference elsewhere),
  lives time-major, and never crosses the host boundary between
  iterations.  ``run_many`` vmaps the same core over a
  thinning-fraction (or admission-target) axis, so an entire saturation
  sweep is one compile + one launch.  The core is module-level and
  takes every per-simulator tensor as an argument, so fleet runs with
  equal shapes — every ``run_many`` rate, every re-placement
  decide/evaluate round — reuse one compile cache entry.  Dtype policy
  mirrors the host path exactly: schedules/bins/deposits in float64
  (``jax.experimental.enable_x64`` scoped to these launches), the
  backlog scan in float32 — the downcast ``run_legacy``'s jitted scans
  have always applied — so the two paths agree to the last bit in
  practice;
* the **legacy host path** (``run_legacy``) — the original NumPy
  fixed-point loop, kept verbatim as the authoritative semantic anchor.
  ``tests/test_fleet_perf.py`` pins fused<->legacy parity on identical
  served/shed sets and rtol <= 1e-5 latency quantiles.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64 as _x64

from repro.core import (ScheduleBatch, evaluate_schedules,
                        schedule_ingress_offsets)
from repro.obs.probes import ProbeConfig, ProbeRecord, make_buffers
from repro.kernels import ops as _kernel_ops
from repro.core.activation import ActivationModel
from repro.core.calibration import resolve_service_model
from repro.core.latency import ComputeConfig, TopologySample
from repro.core.schedule import as_schedule, slot_of_time
from repro.core.workload import MoEWorkload

from .admission import (AdmissionConfig, admission_queue_scan,
                        control_bin_flags, resolve_admission)
from .batching import (BatchingConfig, batch_speedup_at,
                       batched_effective_work, effective_work_np,
                       windowed_counts, windowed_counts_jnp)
from .ground import GroundSegment
from .metrics import PlanTraffic, TrafficResult
from .requests import RequestBatch


@dataclasses.dataclass(frozen=True)
class QueueConfig:
    """Discrete-time queueing parameters.

    Attributes:
        dt_s: Time-bin width.  Per-visit service times below dt never
            self-queue; the binning error is O(dt).
        buffer_s: Per-station backlog cap in seconds of work; arrivals
            overflowing it are dropped (backpressure).
        kv_slots: Max requests concurrently holding KV cache (0 = no
            admission cap).  Ignored when the adaptive controller is
            active — the controller *replaces* the static cap.
        slot_period_s: Wall-clock seconds per topology slot (ties tokens
            to the constellation's time-varying graph; default is a
            550 km LEO period split over 20 slots).
        tail_s: Extra horizon past the last zero-load completion so
            in-flight requests can drain.  Congestion-stretched
            schedules beyond it clip into the final bin (such runs are
            deep in SLO failure anyway).
        iterations: Schedule<->queue fixed-point iterations (1 = open
            loop).
        admission: Optional :class:`~repro.traffic.admission
            .AdmissionConfig`; policy ``"aimd"`` switches the run loop
            to the latency-target controller with gateway retry.
        migration_bytes_per_expert: Weight bytes one expert drags to a
            new satellite when a :class:`~repro.core.schedule
            .PlanSchedule` switches plans at a slot boundary.
        migration_rate_gbps: ISL share available to weight migration;
            each moved expert occupies its destination satellite's queue
            for ``bytes * 8 / rate`` seconds of background load.
    """

    dt_s: float = 0.05
    buffer_s: float = 10.0
    kv_slots: int = 0
    slot_period_s: float = 300.0
    tail_s: float = 120.0
    iterations: int = 3
    admission: AdmissionConfig | None = None
    migration_bytes_per_expert: float = 1e6
    migration_rate_gbps: float = 10.0


# --------------------------------------------------------------------- #
# The fleet queue kernel
# --------------------------------------------------------------------- #


@jax.jit
def _fleet_queue_scan(work, cap, dt):
    """Scan the (P, S) backlog matrix over T time bins.

    work: (P, S, T) seconds of work arriving per bin.
    cap:  scalar or (S,) backlog cap in seconds.
    Returns (wait, dropped), both (P, S, T): ``wait[..., t]`` is the
    backlog an arrival in bin t finds (work deposited in bin t is seen
    by later bins only); ``dropped`` is the overflow discarded per bin.
    """
    def _step(backlog, w_t):
        wait = backlog
        total = backlog + w_t
        dropped = jnp.maximum(total - cap, 0.0)
        backlog = jnp.maximum(jnp.minimum(total, cap) - dt, 0.0)
        return backlog, (wait, dropped)

    p, s, _ = work.shape
    backlog0 = jnp.zeros((p, s), dtype=work.dtype)
    _, (wait, dropped) = jax.lax.scan(_step, backlog0,
                                      jnp.moveaxis(work, 2, 0))
    return jnp.moveaxis(wait, 0, 2), jnp.moveaxis(dropped, 0, 2)


def station_waiting_times(
    arrival_s: np.ndarray,
    service_s: np.ndarray | float,
    dt_s: float,
    buffer_s: float = np.inf,
    horizon_s: float | None = None,
    batching: BatchingConfig | None = None,
) -> np.ndarray:
    """Per-arrival waiting times at one FIFO station via the fleet kernel.

    Runs the same discrete-time scan the fleet simulator uses (P=1, S=1)
    and refines the bin-resolution backlog with the exact within-bin
    Lindley correction: an arrival at offset ``delta`` into bin b waits

        max(0, backlog_at_bin_start + work_of_earlier_same_bin_arrivals
               - delta),

    since the server drains continuously through the bin.  This is the
    single-station reference the M/D/1 Pollaczek-Khinchine test checks.

    Args:
        arrival_s: (n,) sorted arrival times, seconds.
        service_s: Scalar or (n,) per-arrival service demand, seconds.
        dt_s: Time-bin width of the underlying scan.
        buffer_s: Backlog cap (overflow is dropped), default unbounded.
        horizon_s: Optional simulation horizon (defaults to the last
            arrival).
        batching: Optional :class:`~repro.traffic.batching
            .BatchingConfig` — applies the continuous-batching law
            (deposit-time work scaling by the windowed-occupancy
            speedup; see :mod:`repro.traffic.batching`) to this
            station, arrivals counting one occupancy unit each.
            ``None`` is the exact FIFO reference.

    Returns:
        (n,) waiting time each arrival experiences before service.
    """
    t = np.asarray(arrival_s, dtype=np.float64)
    if len(t) and not (np.diff(t) >= 0).all():
        raise ValueError("arrivals must be sorted")
    s = np.broadcast_to(np.asarray(service_s, dtype=np.float64), t.shape)
    horizon = (float(t[-1]) if len(t) else 0.0) \
        if horizon_s is None else horizon_s
    n_bins = int(np.floor(horizon / dt_s)) + 2
    bins = np.minimum((t / dt_s).astype(np.int64), n_bins - 1)

    work = np.bincount(bins, weights=s, minlength=n_bins)
    sp_bin = np.ones(n_bins)
    if batching is not None:
        cnt = np.bincount(bins, minlength=n_bins).astype(np.float64)
        table = batching.resolve_table()
        work, _ = effective_work_np(
            work, work, cnt, table, batching.b_cap,
            batching.window_bins(dt_s))
        sp_bin, _ = batch_speedup_at(
            windowed_counts(cnt, batching.window_bins(dt_s)),
            table, batching.b_cap)
    wait_bins = np.asarray(
        _fleet_queue_scan(jnp.asarray(work[None, None, :]),
                          jnp.asarray(buffer_s), dt_s)[0])[0, 0]

    # Within-bin FIFO: prior work of same-bin arrivals (scaled by the
    # bin's batching speedup when enabled), minus the time already
    # elapsed inside the bin.
    cs = np.cumsum(s)
    first = np.searchsorted(bins, bins, side="left")
    prior = ((cs - s) - (cs[first] - s[first])) / sp_bin[bins]
    delta = t - bins * dt_s
    return np.maximum(wait_bins[bins] + prior - delta, 0.0)


# --------------------------------------------------------------------- #
# Helpers
# --------------------------------------------------------------------- #


def _exclusive_cumsum(a: np.ndarray, axis: int) -> np.ndarray:
    out = np.cumsum(a, axis=axis)
    return out - a


def _segment_any(flags: np.ndarray, seg_ids: np.ndarray,
                 n_seg: int) -> np.ndarray:
    """OR-reduce boolean ``flags`` (P, E) over segments of the last axis."""
    p, _ = flags.shape
    idx = np.arange(p)[:, None] * n_seg + seg_ids[None, :]
    hits = np.bincount(idx.ravel(), weights=flags.ravel().astype(np.float64),
                       minlength=p * n_seg)
    return hits.reshape(p, n_seg) > 0.0


def _station_quantile(values: np.ndarray, ok: np.ndarray,
                      station: np.ndarray, n_stations: int,
                      q: float) -> np.ndarray:
    """(P, G) per-(plan, station) q-quantile of ``values`` (P, R) over
    the requests with ``ok`` set; stations with no valid request fall
    back to the plan-wide quantile (0 when nothing is valid at all)."""
    p = values.shape[0]
    out = np.zeros((p, n_stations))
    overall = np.array([
        np.quantile(values[i][ok[i]], q) if ok[i].any() else 0.0
        for i in range(p)])
    for g in range(n_stations):
        sel = ok & (station[None, :] == g)
        for i in range(p):
            out[i, g] = np.quantile(values[i][sel[i]], q) if sel[i].any() \
                else overall[i]
    return out


# --------------------------------------------------------------------- #
# The fused device fixed point
# --------------------------------------------------------------------- #

#: Incremented once per trace of :func:`_fused_core` — the compilation
#: counter ``tests/test_fleet_perf.py`` pins (a whole rate sweep through
#: ``run_many`` must cost exactly one trace).
FUSED_TRACE_COUNT = 0

#: The compacted chunk table is padded to a multiple of this, so sweeps
#: with similar activity reuse the fused kernel's compile cache.
_CHUNK_BLOCK = 8192


def _fused_core(consts, chunks, work0, work0_sum, ttft_target, tpot_target,
                pbuf, batch, n_iter, n_bins, n_rows, adm_on, use_pallas,
                want_wait, probes, batch_window):
    """Single-launch fleet fixed point (the device half of ``FleetSim.run``).

    Rolls the legacy schedule -> bin -> scan -> gather iteration into one
    ``lax.fori_loop`` over device-resident precomputes, batched over an
    explicit sweep axis F, so the dense work tensor never crosses the
    host boundary between iterations.  Pure module-level function: every
    per-simulator tensor arrives via ``consts`` (the pytree built by
    :meth:`FleetSim._device_tables`), so fleet runs with equal shapes
    share one jit cache entry.

    Two compactions keep the device arrays proportional to *offered*
    work rather than to the constellation:

    * **row compaction** — queue rows are the (plan, satellite) pairs
      that can ever receive a deposit, observation or gather
      (``FleetSim._build_row_map``), not all P x V pairs; zero-work
      stations contribute exactly zero in both paths, so dropping them
      is exact;
    * **chunk compaction** — ``chunks`` holds only the (sweep entry,
      chunk) pairs whose request is active (built host-side per launch
      from the masks, padded to a stable block size), so a thinned rate
      sweep deposits only what it offers.

    Layout/dtype policy (pinned by the parity tests): schedules, bins
    and deposits compute in float64 exactly like the host path; the work
    tensor lives **time-major** ``(T, F, rows)`` so the scan consumes it
    with no transposes; the backlog scan itself runs in float32 — the
    same downcast the legacy path's jitted scans have always applied —
    and emits *only* the wait trace (overload flags are recovered at the
    gather points from ``wait + work > cap``, bit-identical to the
    legacy ``dropped > 0``).

    The first fixed-point iteration is **peeled**: its schedule is the
    zero-wait schedule, known at construction, so its offered-work plane
    ``work0`` arrives as a launch input (one host ``np.bincount`` over
    the compacted chunks — not a per-iteration transfer) and the device
    spends its scatter budget only on the congestion-corrected
    iterations 2..n.

    Args:
        consts: Device-resident precompute pytree (see
            :meth:`FleetSim._device_tables` for the keys).
        chunks: Compacted deposit table — ``src`` (gather index into the
            F-flattened [layer_arr | exp_arr] pair), ``offs`` (chunk
            offset in bins), ``work`` (seconds), ``fprow`` (target row
            in the (F * rows) plane), and under admission ``fpr`` (index
            into the (F, P, R) shed mask).  Entries are grouped by row
            (static sort), so the scatter walks the plane row-major.
        work0: (F, rows, T) float32 iteration-1 offered work (migration
            background load already added).
        work0_sum: (F, rows) float64 per-row sum of iteration-1 work
            (utilization reporting when ``n_iter == 1``).
        ttft_target: (F,) margin-scaled TTFT targets (admission only).
        tpot_target: (F,) margin-scaled TPOT targets (admission only).
        n_iter: Static — schedule<->queue fixed-point iterations.
        n_bins: Static — T, the time-bin count.
        n_rows: Static — compacted queue-row count.
        adm_on: Static — run the AIMD admission regime.
        use_pallas: Static — deposit via the Pallas kernel (TPU; f32
            accumulation) instead of the jnp scatter-add reference.
        want_wait: Static — carry and return the final backlog trace
            (the re-placement controller's observation).
        pbuf: Probe ring buffers (:func:`repro.obs.probes.make_buffers`
            pytree; donated by the probed jit wrapper) — an empty dict
            when ``probes`` is None.
        batch: Continuous-batching pytree — an **empty dict** when
            batching is off (the trace then contains no batching ops and
            shares the batching-free compile-cache entry).  When on:
            ``table`` (the padded speedup interpolation table, f64),
            ``bcap`` (scalar admissible-batch bound) and — only for the
            probed ``n_iter == 1`` peel — ``beff0`` (F, rows, T) f32,
            the host-computed iteration-1 batch occupancy the probe
            channel records.  The law itself is deposit-time scaling
            (see :mod:`repro.traffic.batching`): the decode-work and
            occupancy-count planes ride two extra chunk channels
            (``wdec``/``cntw``) through the same scatter, and the scan
            consumes ``work + work_dec * (1/s(B_eff) - 1)``.
        batch_window: Static — occupancy window in bins (0 when batching
            is off; >= 1 when on).
        probes: Static — ``None`` (the probe-free kernel, byte-identical
            to the pre-observability trace) or the resolved
            ``(capacity, stride)`` pair of a
            :class:`~repro.obs.probes.ProbeConfig`.  When set, the
            backlog/admission scans ring-write per-bin fleet state into
            ``pbuf`` via ``dynamic_update_slice`` (each fixed-point
            iteration rewrites the same slots, so the final iteration
            wins) and the output dict gains ``probes`` (the written
            buffers) plus ``probe_gw_wait``/``probe_ex_wait``
            (F, P, M, L) — the final per-token per-layer queue waits the
            flight recorder splices into the Eq. 43 breakdown.

    Returns:
        Dict of outputs with a leading F axis: ``ttft``/``e2e``
        (F, P, R), ``tok_total`` (F, P, M), ``tok_over`` (F, P, M) bool,
        ``shed``/``retries`` (F, P, R), ``work_sum`` (F, rows), iff
        ``want_wait`` — ``wait`` (T, F, rows) float32 — and iff
        ``probes`` the probe outputs described above.
    """
    global FUSED_TRACE_COUNT
    FUSED_TRACE_COUNT += 1
    q = consts
    first_tok, tok_req = q["first_tok"], q["tok_req"]
    F = ttft_target.shape[0]
    R = first_tok.shape[0]
    P, M, L = q["eff_layer"].shape
    T, SR = n_bins, n_rows
    dt = q["dt"]
    cap32, dt32 = q["cap32"], q["dt32"]
    f32, f64 = jnp.float32, jnp.float64

    def to_bins(times):
        finite = jnp.isfinite(times)
        b = jnp.clip((jnp.where(finite, times, 0.0) / dt)
                     .astype(jnp.int64), 0, T - 1)
        return jnp.where(finite, b, 0), finite

    if probes is not None:
        p_cap, p_stride = probes

    def probe_write(bufs, t, wait, w_t, drop, qhat=None, admit=None,
                    win=None, beff=None):
        # Ring write via dynamic_update_slice: bin t lands in slot
        # (t // stride) % capacity; bins the stride skips write the
        # sentinel scratch slot (index capacity), so the scan step is
        # branch-free and XLA keeps the buffers aliased in the carry.
        # Under batching a fourth row channel records the per-bin batch
        # occupancy B_eff.
        rec = (t % p_stride) == 0
        slot = jnp.where(rec, (t // p_stride) % p_cap, p_cap)
        chans = [wait, w_t, drop] + ([] if beff is None else [beff])
        out = dict(bufs)
        out["rows"] = jax.lax.dynamic_update_slice(
            bufs["rows"], jnp.stack(chans)[None],
            (slot, 0, 0, 0))
        if qhat is not None:
            out["aimd"] = jax.lax.dynamic_update_slice(
                bufs["aimd"], jnp.stack([qhat, win])[None],
                (slot, 0, 0, 0))
            out["admit"] = jax.lax.dynamic_update_slice(
                bufs["admit"], admit[None], (slot, 0, 0, 0))
        return out

    def schedule(gw_wait, ex_max, start_pref):
        # jnp port of FleetSim._schedule + ._chain (identical math),
        # batched over the leading F axis.
        lay_cost = q["eff_layer"][None] + gw_wait + ex_max
        tok_total = q["tok_base"][None] + gw_wait.sum(3) + ex_max.sum(3)
        dec = tok_total[:, :, R:]
        cs = jnp.cumsum(dec, axis=2)
        excl = cs - dec
        base = excl[:, :, first_tok][:, :, tok_req]
        c0 = start_pref + tok_total[:, :, :R]
        start_dec = c0[:, :, tok_req] + (excl - base)
        start_all = jnp.concatenate([start_pref, start_dec], axis=2)
        layer_arr = start_all[..., None] \
            + (jnp.cumsum(lay_cost, axis=3) - lay_cost)
        exp_arr = layer_arr + gw_wait + q["gw_service"][None, None, :, None]
        return layer_arr, exp_arr, tok_total, cs - base

    def bin_work(layer_arr, exp_arr, shed):
        # jnp port of FleetSim._bin_work: every active chunk reads its
        # event's arrival time straight from the F-flattened
        # [layer_arr | exp_arr] pair via the precomputed gather index,
        # then scatter-adds the row-major (F * rows, T) plane in f64
        # (chunks are statically row-grouped, so consecutive updates
        # stay within one row's cache-resident T-span).
        flat_t = jnp.concatenate([layer_arr.reshape(F, -1),
                                  exp_arr.reshape(F, -1)],
                                 axis=1).reshape(-1)
        b_ch, fin = to_bins(flat_t[chunks["src"]])
        bins = jnp.minimum(b_ch + chunks["offs"], T - 1)

        def scat(vals):
            if use_pallas:
                # TPU: one-hot-matmul deposit kernel (f32 accumulation —
                # TPUs have no f64; CPU CI parity runs the reference path).
                return _kernel_ops.deposit(
                    chunks["fprow"], bins.astype(jnp.int32),
                    vals.astype(f32), F * SR, T).astype(f64)
            # int64 flat index: F * rows * T can exceed 2^31 on large
            # worlds/sweeps (x64 is enabled for every fused launch).
            flat = chunks["fprow"].astype(jnp.int64) * T + bins
            return jnp.zeros(F * SR * T).at[flat].add(
                vals, mode="promise_in_bounds")

        vals = chunks["work"] * fin
        if adm_on:
            # Shed requests stop depositing (the activity compaction
            # already removed thinned-out requests).
            keep = ~shed.reshape(-1)[chunks["fpr"]]
            vals = vals * keep
        work = scat(vals).reshape(F, SR, T)
        if "mig_dense" in q:
            work = work + q["mig_dense"][None]
        if not batch:
            return work, work, None
        # Continuous batching (deposit-time scaling): the decode-work
        # and occupancy-count channels ride the same scatter, and the
        # scan consumes work + work_dec * (1/s(B_eff) - 1).  The
        # migration background plane stays outside work_dec — it is not
        # batchable decode work.
        vdec, vcnt = chunks["wdec"] * fin, chunks["cntw"] * fin
        if adm_on:
            vdec, vcnt = vdec * keep, vcnt * keep
        work_dec = scat(vdec).reshape(F, SR, T)
        cnt = scat(vcnt).reshape(F, SR, T)
        work_eff, beff = batched_effective_work(
            work, work_dec, windowed_counts_jnp(cnt, batch_window),
            batch["table"], batch["bcap"])
        return work_eff, work, beff

    def fleet_scan(work32, bufs=None, beff_t=None):
        # The _fleet_queue_scan backlog recursion, time-major and
        # wait-only (f32, exactly the legacy downcast).  With ring
        # buffers passed (the probed final iteration only), the scan
        # carry additionally threads them and every stride-th bin
        # records (backlog, offered work, dropped) — the bufs-free
        # branch below is byte-identical to the legacy scan.  With
        # ``beff_t`` (probed batching runs) the ring gains the
        # batch-occupancy channel.
        if bufs is None:
            def step(b, w_t):
                wait = b
                b = jnp.maximum(jnp.minimum(b + w_t, cap32) - dt32, 0.0)
                return b, wait
            _, wait = jax.lax.scan(step, jnp.zeros((F, SR), f32), work32)
            return wait                                   # (T, F, SR)

        def step(carry, xs):
            b, pb = carry
            if beff_t is None:
                (w_t, t), be = xs, None
            else:
                w_t, t, be = xs
            wait = b
            offered = b + w_t
            drop = jnp.maximum(offered - cap32, 0.0)
            pb = probe_write(pb, t, wait, w_t, drop, beff=be)
            b = jnp.maximum(jnp.minimum(offered, cap32) - dt32, 0.0)
            return (b, pb), wait
        xs = (work32, jnp.arange(T))
        if beff_t is not None:
            xs = xs + (beff_t,)
        (_, bufs), wait = jax.lax.scan(
            step, (jnp.zeros((F, SR), f32), bufs), xs)
        return wait, bufs

    def adm_scan(work32, bufs=None, beff_t=None):
        # The admission_queue_scan recursion (bit-identical backlog and
        # AIMD cell), time-major over compacted rows, emitting wait +
        # the admit trace.  With ring buffers passed (the probed final
        # iteration only), the carry also threads them, recording the
        # fleet channels plus the AIMD cell state (backlog estimate
        # qhat, per-gateway admit, window peak); the bufs-free branch
        # is byte-identical to the legacy scan.
        tt32 = ttft_target.astype(f32)[:, None, None]     # (F, 1, 1)
        tp32 = tpot_target.astype(f32)[:, None]           # (F, 1)
        n_layers = q["gw_rows_bin"].shape[2]

        def cell(backlog, admit, win, w_t, is_ctrl, gw_t, exp_t):
            wait = backlog
            offered = backlog + w_t
            backlog = jnp.maximum(jnp.minimum(offered, cap32) - dt32, 0.0)
            gw = backlog[:, gw_t].sum(axis=2)                    # (F, P)
            exp = backlog[:, exp_t] \
                .reshape(F, P, n_layers, -1).max(axis=3).sum(axis=2)
            win = jnp.maximum(win, gw + exp)
            over = ((q["ttft0"][None] + win[..., None]) > tt32) \
                | ((q["tpot0"][None] + win) > tp32)[..., None]   # (F,P,G)
            stepped = jnp.where(
                over,
                jnp.maximum(admit * q["decrease"], q["admit_min"]),
                jnp.minimum(admit + q["increase"], 1.0))
            admit_next = jnp.where(is_ctrl, stepped, admit)
            win_next = jnp.where(is_ctrl, 0.0, win)
            return backlog, admit_next, win_next, wait, offered, gw + exp

        n_gw = q["ttft0"].shape[1]
        carry0 = (jnp.zeros((F, SR), f32), jnp.ones((F, P, n_gw), f32),
                  jnp.zeros((F, P), f32))
        if bufs is None:
            def step(carry, xs):
                backlog, admit, win = carry
                w_t, is_ctrl, gw_t, exp_t = xs
                backlog, admit_next, win_next, wait, _, _ = cell(
                    backlog, admit, win, w_t, is_ctrl, gw_t, exp_t)
                return (backlog, admit_next, win_next), (wait, admit)
            _, (wait, admit) = jax.lax.scan(
                step, carry0,
                (work32, q["ctrl"], q["gw_rows_bin"], q["exp_rows_bin"]))
            return wait, admit             # (T, F, SR), (T, F, P, G)

        def step(carry, xs):
            backlog, admit, win, pb = carry
            if beff_t is None:
                (w_t, is_ctrl, gw_t, exp_t, t), be = xs, None
            else:
                w_t, is_ctrl, gw_t, exp_t, t, be = xs
            backlog, admit_next, win_next, wait, offered, qhat = cell(
                backlog, admit, win, w_t, is_ctrl, gw_t, exp_t)
            drop = jnp.maximum(offered - cap32, 0.0)
            pb = probe_write(pb, t, wait, w_t, drop, qhat=qhat,
                             admit=admit_next, win=win_next, beff=be)
            return (backlog, admit_next, win_next, pb), (wait, admit)
        xs = (work32, q["ctrl"], q["gw_rows_bin"], q["exp_rows_bin"],
              jnp.arange(T))
        if beff_t is not None:
            xs = xs + (beff_t,)
        (_, _, _, bufs), (wait, admit) = jax.lax.scan(
            step, carry0 + (bufs,), xs)
        return wait, admit, bufs

    def gather(wait_t, work32, gw_b, gw_fin, ex_b, ex_fin):
        # jnp port of FleetSim._gather: wait read from the time-major
        # trace, work from the row-major plane; overload =
        # wait + work > cap is the legacy dropped > 0 flag.
        f_idx = jnp.arange(F)[:, None, None, None]
        gw_rows = q["gw_rows"][None]                  # (1, P, M, L)
        ex_rows = q["ex_rows"][None]                  # (1, P, M, L, K)
        w_g = wait_t[gw_b, f_idx, gw_rows]
        gw_wait = jnp.where(gw_fin, w_g, 0.0).astype(f64)
        gw_over = gw_fin & ((w_g + work32[f_idx, gw_rows, gw_b]) > cap32)
        ex_b5, ex_f5 = ex_b[..., None], ex_fin[..., None]
        f_idx5 = f_idx[..., None]
        w_e = wait_t[ex_b5, f_idx5, ex_rows]
        ex_wait = jnp.where(ex_f5, w_e, 0.0).astype(f64)
        ex_over = ex_f5 & ((w_e + work32[f_idx5, ex_rows, ex_b5]) > cap32)
        return gw_wait, ex_wait.max(axis=4), gw_over, ex_over.any(axis=4)

    def finish_iter(work32, work_sum, gw_b, gw_fin, ex_b, ex_fin, c,
                    record=False, beff=None):
        # Scan + admission resolve + gather for one iteration whose
        # offered work (f32, row-major (F, SR, T)) is already binned;
        # only the scan input is transposed to time-major.  ``record``
        # (static) threads the probe rings through this iteration's
        # scan — set on the peeled *final* iteration only, so the probe
        # cost is paid once per launch, not once per iteration.  Under
        # batching ``work32`` is the *effective* (speedup-scaled) work —
        # gather overload stays consistent with the scan — while
        # ``work_sum`` stays the raw offered sum; ``beff`` feeds the
        # recorded batch-occupancy probe channel.
        work32_t = jnp.moveaxis(work32, 2, 0)             # (T, F, SR)
        beff_t = None
        if record and beff is not None:
            beff_t = jnp.moveaxis(beff.astype(f32), 2, 0)
        pb = c.get("probes")
        if adm_on:
            if not record:
                wait_t, admit = adm_scan(work32_t)
            else:
                wait_t, admit, pb = adm_scan(work32_t, pb, beff_t)
            # Monotone outer iteration (see run_legacy): the admit trace
            # accumulates as a running minimum so the shed set only grows.
            admit_floor = jnp.minimum(c["admit_floor"], admit)
            adm = jnp.transpose(
                admit_floor[q["att_bin"], :, :, q["att_station"]],
                (2, 3, 0, 1))                             # (F, P, A, R)
            ok = (q["adm_u"][None, None] < adm) & q["att_feasible"][None]
            shed = ~ok.any(axis=2)                        # (F, P, R)
            retries = jnp.where(shed, 0, jnp.argmax(ok, axis=2))
            ingress_extra = jnp.take_along_axis(
                jnp.broadcast_to(q["att_extra"][None],
                                 (F,) + q["att_extra"].shape),
                retries[:, :, None, :], axis=2)[:, :, 0, :]
        else:
            if not record:
                wait_t = fleet_scan(work32_t)
            else:
                wait_t, pb = fleet_scan(work32_t, pb, beff_t)
            shed, retries = c["shed"], c["retries"]
            admit_floor = c["admit_floor"]
            ingress_extra = c["ingress_extra"]
        gw_wait, ex_max, gw_over, ex_over = gather(
            wait_t, work32, gw_b, gw_fin, ex_b, ex_fin)
        nxt = dict(gw_wait=gw_wait, ex_max=ex_max, gw_over=gw_over,
                   ex_over=ex_over, shed=shed, retries=retries,
                   admit_floor=admit_floor, ingress_extra=ingress_extra,
                   work_sum=work_sum)
        if want_wait:
            nxt["wait"] = wait_t
        if record:
            nxt["probes"] = pb
        return nxt

    def body(_, c, record=False):
        start_pref = q["arrival_s"][None, None, :] + c["ingress_extra"]
        layer_arr, exp_arr, _, _ = schedule(c["gw_wait"], c["ex_max"],
                                            start_pref)
        work, work_raw, beff = bin_work(layer_arr, exp_arr,
                                        c["shed"])       # (F, SR, T)
        gw_b, gw_fin = to_bins(layer_arr)
        ex_b, ex_fin = to_bins(exp_arr)
        return finish_iter(work.astype(f32), work_raw.sum(axis=2),
                           gw_b, gw_fin, ex_b, ex_fin, c, record=record,
                           beff=beff)

    n_gw = q["ttft0"].shape[1] if adm_on else 1
    carry = dict(
        gw_wait=jnp.zeros((F, P, M, L)), ex_max=jnp.zeros((F, P, M, L)),
        gw_over=jnp.zeros((F, P, M, L), bool),
        ex_over=jnp.zeros((F, P, M, L), bool),
        shed=jnp.zeros((F, P, R), bool),
        retries=jnp.zeros((F, P, R), jnp.int64),
        admit_floor=jnp.ones((T, F, P, n_gw), jnp.float32),
        ingress_extra=jnp.broadcast_to(q["ingress_extra0"][None],
                                       (F, P, R)) + 0.0,
        work_sum=jnp.zeros((F, SR)),
    )
    if want_wait:
        carry["wait"] = jnp.zeros((T, F, SR), f32)
    # Peeled iteration 1: the zero-wait schedule is static, so its
    # offered work arrives pre-binned (host np.bincount) and its gather
    # bins are construction-time constants.  With probes on, the *last*
    # iteration is peeled too (its probe-recording scan is traced
    # separately), so ring writes happen exactly once per launch.
    if probes is None:
        carry = finish_iter(work0, work0_sum,
                            q["gw_b0"][None], q["gw_fin0"][None],
                            q["ex_b0"][None], q["ex_fin0"][None], carry)
        c = jax.lax.fori_loop(0, n_iter - 1, body, carry)
    elif n_iter == 1:
        carry["probes"] = pbuf
        # Peeled-final batching runs ship the host-computed iteration-1
        # occupancy (batch["beff0"]) for the probe channel; work0 itself
        # is already the host-computed effective plane.
        c = finish_iter(work0, work0_sum,
                        q["gw_b0"][None], q["gw_fin0"][None],
                        q["ex_b0"][None], q["ex_fin0"][None], carry,
                        record=True, beff=batch.get("beff0"))
    else:
        carry = finish_iter(work0, work0_sum,
                            q["gw_b0"][None], q["gw_fin0"][None],
                            q["ex_b0"][None], q["ex_fin0"][None], carry)
        c = jax.lax.fori_loop(0, n_iter - 2, body, carry)
        c["probes"] = pbuf
        c = body(0, c, record=True)
    # Fold the final gather into the schedule once more (see run_legacy).
    start_pref = q["arrival_s"][None, None, :] + c["ingress_extra"]
    _, _, tok_total, seg_incl = schedule(c["gw_wait"], c["ex_max"],
                                         start_pref)
    ttft = c["ingress_extra"] + tok_total[:, :, :R]
    out = dict(ttft=ttft, e2e=ttft + seg_incl[:, :, q["last_tok"]],
               tok_total=tok_total,
               tok_over=c["gw_over"].any(axis=3) | c["ex_over"].any(axis=3),
               shed=c["shed"], retries=c["retries"],
               work_sum=c["work_sum"])
    if want_wait:
        out["wait"] = c["wait"]
    if probes is not None:
        out["probes"] = c["probes"]
        out["probe_gw_wait"] = c["gw_wait"]
        out["probe_ex_wait"] = c["ex_max"]
    return out


#: The jitted fused fixed point.  Statics: (n_iter, n_bins, n_rows,
#: adm_on, use_pallas, want_wait, probes, batch_window); everything else
#: rides the pytrees, so any fleet run with equal shapes — every rate of
#: a sweep, every re-placement decide/evaluate round — hits one compile
#: cache entry.  Probe-free launches pass ``probes=None`` and an empty
#: pbuf pytree, and batching-free launches an empty ``batch`` pytree
#: with ``batch_window=0``, so their traced computation is byte-identical
#: to the legacy kernel.
_fused_exec = jax.jit(_fused_core,
                      static_argnums=(8, 9, 10, 11, 12, 13, 14, 15))

#: Probed variant: identical statics, but the probe ring buffers
#: (positional arg 6) are donated so XLA updates them in place instead
#: of copying the rings once per scan step.
_fused_exec_probed = jax.jit(_fused_core,
                             static_argnums=(8, 9, 10, 11, 12, 13, 14, 15),
                             donate_argnums=(6,))


# --------------------------------------------------------------------- #
# The fleet simulator
# --------------------------------------------------------------------- #


class FleetSim:
    """Request-level serving simulator for a sweep of placement plans
    *or* time-indexed :class:`~repro.core.schedule.PlanSchedule` entries
    (plain plans are wrapped into constant schedules, which reproduce
    the PR-2 static behavior bit-for-bit).

    Queue stations are keyed by **satellite id** — one FIFO work queue
    per satellite of the constellation (S = V).  Colocated experts share
    their satellite's queue by construction (the queue-theoretic face of
    Eq. 43), and a schedule that switches plans at a topology-slot
    boundary points new deposits at the incoming plan's satellites while
    the outgoing plan's backlog drains where it sits — the mechanism
    that makes live re-placement pay.  The weight bytes a switch moves
    (:meth:`~repro.core.schedule.PlanSchedule.migration_edges`, the
    ``distributed.elastic`` accounting) occupy each moved expert's
    destination-satellite queue as background load.

    Construction does all the rate-independent precompute: one batched
    engine pass over R prefill macro-tokens + N decode tokens (shared
    slots/draws across plans — common random numbers), the zero-load
    per-layer costs, every queue event's (plan, station, request, work)
    and the chunk layout.  ``run`` then iterates the schedule/queue
    fixed point for any request-activity mask — the cheap inner call of
    a saturation sweep.

    When ``qcfg.admission`` enables the AIMD policy, construction also
    precomputes the gateway-retry attempt tables (per attempt: target
    gateway, terrestrial forward + backoff + uplink + ingress-offset
    latency, feasibility) and the controller's zero-load TTFT/TPOT
    references; ``run`` then resolves per-request admission between
    fixed-point iterations from the controller trace the fleet scan
    emits (see :mod:`repro.traffic.admission` for the law).
    """

    def __init__(
        self,
        plans: list,
        topo: TopologySample,
        activation: ActivationModel,
        workload: MoEWorkload,
        compute: ComputeConfig,
        requests: RequestBatch,
        rng: np.random.Generator,
        qcfg: QueueConfig = QueueConfig(),
        ground: GroundSegment | None = None,
        ctx_len: int = 1024,
        eta: float = 1.0,
        include_lm_head: bool = True,
        batch: ScheduleBatch | None = None,
        min_bins: int = 0,
        service_model=None,
        probes: ProbeConfig | None = None,
        batching: BatchingConfig | None = None,
    ):
        """Build the simulator and run every rate-independent precompute.

        Args:
            plans: Sweep entries (P of them): plain
                :class:`~repro.core.placement.PlacementPlan` /
                :class:`~repro.core.placement.MultiExpertPlan` (held for
                the whole horizon) and/or time-indexed
                :class:`~repro.core.schedule.PlanSchedule` rows, mixed
                freely.
            topo: Sampled time-varying topology the engine pass uses.
            activation: Conditional-Poisson expert-activation model.
            workload: Per-component FLOP model of the served MoE.
            compute: FLOPs -> seconds conversion for onboard compute.
            requests: The request trace (R requests, sorted arrivals).
            rng: Source of the engine's expert draws and the admission
                uniforms (consumed at construction; runs are replayable).
            qcfg: Queueing/admission parameters.
            ground: Optional ground segment; enables uplink + ingress
                accounting and (under AIMD admission) gateway retry.
            ctx_len: Attention context length for gateway service time.
            eta: Eq. 43 compute-sharing efficiency for multi-expert plans.
            include_lm_head: Account lm-head service on the last gateway.
            batch: Optional prebuilt :class:`~repro.core.ScheduleBatch`
                to reuse the deduped Dijkstra table across simulators.
            min_bins: Floor on the time-bin count T.  The re-placement
                loop pins consecutive decide/evaluate rounds to one T so
                every round's fleet run reuses the fused fixed point's
                compile cache (a longer natural horizon still wins).
            service_model: Eq. 43 service-time source — ``None`` /
                ``"analytic"`` keeps the FLOP-count constants
                (bit-identical to the pre-calibration simulator), a
                calibrated :class:`~repro.core.calibration.ServiceModel`
                activates kernel-calibrated per-expert / per-satellite
                service and batch-size-dependent decode gateway rates
                (weight reads amortized over the estimated in-flight
                decode batch, read off the decode-attention roofline).
            probes: Optional :class:`~repro.obs.probes.ProbeConfig`.
                When set, every launch writes on-device telemetry rings
                (per-bin backlog / offered work / drops per satellite,
                plus the AIMD cell state under admission) that land in
                :attr:`last_probes` as a
                :class:`~repro.obs.probes.ProbeRecord`.  ``None`` (the
                default) keeps the fused kernel's traced computation
                bit-identical to the probe-free simulator.
            batching: Optional
                :class:`~repro.traffic.batching.BatchingConfig`.  When
                set, per-(plan, satellite) decode queues drain in
                batches of up to ``b_max`` per time bin with service
                time ``B / decode_rate(B)`` and KV-slot occupancy
                bounding the admissible batch (deposit-time scaling —
                see :mod:`repro.traffic.batching`).  ``None`` (the
                default) keeps every execution path bit-identical to
                the FIFO simulator, and so does ``b_max=1``.
        """
        self.plans = list(plans)
        self.schedules = [as_schedule(p, topo.n_slots) for p in self.plans]
        self.requests = requests
        self.qcfg = qcfg
        self.activation = activation

        P = len(self.schedules)
        R = requests.n_requests
        if R == 0:
            raise ValueError("empty request trace")
        L = activation.n_layers
        n_exp = activation.n_experts
        K = activation.top_k
        N = requests.total_decode_tokens
        M = R + N
        self.n_plans, self.n_requests = P, R
        self.n_decode_tokens, self.n_tokens = N, M
        # One FIFO work queue per satellite of the constellation.
        self.n_layers, self.n_stations = L, topo.n_sats
        self.n_topo_slots = topo.n_slots

        tok_req = requests.request_of_token()                    # (N,)
        self.tok_req = tok_req

        # --- slots from wall-clock time (one slot per request: request
        # lifetimes are seconds, a topology slot is minutes) ---------------
        slot_r = slot_of_time(requests.arrival_s, qcfg.slot_period_s,
                              topo.n_slots)
        self.slots = np.concatenate([slot_r, slot_r[tok_req]])   # (M,)

        # --- ingress mapping ----------------------------------------------
        if batch is None:
            batch = ScheduleBatch.from_schedules(self.schedules, topo,
                                                 eta=eta)
        self.batch = batch
        if ground is not None:
            ing_sat, uplink = ground.for_requests(slot_r, requests.station)
            reachable = ing_sat >= 0
            ing_off = schedule_ingress_offsets(
                batch, slot_r, np.where(reachable, ing_sat, 0))
            ing_off = np.where(reachable[None, :], ing_off, np.inf)
        else:
            uplink = np.zeros(R)
            ing_off = np.zeros((P, R))
        self.fail_ingress = ~np.isfinite(ing_off)                 # (P, R)
        self.ingress_extra = uplink[None, :] + np.where(
            self.fail_ingress, 0.0, ing_off)                      # (P, R)

        # --- engine pass: base (zero-load) per-token latencies -------------
        svc = resolve_service_model(service_model, workload, compute)
        self.service_model = svc
        # Continuous-batching statics: the padded speedup table (read
        # off the service model's batch-size-dependent decode rates),
        # the KV-bounded batch cap, and the occupancy window in bins.
        self.batching = batching
        if batching is not None:
            self._batch_table = batching.resolve_table(svc, ctx_len)
            self._batch_cap = float(batching.b_cap)
            self._batch_window = batching.window_bins(qcfg.dt_s)
        else:
            self._batch_table = None
            self._batch_cap = 0.0
            self._batch_window = 0
        draws = np.stack([activation.sample(layer, rng, M)
                          for layer in range(L)])                 # (L, M, K)
        self.draws = draws
        self.engine_results = evaluate_schedules(
            self.schedules, topo, activation, workload, compute, rng,
            n_tokens=M, ctx_len=ctx_len, include_lm_head=include_lm_head,
            eta=eta, batch=batch, slots=self.slots, draws=draws,
            service_model=svc)
        token_lat = np.stack(
            [r.token_latency_s for r in self.engine_results])     # (P, M)
        layer_lat = np.stack(
            [r.layer_latency_s for r in self.engine_results])     # (P, M, L)

        # Undeliverable tokens (unreachable satellite in that slot) fail
        # the whole request; zero them so the segmented cumsums of the
        # *other* requests sharing the token axis stay finite.
        self.nan_tok = ~np.isfinite(token_lat)
        token_lat = np.where(self.nan_tok, 0.0, token_lat)
        layer_lat = np.where(np.isfinite(layer_lat), layer_lat, 0.0)

        t_gateway = svc.gateway_s(ctx_len)
        t_expert = svc.expert_scalar
        t_head = svc.head_s if include_lm_head else 0.0
        self.t_gateway, self.t_expert = t_gateway, t_expert

        # --- zero-load per-layer costs -------------------------------------
        # Prefill macro-token: the engine token plus, per layer, the
        # incremental pipelined compute of the remaining prompt tokens
        # (the batch shares the network hops; experts each absorb a K/I
        # share of the FFN work in parallel).
        incr_layer = t_gateway + t_expert * K / n_exp
        extra_layer = (requests.prompt_len - 1).astype(np.float64) \
            * incr_layer                                          # (R,)

        if svc.per_satellite:
            # Batch-amortized gateway service (calibrated mode): estimate
            # each request's in-flight decode concurrency from the sorted
            # arrivals and the zero-load token latency, then read the
            # per-token decode service off the decode-attention roofline
            # at that batch size; a prefill amortizes the gateway weight
            # reads over its own prompt batch.
            dec_lat = np.where(self.nan_tok[:, R:], np.nan, token_lat[:, R:])
            with np.errstate(invalid="ignore"):
                mean_tok = float(np.nanmean(dec_lat)) if N else 0.0
            if not np.isfinite(mean_tok) or mean_tok <= 0.0:
                mean_tok = L * t_gateway
            dur = requests.decode_len.astype(np.float64) * mean_tok
            arr = requests.arrival_s.astype(np.float64)
            started = np.searchsorted(arr, arr, side="right")
            ended = np.searchsorted(np.sort(arr + dur), arr, side="right")
            conc = np.maximum(started - ended, 1)                 # (R,)
            self.decode_batch_est = conc
            pre_gw = requests.prompt_len.astype(np.float64) \
                * svc.gateway_s(ctx_len, batch=requests.prompt_len)
            dec_gw = svc.gateway_s(ctx_len, batch=conc)[tok_req]
            self.gw_service = np.concatenate([pre_gw, dec_gw])    # (M,)
        else:
            self.decode_batch_est = None
            self.gw_service = np.concatenate([
                requests.prompt_len.astype(np.float64) * t_gateway,
                np.full(N, t_gateway),
            ])                                                    # (M,)
        self.eff_layer = layer_lat.copy()                         # (P, M, L)
        self.eff_layer[:, :R, :] += extra_layer[None, :, None]
        self.tok_base = token_lat.copy()                          # (P, M)
        self.tok_base[:, :R] += L * extra_layer[None, :]
        self.start_pref = requests.arrival_s[None, :] \
            + self.ingress_extra                                  # (P, R)
        self.first_tok = np.cumsum(requests.decode_len) \
            - requests.decode_len                                 # (R,)

        # --- queue events: (plan, station, request, work) ------------------
        # Stations are satellites: each token's deposits land on the
        # satellites its slot's plan routes it through (the slot -> plan
        # gather), so colocated experts share their satellite's queue
        # (Eq. 43) and a mid-horizon plan switch redirects new deposits
        # while the old plan's backlog drains in place.
        self.gateways_slot = batch.gateways_by_slot()         # (P, N_T, L)
        self.expert_sats_slot = batch.expert_sats_by_slot()   # (P,N_T,L,I)
        eta_slot = batch.eta_by_slot()                        # (P, N_T)
        gw_tok = self.gateways_slot[:, self.slots]            # (P, M, L)
        sats_tok = self.expert_sats_slot[:, self.slots]       # (P, M, L, I)
        eta_tok = eta_slot[:, self.slots]                     # (P, M)

        # Gateway work: every token visits every gateway satellite of its
        # slot's plan; lm-head work on the last gateway.
        gw_station = gw_tok
        gw_work = np.broadcast_to(self.gw_service[None, :, None],
                                  (P, M, L)).copy()
        gw_work[:, :, L - 1] += t_head
        gw_req = np.concatenate([np.arange(R), tok_req])          # (M,)

        # Decode expert work: the engine's own draws, scattered onto the
        # drawn expert's satellite; colocation multiplies the deposited
        # work (the Eq. 43 q factor) and eta scales the shared-compute
        # efficiency.
        draws_mlk = np.moveaxis(draws, 0, 1)                      # (M, L, K)
        exp_sat_tok = np.take_along_axis(
            sats_tok, draws_mlk[None], axis=3)                    # (P,M,L,K)
        dec_exp_station = exp_sat_tok[:, R:]                      # (P,N,L,K)
        probs = activation.all_probs()                            # (L, I)
        if svc.per_satellite:
            # Calibrated deposits: each drawn expert's own service
            # seconds, scaled by the hosting satellite's speed — the
            # queue-theoretic face of the calibrated Eq. 43 term.
            exp_sec = np.asarray(svc.expert_s(), dtype=np.float64)  # (I,)
            inv_sp = np.asarray(svc.inv_speed(topo.n_sats),
                                dtype=np.float64)                 # (V,)
            dec_exp_work = (exp_sec[draws_mlk[R:]][None]
                            * inv_sp[dec_exp_station]
                            / eta_tok[:, R:, None, None])
            pre_exp_station = sats_tok[:, :R]                     # (P,R,L,I)
            pre_exp_work = (requests.prompt_len[None, :, None, None]
                            * probs[None, None, :, :]
                            * exp_sec[None, None, None, :]
                            * inv_sp[pre_exp_station]
                            / eta_tok[:, :R, None, None])
        else:
            dec_exp_work = np.broadcast_to(
                (t_expert / eta_tok[:, R:])[..., None, None],
                dec_exp_station.shape)

            # Prefill expert work: the whole prompt hits every expert of
            # the layer in proportion to its activation probability
            # (fluid split of the batch), deposited at the prefill
            # token's expert visit.
            pre_exp_station = sats_tok[:, :R]                     # (P,R,L,I)
            pre_exp_work = np.broadcast_to(
                requests.prompt_len[None, :, None, None]
                * probs[None, None, :, :] * t_expert
                / eta_tok[:, :R, None, None], (P, R, L, n_exp))

        ev_station = np.concatenate([
            gw_station.reshape(P, -1),
            dec_exp_station.reshape(P, -1),
            pre_exp_station.reshape(P, -1),
        ], axis=1)                                                # (P, E)
        ev_work = np.concatenate([
            gw_work.reshape(P, -1),
            dec_exp_work.reshape(P, -1),
            pre_exp_work.reshape(P, -1),
        ], axis=1)                                                # (P, E)
        ev_req = np.concatenate([
            np.broadcast_to(gw_req[:, None], (M, L)).ravel(),
            np.broadcast_to(tok_req[:, None, None], (N, L, K)).ravel(),
            np.broadcast_to(np.arange(R)[:, None, None],
                            (R, L, n_exp)).ravel(),
        ])                                                        # (E,)

        # Wait-gather stations: per (plan, token, layer) the gateway and
        # the K expert branches (max over branches joins the layer
        # critical path, mirroring the engine's max over experts).
        self.gather_gw_station = gw_station                       # (P, M, L)
        self.gather_exp_station = exp_sat_tok                     # (P,M,L,K)

        # Chunked service (continuous-batching semantics): a deposit
        # larger than one bin of capacity is spread over consecutive
        # bins at the service rate, so a long prefill does not
        # head-of-line-block every token behind one bin.  The chunk
        # layout depends only on work, so it is precomputed; per run
        # only the chunk *bins* are recomputed from the schedule.
        dt = qcfg.dt_s
        w_flat = ev_work.ravel()
        n_ch = np.maximum(np.ceil(w_flat / dt).astype(np.int64), 1)
        self._rep = np.repeat(np.arange(w_flat.size), n_ch)
        self._offs = np.arange(self._rep.size) \
            - np.repeat(np.cumsum(n_ch) - n_ch, n_ch)
        self.ev_chunk_work = np.minimum(w_flat[self._rep]
                                        - self._offs * dt, dt)
        self.ev_chunk_station = ev_station.ravel()[self._rep]
        self.ev_chunk_plan = np.broadcast_to(
            np.arange(P)[:, None], ev_work.shape).ravel()[self._rep]
        self.ev_chunk_req = np.broadcast_to(
            ev_req[None, :], ev_work.shape).ravel()[self._rep]
        self._n_events = ev_work.size

        # Fused-path gather indices: each chunk reads its event's arrival
        # time from the flattened [layer_arr | exp_arr] pair, so the
        # device fixed point rebuilds no event concatenations.  The block
        # order mirrors the ev_* concatenation above exactly.
        p_i = np.arange(P)[:, None, None]
        m_i = np.arange(M)[None, :, None]
        l_i = np.arange(L)[None, None, :]
        gw_src = (p_i * M + m_i) * L + l_i                        # (P, M, L)
        exp_src = P * M * L + gw_src                              # exp_arr
        ev_src = np.concatenate([
            gw_src.reshape(P, -1),
            np.broadcast_to(exp_src[:, R:, :, None],
                            (P, N, L, K)).reshape(P, -1),
            np.broadcast_to(exp_src[:, :R, :, None],
                            (P, R, L, n_exp)).reshape(P, -1),
        ], axis=1).ravel()
        self._chunk_src = ev_src[self._rep]
        self._chunk_row = self.ev_chunk_plan * self.n_stations \
            + self.ev_chunk_station
        self._chunk_pr = self.ev_chunk_plan * R + self.ev_chunk_req

        if batching is not None:
            # Continuous-batching chunk channels.  Decode-side events —
            # decode-token gateway visits and the decode expert block —
            # carry their work in ``wdec`` (the batchable subset the
            # speedup scales) and one fractional token visit per chunk
            # in ``cntw`` (a chunk holds work/ev_work of its event's
            # visit, so each decode event deposits exactly one occupancy
            # unit; a satellite hosting several layers of one token
            # counts that token once per visit).  Prefill blocks batch
            # over their own prompt already and count zero.
            ev_dec = np.concatenate([
                np.broadcast_to((np.arange(M) >= R)[:, None],
                                (M, L)).ravel(),
                np.ones(N * L * K, dtype=bool),
                np.zeros(R * L * n_exp, dtype=bool),
            ]).astype(np.float64)                                 # (E,)
            dec_ch = np.broadcast_to(ev_dec[None, :],
                                     ev_work.shape).ravel()[self._rep]
            wf = w_flat[self._rep]
            self._chunk_wdec = self.ev_chunk_work * dec_ch
            self._chunk_cntw = np.where(
                wf > 0.0,
                self.ev_chunk_work / np.where(wf > 0.0, wf, 1.0),
                0.0) * dec_ch
        #: Lazily-built device-resident precompute (see _device_tables).
        self._dev: dict | None = None
        #: Deposit implementation: "auto" (Pallas on TPU, jnp scatter-add
        #: reference elsewhere), "ref", or "pallas".
        self.deposit_impl = "auto"

        # --- time bins (fixed across runs so the scan compiles once) ------
        start_dec0, _, c00 = self._chain(self.tok_base, self.start_pref)
        end0 = start_dec0 + self.tok_base[:, R:]
        horizon = max(float(requests.arrival_s.max()),
                      float(np.where(np.isfinite(end0), end0, 0.0).max()),
                      float(np.where(np.isfinite(c00), c00, 0.0).max()))
        self.n_bins = max(
            int(np.ceil((horizon + qcfg.tail_s) / qcfg.dt_s)) + 1,
            int(min_bins))
        if self.n_bins > 2_000_000:
            raise ValueError(
                f"{self.n_bins} time bins — raise dt_s or shrink the horizon")

        # --- migration background load (schedule switches) -----------------
        self._build_migration_load()

        # --- admission controller precompute ------------------------------
        acfg = qcfg.admission
        self.admission_on = acfg is not None and acfg.policy == "aimd"
        if self.admission_on:
            self._build_admission_tables(acfg, ground, slot_r, rng)

        # --- fused-path row compaction + static tables --------------------
        self._build_row_map()
        self._build_fused_tables()

        # Filled by ``run``: (plan, satellite, bin) backlog of the last
        # fleet scan (the re-placement controller's observation).
        self.last_wait: np.ndarray | None = None
        # Telemetry: filled by every launch when ``probes`` is set.
        self.probes = probes
        self.last_probes: "ProbeRecord | None" = None

    # ----------------------------------------------------------------- #

    def _build_migration_load(self) -> None:
        """Precompute the background work a schedule's plan switches
        deposit on the fleet.

        Every slot boundary the wall-clock horizon crosses is checked
        against each row's :class:`~repro.core.schedule.PlanSchedule`;
        per moved expert (the ``distributed.elastic`` diff rule via
        :meth:`~repro.core.schedule.PlanSchedule.migrations_over`) the
        weight transfer occupies the *destination* satellite's queue for
        ``bytes * 8 / migration_rate_gbps`` seconds, chunked into dt
        bins from the boundary — arriving tokens queue behind the
        weights being installed.  Constant schedules deposit nothing, so
        the static path is untouched bit-for-bit.
        """
        qcfg = self.qcfg
        dt, T, S = qcfg.dt_s, self.n_bins, self.n_stations
        sec_per_expert = (qcfg.migration_bytes_per_expert * 8.0
                          / (qcfg.migration_rate_gbps * 1e9))
        flat_parts: list[np.ndarray] = []
        work_parts: list[np.ndarray] = []
        self.migration_bytes = np.zeros(self.n_plans)
        for p, sched in enumerate(self.schedules):
            for t_b, mig in sched.migrations_over(
                    T * dt, qcfg.slot_period_s,
                    qcfg.migration_bytes_per_expert):
                self.migration_bytes[p] += mig.bytes_moved
                if mig.n_moved == 0 or sec_per_expert <= 0.0:
                    continue
                n_ch = max(int(np.ceil(sec_per_expert / dt)), 1)
                bins = np.minimum(int(t_b / dt) + np.arange(n_ch), T - 1)
                w = np.minimum(sec_per_expert - np.arange(n_ch) * dt, dt)
                fl = ((p * S + mig.new_sats[:, None]) * T
                      + bins[None, :]).ravel()
                flat_parts.append(fl)
                work_parts.append(np.broadcast_to(
                    w[None, :], (mig.n_moved, n_ch)).ravel())
        self._mig_flat = (np.concatenate(flat_parts) if flat_parts
                          else np.empty(0, dtype=np.int64))
        self._mig_work = (np.concatenate(work_parts) if work_parts
                          else np.empty(0, dtype=np.float64))

    # ----------------------------------------------------------------- #

    def _build_admission_tables(self, acfg: AdmissionConfig,
                                ground: GroundSegment | None,
                                slot_r: np.ndarray,
                                rng: np.random.Generator) -> None:
        """Precompute the gateway-retry attempt tables and the AIMD
        controller's zero-load references.

        Per attempt a (0 = the original gateway, a >= 1 = the a-th best
        alternative gateway from :meth:`GroundSegment.retry_stations`):
        target gateway, total ingress latency (a * backoff + terrestrial
        forward + uplink + ingress hop) and per-plan feasibility.  An
        alternate gateway enters through the first rank of its
        ranked-visibility table whose ingress route exists for the plan
        in that slot (deeper ranks cover an occluded or unroutable best
        satellite).  When no a-th alternative exists — no ground
        segment, or fewer visible gateways than retries — attempt a is a
        same-gateway backoff retry: the origin is re-attempted after the
        backoff, drawing against the (time-varying) admit state of a
        later bin.  Retries happen within the arrival's topology slot
        (backoff << slot period).
        """
        req = self.requests
        P, R = self.n_plans, self.n_requests
        A = acfg.n_attempts
        self.n_gw_stations = ground.n_stations if ground is not None else 1

        # Without a ground segment there is a single logical gateway.
        station = req.station if ground is not None \
            else np.zeros(R, dtype=np.int64)
        st_att = np.tile(station, (A, 1))                         # (A, R)
        alt_ok = np.zeros((A, R), dtype=bool)
        alt_ok[0] = True
        if ground is not None and acfg.max_retries > 0:
            alts = ground.retry_stations(slot_r, req.station,
                                         acfg.max_retries)        # (R, n_alt)
            n_alt = alts.shape[1]
            for a in range(1, min(A, n_alt + 1)):
                st_att[a] = alts[:, a - 1]
                alt_ok[a] = True

        extra = np.empty((A, P, R))
        feas = np.zeros((A, P, R), dtype=bool)
        extra[0] = self.ingress_extra
        feas[0] = ~self.fail_ingress
        for a in range(1, A):
            if ground is None or not alt_ok[a].any():
                # Same-gateway backoff retry (see docstring).
                extra[a] = self.ingress_extra + a * acfg.retry_backoff_s
                feas[a] = feas[0]
                continue
            gdelay = ground.ground_delay_s[req.station, st_att[a]]
            # Ranked-visibility fallback: per plan, the first rank of
            # the alternate gateway's satellite ranking with a finite
            # ingress route.
            ing_r = ground.ingress_ranked[slot_r, st_att[a]]      # (R, K)
            up_r = ground.uplink_ranked_s[slot_r, st_att[a]]      # (R, K)
            best = np.zeros((P, R))
            best_ok = np.zeros((P, R), dtype=bool)
            for k in range(ground.n_ranked):
                reachable = ing_r[:, k] >= 0
                off = schedule_ingress_offsets(
                    self.batch, slot_r, np.where(reachable, ing_r[:, k], 0))
                ok = reachable[None, :] & np.isfinite(off)
                take = ok & ~best_ok
                best = np.where(take, up_r[None, :, k] + off, best)
                best_ok |= ok
            extra[a] = (a * acfg.retry_backoff_s + gdelay)[None, :] \
                + np.where(best_ok, best, 0.0)
            feas[a] = best_ok & alt_ok[a][None, :]
        self._att_station = st_att
        self._att_extra = extra
        self._att_feasible = feas
        # Attempt a is evaluated at the gateway it targets, after the
        # backoff + terrestrial forward but before the uplink.
        t_att = req.arrival_s[None, :] + np.arange(A)[:, None] \
            * acfg.retry_backoff_s
        if ground is not None:
            t_att = t_att + ground.ground_delay_s[req.station, st_att]
        self._att_bin = np.clip((t_att / self.qcfg.dt_s).astype(np.int64),
                                0, self.n_bins - 1)
        # Common random numbers: one uniform per (attempt, request),
        # shared by every plan and every run() call.
        self._adm_u = rng.random((A, R))

        # Zero-load controller references (see admission module
        # docstring): tail anchors at the configured reference quantile.
        base_ttft = self.ingress_extra + self.tok_base[:, :R]     # (P, R)
        ok = feas[0] & ~_segment_any(self.nan_tok[:, R:], self.tok_req, R) \
            & ~self.nan_tok[:, :R]
        self._adm_ttft0 = _station_quantile(
            base_ttft, ok, station, self.n_gw_stations,
            acfg.reference_quantile)                              # (P, G)
        dec_ok = np.isfinite(self.tok_base[:, R:]) & ~self.nan_tok[:, R:]
        self._adm_tpot0 = np.array([
            np.quantile(self.tok_base[i, R:][dec_ok[i]],
                        acfg.reference_quantile)
            if dec_ok[i].any() else 0.0 for i in range(P)])        # (P,)

        # Slot-dependent critical-path stations for the in-scan
        # controller: per time bin, the bin's topology slot selects each
        # plan's gateway chain and expert satellites — the admission
        # law's qhat follows the schedule through every plan switch.
        slot_of_bin = slot_of_time(np.arange(self.n_bins) * self.qcfg.dt_s,
                                   self.qcfg.slot_period_s,
                                   self.n_topo_slots)
        self._adm_gw_idx = np.ascontiguousarray(np.moveaxis(
            self.gateways_slot[:, slot_of_bin], 1, 0)).astype(np.int32)
        self._adm_exp_idx = np.ascontiguousarray(np.moveaxis(
            self.expert_sats_slot[:, slot_of_bin], 1, 0)).reshape(
                self.n_bins, P, -1).astype(np.int32)

    # ----------------------------------------------------------------- #

    def _build_row_map(self) -> None:
        """Compact the (plan, satellite) queue rows the fused path keeps
        dense.

        Only rows that can ever receive a deposit (chunk targets,
        migration destinations) or be read (wait gathers, the admission
        law's per-bin station maps) matter; every other station carries
        exactly zero backlog in both paths, so dropping it from the
        device tensors is exact.  The map scales the fused kernel with
        the *plans'* footprint instead of the constellation size.
        """
        P, S, T = self.n_plans, self.n_stations, self.n_bins
        p_idx = np.arange(P)[:, None, None]
        gw_rows = p_idx * S + self.gather_gw_station              # (P,M,L)
        ex_rows = p_idx[..., None] * S + self.gather_exp_station
        used = [self._chunk_row, gw_rows.ravel(), ex_rows.ravel()]
        if self._mig_flat.size:
            used.append(self._mig_flat // T)
        if self.admission_on:
            pr = np.arange(P, dtype=np.int64)[None, :, None] * S
            used.append((pr + self._adm_gw_idx).ravel())
            used.append((pr + self._adm_exp_idx).ravel())
        rows = np.unique(np.concatenate(used))
        inv = np.full(P * S, -1, dtype=np.int64)
        inv[rows] = np.arange(rows.size)
        self._active_rows = rows
        self._row_inv = inv
        self.n_rows = int(rows.size)
        self._chunk_rowc = inv[self._chunk_row].astype(np.int32)
        self._gw_rowc = inv[gw_rows]                              # (P,M,L)
        self._ex_rowc = inv[ex_rows]                              # (P,M,L,K)
        if self.admission_on:
            self._adm_gw_rowc = inv[pr + self._adm_gw_idx] \
                .astype(np.int32)                                 # (T,P,L)
            self._adm_exp_rowc = inv[pr + self._adm_exp_idx] \
                .astype(np.int32)                                 # (T,P,LI)

    def _expand_rows(self, arr: np.ndarray) -> np.ndarray:
        """Scatter a compact-row array (..., n_rows) back to (..., P, S)."""
        full = np.zeros(arr.shape[:-1] + (self.n_plans * self.n_stations,),
                        dtype=arr.dtype)
        full[..., self._active_rows] = arr
        return full.reshape(arr.shape[:-1]
                            + (self.n_plans, self.n_stations))

    def _build_fused_tables(self) -> None:
        """Static precompute for the fused path's peeled first iteration
        and row-grouped deposits.

        The first fixed-point iteration always runs on the zero-wait
        schedule, so its event times — hence its chunk bins and gather
        bins — are construction-time constants; ``_launch`` turns them
        into the iteration-1 work plane with one host ``np.bincount``.
        The chunk tables are also re-ordered by compact row (stable
        sort), so the device scatter of later iterations walks the
        (row, T) plane row-major instead of hopping across it.
        """
        P, M, L = self.n_plans, self.n_tokens, self.n_layers
        z = np.zeros((P, M, L))
        layer0, exp0, *_ = self._schedule(z, z, self.start_pref)
        self._gw_b0, self._gw_fin0 = self._to_bins(layer0)
        self._ex_b0, self._ex_fin0 = self._to_bins(exp0)
        base0, fin0 = self._to_bins(self._event_times(layer0, exp0))
        bins0 = np.minimum(base0[self._rep] + self._offs, self.n_bins - 1)
        perm = np.argsort(self._chunk_rowc, kind="stable")
        self._f_src = self._chunk_src[perm]
        self._f_offs = self._offs[perm]
        self._f_work = self.ev_chunk_work[perm]
        self._f_rowc = self._chunk_rowc[perm]
        self._f_pr = self._chunk_pr[perm]
        self._f_req = self.ev_chunk_req[perm]
        self._f_bins0 = bins0[perm]
        self._f_fin0 = fin0[self._rep][perm]
        if self.batching is not None:
            self._f_wdec = self._chunk_wdec[perm]
            self._f_cntw = self._chunk_cntw[perm]
        if self._mig_flat.size:
            flat = self._row_inv[self._mig_flat // self.n_bins] \
                * self.n_bins + self._mig_flat % self.n_bins
            self._mig_rm = np.bincount(
                flat, weights=self._mig_work,
                minlength=self.n_rows * self.n_bins
            ).reshape(self.n_rows, self.n_bins)
        else:
            self._mig_rm = None

    # ----------------------------------------------------------------- #

    def _chain(self, tok_total: np.ndarray, start_pref: np.ndarray):
        """Autoregressive chaining: (decode token starts (P, N), their
        per-request inclusive cumsums (P, N), prefill completion (P, R))."""
        R = self.n_requests
        dec = tok_total[:, R:]
        cs = np.cumsum(dec, axis=1)
        base = (cs - dec)[:, self.first_tok][:, self.tok_req]
        seg_excl = (cs - dec) - base
        c0 = start_pref + tok_total[:, :R]
        start_dec = c0[:, self.tok_req] + seg_excl
        return start_dec, cs - base, c0

    def _schedule(self, gw_wait: np.ndarray, ex_max: np.ndarray,
                  start_pref: np.ndarray):
        """Wait-augmented schedule: per-(plan, token, layer) gateway and
        expert arrival times, plus per-token total latencies."""
        lay_cost = self.eff_layer + gw_wait + ex_max              # (P, M, L)
        tok_total = self.tok_base + gw_wait.sum(2) + ex_max.sum(2)
        start_dec, seg_incl, c0 = self._chain(tok_total, start_pref)
        start_all = np.concatenate([start_pref, start_dec], axis=1)
        layer_arr = start_all[:, :, None] + _exclusive_cumsum(lay_cost, 2)
        exp_arr = layer_arr + gw_wait + self.gw_service[None, :, None]
        return layer_arr, exp_arr, tok_total, seg_incl, c0

    def _to_bins(self, times: np.ndarray):
        """Clip finite ``times`` to bin indices; returns (bins, finite)."""
        finite = np.isfinite(times)
        b = np.where(
            finite,
            np.clip((np.where(finite, times, 0.0) / self.qcfg.dt_s)
                    .astype(np.int64), 0, self.n_bins - 1), 0)
        return b, finite

    def _event_times(self, layer_arr: np.ndarray,
                     exp_arr: np.ndarray) -> np.ndarray:
        """(P*E,) arrival time of every queue event under a schedule."""
        P, R = self.n_plans, self.n_requests
        return np.concatenate([
            layer_arr.reshape(P, -1),
            np.broadcast_to(
                exp_arr[:, R:, :, None],
                (P, self.n_decode_tokens, self.n_layers,
                 self.activation.top_k)).reshape(P, -1),
            np.broadcast_to(
                exp_arr[:, :R, :, None],
                (P, R, self.n_layers, self.activation.n_experts))
            .reshape(P, -1),
        ], axis=1).ravel()

    def _bin_work(self, layer_arr, exp_arr, active2d):
        """Offered work (P, S, T) for the current schedule + per-plan
        request-activity mask ``active2d`` (P, R)."""
        P = self.n_plans
        S, T = self.n_stations, self.n_bins
        ev_time = self._event_times(layer_arr, exp_arr)           # (P*E,)
        base_bin, finite = self._to_bins(ev_time)
        bins = np.minimum(base_bin[self._rep] + self._offs, T - 1)
        w = self.ev_chunk_work * finite[self._rep] \
            * active2d[self.ev_chunk_plan, self.ev_chunk_req]
        flat = (self.ev_chunk_plan * S + self.ev_chunk_station) * T + bins
        if self._mig_flat.size:
            # Schedule-switch weight migrations ride as background load.
            flat = np.concatenate([flat, self._mig_flat])
            w = np.concatenate([w, self._mig_work])
        return np.bincount(flat, weights=w,
                           minlength=P * S * T).reshape(P, S, T)

    def _bin_work_planes(self, layer_arr, exp_arr, active2d):
        """Decode-work and occupancy-count planes (P, S, T) for the
        legacy path's continuous-batching law (:mod:`.batching`) —
        same bins as :meth:`_bin_work`, decode-side chunk channels,
        no migration background (weights are not batchable decode)."""
        P = self.n_plans
        S, T = self.n_stations, self.n_bins
        ev_time = self._event_times(layer_arr, exp_arr)           # (P*E,)
        base_bin, finite = self._to_bins(ev_time)
        bins = np.minimum(base_bin[self._rep] + self._offs, T - 1)
        act = finite[self._rep] \
            * active2d[self.ev_chunk_plan, self.ev_chunk_req]
        flat = (self.ev_chunk_plan * S + self.ev_chunk_station) * T + bins
        wdec = np.bincount(flat, weights=self._chunk_wdec * act,
                           minlength=P * S * T).reshape(P, S, T)
        cnt = np.bincount(flat, weights=self._chunk_cntw * act,
                          minlength=P * S * T).reshape(P, S, T)
        return wdec, cnt

    def _gather(self, wait, overload, layer_arr, exp_arr):
        """Per-(plan, token, layer) gateway wait, expert branch-max wait,
        and overload flags, read at the schedule's arrival bins."""
        p_idx = np.arange(self.n_plans)[:, None, None]
        gw_b, gw_fin = self._to_bins(layer_arr)
        gw_wait = np.where(gw_fin,
                           wait[p_idx, self.gather_gw_station, gw_b], 0.0)
        gw_over = gw_fin & overload[p_idx, self.gather_gw_station, gw_b]
        ex_b, ex_fin = self._to_bins(exp_arr)
        ex_b4, ex_f4 = ex_b[..., None], ex_fin[..., None]
        ex_wait = np.where(
            ex_f4, wait[p_idx[..., None], self.gather_exp_station, ex_b4],
            0.0)
        ex_over = ex_f4 & \
            overload[p_idx[..., None], self.gather_exp_station, ex_b4]
        return gw_wait, ex_wait.max(axis=3), gw_over, ex_over.any(axis=3)

    # ----------------------------------------------------------------- #

    def satellite_backlog(self, plan: int, t_s: float) -> np.ndarray:
        """(V,) seconds of backlog per satellite that plan row ``plan``
        observed at wall-clock ``t_s`` in the last ``run`` — the live
        signal the re-placement controller scores candidate plans
        against (zeros before any loaded run)."""
        if self.last_wait is None:
            return np.zeros(self.n_stations)
        b = min(int(t_s / self.qcfg.dt_s), self.n_bins - 1)
        return self.last_wait[plan, :, b]

    # ----------------------------------------------------------------- #

    def _device_tables(self) -> dict:
        """Build (once, lazily) the device-resident precompute pytree the
        fused fixed point consumes.

        Everything rate-independent is staged to the device in float64
        (x64 scoped to the transfer): the zero-load schedule tensors, the
        chunk layout + gather indices, the densified migration background
        load, and — when the AIMD controller is on — the admission scan
        tables and retry attempt tables.
        """
        if self._dev is not None:
            return self._dev
        qcfg = self.qcfg
        with _x64():
            d = dict(
                dt=jnp.asarray(float(qcfg.dt_s)),
                cap32=jnp.asarray(float(qcfg.buffer_s), dtype=jnp.float32),
                dt32=jnp.asarray(float(qcfg.dt_s), dtype=jnp.float32),
                eff_layer=jnp.asarray(self.eff_layer),
                tok_base=jnp.asarray(self.tok_base),
                gw_service=jnp.asarray(self.gw_service),
                arrival_s=jnp.asarray(self.requests.arrival_s),
                ingress_extra0=jnp.asarray(self.ingress_extra),
                first_tok=jnp.asarray(self.first_tok),
                tok_req=jnp.asarray(self.tok_req),
                last_tok=jnp.asarray(
                    self.first_tok + self.requests.decode_len - 1),
                gw_rows=jnp.asarray(self._gw_rowc),
                ex_rows=jnp.asarray(self._ex_rowc),
                gw_b0=jnp.asarray(self._gw_b0),
                gw_fin0=jnp.asarray(self._gw_fin0),
                ex_b0=jnp.asarray(self._ex_b0),
                ex_fin0=jnp.asarray(self._ex_fin0),
            )
            if self._mig_rm is not None:
                d["mig_dense"] = jnp.asarray(self._mig_rm)    # (rows, T)
            if self.admission_on:
                acfg = qcfg.admission
                f32 = np.float32
                d.update(
                    ttft0=jnp.asarray(self._adm_ttft0.astype(f32)),
                    tpot0=jnp.asarray(self._adm_tpot0.astype(f32)),
                    ctrl=jnp.asarray(control_bin_flags(
                        self.n_bins, qcfg.dt_s, acfg.interval_s)),
                    gw_rows_bin=jnp.asarray(self._adm_gw_rowc),
                    exp_rows_bin=jnp.asarray(self._adm_exp_rowc),
                    increase=jnp.asarray(f32(acfg.increase)),
                    decrease=jnp.asarray(f32(acfg.decrease)),
                    admit_min=jnp.asarray(f32(acfg.admit_min)),
                    att_bin=jnp.asarray(self._att_bin),
                    att_station=jnp.asarray(self._att_station),
                    att_feasible=jnp.asarray(
                        np.moveaxis(self._att_feasible, 1, 0)),
                    att_extra=jnp.asarray(
                        np.moveaxis(self._att_extra, 0, 1)),
                    adm_u=jnp.asarray(self._adm_u),
                )
        self._dev = d
        return d

    def _use_pallas(self) -> bool:
        """Resolve the deposit implementation (see ``deposit_impl``)."""
        if self.deposit_impl == "auto":
            return _kernel_ops.on_tpu()
        return self.deposit_impl == "pallas"

    def _launch(self, masks: np.ndarray, ttft_targets, tpot_targets,
                want_wait: bool) -> dict:
        """One fused device launch over the leading sweep axis F.

        The request-activity masks are folded into a host-built compacted
        chunk table (only active chunks are deposited; padded to
        ``_CHUNK_BLOCK`` so repeated sweeps of the same shape reuse the
        compile cache) — the device sees offered work, not the envelope.

        Args:
            masks: (F, R) bool request-activity masks.
            ttft_targets: Optional (F,) raw TTFT targets (margin applied
                here); None uses the construction-time config.
            tpot_targets: Same for TPOT.
            want_wait: Return the (T, F, rows) backlog trace.

        Returns:
            The :func:`_fused_core` output dict as host arrays, each
            with a leading F axis (``wait`` stays time-major compact).
        """
        acfg = self.qcfg.admission
        F = masks.shape[0]
        if self.admission_on:
            m = acfg.target_margin
            tt = (np.full(F, m * acfg.ttft_target_s) if ttft_targets is None
                  else m * np.asarray(ttft_targets, dtype=np.float64))
            tp = (np.full(F, m * acfg.tpot_target_s) if tpot_targets is None
                  else m * np.asarray(tpot_targets, dtype=np.float64))
        else:
            tt = np.zeros(F)
            tp = np.zeros(F)

        # Host-side chunk compaction: keep (f, chunk) pairs whose
        # request is active, in the static row-grouped order.  Padding
        # rides along with zero work.
        P, R = self.n_plans, self.n_requests
        T, SR = self.n_bins, self.n_rows
        f_id, cid = np.nonzero(masks[:, self._f_req])
        n = cid.size
        n_pad = max(-(-n // _CHUNK_BLOCK), 1) * _CHUNK_BLOCK
        pml2 = 2 * P * self.n_tokens * self.n_layers
        src = np.zeros(n_pad, dtype=np.int64)
        src[:n] = f_id * pml2 + self._f_src[cid]
        offs = np.zeros(n_pad, dtype=np.int64)
        offs[:n] = self._f_offs[cid]
        work = np.zeros(n_pad)
        work[:n] = self._f_work[cid]
        fprow = np.zeros(n_pad, dtype=np.int32)
        fprow[:n] = f_id.astype(np.int32) * SR + self._f_rowc[cid]
        chunks = dict(src=src, offs=offs, work=work, fprow=fprow)
        if self.admission_on:
            fpr = np.zeros(n_pad, dtype=np.int64)
            fpr[:n] = f_id * (P * R) + self._f_pr[cid]
            chunks["fpr"] = fpr
        if self.batching is not None:
            wdec = np.zeros(n_pad)
            wdec[:n] = self._f_wdec[cid]
            cntw = np.zeros(n_pad)
            cntw[:n] = self._f_cntw[cid]
            chunks["wdec"] = wdec
            chunks["cntw"] = cntw

        # Iteration-1 offered work: the zero-wait schedule's bins are
        # static, so one host bincount over the active chunks builds the
        # peeled iteration's plane (a launch input, not a per-iteration
        # transfer).
        flat0 = (f_id * SR + self._f_rowc[cid]).astype(np.int64) * T \
            + self._f_bins0[cid]
        # astype: bincount of an *empty* chunk set (an all-False sweep
        # row) returns int64 even with weights given.
        plane0 = np.bincount(
            flat0, weights=self._f_work[cid] * self._f_fin0[cid],
            minlength=F * SR * T).reshape(F, SR, T).astype(np.float64)
        if self._mig_rm is not None:
            plane0 += self._mig_rm[None]
        work0_sum = plane0.sum(axis=2)                        # (F, SR)
        beff0 = None
        if self.batching is not None:
            # The peeled iteration's effective work is host-computed in
            # f64 (mirroring the device's f64-scatter-then-f32-downcast
            # policy) from the decode-work and occupancy planes of the
            # same static bins.
            plane0_dec = np.bincount(
                flat0, weights=self._f_wdec[cid] * self._f_fin0[cid],
                minlength=F * SR * T).reshape(F, SR, T)
            cnt0 = np.bincount(
                flat0, weights=self._f_cntw[cid] * self._f_fin0[cid],
                minlength=F * SR * T).reshape(F, SR, T)
            plane0, beff0 = effective_work_np(
                plane0, plane0_dec, cnt0, self._batch_table,
                self._batch_cap, self._batch_window)

        # Telemetry rings: static (capacity, stride) pair + donated
        # zeroed buffers.  probes=None launches pass an empty pytree and
        # trace exactly the legacy kernel.
        if self.probes is not None:
            p_cap, p_stride = self.probes.resolve(self.n_bins)
            static_probes = (p_cap, p_stride)
            n_gw = self._adm_ttft0.shape[1] if self.admission_on else 0
            pbuf = {k: jnp.asarray(v) for k, v in make_buffers(
                p_cap, F, SR,
                (P, n_gw) if self.admission_on else None,
                n_row_channels=4 if self.batching is not None else 3
            ).items()}
            exec_fn = _fused_exec_probed
        else:
            static_probes = None
            pbuf = {}
            exec_fn = _fused_exec
        # Batching pytree: empty when off (the trace then shares the
        # batching-free compile-cache entry); the host-computed beff0
        # ships only for the probed n_iter == 1 peel, which has no
        # device-side occupancy plane to record from.
        batch_np: dict = {}
        batch_window = 0
        if self.batching is not None:
            batch_np = dict(table=self._batch_table,
                            bcap=np.float64(self._batch_cap))
            batch_window = self._batch_window
            if self.probes is not None and max(1, self.qcfg.iterations) == 1:
                batch_np["beff0"] = beff0.astype(np.float32)
        with _x64(), warnings.catch_warnings():
            # CPU jit declines buffer donation with a UserWarning; the
            # request is still the right thing on TPU/GPU.
            warnings.filterwarnings("ignore", message=".*[Dd]onat")
            out = exec_fn(
                self._device_tables(),
                {k: jnp.asarray(v) for k, v in chunks.items()},
                jnp.asarray(plane0.astype(np.float32)),
                jnp.asarray(work0_sum),
                jnp.asarray(tt), jnp.asarray(tp), pbuf,
                {k: jnp.asarray(v) for k, v in batch_np.items()},
                max(1, self.qcfg.iterations), self.n_bins, self.n_rows,
                self.admission_on, self._use_pallas(), want_wait,
                static_probes, batch_window)
            out = {k: jax.tree_util.tree_map(np.asarray, v)
                   for k, v in out.items()}
        if self.probes is not None:
            # Probe outputs have their own leading axes — ingest and pop
            # them here so run/run_many's per-F slicing stays untouched.
            self.last_probes = ProbeRecord.from_launch(
                out.pop("probes"), out.pop("probe_gw_wait"),
                out.pop("probe_ex_wait"), self.qcfg.dt_s, p_cap, p_stride,
                self.n_bins, self._expand_rows)
        return out

    def run(self, active: np.ndarray | None = None,
            zero_load: bool = False,
            kv_slots: int | None = None) -> TrafficResult:
        """Simulate with an optional per-request activity mask (Poisson
        thinning for rate sweeps) and return per-plan traffic metrics.

        The fixed point executes as **one fused device launch** (see
        :func:`_fused_core`); :meth:`run_legacy` is the host-path anchor
        it is pinned against.  ``zero_load`` delegates to the host path
        (the queue scan is skipped entirely there, so the zero-load
        reference stays bitwise equal to the engine).

        Args:
            active: Optional (R,) bool participation mask (default: all).
            zero_load: Skip queueing and admission entirely.
            kv_slots: Optional override of the static KV admission cap
                (the cap is host post-processing, so budget sweeps reuse
                one device launch shape).

        Returns:
            A :class:`~repro.traffic.metrics.TrafficResult` with one
            :class:`~repro.traffic.metrics.PlanTraffic` per plan.
        """
        if zero_load:
            return self.run_legacy(active, zero_load=True,
                                   kv_slots=kv_slots)
        if active is None:
            active = np.ones(self.n_requests, dtype=bool)
        active = np.asarray(active, dtype=bool)
        out = self._launch(active[None, :], None, None, want_wait=True)
        # Exposed for the re-placement controller: the live
        # (plan, satellite, bin) backlog of the last fleet scan,
        # expanded from compact rows back to every satellite.
        wait = out.pop("wait")                       # (T, 1, rows)
        self.last_wait = np.moveaxis(
            self._expand_rows(wait[:, 0, :]), 0, 2)  # (P, S, T)
        out = {k: v[0] for k, v in out.items()}
        out["work_sum"] = self._expand_rows(out["work_sum"])
        return self._finalize(active, out, self.admission_on, kv_slots)

    def run_many(self, active: np.ndarray, *,
                 ttft_targets: np.ndarray | None = None,
                 tpot_targets: np.ndarray | None = None,
                 kv_slots: int | None = None) -> list[TrafficResult]:
        """Run a whole sweep as one compile + one device launch.

        The F sweep entries ride a vmapped leading axis of the fused
        fixed point: a saturation sweep batches thinning masks, the
        admission-frontier benchmark batches latency targets — either
        way the fused kernel is traced once (``FUSED_TRACE_COUNT``) and
        the per-entry results come back from a single launch.

        Args:
            active: (F, R) bool participation masks (one row per sweep
                entry; rows may repeat when only targets vary).
            ttft_targets: Optional (F,) TTFT targets overriding the
                construction-time admission config (AIMD runs only).
            tpot_targets: Optional (F,) TPOT targets, same contract.
            kv_slots: Optional static-cap override (host post-processing).

        Returns:
            One :class:`~repro.traffic.metrics.TrafficResult` per sweep
            entry, in order.
        """
        masks = np.asarray(active, dtype=bool)
        if masks.ndim != 2 or masks.shape[1] != self.n_requests:
            raise ValueError(f"active must be (F, {self.n_requests})")
        if (ttft_targets is not None or tpot_targets is not None) \
                and not self.admission_on:
            raise ValueError(
                "latency-target sweeps need an AIMD admission config")
        out = self._launch(masks, ttft_targets, tpot_targets,
                           want_wait=False)
        out["work_sum"] = self._expand_rows(out["work_sum"])
        return [
            self._finalize(masks[f], {k: v[f] for k, v in out.items()},
                           self.admission_on, kv_slots)
            for f in range(masks.shape[0])
        ]

    def run_legacy(self, active: np.ndarray | None = None,
                   zero_load: bool = False,
                   kv_slots: int | None = None) -> TrafficResult:
        """Host-path reference fixed point (the pre-fusion ``run``).

        Iterates schedule -> bin -> scan -> gather with the schedule,
        binning and gather steps on the host and only the backlog scan
        on device (whose inputs downcast to float32, as they always
        have — the fused path reproduces exactly that downcast) — the
        authoritative semantic anchor the fused path is parity-pinned
        against in ``tests/test_fleet_perf.py``.

        Args:
            active: Optional (R,) bool participation mask (default: all).
            zero_load: Skip queueing and admission entirely.
            kv_slots: Optional override of the static KV admission cap.

        Returns:
            A :class:`~repro.traffic.metrics.TrafficResult` with one
            :class:`~repro.traffic.metrics.PlanTraffic` per plan.
        """
        qcfg = self.qcfg
        acfg = qcfg.admission
        req = self.requests
        P, R = self.n_plans, self.n_requests
        M, L = self.n_tokens, self.n_layers

        if active is None:
            active = np.ones(R, dtype=bool)
        active = np.asarray(active, dtype=bool)

        adm_on = self.admission_on and not zero_load
        shed = np.zeros((P, R), dtype=bool)
        retries = np.zeros((P, R), dtype=np.int64)
        ingress_extra = self.ingress_extra
        start_pref = self.start_pref
        if adm_on:
            ctrl = jnp.asarray(control_bin_flags(self.n_bins, qcfg.dt_s,
                                                 acfg.interval_s))
            admit_floor = np.ones((P, self.n_gw_stations, self.n_bins))
            margin = acfg.target_margin
            ttft0 = jnp.asarray(self._adm_ttft0)
            tpot0 = jnp.asarray(self._adm_tpot0)
            gw_idx = jnp.asarray(self._adm_gw_idx)
            exp_idx = jnp.asarray(self._adm_exp_idx)

        gw_wait = np.zeros((P, M, L))
        ex_max = np.zeros((P, M, L))
        gw_over = np.zeros((P, M, L), dtype=bool)
        ex_over = np.zeros((P, M, L), dtype=bool)
        n_iter = 1 if zero_load else max(1, qcfg.iterations)
        for _ in range(n_iter):
            layer_arr, exp_arr, tok_total, seg_incl, c0 = \
                self._schedule(gw_wait, ex_max, start_pref)
            work = self._bin_work(layer_arr, exp_arr,
                                  active[None, :] & ~shed)
            if zero_load:
                break
            batch_kw = None
            scan_work = work
            if self.batching is not None:
                wdec, cnt = self._bin_work_planes(
                    layer_arr, exp_arr, active[None, :] & ~shed)
                if adm_on:
                    # The law applies inside the admission jit (the
                    # window sum is pre-applied host-side so the call
                    # carries no static argument).
                    batch_kw = dict(
                        work_dec=jnp.asarray(wdec),
                        cnt_win=jnp.asarray(windowed_counts(
                            cnt, self._batch_window)),
                        table=jnp.asarray(self._batch_table),
                        bcap=jnp.asarray(np.float64(self._batch_cap)))
                else:
                    scan_work, _ = effective_work_np(
                        work, wdec, cnt, self._batch_table,
                        self._batch_cap, self._batch_window)
            if adm_on:
                wait, dropped, admit = admission_queue_scan(
                    jnp.asarray(work), jnp.asarray(qcfg.buffer_s),
                    qcfg.dt_s, ttft0, tpot0, ctrl, gw_idx, exp_idx,
                    jnp.ones((P, self.n_gw_stations)),
                    margin * acfg.ttft_target_s,
                    margin * acfg.tpot_target_s,
                    acfg.increase, acfg.decrease, acfg.admit_min,
                    batching=batch_kw)
                # Monotone outer iteration: accumulate the trace as a
                # running minimum so the shed set only grows and the
                # fixed point converges from the congested side.
                admit_floor = np.minimum(admit_floor, np.asarray(admit))
                choice, shed = resolve_admission(
                    admit_floor, self._att_bin, self._att_station,
                    self._att_feasible, self._adm_u)
                retries = np.where(shed, 0, choice)
                ingress_extra = np.take_along_axis(
                    np.moveaxis(self._att_extra, 0, 1),     # (P, A, R)
                    retries[:, None, :], axis=1)[:, 0, :]   # (P, R)
                start_pref = req.arrival_s[None, :] + ingress_extra
            else:
                wait, dropped = _fleet_queue_scan(
                    jnp.asarray(scan_work), jnp.asarray(qcfg.buffer_s),
                    qcfg.dt_s)
            wait = np.asarray(wait)
            overload = np.asarray(dropped) > 0.0
            # Exposed for the re-placement controller: the live
            # (plan, satellite, bin) backlog of the last fleet scan.
            self.last_wait = wait
            gw_wait, ex_max, gw_over, ex_over = self._gather(
                wait, overload, layer_arr, exp_arr)
        # Fold the final gather into the schedule once more so reported
        # latencies reflect the waits actually found on the last pass.
        layer_arr, exp_arr, tok_total, seg_incl, c0 = \
            self._schedule(gw_wait, ex_max, start_pref)

        last_tok = self.first_tok + req.decode_len - 1
        ttft = ingress_extra + tok_total[:, :R]                   # (P, R)
        out = dict(
            ttft=ttft, e2e=ttft + seg_incl[:, last_tok],
            tok_total=tok_total,
            tok_over=gw_over.any(axis=2) | ex_over.any(axis=2),
            shed=shed, retries=retries, work_sum=work.sum(axis=2))
        return self._finalize(active, out, adm_on, kv_slots)

    def _finalize(self, active: np.ndarray, out: dict, adm_on: bool,
                  kv_slots: int | None = None) -> TrafficResult:
        """Host post-processing shared by every execution path.

        Turns one run's raw outcome tensors (``ttft``/``e2e`` (P, R),
        ``tok_total`` (P, M), ``tok_over`` (P, M), ``shed``/``retries``
        (P, R), ``work_sum`` (P, S)) into per-plan
        :class:`~repro.traffic.metrics.PlanTraffic` rows: delivery
        failure aggregation, the static KV admission cap, spans,
        utilization and the latency quantiles' NaN masking.
        """
        qcfg, req = self.qcfg, self.requests
        P, R = self.n_plans, self.n_requests
        kv = qcfg.kv_slots if kv_slots is None else kv_slots
        ttft, e2e = out["ttft"], out["e2e"]
        tok_total, shed, retries = out["tok_total"], out["shed"], \
            out["retries"]

        fail_tok = self.nan_tok | out["tok_over"]
        failed = fail_tok[:, :R] \
            | _segment_any(fail_tok[:, R:], self.tok_req, R)      # (P, R)
        if adm_on:
            # Shed requests are accounted separately (not involuntary
            # drops); admitted requests entered via a feasible attempt.
            failed = failed | shed
        else:
            failed = failed | self.fail_ingress

        # KV admission cap: reject arrivals that would exceed the
        # in-flight budget (first-order: in-flight counted over all
        # offered requests).  The adaptive controller replaces this cap.
        admitted = np.ones((P, R), dtype=bool)
        if kv > 0 and not adm_on:
            comp = req.arrival_s[None, :] + np.nan_to_num(
                e2e, nan=np.inf, posinf=np.inf)
            comp = np.where(active[None, :], comp, -np.inf)
            n_inactive = int((~active).sum())
            arrived = np.cumsum(active)                           # (R,)
            # Batched searchsorted: one stable argsort per plan ranks
            # the sorted completion row against the (already sorted)
            # arrivals; completions sort before equal arrivals (stable,
            # first half), reproducing searchsorted side="right".
            keys = np.concatenate([
                np.sort(comp, axis=1),
                np.broadcast_to(req.arrival_s[None, :], (P, R))], axis=1)
            order = np.argsort(keys, axis=1, kind="stable")
            pos = np.empty_like(order)
            np.put_along_axis(pos, order, np.arange(2 * R)[None, :],
                              axis=1)
            done = pos[:, R:] - np.arange(R)[None, :] - n_inactive
            admitted = (arrived[None, :] - done) <= kv
        failed = failed | ~admitted

        served = active[None, :] & ~failed                        # (P, R)
        span = max(float(req.arrival_s[active].max()
                         - req.arrival_s[active].min()), qcfg.dt_s) \
            if active.any() else qcfg.dt_s
        # Offered utilization over the arrival window (> 1 = overload).
        util = out["work_sum"] / span                             # (P, S)

        plans_out = []
        for p in range(P):
            with np.errstate(invalid="ignore"):
                tpot = (e2e[p] - ttft[p]) / req.decode_len
            plans_out.append(PlanTraffic(
                plan_name=self.batch.names[p],
                active=active.copy(),
                served=served[p],
                ttft_s=np.where(served[p], ttft[p], np.nan),
                tpot_s=np.where(served[p], tpot, np.nan),
                e2e_s=np.where(served[p], e2e[p], np.nan),
                decode_len=req.decode_len,
                station_util=util[p],
                span_s=span,
                token_total_s=tok_total[p],
                shed=(shed[p] & active) if adm_on else None,
                retries=np.where(served[p], retries[p], 0)
                if adm_on else None,
                migration_bytes=float(self.migration_bytes[p]),
            ))
        return TrafficResult(plans=plans_out, requests=req,
                             slots=self.slots, n_bins=self.n_bins,
                             dt_s=qcfg.dt_s)


def simulate_traffic(
    plans: list,
    topo: TopologySample,
    activation: ActivationModel,
    workload: MoEWorkload,
    compute: ComputeConfig,
    requests: RequestBatch,
    rng: np.random.Generator,
    qcfg: QueueConfig = QueueConfig(),
    ground: GroundSegment | None = None,
    **kwargs,
) -> TrafficResult:
    """One-shot convenience wrapper: build a :class:`FleetSim` and run it
    with every request active.

    Args:
        plans: Placement-plan sweep.
        topo: Sampled topology.
        activation: Expert-activation model.
        workload: FLOP model of the served MoE.
        compute: FLOPs -> seconds conversion.
        requests: The request trace.
        rng: Randomness for engine draws / admission uniforms.
        qcfg: Queueing/admission parameters.
        ground: Optional ground segment.
        **kwargs: Forwarded to :class:`FleetSim`.

    Returns:
        The :class:`~repro.traffic.metrics.TrafficResult` of one full run.
    """
    sim = FleetSim(plans, topo, activation, workload, compute, requests,
                   rng, qcfg=qcfg, ground=ground, **kwargs)
    return sim.run()
