"""Serving metrics, SLOs and saturation sweeps.

TTFT  — time-to-first-token: uplink + ingress hop + prefill (+ queueing,
        + retry backoff/forwarding for admission-retried requests).
TPOT  — time-per-output-token: mean decode-step latency after the first
        token.
E2E   — request completion time.
Goodput — decode tokens/s delivered by served (non-dropped, admitted)
        requests over the arrival span.
Shed  — requests rejected by the adaptive admission controller after
        exhausting their gateway retries; accounted separately from
        involuntary drops (a shed request gets an immediate fast-fail
        response, a dropped one times out), so ``drop_rate`` only counts
        the involuntary kind and ``goodput`` only counts served decode
        tokens — the "goodput under control" the admission benchmarks
        trade against the latency target.

``saturation_sweep`` finds the highest arrival rate at which a plan
still meets an :class:`SLO`, by Poisson-thinning one request trace with
*nested* masks (the same uniform draw decides a request's membership at
every rate, so sweeps are monotone by construction and share the single
:class:`~repro.traffic.queueing.FleetSim` precompute).
"""
from __future__ import annotations

import dataclasses
import typing

import numpy as np

if typing.TYPE_CHECKING:                              # pragma: no cover
    from .queueing import FleetSim
from .requests import RequestBatch


@dataclasses.dataclass(frozen=True)
class SLO:
    """A serving service-level objective, checked at a latency quantile."""

    ttft_s: float = 60.0
    tpot_s: float = 3.0
    quantile: float = 0.99
    max_drop: float = 0.01

    def describe(self) -> str:
        """One-line human-readable rendering of the objective."""
        q = int(round(self.quantile * 100))
        return (f"p{q} TTFT<={self.ttft_s:g}s, p{q} TPOT<={self.tpot_s:g}s, "
                f"drop<={self.max_drop:.0%}")


@dataclasses.dataclass
class PlanTraffic:
    """Per-plan request-level outcome of one traffic simulation.

    Attributes:
        plan_name: Name of the placement plan this row belongs to.
        active: (R,) request participated in this run.
        served: (R,) active, admitted, and fully delivered.
        ttft_s: (R,) time-to-first-token, NaN unless served.
        tpot_s: (R,) time-per-output-token, NaN unless served.
        e2e_s: (R,) completion time, NaN unless served.
        decode_len: (R,) decode tokens per request.
        station_util: (S,) offered utilization per station.
        span_s: Arrival span of the active requests, seconds.
        token_total_s: (M,) per-token latency incl. queueing.
        shed: (R,) rejected by the admission controller after all
            gateway retries (None when no controller ran).
        retries: (R,) gateway-retry attempts used by served requests
            (0 = admitted at the original gateway; None when no
            controller ran).
        migration_bytes: Weight bytes the plan row's
            :class:`~repro.core.schedule.PlanSchedule` migrated at slot
            boundaries within the horizon (0.0 for a static plan).
    """

    plan_name: str
    active: np.ndarray
    served: np.ndarray
    ttft_s: np.ndarray
    tpot_s: np.ndarray
    e2e_s: np.ndarray
    decode_len: np.ndarray
    station_util: np.ndarray
    span_s: float
    token_total_s: np.ndarray
    shed: np.ndarray | None = None
    retries: np.ndarray | None = None
    migration_bytes: float = 0.0

    @property
    def n_active(self) -> int:
        """Number of requests offered in this run."""
        return int(self.active.sum())

    @property
    def shed_rate(self) -> float:
        """Fraction of offered requests the admission controller shed
        (0.0 when no controller ran)."""
        n = self.n_active
        if self.shed is None or not n:
            return 0.0
        return float(self.shed.sum() / n)

    @property
    def drop_rate(self) -> float:
        """Fraction of offered requests that failed *involuntarily*
        (undeliverable tokens, backpressure overflow, static-cap
        rejections) — controller sheds are excluded."""
        n = self.n_active
        if not n:
            return 0.0
        return float(1.0 - self.served.sum() / n) - self.shed_rate

    @property
    def retry_rate(self) -> float:
        """Fraction of served requests that needed >= 1 gateway retry."""
        if self.retries is None or not self.served.any():
            return 0.0
        return float((self.retries[self.served] > 0).mean())

    @property
    def goodput_tok_s(self) -> float:
        """Decode tokens/s delivered by served requests over the span —
        the goodput-under-control figure the admission frontier plots
        (exactly 0.0 when the plan row is degenerate: nothing offered,
        nothing served, or a non-positive span — every execution path
        derives the figure from this one guarded property)."""
        if self.span_s <= 0.0 or not self.n_active:
            return 0.0
        return float(self.decode_len[self.served].sum() / self.span_s)

    @property
    def offered_rps(self) -> float:
        """Offered request rate (active requests over the arrival span;
        exactly 0.0 when nothing was offered or the span is
        degenerate)."""
        if self.span_s <= 0.0 or not self.n_active:
            return 0.0
        return self.n_active / self.span_s

    def with_added_latency(self, extra_s: np.ndarray) -> "PlanTraffic":
        """Copy with per-request latency added to TTFT and E2E.

        The federation scheduler bills inter-constellation forwarding
        into the latencies of overflow-routed requests this way (the
        PR 3 gateway-retry pattern lifted one level up): the same shift
        lands on TTFT and E2E, so TPOT — their difference over the
        decode length — is unchanged, and NaN (unserved) entries stay
        NaN.

        Args:
            extra_s: (R,) seconds to add per request (0 for requests
                that were never forwarded).

        Returns:
            A new :class:`PlanTraffic`; ``self`` is untouched.
        """
        extra = np.asarray(extra_s, dtype=np.float64)
        return dataclasses.replace(
            self, ttft_s=self.ttft_s + extra, e2e_s=self.e2e_s + extra)

    def quantile(self, which: str, q: float) -> float:
        """Latency quantile over served requests.

        Args:
            which: ``"ttft"`` | ``"tpot"`` | ``"e2e"``.
            q: Quantile in [0, 1].

        Returns:
            The quantile in seconds (NaN when nothing was served, or
            when every served latency is non-finite — e.g. the TPOT of
            zero-decode requests).
        """
        arr = {"ttft": self.ttft_s, "tpot": self.tpot_s,
               "e2e": self.e2e_s}[which][self.served]
        arr = arr[np.isfinite(arr)]
        return float(np.quantile(arr, q)) if len(arr) else float("nan")

    def meets(self, slo: SLO) -> bool:
        """True iff this run satisfies ``slo`` (quantiles over served
        requests; ``max_drop`` checked against involuntary drops)."""
        if self.drop_rate > slo.max_drop:
            return False
        if not self.served.any():
            return False
        return (self.quantile("ttft", slo.quantile) <= slo.ttft_s
                and self.quantile("tpot", slo.quantile) <= slo.tpot_s)

    def row(self, slo: SLO | None = None) -> dict:
        """Flat summary dict (one table/JSON row)."""
        out = {
            "plan": self.plan_name,
            "offered_rps": round(self.offered_rps, 4),
            "goodput_tok_s": round(self.goodput_tok_s, 3),
            "drop_rate": round(self.drop_rate, 4),
            "shed_rate": round(self.shed_rate, 4),
            "retry_rate": round(self.retry_rate, 4),
            "ttft_p50_s": round(self.quantile("ttft", 0.5), 3),
            "ttft_p99_s": round(self.quantile("ttft", 0.99), 3),
            "tpot_p50_s": round(self.quantile("tpot", 0.5), 3),
            "tpot_p99_s": round(self.quantile("tpot", 0.99), 3),
            "e2e_p99_s": round(self.quantile("e2e", 0.99), 3),
            "max_util": round(float(self.station_util.max())
                              if self.station_util.size else 0.0, 3),
            "migration_mb": round(self.migration_bytes / 1e6, 3),
        }
        if slo is not None:
            out["slo_met"] = bool(self.meets(slo))
        return out


@dataclasses.dataclass
class TrafficResult:
    """Outcome of one fleet simulation: one :class:`PlanTraffic` per plan
    of the sweep, plus the shared token bookkeeping the tests pin down."""

    plans: list[PlanTraffic]
    requests: RequestBatch
    slots: np.ndarray          # (M,) topology slot per engine token
    n_bins: int
    dt_s: float

    def __getitem__(self, i: int) -> PlanTraffic:
        """The i-th plan's :class:`PlanTraffic` (sweep order)."""
        return self.plans[i]

    def by_name(self, name: str) -> PlanTraffic:
        """Look up a plan's outcome by its plan name (KeyError if absent)."""
        for p in self.plans:
            if p.plan_name == name:
                return p
        raise KeyError(name)

    def table(self, slo: SLO | None = None, scenario: str = "") -> list[dict]:
        """One flat summary row per plan (optionally SLO-checked and
        tagged with a scenario name)."""
        rows = []
        for p in self.plans:
            row = p.row(slo)
            if scenario:
                row = {"scenario": scenario, **row}
            rows.append(row)
        return rows


def format_table(rows: list[dict], prefix: str = "") -> str:
    """Fixed-width text table from a list of flat dicts."""
    if not rows:
        return prefix + "(no rows)"
    cols = list(rows[0].keys())
    widths = {c: max(len(str(c)), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    lines = [" ".join(str(c).ljust(widths[c]) for c in cols)]
    for r in rows:
        lines.append(" ".join(str(r.get(c, "")).ljust(widths[c])
                              for c in cols))
    return "\n".join(prefix + ln for ln in lines)


# --------------------------------------------------------------------- #
# Saturation sweep
# --------------------------------------------------------------------- #


@dataclasses.dataclass
class SaturationResult:
    """Max sustained arrival rate per plan under an SLO."""

    slo: SLO
    tested_rps: np.ndarray                 # (n_rates,) offered rates
    met: dict[str, np.ndarray]             # plan -> (n_rates,) bool
    sustained_rps: dict[str, float]        # plan -> max offered rate met
    results: list                          # per-rate TrafficResult

    def capacity_ratio(self, a: str, b: str) -> float:
        """Sustained-capacity ratio a/b (inf if b sustains nothing)."""
        num, den = self.sustained_rps[a], self.sustained_rps[b]
        return float(num / den) if den > 0 else float("inf")


def saturation_sweep(
    sim: "FleetSim",
    slo: SLO,
    rng: np.random.Generator,
    fractions: np.ndarray | None = None,
) -> SaturationResult:
    """Thin the simulator's request trace to each fraction and find the
    highest offered rate per plan that still meets the SLO.

    The trace held by ``sim`` is treated as the 100% (envelope) rate; a
    single uniform draw per request makes the thinned sets nested, so a
    plan's pass/fail curve is evaluated on monotone workloads and
    "sustained" is the largest tested rate whose run met the SLO.

    The whole sweep executes as **one compile + one device launch**: the
    nested masks ride the vmapped fraction axis of
    :meth:`~repro.traffic.queueing.FleetSim.run_many`, so adding rates
    costs batched device work, not extra fixed-point round-trips.
    """
    if fractions is None:
        fractions = np.array([0.125, 0.25, 0.5, 0.75, 1.0])
    fractions = np.sort(np.asarray(fractions, dtype=np.float64))
    u = rng.random(sim.requests.n_requests)
    masks = u[None, :] < fractions[:, None]

    results, rates = [], []
    met: dict[str, list[bool]] = {}
    for res in sim.run_many(masks):
        results.append(res)
        # No local re-derivation: the guarded ``offered_rps`` property
        # is the single source for the rate figure, so a degenerate
        # zero-offered row reads identically here and in a per-target
        # ``run`` (pinned in tests/test_metrics.py).
        rates.append(res.plans[0].offered_rps)
        for p in res.plans:
            met.setdefault(p.plan_name, []).append(p.meets(slo))

    rates_arr = np.asarray(rates)
    met_arr = {k: np.asarray(v) for k, v in met.items()}
    sustained = {}
    for name, ok in met_arr.items():
        sustained[name] = float(rates_arr[ok].max()) if ok.any() else 0.0
    return SaturationResult(slo=slo, tested_rps=rates_arr, met=met_arr,
                            sustained_rps=sustained, results=results)
