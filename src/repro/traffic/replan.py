"""Continuous re-placement under traffic: backlog-driven plan scheduling.

The ROADMAP's "continuous re-placement" item, built on the PR's
:class:`~repro.core.schedule.PlanSchedule` abstraction: every topology
slot the controller re-ranks the candidate plans — the cheap batched
``evaluate_plans`` sweep with tokens pinned to the slot — and assembles
a schedule, with **hysteresis** and a **migration-cost gate** deciding
whether a switch is worth the weight bytes it moves.

Scoring (pinned)
----------------
A candidate's score at a decision boundary is its predicted per-token
latency under the *live* queue state::

    score[c] = mean zero-load latency at this slot        (engine sweep)
             + drop_rate[c] * drop_penalty_s              (delivery first)
             + sum_l backlog[gateway_l(c)]
             + sum_l max_i backlog[sat(expert_{l,i}(c))]  (backlog inflation)

The backlog term is the same critical-path estimate the admission
controller's qhat uses (gateway chain plus per-layer worst expert
queue), read from the per-satellite backlog the fleet simulator
observed at the boundary — plans whose satellites are drowning score
badly even if their geometry is ideal.  The incumbent is replaced by
the best candidate only when the predicted gain clears both gates::

    gain > hysteresis * score[incumbent]
         + migration_bytes(incumbent -> best) * weight_s_per_mb / 1e6

so oscillation is damped and a switch must amortize the weights it
drags across ISLs (the ``distributed.elastic`` byte accounting via
:func:`~repro.core.schedule.migration_between`).

:func:`replan_traffic` closes the loop the way a live controller would:
a **probe** fleet run under the static candidates observes the backlog
each boundary, the controller **decides** the schedule from those
observations, and the final fleet run **evaluates** the schedule (with
its migration bytes riding the queues as background load) side by side
with every static candidate — one sweep, common random numbers.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import PlanBatch, evaluate_plans
from repro.core.activation import ActivationModel
from repro.core.latency import ComputeConfig, TopologySample
from repro.core.schedule import PlanSchedule, migration_between
from repro.core.workload import MoEWorkload

from .ground import GroundSegment
from .metrics import TrafficResult
from .queueing import FleetSim, QueueConfig
from .requests import RequestBatch

REPLAN_MODES = ("off", "periodic", "backlog")


@dataclasses.dataclass(frozen=True)
class ReplanConfig:
    """Re-placement controller parameters.

    Attributes:
        mode: ``"off"`` picks the best plan once at t=0 and holds it;
            ``"periodic"`` re-ranks on zero-load scores every
            ``period_slots`` boundaries; ``"backlog"`` additionally
            inflates scores with the live per-satellite backlog.
        period_slots: Decision cadence in topology-slot boundaries.
        hysteresis: Fractional predicted-latency gain a switch must
            clear (damps oscillation between near-tied plans).
        migration_weight_s_per_mb: Switching-cost gate — seconds of
            predicted-latency gain demanded per megabyte of expert
            weights the switch moves.
        bytes_per_expert: Weight bytes per migrated expert (the
            ``distributed.elastic`` accounting unit).  ``None`` (the
            default) inherits the fleet's
            :attr:`~repro.traffic.queueing.QueueConfig
            .migration_bytes_per_expert` in :func:`replan_traffic`, so
            the gate prices exactly what the queues will bill.
        n_tokens: Monte-Carlo tokens per slot decision (the batched
            engine sweep is cheap; draws are shared across boundaries —
            common random numbers).
        drop_penalty_s: Latency charged per undeliverable token so
            delivery dominates speed in the ranking (mirrors
            ``rank_plans``'s drop-first ordering).
        controller_iterations: Decide/observe fixed-point rounds in
            :func:`replan_traffic`.  Round 1 observes the static probe
            rows; each further round re-observes the backlog of the
            *assembled schedule's own* fleet row and re-decides — the
            static rows cannot see the load a switching schedule leaves
            behind on previously-used satellites, so a second round
            damps switch-back oscillation.
    """

    mode: str = "backlog"
    period_slots: int = 1
    hysteresis: float = 0.05
    migration_weight_s_per_mb: float = 0.01
    bytes_per_expert: float | None = None
    n_tokens: int = 128
    drop_penalty_s: float = 60.0
    controller_iterations: int = 2

    def __post_init__(self):
        """Validate the controller parameters."""
        if self.mode not in REPLAN_MODES:
            raise ValueError(
                f"unknown replan mode {self.mode!r}; one of {REPLAN_MODES}")
        if self.period_slots < 1:
            raise ValueError("period_slots must be >= 1")
        if self.hysteresis < 0.0:
            raise ValueError("hysteresis must be >= 0")
        if self.migration_weight_s_per_mb < 0.0:
            raise ValueError("migration_weight_s_per_mb must be >= 0")
        if self.n_tokens < 1:
            raise ValueError("n_tokens must be >= 1")
        if self.controller_iterations < 1:
            raise ValueError("controller_iterations must be >= 1")


@dataclasses.dataclass
class ReplanDecision:
    """One boundary's controller outcome."""

    boundary: int              # wall-clock boundary index k (t = k * period)
    slot: int                  # topology slot entered (k mod N_T)
    chosen: int                # candidate index in effect after the boundary
    switched: bool
    scores: np.ndarray         # (C,) backlog-inflated predicted cost
    migration_bytes: float     # bytes the switch moved (0.0 if held)

    def t_s(self, slot_period_s: float) -> float:
        """Wall-clock seconds of this decision's boundary."""
        return float(self.boundary) * float(slot_period_s)


@dataclasses.dataclass
class ReplanReport:
    """The controller's full trajectory and the schedule it assembled.

    ``trace`` is the joint control plane's decision-event channel
    (:class:`repro.obs.probes.DecisionTrace`) — set only by the fused
    grid path, where the decisions are device telemetry rather than a
    host walk; the host controller leaves it ``None``.
    """

    schedule: PlanSchedule
    decisions: list[ReplanDecision]
    candidates: list
    trace: "DecisionTrace | None" = None

    @property
    def n_switches(self) -> int:
        """Number of boundaries where the plan actually changed."""
        return int(sum(bool(d.switched) for d in self.decisions))

    @property
    def total_migration_bytes(self) -> float:
        """Weight bytes moved across every *decided* switch.

        The fleet's per-row ``PlanTraffic.migration_bytes`` bills every
        boundary its horizon actually crosses — including the periodic
        replay of the schedule past one slot wrap (e.g. the wrap back
        to the slot-0 plan during a long drain tail) — so the two can
        differ when the simulated horizon outruns the decision walk.
        """
        return float(sum(d.migration_bytes for d in self.decisions))

    def events(self, slot_period_s: float) -> list:
        """The decision trajectory as flight-recorder control events
        (one :class:`~repro.obs.recorder.ControlEvent` instant per
        boundary; switches carry their migration byte flow) — the hook
        ``serve.py --trace`` and the exporter consume."""
        from repro.obs.recorder import replan_events
        return replan_events(self, slot_period_s)


def backlog_penalty_s(plan, sat_backlog: np.ndarray) -> float:
    """Critical-path backlog a request routed by ``plan`` would find:
    the gateway chain plus, per layer, the worst expert satellite — the
    same conservative qhat shape the admission law uses."""
    sat_backlog = np.asarray(sat_backlog)
    sats = np.asarray(plan.expert_sats)
    return float(sat_backlog[np.asarray(plan.gateways)].sum()
                 + sat_backlog[sats].max(axis=1).sum())


def build_replan_schedule(
    candidates: list,
    topo: TopologySample,
    activation: ActivationModel,
    workload: MoEWorkload,
    compute: ComputeConfig,
    rng: np.random.Generator,
    rcfg: ReplanConfig,
    horizon_s: float,
    slot_period_s: float,
    backlog_at=None,
    name: str | None = None,
) -> ReplanReport:
    """Walk the wall-clock slot boundaries of ``[0, horizon_s)`` and
    assemble the controller's :class:`~repro.core.schedule.PlanSchedule`.

    Args:
        candidates: Candidate plan pool (shared (n_layers, n_experts)).
        topo: Sampled topology (scores use its per-slot graphs).
        activation: Expert-activation model for the scoring sweeps.
        workload: FLOP model for the scoring sweeps.
        compute: FLOPs -> seconds conversion.
        rng: Source of the shared scoring draws (consumed once).
        rcfg: Controller parameters.
        horizon_s: Wall-clock span the schedule must cover.
        slot_period_s: Seconds per topology slot.
        backlog_at: Optional ``f(boundary_k, t_s, current_candidate) ->
            (V,)`` live per-satellite backlog observation; ``None`` (and
            any mode but ``"backlog"``) scores on zero backlog.
        name: Schedule display name (default ``replan/<mode>``).

    Returns:
        The :class:`ReplanReport` with one decision per boundary walked.
        The walk is capped at one full slot cycle (n_slots - 1
        boundaries): a :class:`~repro.core.schedule.PlanSchedule` is
        periodic in the slot index, so later boundaries replay the
        assignments already decided and a "decision" there could never
        be applied.
    """
    candidates = list(candidates)
    if not candidates:
        raise ValueError("empty candidate pool")
    bytes_per_expert = (rcfg.bytes_per_expert
                        if rcfg.bytes_per_expert is not None
                        else QueueConfig().migration_bytes_per_expert)
    n_slots = topo.n_slots
    batch = PlanBatch.from_plans(candidates, topo)
    # Shared draws: every boundary's sweep sees the same expert draws
    # (common random numbers), so score motion reflects the topology
    # slot and the backlog, not sampling noise.
    draws = np.stack([activation.sample(layer, rng, rcfg.n_tokens)
                      for layer in range(activation.n_layers)])

    def scores_at(slot: int, backlog: np.ndarray | None) -> np.ndarray:
        res = evaluate_plans(
            candidates, topo, activation, workload, compute, rng,
            n_tokens=rcfg.n_tokens, batch=batch,
            slots=np.full(rcfg.n_tokens, slot, dtype=np.int64), draws=draws)
        out = np.empty(len(candidates))
        for c, r in enumerate(res):
            base = r.mean_s if np.isfinite(r.mean_s) else rcfg.drop_penalty_s
            out[c] = base + r.drop_rate * rcfg.drop_penalty_s
            if backlog is not None:
                out[c] += backlog_penalty_s(candidates[c], backlog)
        return out

    slot_plan = np.full(n_slots, -1, dtype=np.int64)
    decisions: list[ReplanDecision] = []
    n_bounds = min(int(np.floor(max(horizon_s, 0.0) / slot_period_s)),
                   n_slots - 1)
    current = -1
    for k in range(n_bounds + 1):
        slot = k % n_slots
        decide = (k == 0
                  or (rcfg.mode != "off" and k % rcfg.period_slots == 0))
        if decide:
            backlog = None
            if rcfg.mode == "backlog" and backlog_at is not None and k > 0:
                backlog = backlog_at(k, k * slot_period_s, current)
            scores = scores_at(slot, backlog)
            best = int(np.argmin(scores))
            if current < 0:
                # Initial placement is free: no hysteresis, no migration.
                chosen, switched, mig_bytes = best, False, 0.0
            else:
                gain = scores[current] - scores[best]
                mig = migration_between(candidates[current],
                                        candidates[best],
                                        bytes_per_expert)
                gate = (rcfg.hysteresis * scores[current]
                        + mig.bytes_moved
                        * rcfg.migration_weight_s_per_mb / 1e6)
                switched = bool(best != current and gain > gate)
                chosen = best if switched else current
                mig_bytes = mig.bytes_moved if switched else 0.0
            decisions.append(ReplanDecision(
                boundary=k, slot=slot, chosen=chosen, switched=switched,
                scores=scores, migration_bytes=mig_bytes))
            current = chosen
        slot_plan[slot] = current
    slot_plan[slot_plan < 0] = current   # slots the horizon never reaches
    schedule = PlanSchedule(plans=candidates, slot_plan=slot_plan,
                            name=name or f"replan/{rcfg.mode}")
    return ReplanReport(schedule=schedule, decisions=decisions,
                        candidates=candidates)


def replan_base_scores(
    candidates: list,
    topo: TopologySample,
    activation: ActivationModel,
    workload: MoEWorkload,
    compute: ComputeConfig,
    rng: np.random.Generator,
    rcfg: ReplanConfig,
) -> np.ndarray:
    """Backlog-free candidate scores per topology slot, (n_slots, C).

    Exactly the ``scores_at(slot, backlog=None)`` table of
    :func:`build_replan_schedule` — zero-load mean latency plus the
    drop penalty, with the shared common-random-number draws consumed
    from ``rng`` once.  The joint control plane
    (``FleetSim.run_replan_grid``) precomputes this host-side and adds
    the backlog-inflation term on device, so the decide walk's scores
    match the host controller's bit for bit.
    """
    candidates = list(candidates)
    if not candidates:
        raise ValueError("empty candidate pool")
    batch = PlanBatch.from_plans(candidates, topo)
    draws = np.stack([activation.sample(layer, rng, rcfg.n_tokens)
                      for layer in range(activation.n_layers)])
    out = np.empty((topo.n_slots, len(candidates)))
    for slot in range(topo.n_slots):
        res = evaluate_plans(
            candidates, topo, activation, workload, compute, rng,
            n_tokens=rcfg.n_tokens, batch=batch,
            slots=np.full(rcfg.n_tokens, slot, dtype=np.int64),
            draws=draws)
        for c, r in enumerate(res):
            base = r.mean_s if np.isfinite(r.mean_s) else rcfg.drop_penalty_s
            out[slot, c] = base + r.drop_rate * rcfg.drop_penalty_s
    return out


@dataclasses.dataclass
class ReplanOutcome:
    """Probe -> decide -> evaluate, bundled.

    ``result`` holds C + 1 rows: every static candidate plus the
    controller's schedule (named ``replan/<mode>``), simulated in one
    fleet sweep under common random numbers — the apples-to-apples
    comparison the acceptance benchmarks plot.
    """

    report: ReplanReport
    result: TrafficResult
    probe: TrafficResult | None      # None unless mode == "backlog"
    sim: FleetSim

    @property
    def replanned(self):
        """The schedule row of ``result``."""
        return self.result.by_name(self.report.schedule.name)

    def best_static(self, key=lambda p: -p.goodput_tok_s):
        """The best static candidate row of ``result`` (default: by
        goodput)."""
        static = [p for p in self.result.plans
                  if p.plan_name != self.report.schedule.name]
        return min(static, key=key)


def replan_traffic(
    candidates: list,
    topo: TopologySample,
    activation: ActivationModel,
    workload: MoEWorkload,
    compute: ComputeConfig,
    requests: RequestBatch,
    rng: np.random.Generator,
    rcfg: ReplanConfig,
    qcfg: QueueConfig,
    ground: GroundSegment | None = None,
    batching=None,
    **sim_kwargs,
) -> ReplanOutcome:
    """Close the re-placement loop over one request trace.

    ``batching`` (an optional
    :class:`~repro.traffic.batching.BatchingConfig`) applies the
    continuous-batching service law to *every* fleet run of the loop —
    the probe row, each decide/evaluate round and the final evaluation —
    so the controller observes and is scored on the same batched
    queues; further keyword arguments (``service_model=``, ``probes=``,
    ...) forward to :class:`~repro.traffic.queueing.FleetSim` the same
    way.

    1. **Probe**: run the fleet with every candidate held static and
       record the (plan, satellite, bin) backlog — what a live
       controller would observe on the running system.
    2. **Decide**: walk the slot boundaries; at each decision the
       controller reads the backlog of the *currently chosen*
       candidate's probe row (the system it would actually be running)
       and re-ranks the pool.
    3. **Evaluate**: one fleet sweep of the static candidates plus the
       assembled schedule, migration bytes riding the ISL queues as
       background load.  With ``controller_iterations > 1`` the
       controller then re-observes the backlog of the *schedule's own*
       row — which carries the load its earlier switches left behind,
       invisible to any static probe row — re-decides, and re-evaluates
       (decide <-> observe fixed point, hysteresis-damped).

    All fleet runs share a seed, so engine draws and admission uniforms
    are common random numbers across every row of every round.
    """
    if rcfg.bytes_per_expert is None:
        # The gate must price exactly what the queues will bill.
        rcfg = dataclasses.replace(
            rcfg, bytes_per_expert=qcfg.migration_bytes_per_expert)
    if batching is not None:
        sim_kwargs = dict(sim_kwargs, batching=batching)
    seed = int(rng.integers(0, 2**31 - 1))
    # The probe *construction* (engine pass) fixes the bin horizon the
    # decision walk must cover; only the backlog mode pays for the full
    # probe *run* — its observations are unread otherwise.
    probe_sim = FleetSim(candidates, topo, activation, workload, compute,
                         requests, np.random.default_rng(seed), qcfg=qcfg,
                         ground=ground, **sim_kwargs)
    probe_res = probe_sim.run() if rcfg.mode == "backlog" else None

    # Decide over the whole simulated horizon (arrivals + drain tail):
    # the fleet bills every boundary it crosses, so every billed switch
    # inside the first slot cycle should be a decided one.
    decision_span_s = probe_sim.n_bins * qcfg.dt_s

    def build(backlog_at):
        return build_replan_schedule(
            candidates, topo, activation, workload, compute,
            np.random.default_rng(seed + 1), rcfg,
            horizon_s=decision_span_s, slot_period_s=qcfg.slot_period_s,
            backlog_at=backlog_at if rcfg.mode == "backlog" else None)

    # Pin every decide<->observe round to one time-bin count: equal
    # shapes mean each round's fleet run reuses the fused fixed point's
    # compile cache (a genuinely longer horizon still wins and retraces).
    eval_bins = {"n_bins": 0}

    def evaluate(schedule):
        sim = FleetSim(list(candidates) + [schedule], topo, activation,
                       workload, compute, requests,
                       np.random.default_rng(seed), qcfg=qcfg,
                       ground=ground, min_bins=eval_bins["n_bins"],
                       **sim_kwargs)
        eval_bins["n_bins"] = sim.n_bins
        return sim, sim.run()

    report = build(lambda _k, t_s, cur:
                   probe_sim.satellite_backlog(max(cur, 0), t_s))
    final_sim, result = evaluate(report.schedule)
    for _ in range(rcfg.controller_iterations - 1):
        if rcfg.mode != "backlog":
            break                        # nothing new to observe
        sched_row = len(candidates)      # the schedule's own fleet row
        next_report = build(lambda _k, t_s, _cur:
                            final_sim.satellite_backlog(sched_row, t_s))
        if np.array_equal(next_report.schedule.slot_plan,
                          report.schedule.slot_plan):
            report = next_report
            break                        # fixed point reached
        report = next_report
        final_sim, result = evaluate(report.schedule)
    return ReplanOutcome(report=report, result=result,
                         probe=probe_res, sim=final_sim)


def replan_traffic_fused(
    candidates: list,
    topo: TopologySample,
    activation: ActivationModel,
    workload: MoEWorkload,
    compute: ComputeConfig,
    requests: RequestBatch,
    rng: np.random.Generator,
    rcfg: ReplanConfig,
    qcfg: QueueConfig,
    ground: GroundSegment | None = None,
    *,
    cadences=None,
    mig_weights=None,
    ttft_targets=None,
    tpot_targets=None,
    **sim_kwargs,
):
    """The joint control plane: :func:`replan_traffic` in ONE launch.

    Same signature and seed discipline as the host loop (one
    ``rng.integers`` draw seeds the fleet, seed+1 seeds the scoring
    draws — common random numbers match round for round), but probe,
    decide walk and schedule evaluation execute inside a single fused
    device program (``queueing._ctrl_core``).  On CPU the outcome's
    decisions, switch boundaries and served/shed sets reproduce
    :func:`replan_traffic` exactly; the host loop stays authoritative
    for continuous batching, probe rings and calibrated per-satellite
    service, which this path rejects.

    With any of ``cadences`` / ``mig_weights`` / ``ttft_targets`` given,
    the call becomes a controller *grid* — every cell batches the
    leading axis of the same single launch — and returns one
    :class:`ReplanOutcome` per cell (cadence-major order).  Otherwise a
    single :class:`ReplanOutcome` is returned, with ``sim`` set to the
    probe simulator (the host loop's ``sim`` is its final evaluation
    simulator; the fused path never builds one).
    """
    if rcfg.bytes_per_expert is None:
        rcfg = dataclasses.replace(
            rcfg, bytes_per_expert=qcfg.migration_bytes_per_expert)
    seed = int(rng.integers(0, 2**31 - 1))
    sim = FleetSim(candidates, topo, activation, workload, compute,
                   requests, np.random.default_rng(seed), qcfg=qcfg,
                   ground=ground, **sim_kwargs)
    scores = replan_base_scores(candidates, topo, activation, workload,
                                compute, np.random.default_rng(seed + 1),
                                rcfg)
    outcomes = sim.run_replan_grid(
        rcfg, base_scores=scores, cadences=cadences,
        mig_weights=mig_weights, ttft_targets=ttft_targets,
        tpot_targets=tpot_targets)
    if (cadences is None and mig_weights is None and ttft_targets is None
            and tpot_targets is None):
        return outcomes[0]
    return outcomes
