"""User -> ground gateway -> ingress-satellite mapping.

Ground gateways sit on the rotating Earth; satellites are propagated in
ECI by :class:`repro.core.Constellation`.  Per topology slot we rotate
each gateway into ECI (Earth spin about +z — consistent with the polar
Walker geometry, whose z axis is the rotation axis), compute elevation
angles to every satellite, and pick the highest-elevation visible
satellite as the ingress node.  Uplink latency = slant range / c + the
token transmission time at the (slower) ground-to-space rate.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import Constellation, LinkConfig
from repro.core.constellation import EARTH_RADIUS_M, SPEED_OF_LIGHT

EARTH_ROTATION_RAD_S = 7.2921159e-5   # sidereal rotation rate


@dataclasses.dataclass(frozen=True)
class GroundStation:
    """A ground gateway site (user traffic aggregation point)."""

    name: str
    lat_deg: float
    lon_deg: float

    def ecef(self) -> np.ndarray:
        """(3,) position on the (spherical) Earth surface, meters."""
        lat = np.deg2rad(self.lat_deg)
        lon = np.deg2rad(self.lon_deg)
        return EARTH_RADIUS_M * np.array([
            np.cos(lat) * np.cos(lon),
            np.cos(lat) * np.sin(lon),
            np.sin(lat),
        ])


# A default global gateway set: one aggregation site per macro-region,
# spread in longitude so diurnal scenarios sweep around the planet.
DEFAULT_STATIONS: tuple[GroundStation, ...] = (
    GroundStation("north-america", 40.0, -100.0),
    GroundStation("south-america", -15.0, -55.0),
    GroundStation("europe", 50.0, 10.0),
    GroundStation("africa", 0.0, 25.0),
    GroundStation("south-asia", 20.0, 78.0),
    GroundStation("east-asia", 35.0, 115.0),
    GroundStation("oceania", -30.0, 140.0),
    GroundStation("polar-research", 78.0, 15.0),
)


@dataclasses.dataclass
class GroundSegment:
    """Per-slot ingress mapping for a set of ground stations.

    ingress_sat[n, s]  — best visible satellite for station s in slot n
                         (argmax elevation; -1 when none is visible).
    uplink_s[n, s]     — uplink latency to that satellite (+inf if none).
    elevation_rad[n, s] — elevation of the chosen satellite.
    """

    stations: tuple[GroundStation, ...]
    ingress_sat: np.ndarray
    uplink_s: np.ndarray
    elevation_rad: np.ndarray
    min_elevation_deg: float

    @property
    def n_stations(self) -> int:
        return len(self.stations)

    @property
    def n_slots(self) -> int:
        return self.ingress_sat.shape[0]

    def coverage(self) -> float:
        """Fraction of (slot, station) pairs with a visible satellite."""
        return float((self.ingress_sat >= 0).mean())

    def for_requests(self, slots: np.ndarray, station: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray]:
        """(ingress_sat, uplink_s) per request given its slot + station."""
        slots = np.asarray(slots)
        station = np.asarray(station)
        return (self.ingress_sat[slots, station],
                self.uplink_s[slots, station])


def build_ground_segment(
    constellation: Constellation,
    link: LinkConfig,
    stations: tuple[GroundStation, ...] = DEFAULT_STATIONS,
    min_elevation_deg: float = 25.0,
    uplink_rate_gbps: float = 10.0,
    slot_times: np.ndarray | None = None,
) -> GroundSegment:
    """Compute the per-slot station -> ingress-satellite table.

    ``uplink_rate_gbps`` is the ground-to-space feeder rate (an order of
    magnitude below the optical ISL rate by default); the per-token
    transmission time reuses the :class:`LinkConfig` token size.
    """
    cfg = constellation.cfg
    times = cfg.slot_times() if slot_times is None else np.asarray(slot_times)
    n_slots = len(times)
    n_st = len(stations)
    gs_ecef = np.stack([s.ecef() for s in stations])            # (S, 3)

    tx_s = (link.token_dim * link.bits_per_value) / (uplink_rate_gbps * 1e9)
    min_el = np.deg2rad(min_elevation_deg)

    ingress = np.full((n_slots, n_st), -1, dtype=np.int64)
    uplink = np.full((n_slots, n_st), np.inf, dtype=np.float64)
    elev = np.full((n_slots, n_st), -np.pi / 2, dtype=np.float64)
    for n, t in enumerate(times):
        sat_pos = constellation.positions(float(t))             # (V, 3)
        theta = EARTH_ROTATION_RAD_S * float(t)
        c, s = np.cos(theta), np.sin(theta)
        rot = np.array([[c, -s, 0.0], [s, c, 0.0], [0.0, 0.0, 1.0]])
        gs = gs_ecef @ rot.T                                    # (S, 3) in ECI
        los = sat_pos[None, :, :] - gs[:, None, :]              # (S, V, 3)
        rng_m = np.linalg.norm(los, axis=-1)
        up = gs / np.linalg.norm(gs, axis=-1, keepdims=True)
        sin_el = np.einsum("svi,si->sv", los, up) / rng_m
        el = np.arcsin(np.clip(sin_el, -1.0, 1.0))              # (S, V)
        el_masked = np.where(el >= min_el, el, -np.inf)
        best = el_masked.argmax(axis=1)                         # (S,)
        seen = np.isfinite(el_masked[np.arange(n_st), best])
        ingress[n, seen] = best[seen]
        uplink[n, seen] = rng_m[np.arange(n_st), best][seen] / SPEED_OF_LIGHT \
            + tx_s
        elev[n, seen] = el[np.arange(n_st), best][seen]
    return GroundSegment(
        stations=tuple(stations), ingress_sat=ingress, uplink_s=uplink,
        elevation_rad=elev, min_elevation_deg=min_elevation_deg,
    )
