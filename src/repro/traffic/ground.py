"""User -> ground gateway -> ingress-satellite mapping.

Ground gateways sit on the rotating Earth; satellites are propagated in
ECI by :class:`repro.core.Constellation`.  Per topology slot we rotate
each gateway into ECI (Earth spin about +z — consistent with the polar
Walker geometry, whose z axis is the rotation axis), compute elevation
angles to every satellite, and keep the *ranked* top-R visible
satellites per gateway (descending elevation) rather than just the
argmax: rank 0 is the ingress node, the deeper ranks feed fallback
routing and the admission controller's gateway-retry path.  Uplink
latency = slant range / c + the token transmission time at the (slower)
ground-to-space rate.

Gateways are also connected to each other terrestrially (fiber
backbone): :attr:`GroundSegment.ground_delay_s` holds the great-circle
propagation delay between every gateway pair, and
:meth:`GroundSegment.retry_stations` ranks, per (slot, origin gateway),
the alternative gateways a shed request should retry at — ordered by
terrestrial-forward + best-uplink latency, invisible gateways last.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import Constellation, LinkConfig
from repro.core.constellation import EARTH_RADIUS_M, SPEED_OF_LIGHT

EARTH_ROTATION_RAD_S = 7.2921159e-5   # sidereal rotation rate
#: Effective speed of light in the terrestrial fiber backbone (refractive
#: index ~1.5) used for gateway-to-gateway forwarding of retried requests.
FIBER_LIGHT_FRACTION = 0.66


@dataclasses.dataclass(frozen=True)
class GroundStation:
    """A ground gateway site (user traffic aggregation point).

    Attributes:
        name: Human-readable region label.
        lat_deg: Geodetic latitude, degrees.
        lon_deg: Longitude, degrees east.
    """

    name: str
    lat_deg: float
    lon_deg: float

    def ecef(self) -> np.ndarray:
        """(3,) position on the (spherical) Earth surface, meters."""
        lat = np.deg2rad(self.lat_deg)
        lon = np.deg2rad(self.lon_deg)
        return EARTH_RADIUS_M * np.array([
            np.cos(lat) * np.cos(lon),
            np.cos(lat) * np.sin(lon),
            np.sin(lat),
        ])


# A default global gateway set: one aggregation site per macro-region,
# spread in longitude so diurnal scenarios sweep around the planet.
DEFAULT_STATIONS: tuple[GroundStation, ...] = (
    GroundStation("north-america", 40.0, -100.0),
    GroundStation("south-america", -15.0, -55.0),
    GroundStation("europe", 50.0, 10.0),
    GroundStation("africa", 0.0, 25.0),
    GroundStation("south-asia", 20.0, 78.0),
    GroundStation("east-asia", 35.0, 115.0),
    GroundStation("oceania", -30.0, 140.0),
    GroundStation("polar-research", 78.0, 15.0),
)


def ground_delay_table(stations: tuple[GroundStation, ...]) -> np.ndarray:
    """(S, S) terrestrial forwarding delay between gateways, seconds.

    Great-circle distance on the spherical Earth divided by the fiber
    propagation speed (``FIBER_LIGHT_FRACTION`` * c).  Diagonal is zero.
    """
    pos = np.stack([s.ecef() for s in stations])                 # (S, 3)
    unit = pos / np.linalg.norm(pos, axis=-1, keepdims=True)
    cosang = np.clip(unit @ unit.T, -1.0, 1.0)
    arc_m = EARTH_RADIUS_M * np.arccos(cosang)
    np.fill_diagonal(arc_m, 0.0)          # arccos noise on the diagonal
    return arc_m / (FIBER_LIGHT_FRACTION * SPEED_OF_LIGHT)


@dataclasses.dataclass
class GroundSegment:
    """Per-slot ranked ingress mapping for a set of ground stations.

    The rank axis (size ``n_ranked``) orders each station's visible
    satellites by descending elevation; rank 0 is the classic
    best-elevation ingress choice.

    Attributes:
        stations: The gateway sites, index = station id everywhere below.
        ingress_sat: (n_slots, S) best visible satellite per station
            (-1 when none is visible).  Equals ``ingress_ranked[..., 0]``.
        uplink_s: (n_slots, S) uplink latency to that satellite (+inf if
            none visible).
        elevation_rad: (n_slots, S) elevation of the chosen satellite.
        min_elevation_deg: Visibility mask threshold used at build time.
        ingress_ranked: (n_slots, S, n_ranked) satellites by descending
            elevation, -1 past the last visible one.
        uplink_ranked_s: (n_slots, S, n_ranked) matching uplink latencies
            (+inf where no satellite).
        elevation_ranked_rad: (n_slots, S, n_ranked) matching elevations.
        ground_delay_s: (S, S) terrestrial gateway-to-gateway forwarding
            delay (see :func:`ground_delay_table`).
    """

    stations: tuple[GroundStation, ...]
    ingress_sat: np.ndarray
    uplink_s: np.ndarray
    elevation_rad: np.ndarray
    min_elevation_deg: float
    ingress_ranked: np.ndarray | None = None
    uplink_ranked_s: np.ndarray | None = None
    elevation_ranked_rad: np.ndarray | None = None
    ground_delay_s: np.ndarray | None = None

    def __post_init__(self):
        """Backfill the ranked/terrestrial tables for legacy constructors
        that only supply the argmax (rank-0) arrays."""
        if self.ingress_ranked is None:
            self.ingress_ranked = self.ingress_sat[..., None]
            self.uplink_ranked_s = self.uplink_s[..., None]
            self.elevation_ranked_rad = self.elevation_rad[..., None]
        if self.ground_delay_s is None:
            self.ground_delay_s = ground_delay_table(self.stations)

    @property
    def n_stations(self) -> int:
        """Number of ground gateway sites."""
        return len(self.stations)

    @property
    def n_slots(self) -> int:
        """Number of topology slots the tables were built for."""
        return self.ingress_sat.shape[0]

    @property
    def n_ranked(self) -> int:
        """Depth of the ranked-visibility table (satellites per station)."""
        return self.ingress_ranked.shape[2]

    def coverage(self) -> float:
        """Fraction of (slot, station) pairs with a visible satellite."""
        return float((self.ingress_sat >= 0).mean())

    def for_requests(self, slots: np.ndarray, station: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray]:
        """(ingress_sat, uplink_s) per request given its slot + station.

        Args:
            slots: (R,) topology slot of each request.
            station: (R,) originating gateway of each request.

        Returns:
            Two (R,) arrays: best-elevation ingress satellite (-1 if the
            station sees nothing) and the matching uplink latency.
        """
        slots = np.asarray(slots)
        station = np.asarray(station)
        return (self.ingress_sat[slots, station],
                self.uplink_s[slots, station])

    def ranked_for_requests(self, slots: np.ndarray, station: np.ndarray
                            ) -> tuple[np.ndarray, np.ndarray]:
        """Ranked (ingress sats, uplinks) per request.

        Args:
            slots: (R,) topology slot of each request.
            station: (R,) originating gateway of each request.

        Returns:
            (R, n_ranked) satellite ids (-1 pads the invisible tail) and
            (R, n_ranked) uplink latencies (+inf on the pads).
        """
        slots = np.asarray(slots)
        station = np.asarray(station)
        return (self.ingress_ranked[slots, station],
                self.uplink_ranked_s[slots, station])

    def retry_stations(self, slots: np.ndarray, origin: np.ndarray,
                       n_alternatives: int) -> np.ndarray:
        """Ranked alternative gateways for admission-rejected requests.

        For each request, the other gateways are ordered by the latency a
        retried request would pay to enter through them: terrestrial
        forwarding delay from the origin plus the candidate's best (rank
        0) uplink in that slot.  Gateways with no visible satellite sort
        last (their uplink is +inf, so the caller's feasibility mask —
        ``ingress_sat >= 0`` — rejects them).

        Args:
            slots: (R,) topology slot of each request.
            origin: (R,) gateway the request originally arrived at.
            n_alternatives: How many ranked alternatives to return.

        Returns:
            (R, n_alternatives) station indices, best retry target first.
            The origin itself never appears.
        """
        slots = np.asarray(slots)
        origin = np.asarray(origin)
        n_alt = min(n_alternatives, self.n_stations - 1)
        if n_alt <= 0:
            return np.empty((len(origin), 0), dtype=np.int64)
        score = self.uplink_s[slots] + self.ground_delay_s[origin]  # (R, S)
        order = np.argsort(score, axis=1, kind="stable")            # (R, S)
        # Drop the origin from every row (it may tie at +inf with
        # invisible gateways, so masking by score alone is not enough):
        # a stable sort on the "is origin" flag compacts it to the end.
        not_origin = order != origin[:, None]
        order = np.take_along_axis(
            order, np.argsort(~not_origin, axis=1, kind="stable"), axis=1)
        return order[:, :n_alt]


def build_ground_segment(
    constellation: Constellation,
    link: LinkConfig,
    stations: tuple[GroundStation, ...] = DEFAULT_STATIONS,
    min_elevation_deg: float = 25.0,
    uplink_rate_gbps: float = 10.0,
    slot_times: np.ndarray | None = None,
    n_ranked: int = 4,
) -> GroundSegment:
    """Compute the per-slot station -> ranked-ingress-satellite table.

    Args:
        constellation: Propagates satellite ECI positions per slot.
        link: Supplies the per-token payload size for the uplink
            transmission-time term.
        stations: Gateway sites (defaults to one per macro-region).
        min_elevation_deg: Satellites below this elevation are invisible.
        uplink_rate_gbps: Ground-to-space feeder rate (an order of
            magnitude below the optical ISL rate by default).
        slot_times: Optional explicit slot sample times (seconds);
            defaults to the constellation's own slot grid.
        n_ranked: Depth of the ranked-visibility table kept per station.

    Returns:
        A :class:`GroundSegment` with both the rank-0 (argmax) arrays and
        the full ranked tables populated.
    """
    cfg = constellation.cfg
    times = cfg.slot_times() if slot_times is None else np.asarray(slot_times)
    n_slots = len(times)
    n_st = len(stations)
    gs_ecef = np.stack([s.ecef() for s in stations])            # (S, 3)

    tx_s = (link.token_dim * link.bits_per_value) / (uplink_rate_gbps * 1e9)
    min_el = np.deg2rad(min_elevation_deg)
    n_ranked = max(1, min(n_ranked, cfg.n_sats))

    rows = np.arange(n_st)[:, None]
    ranked = np.full((n_slots, n_st, n_ranked), -1, dtype=np.int64)
    uplink_r = np.full((n_slots, n_st, n_ranked), np.inf, dtype=np.float64)
    elev_r = np.full((n_slots, n_st, n_ranked), -np.pi / 2, dtype=np.float64)
    for n, t in enumerate(times):
        sat_pos = constellation.positions(float(t))             # (V, 3)
        theta = EARTH_ROTATION_RAD_S * float(t)
        c, s = np.cos(theta), np.sin(theta)
        rot = np.array([[c, -s, 0.0], [s, c, 0.0], [0.0, 0.0, 1.0]])
        gs = gs_ecef @ rot.T                                    # (S, 3) in ECI
        los = sat_pos[None, :, :] - gs[:, None, :]              # (S, V, 3)
        rng_m = np.linalg.norm(los, axis=-1)
        up = gs / np.linalg.norm(gs, axis=-1, keepdims=True)
        sin_el = np.einsum("svi,si->sv", los, up) / rng_m
        el = np.arcsin(np.clip(sin_el, -1.0, 1.0))              # (S, V)
        el_masked = np.where(el >= min_el, el, -np.inf)
        order = np.argsort(-el_masked, axis=1, kind="stable")[:, :n_ranked]
        seen = np.isfinite(el_masked[rows, order])              # (S, n_ranked)
        ranked[n] = np.where(seen, order, -1)
        uplink_r[n] = np.where(
            seen, rng_m[rows, order] / SPEED_OF_LIGHT + tx_s, np.inf)
        elev_r[n] = np.where(seen, el[rows, order], -np.pi / 2)
    return GroundSegment(
        stations=tuple(stations),
        ingress_sat=ranked[..., 0].copy(),
        uplink_s=uplink_r[..., 0].copy(),
        elevation_rad=elev_r[..., 0].copy(),
        min_elevation_deg=min_elevation_deg,
        ingress_ranked=ranked, uplink_ranked_s=uplink_r,
        elevation_ranked_rad=elev_r,
        ground_delay_s=ground_delay_table(tuple(stations)),
    )


def rank_constellations(costs: np.ndarray) -> np.ndarray:
    """Deterministic cross-constellation preference order per request.

    The federation-level generalization of the per-constellation
    ``ingress_ranked`` table: given each constellation's ingress cost
    for each request (uplink + gateway hop; ``+inf`` marks a
    constellation whose ground segment cannot ingest the request at
    all), rank the constellations best-first.  A stable argsort breaks
    ties — equal costs, and the all-``+inf`` tail — by constellation
    index, so the federation scheduler's routing is reproducible
    across platforms.

    Args:
        costs: (K, R) per-constellation ingress cost per request
            (``np.inf`` = infeasible).

    Returns:
        (R, K) constellation indices, best first; infeasible
        constellations sort last (callers must still consult the cost
        to know where the feasible prefix ends).
    """
    costs = np.asarray(costs, dtype=np.float64)
    if costs.ndim != 2:
        raise ValueError(f"costs must be (K, R), got {costs.shape}")
    return np.argsort(costs, axis=0, kind="stable").T
