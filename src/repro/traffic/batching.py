"""Continuous decode batching for the fleet queue kernel.

The FIFO queue kernel of PR 2 serves every decode token one-at-a-time:
a token's deposit occupies its satellite for the full single-token
service time.  Real serving systems run *continuous batching* — decode
steps of requests sharing an accelerator are grouped per step, the
weight reads amortize over the group, and per-token service shrinks to
``B / decode_rate(B)`` (the batch-size-dependent rates
:meth:`repro.core.calibration.ServiceModel.decode_rate` exposes off the
measured decode-attention roofline).  This module supplies the law the
fused fleet scan applies:

**Deposit-time scaling.**  Alongside the offered-work plane ``work``
the kernel scatters a decode-work plane ``work_dec`` (the decode-side
subset of the deposits) and an occupancy-count plane ``cnt`` (decode
token visits per (satellite, bin) — deposits are already grouped per
(satellite, step), the count plane is the group size).  Per
(row, bin) the admissible batch is

    ``B_eff = clip(window_sum(cnt), 1, B_cap)``,
    ``B_cap = min(b_max, kv_slots_per_sat)``  (KV-slot occupancy bound),

the speedup ``s(B_eff)`` is a piecewise-linear interpolation of a
monotone per-batch speedup table with ``s(1) = 1``, and the scan runs
on the *effective* work

    ``work_eff = work + work_dec * (1 / s(B_eff) - 1)``.

Scaling at deposit time (rather than state-dependent drain rates)
keeps the backlog recursion itself untouched, which buys two pinned
invariants for free:

* **B_max = 1 is bitwise FIFO** — ``B_eff ≡ 1`` makes ``s ≡ 1.0``
  exactly, so ``work_dec * (1/s - 1)`` is an exact multiply-by-zero and
  ``work_eff == work`` bit-for-bit (fma-safe: ``fma(w_dec, 0, w) = w``);
* **monotone in B_max** — a larger cap yields pointwise-larger ``s``,
  hence pointwise-smaller ``work_eff``, and the scan step
  ``f(b, w) = max(min(b + w, cap) - dt, 0)`` is monotone in both
  arguments, so waits and drops are pointwise non-increasing in
  ``B_max`` (the property tests exercise exactly this argument).

``batching=None`` follows the ``service_model=``/``probes=`` static-flag
pattern: the fused kernel's traced computation stays byte-identical to
the batching-free kernel and shares its compile-cache entry.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class BatchingConfig:
    """Continuous decode-batching parameters (static per simulator).

    Attributes:
        b_max: Largest decode batch a satellite may form per time bin.
            ``b_max=1`` is pinned bitwise-identical to the FIFO kernel.
        kv_slots_per_sat: KV-cache slots one satellite can hold; bounds
            the admissible batch (``B_cap = min(b_max, kv_slots)``).
            0 = unbounded by KV (the batch is bounded by ``b_max`` only).
        window_s: Occupancy window, seconds.  The batch a deposit joins
            is estimated from the decode-visit count of the trailing
            window (inclusive of the deposit's own bin); 0 uses exactly
            one bin — deposits grouped per (satellite, step).
        speedup: Optional explicit per-batch speedup table
            ``(s(1), s(2), ..., s(n))`` overriding the service model's
            (clamped monotone and >= 1, extended flat past its end).
            ``None`` reads the table off
            :meth:`~repro.core.calibration.ServiceModel.batch_speedup`.
    """

    b_max: int = 8
    kv_slots_per_sat: int = 0
    window_s: float = 0.0
    speedup: tuple | None = None

    def __post_init__(self):
        """Validate the batching parameters."""
        if self.b_max < 1:
            raise ValueError("b_max must be >= 1")
        if self.kv_slots_per_sat < 0:
            raise ValueError("kv_slots_per_sat must be >= 0")
        if self.window_s < 0.0:
            raise ValueError("window_s must be >= 0")
        if self.speedup is not None:
            sp = np.asarray(self.speedup, dtype=np.float64)
            if sp.ndim != 1 or sp.size < 1:
                raise ValueError("speedup must be a non-empty 1-D table")
            if not np.all(np.isfinite(sp)) or np.any(sp <= 0.0):
                raise ValueError("speedup entries must be finite and > 0")

    @property
    def b_cap(self) -> int:
        """The admissible batch bound: ``min(b_max, kv_slots_per_sat)``
        (the KV-slot occupancy bound; unbounded KV keeps ``b_max``)."""
        if self.kv_slots_per_sat > 0:
            return int(min(self.b_max, self.kv_slots_per_sat))
        return int(self.b_max)

    def window_bins(self, dt_s: float) -> int:
        """Occupancy window in whole time bins (>= 1)."""
        return max(1, int(round(self.window_s / dt_s)))

    def resolve_table(self, service_model=None,
                      ctx_len: int = 1024) -> np.ndarray:
        """The padded interpolation table the kernels index.

        Returns a ``(b_cap + 2,)`` float64 array with ``table[b]`` the
        speedup at batch b for ``b in 1..b_cap``, ``table[0] = 1`` and a
        flat extension at ``table[b_cap + 1]`` (so linear interpolation
        of ``B_eff in [1, b_cap]`` never reads out of range).  Entries
        are clamped monotone non-decreasing with ``table[1] = 1``
        exactly — the bitwise ``b_max=1`` contract.
        """
        cap = self.b_cap
        if self.speedup is not None:
            s = np.asarray(self.speedup, dtype=np.float64)
        elif service_model is not None:
            s = np.asarray(service_model.batch_speedup(cap, ctx_len),
                           dtype=np.float64)
        else:
            s = np.ones(cap, dtype=np.float64)
        if s.size < cap:
            s = np.concatenate([s, np.full(cap - s.size, s[-1])])
        s = np.maximum.accumulate(np.maximum(s[:cap], 1.0))
        s[0] = 1.0
        return np.concatenate([[1.0], s, [s[-1]]])


def windowed_counts(cnt: np.ndarray, window_bins: int) -> np.ndarray:
    """Causal inclusive window sum of ``cnt`` along the last (time) axis:
    ``out[..., t] = sum(cnt[..., t - w + 1 : t + 1])`` for window w."""
    w = int(window_bins)
    if w <= 1:
        return cnt
    cs = np.cumsum(cnt, axis=-1)
    out = cs.copy()
    out[..., w:] -= cs[..., :-w]
    return out


def batch_speedup_at(cnt_win, table: np.ndarray, b_cap: float):
    """(s, B_eff) at a windowed occupancy count (numpy arrays).

    ``B_eff = clip(cnt_win, 1, b_cap)``; ``s`` linearly interpolates the
    padded ``table`` (see :meth:`BatchingConfig.resolve_table`) at
    ``B_eff``.  ``b_cap = 1`` yields ``s == 1.0`` exactly.
    """
    table = np.asarray(table, dtype=np.float64)
    beff = np.clip(cnt_win, 1.0, float(b_cap))
    idx = np.clip(np.floor(beff).astype(np.int64), 0, table.size - 2)
    frac = beff - idx
    s = table[idx] * (1.0 - frac) + table[idx + 1] * frac
    return s, beff


def effective_work_np(work: np.ndarray, work_dec: np.ndarray,
                      cnt: np.ndarray, table: np.ndarray, b_cap: float,
                      window_bins: int = 1):
    """The deposit-time batching law, host (numpy) form.

    Args:
        work: (..., T) offered seconds of work per bin (decode +
            prefill + background).
        work_dec: (..., T) the decode-side subset of ``work``.
        cnt: (..., T) decode token visits deposited per bin.
        table: Padded speedup table (:meth:`BatchingConfig.resolve_table`).
        b_cap: Admissible batch bound.
        window_bins: Occupancy window in bins.

    Returns:
        (work_eff, b_eff), both shaped like ``work``:
        ``work_eff = work + work_dec * (1 / s(B_eff) - 1)``.
    """
    s, beff = batch_speedup_at(windowed_counts(cnt, window_bins),
                               table, b_cap)
    return work + work_dec * (1.0 / s - 1.0), beff


def batched_effective_work(work, work_dec, cnt_win, table, b_cap):
    """The deposit-time batching law, traced (jax.numpy) form.

    Identical math to :func:`effective_work_np` with the window sum
    already applied (``cnt_win``), so the jitted caller carries no
    static window argument.  Returns ``(work_eff, b_eff)``.
    """
    beff = jnp.clip(cnt_win, 1.0, b_cap)
    idx = jnp.clip(jnp.floor(beff).astype(jnp.int32), 0,
                   table.shape[0] - 2)
    frac = beff - idx
    s = table[idx] * (1.0 - frac) + table[idx + 1] * frac
    return work + work_dec * (1.0 / s - 1.0), beff


def windowed_counts_jnp(cnt, window_bins: int):
    """:func:`windowed_counts` in traced form (time on the last axis;
    ``window_bins`` must be static at trace time)."""
    w = int(window_bins)
    if w <= 1:
        return cnt
    cs = jnp.cumsum(cnt, axis=-1)
    shifted = jnp.concatenate(
        [jnp.zeros(cnt.shape[:-1] + (min(w, cnt.shape[-1]),), cnt.dtype),
         cs[..., :-w]], axis=-1)
    return cs - shifted
