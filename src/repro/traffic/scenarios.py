"""Named traffic scenarios for constellation-scale serving studies.

Each :class:`TrafficScenario` bundles an arrival process, request-length
distributions, queueing/KV parameters and a target SLO into a named,
reproducible configuration; :data:`SCENARIOS` is the registry that
benchmarks, the serve driver and the examples all dispatch on.

``failure-storm`` reuses :mod:`repro.distributed.elastic`: at the storm
time a fraction of each layer's expert satellites is knocked out and
the Theorem-1 machinery re-places their experts onto the survivors
(``replan_on_failure`` on the layer's expert ring), with the weight
:func:`~repro.distributed.elastic.migration` bytes accounted.  The
post-storm fleet runs with colocated experts — the Sec. VI-B
multi-expert regime under degraded capacity.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import Constellation, MultiExpertPlan, PlacementPlan
from repro.core.activation import ActivationModel
from repro.core.device_placement import DevicePlacementPlan, TorusSpec
from repro.core.latency import ComputeConfig, TopologySample
from repro.core.workload import MoEWorkload
from repro.distributed import migration, replan_on_failure

from .admission import AdmissionConfig
from .ground import GroundSegment
from .metrics import SLO, TrafficResult
from .queueing import FleetSim, QueueConfig
from .replan import (ReplanConfig, ReplanReport, replan_traffic,
                     replan_traffic_fused)
from .requests import RequestBatch, sample_requests


@dataclasses.dataclass(frozen=True)
class TrafficScenario:
    """A named, fully-specified serving workload.

    ``admission`` switches the fleet simulator from the static
    ``kv_slots`` cap to the latency-target AIMD controller with gateway
    retry (see :mod:`repro.traffic.admission`); the ``*-controlled``
    registry entries are the canonical examples.
    """

    name: str
    description: str
    horizon_s: float = 120.0
    base_rate_rps: float = 0.3
    arrival: str = "poisson"            # poisson | diurnal | hotspot
    # request-length distributions (satellite serving is short-prompt:
    # the 7-GFLOPS class onboard compute makes long prefills minutes-long)
    prompt_median: int = 16
    prompt_sigma: float = 0.8
    prompt_max: int = 256
    decode_mean: int = 16
    decode_max: int = 128
    # arrival-shape knobs
    diurnal_amplitude: float = 0.6
    diurnal_period_s: float | None = None    # None: one cycle per horizon
    hotspot_station: int = 0
    hotspot_boost: float = 4.0
    station_weights: tuple[float, ...] | None = None
    # queueing / memory
    dt_s: float = 0.05
    buffer_s: float = 10.0
    kv_slots: int = 0
    tail_s: float = 120.0
    # wall-clock seconds per topology slot (None = constellation-derived;
    # re-placement scenarios pin a short slot so boundaries fall inside
    # the horizon)
    slot_period_s: float | None = None
    # adaptive admission (None = static kv_slots cap only)
    admission: AdmissionConfig | None = None
    # continuous re-placement (None = plans held for the whole horizon)
    replan: ReplanConfig | None = None
    # objective
    slo: SLO = SLO()
    # failure storm (None = no storm)
    failure_at_s: float | None = None
    failure_frac: float = 0.25

    def requests(self, rng: np.random.Generator, n_stations: int = 1,
                 rate_scale: float = 1.0) -> RequestBatch:
        """Sample this scenario's request trace.

        Args:
            rng: Randomness source for arrivals and lengths.
            n_stations: Ground-gateway count to spread arrivals over.
            rate_scale: Multiplier on the base arrival rate (overload /
                saturation studies).

        Returns:
            The sampled :class:`~repro.traffic.requests.RequestBatch`.
        """
        period = self.diurnal_period_s or self.horizon_s
        return sample_requests(
            rng,
            rate_rps=self.base_rate_rps * rate_scale,
            horizon_s=self.horizon_s,
            n_stations=n_stations,
            station_weights=(None if self.station_weights is None
                             else np.asarray(self.station_weights)),
            arrival=self.arrival,
            diurnal_amplitude=self.diurnal_amplitude,
            diurnal_period_s=period,
            hotspot_station=self.hotspot_station,
            hotspot_boost=self.hotspot_boost,
            prompt_median=self.prompt_median,
            prompt_sigma=self.prompt_sigma,
            prompt_max=self.prompt_max,
            decode_mean=self.decode_mean,
            decode_max=self.decode_max,
        )

    def queue_config(self, slot_period_s: float | None = None) -> QueueConfig:
        """The scenario's :class:`~repro.traffic.queueing.QueueConfig`.

        The scenario's own ``slot_period_s`` (when set) wins over the
        caller's (typically constellation-derived) value.
        """
        kw = dict(dt_s=self.dt_s, buffer_s=self.buffer_s,
                  kv_slots=self.kv_slots, tail_s=self.tail_s,
                  admission=self.admission)
        period = (self.slot_period_s if self.slot_period_s is not None
                  else slot_period_s)
        if period is not None:
            kw["slot_period_s"] = period
        return QueueConfig(**kw)


SCENARIOS: dict[str, TrafficScenario] = {
    s.name: s for s in (
        TrafficScenario(
            name="smoke",
            description="CI-sized steady Poisson trickle (fast, low load)",
            horizon_s=60.0, base_rate_rps=0.25, decode_mean=8,
            decode_max=32, prompt_median=8, prompt_max=64, tail_s=60.0,
        ),
        TrafficScenario(
            name="steady-state",
            description="homogeneous Poisson at moderate utilization",
            horizon_s=300.0, base_rate_rps=0.4, decode_mean=16,
        ),
        TrafficScenario(
            name="diurnal-peak",
            description="sinusoidal daily cycle, stations phased like "
                        "time zones (one cycle per horizon)",
            horizon_s=600.0, base_rate_rps=0.35, arrival="diurnal",
            diurnal_amplitude=0.8, decode_mean=16,
        ),
        TrafficScenario(
            name="regional-hotspot",
            description="flash crowd: 5x Gaussian surge on one region's "
                        "gateway mid-horizon",
            horizon_s=300.0, base_rate_rps=0.3, arrival="hotspot",
            hotspot_boost=5.0, decode_mean=16,
        ),
        TrafficScenario(
            name="failure-storm",
            description="25% of expert satellites lost mid-horizon; "
                        "experts re-placed on survivors via "
                        "distributed.elastic (multi-expert regime)",
            horizon_s=300.0, base_rate_rps=0.3, decode_mean=16,
            failure_at_s=150.0, failure_frac=0.25,
        ),
        TrafficScenario(
            name="regional-hotspot-controlled",
            description="regional-hotspot surge under the AIMD "
                        "latency-target admission controller "
                        "(gateway retry; replaces the static KV cap)",
            horizon_s=300.0, base_rate_rps=0.3, arrival="hotspot",
            hotspot_boost=5.0, decode_mean=16, kv_slots=0,
            admission=AdmissionConfig(ttft_target_s=30.0),
            slo=SLO(ttft_s=30.0),
        ),
        TrafficScenario(
            name="failure-storm-controlled",
            description="failure-storm with the AIMD admission "
                        "controller defending the TTFT target through "
                        "the post-storm degraded (multi-expert) fleet",
            horizon_s=300.0, base_rate_rps=0.3, decode_mean=16,
            failure_at_s=150.0, failure_frac=0.25, kv_slots=0,
            admission=AdmissionConfig(ttft_target_s=30.0),
            slo=SLO(ttft_s=30.0),
        ),
        TrafficScenario(
            name="regional-hotspot-replan",
            description="regional-hotspot surge under backlog-driven "
                        "per-slot re-placement (hysteresis + "
                        "migration-cost gate; statics ride along for "
                        "comparison)",
            horizon_s=300.0, base_rate_rps=0.3, arrival="hotspot",
            hotspot_boost=5.0, decode_mean=16, slot_period_s=60.0,
            replan=ReplanConfig(mode="backlog"),
        ),
        TrafficScenario(
            name="failure-storm-replan",
            description="failure-storm where both phases re-place per "
                        "slot from live backlog (post-storm: among the "
                        "elastic-degraded multi-expert plans)",
            horizon_s=300.0, base_rate_rps=0.3, decode_mean=16,
            failure_at_s=150.0, failure_frac=0.25, slot_period_s=60.0,
            replan=ReplanConfig(mode="backlog"),
        ),
    )
}


def get_scenario(name: str) -> TrafficScenario:
    """Look up a registry scenario by name (KeyError lists the options)."""
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; available: {sorted(SCENARIOS)}"
        ) from None


# --------------------------------------------------------------------- #
# Failure storm: knock out expert satellites, re-place via elastic
# --------------------------------------------------------------------- #


@dataclasses.dataclass
class StormReport:
    """Degraded plans + per-plan weight-migration accounting."""

    degraded_plans: list
    failed_positions: list[np.ndarray]   # per layer, failed expert ranks
    migration_bytes: dict[str, float]
    moved_experts: dict[str, int]


def apply_failure_storm(
    plans: list,
    activation: ActivationModel,
    rng: np.random.Generator,
    failure_frac: float = 0.25,
    bytes_per_expert: float = 1e6,
) -> StormReport:
    """Fail ``failure_frac`` of each layer's expert positions and re-run
    the Theorem-1 machinery on the survivors.

    Each layer's I expert satellites form a ring of I device slots
    (:class:`TorusSpec`); the failed *positions* are drawn once and
    shared by every plan of the sweep (a storm hits positions in the
    constellation, and the comparison should see the same storm).  The
    surviving satellites then host ceil(I / survivors) experts each —
    plans come back as :class:`MultiExpertPlan` with the elastic
    machinery's migration bytes accounted per plan.
    """
    n_layers, n_experts = activation.n_layers, activation.n_experts
    n_fail = max(1, int(round(failure_frac * n_experts)))
    if n_fail >= n_experts:
        raise ValueError("failure_frac would leave no surviving experts")
    ring = TorusSpec(shape=(n_experts,), wrap=True)
    failed_positions = [
        np.sort(rng.choice(n_experts, size=n_fail, replace=False))
        for _ in range(n_layers)
    ]

    # Pre-storm reference on the same ring: expert e sits on position e.
    identity = DevicePlacementPlan(
        expert_perm=np.arange(n_experts), device_cost_s=np.zeros(n_experts),
        experts_per_device=1, origin=0)

    degraded, mig_bytes, moved = [], {}, {}
    for plan in plans:
        old_sats = np.asarray(plan.expert_sats)
        new_sats = np.empty_like(old_sats)
        total_bytes, total_moved = 0.0, 0
        epd = 1
        for layer in range(n_layers):
            failed = set(int(x) for x in failed_positions[layer])
            new_plan, survivors = replan_on_failure(
                activation.weights[layer], activation.top_k, ring, failed)
            epd = new_plan.experts_per_device
            # device slot of each expert on the survivor ring -> satellite
            dev_of_expert = survivors[new_plan.inverse_perm // epd]
            new_sats[layer] = old_sats[layer][dev_of_expert]
            mig = migration(identity, new_plan, bytes_per_expert, survivors)
            total_moved += len(mig.moved_experts)
            total_bytes += mig.bytes_moved
        name = f"{getattr(plan, 'name', 'plan')}+storm"
        degraded.append(MultiExpertPlan(
            gateways=np.asarray(plan.gateways), expert_sats=new_sats,
            experts_per_sat=epd, name=name))
        mig_bytes[name] = total_bytes
        moved[name] = total_moved
    return StormReport(degraded_plans=degraded,
                       failed_positions=failed_positions,
                       migration_bytes=mig_bytes, moved_experts=moved)


# --------------------------------------------------------------------- #
# Scenario runner
# --------------------------------------------------------------------- #


@dataclasses.dataclass
class ScenarioOutcome:
    """Everything one scenario run produces."""

    scenario: TrafficScenario
    result: TrafficResult                 # main phase (pre-storm plans)
    sim: FleetSim
    post_failure: TrafficResult | None = None
    storm: StormReport | None = None
    replan: ReplanReport | None = None         # main-phase controller
    post_replan: ReplanReport | None = None    # post-storm controller


def make_sim(
    scenario: TrafficScenario,
    plans: list[PlacementPlan | MultiExpertPlan],
    topo: TopologySample,
    activation: ActivationModel,
    workload: MoEWorkload,
    compute: ComputeConfig,
    rng: np.random.Generator,
    ground: GroundSegment | None = None,
    constellation: Constellation | None = None,
    rate_scale: float = 1.0,
    requests: RequestBatch | None = None,
    **sim_kwargs,
) -> FleetSim:
    """Build the :class:`FleetSim` for a scenario (slot wall-clock period
    taken from the constellation's orbit when available)."""
    n_stations = ground.n_stations if ground is not None else 1
    if requests is None:
        requests = scenario.requests(rng, n_stations, rate_scale=rate_scale)
    slot_period = (constellation.cfg.orbital_period_s / topo.n_slots
                   if constellation is not None else None)
    qcfg = scenario.queue_config(slot_period)
    return FleetSim(plans, topo, activation, workload, compute, requests,
                    rng, qcfg=qcfg, ground=ground, **sim_kwargs)


def make_federation(
    scenario: TrafficScenario | str,
    n_members: int,
    constellation_cfg,
    workload: MoEWorkload,
    compute: ComputeConfig,
    rng: np.random.Generator,
    fed_cfg=None,
    rate_scale: float = 1.0,
    requests: RequestBatch | None = None,
    home: np.ndarray | None = None,
    n_layers: int = 4,
    n_experts: int = 4,
    top_k: int = 2,
    min_elevation_deg: float = 10.0,
    **sim_kwargs,
):
    """Build a K-member :class:`~repro.traffic.federation.FederationSim`
    world for a named scenario.

    Each member is an independently-planned constellation (its own
    topology sample, ground visibility and SpaceMoE placement plan over
    a fresh :class:`~repro.core.Constellation` of the given config),
    all serving the scenario's single global request trace; the members
    are built on one shared time-bin grid via
    :func:`~repro.traffic.federation.build_federation`, so the whole
    federation — including a nested rate sweep — costs one device
    launch.

    Args:
        scenario: Scenario name or instance (supplies the arrival
            process and queue/admission config).
        n_members: K, member constellations.
        constellation_cfg: One ``ConstellationConfig`` shared by all
            members (each samples its own topology/outages), or a list
            of K configs.
        workload: MoE workload shared by the federation.
        compute: Compute config shared by the federation.
        rng: Source of the member topology draws (split per member).
        fed_cfg: Optional
            :class:`~repro.traffic.federation.FederationConfig`.
        rate_scale: Arrival-rate multiplier for the global trace.
        requests: Optional pre-built global trace (overrides the
            scenario's arrival process — the million-user bench feeds
            ``stream_requests`` output here).
        home: Optional (R,) member index per request (hotspot benches
            concentrate load on one member this way).
        n_layers / n_experts / top_k: Activation-model grid.
        min_elevation_deg: Gateway visibility threshold per member.
        **sim_kwargs: Extra :class:`FleetSim` keyword arguments.

    Returns:
        The :class:`~repro.traffic.federation.FederationSim`.
    """
    from repro.core import LinkConfig, sample_topology, spacemoe_plan
    from .federation import build_federation
    from .ground import build_ground_segment

    if isinstance(scenario, str):
        scenario = get_scenario(scenario)
    cfgs = (list(constellation_cfg)
            if isinstance(constellation_cfg, (list, tuple))
            else [constellation_cfg] * n_members)
    if len(cfgs) != n_members:
        raise ValueError(f"need {n_members} constellation configs")

    # One global trace: station ids are drawn against member 0's ground
    # segment (members share the gateway *sites*; visibility differs).
    link = LinkConfig()
    cons = [Constellation(c) for c in cfgs]
    grounds = [build_ground_segment(c, link,
                                    min_elevation_deg=min_elevation_deg)
               for c in cons]
    if requests is None:
        requests = scenario.requests(rng, grounds[0].n_stations,
                                     rate_scale=rate_scale)
    # Fixed per-member seeds: a factory must be deterministic — a
    # member rebuilt on the shared bin grid (build_federation's second
    # pass) has to sample the *same* topology.
    seeds = rng.integers(2**32, size=n_members)

    def factory(k):
        def build(min_bins=0):
            con, ground = cons[k], grounds[k]
            r = np.random.default_rng(seeds[k])
            topo = sample_topology(con, link, r)
            activ = ActivationModel.zipf(n_layers, n_experts, top_k,
                                         seed=k + 1)
            plans = [spacemoe_plan(con, topo, activ)]
            slot_period = con.cfg.orbital_period_s / topo.n_slots
            qcfg = scenario.queue_config(slot_period)
            return FleetSim(plans, topo, activ, workload, compute,
                            requests, r, qcfg=qcfg, ground=ground,
                            min_bins=min_bins, **sim_kwargs)
        return build

    return build_federation([factory(k) for k in range(n_members)],
                            fed_cfg, home=home, ground=grounds[0])


def run_scenario(
    scenario: TrafficScenario | str,
    plans: list[PlacementPlan | MultiExpertPlan],
    topo: TopologySample,
    activation: ActivationModel,
    workload: MoEWorkload,
    compute: ComputeConfig,
    rng: np.random.Generator,
    ground: GroundSegment | None = None,
    constellation: Constellation | None = None,
    rate_scale: float = 1.0,
    bytes_per_expert: float = 1e6,
    ctrl: str = "host",
    **sim_kwargs,
) -> ScenarioOutcome:
    """Run one named scenario end-to-end.

    For ``failure-storm`` scenarios the trace is split at the storm
    time: the pre-storm phase runs the given plans, the post-storm phase
    runs the elastic-replanned (degraded, multi-expert) plans on the
    requests arriving after the storm.  Queue state does not carry over
    the boundary (the storm re-plan itself drains the fleet while
    weights migrate), and the migration bytes are reported.

    When ``scenario.replan`` is set, ``plans`` is the *candidate pool*
    of the re-placement controller (:mod:`repro.traffic.replan`): each
    phase probes, decides a :class:`~repro.core.schedule.PlanSchedule`
    and evaluates it alongside the static candidates, so the phase's
    result table carries one extra ``replan/<mode>`` row (for a storm
    scenario, the post phase re-places among the degraded plans).
    ``ctrl`` picks the controller implementation for those phases:
    ``"host"`` walks the pinned decide law round by round
    (:func:`~repro.traffic.replan.replan_traffic`), ``"fused"`` runs
    the same law inside one device launch per phase
    (:func:`~repro.traffic.replan.replan_traffic_fused` — decision
    parity with the host walk is pinned by ``tests/test_control_plane
    .py``, and the report carries the on-device decision-event
    channel).
    """
    if isinstance(scenario, str):
        scenario = get_scenario(scenario)
    n_stations = ground.n_stations if ground is not None else 1
    requests = scenario.requests(rng, n_stations, rate_scale=rate_scale)
    slot_period = (constellation.cfg.orbital_period_s / topo.n_slots
                   if constellation is not None else None)
    # One per-expert byte price for the whole outcome: the storm
    # re-place accounting, the replan migration gate and the fleet's
    # queue billing must all agree.
    qcfg = dataclasses.replace(scenario.queue_config(slot_period),
                               migration_bytes_per_expert=bytes_per_expert)

    if ctrl not in ("host", "fused"):
        raise ValueError(f"unknown controller {ctrl!r} "
                         "(one of 'host', 'fused')")

    def _phase(phase_plans, phase_requests):
        """One phase: replan-controlled when the scenario asks for it."""
        if scenario.replan is not None:
            controller = replan_traffic if ctrl == "host" \
                else replan_traffic_fused
            out = controller(phase_plans, topo, activation, workload,
                             compute, phase_requests, rng,
                             scenario.replan, qcfg, ground=ground,
                             **sim_kwargs)
            return out.result, out.sim, out.report
        sim = FleetSim(phase_plans, topo, activation, workload, compute,
                       phase_requests, rng, qcfg=qcfg, ground=ground,
                       **sim_kwargs)
        return sim.run(), sim, None

    if scenario.failure_at_s is None:
        result, sim, report = _phase(plans, requests)
        return ScenarioOutcome(scenario=scenario, result=result, sim=sim,
                               replan=report)

    pre = requests.subset(requests.arrival_s < scenario.failure_at_s)
    post = requests.subset(requests.arrival_s >= scenario.failure_at_s)
    if pre.n_requests == 0:
        raise ValueError(
            f"failure_at_s={scenario.failure_at_s} precedes every arrival — "
            "nothing to simulate pre-storm")
    storm = apply_failure_storm(plans, activation, rng,
                                failure_frac=scenario.failure_frac,
                                bytes_per_expert=bytes_per_expert)
    result, sim, report = _phase(plans, pre)
    post_result, post_report = None, None
    if post.n_requests:
        post_result, _, post_report = _phase(storm.degraded_plans, post)
    return ScenarioOutcome(scenario=scenario, result=result, sim=sim,
                           post_failure=post_result, storm=storm,
                           replan=report, post_replan=post_report)
