"""Request arrival processes for constellation-scale serving.

A :class:`RequestBatch` is the tensor form of a request trace: arrival
times, prompt/decode lengths and the originating ground station, all as
flat arrays so the queueing layer never loops over requests.

Arrival models
--------------
* homogeneous Poisson (exponential inter-arrival gaps),
* non-homogeneous Poisson via thinning — diurnal sinusoidal modulation
  (regional phase offsets: each ground station peaks at its local
  daytime) and transient regional hotspots (Gaussian bump on one
  station's rate),
* heavy-tail lengths: lognormal prompt lengths, geometric decode
  lengths, both clipped — the standard shape of LLM serving traces.

Planet-scale traces
-------------------
:func:`stream_arrivals` / :func:`stream_requests` run the same thinning
law one bounded time shard at a time, so a federation bench can push a
1e6+-user envelope through generation while only the kept survivors
ever materialize — peak RSS is O(shard), not O(envelope).
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np


@dataclasses.dataclass
class RequestBatch:
    """A trace of R requests, sorted by arrival time."""

    arrival_s: np.ndarray     # (R,) float, sorted ascending
    prompt_len: np.ndarray    # (R,) int >= 1
    decode_len: np.ndarray    # (R,) int >= 1
    station: np.ndarray       # (R,) int ground-station index

    def __post_init__(self):
        self.arrival_s = np.asarray(self.arrival_s, dtype=np.float64)
        self.prompt_len = np.asarray(self.prompt_len, dtype=np.int64)
        self.decode_len = np.asarray(self.decode_len, dtype=np.int64)
        self.station = np.asarray(self.station, dtype=np.int64)
        if not (np.diff(self.arrival_s) >= 0).all():
            raise ValueError("arrivals must be sorted by time")
        if (self.prompt_len < 1).any() or (self.decode_len < 1).any():
            raise ValueError("prompt/decode lengths must be >= 1")

    @property
    def n_requests(self) -> int:
        """Number of requests in the trace (R)."""
        return len(self.arrival_s)

    @property
    def total_decode_tokens(self) -> int:
        """Total decode tokens across the trace (N)."""
        return int(self.decode_len.sum())

    @property
    def horizon_s(self) -> float:
        """Last arrival time, seconds (0 for an empty trace)."""
        return float(self.arrival_s[-1]) if self.n_requests else 0.0

    def subset(self, mask: np.ndarray) -> "RequestBatch":
        """Thinned copy (Poisson thinning: a Bernoulli-kept subset of a
        Poisson trace is Poisson at the scaled rate)."""
        mask = np.asarray(mask, dtype=bool)
        return RequestBatch(
            arrival_s=self.arrival_s[mask], prompt_len=self.prompt_len[mask],
            decode_len=self.decode_len[mask], station=self.station[mask],
        )

    def request_of_token(self) -> np.ndarray:
        """(total_decode_tokens,) request index of every decode token.

        Memoized: the recorder/metrics paths call this once per plan
        row, and at 1e6-user scale the ``np.repeat`` is a measurable
        host cost.  The memo key covers the identity and the content
        signature of ``decode_len`` (length + token total), so
        replacing the array — the only supported mutation, e.g. via
        ``dataclasses.replace`` — invalidates it; the cached array is
        returned read-only so callers cannot corrupt the shared copy.
        """
        key = (id(self.decode_len), self.n_requests,
               self.total_decode_tokens)
        cached = getattr(self, "_token_req_memo", None)
        if cached is not None and cached[0] == key:
            return cached[1]
        out = np.repeat(np.arange(self.n_requests), self.decode_len)
        out.setflags(write=False)
        object.__setattr__(self, "_token_req_memo", (key, out))
        return out


# --------------------------------------------------------------------- #
# Arrival-time processes
# --------------------------------------------------------------------- #


def poisson_arrivals(rate_rps: float, horizon_s: float,
                     rng: np.random.Generator) -> np.ndarray:
    """Homogeneous Poisson arrival times on [0, horizon)."""
    if rate_rps <= 0 or horizon_s <= 0:
        return np.empty(0, dtype=np.float64)
    # Draw ~N + 5 sigma gaps so a second draw is almost never needed.
    n_hint = int(rate_rps * horizon_s + 5.0 * np.sqrt(rate_rps * horizon_s) + 10)
    gaps = rng.exponential(1.0 / rate_rps, size=n_hint)
    t = np.cumsum(gaps)
    while t[-1] < horizon_s:                       # pragma: no cover - rare
        extra = rng.exponential(1.0 / rate_rps, size=n_hint)
        t = np.concatenate([t, t[-1] + np.cumsum(extra)])
    return t[t < horizon_s]


def diurnal_rate(t: np.ndarray, base_rps: float, amplitude: float,
                 period_s: float, phase: float = 0.0) -> np.ndarray:
    """rate(t) = base * (1 + amplitude * sin(2 pi t / period + phase)),
    clipped at zero.  ``amplitude`` in [0, 1] keeps the rate nonnegative."""
    t = np.asarray(t, dtype=np.float64)
    r = base_rps * (1.0 + amplitude * np.sin(2.0 * np.pi * t / period_s + phase))
    return np.maximum(r, 0.0)


def hotspot_rate(t: np.ndarray, base_rps: float, boost: float,
                 center_s: float, width_s: float) -> np.ndarray:
    """rate(t) = base * (1 + boost * exp(-(t-center)^2 / 2 width^2)) — a
    transient regional surge (breaking-news / flash-crowd shape)."""
    t = np.asarray(t, dtype=np.float64)
    return base_rps * (1.0 + boost * np.exp(-0.5 * ((t - center_s) / width_s) ** 2))


def _thinning_probs(rates: np.ndarray, rate_max_rps: float,
                    clip: bool) -> np.ndarray:
    """Validated keep-probabilities ``rate(t)/rate_max``.

    Lewis-Shedler thinning is only exact when the envelope dominates
    the instantaneous rate; a ``rate_fn`` that exceeds ``rate_max_rps``
    used to silently saturate the keep-probability at 1 and bias the
    trace low.  Now it raises — or, with ``clip=True``, clips with a
    warning (the caller accepts the rate-capped trace knowingly).
    """
    rates = np.asarray(rates, dtype=np.float64)
    # Tiny tolerance: a rate_fn that *equals* the envelope at its peak
    # may overshoot by float rounding; that is not an envelope bug.
    tol = rate_max_rps * 1e-12
    if rates.size and float(rates.max()) > rate_max_rps + tol:
        if not clip:
            raise ValueError(
                f"thinning envelope violated: rate_fn peaks at "
                f"{float(rates.max()):g} rps > envelope "
                f"{rate_max_rps:g} rps — the thinned trace would be "
                f"biased low; raise rate_max_rps (or pass clip=True "
                f"to accept a rate-capped trace)")
        warnings.warn(
            f"thinning envelope violated (rate_fn peak "
            f"{float(rates.max()):g} > {rate_max_rps:g} rps); clipping "
            f"— the trace is rate-capped at the envelope",
            RuntimeWarning, stacklevel=3)
        rates = np.minimum(rates, rate_max_rps)
    return rates / rate_max_rps


def thinned_arrivals(rate_fn, rate_max_rps: float, horizon_s: float,
                     rng: np.random.Generator, *,
                     clip: bool = False) -> np.ndarray:
    """Non-homogeneous Poisson via Lewis-Shedler thinning: draw at the
    envelope rate, keep each arrival with prob rate(t)/rate_max.

    Raises ``ValueError`` if ``rate_fn`` ever exceeds the envelope
    (``clip=True`` clips with a warning instead)."""
    t = poisson_arrivals(rate_max_rps, horizon_s, rng)
    if len(t) == 0:
        return t
    keep = rng.random(len(t)) < _thinning_probs(rate_fn(t), rate_max_rps,
                                                clip)
    return t[keep]


def stream_arrivals(rate_fn, rate_max_rps: float, horizon_s: float,
                    rng: np.random.Generator, *,
                    shard_s: float = 600.0,
                    clip: bool = False) -> tuple[np.ndarray, int]:
    """Sharded Lewis-Shedler thinning for planet-scale envelopes.

    Distribution-identical to :func:`thinned_arrivals` (a thinned
    Poisson process is Poisson at the thinned rate regardless of how
    the envelope is generated), but the envelope process materializes
    one bounded time shard at a time: per shard the arrival count is
    Poisson(rate_max * shard) and the times are sorted uniforms (the
    conditional-uniform property), each kept with probability
    ``rate_fn(t)/rate_max`` before the next shard is drawn.  Peak
    memory is O(rate_max * shard_s + kept), not O(envelope) — the
    mechanism behind the million-user federation bench.

    Returns:
        ``(kept_times, n_generated)`` — kept arrival times (sorted,
        within ``[0, horizon_s)``) and the total number of *envelope*
        arrivals generated (the "users offered" count at planet scale).
    """
    if rate_max_rps <= 0 or horizon_s <= 0:
        return np.empty(0, dtype=np.float64), 0
    shard_s = min(float(shard_s), horizon_s)
    kept: list[np.ndarray] = []
    n_generated = 0
    a = 0.0
    while a < horizon_s:
        b = min(a + shard_s, horizon_s)
        n = int(rng.poisson(rate_max_rps * (b - a)))
        n_generated += n
        if n:
            t = np.sort(rng.uniform(a, b, size=n))
            keep = rng.random(n) < _thinning_probs(
                rate_fn(t), rate_max_rps, clip)
            if keep.any():
                kept.append(t[keep])
        a = b
    out = (np.concatenate(kept) if kept
           else np.empty(0, dtype=np.float64))
    return out, n_generated


# --------------------------------------------------------------------- #
# Length distributions
# --------------------------------------------------------------------- #


def sample_prompt_lens(n: int, rng: np.random.Generator,
                       median: int = 256, sigma: float = 1.0,
                       max_len: int = 4096) -> np.ndarray:
    """Lognormal prompt lengths (heavy right tail), clipped to [1, max]."""
    raw = rng.lognormal(mean=np.log(max(median, 1)), sigma=sigma, size=n)
    return np.clip(raw.astype(np.int64), 1, max_len)


def sample_decode_lens(n: int, rng: np.random.Generator,
                       mean: int = 64, max_len: int = 1024) -> np.ndarray:
    """Geometric decode lengths (memoryless stop decision per token),
    clipped to [1, max]."""
    raw = rng.geometric(1.0 / max(mean, 1), size=n)
    return np.clip(raw.astype(np.int64), 1, max_len)


# --------------------------------------------------------------------- #
# Full trace sampling
# --------------------------------------------------------------------- #


def sample_requests(
    rng: np.random.Generator,
    rate_rps: float,
    horizon_s: float,
    n_stations: int,
    station_weights: np.ndarray | None = None,
    arrival: str = "poisson",
    diurnal_amplitude: float = 0.6,
    diurnal_period_s: float = 86400.0,
    station_phases: np.ndarray | None = None,
    hotspot_station: int = 0,
    hotspot_boost: float = 4.0,
    hotspot_center_s: float | None = None,
    hotspot_width_s: float | None = None,
    prompt_median: int = 256,
    prompt_sigma: float = 1.0,
    prompt_max: int = 4096,
    decode_mean: int = 64,
    decode_max: int = 1024,
) -> RequestBatch:
    """Sample a full request trace.

    ``arrival`` is one of:

    * ``"poisson"`` — homogeneous, stations weighted by ``station_weights``;
    * ``"diurnal"`` — per-station sinusoidal modulation, each station
      phase-shifted (``station_phases``, default evenly spread over 2 pi
      like time zones around the globe);
    * ``"hotspot"`` — homogeneous everywhere plus a Gaussian surge on
      ``hotspot_station`` (``boost`` x base at the peak).
    """
    weights = (np.full(n_stations, 1.0 / n_stations)
               if station_weights is None
               else np.asarray(station_weights, dtype=np.float64))
    weights = weights / weights.sum()
    per_station_rate = rate_rps * weights

    times, stations = [], []
    if arrival == "poisson":
        for s in range(n_stations):
            t = poisson_arrivals(per_station_rate[s], horizon_s, rng)
            times.append(t)
            stations.append(np.full(len(t), s, dtype=np.int64))
    elif arrival == "diurnal":
        phases = (np.linspace(0.0, 2.0 * np.pi, n_stations, endpoint=False)
                  if station_phases is None else np.asarray(station_phases))
        for s in range(n_stations):
            env = per_station_rate[s] * (1.0 + diurnal_amplitude)
            t = thinned_arrivals(
                lambda tt, s=s: diurnal_rate(tt, per_station_rate[s],
                                             diurnal_amplitude,
                                             diurnal_period_s, phases[s]),
                env, horizon_s, rng)
            times.append(t)
            stations.append(np.full(len(t), s, dtype=np.int64))
    elif arrival == "hotspot":
        center = horizon_s / 2.0 if hotspot_center_s is None else hotspot_center_s
        width = horizon_s / 8.0 if hotspot_width_s is None else hotspot_width_s
        for s in range(n_stations):
            if s == hotspot_station:
                env = per_station_rate[s] * (1.0 + hotspot_boost)
                t = thinned_arrivals(
                    lambda tt: hotspot_rate(tt, per_station_rate[s],
                                            hotspot_boost, center, width),
                    env, horizon_s, rng)
            else:
                t = poisson_arrivals(per_station_rate[s], horizon_s, rng)
            times.append(t)
            stations.append(np.full(len(t), s, dtype=np.int64))
    else:
        raise ValueError(f"unknown arrival model {arrival!r}")

    t = np.concatenate(times) if times else np.empty(0)
    st = np.concatenate(stations) if stations else np.empty(0, dtype=np.int64)
    order = np.argsort(t, kind="stable")
    t, st = t[order], st[order]
    n = len(t)
    return RequestBatch(
        arrival_s=t,
        prompt_len=sample_prompt_lens(n, rng, prompt_median, prompt_sigma,
                                      prompt_max),
        decode_len=sample_decode_lens(n, rng, decode_mean, decode_max),
        station=st,
    )


def stream_requests(
    rng: np.random.Generator,
    rate_fn,
    rate_max_rps: float,
    horizon_s: float,
    n_stations: int,
    *,
    shard_s: float = 600.0,
    station_weights: np.ndarray | None = None,
    prompt_median: int = 256,
    prompt_sigma: float = 1.0,
    prompt_max: int = 4096,
    decode_mean: int = 64,
    decode_max: int = 1024,
) -> tuple[RequestBatch, int]:
    """Planet-scale trace sampling with bounded peak memory.

    The envelope process (``rate_max_rps``, potentially millions of
    users over the horizon) streams through :func:`stream_arrivals` in
    bounded shards; only arrivals kept by the thinning law
    ``rate_fn(t)/rate_max`` materialize into the returned
    :class:`RequestBatch`.  Stations are sampled i.i.d. by
    ``station_weights`` for the kept arrivals (valid because thinning
    and station assignment are independent), lengths with the same
    heavy-tail samplers as :func:`sample_requests`.

    Returns:
        ``(batch, n_generated)`` — the kept-request trace and the
        total number of envelope arrivals generated (the offered-user
        count the federation bench reports at the 1e6+ scale).
    """
    t, n_generated = stream_arrivals(rate_fn, rate_max_rps, horizon_s,
                                     rng, shard_s=shard_s)
    n = len(t)
    weights = (np.full(n_stations, 1.0 / n_stations)
               if station_weights is None
               else np.asarray(station_weights, dtype=np.float64))
    weights = weights / weights.sum()
    batch = RequestBatch(
        arrival_s=t,
        prompt_len=sample_prompt_lens(n, rng, prompt_median, prompt_sigma,
                                      prompt_max),
        decode_len=sample_decode_lens(n, rng, decode_mean, decode_max),
        station=rng.choice(n_stations, size=n, p=weights),
    )
    return batch, n_generated
