"""Request arrival processes for constellation-scale serving.

A :class:`RequestBatch` is the tensor form of a request trace: arrival
times, prompt/decode lengths and the originating ground station, all as
flat arrays so the queueing layer never loops over requests.

Arrival models
--------------
* homogeneous Poisson (exponential inter-arrival gaps),
* non-homogeneous Poisson via thinning — diurnal sinusoidal modulation
  (regional phase offsets: each ground station peaks at its local
  daytime) and transient regional hotspots (Gaussian bump on one
  station's rate),
* heavy-tail lengths: lognormal prompt lengths, geometric decode
  lengths, both clipped — the standard shape of LLM serving traces.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class RequestBatch:
    """A trace of R requests, sorted by arrival time."""

    arrival_s: np.ndarray     # (R,) float, sorted ascending
    prompt_len: np.ndarray    # (R,) int >= 1
    decode_len: np.ndarray    # (R,) int >= 1
    station: np.ndarray       # (R,) int ground-station index

    def __post_init__(self):
        self.arrival_s = np.asarray(self.arrival_s, dtype=np.float64)
        self.prompt_len = np.asarray(self.prompt_len, dtype=np.int64)
        self.decode_len = np.asarray(self.decode_len, dtype=np.int64)
        self.station = np.asarray(self.station, dtype=np.int64)
        if not (np.diff(self.arrival_s) >= 0).all():
            raise ValueError("arrivals must be sorted by time")
        if (self.prompt_len < 1).any() or (self.decode_len < 1).any():
            raise ValueError("prompt/decode lengths must be >= 1")

    @property
    def n_requests(self) -> int:
        """Number of requests in the trace (R)."""
        return len(self.arrival_s)

    @property
    def total_decode_tokens(self) -> int:
        """Total decode tokens across the trace (N)."""
        return int(self.decode_len.sum())

    @property
    def horizon_s(self) -> float:
        """Last arrival time, seconds (0 for an empty trace)."""
        return float(self.arrival_s[-1]) if self.n_requests else 0.0

    def subset(self, mask: np.ndarray) -> "RequestBatch":
        """Thinned copy (Poisson thinning: a Bernoulli-kept subset of a
        Poisson trace is Poisson at the scaled rate)."""
        mask = np.asarray(mask, dtype=bool)
        return RequestBatch(
            arrival_s=self.arrival_s[mask], prompt_len=self.prompt_len[mask],
            decode_len=self.decode_len[mask], station=self.station[mask],
        )

    def request_of_token(self) -> np.ndarray:
        """(total_decode_tokens,) request index of every decode token."""
        return np.repeat(np.arange(self.n_requests), self.decode_len)


# --------------------------------------------------------------------- #
# Arrival-time processes
# --------------------------------------------------------------------- #


def poisson_arrivals(rate_rps: float, horizon_s: float,
                     rng: np.random.Generator) -> np.ndarray:
    """Homogeneous Poisson arrival times on [0, horizon)."""
    if rate_rps <= 0 or horizon_s <= 0:
        return np.empty(0, dtype=np.float64)
    # Draw ~N + 5 sigma gaps so a second draw is almost never needed.
    n_hint = int(rate_rps * horizon_s + 5.0 * np.sqrt(rate_rps * horizon_s) + 10)
    gaps = rng.exponential(1.0 / rate_rps, size=n_hint)
    t = np.cumsum(gaps)
    while t[-1] < horizon_s:                       # pragma: no cover - rare
        extra = rng.exponential(1.0 / rate_rps, size=n_hint)
        t = np.concatenate([t, t[-1] + np.cumsum(extra)])
    return t[t < horizon_s]


def diurnal_rate(t: np.ndarray, base_rps: float, amplitude: float,
                 period_s: float, phase: float = 0.0) -> np.ndarray:
    """rate(t) = base * (1 + amplitude * sin(2 pi t / period + phase)),
    clipped at zero.  ``amplitude`` in [0, 1] keeps the rate nonnegative."""
    t = np.asarray(t, dtype=np.float64)
    r = base_rps * (1.0 + amplitude * np.sin(2.0 * np.pi * t / period_s + phase))
    return np.maximum(r, 0.0)


def hotspot_rate(t: np.ndarray, base_rps: float, boost: float,
                 center_s: float, width_s: float) -> np.ndarray:
    """rate(t) = base * (1 + boost * exp(-(t-center)^2 / 2 width^2)) — a
    transient regional surge (breaking-news / flash-crowd shape)."""
    t = np.asarray(t, dtype=np.float64)
    return base_rps * (1.0 + boost * np.exp(-0.5 * ((t - center_s) / width_s) ** 2))


def thinned_arrivals(rate_fn, rate_max_rps: float, horizon_s: float,
                     rng: np.random.Generator) -> np.ndarray:
    """Non-homogeneous Poisson via Lewis-Shedler thinning: draw at the
    envelope rate, keep each arrival with prob rate(t)/rate_max."""
    t = poisson_arrivals(rate_max_rps, horizon_s, rng)
    if len(t) == 0:
        return t
    keep = rng.random(len(t)) < np.asarray(rate_fn(t)) / rate_max_rps
    return t[keep]


# --------------------------------------------------------------------- #
# Length distributions
# --------------------------------------------------------------------- #


def sample_prompt_lens(n: int, rng: np.random.Generator,
                       median: int = 256, sigma: float = 1.0,
                       max_len: int = 4096) -> np.ndarray:
    """Lognormal prompt lengths (heavy right tail), clipped to [1, max]."""
    raw = rng.lognormal(mean=np.log(max(median, 1)), sigma=sigma, size=n)
    return np.clip(raw.astype(np.int64), 1, max_len)


def sample_decode_lens(n: int, rng: np.random.Generator,
                       mean: int = 64, max_len: int = 1024) -> np.ndarray:
    """Geometric decode lengths (memoryless stop decision per token),
    clipped to [1, max]."""
    raw = rng.geometric(1.0 / max(mean, 1), size=n)
    return np.clip(raw.astype(np.int64), 1, max_len)


# --------------------------------------------------------------------- #
# Full trace sampling
# --------------------------------------------------------------------- #


def sample_requests(
    rng: np.random.Generator,
    rate_rps: float,
    horizon_s: float,
    n_stations: int,
    station_weights: np.ndarray | None = None,
    arrival: str = "poisson",
    diurnal_amplitude: float = 0.6,
    diurnal_period_s: float = 86400.0,
    station_phases: np.ndarray | None = None,
    hotspot_station: int = 0,
    hotspot_boost: float = 4.0,
    hotspot_center_s: float | None = None,
    hotspot_width_s: float | None = None,
    prompt_median: int = 256,
    prompt_sigma: float = 1.0,
    prompt_max: int = 4096,
    decode_mean: int = 64,
    decode_max: int = 1024,
) -> RequestBatch:
    """Sample a full request trace.

    ``arrival`` is one of:

    * ``"poisson"`` — homogeneous, stations weighted by ``station_weights``;
    * ``"diurnal"`` — per-station sinusoidal modulation, each station
      phase-shifted (``station_phases``, default evenly spread over 2 pi
      like time zones around the globe);
    * ``"hotspot"`` — homogeneous everywhere plus a Gaussian surge on
      ``hotspot_station`` (``boost`` x base at the peak).
    """
    weights = (np.full(n_stations, 1.0 / n_stations)
               if station_weights is None
               else np.asarray(station_weights, dtype=np.float64))
    weights = weights / weights.sum()
    per_station_rate = rate_rps * weights

    times, stations = [], []
    if arrival == "poisson":
        for s in range(n_stations):
            t = poisson_arrivals(per_station_rate[s], horizon_s, rng)
            times.append(t)
            stations.append(np.full(len(t), s, dtype=np.int64))
    elif arrival == "diurnal":
        phases = (np.linspace(0.0, 2.0 * np.pi, n_stations, endpoint=False)
                  if station_phases is None else np.asarray(station_phases))
        for s in range(n_stations):
            env = per_station_rate[s] * (1.0 + diurnal_amplitude)
            t = thinned_arrivals(
                lambda tt, s=s: diurnal_rate(tt, per_station_rate[s],
                                             diurnal_amplitude,
                                             diurnal_period_s, phases[s]),
                env, horizon_s, rng)
            times.append(t)
            stations.append(np.full(len(t), s, dtype=np.int64))
    elif arrival == "hotspot":
        center = horizon_s / 2.0 if hotspot_center_s is None else hotspot_center_s
        width = horizon_s / 8.0 if hotspot_width_s is None else hotspot_width_s
        for s in range(n_stations):
            if s == hotspot_station:
                env = per_station_rate[s] * (1.0 + hotspot_boost)
                t = thinned_arrivals(
                    lambda tt: hotspot_rate(tt, per_station_rate[s],
                                            hotspot_boost, center, width),
                    env, horizon_s, rng)
            else:
                t = poisson_arrivals(per_station_rate[s], horizon_s, rng)
            times.append(t)
            stations.append(np.full(len(t), s, dtype=np.int64))
    else:
        raise ValueError(f"unknown arrival model {arrival!r}")

    t = np.concatenate(times) if times else np.empty(0)
    st = np.concatenate(stations) if stations else np.empty(0, dtype=np.int64)
    order = np.argsort(t, kind="stable")
    t, st = t[order], st[order]
    n = len(t)
    return RequestBatch(
        arrival_s=t,
        prompt_len=sample_prompt_lens(n, rng, prompt_median, prompt_sigma,
                                      prompt_max),
        decode_len=sample_decode_lens(n, rng, decode_mean, decode_max),
        station=st,
    )
