"""Latency-target adaptive admission control for the fleet simulator.

The static ``kv_slots`` cap of PR 2 sheds load only after the SLO is
already breached (it reacts to the in-flight count, not to latency).
This module closes the loop instead: a per-gateway controller observes
the queue kernel's own backlog state each control interval and adjusts
an admission probability so load is shed *before* the latency target is
crossed.  Rejected requests retry at the next-best visible ground
gateway (:meth:`repro.traffic.ground.GroundSegment.retry_stations`,
entering through the first routable rank of that gateway's
ranked-visibility table; when no alternative gateway exists the retry
re-attempts the origin after the backoff), bounded by ``max_retries``,
with the backoff + terrestrial-forward + alternate-uplink latency
accounted in TTFT/E2E.

Control law (pinned)
--------------------
**AIMD on the windowed-max predicted TTFT.**  Let ``backlog[p, s]`` be
the queue kernel's per-station backlog (seconds of unserved work).  The
critical-path queueing delay a request admitted *now* would face under
plan p is estimated as::

    qhat[p] = sum_l backlog[p, gateway_l] + sum_l max_i backlog[p, expert_{l,i}]

i.e. the gateway chain plus, per layer, the worst expert queue (an upper
bound on the max over the top-K draw — deliberately conservative: a
control signal should breach before the SLO does).  Per control interval
(``interval_s``, quantized to whole time bins) the controller tracks the
windowed **max** of ``qhat`` — the sup-quantile of the interval — and
compares the predicted latencies

    ``ttft_hat[p, g] = ttft0[p, g] + max_win qhat[p]``  (per gateway g)
    ``tpot_hat[p]    = tpot0[p]    + max_win qhat[p]``

against ``target_margin *`` the configured targets, where ``ttft0`` /
``tpot0`` are the zero-load (engine-exact) reference latencies.  On
breach the admission probability is multiplicatively decreased
(``admit *= decrease``), otherwise additively increased
(``admit += increase``), clamped to ``[admit_min, 1]`` — the classic
AIMD cell that converges to a fair stable shedding rate under sustained
overload and recovers quickly once the surge passes.

The controller state — ``(admit (P, G), window-max (P,))`` — is carried
through the same jitted ``lax.scan`` that evolves the backlog matrix,
vectorized over every plan of the sweep; no host round-trips happen
inside the horizon.  Per-request admission is then resolved *between*
schedule<->queue fixed-point iterations from the emitted admission
trace (monotone outer iteration: the trace is accumulated as a running
minimum, so the shed set only grows and the fixed point converges from
the congested side).

PID variant (pinned)
--------------------
``policy="pid"`` replaces the AIMD cell with a PID step on the
normalized latency *headroom*.  At each control-interval close::

    err      = min((ttft_target - ttft_hat) / ttft_target,
                   (tpot_target - tpot_hat) / tpot_target)   # (P, G)
    integ    = clip(integ + err, -_PID_WINDUP, _PID_WINDUP)
    delta    = kp * err + ki * integ + kd * (err - prev_err)
    admit    = clip(admit + gain[p] * delta, admit_min, 1.0)

An infinite target contributes +inf headroom (the term drops out, same
as AIMD's never-breaching comparison).  ``gain`` is the per-plan
``gain_scale`` vector (ones when unset) — the joint control plane uses
it to give each placement candidate its own loop stiffness while the
error signal stays the shared ``qhat`` critical-path estimate.  The
integral clamp is the standard anti-windup guard: a long breach cannot
bank so much deficit that recovery overshoots.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

#: Anti-windup clamp on the PID integral term (units of normalized
#: headroom-intervals); pinned so the fused and host scans agree bitwise.
_PID_WINDUP = 10.0


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Latency-target admission controller parameters.

    Attributes:
        policy: ``"aimd"`` enables the closed-loop controller;
            ``"pid"`` swaps in the PID cell (module docstring);
            ``"static"`` keeps the legacy ``kv_slots`` cap (the
            controller machinery is bypassed entirely).
        ttft_target_s: TTFT latency target the controller defends.
        tpot_target_s: TPOT target (per decode token); +inf disables the
            TPOT term.
        interval_s: Control interval — the AIMD update cadence and the
            width of the observation window (quantized to time bins).
        increase: Additive admission-probability increase per
            non-breaching interval.
        decrease: Multiplicative factor applied on a breaching interval.
        admit_min: Admission-probability floor (keeps a trickle flowing
            so the controller can observe recovery).
        target_margin: Fraction of the target the predictor is compared
            against (< 1 sheds with headroom, compensating for the O(dt)
            binning error and post-admission queue growth).
        reference_quantile: Quantile of the zero-load TTFT/TPOT
            distributions used as the predictor's ``ttft0``/``tpot0``
            anchors.  The controller defends a *tail* target, so the
            anchor must be a tail statistic — a median anchor would
            under-budget the long-prompt requests that dominate p99.
        max_retries: Gateway-retry attempts a rejected request may make
            before it is shed.
        retry_backoff_s: Delay between consecutive attempts, paid in
            TTFT/E2E by retried requests.
        kp: PID proportional gain on the normalized headroom
            (``policy="pid"`` only).
        ki: PID integral gain (anti-windup clamped at ``_PID_WINDUP``).
        kd: PID derivative gain.
        gain_scale: Optional per-plan multipliers on the PID output —
            one entry per plan of the sweep, letting each placement
            candidate run its own loop stiffness over the shared qhat
            signal.  ``None`` means ones.
    """

    policy: str = "aimd"
    ttft_target_s: float = 30.0
    tpot_target_s: float = float("inf")
    interval_s: float = 0.5
    increase: float = 0.1
    decrease: float = 0.6
    admit_min: float = 0.05
    target_margin: float = 0.85
    reference_quantile: float = 0.99
    max_retries: int = 2
    retry_backoff_s: float = 1.0
    kp: float = 0.4
    ki: float = 0.05
    kd: float = 0.0
    gain_scale: tuple | None = None

    def __post_init__(self):
        """Validate the law's parameters."""
        if self.policy not in ("aimd", "pid", "static"):
            raise ValueError(f"unknown admission policy {self.policy!r}")
        if self.policy == "pid":
            if self.kp <= 0.0:
                raise ValueError("kp must be positive")
            if self.ki < 0.0 or self.kd < 0.0:
                raise ValueError("ki/kd must be non-negative")
            if self.gain_scale is not None \
                    and any(g <= 0.0 for g in self.gain_scale):
                raise ValueError("gain_scale entries must be positive")
        if not 0.0 < self.decrease < 1.0:
            raise ValueError("decrease must be in (0, 1)")
        if self.increase <= 0.0:
            raise ValueError("increase must be positive")
        if not 0.0 < self.admit_min <= 1.0:
            raise ValueError("admit_min must be in (0, 1]")
        if not 0.0 < self.target_margin <= 1.0:
            raise ValueError("target_margin must be in (0, 1]")
        if not 0.0 <= self.reference_quantile <= 1.0:
            raise ValueError("reference_quantile must be in [0, 1]")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")

    @property
    def n_attempts(self) -> int:
        """Total ingress attempts per request (first try + retries)."""
        return self.max_retries + 1


@jax.jit
def admission_queue_scan(work, cap, dt, ttft0, tpot0, ctrl, gw_idx, exp_idx,
                         admit0, ttft_target, tpot_target, increase,
                         decrease, admit_min, batching=None, pid=None):
    """Fleet backlog scan with the AIMD controller in the carry.

    The backlog recursion is identical to
    :func:`repro.traffic.queueing._fleet_queue_scan` (same wait/drop
    outputs bit-for-bit), extended with the per-(plan, gateway)
    admission state evolved by the AIMD law in the module docstring.

    Stations are satellites, so which of them form a plan's gateway
    chain and expert queues is a function of the bin's topology slot
    under a time-indexed :class:`~repro.core.schedule.PlanSchedule`:
    ``gw_idx``/``exp_idx`` carry the per-bin station maps through the
    scan, and the qhat estimate follows the schedule across every plan
    switch.

    Args:
        work: (P, S, T) seconds of offered work per (plan, station, bin).
        cap: Scalar or (S,) backlog cap in seconds (backpressure).
        dt: Time-bin width, seconds.
        ttft0: (P, G) zero-load TTFT reference per (plan, ground gateway).
        tpot0: (P,) zero-load TPOT reference per plan.
        ctrl: (T,) bool — True on bins that close a control interval.
        gw_idx: (T, P, L) station (satellite) of each gateway of the
            plan in effect during the bin's topology slot.
        exp_idx: (T, P, L*I) station of each (layer, expert) under the
            bin's plan.
        admit0: (P, G) initial admission probabilities (normally ones).
        ttft_target: Margin-scaled TTFT target (scalar).
        tpot_target: Margin-scaled TPOT target (scalar, +inf disables).
        increase: AIMD additive increase per clean interval.
        decrease: AIMD multiplicative decrease on breach.
        admit_min: Admission floor.
        batching: Optional continuous-batching pytree —
            ``work_dec``/``cnt_win`` (P, S, T) decode-work and windowed
            occupancy planes plus ``table``/``bcap`` (the padded speedup
            table and batch cap).  The deposit-time scaling law
            (:func:`repro.traffic.batching.batched_effective_work`)
            rewrites ``work`` before the scan; ``None`` (a distinct
            trace) leaves the FIFO kernel untouched.
        pid: Optional PID parameter pytree —
            ``kp``/``ki``/``kd`` scalars and ``gain`` (P,) per-plan
            multipliers.  ``None`` (a distinct trace) keeps the AIMD
            cell byte-identical to the pre-PID scan.

    Returns:
        (wait, dropped, admit): wait/dropped are (P, S, T) exactly as in
        the plain kernel; admit is (P, G, T), the admission probability
        in effect during each bin.
    """
    if batching is not None:
        from .batching import batched_effective_work
        work, _ = batched_effective_work(
            work, batching["work_dec"], batching["cnt_win"],
            batching["table"], batching["bcap"])
    p, s, _ = work.shape
    n_layers = gw_idx.shape[2]

    def _step(carry, xs):
        if pid is None:
            backlog, admit, win = carry
        else:
            backlog, admit, win, integ, prev = carry
        w_t, is_ctrl, gw_t, exp_t = xs
        wait = backlog
        total = backlog + w_t
        dropped = jnp.maximum(total - cap, 0.0)
        backlog = jnp.maximum(jnp.minimum(total, cap) - dt, 0.0)
        # Critical-path queueing-delay estimate (see module docstring),
        # read at the bin's slot-dependent gateway/expert stations.
        gw = jnp.take_along_axis(backlog, gw_t, axis=1).sum(axis=1)
        exp = jnp.take_along_axis(backlog, exp_t, axis=1) \
            .reshape(p, n_layers, -1).max(axis=2).sum(axis=1)
        win = jnp.maximum(win, gw + exp)                         # (P,)
        if pid is None:
            over = ((ttft0 + win[:, None]) > ttft_target) \
                | ((tpot0 + win) > tpot_target)[:, None]         # (P, G)
            stepped = jnp.where(over,
                                jnp.maximum(admit * decrease, admit_min),
                                jnp.minimum(admit + increase, 1.0))
            admit_next = jnp.where(is_ctrl, stepped, admit)
            win_next = jnp.where(is_ctrl, 0.0, win)
            return ((backlog, admit_next, win_next),
                    (wait, dropped, admit))
        # PID cell (module docstring): normalized headroom error; an
        # infinite target contributes +inf headroom so its term drops.
        h_t = jnp.where(jnp.isfinite(ttft_target),
                        (ttft_target - (ttft0 + win[:, None]))
                        / ttft_target, jnp.inf)                  # (P, G)
        h_p = jnp.where(jnp.isfinite(tpot_target),
                        (tpot_target - (tpot0 + win))
                        / tpot_target, jnp.inf)[:, None]         # (P, 1)
        err = jnp.minimum(h_t, h_p)                              # (P, G)
        integ2 = jnp.minimum(jnp.maximum(integ + err, -_PID_WINDUP),
                             _PID_WINDUP)
        delta = (pid["kp"] * err + pid["ki"] * integ2
                 + pid["kd"] * (err - prev))
        stepped = jnp.minimum(
            jnp.maximum(admit + pid["gain"][:, None] * delta, admit_min),
            1.0)
        admit_next = jnp.where(is_ctrl, stepped, admit)
        win_next = jnp.where(is_ctrl, 0.0, win)
        return ((backlog, admit_next, win_next,
                 jnp.where(is_ctrl, integ2, integ),
                 jnp.where(is_ctrl, err, prev)),
                (wait, dropped, admit))

    backlog0 = jnp.zeros((p, s), dtype=work.dtype)
    win0 = jnp.zeros((p,), dtype=work.dtype)
    carry0 = (backlog0, jnp.asarray(admit0, dtype=work.dtype), win0)
    if pid is not None:
        n_gw = np.shape(ttft0)[1]
        carry0 = carry0 + (jnp.zeros((p, n_gw), dtype=work.dtype),
                           jnp.zeros((p, n_gw), dtype=work.dtype))
    _, (wait, dropped, admit) = jax.lax.scan(
        _step, carry0,
        (jnp.moveaxis(work, 2, 0), ctrl, gw_idx, exp_idx))
    return (jnp.moveaxis(wait, 0, 2), jnp.moveaxis(dropped, 0, 2),
            jnp.moveaxis(admit, 0, 2))


def control_bin_flags(n_bins: int, dt_s: float, interval_s: float
                      ) -> np.ndarray:
    """(T,) bool — True on bins that close a control interval.

    The interval is quantized to whole bins (minimum one bin, i.e. a
    controller update every ``max(1, round(interval_s / dt_s))`` bins).
    """
    every = max(1, int(round(interval_s / dt_s)))
    t = np.arange(n_bins)
    return (t + 1) % every == 0


def resolve_admission(admit: np.ndarray, attempt_bin: np.ndarray,
                      attempt_station: np.ndarray, feasible: np.ndarray,
                      u: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Resolve each request's first admitted ingress attempt.

    Attempt a of request r is admitted iff its uniform draw clears the
    admission probability in effect at the attempt's (gateway, bin) —
    common random numbers: the same ``u`` is used for every plan, so
    plan-to-plan differences reflect the controllers, not the dice.

    Args:
        admit: (P, G, T) admission-probability trace.
        attempt_bin: (A, R) time bin of each attempt.
        attempt_station: (A, R) gateway of each attempt.
        feasible: (A, P, R) attempt reaches a visible, routable ingress.
        u: (A, R) per-(attempt, request) uniform draws in [0, 1).

    Returns:
        (choice, shed): choice is (P, R) — the index of the first
        admitted attempt (0 = no retry needed; undefined where shed);
        shed is (P, R) bool — every attempt rejected or infeasible.
    """
    adm = admit[:, attempt_station, attempt_bin]                # (P, A, R)
    ok = (u[None, :, :] < adm) & np.moveaxis(feasible, 1, 0)    # (P, A, R)
    shed = ~ok.any(axis=1)
    return ok.argmax(axis=1), shed
