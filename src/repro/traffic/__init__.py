"""repro.traffic — request-level traffic, queueing & SLO subsystem.

Layers a constellation-scale serving simulator on top of the batched
plan-evaluation engine: arrival processes (:mod:`.requests`), ground
gateway -> ingress satellite mapping (:mod:`.ground`), the discrete-time
per-satellite fleet queue kernel (:mod:`.queueing`), serving metrics +
saturation sweeps (:mod:`.metrics`) and the named scenario registry
(:mod:`.scenarios`).
"""
from .ground import (DEFAULT_STATIONS, GroundSegment, GroundStation,
                     build_ground_segment)
from .metrics import (SLO, PlanTraffic, SaturationResult, TrafficResult,
                      format_table, saturation_sweep)
from .queueing import (FleetSim, QueueConfig, simulate_traffic,
                       station_waiting_times)
from .requests import (RequestBatch, diurnal_rate, hotspot_rate,
                       poisson_arrivals, sample_decode_lens,
                       sample_prompt_lens, sample_requests, thinned_arrivals)
from .scenarios import (SCENARIOS, ScenarioOutcome, StormReport,
                        TrafficScenario, apply_failure_storm, get_scenario,
                        make_sim, run_scenario)

__all__ = [
    "DEFAULT_STATIONS", "GroundSegment", "GroundStation",
    "build_ground_segment",
    "SLO", "PlanTraffic", "SaturationResult", "TrafficResult",
    "format_table", "saturation_sweep",
    "FleetSim", "QueueConfig", "simulate_traffic", "station_waiting_times",
    "RequestBatch", "diurnal_rate", "hotspot_rate", "poisson_arrivals",
    "sample_decode_lens", "sample_prompt_lens", "sample_requests",
    "thinned_arrivals",
    "SCENARIOS", "ScenarioOutcome", "StormReport", "TrafficScenario",
    "apply_failure_storm", "get_scenario", "make_sim", "run_scenario",
]
