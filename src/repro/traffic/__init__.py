"""repro.traffic — request-level traffic, queueing & SLO subsystem.

Layers a constellation-scale serving simulator on top of the batched
plan-evaluation engine: arrival processes (:mod:`.requests`), ground
gateway -> ranked ingress-satellite mapping (:mod:`.ground`), the
discrete-time per-satellite fleet queue kernel (:mod:`.queueing`),
continuous decode batching for it (:mod:`.batching`),
latency-target adaptive admission control with gateway retry
(:mod:`.admission`), backlog-driven continuous re-placement over
time-indexed :class:`~repro.core.schedule.PlanSchedule` rows
(:mod:`.replan`), serving metrics + saturation sweeps (:mod:`.metrics`)
and the named scenario registry (:mod:`.scenarios`).

Shape conventions used throughout the subsystem: ``P`` plan/schedule
rows of the sweep, ``R`` requests, ``N`` decode tokens, ``M = R + N``
engine tokens (prefill macro-token per request first), ``L`` layers,
``I`` experts per layer, ``K`` = top-k, ``S = V`` queue stations (one
FIFO per satellite), ``G`` ground gateways, ``T`` time bins, ``A``
ingress attempts (1 + retries), ``N_T`` topology slots, ``C`` candidate
plans of the re-placement pool.
"""
from .admission import (AdmissionConfig, admission_queue_scan,
                        control_bin_flags, resolve_admission)
from .batching import (BatchingConfig, batched_effective_work,
                       effective_work_np, windowed_counts)
from .federation import (FederationConfig, FederationResult, FederationSim,
                         build_federation)
from .ground import (DEFAULT_STATIONS, GroundSegment, GroundStation,
                     build_ground_segment, ground_delay_table,
                     rank_constellations)
from .metrics import (SLO, PlanTraffic, SaturationResult, TrafficResult,
                      format_table, saturation_sweep)
from .queueing import (FleetSim, QueueConfig, simulate_traffic,
                       station_waiting_times)
from .replan import (ReplanConfig, ReplanDecision, ReplanOutcome,
                     ReplanReport, backlog_penalty_s, build_replan_schedule,
                     replan_base_scores, replan_traffic,
                     replan_traffic_fused)
from .requests import (RequestBatch, diurnal_rate, hotspot_rate,
                       poisson_arrivals, sample_decode_lens,
                       sample_prompt_lens, sample_requests, stream_arrivals,
                       stream_requests, thinned_arrivals)
from .scenarios import (SCENARIOS, ScenarioOutcome, StormReport,
                        TrafficScenario, apply_failure_storm, get_scenario,
                        make_federation, make_sim, run_scenario)

__all__ = [
    "AdmissionConfig", "admission_queue_scan", "control_bin_flags",
    "resolve_admission",
    "BatchingConfig", "batched_effective_work", "effective_work_np",
    "windowed_counts",
    "FederationConfig", "FederationResult", "FederationSim",
    "build_federation",
    "DEFAULT_STATIONS", "GroundSegment", "GroundStation",
    "build_ground_segment", "ground_delay_table", "rank_constellations",
    "SLO", "PlanTraffic", "SaturationResult", "TrafficResult",
    "format_table", "saturation_sweep",
    "FleetSim", "QueueConfig", "simulate_traffic", "station_waiting_times",
    "ReplanConfig", "ReplanDecision", "ReplanOutcome", "ReplanReport",
    "backlog_penalty_s", "build_replan_schedule", "replan_base_scores",
    "replan_traffic", "replan_traffic_fused",
    "RequestBatch", "diurnal_rate", "hotspot_rate", "poisson_arrivals",
    "sample_decode_lens", "sample_prompt_lens", "sample_requests",
    "stream_arrivals", "stream_requests", "thinned_arrivals",
    "SCENARIOS", "ScenarioOutcome", "StormReport", "TrafficScenario",
    "apply_failure_storm", "get_scenario", "make_federation", "make_sim",
    "run_scenario",
]
