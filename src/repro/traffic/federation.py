"""Planet-scale federation: K constellations, one device launch.

A :class:`FederationSim` wraps K independently-planned constellations —
each an ordinary :class:`~repro.traffic.queueing.FleetSim` world with
its own topology, plans/schedules and admission config — behind one
shared :class:`~repro.traffic.ground.GroundSegment`, and serves the
whole federation through the *existing* fused fleet fixed point:

* **One launch.**  Member device tables are padded to common shapes
  (plans edge-repeated to ``P_max``, queue rows zero-extended to
  ``rows_max``; the time-bin count ``T`` must already agree — see
  :func:`build_federation`) and stacked along the F-leading sweep axis
  of :func:`repro.traffic.queueing._fused_core`.  A federation of K
  members under an S-point nested rate sweep runs as ``F = S * K``
  lanes of **one compile trace and one device launch** (pinned via
  ``FUSED_TRACE_COUNT``, the PR 5/9 pattern).  With overflow routing
  off, each lane's arithmetic is element-for-element the member's own
  plan-leading launch, so per-constellation results are **bitwise
  identical** to running each ``FleetSim`` alone — the parity anchor.

* **Overflow scheduling.**  Requests shed by one member's admission
  controller retry at the next-best constellation: the per-request
  preference order generalizes the per-constellation ranked-visibility
  gateway table across members
  (:func:`repro.traffic.ground.rank_constellations` over each member's
  ingress cost), and each forward is billed into TTFT/E2E like PR 3's
  gateway retries (terrestrial forward delay + the rejecting
  controller's retry backoff).  The host-side fixed point is monotone
  the same way ``admission_queue_scan``'s running-minimum admit trace
  is: a rejection is permanent (the request is never re-offered to
  that member), so per-member rejection sets only grow, hop pointers
  only advance, and the loop converges in at most ``K`` rounds of
  relaunches that all reuse the one compile-cache entry.

Padding is exact, not approximate: padded plan lanes repeat the last
real plan (they compute independently and are sliced off the outputs),
padded rows receive zero work and are never gathered, and shed requests
deposit nothing — so removing a rejected request from a member's mask
leaves that member's remaining outcomes bit-for-bit unchanged while the
receiving member only *gains* load (its shed set can only grow).
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64 as _x64

from .batching import effective_work_np
from .ground import GroundSegment, rank_constellations
from .metrics import PlanTraffic, TrafficResult
from .queueing import _CHUNK_BLOCK, FleetSim, _fused_exec


@dataclasses.dataclass(frozen=True)
class FederationConfig:
    """Federation-scheduler knobs.

    Attributes:
        overflow: Route admission-shed requests to the next-best
            member constellation (requires every member to run the
            adaptive admission controller).  ``False`` serves each
            request only at its home constellation — the bitwise
            parity anchor against standalone ``FleetSim`` runs.
        forward_delay_s: Terrestrial latency billed per
            inter-constellation forward (on top of the rejecting
            controller's ``retry_backoff_s``).  ``None`` derives the
            mean off-diagonal ground delay of the shared ground
            segment when one is given, else 0.15 s.
        max_hops: Forward budget per request (default ``K - 1`` — at
            most one visit per member).
        max_rounds: Relaunch budget for the overflow fixed point
            (default ``K``; the monotone rejection sets converge in at
            most that many rounds when ``max_hops`` is ``K - 1``).
        serve_plan: Plan row of each member whose shed mask drives the
            routing decisions (results are still reported for every
            plan).
    """

    overflow: bool = True
    forward_delay_s: float | None = None
    max_hops: int | None = None
    max_rounds: int | None = None
    serve_plan: int = 0


@dataclasses.dataclass
class FederationResult:
    """Outcome of one federation run (one nested-sweep entry).

    Attributes:
        members: One :class:`~repro.traffic.metrics.TrafficResult` per
            member constellation, computed on its final offered mask
            with forwarding latency billed into TTFT/E2E.
        federated: Pooled :class:`~repro.traffic.metrics.PlanTraffic`
            over the members' ``serve_plan`` rows — the federation's
            own goodput/latency row.  Its ``retries`` column records
            inter-constellation hops; its ``shed`` column marks
            requests rejected by every member they could reach.
        assigned: (R,) final member index per request (-1 when the
            request ended up offered nowhere).
        hops: (R,) inter-constellation forwards each request took.
        n_rounds: Overflow fixed-point rounds executed (1 = no
            request moved).
        offered: (K, R) final per-member offered masks.
    """

    members: list
    federated: PlanTraffic
    assigned: np.ndarray
    hops: np.ndarray
    n_rounds: int
    offered: np.ndarray


def _edge_pad(a: np.ndarray, n: int, axis: int) -> np.ndarray:
    """Pad ``a`` to length ``n`` along ``axis`` by repeating its last
    entry (the exact-padding policy for the plan axis: a padded plan
    lane recomputes the last real plan and is sliced off on output)."""
    cur = a.shape[axis]
    if cur == n:
        return a
    idx = np.concatenate([np.arange(cur),
                          np.full(n - cur, cur - 1, dtype=np.int64)])
    return np.take(a, idx, axis=axis)


def _zero_pad(a: np.ndarray, n: int, axis: int) -> np.ndarray:
    """Pad ``a`` to length ``n`` along ``axis`` with zeros (the queue
    -row policy: padded rows receive no deposits and are never
    gathered)."""
    cur = a.shape[axis]
    if cur == n:
        return a
    shape = list(a.shape)
    shape[axis] = n - cur
    return np.concatenate([a, np.zeros(shape, dtype=a.dtype)], axis=axis)


class FederationSim:
    """K constellations behind one ground segment, one fused launch.

    Args:
        sims: Member :class:`~repro.traffic.queueing.FleetSim` worlds.
            They must share the request trace, the time-bin grid
            (``n_bins`` — build via :func:`build_federation` to
            equalize it), the queueing constants and — when admission
            is on — the controller law constants; topology, plans,
            schedules, ground visibility and admission *targets* are
            free per member.
        cfg: :class:`FederationConfig` (default: overflow on).
        home: Optional (R,) member index per request overriding the
            cost-based home assignment (benches use this to
            concentrate a hotspot on one member; -1 = use the cost
            ranking).
        ground: Optional shared ground segment — only used to derive
            ``forward_delay_s`` when the config leaves it ``None``.
    """

    def __init__(self, sims: list, cfg: FederationConfig | None = None,
                 *, home: np.ndarray | None = None,
                 ground: GroundSegment | None = None):
        if not sims:
            raise ValueError("a federation needs at least one member")
        self.sims = list(sims)
        self.cfg = cfg or FederationConfig()
        self._validate()
        K = len(self.sims)
        s0 = self.sims[0]
        self.n_members, self.n_requests = K, s0.n_requests
        self.n_bins = s0.n_bins
        self.requests = s0.requests
        self.admission_on = s0.admission_on
        self.serve_plan = self.cfg.serve_plan
        if not 0 <= self.serve_plan < min(s.n_plans for s in self.sims):
            raise ValueError("serve_plan out of range for some member")
        self._p_max = max(s.n_plans for s in self.sims)
        self._sr_max = max(s.n_rows for s in self.sims)
        # Member chunk gather indices remapped for the padded plan
        # block: the flat [layer | expert] pair per lane is laid out at
        # P_max plans, so expert-block sources shift up by the pad.
        self._fed_src = []
        for s in self.sims:
            gw_span = s.n_plans * s.n_tokens * s.n_layers
            shift = (self._p_max - s.n_plans) * s.n_tokens * s.n_layers
            self._fed_src.append(np.where(s._f_src < gw_span, s._f_src,
                                          s._f_src + shift))
        # Cross-constellation preference ranking: each member's ingress
        # cost for each request at the serve plan (+inf = its ground
        # segment cannot ingest the request), ranked best-first with
        # index tie-breaks — ground.ingress_ranked generalized across
        # members.
        costs = np.stack([
            np.where(s.fail_ingress[self.serve_plan], np.inf,
                     s.ingress_extra[self.serve_plan])
            for s in self.sims])                              # (K, R)
        self.ingress_cost = costs
        self.ranking = rank_constellations(costs)             # (R, K)
        self.feasible = np.isfinite(costs)                    # (K, R)
        best = self.ranking[:, 0]
        home_cost = np.where(self.feasible.any(axis=0), best, -1)
        if home is not None:
            home = np.asarray(home, dtype=np.int64)
            if home.shape != (self.n_requests,):
                raise ValueError(f"home must be ({self.n_requests},)")
            if (home >= K).any():
                raise ValueError("home index out of range")
            # Explicit homes must be feasible there; fall back to the
            # cost ranking (or -1) where they are not.
            ok = (home >= 0) & self.feasible[np.clip(home, 0, K - 1),
                                            np.arange(self.n_requests)]
            home_cost = np.where(ok, home, home_cost)
        self.home = home_cost                                 # (R,)
        if self.cfg.forward_delay_s is not None:
            self.forward_delay_s = float(self.cfg.forward_delay_s)
        elif ground is not None and ground.n_stations > 1:
            gd = ground.ground_delay_s
            off = ~np.eye(ground.n_stations, dtype=bool)
            self.forward_delay_s = float(gd[off].mean())
        else:
            self.forward_delay_s = 0.15
        self.max_hops = (K - 1 if self.cfg.max_hops is None
                         else int(self.cfg.max_hops))
        self.max_rounds = (K if self.cfg.max_rounds is None
                           else int(self.cfg.max_rounds))
        self._dev_cache: dict = {}

    # ------------------------------------------------------------- #
    # Validation + padded device tables
    # ------------------------------------------------------------- #

    def _validate(self) -> None:
        s0 = self.sims[0]
        req0 = s0.requests
        for i, s in enumerate(self.sims[1:], start=1):
            r = s.requests
            if not (np.array_equal(req0.arrival_s, r.arrival_s)
                    and np.array_equal(req0.prompt_len, r.prompt_len)
                    and np.array_equal(req0.decode_len, r.decode_len)
                    and np.array_equal(req0.station, r.station)):
                raise ValueError(
                    f"member {i} serves a different request trace — a "
                    f"federation shares one global trace")
            if s.n_bins != s0.n_bins:
                raise ValueError(
                    f"member {i} has {s.n_bins} time bins vs "
                    f"{s0.n_bins}: the fused kernel's bin clipping is "
                    f"static in T, so members must share n_bins — "
                    f"rebuild the shorter ones with min_bins="
                    f"{max(s.n_bins, s0.n_bins)} (build_federation "
                    f"does this)")
            q0, q = s0.qcfg, s.qcfg
            if (q0.dt_s, q0.buffer_s, q0.iterations) != \
                    (q.dt_s, q.buffer_s, q.iterations):
                raise ValueError(
                    f"member {i} queueing constants differ "
                    f"(dt_s/buffer_s/iterations are shared kernel "
                    f"consts)")
            if s.admission_on != s0.admission_on:
                raise ValueError(
                    "members must all run admission, or none")
            if s.admission_on:
                a0, a = q0.admission, q.admission
                same = (a0.policy == a.policy
                        and a0.increase == a.increase
                        and a0.decrease == a.decrease
                        and a0.admit_min == a.admit_min
                        and a0.interval_s == a.interval_s
                        and a0.max_retries == a.max_retries)
                if a0.policy == "pid":
                    same = same and (a0.kp, a0.ki, a0.kd) == \
                        (a.kp, a.ki, a.kd) \
                        and a0.gain_scale is None \
                        and a.gain_scale is None
                if not same:
                    raise ValueError(
                        f"member {i} admission law differs (the AIMD/"
                        f"PID constants are shared kernel consts; "
                        f"targets may differ, the law may not)")
            if not np.array_equal(s0.gw_service, s.gw_service):
                raise ValueError(
                    f"member {i} gateway service times differ — "
                    f"federation lanes share the per-token service "
                    f"array (use one workload/service model)")
            if (s.n_tokens, s.n_layers) != (s0.n_tokens, s0.n_layers):
                raise ValueError(
                    f"member {i} token/layer grid differs")
            if s._ex_rowc.shape[-1] != s0._ex_rowc.shape[-1]:
                raise ValueError(
                    f"member {i} expert gather depth differs")
            if s.admission_on and \
                    s._adm_exp_rowc.shape[-1] != s0._adm_exp_rowc.shape[-1]:
                raise ValueError(
                    f"member {i} admission station-map width differs")
            if s.admission_on and \
                    s._adm_ttft0.shape[1] != s0._adm_ttft0.shape[1]:
                raise ValueError(
                    f"member {i} gateway count differs — members share "
                    f"one ground segment (G is a kernel const)")
            if s.probes is not None or s0.probes is not None:
                raise ValueError(
                    "probes are not supported on federation launches")
            b0, b = s0.batching, s.batching
            if (b0 is None) != (b is None):
                raise ValueError(
                    "members must all batch, or none")
            if b0 is not None and not (
                    np.array_equal(s0._batch_table, s._batch_table)
                    and s0._batch_cap == s._batch_cap
                    and s0._batch_window == s._batch_window):
                raise ValueError(
                    f"member {i} batching table differs (shared const)")
        if self.cfg.overflow and not s0.admission_on:
            raise ValueError(
                "overflow routing re-routes admission-shed requests — "
                "it needs every member to run the adaptive admission "
                "controller (or pass FederationConfig(overflow=False))")

    def _stacked_consts(self) -> dict:
        """K-leading numpy stack of the members' device tables, padded
        to (P_max, rows_max)."""
        P, SR = self._p_max, self._sr_max
        sims = self.sims

        def plans(attr, axis=0):
            return np.stack([_edge_pad(getattr(s, attr), P, axis)
                             for s in sims])

        base = dict(
            eff_layer=plans("eff_layer"),            # (K, P, M, L)
            tok_base=plans("tok_base"),              # (K, P, M)
            ingress_extra0=plans("ingress_extra"),   # (K, P, R)
            gw_rows=plans("_gw_rowc"),               # (K, P, M, L)
            ex_rows=plans("_ex_rowc"),               # (K, P, M, L, I)
            gw_b0=plans("_gw_b0"), gw_fin0=plans("_gw_fin0"),
            ex_b0=plans("_ex_b0"), ex_fin0=plans("_ex_fin0"),
        )
        if any(s._mig_rm is not None for s in sims):
            base["mig_dense_f"] = np.stack([
                _zero_pad(s._mig_rm, SR, 0) if s._mig_rm is not None
                else np.zeros((SR, self.n_bins))
                for s in sims])                      # (K, rows, T)
        if self.admission_on:
            f32 = np.float32
            base.update(
                ttft0=np.stack([_edge_pad(s._adm_ttft0.astype(f32), P, 0)
                                for s in sims]),     # (K, P, G)
                tpot0=np.stack([_edge_pad(s._adm_tpot0.astype(f32), P, 0)
                                for s in sims]),     # (K, P)
                # Per-bin station maps stay T-leading with the lane
                # axis second: (T, K, P, L) / (T, K, P, LI).
                gw_rows_bin=np.stack(
                    [_edge_pad(s._adm_gw_rowc, P, 1) for s in sims],
                    axis=1),
                exp_rows_bin=np.stack(
                    [_edge_pad(s._adm_exp_rowc, P, 1) for s in sims],
                    axis=1),
                # Per-member attempt tables (the new (F, A, R) kernel
                # branch): retry gateways/bins follow each member's own
                # ground visibility.
                att_bin=np.stack([s._att_bin for s in sims]),
                att_station=np.stack([s._att_station for s in sims]),
                att_feasible=np.stack([
                    _edge_pad(np.moveaxis(s._att_feasible, 1, 0), P, 0)
                    for s in sims]),                 # (K, P, A, R)
                att_extra=np.stack([
                    _edge_pad(np.moveaxis(s._att_extra, 0, 1), P, 0)
                    for s in sims]),                 # (K, P, A, R)
                adm_u=np.stack([s._adm_u for s in sims]),  # (K, A, R)
            )
        return base

    def _device_consts(self, n_sweep: int) -> dict:
        """The fused kernel's consts pytree for ``F = n_sweep * K``
        lanes (lane ``f = s * K + k`` carries member ``k``): the
        K-leading stack tiled along the sweep, plus the shared
        request/clock tables taken from member 0."""
        if n_sweep in self._dev_cache:
            return self._dev_cache[n_sweep]
        s0 = self.sims[0]
        qcfg = s0.qcfg
        base = self._stacked_consts()
        with _x64():
            d = {}
            for key, a in base.items():
                if key in ("gw_rows_bin", "exp_rows_bin"):
                    reps = (1, n_sweep) + (1,) * (a.ndim - 2)
                else:
                    reps = (n_sweep,) + (1,) * (a.ndim - 1)
                d[key] = jnp.asarray(np.tile(a, reps))
            d.update(
                dt=jnp.asarray(float(qcfg.dt_s)),
                cap32=jnp.asarray(float(qcfg.buffer_s),
                                  dtype=jnp.float32),
                dt32=jnp.asarray(float(qcfg.dt_s), dtype=jnp.float32),
                gw_service=jnp.asarray(s0.gw_service),
                arrival_s=jnp.asarray(self.requests.arrival_s),
                first_tok=jnp.asarray(s0.first_tok),
                tok_req=jnp.asarray(s0.tok_req),
                last_tok=jnp.asarray(
                    s0.first_tok + self.requests.decode_len - 1),
            )
            if self.admission_on:
                sd = s0._device_tables()
                for key in ("ctrl", "increase", "decrease", "admit_min"):
                    d[key] = sd[key]
                if qcfg.admission.policy == "pid":
                    d["pid_kp"] = sd["pid_kp"]
                    d["pid_ki"] = sd["pid_ki"]
                    d["pid_kd"] = sd["pid_kd"]
                    d["pid_gain"] = jnp.asarray(
                        np.ones(self._p_max, dtype=np.float32))
        self._dev_cache[n_sweep] = d
        return d

    # ------------------------------------------------------------- #
    # Launch
    # ------------------------------------------------------------- #

    def _launch(self, offered: np.ndarray) -> dict:
        """One fused launch over ``F = n_sweep * K`` federation lanes.

        Mirrors :meth:`FleetSim._launch` exactly, per lane: the chunk
        compaction streams one lane at a time (bounded shards — the
        dense (F, n_chunks) activity matrix never materializes), lane
        ``f = s * K + k`` deposits member ``k``'s active chunks under
        sweep entry ``s``'s mask, and the iteration-1 plane is one
        host bincount per lane.

        Args:
            offered: (n_sweep, K, R) bool per-member offered masks.

        Returns:
            The fused output dict as host arrays, leading axis F.
        """
        return self._execute(self._prepare(offered))

    def _prepare(self, offered: np.ndarray) -> dict:
        """Host side of a launch: per-lane chunk compaction and the
        iteration-1 deposit planes.  Split from :meth:`_execute` so the
        benchmark can bill host prep and device time separately."""
        n_sweep, K, R = offered.shape
        F = n_sweep * K
        P, SR, T = self._p_max, self._sr_max, self.n_bins
        s0 = self.sims[0]
        M, L = s0.n_tokens, s0.n_layers
        pml2 = 2 * P * M * L
        batching = s0.batching is not None

        lane_cols: list[tuple[int, "FleetSim", np.ndarray]] = []
        for s in range(n_sweep):
            for k, sim in enumerate(self.sims):
                cid = np.flatnonzero(offered[s, k][sim._f_req])
                lane_cols.append((s * K + k, sim, cid))
        n = sum(c.size for _, _, c in lane_cols)
        n_pad = max(-(-n // _CHUNK_BLOCK), 1) * _CHUNK_BLOCK

        src = np.zeros(n_pad, dtype=np.int64)
        offs = np.zeros(n_pad, dtype=np.int64)
        work = np.zeros(n_pad)
        fprow = np.zeros(n_pad, dtype=np.int32)
        fpr = np.zeros(n_pad, dtype=np.int64)
        wdec = np.zeros(n_pad) if batching else None
        cntw = np.zeros(n_pad) if batching else None
        plane0 = np.zeros((F, SR, T))
        plane0_dec = np.zeros((F, SR, T)) if batching else None
        cnt0 = np.zeros((F, SR, T)) if batching else None

        pos = 0
        for f, sim, cid in lane_cols:
            m = cid.size
            k = f % K
            sl = slice(pos, pos + m)
            src[sl] = f * pml2 + self._fed_src[k][cid]
            offs[sl] = sim._f_offs[cid]
            work[sl] = sim._f_work[cid]
            fprow[sl] = np.int32(f * SR) + sim._f_rowc[cid]
            fpr[sl] = f * (P * R) + sim._f_pr[cid]
            if batching:
                wdec[sl] = sim._f_wdec[cid]
                cntw[sl] = sim._f_cntw[cid]
            pos += m
            flat0 = sim._f_rowc[cid].astype(np.int64) * T \
                + sim._f_bins0[cid]
            w0 = sim._f_work[cid] * sim._f_fin0[cid]
            plane0[f] = np.bincount(
                flat0, weights=w0, minlength=SR * T
            ).reshape(SR, T).astype(np.float64)
            if sim._mig_rm is not None:
                plane0[f, :sim.n_rows] += sim._mig_rm
            if batching:
                plane0_dec[f] = np.bincount(
                    flat0, weights=sim._f_wdec[cid] * sim._f_fin0[cid],
                    minlength=SR * T).reshape(SR, T)
                cnt0[f] = np.bincount(
                    flat0, weights=sim._f_cntw[cid] * sim._f_fin0[cid],
                    minlength=SR * T).reshape(SR, T)

        work0_sum = plane0.sum(axis=2)
        batch_np: dict = {}
        batch_window = 0
        if batching:
            plane0, _ = effective_work_np(
                plane0, plane0_dec, cnt0, s0._batch_table,
                s0._batch_cap, s0._batch_window)
            batch_np = dict(table=s0._batch_table,
                            bcap=np.float64(s0._batch_cap))
            batch_window = s0._batch_window

        chunks = dict(src=src, offs=offs, work=work, fprow=fprow)
        if self.admission_on:
            chunks["fpr"] = fpr
            tt = np.empty(F)
            tp = np.empty(F)
            for k, sim in enumerate(self.sims):
                acfg = sim.qcfg.admission
                m = acfg.target_margin
                tt[k::K] = m * acfg.ttft_target_s
                tp[k::K] = m * acfg.tpot_target_s
        else:
            tt = np.zeros(F)
            tp = np.zeros(F)
        if batching:
            chunks["wdec"], chunks["cntw"] = wdec, cntw

        return dict(chunks=chunks, plane0=plane0, work0_sum=work0_sum,
                    tt=tt, tp=tp, batch_np=batch_np,
                    batch_window=batch_window, n_sweep=n_sweep,
                    T=T, SR=SR)

    def _execute(self, prep: dict) -> dict:
        """Device side of a launch: move the prepared chunk stream to
        the device and run the fused kernel once."""
        s0 = self.sims[0]
        with _x64(), warnings.catch_warnings():
            warnings.filterwarnings("ignore", message=".*[Dd]onat")
            out = _fused_exec(
                self._device_consts(prep["n_sweep"]),
                {k: jnp.asarray(v) for k, v in prep["chunks"].items()},
                jnp.asarray(prep["plane0"].astype(np.float32)),
                jnp.asarray(prep["work0_sum"]),
                jnp.asarray(prep["tt"]), jnp.asarray(prep["tp"]), {},
                {k: jnp.asarray(v) for k, v in prep["batch_np"].items()},
                max(1, s0.qcfg.iterations), prep["T"], prep["SR"],
                self.admission_on, s0._deposit_mode(), False,
                None, prep["batch_window"])
            out = {k: jax.tree_util.tree_map(np.asarray, v)
                   for k, v in out.items()}
        return out

    # ------------------------------------------------------------- #
    # Overflow fixed point + result assembly
    # ------------------------------------------------------------- #

    def run_many(self, masks: np.ndarray | None = None, *,
                 overflow: bool | None = None) -> list[FederationResult]:
        """Serve a nested sweep of global activity masks — the whole
        federation, every sweep entry, in one compile trace.

        The first launch covers every (sweep entry, member) lane; each
        overflow round removes newly-rejected requests from the
        rejecting member (permanently — the monotone invariant) and
        offers them to the next-best feasible member on their ranking,
        then relaunches the *same shapes* (compile-cache hit, no new
        trace).  The loop stops when no request moves or after
        ``max_rounds`` launches.

        Args:
            masks: (n_sweep, R) bool global activity masks (None = one
                all-active entry).
            overflow: Override the config's overflow switch for this
                run.

        Returns:
            One :class:`FederationResult` per sweep entry.
        """
        R, K = self.n_requests, self.n_members
        if masks is None:
            masks = np.ones((1, R), dtype=bool)
        masks = np.asarray(masks, dtype=bool)
        if masks.ndim != 2 or masks.shape[1] != R:
            raise ValueError(f"masks must be (n_sweep, {R})")
        n_sweep = masks.shape[0]
        route = self.cfg.overflow if overflow is None else bool(overflow)
        if route and not self.admission_on:
            raise ValueError("overflow routing needs admission")

        # Home assignment: each active request starts at its preferred
        # feasible member; requests no member can ingest start nowhere.
        offered = np.zeros((n_sweep, K, R), dtype=bool)
        for k in range(K):
            offered[:, k] = masks & (self.home == k)[None, :]
        visited = offered.copy()                     # never re-offer
        assigned = np.where(masks, self.home[None, :], -1)  # (n_sweep, R)
        hops = np.zeros((n_sweep, R), dtype=np.int64)
        extra_s = np.zeros((n_sweep, R))

        sp = self.serve_plan
        n_rounds = 0
        while True:
            out = self._launch(offered)
            n_rounds += 1
            if not route or n_rounds >= self.max_rounds:
                break
            moved = False
            for s in range(n_sweep):
                for k in range(K):
                    f = s * K + k
                    rej = out["shed"][f, sp] & offered[s, k]
                    if not rej.any():
                        continue
                    # Permanent rejection at k: shed requests deposit
                    # nothing, so dropping them leaves k's remaining
                    # outcomes bit-identical.
                    offered[s, k][rej] = False
                    backoff = self.sims[k].qcfg.admission.retry_backoff_s
                    for r in np.flatnonzero(rej):
                        assigned[s, r] = -1
                        if hops[s, r] >= self.max_hops:
                            continue
                        for k2 in self.ranking[r]:
                            if visited[s, k2, r] or \
                                    not self.feasible[k2, r]:
                                continue
                            offered[s, k2, r] = True
                            visited[s, k2, r] = True
                            assigned[s, r] = k2
                            hops[s, r] += 1
                            extra_s[s, r] += \
                                self.forward_delay_s + backoff
                            moved = True
                            break
            if not moved:
                break

        return [self._assemble(masks[s], offered[s], out, s,
                               assigned[s], hops[s], extra_s[s],
                               n_rounds)
                for s in range(n_sweep)]

    def run(self, active: np.ndarray | None = None, *,
            overflow: bool | None = None) -> FederationResult:
        """Single-entry convenience wrapper around :meth:`run_many`."""
        if active is None:
            active = np.ones(self.n_requests, dtype=bool)
        return self.run_many(np.asarray(active, dtype=bool)[None, :],
                             overflow=overflow)[0]

    def _assemble(self, active, offered, out, s, assigned, hops,
                  extra_s, n_rounds) -> FederationResult:
        """Slice one sweep entry's lanes out of the fused output, bill
        the forwarding latency, and pool the federation row."""
        K, sp = self.n_members, self.serve_plan
        members = []
        for k, sim in enumerate(self.sims):
            f = s * K + k
            o = dict(
                ttft=out["ttft"][f, :sim.n_plans],
                e2e=out["e2e"][f, :sim.n_plans],
                tok_total=out["tok_total"][f, :sim.n_plans],
                tok_over=out["tok_over"][f, :sim.n_plans],
                shed=out["shed"][f, :sim.n_plans],
                retries=out["retries"][f, :sim.n_plans],
                work_sum=sim._expand_rows(
                    out["work_sum"][f, :sim.n_rows]),
            )
            res = sim._finalize(offered[k], o, self.admission_on)
            if extra_s.any():
                res = dataclasses.replace(res, plans=[
                    p.with_added_latency(extra_s) for p in res.plans])
            members.append(res)

        # Pooled federation row over the serve-plan rows: the offered
        # masks are disjoint per round, so served sets never overlap.
        req = self.requests
        R = self.n_requests
        nan = np.full(R, np.nan)
        served = np.zeros(R, dtype=bool)
        ttft, tpot, e2e = nan.copy(), nan.copy(), nan.copy()
        retries = np.zeros(R, dtype=np.int64)
        shed_any = np.zeros(R, dtype=bool)
        mig = 0.0
        utils, toks = [], []
        for k, res in enumerate(members):
            row = res.plans[sp]
            sk = row.served
            served |= sk
            ttft[sk] = row.ttft_s[sk]
            tpot[sk] = row.tpot_s[sk]
            e2e[sk] = row.e2e_s[sk]
            retries[sk] = hops[sk]
            if row.shed is not None:
                # Final-round sheds only: earlier rejections already
                # left this member's offered mask.
                shed_any |= row.shed
            mig += row.migration_bytes
            utils.append(row.station_util)
            toks.append(row.token_total_s)
        span = max(float(req.arrival_s[active].max()
                         - req.arrival_s[active].min()),
                   self.sims[0].qcfg.dt_s) if active.any() \
            else self.sims[0].qcfg.dt_s
        federated = PlanTraffic(
            plan_name="federation",
            active=active.copy(),
            served=served,
            ttft_s=ttft, tpot_s=tpot, e2e_s=e2e,
            decode_len=req.decode_len,
            station_util=np.concatenate(utils),
            span_s=span,
            token_total_s=np.concatenate(toks),
            shed=(active & ((assigned < 0) | shed_any))
            if self.admission_on else None,
            retries=np.where(served, retries, 0)
            if self.admission_on else None,
            migration_bytes=mig,
        )
        return FederationResult(
            members=members, federated=federated, assigned=assigned,
            hops=hops, n_rounds=n_rounds, offered=offered.copy())


def build_federation(factories: list, cfg: FederationConfig | None = None,
                     **kwargs) -> FederationSim:
    """Construct member worlds on one shared time-bin grid.

    Each factory is a callable taking a ``min_bins`` keyword and
    returning a :class:`~repro.traffic.queueing.FleetSim` (e.g. a
    ``functools.partial`` over ``FleetSim`` or
    :func:`repro.traffic.scenarios.make_sim`).  Members are built
    once, then any member whose natural horizon came up short is
    rebuilt with ``min_bins`` pinned to the federation maximum — the
    fused kernel's bin clipping is static in T, so sharing the grid is
    what makes the padded stacking exact.

    Args:
        factories: K callables ``f(min_bins=...) -> FleetSim``.
        cfg: Passed through to :class:`FederationSim`.
        **kwargs: Passed through to :class:`FederationSim` (``home``,
            ``ground``).

    Returns:
        The federation over the (re)built members.
    """
    sims = [f(min_bins=0) for f in factories]
    t_max = max(s.n_bins for s in sims)
    sims = [s if s.n_bins == t_max else f(min_bins=t_max)
            for s, f in zip(sims, factories)]
    return FederationSim(sims, cfg, **kwargs)
