"""Chrome trace-event / Perfetto JSON exporter for a :class:`FlightLog`.

Produces the Trace Event Format's JSON-object flavor (loadable by
Perfetto's trace viewer and ``chrome://tracing``):

* **request lanes** (pid 1) — one thread per exported request, with
  contiguous ``prefill`` and ``decode`` complete spans; shed/failed
  requests appear as instants at their arrival;
* **satellite lanes** (pid 2) — per-satellite counter tracks sampled
  from the probe ring (backlog seconds, offered utilization, dropped
  seconds), busiest satellites first;
* **control lane** (pid 3) — instants for every control-plane event
  (AIMD admit steps with their qhat, replan decisions with the
  migration byte flow of a switch).

Timestamps are microseconds of simulated wall-clock time.  The
``metadata`` object carries :data:`repro.obs.schema.SCHEMA_VERSION`
plus run provenance; ``tools/check_trace.py`` validates both halves.
"""
from __future__ import annotations

import json
import math

import numpy as np

from .recorder import FlightLog
from .schema import SCHEMA_VERSION

#: Process-lane ids of the exported trace.
PID_REQUESTS, PID_FLEET, PID_CONTROL = 1, 2, 3


def _us(t_s: float) -> float:
    """Seconds -> trace microseconds (clamped non-negative)."""
    return max(round(float(t_s) * 1e6, 3), 0.0)


def _meta(pid: int, name: str, tid: int | None = None,
          thread: str | None = None) -> dict:
    """A process/thread-naming metadata event."""
    ev = {"name": "process_name" if tid is None else "thread_name",
          "ph": "M", "pid": pid, "ts": 0,
          "args": {"name": name if tid is None else thread}}
    if tid is not None:
        ev["tid"] = tid
    return ev


def _finite(x: float) -> bool:
    return isinstance(x, (int, float)) and math.isfinite(x)


def _request_events(log: FlightLog, max_requests: int) -> list[dict]:
    events: list[dict] = []
    served = [r for r in log.requests if r.served][:max_requests]
    unserved = [r for r in log.requests
                if r.active and not r.served][:max_requests]
    for r in served:
        tid = r.rid + 1
        events.append(_meta(PID_REQUESTS, "", tid=tid,
                            thread=f"req {r.rid} (gw {r.station})"))
        args = {
            "station": r.station, "retries": r.retries,
            "prompt_len": r.prompt_len, "decode_len": r.decode_len,
            "ingress_s": round(r.ingress_s, 6),
            "queue_wait_s": round(r.queue_wait_s, 6),
            "zero_load_s": round(float(r.layer_zero_s.sum()), 6),
        }
        if r.layer_gw_wait_s is not None and r.layer_zero_s.size <= 64:
            # Per-layer Eq. 43 breakdown: zero-load hop+service cost and
            # the final iteration's queue waits, layer by layer.
            args["layer_zero_ms"] = [
                round(float(v) * 1e3, 3) for v in r.layer_zero_s]
            args["layer_gw_wait_ms"] = [
                round(float(v) * 1e3, 3) for v in r.layer_gw_wait_s]
            args["layer_ex_wait_ms"] = [
                round(float(v) * 1e3, 3) for v in r.layer_ex_wait_s]
        if _finite(r.ttft_s):
            events.append({
                "name": "prefill", "cat": "request", "ph": "X",
                "pid": PID_REQUESTS, "tid": tid,
                "ts": _us(r.arrival_s), "dur": _us(r.ttft_s),
                "args": args})
        if _finite(r.ttft_s) and _finite(r.e2e_s):
            dec_args = {"decode_len": r.decode_len,
                        "tpot_s": round(r.tpot_s, 6)
                        if _finite(r.tpot_s) else -1.0}
            if _finite(r.batch_b):
                # Continuous-batching runs: the request's batch span —
                # mean B_eff over its decode window.
                dec_args["batch_b"] = round(r.batch_b, 3)
            events.append({
                "name": "decode", "cat": "request", "ph": "X",
                "pid": PID_REQUESTS, "tid": tid,
                "ts": _us(r.arrival_s + r.ttft_s),
                "dur": _us(max(r.e2e_s - r.ttft_s, 0.0)),
                "args": dec_args})
    for r in unserved:
        events.append({
            "name": "shed" if r.shed else "dropped", "cat": "request",
            "ph": "i", "s": "p", "pid": PID_REQUESTS, "tid": 0,
            "ts": _us(r.arrival_s),
            "args": {"rid": r.rid, "station": r.station,
                     "retries": r.retries}})
    return events


def _satellite_events(log: FlightLog, max_sats: int) -> list[dict]:
    probes = log.probes
    if probes is None or probes.n_recorded == 0:
        return []
    p = log.plan
    backlog = probes.backlog_s[:, 0, p]                    # (B, S)
    util = probes.util_s[:, 0, p] / probes.dt_s
    drops = probes.drops_s[:, 0, p]
    # Busiest satellites only: a constellation-wide counter dump would
    # dwarf the request lanes without adding signal.
    load = backlog.max(axis=0) + util.max(axis=0)
    order = np.argsort(-load)
    sats = [int(v) for v in order[:max_sats] if load[v] > 0.0] \
        or [int(order[0])]
    t_us = [_us(t) for t in probes.t_s]
    events: list[dict] = []
    for v in sats:
        for b, ts in enumerate(t_us):
            events.append({
                "name": f"sat{v}", "cat": "fleet", "ph": "C",
                "pid": PID_FLEET, "tid": 0, "ts": ts,
                "args": {"backlog_s": round(float(backlog[b, v]), 5),
                         "util": round(float(util[b, v]), 5),
                         "dropped_s": round(float(drops[b, v]), 5)}})
    return events


def _control_events(log: FlightLog) -> list[dict]:
    events: list[dict] = []
    tids = {"aimd": 1, "replan": 2, "joint": 3}
    for ev in log.events:
        events.append({
            "name": ev.name, "cat": ev.kind, "ph": "i", "s": "g",
            "pid": PID_CONTROL, "tid": tids.get(ev.kind, 9),
            "ts": _us(ev.t_s),
            "args": {"plan": ev.plan, **ev.args}})
    return events


def chrome_trace(log: FlightLog, max_requests: int = 200,
                 max_sats: int = 16) -> dict:
    """Render a :class:`~repro.obs.recorder.FlightLog` as a Chrome
    trace-event object.

    Args:
        log: The flight log to export.
        max_requests: Cap on exported request lanes (served and
            unserved counted separately; arrival order).
        max_sats: Cap on exported satellite counter lanes (busiest
            first).

    Returns:
        The trace dict (``json.dump``-ready; validates against
        :mod:`repro.obs.schema`).
    """
    plan_name = log.plan_names[log.plan]
    events = [
        _meta(PID_REQUESTS, f"requests · {plan_name}"),
        _meta(PID_FLEET, f"fleet · {plan_name}"),
        _meta(PID_CONTROL, "control plane"),
        _meta(PID_CONTROL, "", tid=1, thread="admission (AIMD)"),
        _meta(PID_CONTROL, "", tid=2, thread="replan"),
        _meta(PID_CONTROL, "", tid=3, thread="joint control"),
    ]
    events += _request_events(log, max_requests)
    events += _satellite_events(log, max_sats)
    events += _control_events(log)
    n_served = sum(1 for r in log.requests if r.served)
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "metadata": {
            "schema_version": SCHEMA_VERSION,
            "generator": "repro.obs",
            "scenario": log.scenario,
            "dt_s": float(log.dt_s),
            "horizon_s": float(log.horizon_s),
            "plans": list(log.plan_names),
            "plan": plan_name,
            "n_requests": len(log.requests),
            "n_served": int(n_served),
            "n_control_events": len(log.events),
            "probed": log.probes is not None,
            "summary": log.summary or {},
        },
    }


def write_trace(path: str, log: FlightLog, **kwargs) -> dict:
    """Export ``log`` to ``path`` as trace JSON; returns the trace dict."""
    trace = chrome_trace(log, **kwargs)
    with open(path, "w") as f:
        json.dump(trace, f)
    return trace
