"""Request flight recorder: per-request lifecycle + control-plane events.

Host-side assembly, run *after* a fused launch: the fleet simulator's
construction tables (ingress mapping, zero-load Eq. 43 layer costs),
the launch outputs digested into :class:`~repro.traffic.metrics
.PlanTraffic` rows, and the on-device :class:`~repro.obs.probes
.ProbeRecord` are joined into one :class:`FlightLog` — per-request
records with prefill/decode spans and a per-layer latency breakdown
(zero-load hop terms + the final iteration's queueing waits), plus the
control-plane event stream (AIMD admit changes read off the probe ring,
replan slot switches read off the controller's decision trajectory).

Everything here is plain numpy bookkeeping; the exporter
(:mod:`repro.obs.export`) turns a :class:`FlightLog` into Chrome
trace-event JSON and :func:`summarize_timeseries` turns the probe ring
into flat rows for :func:`repro.traffic.metrics.format_table`.
"""
from __future__ import annotations

import dataclasses
import typing

import numpy as np

from .probes import ProbeRecord

if typing.TYPE_CHECKING:                              # pragma: no cover
    from repro.traffic.metrics import TrafficResult
    from repro.traffic.queueing import FleetSim
    from repro.traffic.replan import ReplanReport


@dataclasses.dataclass
class RequestRecord:
    """One request's lifecycle under one plan row.

    Spans are wall-clock seconds; per-layer arrays have length L.

    Attributes:
        rid: Request index in the trace.
        station: Ground-station (gateway) index the request entered at.
        arrival_s: Arrival wall-clock time.
        prompt_len: Prompt tokens.
        decode_len: Decode tokens.
        active: Participated in the run (thinning mask).
        served: Fully delivered.
        shed: Rejected by the admission controller.
        retries: Gateway-retry attempts used (0 = first gateway).
        ingress_s: Uplink + ingress-hop + retry overhead before prefill.
        ttft_s: Time to first token (NaN unless served).
        tpot_s: Time per output token (NaN unless served).
        e2e_s: Completion time (NaN unless served).
        layer_zero_s: (L,) zero-load Eq. 43 per-layer cost of the
            prefill macro-token (hops + service + colocation).
        layer_gw_wait_s: (L,) gateway queue wait per layer, final
            fixed-point iteration (None without probes).
        layer_ex_wait_s: (L,) worst expert-branch queue wait per layer,
            final fixed-point iteration (None without probes).
        batch_b: Mean effective decode batch occupancy (B_eff) over the
            request's decode span at its plan's gateway satellites —
            the per-request batch span of a continuous-batching run
            (NaN without batching probes or when no recorded bin falls
            inside the span).
    """

    rid: int
    station: int
    arrival_s: float
    prompt_len: int
    decode_len: int
    active: bool
    served: bool
    shed: bool
    retries: int
    ingress_s: float
    ttft_s: float
    tpot_s: float
    e2e_s: float
    layer_zero_s: np.ndarray
    layer_gw_wait_s: np.ndarray | None = None
    layer_ex_wait_s: np.ndarray | None = None
    batch_b: float = float("nan")

    @property
    def prefill_span(self) -> tuple[float, float]:
        """(start, end) of the prefill span — arrival to first token."""
        return self.arrival_s, self.arrival_s + self.ttft_s

    @property
    def decode_span(self) -> tuple[float, float]:
        """(start, end) of the decode span — first token to completion."""
        return self.arrival_s + self.ttft_s, self.arrival_s + self.e2e_s

    @property
    def queue_wait_s(self) -> float:
        """Total queueing seconds on the prefill critical path."""
        gw = 0.0 if self.layer_gw_wait_s is None \
            else float(self.layer_gw_wait_s.sum())
        ex = 0.0 if self.layer_ex_wait_s is None \
            else float(self.layer_ex_wait_s.sum())
        return gw + ex


@dataclasses.dataclass
class ControlEvent:
    """One control-plane instant (AIMD step, replan decision, ...)."""

    t_s: float
    kind: str                  # "aimd" | "replan" | "joint"
    name: str                  # short display label
    plan: str                  # plan/schedule name the event belongs to
    args: dict                 # numeric/string payload for the exporter


@dataclasses.dataclass
class FlightLog:
    """One run's full observability record, ready to export."""

    plan_names: list[str]
    plan: int                  # the plan row the request records follow
    dt_s: float
    n_bins: int
    requests: list[RequestRecord]
    events: list[ControlEvent]
    probes: ProbeRecord | None
    scenario: str = ""
    summary: dict | None = None     # the plan row's metrics.row() dict

    @property
    def horizon_s(self) -> float:
        """Simulated wall-clock span, seconds."""
        return self.n_bins * self.dt_s

    def served(self) -> list[RequestRecord]:
        """The served subset of the request records."""
        return [r for r in self.requests if r.served]


def aimd_events(probes: ProbeRecord, plan_names: list[str],
                sweep: int = 0) -> list[ControlEvent]:
    """AIMD admit-state changes between consecutive recorded bins.

    One event per (recorded bin, plan) with any per-gateway admit
    motion; the args carry the mean admit before/after, the tightest
    gateway after the step and the window-max qhat that drove it.
    """
    if probes is None or not probes.admission_on or probes.n_recorded < 2:
        return []
    admit = probes.admit[:, sweep]                    # (B, P, G)
    qhat = probes.qhat_s[:, sweep]                    # (B, P)
    t = probes.t_s
    events: list[ControlEvent] = []
    for b in range(1, admit.shape[0]):
        delta = admit[b] - admit[b - 1]               # (P, G)
        for p in np.nonzero(np.abs(delta).max(axis=1) > 0)[0]:
            mean_before = float(admit[b - 1, p].mean())
            mean_after = float(admit[b, p].mean())
            direction = "down" if mean_after < mean_before else "up"
            events.append(ControlEvent(
                t_s=float(t[b]), kind="aimd",
                name=f"aimd {direction}",
                plan=plan_names[int(p)],
                args={
                    "admit_mean_before": round(mean_before, 4),
                    "admit_mean_after": round(mean_after, 4),
                    "admit_min_after": round(float(admit[b, p].min()), 4),
                    "n_gateways_changed":
                        int((np.abs(delta[p]) > 0).sum()),
                    "qhat_s": round(float(qhat[b, p]), 4),
                }))
    return events


def replan_events(report: "ReplanReport",
                  slot_period_s: float) -> list[ControlEvent]:
    """The re-placement controller's decision trajectory as instants
    (every decision; switches carry their migration byte flow)."""
    if report is None:
        return []
    names = [getattr(c, "name", f"cand{i}")
             for i, c in enumerate(report.candidates)]
    events: list[ControlEvent] = []
    for d in report.decisions:
        label = "replan switch" if d.switched else "replan hold"
        events.append(ControlEvent(
            t_s=d.t_s(slot_period_s), kind="replan",
            name=label, plan=report.schedule.name,
            args={
                "boundary": int(d.boundary),
                "slot": int(d.slot),
                "chosen": names[int(d.chosen)],
                "switched": bool(d.switched),
                "migration_bytes": float(d.migration_bytes),
                "best_score_s": round(float(np.min(d.scores)), 6),
            }))
    return events


def joint_decision_events(report: "ReplanReport") -> list[ControlEvent]:
    """The joint control plane's decision-event channel as instants.

    Emitted only for reports carrying a
    :class:`~repro.obs.probes.DecisionTrace` (the fused grid path):
    one ``joint`` instant per decide boundary, with the full
    per-candidate score vector the on-device decide loop compared —
    the host controller's ``replan`` instants only carry the winner.
    """
    trace = getattr(report, "trace", None)
    if trace is None:
        return []
    names = [getattr(c, "name", f"cand{i}")
             for i, c in enumerate(report.candidates)]
    events: list[ControlEvent] = []
    t = trace.t_s
    for d in range(trace.n_decisions):
        switched = bool(trace.switched[d])
        events.append(ControlEvent(
            t_s=float(t[d]),
            kind="joint",
            name="joint switch" if switched else "joint decide",
            plan=report.schedule.name,
            args={
                "boundary": int(trace.boundaries[d]),
                "slot": int(trace.slots[d]),
                "chosen": names[int(trace.chosen[d])],
                "switched": switched,
                "migration_bytes": float(trace.migration_bytes[d]),
                "scores_s": [round(float(s), 6)
                             for s in trace.scores[d]],
            }))
    return events


def build_flight_log(
    sim: "FleetSim",
    result: "TrafficResult",
    plan: int | None = None,
    replan: "ReplanReport | None" = None,
    scenario: str = "",
    sweep: int = 0,
) -> FlightLog:
    """Assemble the flight log of one finished run.

    Args:
        sim: The simulator the run executed on (its construction tables
            and — when built with ``probes=`` — its ``last_probes``).
        result: The run's :class:`~repro.traffic.metrics.TrafficResult`.
        plan: Plan row the request records follow; ``None`` picks the
            last row (the replan schedule when one rode the sweep).
        replan: Optional controller report for the decision instants.
        scenario: Scenario name stamped into the log.
        sweep: Probe sweep entry to read (F axis; ``run`` has F = 1).

    Returns:
        The :class:`FlightLog` (requests, control events, probe ring).
    """
    p = (len(result.plans) - 1) if plan is None else int(plan)
    pt = result.plans[p]
    req = sim.requests
    probes = getattr(sim, "last_probes", None)
    # Per-request row into the simulator's per-plan tables.  A fused
    # joint-control outcome stitches the decided schedule row onto the
    # *probe* simulator's result, so the schedule row has no row of its
    # own there — its per-request values are gathers of the decided
    # candidate's row (the same identity run_replan_grid uses).
    n_sim_rows = np.asarray(sim.ingress_extra).shape[0]
    row_of_req = np.full(req.n_requests, p, dtype=np.int64)
    if p >= n_sim_rows:
        if replan is None:
            raise ValueError(
                f"plan row {p} not in the simulator ({n_sim_rows} rows) "
                "and no replan report to resolve it from")
        row_of_req = np.asarray(replan.schedule.slot_plan)[
            np.asarray(sim.slots)[:req.n_requests]]
    retries = pt.retries if pt.retries is not None \
        else np.zeros(req.n_requests, dtype=np.int64)
    shed = pt.shed if pt.shed is not None \
        else np.zeros(req.n_requests, dtype=bool)

    records: list[RequestRecord] = []
    batching_on = probes is not None and probes.batch_b is not None
    probe_t = probes.t_s if probes is not None else None
    for r in range(req.n_requests):
        pr = int(row_of_req[r])
        gw_wait = ex_wait = None
        if probes is not None and probes.gw_wait_s is not None:
            gw_wait = probes.gw_wait_s[sweep, pr, r]
            ex_wait = probes.ex_wait_s[sweep, pr, r]
        batch_b = float("nan")
        if batching_on and pt.served[r] and np.isfinite(pt.e2e_s[r]):
            # Per-request batch span: mean B_eff over the recorded bins
            # of the decode span, at the plan's gateway satellites for
            # the request's topology slot.
            lo = req.arrival_s[r] + pt.ttft_s[r]
            hi = req.arrival_s[r] + pt.e2e_s[r]
            m = (probe_t >= lo) & (probe_t <= hi)
            if m.any():
                sats = sim.gateways_slot[pr, sim.slots[r]]     # (L,)
                batch_b = float(
                    probes.batch_b[m][:, sweep, pr][:, sats].mean())
        records.append(RequestRecord(
            rid=r,
            station=int(req.station[r]),
            arrival_s=float(req.arrival_s[r]),
            prompt_len=int(req.prompt_len[r]),
            decode_len=int(req.decode_len[r]),
            active=bool(pt.active[r]),
            served=bool(pt.served[r]),
            shed=bool(shed[r]),
            retries=int(retries[r]),
            ingress_s=float(sim.ingress_extra[pr, r]),
            ttft_s=float(pt.ttft_s[r]),
            tpot_s=float(pt.tpot_s[r]),
            e2e_s=float(pt.e2e_s[r]),
            layer_zero_s=np.asarray(sim.eff_layer[pr, r]),
            layer_gw_wait_s=gw_wait,
            layer_ex_wait_s=ex_wait,
            batch_b=batch_b,
        ))

    names = [q.plan_name for q in result.plans]
    events = aimd_events(probes, names, sweep=sweep)
    if replan is not None:
        events += replan_events(replan, sim.qcfg.slot_period_s)
        events += joint_decision_events(replan)
    events.sort(key=lambda e: e.t_s)
    return FlightLog(plan_names=names, plan=p, dt_s=result.dt_s,
                     n_bins=result.n_bins, requests=records,
                     events=events, probes=probes, scenario=scenario,
                     summary=pt.row())


def eq43_breakdown(sim: "FleetSim", plan: int,
                   tokens: np.ndarray | None = None) -> dict:
    """Zero-load Eq. 43 term decomposition for a plan row's tokens.

    Re-reads the engine's own tables (:func:`repro.core.engine
    .eq43_layer_terms` — identical indexing to the jitted kernel) for
    ``d_out``/``t_exp``/``d_in``/``q`` per (token, layer, branch); the
    default token set is the R prefill macro-tokens.
    """
    from repro.core.engine import eq43_layer_terms
    svc = sim.service_model
    tokens = np.arange(sim.n_requests) if tokens is None \
        else np.asarray(tokens)
    kwargs = {}
    if svc.per_satellite:
        kwargs = dict(expert_sec=np.asarray(svc.expert_s()),
                      inv_speed=np.asarray(svc.inv_speed(sim.n_stations)))
    return eq43_layer_terms(
        sim.batch, plan, sim.slots[tokens],
        np.asarray(sim.draws)[:, tokens], t_gateway=sim.t_gateway,
        t_expert=sim.t_expert, **kwargs)


def summarize_timeseries(probes: ProbeRecord, n_windows: int = 12,
                         plan: int = 0, sweep: int = 0) -> list[dict]:
    """Windowed fleet-state aggregates from the probe ring — flat rows
    shaped for :func:`repro.traffic.metrics.format_table`.

    Args:
        probes: A probed run's :class:`~repro.obs.probes.ProbeRecord`.
        n_windows: Number of equal recorded-bin windows to aggregate.
        plan: Plan row to aggregate.
        sweep: Probe sweep entry (F axis).

    Returns:
        One dict per window: window start time, fleet-max/mean backlog,
        peak per-satellite utilization, dropped seconds and — under
        admission — min admit and max qhat.
    """
    if probes is None or probes.n_recorded == 0:
        return []
    b = probes.n_recorded
    n_windows = max(1, min(int(n_windows), b))
    edges = np.linspace(0, b, n_windows + 1).astype(int)
    rows: list[dict] = []
    for w in range(n_windows):
        lo, hi = edges[w], max(edges[w] + 1, edges[w + 1])
        backlog = probes.backlog_s[lo:hi, sweep, plan]       # (w, S)
        util = probes.util_s[lo:hi, sweep, plan] / probes.dt_s
        drops = probes.drops_s[lo:hi, sweep, plan]
        row = {
            "t_s": round(float(probes.t_s[lo]), 2),
            "backlog_max_s": round(float(backlog.max()), 4),
            "backlog_mean_s": round(float(backlog.mean()), 4),
            "util_max": round(float(util.max()), 4),
            "dropped_s": round(float(drops.sum()), 4),
        }
        if probes.admission_on:
            row["admit_min"] = round(
                float(probes.admit[lo:hi, sweep, plan].min()), 4)
            row["qhat_max_s"] = round(
                float(probes.qhat_s[lo:hi, sweep, plan].max()), 4)
        rows.append(row)
    return rows
