"""Schema of the exported Chrome/Perfetto trace JSON and its validator.

The exporter (:mod:`repro.obs.export`) writes the Trace Event Format's
JSON-object flavor: a ``traceEvents`` list plus a ``metadata`` object
stamped with :data:`SCHEMA_VERSION` and run provenance.  Perfetto and
``chrome://tracing`` both load it directly; :func:`validate_trace` is
the structural gate ``tools/check_trace.py`` runs in CI so a drifting
exporter cannot silently ship un-loadable traces.

Event phases used (and accepted) here:

===== ================================================================
``X`` complete span (request prefill/decode, per-layer hops) — needs
      a non-negative ``dur``
``C`` counter sample (per-satellite backlog/util/drops lanes) — needs
      numeric ``args``
``i`` instant (AIMD window change, replan switch, shed burst)
``M`` metadata (process/thread naming of the lanes)
===== ================================================================
"""
from __future__ import annotations

import numbers

#: Version stamped into ``metadata.schema_version`` by the exporter and
#: required (exactly) by the validator — bump on breaking layout changes.
SCHEMA_VERSION = 1

#: Accepted trace-event phases.
PHASES = ("X", "C", "i", "M")

#: Fields every event must carry.
REQUIRED_FIELDS = ("name", "ph", "pid", "ts")

#: ``metadata`` keys the exporter always writes.
REQUIRED_METADATA = ("schema_version", "generator", "dt_s", "plans")


def _problem(out: list[str], i: int, msg: str) -> None:
    out.append(f"traceEvents[{i}]: {msg}")


def validate_trace(obj) -> list[str]:
    """Structural check of one exported trace object.

    Args:
        obj: The parsed trace JSON (dict).

    Returns:
        A list of human-readable problems; empty means the trace
        conforms to this schema version.
    """
    problems: list[str] = []
    if not isinstance(obj, dict):
        return ["trace must be a JSON object (the Trace Event Format's "
                "object flavor)"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        problems.append("missing or non-list 'traceEvents'")
        events = []
    meta = obj.get("metadata")
    if not isinstance(meta, dict):
        problems.append("missing or non-object 'metadata'")
    else:
        for key in REQUIRED_METADATA:
            if key not in meta:
                problems.append(f"metadata missing {key!r}")
        ver = meta.get("schema_version")
        if ver is not None and ver != SCHEMA_VERSION:
            problems.append(f"metadata.schema_version {ver!r} != "
                            f"supported {SCHEMA_VERSION}")

    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            _problem(problems, i, "event is not an object")
            continue
        for field in REQUIRED_FIELDS:
            if field not in ev:
                _problem(problems, i, f"missing {field!r}")
        ph = ev.get("ph")
        if ph not in PHASES:
            _problem(problems, i, f"unknown phase {ph!r} (one of {PHASES})")
            continue
        ts = ev.get("ts")
        if not isinstance(ts, numbers.Real) or isinstance(ts, bool) \
                or ts < 0:
            _problem(problems, i, f"ts must be a number >= 0, got {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, numbers.Real) or isinstance(dur, bool) \
                    or dur < 0:
                _problem(problems, i,
                         f"'X' event needs numeric dur >= 0, got {dur!r}")
        if ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                _problem(problems, i, "'C' event needs non-empty args")
            else:
                for k, v in args.items():
                    if not isinstance(v, numbers.Real) \
                            or isinstance(v, bool):
                        _problem(problems, i,
                                 f"counter arg {k!r} is not numeric")
        if ph == "i" and ev.get("s", "t") not in ("g", "p", "t"):
            _problem(problems, i, f"instant scope {ev.get('s')!r} not in "
                                  "('g', 'p', 't')")
    return problems


def count_events(obj, name_prefix: str = "", ph: str | None = None) -> int:
    """Number of events whose name starts with ``name_prefix`` (and
    matches ``ph`` when given) — the acceptance checks' counting helper."""
    n = 0
    for ev in obj.get("traceEvents", []):
        if not isinstance(ev, dict):
            continue
        if ph is not None and ev.get("ph") != ph:
            continue
        if str(ev.get("name", "")).startswith(name_prefix):
            n += 1
    return n
