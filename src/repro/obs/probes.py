"""On-device probe ring buffers for the fused fleet fixed point.

The probes-on path of :func:`repro.traffic.queueing._fused_core` writes
preallocated, donated ring buffers via ``jax.lax.dynamic_update_slice``
from inside the backlog/admission scans — one write per time bin, into
the slot ``(bin // stride) % capacity`` (bins the stride skips write a
sentinel scratch slot, so the scan step stays branch-free and the
probes-on trace adds no control flow).  Only the peeled **final**
fixed-point iteration records — the converged schedule the reported
latencies come from — so a launch pays the ring-write cost once, not
once per iteration.

The flag is static (the ``service_model=None`` pattern): ``probes=None``
leaves the traced computation byte-identical to the probe-free kernel,
and the probed launch compiles as its own cache entry with the buffers
donated (donation is a TPU/GPU fast path; CPU declines it harmlessly).

Host side, :meth:`ProbeRecord.from_launch` unwraps the rings — the
slot -> bin mapping is recomputed deterministically (:func:`ring_bins`),
no device bookkeeping — and expands the compacted (plan, satellite)
queue rows back to the full fleet.
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: Ring-buffer channels recorded per (sweep entry, queue row) per bin.
ROW_CHANNELS = ("backlog", "util", "drops")
#: Fourth row channel, recorded only under continuous batching: the
#: per-(row, bin) effective decode batch occupancy B_eff.
BATCH_CHANNEL = "batch_b"
#: Extra channels recorded under AIMD admission.
ADMISSION_CHANNELS = ("qhat", "admit", "win")
#: Decision-event channel emitted by the joint control plane — one
#: entry per decide boundary of the fused replan walk.
DECISION_CHANNELS = ("scores", "chosen", "switched", "mig_bytes")


@dataclasses.dataclass
class DecisionTrace:
    """The joint controller's decision-event channel, host-unwrapped.

    One entry per decide boundary of one fused control launch (the
    replan walk of :meth:`repro.traffic.queueing.FleetSim
    .run_replan_grid`) — the device telemetry of the decide loop, not a
    host re-derivation, so an exported trace shows exactly what the
    launch chose.  D decisions, C candidates.

    Attributes:
        period_s: Wall-clock seconds per slot boundary.
        boundaries: (D,) boundary index k of each decision (t = k *
            ``period_s``).
        slots: (D,) topology slot entered at each boundary.
        scores: (D, C) backlog-inflated predicted cost per candidate.
        chosen: (D,) candidate index in effect after each boundary.
        switched: (D,) bool — the boundary changed the incumbent.
        migration_bytes: (D,) bytes the switch moved (0.0 on holds).
    """

    period_s: float
    boundaries: np.ndarray
    slots: np.ndarray
    scores: np.ndarray
    chosen: np.ndarray
    switched: np.ndarray
    migration_bytes: np.ndarray

    @property
    def n_decisions(self) -> int:
        """Decide boundaries recorded (D)."""
        return int(self.boundaries.size)

    @property
    def n_switches(self) -> int:
        """Boundaries whose decision changed the incumbent plan."""
        return int(self.switched.sum())

    @property
    def t_s(self) -> np.ndarray:
        """(D,) wall-clock seconds of each decision's boundary."""
        return self.boundaries.astype(np.float64) * self.period_s


@dataclasses.dataclass(frozen=True)
class ProbeConfig:
    """On-device telemetry probe parameters (static per launch).

    Attributes:
        capacity: Ring slots preallocated on device.  When the horizon
            has more recorded bins than slots the ring wraps and only
            the last ``capacity`` recorded bins survive.
        stride: Record every ``stride``-th time bin; ``None`` derives
            the smallest stride that makes one horizon fit the ring
            (``ceil(n_bins / capacity)``) — whole-run coverage at
            bounded device memory.
    """

    capacity: int = 256
    stride: int | None = None

    def __post_init__(self):
        """Validate the probe parameters."""
        if self.capacity < 1:
            raise ValueError("probe capacity must be >= 1")
        if self.stride is not None and self.stride < 1:
            raise ValueError("probe stride must be >= 1 (or None)")

    def resolve(self, n_bins: int) -> tuple[int, int]:
        """The static ``(capacity, stride)`` pair for an ``n_bins``-bin
        horizon (the hashable object the fused kernel keys its compile
        cache on)."""
        stride = self.stride if self.stride is not None \
            else max(1, -(-int(n_bins) // self.capacity))
        return int(self.capacity), int(stride)


def make_buffers(capacity: int, n_sweep: int, n_rows: int,
                 admit_shape: tuple[int, int] | None,
                 n_row_channels: int = len(ROW_CHANNELS)) -> dict:
    """Zeroed host-side ring buffers for one probed launch.

    One extra slot (index ``capacity``) is the sentinel scratch target
    for non-recorded bins.  No ``bin`` channel exists on device: the
    deterministic scan covers every bin in order, so the slot -> bin
    mapping is a pure function of ``(n_bins, capacity, stride)`` —
    :func:`ring_bins` recomputes it host-side for free.

    Args:
        capacity: Ring slots (the extra sentinel slot is added here).
        n_sweep: Leading sweep axis F of the launch.
        n_rows: Compacted (plan, satellite) queue-row count.
        admit_shape: ``(n_plans, n_gateways)`` to also allocate the AIMD
            channels; ``None`` for uncontrolled runs.
        n_row_channels: Row channels to allocate — ``len(ROW_CHANNELS)``
            normally, one more under continuous batching (the
            ``BATCH_CHANNEL`` occupancy plane rides the same write).

    Returns:
        Dict of numpy arrays, the donated pytree of the probed launch.
    """
    c1 = int(capacity) + 1
    # The row channels share one stacked buffer (axis 1 ordered as
    # ROW_CHANNELS [+ BATCH_CHANNEL]) so the scan step pays one ring
    # write for all of them; same for the two (F, P) AIMD channels
    # (axis 1 = qhat, win).
    bufs = {
        "rows": np.zeros((c1, int(n_row_channels), n_sweep, n_rows),
                         dtype=np.float32),
    }
    if admit_shape is not None:
        n_plans, n_gw = admit_shape
        bufs["aimd"] = np.zeros((c1, 2, n_sweep, n_plans),
                                dtype=np.float32)
        bufs["admit"] = np.zeros((c1, n_sweep, n_plans, n_gw),
                                 dtype=np.float32)
    return bufs


def ring_bins(n_bins: int, capacity: int,
              stride: int) -> tuple[np.ndarray, np.ndarray]:
    """(slots, bins) the ring holds after one full scan of ``n_bins``.

    The scan visits every bin in order and records each ``stride``-th
    one into slot ``(bin // stride) % capacity``, so slot ``s`` ends up
    holding the *last* recorded index congruent to ``s`` — no device
    bookkeeping needed.  Both arrays come back sorted by bin
    (ascending); ``slots`` indexes the ring axis of the raw buffers.
    """
    n_rec = -(-int(n_bins) // int(stride))         # recorded indices
    used = min(n_rec, int(capacity))
    slots = np.arange(used)
    k_last = slots + capacity * ((n_rec - 1 - slots) // capacity)
    bins = k_last * stride
    order = np.argsort(bins, kind="stable")
    return slots[order], bins[order]


@dataclasses.dataclass
class ProbeRecord:
    """One probed launch's telemetry, unwrapped to host arrays.

    B recorded bins (ascending), F sweep entries, P plans, S satellites,
    M engine tokens, L layers, G gateways.

    Attributes:
        dt_s: Seconds per time bin.
        capacity: Ring capacity the launch ran with.
        stride: Bin stride the launch recorded at.
        bins: (B,) recorded bin indices, ascending.
        backlog_s: (B, F, P, S) per-satellite queue backlog (seconds of
            work) at each recorded bin's start.
        util_s: (B, F, P, S) work deposited into the queue during the
            recorded bin (seconds; divide by ``dt_s`` for utilization).
        drops_s: (B, F, P, S) seconds of work beyond the buffer cap in
            the recorded bin (overflow pressure).
        qhat_s: (B, F, P) AIMD critical-path backlog estimate (gateway
            chain + per-layer worst expert); None without admission.
        admit: (B, F, P, G) per-gateway admit probability after the
            bin's control action; None without admission.
        win_s: (B, F, P) the controller's running window-max qhat;
            None without admission.
        gw_wait_s: (F, P, M, L) final-iteration gateway queue wait per
            token and layer (the queueing half of the Eq. 43 layer
            breakdown the flight recorder reports).
        ex_wait_s: (F, P, M, L) final-iteration worst expert-branch
            queue wait per token and layer.
        batch_b: (B, F, P, S) effective decode batch occupancy B_eff at
            each recorded bin (>= 1 wherever decode work landed); None
            unless the launch ran with continuous batching.
    """

    dt_s: float
    capacity: int
    stride: int
    bins: np.ndarray
    backlog_s: np.ndarray
    util_s: np.ndarray
    drops_s: np.ndarray
    qhat_s: np.ndarray | None = None
    admit: np.ndarray | None = None
    win_s: np.ndarray | None = None
    gw_wait_s: np.ndarray | None = None
    ex_wait_s: np.ndarray | None = None
    batch_b: np.ndarray | None = None

    @property
    def n_recorded(self) -> int:
        """Number of recorded bins that survived the ring (B)."""
        return int(self.bins.size)

    @property
    def t_s(self) -> np.ndarray:
        """(B,) wall-clock seconds of each recorded bin's start."""
        return self.bins.astype(np.float64) * self.dt_s

    @property
    def admission_on(self) -> bool:
        """True iff the AIMD channels were recorded."""
        return self.qhat_s is not None

    @classmethod
    def from_launch(cls, raw: dict, gw_wait: np.ndarray | None,
                    ex_wait: np.ndarray | None, dt_s: float,
                    capacity: int, stride: int, n_bins: int,
                    expand_rows) -> "ProbeRecord":
        """Unwrap one launch's ring buffers.

        Args:
            raw: The ``probes`` output pytree (host arrays, sentinel
                slot still attached).
            gw_wait: (F, P, M, L) final gateway waits (or None).
            ex_wait: (F, P, M, L) final expert waits (or None).
            dt_s: Seconds per bin.
            capacity: Ring capacity of the launch.
            stride: Recording stride of the launch.
            n_bins: Bin count T of the launch's horizon (fixes the
                slot -> bin mapping, see :func:`ring_bins`).
            expand_rows: ``FleetSim._expand_rows`` — scatters the
                compact-row last axis back to (..., P, S).
        """
        slots, bins = ring_bins(n_bins, capacity, stride)

        def unwrap(arr, expand):
            arr = np.asarray(arr)[slots]
            return expand_rows(arr) if expand else arr

        rows = {name: unwrap(raw["rows"][:, i], True)
                for i, name in enumerate(ROW_CHANNELS)}
        extra = {}
        # A fourth row channel means the launch ran under continuous
        # batching and recorded the B_eff occupancy plane.
        if np.asarray(raw["rows"]).shape[1] > len(ROW_CHANNELS):
            extra["batch_b"] = unwrap(
                raw["rows"][:, len(ROW_CHANNELS)], True)
        if "aimd" in raw:
            extra.update(qhat_s=unwrap(raw["aimd"][:, 0], False),
                         win_s=unwrap(raw["aimd"][:, 1], False),
                         admit=unwrap(raw["admit"], False))
        return cls(
            dt_s=float(dt_s), capacity=int(capacity), stride=int(stride),
            bins=bins,
            backlog_s=rows["backlog"],
            util_s=rows["util"],
            drops_s=rows["drops"],
            gw_wait_s=None if gw_wait is None else np.asarray(gw_wait),
            ex_wait_s=None if ex_wait is None else np.asarray(ex_wait),
            **extra)
