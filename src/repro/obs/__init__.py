"""repro.obs — flight recorder & telemetry for the fleet simulator.

Three layers (see ``docs/architecture.md`` § Observability):

* :mod:`.probes` — on-device probe ring buffers the fused fleet kernel
  writes via ``dynamic_update_slice`` under a *static* ``probes=`` flag
  (``None`` keeps the trace bit-identical to the probe-free kernel);
* :mod:`.recorder` — host-side request flight recorder + control-plane
  event assembly (:func:`build_flight_log`), and the
  :func:`summarize_timeseries` rows that feed
  :func:`repro.traffic.metrics.format_table`;
* :mod:`.export` / :mod:`.schema` — Chrome trace-event / Perfetto JSON
  exporter and the schema gate ``tools/check_trace.py`` runs in CI.

Typical use::

    sim = FleetSim(..., probes=ProbeConfig())
    res = sim.run()
    log = build_flight_log(sim, res, scenario="smoke")
    write_trace("out.json", log)          # open in ui.perfetto.dev
"""
from .export import chrome_trace, write_trace
from .probes import DecisionTrace, ProbeConfig, ProbeRecord, ring_bins
from .recorder import (ControlEvent, FlightLog, RequestRecord,
                       aimd_events, build_flight_log, eq43_breakdown,
                       joint_decision_events, replan_events,
                       summarize_timeseries)
from .schema import SCHEMA_VERSION, count_events, validate_trace

__all__ = [
    "DecisionTrace", "ProbeConfig", "ProbeRecord", "ring_bins",
    "ControlEvent", "FlightLog", "RequestRecord",
    "aimd_events", "build_flight_log", "eq43_breakdown",
    "joint_decision_events", "replan_events",
    "summarize_timeseries",
    "chrome_trace", "write_trace",
    "SCHEMA_VERSION", "count_events", "validate_trace",
]
