"""Pallas TPU kernels: moe_gmm (grouped expert matmul), decode_attn
(GQA flash-decode), deposit (fleet-sim scatter-add work binning).
ops.py = jit wrappers, ref.py = jnp oracles."""
from . import ops, ref

__all__ = ["ops", "ref"]
