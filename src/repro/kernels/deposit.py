"""Pallas TPU kernel: scatter-add work deposit (the fleet-sim hot bin).

The fused fleet simulator bins millions of chunked-prefill token
deposits into the dense ``(plans * stations, time-bins)`` work tensor on
every fixed-point iteration.  A scatter is MXU-hostile, so the kernel
uses the standard one-hot-matmul trick: for each block of chunks and
each output time-tile, build the (chunk, row) and (chunk, bin-in-tile)
one-hot matrices and accumulate ``onehot_rows.T @ (vals * onehot_bins)``
— a dense (bc, S) x (bc, bt) contraction the MXU eats, with the full
row axis resident in a VMEM scratch accumulator.

Tiling: grid (rows/br, T/bt, C/bc) with the chunk axis innermost, so a
VMEM scratch (br, bt) accumulates over chunk blocks and flushes once per
(row-tile, time-tile).  Chunks outside a tile contribute zero rows in
the one-hots (no masking pass needed), and chunk padding points at
column ``n_cols_pad`` which no tile covers.  The row tiling bounds VMEM
at ``br * bt`` regardless of the fleet size (the fused fleet simulator
deposits into F * rows planes that can reach tens of thousands of rows).

Off-TPU the one-hot matmul is hopeless (interpret mode runs the kernel
body in Python), so :func:`deposit_segments` offers the CPU/GPU scatter
relief: the same COO triples as a row-bucketed sorted ``segment_sum``,
bitwise identical to the :func:`repro.kernels.ref.deposit_ref` oracle
(see its docstring for when it actually pays).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _deposit_kernel(rows_ref, cols_ref, vals_ref, o_ref, acc_ref, *,
                    n_chunk_blocks: int):
    """One (row-tile, time-tile, chunk-block) grid step."""
    r = pl.program_id(0)
    t = pl.program_id(1)
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = rows_ref[0]                                   # (bc,) int32
    cols = cols_ref[0]
    vals = vals_ref[0]
    bc = rows.shape[0]
    br, bt = acc_ref.shape
    dtype = acc_ref.dtype
    # Chunks outside this (row, time) tile match no one-hot lane: zero
    # contribution, no separate masking pass.
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (bc, br), 1)
    oh_rows = ((rows[:, None] - r * br) == iota_r).astype(dtype)
    iota_t = jax.lax.broadcasted_iota(jnp.int32, (bc, bt), 1)
    oh_cols = ((cols[:, None] - t * bt) == iota_t).astype(dtype)
    acc_ref[...] += jnp.dot(oh_rows.T, vals[:, None] * oh_cols,
                            preferred_element_type=dtype)

    @pl.when(c == n_chunk_blocks - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(x: jnp.ndarray, mult: int, fill) -> jnp.ndarray:
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full(pad, fill, dtype=x.dtype)])


@functools.partial(
    jax.jit, static_argnames=("n_rows", "n_cols", "bucketed"))
def deposit_segments(
    rows: jnp.ndarray,            # (C,) int, in [0, n_rows)
    cols: jnp.ndarray,            # (C,) int, in [0, n_cols)
    vals: jnp.ndarray,            # (C,) float
    n_rows: int,
    n_cols: int,
    bucketed: bool = True,
) -> jnp.ndarray:
    """Row-bucketed segment-sum deposit — the non-TPU scatter relief.

    Off-TPU the fleet simulator's hot bin is a bare
    ``zeros.at[flat].add(vals)`` — a serial scatter on XLA:CPU whose
    per-update random access hurts once the target ids shuffle.  This
    path instead presents the same deposit as a sorted
    :func:`jax.ops.segment_sum`, which XLA handles with the
    sorted-segment reduction (~3x the scatter's throughput once the ids
    are sorted).  Measured head-to-head by ``bench_fleet``'s
    ``deposit_stage``: it wins on mid-size shuffled tables, while the
    fleet's statically row-grouped chunk table keeps the inline scatter
    cache-friendly enough that this stays the opt-in
    ``deposit_impl="segments"`` rather than the default.

    The sort is the whole battle: a two-operand (key, payload) sort —
    ``argsort`` or ``sort_key_val`` — costs ~8x a single-operand key
    sort on XLA:CPU and would eat the relief.  So with ``bucketed=True``
    the chunk index is **packed into the low bits of the flat id**
    (``flat << ceil(log2(C)) | i``) and one single-operand int64 sort
    yields both the sorted segment ids (high bits) and the gather order
    (low bits).  The packing doubles as a stability guarantee: ties in
    the flat id sort by original chunk position, so per-(row, bin)
    deposits apply in table order.  Because XLA scatter/segment
    additions into one accumulator apply in update order, the result is
    **bitwise identical** to :func:`deposit_ref` (pinned by
    ``tests/test_fleet_perf.py``), which is what lets the fused fleet
    trace stay bit-identical when this path replaces the inline scatter.
    On worlds so large that ``n_rows * n_cols * C`` overflows the packed
    int64, the path degrades to a stable two-operand sort.

    Returns (n_rows, n_cols) in vals.dtype.
    """
    if rows.shape != cols.shape or rows.shape != vals.shape:
        raise ValueError(
            f"shape mismatch {rows.shape} / {cols.shape} / {vals.shape}")
    n_flat = n_rows * n_cols
    idx = jnp.int32 if n_flat <= jnp.iinfo(jnp.int32).max else jnp.int64
    flat = rows.astype(idx) * n_cols + cols.astype(idx)
    if n_flat > jnp.iinfo(flat.dtype).max:
        raise ValueError(
            f"deposit target {n_rows}x{n_cols} overflows {flat.dtype} "
            "flat indices (enable jax x64)")
    n = rows.shape[0]
    shift = max(1, int(n - 1).bit_length())
    if bucketed and n > 0 and n_flat <= (1 << (63 - shift)):
        packed = jnp.sort((flat.astype(jnp.int64) << shift)
                          | jnp.arange(n, dtype=jnp.int64))
        ids = packed >> shift
        vals = vals[packed & ((1 << shift) - 1)]
        flat = ids.astype(idx)
    elif bucketed:
        order = jnp.argsort(flat, stable=True)
        flat, vals = flat[order], vals[order]
    out = jax.ops.segment_sum(vals, flat, num_segments=n_flat,
                              indices_are_sorted=bucketed)
    return out.reshape(n_rows, n_cols)


@functools.partial(
    jax.jit,
    static_argnames=("n_rows", "n_cols", "block_r", "block_c", "block_t",
                     "interpret"),
)
def deposit(
    rows: jnp.ndarray,            # (C,) int, in [0, n_rows)
    cols: jnp.ndarray,            # (C,) int, in [0, n_cols)
    vals: jnp.ndarray,            # (C,) float
    n_rows: int,
    n_cols: int,
    block_r: int = 512,
    block_c: int = 512,
    block_t: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Dense scatter-add: out[rows[i], cols[i]] += vals[i].

    Returns (n_rows, n_cols) in vals.dtype.
    """
    if rows.shape != cols.shape or rows.shape != vals.shape:
        raise ValueError(
            f"shape mismatch {rows.shape} / {cols.shape} / {vals.shape}")
    if rows.shape[0] == 0:
        # Zero chunk blocks would leave the output buffer unwritten.
        return jnp.zeros((n_rows, n_cols), dtype=vals.dtype)
    br = min(block_r, n_rows)
    n_rows_pad = -(-n_rows // br) * br
    bt = min(block_t, n_cols)
    n_cols_pad = -(-n_cols // bt) * bt
    bc = min(block_c, max(8, rows.shape[0]))
    # Padding chunks target column n_cols_pad (outside every tile) with
    # zero weight, so they deposit nothing.
    rows_p = _pad_to(rows.astype(jnp.int32), bc, 0)
    cols_p = _pad_to(cols.astype(jnp.int32), bc, n_cols_pad)
    vals_p = _pad_to(vals, bc, 0)
    n_blocks = rows_p.shape[0] // bc
    grid = (n_rows_pad // br, n_cols_pad // bt, n_blocks)

    out = pl.pallas_call(
        functools.partial(_deposit_kernel, n_chunk_blocks=n_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc), lambda r, t, c: (c, 0)),
            pl.BlockSpec((1, bc), lambda r, t, c: (c, 0)),
            pl.BlockSpec((1, bc), lambda r, t, c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((br, bt), lambda r, t, c: (r, t)),
        out_shape=jax.ShapeDtypeStruct((n_rows_pad, n_cols_pad),
                                       vals.dtype),
        scratch_shapes=[pltpu.VMEM((br, bt), vals.dtype)],
        interpret=interpret,
    )(rows_p.reshape(n_blocks, bc), cols_p.reshape(n_blocks, bc),
      vals_p.reshape(n_blocks, bc))
    return out[:n_rows, :n_cols]
