"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in this
CPU container (Pallas interpret mode executes the kernel body in Python);
on TPU the compiled Mosaic kernels run.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .decode_attn import decode_attention as _decode_attention
from .deposit import deposit as _deposit
from .deposit import deposit_segments as _deposit_segments
from .moe_gmm import gmm as _gmm


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def timed_call(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    """Best-of-``iters`` blocked wall time of ``fn(*args)``, seconds.

    The measurement primitive behind ``repro.core.calibration`` and the
    kernel benchmarks: warmup calls absorb compilation, then each timed
    call blocks on the result so async dispatch cannot hide the work.
    """
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def gmm(x: jnp.ndarray, w: jnp.ndarray, *, block_c: int = 128,
        block_n: int = 128, block_k: int = 512,
        interpret: bool | None = None) -> jnp.ndarray:
    """Grouped expert matmul (E,C,K)x(E,K,N)->(E,C,N)."""
    if interpret is None:
        interpret = not on_tpu()
    return _gmm(x, w, block_c=block_c, block_n=block_n, block_k=block_k,
                interpret=interpret)


def expert_ffn_pallas(params: dict, xs: jnp.ndarray, compute_dtype,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Drop-in replacement for ``repro.models.moe.expert_ffn`` using gmm."""
    xs = xs.astype(compute_dtype)
    wg = params["w_gate"].astype(compute_dtype)
    wu = params["w_up"].astype(compute_dtype)
    wd = params["w_down"].astype(compute_dtype)
    gate = jax.nn.silu(gmm(xs, wg, interpret=interpret))
    up = gmm(xs, wu, interpret=interpret)
    return gmm(gate * up, wd, interpret=interpret)


def deposit(rows: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
            n_rows: int, n_cols: int, *, block_r: int = 512,
            block_c: int = 512, block_t: int = 256,
            interpret: bool | None = None) -> jnp.ndarray:
    """Scatter-add work deposit: (n_rows, n_cols) dense from COO triples."""
    if interpret is None:
        interpret = not on_tpu()
    return _deposit(rows, cols, vals, n_rows, n_cols, block_r=block_r,
                    block_c=block_c, block_t=block_t, interpret=interpret)


def deposit_segments(rows: jnp.ndarray, cols: jnp.ndarray,
                     vals: jnp.ndarray, n_rows: int, n_cols: int, *,
                     bucketed: bool = True) -> jnp.ndarray:
    """Row-bucketed segment-sum deposit (non-TPU scatter relief).

    Bitwise identical to ``repro.kernels.ref.deposit_ref``; the fused
    fleet simulator's opt-in off-TPU deposit (``deposit_impl="segments"``,
    timed against the inline scatter by ``bench_fleet``).
    """
    return _deposit_segments(rows, cols, vals, n_rows, n_cols,
                             bucketed=bucketed)


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     pos: jnp.ndarray, *, block_s: int = 512,
                     interpret: bool | None = None) -> jnp.ndarray:
    """GQA flash-decode over a KV cache: (B,Hkv,G,hd) out."""
    if interpret is None:
        interpret = not on_tpu()
    return _decode_attention(q, k, v, pos, block_s=block_s,
                             interpret=interpret)
