"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def deposit_ref(rows: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
                n_rows: int, n_cols: int) -> jnp.ndarray:
    """Scatter-add oracle: out[rows[i], cols[i]] += vals[i].

    The jnp oracle the Pallas kernel must match exactly (the fused
    fleet simulator's off-TPU deposits use the same flat-index
    scatter-add inline).
    """
    idx = jnp.int32 if n_rows * n_cols <= jnp.iinfo(jnp.int32).max \
        else jnp.int64
    flat = rows.astype(idx) * n_cols + cols.astype(idx)
    if n_rows * n_cols > jnp.iinfo(flat.dtype).max:
        raise ValueError(
            f"deposit target {n_rows}x{n_cols} overflows {flat.dtype} "
            "flat indices (enable jax x64)")
    out = jnp.zeros(n_rows * n_cols, dtype=vals.dtype).at[flat].add(vals)
    return out.reshape(n_rows, n_cols)


def gmm_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (E, C, K), w: (E, K, N) -> (E, C, N), f32 accumulation."""
    out = jnp.einsum("eck,ekn->ecn", x, w,
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def decode_attention_ref(
    q: jnp.ndarray,     # (B, Hkv, G, hd)
    k: jnp.ndarray,     # (B, Hkv, S, hd)
    v: jnp.ndarray,
    pos: jnp.ndarray,   # (B,)
) -> jnp.ndarray:
    hd = q.shape[-1]
    s = k.shape[2]
    sco = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32),
                     k.astype(jnp.float32)) * hd**-0.5
    mask = jnp.arange(s)[None, None, None, :] <= pos[:, None, None, None]
    sco = jnp.where(mask, sco, -1e30)
    p = jax.nn.softmax(sco, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
