"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gmm_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (E, C, K), w: (E, K, N) -> (E, C, N), f32 accumulation."""
    out = jnp.einsum("eck,ekn->ecn", x, w,
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def decode_attention_ref(
    q: jnp.ndarray,     # (B, Hkv, G, hd)
    k: jnp.ndarray,     # (B, Hkv, S, hd)
    v: jnp.ndarray,
    pos: jnp.ndarray,   # (B,)
) -> jnp.ndarray:
    hd = q.shape[-1]
    s = k.shape[2]
    sco = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32),
                     k.astype(jnp.float32)) * hd**-0.5
    mask = jnp.arange(s)[None, None, None, :] <= pos[:, None, None, None]
    sco = jnp.where(mask, sco, -1e30)
    p = jax.nn.softmax(sco, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
