"""Pallas TPU kernel: GQA flash-decode attention over a KV cache.

One new token per sequence attends over its cache row (paper Sec. III-B,
the gateway satellite's per-token self-attention).  Inputs:

    q:   (B, Hkv, G, hd)   query heads grouped under their KV head
    k/v: (B, Hkv, S, hd)   cache (dense layout, padded to S)
    pos: (B,) int32        current position; kv index > pos is masked

Grid (B, Hkv, S/bs) with the KV-length dimension innermost: VMEM scratch
carries the online-softmax state (m, l, acc) across KV blocks, so HBM
traffic is exactly one pass over the cache — the kernel is HBM-bandwidth
bound as decode attention should be.  ``pos`` rides scalar prefetch (SMEM)
since the mask needs it before the block loop starts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_attn_kernel(
    pos_ref,                      # scalar-prefetch: (B,) int32 in SMEM
    q_ref, k_ref, v_ref,          # VMEM blocks
    o_ref,
    m_ref, l_ref, acc_ref,        # VMEM scratch
    *, block_s: int, n_s: int, scale: float,
):
    b = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (bs, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    sco = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                      # (G, bs)

    kv_idx = s * block_s + jax.lax.broadcasted_iota(jnp.int32, sco.shape, 1)
    mask = kv_idx <= pos_ref[b]
    sco = jnp.where(mask, sco, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, sco.max(axis=1, keepdims=True))   # (G,1)
    p = jnp.exp(sco - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _flush():
        o_ref[...] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)[None, None]


def _pad_axis(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(
    q: jnp.ndarray,       # (B, Hkv, G, hd)
    k: jnp.ndarray,       # (B, Hkv, S, hd)
    v: jnp.ndarray,
    pos: jnp.ndarray,     # (B,) int32
    block_s: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns (B, Hkv, G, hd) attention output in q.dtype."""
    b, hkv, g, hd = q.shape
    s = k.shape[2]
    scale = hd ** -0.5

    gp = max(8, g)                       # sublane-align the query group
    qp = _pad_axis(q, 2, gp)
    bs = min(block_s, s)
    kp = _pad_axis(k, 2, bs)
    vp = _pad_axis(v, 2, bs)
    sp = kp.shape[2]
    n_s = sp // bs
    # Padded KV rows are masked because kv_idx > pos always holds there
    # (pos < S <= padded index).

    grid = (b, hkv, n_s)
    out = pl.pallas_call(
        functools.partial(
            _decode_attn_kernel, block_s=bs, n_s=n_s, scale=scale
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, gp, hd), lambda b_, h, s_, pos_ref: (b_, h, 0, 0)),
                pl.BlockSpec((1, 1, bs, hd), lambda b_, h, s_, pos_ref: (b_, h, s_, 0)),
                pl.BlockSpec((1, 1, bs, hd), lambda b_, h, s_, pos_ref: (b_, h, s_, 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, gp, hd), lambda b_, h, s_, pos_ref: (b_, h, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((gp, 1), jnp.float32),
                pltpu.VMEM((gp, 1), jnp.float32),
                pltpu.VMEM((gp, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, gp, hd), q.dtype),
        interpret=interpret,
    )(pos.astype(jnp.int32), qp, kp, vp)
    return out[:, :, :g, :]
