"""Pallas TPU kernel: grouped expert matmul (the MoE FFN hot loop).

Computes ``out[e] = x[e] @ w[e]`` for capacity-padded expert buckets
x: (E, C, K), w: (E, K, N) -> (E, C, N) — the TPU adaptation of the
paper's per-satellite ``FFN_i`` execution (Sec. III-C): after dispatch,
each expert's bucket is a dense matmul perfectly shaped for the MXU.

Tiling: grid (E, C/bc, N/bn, K/bk), K innermost so a VMEM f32 scratch
accumulates partial products; blocks are MXU-aligned (multiples of
8 x 128 for bf16 inputs, 128 x 128 preferred).  HBM->VMEM traffic per
grid step is bc*bk + bk*bn (+ bc*bn once), so arithmetic intensity is
controlled by the block sizes, not the bucket size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    """One (expert, row-tile, col-tile, k-tile) grid step."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0], w_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)[None]


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("block_c", "block_n", "block_k", "interpret"),
)
def gmm(
    x: jnp.ndarray,           # (E, C, K)
    w: jnp.ndarray,           # (E, K, N)
    block_c: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Grouped matmul via pallas_call.  Returns (E, C, N) in x.dtype."""
    e, c, kdim = x.shape
    if w.shape[0] != e or w.shape[1] != kdim:
        raise ValueError(f"shape mismatch {x.shape} @ {w.shape}")
    n = w.shape[2]

    bc = min(block_c, max(8, c))
    bn = min(block_n, max(128, min(n, 128)))
    bk = min(block_k, kdim)
    xp = _pad_to(_pad_to(x, 1, bc), 2, bk)
    wp = _pad_to(_pad_to(w, 1, bk), 2, bn)
    cp, kp = xp.shape[1], xp.shape[2]
    np_ = wp.shape[2]
    grid = (e, cp // bc, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(_gmm_kernel, n_k=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bk), lambda e_, i, j, k_: (e_, i, k_)),
            pl.BlockSpec((1, bk, bn), lambda e_, i, j, k_: (e_, k_, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bn), lambda e_, i, j, k_: (e_, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, cp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp)
    return out[:, :c, :n]
