"""Loop-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` over 88 layers reports one layer's FLOPs.  This module walks
the post-optimization (SPMD-partitioned, per-device) HLO text, recovers
every while-loop's trip count from the constant in its condition
computation, and multiplies body costs accordingly:

  flops        from dot/convolution shapes (2*M*N*K semantics, XLA-style)
  bytes        operand+result bytes at fusion boundaries (inner fused
               instructions are register-level, as XLA accounts them)
  collectives  per-op result bytes x ring-cost factor x loop multiplier

Validation: with all multipliers forced to 1 the walker reproduces
``cost_analysis()`` FLOPs within a few percent (tests/test_hlo_analysis.py);
with real multipliers it is exact at depth, which raw cost_analysis is not.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?(%[\w\.\-]+)\s*\((.*)\)\s*->\s*(.*)\{\s*$")
_ATTR_REF_RE = re.compile(
    r"(?:condition|body|calls|to_apply)=\{?((?:%[\w\.\-]+(?:,\s*)?)+)\}?"
)
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DOT_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9,\s]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([0-9,]+)\]<=\[")
_OPERAND_RE = re.compile(r"%[\w\.\-]+")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_NO_BYTES_OPS = frozenset({
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "call", "conditional", "after-all", "partition-id",
    "replica-id", "iota", "copy-start", "copy-done",
})


def _shapes(text: str) -> list[tuple[str, tuple[int, ...]]]:
    return [
        (dt, tuple(int(d) for d in dims.split(",") if d))
        for dt, dims in _SHAPE_RE.findall(text)
    ]


def _nbytes_of(shapes) -> int:
    total = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instruction:
    name: str
    op: str
    line: str
    result_shapes: list
    operand_names: list
    refs: list


@dataclasses.dataclass
class Computation:
    name: str
    instructions: list
    symbols: dict          # %name -> result_shapes
    root: "Instruction | None" = None
    param_order: list = dataclasses.field(default_factory=list)


def _split_op(rhs: str) -> tuple[str, str] | None:
    """rhs after '=': returns (op, operand_text)."""
    s = rhs.strip()
    if s.startswith("("):               # tuple result type
        depth = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    s = s[i + 1:].strip()
                    break
    else:                                # array/token type then op
        sp = s.find(" ")
        if sp < 0:
            return None
        s = s[sp + 1:].strip()
    par = s.find("(")
    if par <= 0:
        return None
    op = s[:par].strip()
    if not re.fullmatch(r"[a-z][\w\-\.]*", op):
        return None
    depth, start, body = 0, par + 1, ""
    for i in range(par, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                body = s[start:i]
                break
    return op, body


def parse_hlo(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hm = _HEADER_RE.match(line)
        if hm:
            name = hm.group(2).lstrip("%")
            cur = Computation(name=name, instructions=[], symbols={})
            comps[name] = cur
            # header params carry types: "p0: f32[4,64], p1: s32[]"
            for part in hm.group(3).split(","):
                if ":" in part:
                    pname, ptype = part.split(":", 1)
                    key = pname.strip().lstrip("%")
                    cur.symbols[key] = _shapes(ptype)
                    cur.param_order.append(key)
            if hm.group(1):
                entry = name
            continue
        stripped = line.strip()
        if stripped == "}":
            cur = None
            continue
        is_root = stripped.startswith("ROOT ")
        if is_root:
            stripped = stripped[5:]
        if cur is None or " = " not in stripped or not stripped.startswith("%"):
            continue
        lhs, rhs = stripped.split(" = ", 1)
        name = lhs.strip().lstrip("%")
        so = _split_op(rhs)
        if so is None:
            continue
        op, body = so
        result_shapes = _shapes(rhs[: rhs.find(f" {op}(") + 1]
                                if f" {op}(" in rhs else rhs.split(op + "(")[0])
        # attrs AFTER the operand parens (avoid matching operand names)
        after = rhs[rhs.find(body) + len(body):] if body else rhs
        refs = []
        for rm in _ATTR_REF_RE.finditer(after):
            refs += [r.strip().lstrip("%") for r in rm.group(1).split(",") if r.strip()]
        operand_names = [o.lstrip("%") for o in _OPERAND_RE.findall(body)]
        ins = Instruction(name=name, op=op, line=stripped,
                          result_shapes=result_shapes,
                          operand_names=operand_names, refs=refs)
        cur.instructions.append(ins)
        cur.symbols[name] = result_shapes
        if is_root:
            cur.root = ins
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


def _trip_count(comps, cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = []
    for ins in cond.instructions:
        consts += [int(c) for c in _CONST_RE.findall(ins.line)]
    return max(consts) if consts else 1


def computation_multipliers(comps, entry: str) -> dict[str, float]:
    """multiplier[comp] = times the computation runs per entry call."""
    mult: dict[str, float] = {}

    def visit(name: str, m: float):
        if name not in comps or m == 0:
            return
        mult[name] = mult.get(name, 0.0) + m
        for ins in comps[name].instructions:
            if ins.op == "while":
                cm = re.search(r"condition=(%[\w\.\-]+)", ins.line)
                bm = re.search(r"body=(%[\w\.\-]+)", ins.line)
                cond = cm.group(1).lstrip("%") if cm else None
                body = bm.group(1).lstrip("%") if bm else None
                trips = _trip_count(comps, cond) if cond else 1
                if body:
                    visit(body, m * trips)
            else:
                for r in ins.refs:
                    visit(r, m)

    visit(entry, 1.0)
    return mult


def _dot_flops(ins: Instruction, symbols: dict) -> float:
    res = 1
    if ins.result_shapes:
        for d in ins.result_shapes[0][1]:
            res *= d
    k = 1
    cd = _DOT_CDIMS_RE.search(ins.line)
    if cd and ins.operand_names:
        lhs_shapes = symbols.get(ins.operand_names[0]) or []
        if lhs_shapes:
            lhs = lhs_shapes[0][1]
            for i in (int(x) for x in cd.group(1).split(",") if x):
                if i < len(lhs):
                    k *= lhs[i]
    return 2.0 * res * k


def _conv_flops(ins: Instruction, symbols: dict) -> float:
    out = 1
    if ins.result_shapes:
        for d in ins.result_shapes[0][1]:
            out *= d
    ker = 1
    if len(ins.operand_names) > 1:
        ks = symbols.get(ins.operand_names[1]) or []
        if ks:
            och = 1
            for d in ks[0][1]:
                ker *= d
    return 2.0 * out * ker


_RING_COST = {
    "all-gather": lambda r, g: r * (g - 1) / max(g, 1),
    "reduce-scatter": lambda r, g: r * (g - 1),
    "all-reduce": lambda r, g: 2 * r * (g - 1) / max(g, 1),
    "all-to-all": lambda r, g: r * (g - 1) / max(g, 1),
    "collective-permute": lambda r, g: r,
}


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        body = m.group(1).strip()
        return len(body.split(",")) if body else 1
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return [int(x) for x in m.group(1).split(",")][-1]
    return default


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0       # ring-cost, per chip
    collective_counts: dict = dataclasses.field(default_factory=dict)
    loop_multiplied: bool = True

    def asdict(self):
        return dataclasses.asdict(self)


_SLICE_OPS = frozenset({"dynamic-slice", "slice", "gather"})


def _operand_bytes(comp: Computation, name: str) -> float:
    return _nbytes_of(comp.symbols.get(name) or [])


def _instruction_bytes(comp: Computation, ins: Instruction) -> float:
    """XLA HloCostAnalysis-style bytes for one boundary instruction."""
    if ins.op in _NO_BYTES_OPS:
        return 0.0
    if ins.op in _SLICE_OPS:
        # read the slice, write the slice — not the whole operand
        return 2.0 * _nbytes_of(ins.result_shapes)
    if ins.op == "dynamic-update-slice":
        upd = (_operand_bytes(comp, ins.operand_names[1])
               if len(ins.operand_names) > 1 else 0.0)
        return 2.0 * upd            # read update, write region (aliased base)
    if ins.op == "scatter":
        upd = (_operand_bytes(comp, ins.operand_names[2])
               if len(ins.operand_names) > 2 else 0.0)
        return 2.0 * upd
    b = _nbytes_of(ins.result_shapes)
    for o in ins.operand_names:
        b += _operand_bytes(comp, o)
    return b


def _fusion_bytes(comp: Computation, ins: Instruction,
                  comps: dict[str, Computation]) -> float:
    """Fusion boundary: params consumed only through slices count as slice
    bytes; a dynamic-update-slice root writes the update size (aliased)."""
    callee = comps.get(ins.refs[0]) if ins.refs else None
    if callee is None:
        return _instruction_bytes(comp, ins)
    # per-param consumption inside the fused computation.  The effective
    # root follows convert/bitcast wrappers: XLA:CPU sometimes types an
    # in-place DUS accumulator round-trip through f32 (convert-DUS-convert)
    # that XLA:TPU fuses in place — we charge the TPU (slice-sized) cost.
    root = callee.root
    by_name = {i.name: i for i in callee.instructions}
    while root is not None and root.op in ("convert", "bitcast", "copy") \
            and root.operand_names:
        root = by_name.get(root.operand_names[0])
    result_bytes = _nbytes_of(ins.result_shapes)
    dus_root = root is not None and root.op == "dynamic-update-slice"
    param_cost: dict[str, float] = {}
    for p in callee.param_order:
        uses = [i for i in callee.instructions if p in i.operand_names]
        full = _nbytes_of(callee.symbols.get(p) or [])
        if uses and all(u.op in _SLICE_OPS and u.operand_names
                        and u.operand_names[0] == p for u in uses):
            param_cost[p] = sum(_nbytes_of(u.result_shapes) for u in uses)
        elif dus_root and full == result_bytes:
            # the in-place accumulator feeding a DUS root (possibly through
            # a bitcast chain): aliased, not streamed through HBM
            param_cost[p] = 0.0
        else:
            param_cost[p] = full
    total = 0.0
    for i, o in enumerate(ins.operand_names):
        if i < len(callee.param_order):
            total += param_cost[callee.param_order[i]]
        else:
            total += _operand_bytes(comp, o)
    if dus_root and len(root.operand_names) > 1:
        total += 2.0 * _nbytes_of(callee.symbols.get(root.operand_names[1])
                                  or [])
    else:
        total += result_bytes
    return total


def analyze(hlo_text: str, n_devices: int,
            apply_multipliers: bool = True) -> HloCost:
    comps, entry = parse_hlo(hlo_text)
    mults = computation_multipliers(comps, entry)
    # computations called by fusion ops: their interiors are registers
    fused_comps: set[str] = set()
    for comp in comps.values():
        for ins in comp.instructions:
            if ins.op == "fusion":
                fused_comps.update(ins.refs)
    cost = HloCost(loop_multiplied=apply_multipliers)
    for cname, comp in comps.items():
        if cname not in mults:
            continue
        m = mults[cname] if apply_multipliers else 1.0
        fused = cname in fused_comps
        for ins in comp.instructions:
            if ins.op == "dot":
                cost.flops += m * _dot_flops(ins, comp.symbols)
            elif ins.op == "convolution":
                cost.flops += m * _conv_flops(ins, comp.symbols)
            if not fused:
                if ins.op == "fusion":
                    cost.bytes_accessed += m * _fusion_bytes(comp, ins, comps)
                else:
                    cost.bytes_accessed += m * _instruction_bytes(comp, ins)
            base = ins.op.replace("-start", "")
            if base in _RING_COST and not ins.op.endswith("-done"):
                r = _nbytes_of(ins.result_shapes)
                g = _group_size(ins.line, n_devices)
                cost.collective_bytes += m * _RING_COST[base](r, g)
                cost.collective_counts[base] = \
                    cost.collective_counts.get(base, 0) + max(int(m), 1)
    return cost
