"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS for 512 host
devices *before* any jax import; tests and benches see the real device
count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``.

    Axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
    Batch shards over ("pod", "data"); tensor/expert parallelism over
    "model" (see repro.distributed.sharding).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_"
            "count=512 before importing jax"
        )
    return jax.make_mesh(shape, axes, devices=devices[:n])


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_host_mesh():
    """Whatever this host offers (smoke/example runs): 1 device -> (1, 1)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
