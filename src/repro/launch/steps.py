"""Step functions + ShapeDtypeStruct input specs for lowering.

``input_specs(cfg, shape)`` produces weak-type-correct, shardable
stand-ins for every model input (no device allocation): train batches,
prefill prompts, or (cache, token, pos) decode triples — the same pattern
the multi-pod dry-run lowers with.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.models import (ModelConfig, Parallel, batch_specs, decode_step,
                          init_cache, init_params, loss_fn, prefill)
from repro.optim import AdamWConfig, adamw_init, adamw_update


# --------------------------------------------------------------------- #
# Step functions (pure; jit/lower at the call site)
# --------------------------------------------------------------------- #


def make_train_step(cfg: ModelConfig, par: Parallel,
                    opt_cfg: AdamWConfig = AdamWConfig(), schedule=None,
                    micro_batches: int = 1):
    """One optimizer step; with ``micro_batches > 1`` the global batch is
    processed as a ``lax.scan`` of gradient-accumulation slices, so live
    activation memory (incl. per-layer saved residuals) scales with the
    micro-batch, not the global batch."""
    schedule = schedule or (lambda s: 1.0)
    grad_fn = jax.value_and_grad(
        functools.partial(loss_fn, cfg, par=par), has_aux=True
    )

    def train_step(params, opt_state, batch):
        if micro_batches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                if b % micro_batches:
                    raise ValueError(
                        f"batch {b} not divisible by {micro_batches} slices")
                return x.reshape(micro_batches, b // micro_batches,
                                 *x.shape[1:])

            mb = jax.tree.map(split, batch)
            if par.mesh is not None:
                # keep the batch sharded over the data axes after the
                # (global, ...) -> (micro, global/micro, ...) reshape —
                # without this XLA may replicate the microbatch slices.
                from jax.sharding import PartitionSpec as P
                baxes = (par.data_axes if len(par.data_axes) > 1
                         else par.data_axes[0])
                mb = jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(
                        x, P(None, baxes, *([None] * (x.ndim - 2)))
                    ),
                    mb,
                )

            def acc_step(grads, mb_batch):
                (l, m), g = grad_fn(params, mb_batch)
                grads = jax.tree.map(jnp.add, grads, g)
                return grads, (l, m["ce"], m["aux"])

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            grads, (ls, ces, auxs) = jax.lax.scan(acc_step, zeros, mb)
            grads = jax.tree.map(lambda g: g / micro_batches, grads)
            loss = ls.mean()
            metrics = {"ce": ces.mean(), "aux": auxs.mean()}
        params, opt_state, gnorm = adamw_update(
            opt_cfg, params, grads, opt_state, schedule(opt_state["count"])
        )
        out_metrics = {
            "loss": loss, "ce": metrics["ce"], "aux": metrics["aux"],
            "grad_norm": gnorm,
        }
        return params, opt_state, out_metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, par: Parallel, max_len: int):
    def prefill_step(params, batch):
        return prefill(cfg, params, batch, max_len=max_len, par=par)

    return prefill_step


def make_serve_step(cfg: ModelConfig, par: Parallel):
    """One decode step: greedy next token + updated cache.

    ``embeds`` is positional (pjit forbids kwargs with in_shardings); pass
    None for token-input archs.
    """

    def serve_step(params, cache, tokens, pos, embeds):
        logits, cache = decode_step(cfg, params, cache, tokens, pos, par=par,
                                    embeds=embeds)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, cache

    return serve_step


# --------------------------------------------------------------------- #
# ShapeDtypeStruct stand-ins
# --------------------------------------------------------------------- #


def param_structs(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0)
    )


def opt_structs(params_structs):
    return jax.eval_shape(adamw_init, params_structs)


def cache_structs(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, batch, max_len)
    )


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    """All lowering inputs for one (arch x shape) cell, as structs.

    train:   {params, opt_state, batch}
    prefill: {params, batch}            (batch without labels)
    decode:  {params, cache, tokens, pos [, embeds]}
    """
    p = param_structs(cfg)
    if shape.kind == "train":
        return {
            "params": p,
            "opt_state": opt_structs(p),
            "batch": batch_specs(cfg, shape.global_batch, shape.seq_len),
        }
    if shape.kind == "prefill":
        b = batch_specs(cfg, shape.global_batch, shape.seq_len)
        b.pop("labels")
        return {"params": p, "batch": b}
    if shape.kind == "decode":
        out = {
            "params": p,
            "cache": cache_structs(cfg, shape.global_batch, shape.seq_len),
            "pos": jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32),
        }
        if cfg.frontend == "audio":
            out["tokens"] = None
            out["embeds"] = jax.ShapeDtypeStruct(
                (shape.global_batch, 1, cfg.d_model), jnp.bfloat16
            )
        else:
            out["tokens"] = jax.ShapeDtypeStruct(
                (shape.global_batch, 1), jnp.int32
            )
        return out
    raise ValueError(shape.kind)
