import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import (jax locks the device count on first
# init).  The dry-run — and ONLY the dry-run — sees 512 placeholder
# devices so the production meshes can be built on this 1-CPU container.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent without real
hardware:  ``jax.jit(step).lower(**input_specs).compile()`` must succeed on
the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh; the compiled
artifact yields memory_analysis (fits?), cost_analysis (FLOPs/bytes for
the roofline) and the HLO collective schedule (collective bytes).

Usage:
    python -m repro.launch.dryrun --arch deepseek-moe-16b --shape decode_32k
    python -m repro.launch.dryrun --all --mesh both
    python -m repro.launch.dryrun --all --mesh pod --archs-file cells.txt

Results are cached as JSON under experiments/dryrun/ (one file per cell);
--force recompiles.
"""
import argparse
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding

from repro.compat import cost_analysis
from repro.configs import ASSIGNED, REGISTRY, SHAPES, get_config, shape_applies
from repro.distributed.sharding import ShardingRules
from repro.launch import hlo_analysis
from repro.launch import roofline as rl
from repro.launch.mesh import data_axes, make_production_mesh
from repro.launch.steps import (input_specs, make_prefill_step,
                                make_serve_step, make_train_step)
from repro.models import Parallel

OUT_DIR_DEFAULT = "experiments/dryrun"


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec),
    )


def _mem_dict(mem) -> dict:
    fields = ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes")
    return {f: int(getattr(mem, f, -1)) for f in fields}


def _cost_dict(compiled) -> dict:
    cost = cost_analysis(compiled)
    return {k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float)) and not k.startswith("utilization")}


def _parse_overrides(pairs) -> dict:
    out = {}
    for kv in pairs or []:
        k, v = kv.split("=", 1)
        if v in ("true", "True"):
            out[k] = True
        elif v in ("false", "False"):
            out[k] = False
        else:
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = v
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None):
    """Build the jitted step for one cell and lower it.  Returns
    (lowered, mesh, n_devices, cfg, shape)."""
    import dataclasses
    cfg = get_config(arch)
    overrides = dict(overrides or {})
    zero1 = overrides.pop("zero1", False)    # sharding-level, not ModelConfig
    micro = overrides.pop("micro", 1)        # gradient-accumulation slices
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    daxes = data_axes(mesh)
    par = Parallel(mesh=mesh, data_axes=daxes)
    rules = ShardingRules(cfg, mesh, data_axes=daxes, zero_opt=zero1)
    specs = input_specs(cfg, shape)

    p_sh = _named(mesh, rules.param_specs(specs["params"]))
    with mesh:
        if shape.kind == "train":
            step = make_train_step(cfg, par, micro_batches=micro)
            o_sh = _named(mesh, rules.opt_state_specs(specs["opt_state"],
                                                      rules.param_specs(specs["params"])))
            b_sh = _named(mesh, rules.batch_spec(specs["batch"]))
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(specs["params"], specs["opt_state"],
                                   specs["batch"])
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, par, max_len=shape.seq_len)
            b_sh = _named(mesh, rules.batch_spec(specs["batch"]))
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(specs["params"], specs["batch"])
        else:  # decode
            step = make_serve_step(cfg, par)
            c_sh = _named(mesh, rules.cache_specs(specs["cache"]))
            tok_sh = (None if specs["tokens"] is None
                      else _named(mesh, rules.batch_spec(specs["tokens"])))
            embeds = specs.get("embeds")
            emb_sh = (None if embeds is None
                      else _named(mesh, rules.batch_spec(embeds)))
            args = [specs["params"], specs["cache"], specs["tokens"],
                    specs["pos"], embeds]
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, c_sh, tok_sh, None, emb_sh),
                out_shardings=(None, None, c_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(*args)
    return lowered, mesh, cfg, shape


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             force: bool = False, overrides: dict | None = None,
             tag: str = "") -> dict:
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}"
    if tag:
        cell_id += f"__{tag}"
    path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applies(cfg, shape)
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "overrides": overrides or {}, "tag": tag,
    }
    if not ok:
        record["status"] = "skip"
        record["reason"] = reason
        _write(path, record)
        return record

    t0 = time.time()
    try:
        lowered, mesh, cfg, shape = lower_cell(arch, shape_name, multi_pod,
                                               overrides)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        cost = _cost_dict(compiled)
        mem = _mem_dict(compiled.memory_analysis())
        hlo = compiled.as_text()
        # loop-aware walk: multiplies scan-body costs by trip counts, which
        # raw cost_analysis does not (see hlo_analysis.py docstring)
        hcost = hlo_analysis.analyze(hlo, mesh.size)
        roof = rl.derive_from_hlo_cost(hcost, mesh.size,
                                       rl.model_flops(cfg, shape))
        record.update({
            "status": "ok",
            "n_devices": mesh.size,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "raw_cost_analysis": cost,
            "memory_analysis": mem,
            "hlo_cost": hcost.asdict(),
            "roofline": roof.asdict(),
        })
        print(f"[OK] {cell_id}: dominant={roof.dominant} "
              f"compute={roof.compute_s:.4f}s memory={roof.memory_s:.4f}s "
              f"collective={roof.collective_s:.4f}s "
              f"frac={roof.roofline_fraction:.3f} "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
    except Exception as e:  # a failure here is a bug in the system
        record["status"] = "fail"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {cell_id}: {type(e).__name__}: {e}")
    _write(path, record)
    return record


def calibrate_hook(record: dict) -> None:
    """Fold a compiled cell's roofline terms into the arch's committed
    service-time calibration table (repro.core.calibration), if one
    exists.  The attached per-chip FLOPs/bytes cross-check the table's
    analytic energy accounting against the real compiled HLO."""
    if record.get("status") != "ok" or "roofline" not in record:
        return
    from repro.core import calibration as cal
    name = record["arch"]
    try:
        table = cal.load_table(name)
    except FileNotFoundError:
        print(f"[calibrate] no committed service table for {name}; run "
              "benchmarks/bench_calibration.py --refresh first")
        return
    table = cal.attach_dryrun(table, record)
    path = cal.save_table(table)
    print(f"[calibrate] attached {record['shape']} roofline to {path}")


def _write(path: str, record: dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(record, f, indent=1)
    os.replace(tmp, path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true",
                    help="every assigned (arch x shape) cell")
    ap.add_argument("--include-paper-model", action="store_true")
    ap.add_argument("--out", default=OUT_DIR_DEFAULT)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--set", nargs="*", default=[], dest="overrides",
                    help="ModelConfig overrides k=v (perf variants)")
    ap.add_argument("--tag", default="", help="artifact suffix for variants")
    ap.add_argument("--calibrate", action="store_true",
                    help="attach each OK cell's roofline terms to the "
                         "arch's committed service-time calibration table")
    args = ap.parse_args()
    overrides = _parse_overrides(args.overrides)

    archs = list(ASSIGNED)
    if args.include_paper_model:
        archs = list(REGISTRY)
    if args.arch:
        archs = [args.arch]
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    if not (args.all or args.arch):
        ap.error("pass --all or --arch")

    n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                rec = run_cell(arch, shape_name, multi_pod, args.out,
                               force=args.force, overrides=overrides,
                               tag=args.tag)
                if args.calibrate:
                    calibrate_hook(rec)
                n_fail += rec.get("status") == "fail"
    print(f"done; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
