"""Training driver: data pipeline -> jitted train step -> checkpoints.

Runs real steps on whatever mesh the host offers (1 CPU device here; the
same code path drives the production mesh on TPU — the dry-run proves
those shardings compile).  Fault tolerance: checkpoint/resume is exercised
by ``--simulate-failure N`` which kills the process mid-run; re-launching
with the same --ckpt-dir resumes exactly (the data pipeline is a pure
function of step, so no batches are skipped or repeated).

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, smoke_config
from repro.data import DataConfig, SyntheticTokens, make_batch
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import Parallel, init_params
from repro.optim import AdamWConfig, adamw_init, cosine_schedule, wsd_schedule


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd"])
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--simulate-failure", type=int, default=0,
                    help="hard-exit after N steps (fault-tolerance demo)")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh()
    par = Parallel(mesh=None)           # host run: single-shard math
    sched = (cosine_schedule if args.schedule == "cosine" else wsd_schedule)(
        args.warmup, args.steps
    )
    opt_cfg = AdamWConfig(lr=args.lr)
    step_fn = jax.jit(make_train_step(cfg, par, opt_cfg, sched),
                      donate_argnums=(0, 1))

    data = SyntheticTokens(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed,
    ))

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = adamw_init(params)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    start_step = 0

    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        restored = mgr.restore_latest({"params": params, "opt": opt_state})
        if restored is not None:
            start_step, tree = restored
            params = jax.tree.map(jnp.asarray, tree["params"])
            opt_state = jax.tree.map(jnp.asarray, tree["opt"])
            print(f"[resume] from step {start_step}")

    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.batch}x{args.seq} tokens/step, mesh={dict(mesh.shape)}")
    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in make_batch(cfg, data, step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            tok_s = (step - start_step + 1) * args.batch * args.seq / max(dt, 1e-9)
            print(f"step {step:5d}  loss {losses[-1]:.4f}  "
                  f"ce {float(metrics['ce']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  tok/s {tok_s:,.0f}")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state})
        if args.simulate_failure and step + 1 - start_step >= args.simulate_failure:
            print(f"[failure-sim] hard exit at step {step + 1}")
            import os
            os._exit(42)
    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt_state})
    return {"final_loss": losses[-1] if losses else float("nan"),
            "losses": losses, "n_params": n_params}


if __name__ == "__main__":
    out = main()
    print(f"[done] final loss {out['final_loss']:.4f}")
