"""Serving driver: batched autoregressive decode with SpaceMoE placement.

The paper's kind is inference, so this is the headline end-to-end driver:

  1. calibrate: run a forward pass collecting per-layer expert-selection
     counts (the paper's activation statistics, Eq. 14 plug-in);
  2. plan: Theorem-1 expert->device placement per MoE layer on the EP
     ring (repro.core.device_placement), applied as a zero-cost weight
     permutation (repro.models.moe.apply_placement);
  3. serve: prefill a batch of prompts, decode N tokens per request with
     the jitted serve step; report tokens/s;
  4. account: expected dispatch-cost reduction vs identity placement, and
     the full space-network latency of the same token stream under the
     paper's constellation — SpaceMoE vs RandIntra-CG in one batched
     ``evaluate_plans`` sweep (``--traffic <scenario>`` upgrades this to
     the request-level fleet simulation of ``repro.traffic`` and prints
     the SLO table; ``--admission aimd --ttft-target T`` swaps the
     static KV cap for the latency-target admission controller with
     gateway retry);
  5. (optional) elastic: fail a device, re-plan, report migration bytes.

    PYTHONPATH=src python -m repro.launch.serve --arch llama-moe-3.5b \
        --smoke --batch 4 --prompt-len 32 --decode-tokens 16
    PYTHONPATH=src python -m repro.launch.serve --arch llama-moe-3.5b \
        --smoke --traffic smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        TorusSpec, evaluate_plans, expected_dispatch_cost,
                        identity_plan, plan_expert_devices,
                        rand_intra_cg_plan, sample_topology,
                        simulate_token_generation_legacy, spacemoe_plan)
from repro.distributed import migration, replan_on_failure
from repro.launch.steps import make_serve_step
from repro.models import (Parallel, forward, init_params, prefill,
                          random_batch)
from repro.models.moe import apply_placement


def calibrate_router_stats(cfg, params, batch) -> np.ndarray | None:
    """(n_scan_units, E) expert-selection counts from one forward pass."""
    if not cfg.has_moe:
        return None
    _, _, counts = forward(cfg, params, batch, return_router_stats=True)
    return np.asarray(counts)


def plan_and_apply_placement(cfg, params, counts: np.ndarray,
                             ep_ring: int = 16):
    """Per-unit Theorem-1 device placement, applied to the expert stacks."""
    e = cfg.n_experts
    ring = TorusSpec(shape=(min(ep_ring, e),), wrap=True)
    plans, costs = [], {"theorem1": 0.0, "identity": 0.0}
    perms = []
    for u in range(counts.shape[0]):
        w = counts[u] + 1e-3
        plan = plan_expert_devices(w, cfg.top_k, ring,
                                   bytes_per_token=2.0 * cfg.d_model)
        base = identity_plan(e, ring, bytes_per_token=2.0 * cfg.d_model)
        costs["theorem1"] += expected_dispatch_cost(plan, w, cfg.top_k)
        costs["identity"] += expected_dispatch_cost(base, w, cfg.top_k)
        plans.append(plan)
        perms.append(plan.expert_perm)
    perms = np.stack(perms)                      # (U, E)

    units = params["units"]

    def permute_stacked(ffn):
        router = jnp.stack([ffn["router"][u][:, perms[u]]
                            for u in range(perms.shape[0])])
        out = dict(ffn, router=router)
        for k in ("w_gate", "w_up", "w_down"):
            out[k] = jnp.stack([ffn[k][u][perms[u]]
                                for u in range(perms.shape[0])])
        return out

    new_units = dict(units)
    for bname, bparams in units.items():
        if isinstance(bparams, dict) and "ffn" in bparams \
                and "router" in bparams["ffn"]:
            nb = dict(bparams)
            nb["ffn"] = permute_stacked(bparams["ffn"])
            new_units[bname] = nb
    params = dict(params, units=new_units)
    return params, plans, costs


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-moe-3.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-placement", action="store_true",
                    help="A/B: skip the Theorem-1 placement")
    ap.add_argument("--space-sim", action="store_true",
                    help="also simulate the constellation latency")
    ap.add_argument("--traffic", default=None, metavar="SCENARIO",
                    help="request-level fleet simulation under a named "
                         "repro.traffic scenario (implies --space-sim)")
    ap.add_argument("--admission", default=None,
                    choices=["static", "aimd", "pid"],
                    help="admission policy for --traffic: 'static' forces "
                         "the KV-slot cap (--kv-slots), 'aimd' switches to "
                         "the latency-target controller with gateway retry, "
                         "'pid' swaps in the PID cell on the same qhat "
                         "signal")
    ap.add_argument("--ttft-target", type=float, default=30.0,
                    help="TTFT target (s) the aimd admission controller "
                         "defends (with --admission aimd)")
    ap.add_argument("--kv-slots", type=int, default=8,
                    help="static KV-slot budget applied with "
                         "--admission static (0 = uncapped)")
    ap.add_argument("--replan", default=None,
                    choices=["off", "periodic", "backlog"],
                    help="continuous re-placement for --traffic: 'off' "
                         "holds the plans for the whole horizon, "
                         "'periodic' re-ranks the candidate pool every "
                         "topology slot, 'backlog' additionally inflates "
                         "scores with the live per-satellite backlog "
                         "(adds a replan/<mode> row to the table)")
    ap.add_argument("--ctrl", default="host", choices=["host", "fused"],
                    help="controller implementation for --replan "
                         "scenarios: 'host' walks the decide law round "
                         "by round, 'fused' runs the joint "
                         "replan+admission decide loop in one device "
                         "launch (same decisions; the exported trace "
                         "gains the joint decision-event channel)")
    ap.add_argument("--rate-scale", type=float, default=1.0,
                    help="multiply the --traffic scenario's arrival "
                         "rates (overload knob for admission/replan "
                         "demos and the CI trace smoke)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="with --traffic: run the fleet simulation with "
                         "on-device probes and export the flight "
                         "recorder as Chrome/Perfetto trace-event JSON "
                         "(open at ui.perfetto.dev); also prints the "
                         "windowed fleet-telemetry table")
    ap.add_argument("--batching", type=int, default=0, metavar="B_MAX",
                    help="with --traffic: continuous decode batching in "
                         "the fleet queues — satellites drain decode "
                         "steps in batches of up to B_MAX per time bin "
                         "at the service model's batch rate (0 = off, "
                         "the bit-identical FIFO kernel)")
    ap.add_argument("--federation", type=int, default=0, metavar="K",
                    help="with --traffic: additionally serve the scenario "
                         "over a K-member constellation federation in one "
                         "fused launch; admission-shed requests overflow "
                         "to the next-best member (needs --admission "
                         "aimd/pid for overflow; reports the pooled "
                         "federation row plus one row per member)")
    ap.add_argument("--fail-device", type=int, default=-1,
                    help="elastic demo: fail this EP device and re-plan")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    par = Parallel(mesh=None)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    out: dict = {"arch": cfg.name}

    # ---- 1-2: calibrate + place ---------------------------------------
    counts = None
    if cfg.has_moe:
        calib = random_batch(cfg, args.batch, args.prompt_len, seed=7)
        counts = calibrate_router_stats(cfg, params, calib)
        if not args.no_placement:
            params, plans, costs = plan_and_apply_placement(cfg, params, counts)
            red = (1 - costs["theorem1"] / costs["identity"]) * 100 \
                if costs["identity"] else 0.0
            out["dispatch_cost"] = costs
            print(f"[placement] expected dispatch cost: theorem1="
                  f"{costs['theorem1']*1e6:.1f}us identity="
                  f"{costs['identity']*1e6:.1f}us  (-{red:.1f}%)")
            if args.fail_device >= 0:
                w = counts.sum(axis=0) + 1e-3
                ring = TorusSpec(shape=(min(16, cfg.n_experts),), wrap=True)
                plan0 = plan_expert_devices(w, cfg.top_k, ring)
                plan1, survivors = replan_on_failure(
                    w, cfg.top_k, ring, {args.fail_device})
                bytes_per_expert = 3 * cfg.d_model * cfg.d_ff_expert * 2
                mig = migration(plan0, plan1, bytes_per_expert, survivors)
                out["migration_bytes"] = mig.bytes_moved
                print(f"[elastic] device {args.fail_device} failed: "
                      f"{len(mig.moved_experts)} experts move, "
                      f"{mig.bytes_moved/1e6:.1f} MB")

    # ---- 3: serve ------------------------------------------------------
    batch = random_batch(cfg, args.batch, args.prompt_len, seed=args.seed)
    prompt = {k: v for k, v in batch.items() if k != "labels"}
    max_len = args.prompt_len + args.decode_tokens + 1
    logits, cache = prefill(cfg, params, prompt, max_len=max_len, par=par)
    serve_step = jax.jit(make_serve_step(cfg, par), donate_argnums=(1,))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    pos = jnp.full((args.batch,), args.prompt_len, jnp.int32)
    emb = (jnp.ones((args.batch, 1, cfg.d_model), jnp.float32)
           if cfg.frontend == "audio" else None)
    generated = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(args.decode_tokens):
        tok, logits, cache = serve_step(params, cache, tok, pos, emb)
        pos = pos + 1
        generated.append(np.asarray(tok))
    jax.block_until_ready(logits)
    dt = time.time() - t0
    toks = args.batch * args.decode_tokens
    out["tokens_per_s"] = toks / dt
    gen = np.concatenate(generated, axis=1)
    assert np.isfinite(np.asarray(logits)).all()
    print(f"[serve] {toks} tokens in {dt:.2f}s -> {out['tokens_per_s']:.1f} tok/s "
          f"(host mesh; see dry-run for production-mesh compilation)")

    # ---- 4: space-network latency accounting ---------------------------
    if (args.space_sim or args.traffic) and cfg.has_moe:
        ccfg = ConstellationConfig.scaled(12, 16, n_slots=20)
        con = Constellation(ccfg)
        rng = np.random.default_rng(1)
        topo = sample_topology(con, LinkConfig(token_dim=cfg.d_model), rng)
        n_layers = counts.shape[0]
        activ = ActivationModel.from_router_counts(counts, cfg.top_k)
        wl = MoEWorkload(
            d_model=cfg.d_model, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
            d_ff_expert=cfg.d_ff_expert, n_experts=cfg.n_experts,
            top_k=cfg.top_k, vocab_size=cfg.vocab_size,
        )
        comp = ComputeConfig()
        sweep = [
            spacemoe_plan(con, topo, activ, wl, comp),
            rand_intra_cg_plan(ccfg, n_layers, cfg.n_experts,
                               np.random.default_rng(3)),
        ]
        # One batched sweep; both plans share the rng(2) token stream,
        # exactly what the legacy per-plan path consumed.
        sm, cg = evaluate_plans(sweep, topo, activ, wl, comp,
                                np.random.default_rng(2), n_tokens=200)
        if args.smoke:
            for plan, res in zip(sweep, (sm, cg)):
                ref = simulate_token_generation_legacy(
                    plan, topo, activ, wl, comp, np.random.default_rng(2),
                    n_tokens=200)
                assert abs(res.mean_s - ref.mean_s) / ref.mean_s < 1e-5, \
                    f"engine/legacy divergence for {plan.name}"
        out["space_latency_s"] = {"SpaceMoE": sm.mean_s,
                                  "RandIntra-CG": cg.mean_s}
        print(f"[space-sim] s/token: SpaceMoE={sm.mean_s:.3f} "
              f"RandIntra-CG={cg.mean_s:.3f} "
              f"({cg.mean_s/sm.mean_s:.2f}x reduction)")

        if args.traffic:
            import dataclasses

            from repro.traffic import (AdmissionConfig, ReplanConfig,
                                       build_ground_segment, format_table,
                                       get_scenario, run_scenario)
            sc = get_scenario(args.traffic)
            if args.replan is not None:
                # Re-placement needs slot boundaries inside the horizon;
                # keep the scenario's own period when it pins one.
                sc = dataclasses.replace(
                    sc,
                    replan=(None if args.replan == "off"
                            else ReplanConfig(mode=args.replan)),
                    slot_period_s=sc.slot_period_s or 60.0)
            if args.admission in ("aimd", "pid"):
                sc = dataclasses.replace(
                    sc, kv_slots=0,
                    admission=AdmissionConfig(
                        policy=args.admission,
                        ttft_target_s=args.ttft_target),
                    slo=dataclasses.replace(sc.slo,
                                            ttft_s=args.ttft_target))
            elif args.admission == "static":
                sc = dataclasses.replace(sc, admission=None,
                                         kv_slots=args.kv_slots)
            if args.smoke:
                horizon = min(sc.horizon_s, 60.0)
                sc = dataclasses.replace(
                    sc, horizon_s=horizon, tail_s=60.0,
                    failure_at_s=(horizon / 2.0
                                  if sc.failure_at_s is not None else None))
            ground = build_ground_segment(
                con, LinkConfig(token_dim=cfg.d_model),
                min_elevation_deg=10.0)
            sim_kwargs = {}
            fused_replan = args.ctrl == "fused" and sc.replan is not None
            if args.trace:
                if fused_replan:
                    # The control launch records no probe rings (the
                    # decide loop owns the device pass); the exported
                    # trace carries the request spans plus the joint
                    # decision-event channel instead.
                    print("[trace] fused controller: probe rings off, "
                          "joint decision channel on")
                else:
                    from repro.obs import ProbeConfig
                    sim_kwargs["probes"] = ProbeConfig()
            if args.batching > 0:
                from repro.traffic import BatchingConfig
                sim_kwargs["batching"] = BatchingConfig(b_max=args.batching)
            res = run_scenario(sc, sweep, topo, activ, wl, comp,
                               np.random.default_rng(4), ground=ground,
                               constellation=con,
                               rate_scale=args.rate_scale, ctrl=args.ctrl,
                               **sim_kwargs)
            rows = res.result.table(sc.slo, scenario=sc.name)
            if res.post_failure is not None:
                rows += res.post_failure.table(
                    sc.slo, scenario=f"{sc.name}(post)")
            print(format_table(rows, prefix="[traffic] "))
            out["traffic"] = rows
            for tag, rep in (("replan", res.replan),
                             ("replan(post)", res.post_replan)):
                if rep is None:
                    continue
                print(f"[{tag}] {rep.schedule.name}: "
                      f"{rep.n_switches} switch(es), "
                      f"{rep.total_migration_bytes/1e6:.1f} MB migrated "
                      f"over {len(rep.decisions)} decision(s)")
                out[tag] = {"switches": rep.n_switches,
                            "migration_bytes": rep.total_migration_bytes}
            if args.federation > 0:
                from repro.traffic import FederationConfig, make_federation
                from repro.traffic import queueing as _queueing
                fed_sc = dataclasses.replace(sc, replan=None)
                fed = make_federation(
                    fed_sc, args.federation, ccfg, wl, comp,
                    np.random.default_rng(6),
                    fed_cfg=FederationConfig(
                        overflow=fed_sc.admission is not None),
                    rate_scale=args.rate_scale, n_layers=n_layers,
                    n_experts=cfg.n_experts, top_k=cfg.top_k)
                t_before = _queueing.FUSED_TRACE_COUNT
                fres = fed.run()
                frow = fres.federated.row(fed_sc.slo)
                frows = [{"scenario": f"{sc.name}(fed)", **frow}]
                for k, mem in enumerate(fres.members):
                    mrow = mem.plans[fed.serve_plan].row(fed_sc.slo)
                    mrow["plan"] = f"member{k}/{mrow['plan']}"
                    frows.append({"scenario": f"{sc.name}(fed)", **mrow})
                print(format_table(frows, prefix="[federation] "))
                print(f"[federation] K={args.federation} members, "
                      f"{fres.n_rounds} overflow round(s), "
                      f"{int((fres.hops > 0).sum())} request(s) "
                      f"re-routed, "
                      f"{_queueing.FUSED_TRACE_COUNT - t_before} "
                      f"trace(s)")
                out["federation"] = {
                    "rows": frows, "n_rounds": fres.n_rounds,
                    "n_rerouted": int((fres.hops > 0).sum()),
                }
            if args.trace:
                from repro.obs import (build_flight_log,
                                       summarize_timeseries, write_trace)
                log = build_flight_log(res.sim, res.result,
                                       replan=res.replan,
                                       scenario=sc.name)
                trace = write_trace(args.trace, log)
                tw = summarize_timeseries(res.sim.last_probes,
                                          plan=log.plan)
                if tw:
                    print(format_table(tw, prefix="[telemetry] "))
                print(f"[trace] {len(trace['traceEvents'])} events "
                      f"({len(log.requests)} requests, "
                      f"{len(log.events)} control instants) -> "
                      f"{args.trace}")
                out["trace"] = {"path": args.trace,
                                "n_events": len(trace["traceEvents"]),
                                "n_control_events": len(log.events)}
    return out


if __name__ == "__main__":
    main()
