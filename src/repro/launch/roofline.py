"""Roofline term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs_per_chip / 197e12          (bf16 MXU peak)
    memory     = HLO_bytes_per_chip / 819e9           (HBM bandwidth)
    collective = collective_bytes_per_chip / 50e9     (per-link ICI)

``cost_analysis()`` supplies per-chip FLOPs/bytes (the compiled module is
the per-device SPMD program).  Collective bytes are NOT in cost_analysis —
they are parsed from the compiled HLO text with ring-algorithm per-chip
costs:  all-gather R*(g-1)/g, reduce-scatter R*(g-1), all-reduce
2*R*(g-1)/g, all-to-all R*(g-1)/g, collective-permute R   (R = result
bytes, g = replica-group size).

MODEL_FLOPS uses 6*N_active*tokens (train) / 2*N_active*tokens (inference)
plus the exact attention term; the ratio MODEL_FLOPS / (HLO_FLOPs * chips)
exposes remat/causal-overcount waste.
"""
from __future__ import annotations

import dataclasses
import re

from repro.configs.shapes import ShapeSpec
from repro.models.config import ModelConfig

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9,\s]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([0-9,]+)\]<=\[")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        body = m.group(1).strip()
        return len(body.split(",")) if body else 1
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        dims = [int(x) for x in m.group(1).split(",")]
        return dims[-1]
    return default


def _result_bytes(line: str, op: str) -> int:
    """Sum of result-type shape bytes (everything left of the op token)."""
    head = line.split(f" {op}(")[0]
    if "=" in head:
        head = head.split("=", 1)[1]
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_per_chip: float
    total_result_bytes: float

    def asdict(self):
        return {"counts": self.counts, "bytes_per_chip": self.bytes_per_chip,
                "total_result_bytes": self.total_result_bytes}


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    counts: dict[str, int] = {}
    per_chip = 0.0
    total = 0.0
    for line in hlo_text.splitlines():
        for op in _COLLECTIVES:
            token = f" {op}("
            start_token = f" {op}-start("
            if start_token in line:
                use = op + "-start"
            elif token in line:
                use = op
            else:
                continue
            if f"{op}-done" in line:
                continue
            r = _result_bytes(line, use)
            g = _group_size(line, n_devices)
            if op == "all-gather":
                cost = r * (g - 1) / max(g, 1)
            elif op == "reduce-scatter":
                cost = r * (g - 1)
            elif op == "all-reduce":
                cost = 2 * r * (g - 1) / max(g, 1)
            elif op == "all-to-all":
                cost = r * (g - 1) / max(g, 1)
            else:                      # collective-permute
                cost = r
            counts[op] = counts.get(op, 0) + 1
            per_chip += cost
            total += r
            break
    return CollectiveStats(counts=counts, bytes_per_chip=per_chip,
                           total_result_bytes=total)


# --------------------------------------------------------------------- #
# MODEL_FLOPS (the "useful work" yardstick)
# --------------------------------------------------------------------- #


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """6*N_active*D (train) / 2*N_active*D (inference) + exact attention."""
    counts = cfg.param_counts()
    n_active = counts["active"]
    n_attn_layers = sum(
        1 for i in range(cfg.n_layers)
        if cfg.pattern[i % len(cfg.pattern)].mixer == "attn"
    )
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 6.0 * n_active * tokens
        # causal attention: fwd 2*2*S^2/2*d_attn per layer, x3 with backward
        attn = (3 * 2 * 2 * 0.5 * shape.seq_len ** 2 * cfg.q_dim
                * n_attn_layers * shape.global_batch)
        return base + attn
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        base = 2.0 * n_active * tokens
        attn = (2 * 2 * 0.5 * shape.seq_len ** 2 * cfg.q_dim
                * n_attn_layers * shape.global_batch)
        return base + attn
    # decode: one token per sequence, attention reads the whole cache
    tokens = shape.global_batch
    base = 2.0 * n_active * tokens
    attn = (2 * 2 * shape.seq_len * cfg.q_dim * n_attn_layers * tokens)
    return base + attn


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    n_devices: int
    model_flops_total: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * chips) — remat/redundancy waste."""
        hlo_total = self.flops_per_chip * self.n_devices
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bottleneck time — the score.

        1.0 means the step time is fully explained by MODEL_FLOPS at peak
        MXU throughput; less means the dominant term (or wasted FLOPs) is
        costing wall-clock."""
        ideal = self.model_flops_total / (self.n_devices * PEAK_FLOPS)
        return ideal / self.bound_time_s if self.bound_time_s else 0.0

    def asdict(self):
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "n_devices": self.n_devices,
            "model_flops_total": self.model_flops_total,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def derive(cost: dict, coll: CollectiveStats, n_devices: int,
           model_flops_total: float) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=coll.bytes_per_chip / ICI_BW,
        flops_per_chip=flops,
        bytes_per_chip=byts,
        coll_bytes_per_chip=coll.bytes_per_chip,
        n_devices=n_devices,
        model_flops_total=model_flops_total,
    )


def derive_from_hlo_cost(hlo_cost, n_devices: int,
                         model_flops_total: float) -> Roofline:
    """Roofline terms from the loop-aware HLO walker (the accurate path —
    raw cost_analysis counts while-loop bodies once; see hlo_analysis.py)."""
    return Roofline(
        compute_s=hlo_cost.flops / PEAK_FLOPS,
        memory_s=hlo_cost.bytes_accessed / HBM_BW,
        collective_s=hlo_cost.collective_bytes / ICI_BW,
        flops_per_chip=hlo_cost.flops,
        bytes_per_chip=hlo_cost.bytes_accessed,
        coll_bytes_per_chip=hlo_cost.collective_bytes,
        n_devices=n_devices,
        model_flops_total=model_flops_total,
    )
