"""Deterministic, shard-aware synthetic token pipeline.

Every batch is a pure function of (seed, step, shard) — there is no
iterator state, so restart-after-failure resumes exactly (the checkpoint
only needs the step counter) and data parallelism never double-reads.
Token statistics are Zipf-distributed with short-range repetition so a
~100M-parameter model has real structure to learn in the examples.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.config import ModelConfig
from repro.models.frontends import frontend_split


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_s: float = 1.1
    repeat_prob: float = 0.3      # P(copy a recent token) — learnable signal


class SyntheticTokens:
    """Stateless batch source: ``batch(step, shard, n_shards)``."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # Zipf probabilities over the vocab (heavy-tailed like text).
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_s)
        self._probs = p / p.sum()

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> np.ndarray:
        cfg = self.cfg
        if cfg.global_batch % n_shards:
            raise ValueError("global_batch must divide by n_shards")
        local = cfg.global_batch // n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, shard])
        )
        toks = rng.choice(cfg.vocab_size, size=(local, cfg.seq_len),
                          p=self._probs)
        # short-range repetition: token t copies token t-delta sometimes
        rep = rng.random((local, cfg.seq_len)) < cfg.repeat_prob
        delta = rng.integers(1, 8, size=(local, cfg.seq_len))
        idx = np.maximum(np.arange(cfg.seq_len)[None, :] - delta, 0)
        toks = np.where(rep, np.take_along_axis(toks, idx, axis=1), toks)
        return toks.astype(np.int32)


def make_batch(model_cfg: ModelConfig, data: SyntheticTokens, step: int,
               shard: int = 0, n_shards: int = 1) -> dict:
    """Model-ready batch dict ({tokens|embeds}, labels) for any frontend."""
    toks = data.batch(step, shard, n_shards)
    b, s = toks.shape
    n_emb, n_text = frontend_split(model_cfg, s)
    out: dict = {"labels": toks.copy()}
    if n_emb:
        rng = np.random.default_rng(np.random.SeedSequence(
            [data.cfg.seed, step, shard, 7]))
        out["embeds"] = rng.normal(
            0, 1, (b, n_emb, model_cfg.d_model)
        ).astype(np.float32)
        if model_cfg.frontend == "vision":
            out["labels"][:, :n_emb] = -1
    if n_text:
        out["tokens"] = toks[:, n_emb:] if n_emb else toks
    return out
