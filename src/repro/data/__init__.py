from .pipeline import DataConfig, SyntheticTokens, make_batch

__all__ = ["DataConfig", "SyntheticTokens", "make_batch"]
