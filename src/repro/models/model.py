"""Composable LM: init / forward / loss / prefill / decode_step.

The layer stack is a ``lax.scan`` over repeating pattern units (HLO size
independent of depth).  Each unit applies its pattern of
(mixer, ffn) blocks; mixers are attention / mamba / mlstm / slstm, FFNs
are dense SwiGLU or MoE.  Decode carries a per-unit cache pytree (KV cache
for attention, recurrent state for SSM blocks) stacked along the unit axis.

Parallelism: activations are batch-sharded; tensor parallelism comes from
weight sharding (pjit propagation); expert parallelism uses the explicit
``shard_map`` paths in ``repro.models.moe`` selected via ``Parallel``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from . import attention as attn
from . import moe as moe_mod
from . import ssm
from .config import LayerSpec, ModelConfig
from .layers import (dtype_of, embed, embedding_init, ffn_apply, ffn_init,
                     lm_head, normal_init, rmsnorm, rmsnorm_init)


@dataclasses.dataclass(frozen=True)
class Parallel:
    """How a step function should distribute work (None => single shard)."""

    mesh: Mesh | None = None
    data_axes: tuple[str, ...] = ("data",)   # batch axes ("pod","data") multi-pod
    model_axis: str = "model"
    moe_mode: str = "auto"    # "auto" | "ep" | "ep_rep" | "local"

    @property
    def model_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.model_axis]

    def resolve_moe(self, cfg: ModelConfig, seq_len: int) -> str:
        if self.mesh is None or self.model_size == 1:
            return "local"
        if self.moe_mode != "auto":
            return self.moe_mode
        n_buckets = cfg.n_experts
        sl = moe_mod.slotting_for(cfg)
        if sl is not None:
            n_buckets = sl.n_virtual
        if n_buckets % self.model_size == 0:
            if seq_len % self.model_size == 0:
                return "ep"        # sequence-sharded all-to-all dispatch
            return "ep_rep"        # replicated-token EP (decode)
        return "local"             # TP over d_ff via weight sharding


# ===================================================================== #
# Parameter init
# ===================================================================== #


def _block_init(key, cfg: ModelConfig, spec: LayerSpec, dtype) -> dict:
    km, kf = jax.random.split(key)
    p: dict[str, Any] = {"norm1": rmsnorm_init(cfg.d_model, jnp.float32)}
    if spec.mixer == "attn":
        p["mixer"] = attn.attn_init(km, cfg, dtype)
    elif spec.mixer == "mamba":
        p["mixer"] = ssm.mamba_init(km, cfg, dtype)
    elif spec.mixer == "mlstm":
        p["mixer"] = ssm.mlstm_init(km, cfg, dtype)
    elif spec.mixer == "slstm":
        p["mixer"] = ssm.slstm_init(km, cfg, dtype)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn != "none":
        p["norm2"] = rmsnorm_init(cfg.d_model, jnp.float32)
        if spec.ffn == "dense":
            p["ffn"] = ffn_init(kf, cfg.d_model, cfg.d_ff, cfg.n_layers, dtype)
        elif spec.ffn == "moe":
            p["ffn"] = moe_mod.moe_init(kf, cfg, dtype)
        else:
            raise ValueError(spec.ffn)
    return p


def _unit_init(key, cfg: ModelConfig, dtype) -> dict:
    keys = jax.random.split(key, len(cfg.pattern))
    return {f"b{i}": _block_init(keys[i], cfg, spec, dtype)
            for i, spec in enumerate(cfg.pattern)}


def n_scan_units(cfg: ModelConfig) -> int:
    return cfg.n_units - (1 if cfg.first_layer_dense else 0)


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = dtype_of(cfg.param_dtype)
    k_emb, k_units, k_first, k_head = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": embedding_init(k_emb, cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = normal_init(k_head, (cfg.d_model, cfg.padded_vocab), dtype)
    if cfg.first_layer_dense:
        first_cfg = dataclasses.replace(
            cfg, d_ff=(cfg.first_dense_d_ff or cfg.d_ff)
        )
        params["first"] = _block_init(
            k_first, first_cfg, LayerSpec(mixer=cfg.pattern[0].mixer, ffn="dense"),
            dtype,
        )
    unit_keys = jax.random.split(k_units, n_scan_units(cfg))
    params["units"] = jax.vmap(
        functools.partial(_unit_init, cfg=cfg, dtype=dtype)
    )(unit_keys)
    return params


# ===================================================================== #
# Block application (train / prefill / decode share this)
# ===================================================================== #


def _apply_mixer(cfg, spec, bp, x, positions, par, cdt, cache, mode):
    """Returns (y, new_cache)."""
    if spec.mixer == "attn":
        if mode == "train":
            return attn.attention_forward(cfg, bp["mixer"], x, positions, cdt), None
        if mode == "prefill":
            return attn.attention_prefill(cfg, bp["mixer"], x, positions, cache, cdt)
        return attn.attention_decode(cfg, bp["mixer"], x, positions, cache, cdt)
    if spec.mixer in ("mamba", "mlstm"):
        fwd, dec = {"mamba": (ssm.mamba_forward, ssm.mamba_decode),
                    "mlstm": (ssm.mlstm_forward, ssm.mlstm_decode)}[spec.mixer]
        if mode == "train":
            return fwd(cfg, bp["mixer"], x, cdt, par), None
        return dec(cfg, bp["mixer"], x, cache, cdt, par)
    if mode == "train":
        return ssm.slstm_forward(cfg, bp["mixer"], x, cdt), None
    return ssm.slstm_decode(cfg, bp["mixer"], x, cache, cdt)


def _apply_moe(cfg, bp_ffn, x, par: Parallel, cdt):
    mode = par.resolve_moe(cfg, x.shape[1])
    if mode == "local":
        return moe_mod.moe_apply_local(cfg, bp_ffn, x, cdt)
    mesh = par.mesh
    n_data = 1
    for a in par.data_axes:
        n_data *= mesh.shape[a]
    batch_axes = par.data_axes if len(par.data_axes) > 1 else par.data_axes[0]
    if x.shape[0] % n_data != 0:     # e.g. long-context batch=1 decode
        batch_axes = None
    in_params_spec = {k: P(par.model_axis) for k in ("w_gate", "w_up", "w_down")}
    in_params_spec["router"] = P()
    if "shared" in bp_ffn:
        in_params_spec["shared"] = jax.tree.map(lambda _: P(), bp_ffn["shared"])
    aux_spec = {"load_balance_loss": P(), "router_z_loss": P(),
                "expert_counts": P()}

    if mode == "ep":
        # sequence-sharded dispatch: tokens split over the EP axis
        x_spec = P(batch_axes, par.model_axis, None)
        fn = functools.partial(moe_mod.moe_apply_ep, cfg,
                               axis_name=par.model_axis, compute_dtype=cdt)
    elif mode == "ep_rep":
        # replicated tokens (decode): local experts + psum combine
        x_spec = P(batch_axes, None, None)
        fn = functools.partial(moe_mod.moe_apply_ep_replicated, cfg,
                               axis_name=par.model_axis, compute_dtype=cdt)
    else:
        raise ValueError(mode)
    sharded = moe_mod.sharded_moe(
        lambda p, xx: fn(p, x_local=xx),
        mesh=mesh,
        in_specs=(in_params_spec, x_spec),
        out_specs=(x_spec, aux_spec),
    )
    return sharded(bp_ffn, x)


def _apply_block(cfg, spec, bp, x, positions, par, cdt, cache, mode):
    aux = None
    h = rmsnorm(bp["norm1"], x, cfg.norm_eps)
    y, new_cache = _apply_mixer(cfg, spec, bp, h, positions, par, cdt, cache, mode)
    x = x + y
    if spec.ffn != "none":
        h = rmsnorm(bp["norm2"], x, cfg.norm_eps)
        if spec.ffn == "dense":
            x = x + ffn_apply(bp["ffn"], h, cdt)
        else:
            out, aux = _apply_moe(cfg, bp["ffn"], h, par, cdt)
            x = x + out
    return x, new_cache, aux


def _apply_unit(cfg, unit_params, x, positions, par, cdt, unit_cache, mode):
    new_caches = {}
    aux_sum = jnp.zeros((), jnp.float32)
    counts = jnp.zeros((max(cfg.n_experts, 1),), jnp.float32)
    for i, spec in enumerate(cfg.pattern):
        cache_i = None if unit_cache is None else unit_cache.get(f"b{i}")
        x, nc, aux = _apply_block(
            cfg, spec, unit_params[f"b{i}"], x, positions, par, cdt, cache_i, mode
        )
        if nc is not None:
            new_caches[f"b{i}"] = nc
        if aux is not None:
            aux_sum = aux_sum + aux["load_balance_loss"] \
                + 1e-3 * aux["router_z_loss"]
            counts = counts + aux["expert_counts"]
    return x, (new_caches or None), aux_sum, counts


# ===================================================================== #
# Full passes
# ===================================================================== #


def _embed_inputs(cfg, params, batch, cdt):
    """batch: dict with 'tokens' (B,S) and/or 'embeds' (B,S_e,d)."""
    parts = []
    if "embeds" in batch:
        parts.append(batch["embeds"].astype(cdt))
    if "tokens" in batch:
        parts.append(embed(params["embed"], batch["tokens"], cdt))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return x, positions


def forward(cfg: ModelConfig, params: dict, batch: dict,
            par: Parallel = Parallel(), return_router_stats: bool = False):
    """Training forward: returns (logits (B,S,V_padded), aux_loss).

    With ``return_router_stats`` also returns per-unit expert-selection
    counts (n_scan_units, n_experts) — the activation statistics that feed
    the SpaceMoE placement planner (Eq. 14 plug-in).
    """
    cdt = dtype_of(cfg.compute_dtype)
    x, positions = _embed_inputs(cfg, params, batch, cdt)
    aux_total = jnp.zeros((), jnp.float32)
    if cfg.first_layer_dense:
        x, _, aux = _apply_block(
            cfg, LayerSpec(cfg.pattern[0].mixer, "dense"), params["first"],
            x, positions, par, cdt, None, "train",
        )

    def unit_step(carry, unit_params):
        xx, aux_acc = carry
        xx, _, aux, counts = _apply_unit(cfg, unit_params, xx, positions,
                                         par, cdt, None, "train")
        return (xx, aux_acc + aux), counts

    body = unit_step
    if cfg.remat == "unit":
        body = jax.checkpoint(unit_step, prevent_cse=False)
    (x, aux_total), counts = jax.lax.scan(body, (x, aux_total),
                                          params["units"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = lm_head(table, x, cfg.tie_embeddings)
    if return_router_stats:
        return logits, aux_total, counts
    return logits, aux_total


def loss_fn(cfg: ModelConfig, params: dict, batch: dict,
            par: Parallel = Parallel(), aux_weight: float = 0.01):
    """Next-token cross-entropy (+ MoE aux).  batch['labels']: (B,S) int32,
    -1 => ignore."""
    logits, aux = forward(cfg, params, batch, par)
    labels = batch["labels"]
    s = min(logits.shape[1], labels.shape[1])
    logits = logits[:, -s:].astype(jnp.float32)
    labels = labels[:, -s:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, nll, 0.0)
    denom = jnp.maximum(valid.sum(), 1)
    ce = nll.sum() / denom
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


# --------------------------------------------------------------------- #
# Decode: cache init / prefill / single-step
# --------------------------------------------------------------------- #


def _block_cache(cfg, spec: LayerSpec, batch: int, max_len: int, cdt):
    if spec.mixer == "attn":
        return attn.init_kv_cache(cfg, batch, max_len, cdt)
    if spec.mixer == "mamba":
        return ssm.mamba_init_state(cfg, batch)
    if spec.mixer == "mlstm":
        return ssm.mlstm_init_state(cfg, batch)
    if spec.mixer == "slstm":
        return ssm.slstm_init_state(cfg, batch)
    raise ValueError(spec.mixer)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Stacked decode cache: every leaf has leading dim n_scan_units."""
    cdt = dtype_of(cfg.compute_dtype)
    unit = {f"b{i}": _block_cache(cfg, spec, batch, max_len, cdt)
            for i, spec in enumerate(cfg.pattern)}
    n = n_scan_units(cfg)
    stacked = jax.tree.map(
        lambda leaf: jnp.broadcast_to(leaf[None], (n, *leaf.shape)), unit
    )
    out = {"units": stacked}
    if cfg.first_layer_dense:
        out["first"] = _block_cache(cfg, cfg.pattern[0], batch, max_len, cdt)
    return out


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jnp.ndarray, pos: jnp.ndarray,
                par: Parallel = Parallel(), embeds: jnp.ndarray | None = None):
    """One autoregressive step.

    tokens: (B, 1) int32 (or ``embeds`` (B, 1, d) for stub frontends);
    pos: (B,) positions of these tokens.  Returns (logits (B, V), cache').
    """
    cdt = dtype_of(cfg.compute_dtype)
    if embeds is not None:
        x = embeds.astype(cdt)
    else:
        x = embed(params["embed"], tokens, cdt)
    new_cache: dict = {}
    if cfg.first_layer_dense:
        x, fc, _ = _apply_block(
            cfg, LayerSpec(cfg.pattern[0].mixer, "dense"), params["first"],
            x, pos, par, cdt, cache["first"], "decode",
        )
        new_cache["first"] = fc

    def unit_step(x, xs):
        unit_params, unit_cache = xs
        x, nc, _, _ = _apply_unit(cfg, unit_params, x, pos, par, cdt,
                                  unit_cache, "decode")
        return x, nc

    x, new_unit_caches = jax.lax.scan(
        unit_step, x, (params["units"], cache["units"])
    )
    new_cache["units"] = new_unit_caches
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = lm_head(table, x, cfg.tie_embeddings)
    return logits[:, 0, :], new_cache


def prefill(cfg: ModelConfig, params: dict, batch: dict, max_len: int,
            par: Parallel = Parallel()):
    """Run the prompt through the stack, returning (last-token logits, cache).

    Attention blocks write K/V for positions [0, S); recurrent blocks carry
    their final state.
    """
    cdt = dtype_of(cfg.compute_dtype)
    x, positions = _embed_inputs(cfg, params, batch, cdt)
    b, s = x.shape[0], x.shape[1]
    cache = init_cache(cfg, b, max_len)
    new_cache: dict = {}
    if cfg.first_layer_dense:
        x, fc, _ = _apply_block(
            cfg, LayerSpec(cfg.pattern[0].mixer, "dense"), params["first"],
            x, positions, par, cdt, cache["first"], "prefill",
        )
        new_cache["first"] = fc

    def unit_step(x, xs):
        unit_params, unit_cache = xs
        x, nc, _, _ = _apply_unit(cfg, unit_params, x, positions, par, cdt,
                                  unit_cache, "prefill")
        return x, nc

    x, new_unit_caches = jax.lax.scan(
        unit_step, x, (params["units"], cache["units"])
    )
    new_cache["units"] = new_unit_caches
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = lm_head(table, x[:, -1:, :], cfg.tie_embeddings)
    return logits[:, 0, :], new_cache
