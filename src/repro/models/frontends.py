"""Stub modality frontends (per the assignment brief).

The [vlm]/[audio] entries specify the transformer BACKBONE only; the
modality encoder (CLIP tower / EnCodec) is a STUB — ``input_specs()``
supplies precomputed patch/frame embeddings.  These helpers generate the
matching ShapeDtypeStructs and random test inputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

# llava-next anyres: one 24x24 base tile + CLS drop => 576 patch embeddings.
VISION_TOKENS = 576


def frontend_split(cfg: ModelConfig, seq_len: int) -> tuple[int, int]:
    """(n_embed_tokens, n_text_tokens) summing to seq_len."""
    if cfg.frontend == "vision":
        n_emb = min(VISION_TOKENS, seq_len // 2)
        return n_emb, seq_len - n_emb
    if cfg.frontend == "audio":
        return seq_len, 0        # decoder over EnCodec frames only
    return 0, seq_len


def batch_specs(cfg: ModelConfig, batch: int, seq_len: int,
                embed_dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStructs for a training batch (tokens/embeds + labels)."""
    n_emb, n_text = frontend_split(cfg, seq_len)
    out: dict = {}
    if n_emb:
        out["embeds"] = jax.ShapeDtypeStruct((batch, n_emb, cfg.d_model),
                                             embed_dtype)
    if n_text:
        out["tokens"] = jax.ShapeDtypeStruct((batch, n_text), jnp.int32)
    out["labels"] = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)
    return out


def random_batch(cfg: ModelConfig, batch: int, seq_len: int, seed: int = 0,
                 embed_dtype=jnp.bfloat16) -> dict:
    rng = np.random.default_rng(seed)
    n_emb, n_text = frontend_split(cfg, seq_len)
    out: dict = {}
    if n_emb:
        out["embeds"] = jnp.asarray(
            rng.normal(0, 1, (batch, n_emb, cfg.d_model)), dtype=embed_dtype
        )
    if n_text:
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, n_text)), dtype=jnp.int32
        )
    labels = rng.integers(0, cfg.vocab_size, (batch, seq_len))
    if n_emb and cfg.frontend == "vision":
        labels[:, :n_emb] = -1   # no next-token loss on the image prefix
    # (audio: the EnCodec frames are stubbed as input embeddings, but the
    # codec token ids remain the prediction targets.)
    out["labels"] = jnp.asarray(labels, dtype=jnp.int32)
    return out
