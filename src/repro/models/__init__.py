"""JAX model zoo: composable dense / MoE / SSM / hybrid language models."""
from .config import LayerSpec, ModelConfig
from .frontends import batch_specs, frontend_split, random_batch
from .model import (Parallel, decode_step, forward, init_cache, init_params,
                    loss_fn, n_scan_units, prefill)
from .moe import apply_placement

__all__ = [
    "LayerSpec", "ModelConfig", "Parallel",
    "batch_specs", "frontend_split", "random_batch",
    "decode_step", "forward", "init_cache", "init_params", "loss_fn",
    "n_scan_units", "prefill", "apply_placement",
]
