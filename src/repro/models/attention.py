"""GQA attention: chunked (flash-style) training path + KV-cache decode.

The training path is an online-softmax two-level scan (query chunks x KV
chunks) so the S x S score matrix is never materialized — peak temp memory
is O(q_chunk * kv_chunk) per head, and HLO size is O(1) in sequence
length.  Causally fully-masked KV blocks are still computed (XLA scans
cannot skip iterations), which overcounts attention FLOPs by ~2x — this is
accounted for in the roofline's MODEL_FLOPS/HLO_FLOPs ratio and is the
motivation for the Pallas decode/splash kernels in ``repro.kernels``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_rope, normal_init, out_proj_init

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig, dtype) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "w_q": normal_init(kq, (cfg.d_model, cfg.q_dim), dtype),
        "w_k": normal_init(kk, (cfg.d_model, cfg.kv_dim), dtype),
        "w_v": normal_init(kv, (cfg.d_model, cfg.kv_dim), dtype),
        "w_o": out_proj_init(ko, (cfg.q_dim, cfg.d_model), dtype, cfg.n_layers),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((cfg.q_dim,), dtype)
        p["b_k"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["b_v"] = jnp.zeros((cfg.kv_dim,), dtype)
    return p


def _project_qkv(cfg: ModelConfig, params, x, positions, compute_dtype):
    """x: (B, S, d) -> q (B,S,Hq,hd), k/v (B,S,Hkv,hd), with RoPE."""
    b, s, _ = x.shape
    x = x.astype(compute_dtype)
    q = x @ params["w_q"].astype(compute_dtype)
    k = x @ params["w_k"].astype(compute_dtype)
    v = x @ params["w_v"].astype(compute_dtype)
    if cfg.qkv_bias:
        q = q + params["b_q"].astype(compute_dtype)
        k = k + params["b_k"].astype(compute_dtype)
        v = v + params["b_v"].astype(compute_dtype)
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _softcap(s, cap: float):
    return cap * jnp.tanh(s / cap) if cap > 0 else s


def _pick_chunk(s: int, target: int) -> int:
    """Largest divisor of ``s`` that is <= target (trace-time, static)."""
    c = min(target, s)
    while s % c:
        c -= 1
    return c


def flash_attention(
    cfg: ModelConfig,
    q: jnp.ndarray,          # (B, S, Hq, hd)
    k: jnp.ndarray,          # (B, S, Hkv, hd)
    v: jnp.ndarray,
    q_positions: jnp.ndarray,   # (B, S) global positions of queries
    kv_positions: jnp.ndarray,  # (B, S)
) -> jnp.ndarray:
    """Causal online-softmax attention, chunked along both S axes.

    With ``cfg.flash_vjp`` the backward pass recomputes probabilities
    chunk-wise (custom VJP) instead of letting scan-AD save every (qc,kc)
    probability block — which otherwise materializes the full S^2 attention
    matrix per layer during backprop and dominates the memory roofline of
    every *train* cell (EXPERIMENTS.md §Perf iteration A1).
    """
    if cfg.flash_vjp:
        out, _ = _flash_vjp_fn(cfg)(q, k, v, q_positions, kv_positions)
        return out
    out, _ = _flash_fwd(cfg, q, k, v, q_positions, kv_positions)
    return out


def _flash_fwd(
    cfg: ModelConfig,
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_positions: jnp.ndarray,
    kv_positions: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out (B,S,Hq,hd), lse (B,Hkv,G,S) log-sum-exp per query)."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qc = _pick_chunk(s, cfg.attn_q_chunk)
    kc = _pick_chunk(s, cfg.attn_kv_chunk)
    nq, nk = s // qc, s // kc
    scale = hd ** -0.5

    # (B, Hkv, G, S, hd) view of q; K/V stay (B, Hkv, S, hd).
    qg = q.reshape(b, s, hkv, g, hd).transpose(0, 2, 3, 1, 4)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    q_chunks = qg.reshape(b, hkv, g, nq, qc, hd).transpose(3, 0, 1, 2, 4, 5)
    qpos_chunks = q_positions.reshape(b, nq, qc).transpose(1, 0, 2)
    k_chunks = kt.reshape(b, hkv, nk, kc, hd).transpose(2, 0, 1, 3, 4)
    v_chunks = vt.reshape(b, hkv, nk, kc, hd).transpose(2, 0, 1, 3, 4)
    kpos_chunks = kv_positions.reshape(b, nk, kc).transpose(1, 0, 2)

    def q_step(_, q_in):
        q_blk, qpos = q_in        # (B,Hkv,G,qc,hd), (B,qc)

        def kv_step(carry, kv_in):
            m, l, acc = carry
            k_blk, v_blk, kpos = kv_in
            sco = jnp.einsum(
                "bngqd,bnkd->bngqk", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale
            sco = _softcap(sco, cfg.attn_logit_softcap)
            mask = qpos[:, None, None, :, None] >= kpos[:, None, None, None, :]
            if cfg.sliding_window > 0:
                near = (qpos[:, None, None, :, None]
                        - kpos[:, None, None, None, :]) < cfg.sliding_window
                mask = mask & near
            sco = jnp.where(mask, sco, NEG_INF)
            m_new = jnp.maximum(m, sco.max(axis=-1))
            p = jnp.exp(sco - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bngqk,bnkd->bngqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, qc), NEG_INF, dtype=jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qc), dtype=jnp.float32)
        a0 = jnp.zeros((b, hkv, g, qc, hd), dtype=jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (k_chunks, v_chunks, kpos_chunks)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out.astype(q.dtype), lse)

    _, (out_chunks, lse_chunks) = jax.lax.scan(
        q_step, None, (q_chunks, qpos_chunks)
    )
    # (nq, B, Hkv, G, qc, hd) -> (B, S, Hq, hd)
    out = out_chunks.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, g, s, hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, hq, hd)
    lse = lse_chunks.transpose(1, 2, 3, 0, 4).reshape(b, hkv, g, s)
    return out, lse


def _mask_block(cfg: ModelConfig, qpos, kpos):
    """(B, qc, kc) bool mask for one chunk pair (causal [+ window])."""
    m = qpos[:, :, None] >= kpos[:, None, :]
    if cfg.sliding_window > 0:
        m = m & ((qpos[:, :, None] - kpos[:, None, :]) < cfg.sliding_window)
    return m


@functools.lru_cache(maxsize=None)
def _flash_vjp_fn(cfg: ModelConfig):
    """custom-VJP flash attention: O(qc*kc) backward temporaries."""
    if cfg.attn_logit_softcap > 0:
        raise NotImplementedError(
            "flash_vjp does not implement the softcap derivative"
        )

    @jax.custom_vjp
    def flash(q, k, v, qpos, kpos):
        return _flash_fwd(cfg, q, k, v, qpos, kpos)

    def fwd(q, k, v, qpos, kpos):
        out, lse = _flash_fwd(cfg, q, k, v, qpos, kpos)
        return (out, lse), (q, k, v, qpos, kpos, out, lse)

    def bwd(res, cts):
        do, _ = cts                      # no cotangent flows into lse
        q, k, v, qpos, kpos, out, lse = res
        b, s, hq, hd = q.shape
        hkv = k.shape[2]
        g = hq // hkv
        qc = _pick_chunk(s, cfg.attn_q_chunk)
        kc = _pick_chunk(s, cfg.attn_kv_chunk)
        nq, nk = s // qc, s // kc
        scale = hd ** -0.5
        f32 = jnp.float32
        # chunk intermediates ride in the model dtype (bf16 on TPU: halves
        # the backward's HBM traffic; accumulation stays f32 via
        # preferred_element_type) — f32 inputs keep f32 for exact tests.
        wdt = q.dtype

        def grouped(x):                  # (B,S,Hq,hd) -> (nq,B,Hkv,G,qc,hd)
            xg = x.reshape(b, s, hkv, g, hd).transpose(0, 2, 3, 1, 4)
            return xg.reshape(b, hkv, g, nq, qc, hd).transpose(3, 0, 1, 2, 4, 5)

        q_chunks = grouped(q)
        do_chunks = grouped(do.astype(wdt))
        # delta_i = sum_d do * out per query (rescales dp -> ds)
        delta = jnp.sum(do.astype(f32) * out.astype(f32), axis=-1)  # (B,S,Hq)
        delta = delta.reshape(b, s, hkv, g).transpose(0, 2, 3, 1)
        delta_chunks = delta.reshape(b, hkv, g, nq, qc).transpose(3, 0, 1, 2, 4)
        lse_chunks = lse.reshape(b, hkv, g, nq, qc).transpose(3, 0, 1, 2, 4)
        qpos_chunks = qpos.reshape(b, nq, qc).transpose(1, 0, 2)

        kt = k.transpose(0, 2, 1, 3)                   # (B,Hkv,S,hd)
        vt = v.transpose(0, 2, 1, 3)
        k_chunks = kt.reshape(b, hkv, nk, kc, hd).transpose(2, 0, 1, 3, 4)
        v_chunks = vt.reshape(b, hkv, nk, kc, hd).transpose(2, 0, 1, 3, 4)
        kpos_chunks = kpos.reshape(b, nk, kc).transpose(1, 0, 2)

        def kv_step(dq_acc, kv_in):
            k_blk, v_blk, kpb = kv_in    # (B,Hkv,kc,hd), (B,kc)

            def q_step(carry, q_in):
                dk_blk, dv_blk = carry
                q_blk, do_blk, lse_blk, dl_blk, qpb = q_in
                sco = jnp.einsum("bngqd,bnkd->bngqk", q_blk, k_blk,
                                 preferred_element_type=f32) * scale
                sco = _softcap(sco, cfg.attn_logit_softcap)
                mask = _mask_block(cfg, qpb, kpb)[:, None, None]
                p = jnp.where(mask, jnp.exp(sco - lse_blk[..., None]), 0.0)
                p_w = p.astype(wdt)
                dv_blk = dv_blk + jnp.einsum("bngqk,bngqd->bnkd", p_w, do_blk,
                                             preferred_element_type=f32)
                dp = jnp.einsum("bngqd,bnkd->bngqk", do_blk, v_blk,
                                preferred_element_type=f32)
                ds = (p * (dp - dl_blk[..., None]) * scale).astype(wdt)
                dq_blk = jnp.einsum("bngqk,bnkd->bngqd", ds, k_blk,
                                    preferred_element_type=f32)
                dk_blk = dk_blk + jnp.einsum("bngqk,bngqd->bnkd", ds, q_blk,
                                             preferred_element_type=f32)
                return (dk_blk, dv_blk), dq_blk

            zeros_kv = jnp.zeros((b, hkv, kc, hd), f32)
            (dk_blk, dv_blk), dq_parts = jax.lax.scan(
                q_step, (zeros_kv, zeros_kv),
                (q_chunks, do_chunks, lse_chunks, delta_chunks, qpos_chunks),
            )
            return dq_acc + dq_parts, (dk_blk, dv_blk)

        dq0 = jnp.zeros((nq, b, hkv, g, qc, hd), f32)
        dq_chunks, (dk_chunks, dv_chunks) = jax.lax.scan(
            kv_step, dq0, (k_chunks, v_chunks, kpos_chunks)
        )
        dq = dq_chunks.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, g, s, hd)
        dq = dq.transpose(0, 3, 1, 2, 4).reshape(b, s, hq, hd).astype(q.dtype)
        dk = dk_chunks.transpose(1, 2, 0, 3, 4).reshape(b, hkv, s, hd)
        dk = dk.transpose(0, 2, 1, 3).astype(k.dtype)
        dv = dv_chunks.transpose(1, 2, 0, 3, 4).reshape(b, hkv, s, hd)
        dv = dv.transpose(0, 2, 1, 3).astype(v.dtype)
        return dq, dk, dv, None, None

    flash.defvjp(fwd, bwd)
    return flash


def attention_forward(
    cfg: ModelConfig, params: dict, x: jnp.ndarray, positions: jnp.ndarray,
    compute_dtype,
) -> jnp.ndarray:
    """Training / prefill self-attention (no cache returned)."""
    q, k, v = _project_qkv(cfg, params, x, positions, compute_dtype)
    out = flash_attention(cfg, q, k, v, positions, positions)
    b, s = x.shape[:2]
    return out.reshape(b, s, cfg.q_dim) @ params["w_o"].astype(compute_dtype)


def attention_prefill(
    cfg: ModelConfig, params: dict, x: jnp.ndarray, positions: jnp.ndarray,
    cache: dict, compute_dtype,
) -> tuple[jnp.ndarray, dict]:
    """Prefill: run causal attention AND write K/V into the cache at [0, S)."""
    q, k, v = _project_qkv(cfg, params, x, positions, compute_dtype)
    out = flash_attention(cfg, q, k, v, positions, positions)
    new_cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)
        ),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)
        ),
    }
    b, s = x.shape[:2]
    y = out.reshape(b, s, cfg.q_dim) @ params["w_o"].astype(compute_dtype)
    return y, new_cache


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_decode(
    cfg: ModelConfig, params: dict, x: jnp.ndarray, pos: jnp.ndarray,
    cache: dict, compute_dtype,
) -> tuple[jnp.ndarray, dict]:
    """One-token decode: x (B, 1, d), pos (B,) current position.

    Writes k/v at ``pos``, attends over cache[0..pos].  This is the jnp
    reference path; the Pallas ``decode_attn`` kernel implements the same
    contract for TPU.
    """
    b = x.shape[0]
    positions = pos[:, None]                                   # (B, 1)
    q, k, v = _project_qkv(cfg, params, x, positions, compute_dtype)

    # Scatter the new K/V row at each batch element's position.
    batch_idx = jnp.arange(b)
    ck = cache["k"].at[batch_idx, pos].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[batch_idx, pos].set(v[:, 0].astype(cache["v"].dtype))

    s_max = ck.shape[1]
    hkv, g, hd = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.head_dim
    qg = q.reshape(b, hkv, g, hd)
    if cfg.use_pallas_decode and cfg.sliding_window == 0 \
            and cfg.attn_logit_softcap == 0:
        # Pallas flash-decode kernel: one HBM pass over the cache.  (The
        # cache transpose to (B,Hkv,S,hd) is layout-only; a production
        # deployment keeps the cache in kernel layout.)
        from repro.kernels.ops import decode_attention as _pallas_decode
        out = _pallas_decode(
            qg.astype(compute_dtype),
            ck.transpose(0, 2, 1, 3).astype(compute_dtype),
            cv.transpose(0, 2, 1, 3).astype(compute_dtype),
            pos,
        )
        y = out.reshape(b, 1, cfg.q_dim).astype(compute_dtype) \
            @ params["w_o"].astype(compute_dtype)
        return y, {"k": ck, "v": cv}
    kt = ck.astype(compute_dtype)
    vt = cv.astype(compute_dtype)
    sco = jnp.einsum("bngd,bsnd->bngs", qg, kt,
                     preferred_element_type=jnp.float32) * (hd ** -0.5)
    sco = _softcap(sco, cfg.attn_logit_softcap)
    kv_pos = jnp.arange(s_max)[None, :]                        # (1, S)
    mask = kv_pos <= pos[:, None]
    if cfg.sliding_window > 0:
        mask = mask & ((pos[:, None] - kv_pos) < cfg.sliding_window)
    sco = jnp.where(mask[:, None, None, :], sco, NEG_INF)
    p = jax.nn.softmax(sco, axis=-1)
    out = jnp.einsum("bngs,bsnd->bngd", p.astype(compute_dtype), vt,
                     preferred_element_type=jnp.float32)
    y = out.reshape(b, 1, cfg.q_dim).astype(compute_dtype) \
        @ params["w_o"].astype(compute_dtype)
    return y, {"k": ck, "v": cv}
