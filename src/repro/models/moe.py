"""Mixture-of-Experts layer (paper Sec. III-C) with placement-aware layout.

Routing follows the paper: softmax gate scores (Eq. 11), top-K selection,
combine weights normalized over the active set (Eq. 15).  Dispatch uses a
sort+gather formulation (megablocks-style, capacity-padded): memory is
O(tokens * K * d), never O(tokens * E * C) like the classic GShard one-hot
einsum — that is what makes 64-expert configs viable.

Execution paths
---------------
- ``moe_apply_local``: single-shard math (also the oracle for tests).
- ``moe_apply_ep``: expert parallelism inside ``shard_map`` — tokens are
  sequence-sharded over the EP axis, buckets travel via ``lax.all_to_all``,
  each device runs its local expert group, and a reverse all-to-all brings
  results home.  Requires E % |EP axis| == 0.
- TP fallback for E not divisible by the axis (e.g. granite's 40 experts on
  16 devices): experts' d_ff is sharded over the axis instead and partial
  outputs are psum-reduced; selected automatically by the model layer.

SpaceMoE placement enters as a *checkpoint transform*: ``apply_placement``
permutes the stacked expert weights and the router's output columns so
that EP slot s holds the expert Theorem 1 assigns there — zero runtime
cost, identical math (router logits are permuted consistently).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import axis_size, shard_map
from .config import ModelConfig
from .layers import normal_init, out_proj_init


# --------------------------------------------------------------------- #
# EP slotting (perf feature; paper Sec. VI-B multi-expert rule on devices)
#
# The EP all-to-all path needs the expert-stack's leading dim to divide the
# EP axis.  Slotting makes that true for ANY expert count by re-laying the
# stack into "virtual slots":
#   E >= S:  pad with dummy experts to the next multiple of S
#            (granite: 40 -> 48, 3 slots/device; dummies get no tokens);
#   E <  S:  fragment each expert's d_ff into S/E' slices after padding E
#            to a divisor of S (llama-moe: 8 experts x 2 half-experts = 16
#            slots; fragment outputs sum to the exact expert output).
# Without slotting these configs fall back to TP over d_ff, whose
# all-reduces made granite/llama-moe train cells collective-bound by ~100x
# (see EXPERIMENTS.md §Perf).
# --------------------------------------------------------------------- #
import dataclasses


@dataclasses.dataclass(frozen=True)
class Slotting:
    n_experts: int
    n_slots: int       # EP axis size the layout targets
    frag: int          # d_ff fragments per expert
    e_pad: int         # padded expert count (>= n_experts)

    @property
    def n_virtual(self) -> int:
        return self.e_pad * self.frag


def make_slotting(n_experts: int, n_slots: int) -> Slotting:
    if n_experts >= n_slots:
        e_pad = -(-n_experts // n_slots) * n_slots
        return Slotting(n_experts, n_slots, 1, e_pad)
    e_pad = n_experts
    while n_slots % e_pad:
        e_pad += 1
    return Slotting(n_experts, n_slots, n_slots // e_pad, e_pad)


def slotting_for(cfg: ModelConfig) -> Slotting | None:
    if not getattr(cfg, "moe_slotting", False) or cfg.n_experts == 0:
        return None
    return make_slotting(cfg.n_experts, cfg.moe_ep_slots)


def slotted_weights(w_gate, w_up, w_down, sl: Slotting):
    """Canonical (E,d,f)/(E,f,d) stacks -> virtual (V,d,f/frag)/(V,f/frag,d)."""
    e, d, f = w_gate.shape
    if f % sl.frag:
        raise ValueError(f"d_ff_expert={f} not divisible by frag={sl.frag}")
    pad = sl.e_pad - e
    if pad:
        w_gate = jnp.concatenate([w_gate, jnp.zeros((pad, d, f), w_gate.dtype)])
        w_up = jnp.concatenate([w_up, jnp.zeros((pad, d, f), w_up.dtype)])
        w_down = jnp.concatenate([w_down, jnp.zeros((pad, f, d), w_down.dtype)])
    fs = f // sl.frag
    # (E', d, f) -> (E', frag, d, fs) -> (V, d, fs), slot-major per expert
    wg = w_gate.reshape(sl.e_pad, d, sl.frag, fs).transpose(0, 2, 1, 3) \
        .reshape(sl.n_virtual, d, fs)
    wu = w_up.reshape(sl.e_pad, d, sl.frag, fs).transpose(0, 2, 1, 3) \
        .reshape(sl.n_virtual, d, fs)
    wd = w_down.reshape(sl.e_pad, sl.frag, fs, d).reshape(sl.n_virtual, fs, d)
    return wg, wu, wd


def virtual_indices(idx: jnp.ndarray, sl: Slotting) -> jnp.ndarray:
    """(T, K) expert ids -> (T, K*frag) virtual slot ids."""
    frag_ids = jnp.arange(sl.frag, dtype=idx.dtype)
    v = idx[..., None] * sl.frag + frag_ids          # (T, K, frag)
    return v.reshape(idx.shape[0], -1)


def moe_init(key, cfg: ModelConfig, dtype) -> dict:
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    p = {
        "router": normal_init(kr, (d, e), jnp.float32),  # router kept fp32
        "w_gate": normal_init(kg, (e, d, f), dtype),
        "w_up": normal_init(ku, (e, d, f), dtype),
        "w_down": out_proj_init(kd, (e, f, d), dtype, cfg.n_layers),
    }
    sl = slotting_for(cfg)
    if sl is not None:
        p["w_gate"], p["w_up"], p["w_down"] = slotted_weights(
            p["w_gate"], p["w_up"], p["w_down"], sl
        )
    if cfg.n_shared_experts > 0:
        fs = f * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks, 3)
        p["shared"] = {
            "w_gate": normal_init(k1, (d, fs), dtype),
            "w_up": normal_init(k2, (d, fs), dtype),
            "w_down": out_proj_init(k3, (fs, d), dtype, cfg.n_layers),
        }
    return p


# --------------------------------------------------------------------- #
# Routing (Eq. 11 + top-K + Eq. 15 combine weights)
# --------------------------------------------------------------------- #


def route(cfg: ModelConfig, router_w: jnp.ndarray, x: jnp.ndarray):
    """x: (T, d) -> (weights (T,K), idx (T,K) int32, aux dict)."""
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    weights = top_p / jnp.sum(top_p, axis=-1, keepdims=True)        # Eq. 15
    # Switch-style load-balance loss + router z-loss.
    e = cfg.n_experts
    me = jnp.mean(probs, axis=0)                                    # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[top_i.ravel()].add(
        jnp.ones_like(top_i.ravel(), jnp.float32)
    ) / (top_i.size)
    aux = {
        "load_balance_loss": e * jnp.sum(me * ce),
        "router_z_loss": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
        "expert_counts": ce,
    }
    return weights, top_i.astype(jnp.int32), aux


# --------------------------------------------------------------------- #
# Sort + gather dispatch to capacity-padded (E, C, d) buckets
# --------------------------------------------------------------------- #


def capacity(cfg: ModelConfig, n_tokens: int, n_buckets: int) -> int:
    c = int(np.ceil(cfg.capacity_factor * n_tokens * cfg.top_k / n_buckets))
    return max(c, cfg.top_k)


def dispatch_indices(idx: jnp.ndarray, n_experts: int, cap: int):
    """Compute the gather plan mapping (E, C) slots to token copies.

    idx: (T, K) expert choice per token copy.  Returns
      slot_token: (E*C,) index into the flattened (T*K,) copy list
                  (arbitrary valid index where unfilled),
      slot_valid: (E*C,) bool — slot actually holds a token,
      copy_slot:  (T*K,) slot of each copy (E*C where dropped),
      copy_kept:  (T*K,) bool.
    """
    tk = idx.size
    flat = idx.reshape(-1)                                   # (T*K,)
    order = jnp.argsort(flat, stable=True)                   # sort copies by expert
    sorted_e = flat[order]
    # position within expert = rank among same-expert copies
    pos_in_e = jnp.arange(tk) - jnp.searchsorted(sorted_e, sorted_e, side="left")
    kept = pos_in_e < cap
    slot_of_sorted = sorted_e * cap + pos_in_e               # (T*K,)
    # Dropped copies target slot E*C (out of bounds) and are discarded by
    # the scatter's mode="drop"; no valid slot is ever overwritten.
    tgt = jnp.where(kept, slot_of_sorted, n_experts * cap)
    slot_token = jnp.zeros((n_experts * cap,), jnp.int32).at[tgt].set(
        order.astype(jnp.int32), mode="drop"
    )
    slot_valid = jnp.zeros((n_experts * cap,), bool).at[tgt].set(
        True, mode="drop"
    )
    copy_slot = jnp.zeros((tk,), jnp.int32).at[order].set(
        jnp.where(kept, slot_of_sorted, 0).astype(jnp.int32)
    )
    copy_kept = jnp.zeros((tk,), bool).at[order].set(kept)
    return slot_token, slot_valid, copy_slot, copy_kept


def expert_ffn(params: dict, xs: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    """Batched SwiGLU over expert buckets.  xs: (E, C, d) -> (E, C, d)."""
    wg = params["w_gate"].astype(compute_dtype)
    wu = params["w_up"].astype(compute_dtype)
    wd = params["w_down"].astype(compute_dtype)
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, wg))
    up = jnp.einsum("ecd,edf->ecf", xs, wu)
    return jnp.einsum("ecf,efd->ecd", gate * up, wd)


def _shared_ffn(params: dict, x: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    g = jax.nn.silu(x @ params["w_gate"].astype(compute_dtype))
    u = x @ params["w_up"].astype(compute_dtype)
    return (g * u) @ params["w_down"].astype(compute_dtype)


def _plan(cfg: ModelConfig, idx: jnp.ndarray, t: int):
    """Virtual-slot dispatch plan: (v_idx, n_buckets, cap, frag)."""
    sl = slotting_for(cfg)
    if sl is None:
        return idx, cfg.n_experts, capacity(cfg, t, cfg.n_experts), 1
    return (virtual_indices(idx, sl), sl.n_virtual,
            capacity(cfg, t, sl.e_pad), sl.frag)


def _combine(gathered: jnp.ndarray, weights: jnp.ndarray, t: int, k: int,
             frag: int, compute_dtype) -> jnp.ndarray:
    """(T*K*frag, d) copy outputs -> (T, d): sum fragments, weight top-K."""
    per_copy = gathered.reshape(t, k, frag, -1).sum(axis=2)
    return jnp.einsum("tkd,tk->td", per_copy, weights.astype(compute_dtype))


def moe_apply_local(cfg: ModelConfig, params: dict, x: jnp.ndarray,
                    compute_dtype) -> tuple[jnp.ndarray, dict]:
    """Single-shard MoE: x (B, S, d) -> (B, S, d).  Test oracle + CPU path."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d).astype(compute_dtype)
    weights, idx, aux = route(cfg, params["router"], xt)
    v_idx, n_b, cap, frag = _plan(cfg, idx, t)
    slot_token, slot_valid, copy_slot, copy_kept = dispatch_indices(
        v_idx, n_b, cap
    )
    copies = jnp.repeat(xt, cfg.top_k * frag, axis=0)         # (T*K*frag, d)
    buckets = copies[slot_token] * slot_valid[:, None].astype(compute_dtype)
    buckets = buckets.reshape(n_b, cap, d)
    outs = expert_ffn(params, buckets, compute_dtype)
    flat_out = outs.reshape(n_b * cap, d)
    gathered = flat_out[copy_slot] * copy_kept[:, None].astype(compute_dtype)
    y = _combine(gathered, weights, t, cfg.top_k, frag, compute_dtype)
    if cfg.n_shared_experts > 0:
        y = y + _shared_ffn(params["shared"], xt, compute_dtype)
    return y.reshape(b, s, d), aux


# --------------------------------------------------------------------- #
# Expert-parallel path (runs inside shard_map over the EP axis)
# --------------------------------------------------------------------- #


def sharded_moe(fn, mesh, in_specs, out_specs):
    """Wrap an EP body (``moe_apply_ep`` / ``moe_apply_ep_replicated``
    partial) in ``shard_map`` via the version-compat shim.

    Replication checking is disabled: the aux outputs are per-shard sums
    the caller combines, which the checker would reject as unreplicated.
    """
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_vma=False)


def moe_apply_ep(cfg: ModelConfig, params: dict, x_local: jnp.ndarray,
                 axis_name: str, compute_dtype) -> tuple[jnp.ndarray, dict]:
    """EP MoE body. ``x_local``: this shard's (B_loc, S_loc, d) slice; the
    stacked expert params carry only the local expert group (E_loc, ...).

    Pipeline: route -> bucket by *global* expert slot -> all_to_all (split
    by owner device) -> local expert FFN -> reverse all_to_all -> combine.
    """
    n_dev = axis_size(axis_name)
    b, s, d = x_local.shape
    t = b * s
    loc = params["w_gate"].shape[0]          # local buckets (experts/slots)
    xt = x_local.reshape(t, d).astype(compute_dtype)
    weights, idx, aux = route(cfg, params["router"], xt)
    v_idx, n_b, cap, frag = _plan(cfg, idx, t)
    if n_b != loc * n_dev:
        raise ValueError(f"bucket count {n_b} != {loc}x{n_dev} local stacks")

    slot_token, slot_valid, copy_slot, copy_kept = dispatch_indices(
        v_idx, n_b, cap
    )
    copies = jnp.repeat(xt, cfg.top_k * frag, axis=0)
    buckets = copies[slot_token] * slot_valid[:, None].astype(compute_dtype)
    buckets = buckets.reshape(n_dev, loc, cap, d)             # dest-device major

    # exchange buckets: after a2a, axis 0 indexes the *source* device.
    recv = jax.lax.all_to_all(buckets, axis_name, split_axis=0, concat_axis=0,
                              tiled=False)
    recv = recv.reshape(n_dev, loc, cap, d).transpose(1, 0, 2, 3)
    recv = recv.reshape(loc, n_dev * cap, d)
    outs = expert_ffn(params, recv, compute_dtype)            # (loc, n*C, d)
    back = outs.reshape(loc, n_dev, cap, d).transpose(1, 0, 2, 3)
    home = jax.lax.all_to_all(back, axis_name, split_axis=0, concat_axis=0,
                              tiled=False)
    flat_out = home.reshape(n_b * cap, d)

    gathered = flat_out[copy_slot] * copy_kept[:, None].astype(compute_dtype)
    y = _combine(gathered, weights, t, cfg.top_k, frag, compute_dtype)
    if cfg.n_shared_experts > 0:
        y = y + _shared_ffn(params["shared"], xt, compute_dtype)
    return y.reshape(b, s, d), aux


def moe_apply_ep_replicated(cfg: ModelConfig, params: dict,
                            x_local: jnp.ndarray, axis_name: str,
                            compute_dtype) -> tuple[jnp.ndarray, dict]:
    """EP for replicated activations (decode path).

    Tokens are identical on every device of the EP axis (the usual decode
    layout: batch over data, activations replicated over model).  Each
    device routes all tokens but computes only its local expert group; a
    single psum combines.  Communication = one all-reduce of (T, d) —
    no all-to-all, which is the right trade at S=1.
    """
    n_dev = axis_size(axis_name)
    my = jax.lax.axis_index(axis_name)
    b, s, d = x_local.shape
    t = b * s
    loc = params["w_gate"].shape[0]
    xt = x_local.reshape(t, d).astype(compute_dtype)
    weights, idx, aux = route(cfg, params["router"], xt)
    v_idx, n_b, cap, frag = _plan(cfg, idx, t)
    if n_b != loc * n_dev:
        raise ValueError(f"bucket count {n_b} != {loc}x{n_dev} local stacks")

    # Map global bucket ids to local ids; foreign copies go to a trash
    # bucket (local id loc) whose output is forced to zero.
    is_mine = (v_idx // loc) == my
    local_idx = jnp.where(is_mine, v_idx - my * loc, loc)
    slot_token, slot_valid, copy_slot, copy_kept = dispatch_indices(
        local_idx, loc + 1, cap
    )
    copies = jnp.repeat(xt, cfg.top_k * frag, axis=0)
    buckets = copies[slot_token] * slot_valid[:, None].astype(compute_dtype)
    buckets = buckets.reshape(loc + 1, cap, d)
    outs = expert_ffn(params, buckets[:loc], compute_dtype)
    outs = jnp.concatenate(
        [outs, jnp.zeros((1, cap, d), outs.dtype)], axis=0
    )                                                   # zero trash bucket
    flat_out = outs.reshape((loc + 1) * cap, d)
    gathered = flat_out[copy_slot] * copy_kept[:, None].astype(compute_dtype)
    y = _combine(gathered, weights, t, cfg.top_k, frag, compute_dtype)
    y = jax.lax.psum(y, axis_name)
    if cfg.n_shared_experts > 0:
        y = y + _shared_ffn(params["shared"], xt, compute_dtype)  # replicated
    return y.reshape(b, s, d), aux


# --------------------------------------------------------------------- #
# SpaceMoE placement as a checkpoint transform
# --------------------------------------------------------------------- #


def apply_placement(moe_params: dict, slot_to_expert: np.ndarray) -> dict:
    """Permute a MoE layer's weights so EP slot s hosts expert
    ``slot_to_expert[s]`` (a ``DevicePlacementPlan.expert_perm``).

    The router columns are permuted identically, so routing semantics are
    unchanged: logits[slot] == original logits[slot_to_expert[slot]].
    """
    perm = jnp.asarray(slot_to_expert)
    out = dict(moe_params)
    out["router"] = moe_params["router"][:, perm]
    for name in ("w_gate", "w_up", "w_down"):
        out[name] = moe_params[name][perm]
    return out
