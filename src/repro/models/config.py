"""Model configuration for the composable LM zoo.

A model is a stack of ``n_layers`` blocks described by a repeating
``pattern`` of (mixer, ffn) pairs — this one abstraction covers all ten
assigned architectures (dense / GQA / MoE / Mamba-hybrid / xLSTM) plus the
paper's LLaMA-MoE.  ``len(pattern)`` must divide ``n_layers``; the stack is
executed as ``lax.scan`` over ``n_units = n_layers // len(pattern)`` units
so HLO size is O(pattern), not O(depth).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One block of the repeating pattern."""

    mixer: str = "attn"     # "attn" | "mamba" | "mlstm" | "slstm"
    ffn: str = "dense"      # "dense" | "moe" | "none"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    head_dim: int = 0                    # 0 => d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    first_layer_dense: bool = False      # deepseek-moe: layer 0 is dense FFN
    first_dense_d_ff: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0

    # --- attention ---
    qkv_bias: bool = False               # qwen2.5
    rope_theta: float = 10000.0
    attn_logit_softcap: float = 0.0
    sliding_window: int = 0              # 0 => full causal

    # --- SSM / recurrent ---
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_d_conv: int = 4
    mamba_dt_rank: int = 0               # 0 => ceil(d_model / 16)

    # --- embeddings / head ---
    tie_embeddings: bool = False
    vocab_pad_multiple: int = 128

    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-5

    # --- modality frontend (stub; see models/frontends.py) ---
    frontend: str = ""                  # "" | "vision" | "audio"

    # --- execution knobs (perf pass) ---
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    remat: str = "unit"                  # "none" | "unit"
    moe_impl: str = "einsum"             # "einsum" (GShard-style) | "ragged"
    moe_slotting: bool = False           # EP slot layout (pad/fragment) so
    moe_ep_slots: int = 16               #   any E runs expert-parallel
    flash_vjp: bool = False              # custom-VJP flash attention (bwd
    #   recomputes P chunk-wise instead of saving it; see attention.py)
    use_pallas_decode: bool = False      # decode attention via the Pallas
    #   flash-decode kernel (kernels/decode_attn); interpret-mode on CPU

    def __post_init__(self):
        if self.n_layers % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: len(pattern)={len(self.pattern)} must divide "
                f"n_layers={self.n_layers}"
            )
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.mamba_dt_rank == 0:
            object.__setattr__(self, "mamba_dt_rank", -(-self.d_model // 16))
        if any(s.ffn == "moe" for s in self.pattern):
            if self.n_experts <= 0 or self.top_k <= 0:
                raise ValueError(f"{self.name}: MoE pattern needs n_experts/top_k")
            if self.d_ff_expert == 0:
                object.__setattr__(self, "d_ff_expert", self.d_ff)

    # ------------------------------------------------------------------ #
    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_multiple)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def has_moe(self) -> bool:
        return any(s.ffn == "moe" for s in self.pattern)

    @property
    def is_recurrent(self) -> bool:
        """True if every mixer carries O(1) decode state (no KV growth)."""
        return all(s.mixer in ("mamba", "mlstm", "slstm") for s in self.pattern)

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic prefill / O(1)-ish decode state per the assignment:
        SSM / hybrid archs run long_500k; pure full-attention archs skip."""
        return any(s.mixer in ("mamba", "mlstm", "slstm") for s in self.pattern)

    # -- parameter counting (for roofline MODEL_FLOPS = 6*N*D) ----------- #
    def param_counts(self) -> dict[str, float]:
        """Approximate parameter counts: total and active-per-token."""
        d = self.d_model
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        total = float(emb)
        active = float(emb)
        for i in range(self.n_layers):
            spec = self.pattern[i % len(self.pattern)]
            if i == 0 and self.first_layer_dense:
                spec = LayerSpec(mixer=spec.mixer, ffn="dense")
                dff = self.first_dense_d_ff or self.d_ff
            else:
                dff = self.d_ff
            if spec.mixer == "attn":
                p = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
            elif spec.mixer == "mamba":
                di = self.d_inner
                p = d * 2 * di + di * self.mamba_d_conv \
                    + di * (self.mamba_dt_rank + 2 * self.mamba_d_state) \
                    + self.mamba_dt_rank * di + di * self.mamba_d_state + di * d
            else:  # mlstm / slstm
                di = self.d_inner
                p = d * 3 * di + 3 * di + di * d   # qkv-ish + gates + out
            total += p
            active += p
            if spec.ffn == "dense":
                total += 3 * d * dff
                active += 3 * d * dff
            elif spec.ffn == "moe":
                e = 3 * d * self.d_ff_expert
                total += self.n_experts * e + self.n_shared_experts * e \
                    + d * self.n_experts
                active += self.top_k * e + self.n_shared_experts * e \
                    + d * self.n_experts
        return {"total": total, "active": active}
