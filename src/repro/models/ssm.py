"""Recurrent mixers: Mamba (selective SSM), mLSTM and sLSTM (xLSTM).

All three expose the same triplet of entry points:
  *_init(key, cfg, dtype)                  -> params
  *_forward(cfg, params, x, compute_dtype) -> y          (train/prefill)
  *_init_state / *_decode(...)             -> O(1) decode state + step

Sequence processing uses ``lax.scan`` over time — correct and HLO-compact;
the per-step state is exactly the decode state, so prefill and decode
cannot drift apart.  These mixers carry no KV cache, which is what makes
``long_500k`` decode feasible for jamba/xlstm.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import normal_init, out_proj_init


def _pin(x, par, *dims):
    """Sharding-constrain a scan input/carry so per-time-step ops stay
    local (without this, propagation can put an all-gather inside every
    step — measured 520k collectives per jamba train step).

    dims entries: "b" batch axes, "m" model axis, None replicated —
    divisibility-guarded.
    """
    if par is None or getattr(par, "mesh", None) is None:
        return x
    mesh = par.mesh
    n_data = 1
    for a in par.data_axes:
        n_data *= mesh.shape[a]
    baxes = par.data_axes if len(par.data_axes) > 1 else par.data_axes[0]
    spec = []
    for dim, want in zip(x.shape, dims):
        if want == "b" and n_data > 1 and dim % n_data == 0:
            spec.append(baxes)
        elif want == "m" and dim % mesh.shape[par.model_axis] == 0 \
                and mesh.shape[par.model_axis] > 1:
            spec.append(par.model_axis)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))

# ===================================================================== #
# Mamba (selective state-space, Mamba-1)
# ===================================================================== #


def mamba_init(key, cfg: ModelConfig, dtype) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    n, r, dc = cfg.mamba_d_state, cfg.mamba_dt_rank, cfg.mamba_d_conv
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A.
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "w_in": normal_init(ks[0], (d, 2 * di), dtype),
        "conv_w": normal_init(ks[1], (dc, di), dtype, scale=0.1),
        "conv_b": jnp.zeros((di,), dtype),
        "w_x_proj": normal_init(ks[2], (di, r + 2 * n), dtype),
        "w_dt": normal_init(ks[3], (r, di), dtype, scale=r**-0.5),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01))).astype(jnp.float32),
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": out_proj_init(ks[4], (di, d), dtype, cfg.n_layers),
    }


def _mamba_scan_step(a_neg, carry, xt, dt, b_t, c_t):
    """One SSM step.  carry h: (B, di, N); xt/dt: (B, di); b/c: (B, N)."""
    da = jnp.exp(dt[..., None] * a_neg[None])                 # (B, di, N)
    dbx = dt[..., None] * b_t[:, None, :] * xt[..., None]     # (B, di, N)
    h = da * carry + dbx
    y = jnp.einsum("bdn,bn->bd", h, c_t)
    return h, y


def _mamba_inner(cfg, params, xz, conv_state, ssm_state, compute_dtype,
                 par=None):
    """Shared conv+SSM core.  xz: (B, S, 2*di).  States carried across calls.

    conv_state: (B, dc-1, di) trailing inputs; ssm_state: (B, di, N).
    Returns (y (B,S,di), new_conv_state, new_ssm_state).
    """
    di, n = cfg.d_inner, cfg.mamba_d_state
    x, z = jnp.split(xz, 2, axis=-1)                          # (B, S, di)
    b, s, _ = x.shape
    dc = cfg.mamba_d_conv

    # Causal depthwise conv along S with carried state.
    xpad = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    conv_w = params["conv_w"].astype(x.dtype)                 # (dc, di)
    xc = sum(xpad[:, i : i + s, :] * conv_w[i] for i in range(dc))
    xc = jax.nn.silu(xc + params["conv_b"].astype(x.dtype))
    new_conv_state = xpad[:, s:, :] if dc > 1 else conv_state
    xc = _pin(xc, par, "b", None, "m")

    proj = xc @ params["w_x_proj"].astype(x.dtype)            # (B,S,r+2N)
    dt_r, b_ssm, c_ssm = jnp.split(
        proj, [cfg.mamba_dt_rank, cfg.mamba_dt_rank + n], axis=-1
    )
    # B/C/dt are tiny (N=16, r<=512): replicate them so the per-step scan
    # math is collective-free; di stays model-sharded.
    b_ssm = _pin(b_ssm, par, "b", None, None)
    c_ssm = _pin(c_ssm, par, "b", None, None)
    dt = jax.nn.softplus(
        (dt_r @ params["w_dt"].astype(x.dtype)).astype(jnp.float32)
        + params["dt_bias"]
    )                                                         # (B,S,di) fp32
    dt = _pin(dt, par, "b", None, "m")
    ssm_state = _pin(ssm_state, par, "b", "m", None)
    a_neg = -jnp.exp(params["a_log"])                         # (di, N)

    def step(h, inp):
        xt, dtt, bt, ct = inp
        h, y = _mamba_scan_step(a_neg, h, xt.astype(jnp.float32), dtt,
                                bt.astype(jnp.float32), ct.astype(jnp.float32))
        return h, y

    xs = (
        xc.transpose(1, 0, 2), dt.transpose(1, 0, 2),
        b_ssm.transpose(1, 0, 2), c_ssm.transpose(1, 0, 2),
    )
    h_last, ys = jax.lax.scan(step, ssm_state, xs)
    y = ys.transpose(1, 0, 2).astype(compute_dtype)           # (B,S,di)
    y = y + xc * params["d_skip"].astype(compute_dtype)
    y = y * jax.nn.silu(z)
    return y, new_conv_state.astype(jnp.float32), h_last


def mamba_forward(cfg, params, x, compute_dtype, par=None):
    b = x.shape[0]
    st = mamba_init_state(cfg, b)
    xz = x.astype(compute_dtype) @ params["w_in"].astype(compute_dtype)
    y, _, _ = _mamba_inner(cfg, params, xz, st["conv"], st["ssm"],
                           compute_dtype, par)
    return y @ params["w_out"].astype(compute_dtype)


def mamba_init_state(cfg: ModelConfig, batch: int) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, cfg.d_inner), jnp.float32),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.mamba_d_state), jnp.float32),
    }


def mamba_decode(cfg, params, x, state, compute_dtype, par=None):
    """x: (B, 1, d) -> (y (B,1,d), new state)."""
    xz = x.astype(compute_dtype) @ params["w_in"].astype(compute_dtype)
    y, conv, ssm = _mamba_inner(cfg, params, xz, state["conv"], state["ssm"],
                                compute_dtype, par)
    return y @ params["w_out"].astype(compute_dtype), {"conv": conv, "ssm": ssm}


# ===================================================================== #
# mLSTM (xLSTM matrix-memory block)
# ===================================================================== #


def mlstm_init(key, cfg: ModelConfig, dtype) -> dict:
    """Separate q/k/v projections so TP can shard the matrix memory by
    ROWS (v-index): C = f*C + i*(v k^T) and h = C q stay local per step
    when v/C-rows/h are model-sharded and q/k/n are replicated — zero
    collectives inside the time scan."""
    d, di = cfg.d_model, cfg.d_inner
    ks = jax.random.split(key, 6)
    return {
        "w_q_m": normal_init(ks[0], (d, di), dtype),
        "w_k_m": normal_init(ks[1], (d, di), dtype),
        "w_v_m": normal_init(ks[2], (d, di), dtype),
        "w_gates": normal_init(ks[3], (d, 2 * cfg.n_heads), jnp.float32),
        "b_gates": jnp.concatenate(
            [jnp.zeros((cfg.n_heads,)), jnp.full((cfg.n_heads,), 3.0)]
        ),  # forget-gate bias init high (remember by default)
        "w_z": normal_init(ks[4], (d, di), dtype),
        "w_out": out_proj_init(ks[5], (di, d), dtype, cfg.n_layers),
    }


def mlstm_init_state(cfg: ModelConfig, batch: int) -> dict:
    nh = cfg.n_heads
    dh = cfg.d_inner // nh
    return {
        "c": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }


def _mlstm_step(carry, inp):
    """Stabilized exponential-gating matrix-memory update."""
    c, n, m = carry
    q, k, v, log_i, log_f = inp        # q/k/v: (B,NH,dh); gates: (B,NH)
    m_new = jnp.maximum(log_f + m, log_i)
    i_g = jnp.exp(log_i - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c = f_g[..., None, None] * c + i_g[..., None, None] * (
        v[..., :, None] * k[..., None, :]
    )                                   # (B,NH,dh,dh) += v k^T  (row = v idx)
    n = f_g[..., None] * n + i_g[..., None] * k
    num = jnp.einsum("bhij,bhj->bhi", c, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, q)), 1.0)
    h = num / den[..., None]
    return (c, n, m_new), h


def _mlstm_core(cfg, params, x, state, compute_dtype, par=None):
    b, s, _ = x.shape
    nh = cfg.n_heads
    dh = cfg.d_inner // nh
    xq = x.astype(compute_dtype)
    q = xq @ params["w_q_m"].astype(compute_dtype)
    k = xq @ params["w_k_m"].astype(compute_dtype)
    v = xq @ params["w_v_m"].astype(compute_dtype)
    scale = dh ** -0.5
    q = q.reshape(b, s, nh, dh).astype(jnp.float32)
    k = (k.reshape(b, s, nh, dh) * scale).astype(jnp.float32)
    v = v.reshape(b, s, nh, dh).astype(jnp.float32)
    gates = xq.astype(jnp.float32) @ params["w_gates"] + params["b_gates"]
    log_i, f_raw = jnp.split(gates, 2, axis=-1)               # (B,S,NH)
    log_f = -jax.nn.softplus(-f_raw)                          # log sigmoid

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (q, k, v)) + tuple(
        a.transpose(1, 0, 2) for a in (log_i, log_f)
    )
    carry0 = (state["c"], state["n"], state["m"])
    (c, n, m), hs = jax.lax.scan(_mlstm_step, carry0, xs)
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, cfg.d_inner).astype(compute_dtype)
    z = jax.nn.silu(xq @ params["w_z"].astype(compute_dtype))
    y = (h * z) @ params["w_out"].astype(compute_dtype)
    return y, {"c": c, "n": n, "m": m}


def mlstm_forward(cfg, params, x, compute_dtype, par=None):
    y, _ = _mlstm_core(cfg, params, x, mlstm_init_state(cfg, x.shape[0]),
                       compute_dtype, par)
    return y


def mlstm_decode(cfg, params, x, state, compute_dtype, par=None):
    return _mlstm_core(cfg, params, x, state, compute_dtype, par)


# ===================================================================== #
# sLSTM (xLSTM scalar-memory block with per-head recurrence)
# ===================================================================== #


def slstm_init(key, cfg: ModelConfig, dtype) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    nh = cfg.n_heads
    dh = di // nh
    ks = jax.random.split(key, 3)
    return {
        "w_x": normal_init(ks[0], (d, 4 * di), dtype),
        # block-diagonal recurrent weights, one (dh, 4*dh) block per head
        "r_h": normal_init(ks[1], (nh, dh, 4 * dh), jnp.float32, scale=dh**-0.5),
        "b": jnp.concatenate(
            [jnp.zeros((2 * di,)), jnp.full((di,), 3.0), jnp.zeros((di,))]
        ),  # (z, i, f, o) biases; forget bias high
        "w_out": out_proj_init(ks[2], (di, d), dtype, cfg.n_layers),
    }


def slstm_init_state(cfg: ModelConfig, batch: int) -> dict:
    nh = cfg.n_heads
    dh = cfg.d_inner // nh
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return {"c": z, "n": z + 1e-6, "h": z,
            "m": jnp.full((batch, nh, dh), -1e30, jnp.float32)}


def _slstm_step(params_rh, carry, x_gates):
    """x_gates: (B, 4*di) pre-activations from the input path."""
    c, n, h, m = carry                 # each (B, NH, dh)
    b = c.shape[0]
    nh, dh = c.shape[1], c.shape[2]
    rec = jnp.einsum("bhd,hdk->bhk", h, params_rh)            # (B,NH,4dh)
    pre = x_gates.reshape(b, nh, 4 * dh) + rec
    z_p, i_p, f_p, o_p = jnp.split(pre, 4, axis=-1)
    log_i = i_p
    log_f = -jax.nn.softplus(-f_p)     # log sigmoid
    m_new = jnp.maximum(log_f + m, log_i)
    i_g = jnp.exp(log_i - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    z_v = jnp.tanh(z_p)
    c_new = f_g * c + i_g * z_v
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(o_p) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def _slstm_core(cfg, params, x, state, compute_dtype):
    b, s, _ = x.shape
    pre = (x.astype(compute_dtype) @ params["w_x"].astype(compute_dtype)
           ).astype(jnp.float32) + params["b"]
    xs = pre.transpose(1, 0, 2)        # (S, B, 4di)

    def step(carry, xg):
        return _slstm_step(params["r_h"], carry, xg)

    carry0 = (state["c"], state["n"], state["h"], state["m"])
    (c, n, h, m), hs = jax.lax.scan(step, carry0, xs)
    y = hs.transpose(1, 0, 2, 3).reshape(b, s, cfg.d_inner).astype(compute_dtype)
    y = y @ params["w_out"].astype(compute_dtype)
    return y, {"c": c, "n": n, "h": h, "m": m}


def slstm_forward(cfg, params, x, compute_dtype):
    y, _ = _slstm_core(cfg, params, x, slstm_init_state(cfg, x.shape[0]),
                       compute_dtype)
    return y


def slstm_decode(cfg, params, x, state, compute_dtype):
    return _slstm_core(cfg, params, x, state, compute_dtype)
