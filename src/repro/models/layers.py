"""Shared primitives: norms, initializers, rotary embeddings, FFN."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# --------------------------------------------------------------------- #
# Initializers
# --------------------------------------------------------------------- #


def normal_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def out_proj_init(key, shape, dtype, n_layers: int, scale: float = 0.02):
    """GPT-2 style residual-branch scaling."""
    return (scale / np.sqrt(2 * n_layers) * jax.random.normal(key, shape)).astype(dtype)


# --------------------------------------------------------------------- #
# RMSNorm
# --------------------------------------------------------------------- #


def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    orig = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(orig)


# --------------------------------------------------------------------- #
# Rotary position embeddings
# --------------------------------------------------------------------- #


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim // 2,), float32."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate q/k.  x: (..., seq, n_heads, head_dim); positions: (..., seq)."""
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)                  # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                           # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    rotated = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return rotated.astype(x.dtype)


# --------------------------------------------------------------------- #
# Gated FFN (SwiGLU)
# --------------------------------------------------------------------- #


def ffn_init(key, d_model: int, d_ff: int, n_layers: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": normal_init(k1, (d_model, d_ff), dtype),
        "w_up": normal_init(k2, (d_model, d_ff), dtype),
        "w_down": out_proj_init(k3, (d_ff, d_model), dtype, n_layers),
    }


def ffn_apply(params: dict, x: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    x = x.astype(compute_dtype)
    gate = jax.nn.silu(x @ params["w_gate"].astype(compute_dtype))
    up = x @ params["w_up"].astype(compute_dtype)
    return (gate * up) @ params["w_down"].astype(compute_dtype)


# --------------------------------------------------------------------- #
# Embedding / LM head
# --------------------------------------------------------------------- #


def embedding_init(key, vocab: int, d_model: int, dtype) -> jnp.ndarray:
    return normal_init(key, (vocab, d_model), dtype)


def embed(table: jnp.ndarray, tokens: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0).astype(compute_dtype)


def lm_head(table_or_w: jnp.ndarray, x: jnp.ndarray, tied: bool) -> jnp.ndarray:
    w = table_or_w.astype(x.dtype)
    return x @ (w.T if tied else w)
