"""Fused fleet simulator: one-launch run_many sweep vs the legacy host loop.

The saturation-sweep workload of ``bench_traffic`` (smoke scenario at an
8x envelope rate, nested thinning masks) is executed twice on one shared
:class:`FleetSim` precompute:

* **legacy** — the pre-fusion per-fraction Python loop
  (``run_legacy`` per mask: host schedule/bin/gather, device scan, a
  (P, S, T) host<->device transfer per fixed-point iteration);
* **fused** — one ``run_many`` call: the whole sweep is a single compile
  + a single device launch of the fused fixed point, vmapped over the
  fraction axis.

The bench asserts fused<->legacy parity (identical served/shed sets,
goodput equal to 1e-9, TTFT/E2E quantiles within rtol 1e-5) and **fails
hard on deviation** — CI runs it as the fleet-path regression gate.  It
also reports per-stage legacy timings (schedule / bin / scan / gather)
so the JSON artifact tracks where the host loop spends its time, and a
before/after timing of the off-TPU deposit stage: the inline
``.at[].add`` scatter ("ref", the default off TPU) vs the row-bucketed
``segment_sum`` path (``deposit_impl="segments"``) over the sweep's
real compacted chunk triples — the measurement that keeps the segments
path opt-in.

    PYTHONPATH=src python -m benchmarks.run --fast --only fleet
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.traffic import FleetSim, get_scenario
from repro.traffic import queueing

from .bench_traffic import _plans, _world
from .common import Timer, emit

#: Thinning fractions of the envelope trace (the bench_traffic sweep).
FRACTIONS = np.array([0.1, 0.15, 0.2, 0.25, 0.3, 0.35, 0.4, 0.5,
                      0.6, 0.8, 1.0])


def _stage_times(sim: FleetSim, active: np.ndarray) -> dict:
    """Wall-time one legacy fixed-point pass, stage by stage."""
    P, M, L = sim.n_plans, sim.n_tokens, sim.n_layers
    z = np.zeros((P, M, L))
    with Timer() as t_sched:
        layer_arr, exp_arr, *_ = sim._schedule(z, z, sim.start_pref)
    with Timer() as t_bin:
        work = sim._bin_work(layer_arr, exp_arr,
                             np.broadcast_to(active[None, :],
                                             (P, sim.n_requests)))
    # No x64 scope: the legacy scan's inputs downcast to f32, exactly
    # as in run_legacy — time the kernel that actually runs.
    w = jnp.asarray(work)
    cap = jnp.asarray(sim.qcfg.buffer_s)
    jax.block_until_ready(
        queueing._fleet_queue_scan(w, cap, sim.qcfg.dt_s))      # compile
    with Timer() as t_scan:
        wait, dropped = queueing._fleet_queue_scan(w, cap, sim.qcfg.dt_s)
        jax.block_until_ready(wait)
    wait = np.asarray(wait)
    overload = np.asarray(dropped) > 0.0
    with Timer() as t_gather:
        sim._gather(wait, overload, layer_arr, exp_arr)
    return {
        "schedule_s": round(t_sched.seconds, 4),
        "bin_work_s": round(t_bin.seconds, 4),
        "scan_s": round(t_scan.seconds, 4),
        "gather_s": round(t_gather.seconds, 4),
    }


def _deposit_stage_times(sim: FleetSim, masks: np.ndarray) -> dict:
    """Before/after wall time of the fused deposit stage off TPU.

    Rebuilds the sweep's compacted chunk table exactly as ``_launch``
    does (the iteration-1 static bins), then times the inline
    scatter-add ("ref" — the off-TPU default) against the row-bucketed
    ``segment_sum`` path ("segments") on the identical COO triples.
    Both run under x64 like the fused launch itself.
    """
    from jax.experimental import enable_x64

    from repro.kernels import ops as kernel_ops

    F = masks.shape[0]
    T, SR = sim.n_bins, sim.n_rows
    f_id, cid = np.nonzero(masks[:, sim._f_req])
    fprow = (f_id.astype(np.int32) * SR
             + sim._f_rowc[cid].astype(np.int32))
    bins = sim._f_bins0[cid]
    vals = sim._f_work[cid] * sim._f_fin0[cid]
    with enable_x64():
        rows_d = jnp.asarray(fprow)
        bins_d = jnp.asarray(bins.astype(np.int64))
        vals_d = jnp.asarray(vals)
        flat = rows_d.astype(jnp.int64) * T + bins_d

        @jax.jit
        def ref_scat(fl, v):
            return jnp.zeros(F * SR * T).at[fl].add(
                v, mode="promise_in_bounds")

        def seg_scat(r, b, v):
            return kernel_ops.deposit_segments(r, b, v, F * SR, T)

        t_ref = kernel_ops.timed_call(ref_scat, flat, vals_d)
        t_seg = kernel_ops.timed_call(seg_scat, rows_d, bins_d, vals_d)
        parity = bool(np.array_equal(
            np.asarray(ref_scat(flat, vals_d)).reshape(F * SR, T),
            np.asarray(seg_scat(rows_d, bins_d, vals_d))))
    return {
        "n_chunks": int(cid.size),
        "n_rows": F * SR,
        "n_bins": T,
        "ref_s": round(t_ref, 4),
        "segments_s": round(t_seg, 4),
        "speedup": round(t_ref / max(t_seg, 1e-9), 2),
        "bitwise_ok": parity,
    }


def _check_parity(legacy: list, fused: list) -> list[str]:
    """Fused vs legacy per (fraction, plan): served/shed sets must be
    identical, goodput equal to 1e-9, latency quantiles within 1e-5."""
    problems = []
    for f, (rl, rf) in enumerate(zip(legacy, fused)):
        for pl_, pf in zip(rl.plans, rf.plans):
            tag = f"f={f} plan={pl_.plan_name}"
            if not np.array_equal(pl_.served, pf.served):
                problems.append(f"{tag}: served sets differ")
            if (pl_.shed is None) != (pf.shed is None) or (
                    pl_.shed is not None
                    and not np.array_equal(pl_.shed, pf.shed)):
                problems.append(f"{tag}: shed sets differ")
            if not np.isclose(pl_.goodput_tok_s, pf.goodput_tok_s,
                              rtol=1e-9, atol=1e-12):
                problems.append(f"{tag}: goodput {pl_.goodput_tok_s} vs "
                                f"{pf.goodput_tok_s}")
            for which in ("ttft", "e2e"):
                for q in (0.5, 0.99):
                    a, b = pl_.quantile(which, q), pf.quantile(which, q)
                    same = (np.isnan(a) and np.isnan(b)) or \
                        np.isclose(a, b, rtol=1e-5)
                    if not same:
                        problems.append(
                            f"{tag}: p{q:g} {which} {a} vs {b}")
    return problems


def run(fast: bool = True, json_path: str | None = None) -> dict:
    """Time the fused sweep against the legacy loop; emit BENCH_fleet rows.

    Returns the JSON-able summary (speedups, per-stage legacy timings,
    parity verdict).  Raises SystemExit when the fused/legacy parity
    check deviates, so CI smoke fails on fleet-path regressions.
    """
    con, topo, activ, wl, comp, ground = _world(fast)
    plans = _plans(con, topo, activ)[:2]
    sc = dataclasses.replace(get_scenario("smoke"),
                             horizon_s=60.0 if fast else 120.0,
                             tail_s=60.0, kv_slots=8)
    requests = sc.requests(np.random.default_rng(13), ground.n_stations,
                           rate_scale=8.0)
    slot_period = con.cfg.orbital_period_s / topo.n_slots
    with Timer() as t_build:
        sim = FleetSim(plans, topo, activ, wl, comp, requests,
                       np.random.default_rng(13),
                       qcfg=sc.queue_config(slot_period), ground=ground)
    u = np.random.default_rng(17).random(requests.n_requests)
    masks = u[None, :] < FRACTIONS[:, None]

    with Timer() as t_legacy:
        legacy = [sim.run_legacy(active=m) for m in masks]
    stages = _stage_times(sim, masks[-1])
    deposit_stage = _deposit_stage_times(sim, masks)
    with Timer() as t_first:             # compile + launch
        fused = sim.run_many(masks)
    with Timer() as t_steady:            # cached compile, one launch
        fused = sim.run_many(masks)

    problems = _check_parity(legacy, fused)
    if not deposit_stage["bitwise_ok"]:
        problems.append("deposit segments path deviates from ref scatter")
    speedup = t_legacy.seconds / max(t_steady.seconds, 1e-9)
    speedup_cold = t_legacy.seconds / max(t_first.seconds, 1e-9)
    out = {
        "fast": fast,
        "n_requests": requests.n_requests,
        "n_rates": len(FRACTIONS),
        "n_bins": sim.n_bins,
        "build_s": round(t_build.seconds, 3),
        "legacy_sweep_s": round(t_legacy.seconds, 3),
        "fused_first_s": round(t_first.seconds, 3),
        "fused_steady_s": round(t_steady.seconds, 3),
        "speedup_steady": round(speedup, 2),
        "speedup_with_compile": round(speedup_cold, 2),
        "legacy_stages": stages,
        "deposit_stage": deposit_stage,
        "parity_ok": not problems,
        "parity_problems": problems,
    }
    emit("fleet/legacy_sweep", t_legacy.seconds * 1e6,
         f"n_rates={len(FRACTIONS)}")
    emit("fleet/fused_sweep", t_steady.seconds * 1e6,
         f"speedup={speedup:.1f}x;with_compile={speedup_cold:.1f}x")
    print(f"# fused fleet sweep: {speedup:.1f}x over the legacy loop "
          f"({t_legacy.seconds:.2f}s -> {t_steady.seconds:.2f}s steady, "
          f"{t_first.seconds:.2f}s incl. compile); legacy stages {stages}")
    print(f"# deposit stage (off-TPU scatter relief): "
          f"ref {deposit_stage['ref_s']}s -> segments "
          f"{deposit_stage['segments_s']}s "
          f"({deposit_stage['speedup']}x, "
          f"bitwise_ok={deposit_stage['bitwise_ok']})")

    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
    if problems:
        for p in problems:
            print(f"# PARITY DEVIATION: {p}")
        raise SystemExit("bench_fleet: fused/legacy parity check failed")
    return out


if __name__ == "__main__":
    run()
