"""Joint control plane: one-launch control grid vs the per-cell host loop.

A full 3x3x3 controller grid — decision **cadence** x **migration
budget** x **admission TTFT target** — runs two ways on one world and
candidate pool:

* **host** — the pinned decide law walked round by round per cell
  (:func:`repro.traffic.replan.replan_traffic`), one controller run per
  grid point: the pre-fusion cost of tuning the joint controller;
* **fused** — one :meth:`repro.traffic.queueing.FleetSim
  .run_replan_grid` call: all 27 cells batched along the leading axis of
  a single device program (``FUSED_TRACE_COUNT`` must move by exactly
  one — the one-launch acceptance pin).

The bench checks per-cell **decision parity** — identical slot plans,
switch boundaries, incumbent sequences, scores and migration bytes in
every cell — and **fails hard on deviation or on a multi-trace grid**
(CI runs it as the control-plane regression gate).  Wall-clock speedup
(the PR targets >=5x steady-state over the host loop) is reported and
tracked as an artifact, not gated: it is machine-dependent.

    PYTHONPATH=src python -m benchmarks.run --fast --only ctrl
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        rand_intra_cg_plan, sample_topology, spacemoe_plan)
from repro.traffic import (AdmissionConfig, FleetSim, QueueConfig,
                           ReplanConfig, replan_traffic, sample_requests)
from repro.traffic import queueing
from repro.traffic.replan import build_replan_schedule, replan_base_scores

from .common import Timer, emit

#: The controller grid (cells = cadence-major product, 27 points).
CADENCES = (1, 2, 3)
MIG_WEIGHTS = (0.0, 0.01, 0.1)
TTFT_TARGETS = (30.0, 60.0, 90.0)


def _world(fast: bool):
    """A congested three-candidate world with admission on: every grid
    axis has to matter (switches happen, the gates bite, the TTFT
    target moves the AIMD window)."""
    cfg = ConstellationConfig.scaled(8, 12, n_slots=10, survival_prob=1.0)
    con = Constellation(cfg)
    topo = sample_topology(con, LinkConfig(), np.random.default_rng(0))
    activ = ActivationModel.zipf(4, 4, 2, seed=1)
    plans = [rand_intra_cg_plan(con.cfg, 4, 4, np.random.default_rng(7)),
             spacemoe_plan(con, topo, activ),
             rand_intra_cg_plan(con.cfg, 4, 4, np.random.default_rng(11))]
    req = sample_requests(np.random.default_rng(2),
                          rate_rps=20.0 if fast else 40.0,
                          horizon_s=60.0 if fast else 120.0,
                          n_stations=2, prompt_median=8, prompt_max=32,
                          decode_mean=8, decode_max=16)
    qcfg = QueueConfig(dt_s=0.05, tail_s=30.0, slot_period_s=10.0,
                       buffer_s=6.0,
                       admission=AdmissionConfig(policy="aimd",
                                                 ttft_target_s=60.0))
    return topo, activ, plans, req, qcfg


def _cells():
    """Cadence-major grid cells, the fused launch's cell order."""
    return [(c, w, t) for c in CADENCES for w in MIG_WEIGHTS
            for t in TTFT_TARGETS]


def _host_cell(plans, topo, activ, wl, comp, req, qcfg, rcfg, cell):
    """One host-controller run at one grid point."""
    cad, w, tt = cell
    qc = dataclasses.replace(
        qcfg, admission=dataclasses.replace(qcfg.admission,
                                            ttft_target_s=tt))
    rc = dataclasses.replace(rcfg, period_slots=cad,
                             migration_weight_s_per_mb=w)
    return replan_traffic(plans, topo, activ, wl, comp, req,
                          np.random.default_rng(4), rc, qc)


def _host_stage_times(plans, topo, activ, wl, comp, req, qcfg, rcfg,
                      cell) -> dict:
    """Warm per-stage wall times of ONE host-controller cell — the
    decomposition behind ``host_loop_s``, so the headline ratio is
    auditable: the build stages carry the per-cell table construction
    and any jit cache misses, the run stages the device fixed points,
    the decide walk the pure-python boundary loop.  Mirrors
    ``replan_traffic``'s exact stage order and seed discipline."""
    cad, w, tt = cell
    qc = dataclasses.replace(
        qcfg, admission=dataclasses.replace(qcfg.admission,
                                            ttft_target_s=tt))
    rc = dataclasses.replace(
        rcfg, period_slots=cad, migration_weight_s_per_mb=w,
        bytes_per_expert=qc.migration_bytes_per_expert)
    seed = int(np.random.default_rng(4).integers(0, 2**31 - 1))
    with Timer() as t_pb:
        probe_sim = FleetSim(plans, topo, activ, wl, comp, req,
                             np.random.default_rng(seed), qcfg=qc)
    with Timer() as t_pr:
        probe_sim.run()
    with Timer() as t_dw:
        report = build_replan_schedule(
            plans, topo, activ, wl, comp,
            np.random.default_rng(seed + 1), rc,
            horizon_s=probe_sim.n_bins * qc.dt_s,
            slot_period_s=qc.slot_period_s,
            backlog_at=lambda _k, t_s, cur:
                probe_sim.satellite_backlog(max(cur, 0), t_s))
    with Timer() as t_eb:
        ev = FleetSim(list(plans) + [report.schedule], topo, activ, wl,
                      comp, req, np.random.default_rng(seed), qcfg=qc)
    with Timer() as t_er:
        ev.run()
    return {"probe_build_s": round(t_pb.seconds, 3),
            "probe_run_s": round(t_pr.seconds, 3),
            "decide_walk_s": round(t_dw.seconds, 3),
            "eval_build_s": round(t_eb.seconds, 3),
            "eval_run_s": round(t_er.seconds, 3)}


def _compare_cell(tag: str, host, fused) -> list[str]:
    """Decision parity for one grid cell; returns problem strings."""
    problems = []
    hr, fr = host.report, fused.report
    if not np.array_equal(hr.schedule.slot_plan, fr.schedule.slot_plan):
        problems.append(f"{tag}: slot plans differ "
                        f"{hr.schedule.slot_plan.tolist()} vs "
                        f"{fr.schedule.slot_plan.tolist()}")
    if len(hr.decisions) != len(fr.decisions):
        problems.append(f"{tag}: {len(hr.decisions)} host decisions vs "
                        f"{len(fr.decisions)} fused")
        return problems
    for dh, df in zip(hr.decisions, fr.decisions):
        if (dh.boundary, dh.slot, dh.chosen, dh.switched) != \
                (df.boundary, df.slot, df.chosen, df.switched):
            problems.append(f"{tag} k={dh.boundary}: decision "
                            f"{(dh.chosen, dh.switched)} vs "
                            f"{(df.chosen, df.switched)}")
        if not np.array_equal(dh.scores, df.scores):
            problems.append(f"{tag} k={dh.boundary}: scores "
                            f"{dh.scores} vs {df.scores}")
        if dh.migration_bytes != df.migration_bytes:
            problems.append(f"{tag} k={dh.boundary}: migration "
                            f"{dh.migration_bytes} vs {df.migration_bytes}")
    return problems


def run(fast: bool = True, json_path: str | None = None) -> dict:
    """Time host loop vs fused grid; gate decision parity + one-launch.

    Raises SystemExit when any grid cell's fused decisions deviate from
    the host walk or when the grid costs more than one trace.
    """
    wl, comp = MoEWorkload.llama_moe_3p5b(), ComputeConfig()
    topo, activ, plans, req, qcfg = _world(fast)
    # One decide round: the host loop's early-exit on a converged second
    # round would otherwise make the two sides run different amounts of
    # device work per cell — with controller_iterations=1 both execute
    # exactly probe + decide walk + evaluate, so the wall-clock ratio
    # isolates the launch structure (27 programs vs one batched one).
    rcfg = ReplanConfig(mode="backlog", controller_iterations=1)
    cells = _cells()

    # The host loop's seed discipline (replan_traffic): one integer draw
    # seeds every fleet run, seed+1 seeds the base-score draws — common
    # random numbers per cell, so decisions must match bit for bit.
    seed = int(np.random.default_rng(4).integers(0, 2**31 - 1))
    rc_full = dataclasses.replace(
        rcfg, bytes_per_expert=qcfg.migration_bytes_per_expert)
    with Timer() as t_build:
        sim = FleetSim(plans, topo, activ, wl, comp, req,
                       np.random.default_rng(seed), qcfg)
    scores = replan_base_scores(plans, topo, activ, wl, comp,
                                np.random.default_rng(seed + 1), rc_full)
    grid = dict(base_scores=scores, cadences=list(CADENCES),
                mig_weights=list(MIG_WEIGHTS),
                ttft_targets=list(TTFT_TARGETS))
    before = queueing.FUSED_TRACE_COUNT
    with Timer() as t_first:             # compile + launch
        fused = sim.run_replan_grid(rc_full, **grid)
    trace_delta = queueing.FUSED_TRACE_COUNT - before
    with Timer() as t_steady:            # cached compile, one launch
        fused = sim.run_replan_grid(rc_full, **grid)

    with Timer() as t_host:
        host = [_host_cell(plans, topo, activ, wl, comp, req, qcfg,
                           rcfg, cell) for cell in cells]
    # One warm cell decomposed stage by stage (the loop above warmed
    # every jit cache): host_loop_s minus 27x these stage sums is the
    # per-cell recompile + dispatch overhead the fused launch removes.
    stages = _host_stage_times(plans, topo, activ, wl, comp, req, qcfg,
                               rcfg, cells[0])

    problems: list[str] = []
    if trace_delta != 1:
        problems.append(f"grid cost {trace_delta} traces, not 1 — the "
                        "control grid no longer batches as one program")
    rows = []
    for cell, h, f in zip(cells, host, fused):
        cad, w, tt = cell
        tag = f"cad={cad} w={w} ttft={tt:g}"
        problems += _compare_cell(tag, h, f)
        rep = f.report
        rows.append({
            "cadence": cad, "mig_weight": w, "ttft_target": tt,
            "n_decisions": len(rep.decisions),
            "n_switches": rep.n_switches,
            "migration_mb": round(rep.total_migration_bytes / 1e6, 3),
            "replan_goodput_tok_s": round(
                f.replanned.goodput_tok_s, 3),
        })

    speedup = t_host.seconds / max(t_steady.seconds, 1e-9)
    speedup_cold = t_host.seconds / max(t_first.seconds, 1e-9)
    out = {
        "fast": fast,
        "n_cells": len(cells),
        "n_candidates": len(plans),
        "n_requests": req.n_requests,
        "trace_count_delta": trace_delta,
        "build_s": round(t_build.seconds, 3),
        "host_loop_s": round(t_host.seconds, 3),
        "host_cell_mean_s": round(t_host.seconds / len(cells), 3),
        "fused_first_s": round(t_first.seconds, 3),
        "fused_steady_s": round(t_steady.seconds, 3),
        "speedup_steady": round(speedup, 2),
        "speedup_with_compile": round(speedup_cold, 2),
        "host_cell_stages": stages,
        "any_switches": bool(any(r["n_switches"] for r in rows)),
        "cells": rows,
        "parity_ok": not problems,
        "parity_problems": problems,
    }
    emit("ctrl/host_loop", t_host.seconds * 1e6, f"n_cells={len(cells)}")
    emit("ctrl/fused_grid", t_steady.seconds * 1e6,
         f"speedup={speedup:.1f}x;with_compile={speedup_cold:.1f}x;"
         f"traces={trace_delta}")
    print(f"# fused control grid: {len(cells)} cells in {trace_delta} "
          f"trace(s), {speedup:.1f}x over the host loop "
          f"({t_host.seconds:.2f}s -> {t_steady.seconds:.2f}s steady, "
          f"{t_first.seconds:.2f}s incl. compile); warm host cell "
          f"stages {stages}")

    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
    if problems:
        for p in problems:
            print(f"# PARITY DEVIATION: {p}")
        raise SystemExit("bench_ctrl: fused/host decision parity failed")
    return out


if __name__ == "__main__":
    run()
