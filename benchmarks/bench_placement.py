"""Placement machinery benchmarks: planner scaling + plan-sweep cost.

- planner scaling: spacemoe_plan cost vs constellation size (the paper
  claims O(I log I + V log V) per layer — Sec. V end);
- optimality gap: Theorem-1 closed-form objective vs brute force (small I)
  and vs Monte-Carlo of the actual simulator;
- TPU transplant: expected dispatch-cost reduction of the Theorem-1
  expert->device permutation vs identity, per MoE arch in the pool.
"""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core import (ActivationModel, ConstellationConfig, Constellation,
                        LinkConfig, TorusSpec, activation_probs,
                        brute_force_optimal, expected_dispatch_cost,
                        identity_plan, layer_latency_closed_form,
                        plan_expert_devices, sample_topology, spacemoe_plan,
                        theorem1_assignment)

from .common import Timer, emit


def run() -> dict:
    out: dict = {}

    # planner scaling
    for nx, ny in ((9, 8), (17, 16), (33, 32)):
        ccfg = ConstellationConfig.scaled(nx, ny, n_slots=20)
        con = Constellation(ccfg)
        topo = sample_topology(con, LinkConfig(), np.random.default_rng(0))
        activ = ActivationModel.zipf(8, 8, 2, seed=0)
        with Timer() as t:
            spacemoe_plan(con, topo, activ)
        emit(f"placement/plan_{nx}x{ny}", t.seconds * 1e6,
             f"sats={ccfg.n_sats};layers=8")
        out[f"plan_{nx}x{ny}"] = t.seconds

    # optimality: Theorem 1 == brute force on I<=6
    rng = np.random.default_rng(0)
    gaps = []
    for trial in range(20):
        n, k = 6, 2
        tau = np.sort(rng.uniform(0.01, 0.3, n))
        w = rng.gamma(2, 1, n) + 0.05
        probs = activation_probs(w, k)
        assign = theorem1_assignment(probs, tau)
        r2e = np.empty(n, dtype=np.int64)
        r2e[assign] = np.arange(n)
        thm = layer_latency_closed_form(tau, w, r2e, k)
        _, best = brute_force_optimal(tau, w, k)
        gaps.append(thm - best)
    emit("placement/theorem1_optimality_gap", 0.0,
         f"max_gap={max(gaps):.2e};trials=20")
    out["max_gap"] = max(gaps)

    # TPU transplant per MoE arch
    for arch in ("granite-moe-3b-a800m", "deepseek-moe-16b",
                 "jamba-1.5-large-398b", "llama-moe-3.5b"):
        cfg = get_config(arch)
        e, k = cfg.n_experts, cfg.top_k
        n_dev = max(d for d in range(1, 17) if e % d == 0)  # EP ring size
        ring = TorusSpec(shape=(n_dev,), wrap=True)
        w = ActivationModel.zipf(1, e, k, seed=1).weights[0]
        with Timer() as t:
            plan = plan_expert_devices(w, k, ring,
                                       bytes_per_token=2.0 * cfg.d_model)
        base = identity_plan(e, ring, bytes_per_token=2.0 * cfg.d_model)
        c_t = expected_dispatch_cost(plan, w, k)
        c_i = expected_dispatch_cost(base, w, k)
        emit(f"placement/device_{arch}", t.seconds * 1e6,
             f"theorem1_us={c_t*1e6:.2f};identity_us={c_i*1e6:.2f};"
             f"reduction={100*(1-c_t/c_i):.1f}%")
        out[arch] = (c_t, c_i)
    return out


if __name__ == "__main__":
    run()
