"""Continuous-batching capacity frontier: batched vs FIFO goodput at matched p99 TTFT.

One overloaded Poisson trace is served twice on the same world, plans
and random draws — once by the FIFO fleet kernel (every decode step
occupies its satellite for the full single-token service time) and once
under :class:`repro.traffic.BatchingConfig` continuous batching (decode
steps sharing a (satellite, bin) drain in batches of up to ``B_max`` at
the service model's batch speedup).  A nested thinning-fraction sweep
rides one ``run_many`` launch per regime, so the whole frontier costs
two compiles; each run contributes one (offered rate, goodput, p99
TTFT, drop rate) point per plan.

The headline figure is **best goodput at matched p99 TTFT**: the
highest served-decode throughput each regime reaches while keeping p99
TTFT within a fixed multiple of the zero-load p99.  The fused batching
law is pinned bitwise-FIFO at ``B_max=1`` (tests), so any frontier gap
is the capacity continuous batching buys; the run exits non-zero if
batching fails to beat FIFO (``BENCH_batching.json`` tracks the margin
across PRs).

    PYTHONPATH=src python -m benchmarks.run --fast --only batching
"""
from __future__ import annotations

import numpy as np

from repro.traffic import (BatchingConfig, FleetSim, QueueConfig,
                           format_table, sample_requests)

from .bench_traffic import _plans, _world
from .common import Timer, emit

#: Largest decode batch per (satellite, bin) in the batched regime.
B_MAX = 8
#: Nested thinning fractions of the envelope trace (ascending).
FRACTIONS = (0.25, 0.5, 0.75, 1.0)
#: p99-TTFT bound for the matched comparison, as a multiple of the
#: zero-load p99 (the same relative-headroom style the traffic
#: saturation sweep uses).
TTFT_BOUND_SCALE = 2.5


def _round(x: float, digits: int) -> float | None:
    """Round for JSON; non-finite (nothing served) becomes null."""
    return round(float(x), digits) if np.isfinite(x) else None


def _frontier_row(regime: str, fraction: float, plan) -> dict:
    """One frontier point: thinning fraction -> goodput/latency/drops."""
    return {
        "regime": regime,
        "fraction": fraction,
        "plan": plan.plan_name,
        "offered_rps": _round(plan.offered_rps, 4),
        "goodput_tok_s": _round(plan.goodput_tok_s, 3),
        "ttft_p99_s": _round(plan.quantile("ttft", 0.99), 3),
        "drop_rate": round(plan.drop_rate, 4),
    }


def run(fast: bool = True, json_path: str | None = None,
        rate_rps: float | None = None) -> dict:
    """Sweep thinning fractions under both regimes; emit the frontier.

    Args:
        fast: CI-sized world and horizon when True.
        json_path: Optional path for the JSON frontier summary.
        rate_rps: Envelope (100% fraction) arrival rate; ``None`` picks
            a rate that saturates the FIFO kernel on the chosen world.

    Returns:
        JSON-able dict with the frontier rows, the per-regime best
        goodput at the matched p99 bound, and the ``pass`` flag CI
        gates on (batched strictly above FIFO).
    """
    con, topo, activ, wl, comp, ground = _world(fast)
    plans = _plans(con, topo, activ)[:2]          # SpaceMoE vs RandIntra-CG
    horizon = 60.0 if fast else 180.0
    if rate_rps is None:
        rate_rps = 3.0 if fast else 4.0
    requests = sample_requests(
        np.random.default_rng(29), rate_rps=rate_rps, horizon_s=horizon,
        n_stations=ground.n_stations, prompt_median=4, prompt_max=16,
        decode_mean=8, decode_max=16)
    qcfg = QueueConfig(dt_s=0.05, tail_s=60.0)

    def make(batching: BatchingConfig | None) -> FleetSim:
        return FleetSim(plans, topo, activ, wl, comp, requests,
                        np.random.default_rng(23), qcfg=qcfg,
                        ground=ground, batching=batching)

    sim_fifo = make(None)
    sim_bat = make(BatchingConfig(b_max=B_MAX))

    # Zero-load reference anchors the matched-latency bound.
    base = sim_fifo.run(zero_load=True)
    ttft0_p99 = max(p.quantile("ttft", 0.99) for p in base.plans)
    bound = TTFT_BOUND_SCALE * ttft0_p99

    # Nested masks (one uniform draw per request) keep the thinned sets
    # monotone; each regime's whole fraction axis is one launch.
    u = np.random.default_rng(31).random(requests.n_requests)
    fractions = np.asarray(FRACTIONS)
    masks = u[None, :] < fractions[:, None]

    rows: list[dict] = []
    timers = {}
    for regime, sim in (("fifo", sim_fifo), ("batched", sim_bat)):
        with Timer() as t:
            for frac, res in zip(FRACTIONS, sim.run_many(masks)):
                rows += [_frontier_row(regime, float(frac), p)
                         for p in res.plans]
        timers[regime] = t

    out = {
        "fast": fast,
        "plans": [p.name for p in plans],
        "b_max": B_MAX,
        "rate_rps": rate_rps,
        "fractions": list(FRACTIONS),
        "zero_load_ttft_p99_s": round(ttft0_p99, 3),
        "ttft_bound_scale": TTFT_BOUND_SCALE,
        "frontier": rows,
    }
    # Best goodput each regime reaches while p99 TTFT stays within the
    # matched bound — the headline capacity figure.
    for regime in ("fifo", "batched"):
        ok = [r for r in rows if r["regime"] == regime
              and r["ttft_p99_s"] is not None and r["ttft_p99_s"] <= bound]
        out[f"best_goodput_{regime}"] = (
            max(r["goodput_tok_s"] or 0.0 for r in ok) if ok else 0.0)
    out["capacity_gain"] = round(
        out["best_goodput_batched"] / out["best_goodput_fifo"], 3) \
        if out["best_goodput_fifo"] > 0 else None
    out["pass"] = bool(out["best_goodput_batched"]
                       > out["best_goodput_fifo"])

    print(format_table(rows, prefix="# "))
    print(f"# zero-load p99 TTFT {ttft0_p99:.2f}s; p99<= {bound:.1f}s "
          f"goodput: fifo={out['best_goodput_fifo']:.2f} "
          f"batched={out['best_goodput_batched']:.2f} tok/s "
          f"(gain {out['capacity_gain']}x, B_max={B_MAX})")
    emit("batching/fifo_sweep", timers["fifo"].seconds * 1e6,
         f"best_goodput={out['best_goodput_fifo']}")
    emit("batching/batched_sweep", timers["batched"].seconds * 1e6,
         f"best_goodput={out['best_goodput_batched']}")

    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
    if not out["pass"]:
        raise SystemExit(
            "bench_batching: batched goodput failed to beat FIFO at the "
            "matched p99 TTFT bound")
    return out


if __name__ == "__main__":
    run()
