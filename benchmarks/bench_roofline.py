"""Roofline summary: per-cell three-term table from the dry-run artifacts."""
from __future__ import annotations

import glob
import json
import os

from .common import emit

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def run() -> dict:
    cells = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    out: dict = {}
    n_ok = n_skip = n_fail = 0
    for path in cells:
        with open(path) as f:
            rec = json.load(f)
        cell = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec["status"] == "skip":
            n_skip += 1
            continue
        if rec["status"] != "ok":
            n_fail += 1
            emit(f"roofline/{cell}", 0.0, "status=FAIL")
            continue
        n_ok += 1
        r = rec["roofline"]
        emit(
            f"roofline/{cell}",
            r["compute_s"] * 1e6,
            f"dominant={r['dominant']};compute_s={r['compute_s']:.5f};"
            f"memory_s={r['memory_s']:.5f};"
            f"collective_s={r['collective_s']:.5f};"
            f"useful_flops_ratio={r['useful_flops_ratio']:.3f};"
            f"roofline_fraction={r['roofline_fraction']:.4f}",
        )
        out[cell] = r
    emit("roofline/summary", 0.0, f"ok={n_ok};skip={n_skip};fail={n_fail}")
    return out


if __name__ == "__main__":
    run()
