"""Observability overhead: probes-on vs probes-off fused fleet launches.

The same smoke-scenario :class:`FleetSim` workload runs twice — once
probe-free and once with the on-device telemetry rings
(:class:`repro.obs.ProbeConfig`) — and the bench reports the
steady-state (post-compile) overhead ratio of the probed launch,
comparing the minimum of interleaved repetitions (noise-robust on
shared CI machines).  The probes ride only the peeled final iteration's
backlog scan as branch-free ``dynamic_update_slice`` ring writes, so
the documented budget is **<10% steady-state overhead**
(``OVERHEAD_BUDGET``); the boolean ``within_budget`` is the gated
metric (timings themselves vary machine to machine and are skipped by
``tools/check_bench.py``).

The bench also asserts the bit-parity invariant the static ``probes=``
flag guarantees — probes-off results must be bitwise identical whether
or not a probed run happened in between — and fails hard on deviation.

    PYTHONPATH=src python -m benchmarks.run --fast --only obs
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.obs import ProbeConfig, build_flight_log, chrome_trace, \
    validate_trace
from repro.traffic import FleetSim, get_scenario

from .bench_traffic import _plans, _world
from .common import Timer, emit

#: Documented steady-state overhead budget of the probed launch
#: (fraction of the probe-free launch time; see docs/architecture.md).
OVERHEAD_BUDGET = 0.10
#: Interleaved timing repetitions; the *minimum* launch times are
#: compared — the noise-robust estimator for millisecond-scale launches
#: on shared CI machines (scheduler bursts only ever add time).
REPS = 7


def _min_launch_s(sim_off: FleetSim, sim_on: FleetSim,
                  reps: int = REPS) -> tuple[float, float]:
    """(min off, min on) wall times over ``reps`` interleaved
    post-compile runs (interleaving cancels slow machine-load drift)."""
    offs, ons = [], []
    for _ in range(reps):
        with Timer() as t_off:
            sim_off.run()
        with Timer() as t_on:
            sim_on.run()
        offs.append(t_off.seconds)
        ons.append(t_on.seconds)
    return float(np.min(offs)), float(np.min(ons))


def run(fast: bool = True, json_path: str | None = None) -> dict:
    """Measure probe overhead + parity; emit BENCH_obs rows.

    Returns the JSON-able summary (median launch times, overhead ratio,
    ``within_budget`` verdict, probe/export sanity counters).  Raises
    SystemExit when the probes-off bit-parity invariant breaks.
    """
    con, topo, activ, wl, comp, ground = _world(fast)
    plans = _plans(con, topo, activ)[:2]
    sc = dataclasses.replace(get_scenario("smoke"),
                             horizon_s=60.0 if fast else 120.0,
                             tail_s=60.0, kv_slots=8)
    requests = sc.requests(np.random.default_rng(13), ground.n_stations,
                           rate_scale=8.0)
    slot_period = con.cfg.orbital_period_s / topo.n_slots
    qcfg = sc.queue_config(slot_period)

    def build(probes):
        return FleetSim(plans, topo, activ, wl, comp, requests,
                        np.random.default_rng(13), qcfg=qcfg,
                        ground=ground, probes=probes)

    sim_off = build(None)
    sim_on = build(ProbeConfig())
    res_off_before = sim_off.run()       # also compiles the plain kernel
    res_on = sim_on.run()                # compiles the probed kernel
    off_s, on_s = _min_launch_s(sim_off, sim_on)
    overhead = on_s / max(off_s, 1e-9) - 1.0

    # Bit-parity invariant: a probes-off run after probed traffic on the
    # same workload must be bitwise identical to one before it.
    res_off_after = sim_off.run()
    problems = []
    for pb, pa in zip(res_off_before.plans, res_off_after.plans):
        for field in ("ttft_s", "e2e_s", "tpot_s"):
            if not np.array_equal(getattr(pb, field), getattr(pa, field),
                                  equal_nan=True):
                problems.append(f"{pb.plan_name}: {field} not bitwise "
                                "stable across a probed run")

    # Export sanity: the probed run's flight log renders a valid trace.
    log = build_flight_log(sim_on, res_on, scenario="bench-obs")
    trace = chrome_trace(log)
    trace_problems = validate_trace(trace)

    probes = sim_on.last_probes
    out = {
        "fast": fast,
        "n_requests": requests.n_requests,
        "n_bins": sim_on.n_bins,
        "probe_capacity": probes.capacity,
        "probe_stride": probes.stride,
        "n_recorded_bins": probes.n_recorded,
        "off_min_wall_s": round(off_s, 4),
        "on_min_wall_s": round(on_s, 4),
        "overhead_ratio": round(overhead, 4),
        "overhead_budget": OVERHEAD_BUDGET,
        "within_budget": bool(overhead < OVERHEAD_BUDGET),
        "parity_ok": not problems,
        "parity_problems": problems,
        "trace_valid": not trace_problems,
        "n_trace_events": len(trace["traceEvents"]),
    }
    emit("obs/probes_off", off_s * 1e6, f"reps={REPS}")
    emit("obs/probes_on", on_s * 1e6,
         f"overhead={overhead:+.1%};budget={OVERHEAD_BUDGET:.0%}")
    print(f"# probed launch overhead: {overhead:+.1%} "
          f"({off_s:.3f}s -> {on_s:.3f}s min of {REPS} interleaved; "
          f"budget {OVERHEAD_BUDGET:.0%}), "
          f"{probes.n_recorded} recorded bins @ stride {probes.stride}, "
          f"{len(trace['traceEvents'])} trace events")

    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
    if problems or trace_problems:
        for p in problems + trace_problems:
            print(f"# OBS DEVIATION: {p}")
        raise SystemExit("bench_obs: parity/trace check failed")
    return out


if __name__ == "__main__":
    run()
