"""Traffic & capacity: plans x scenarios SLO table + sustained-capacity ratio.

Every registry scenario runs against the plan sweep and the saturation
sweep reports the SpaceMoE-vs-RandIntra-CG sustained-capacity ratio.

Every registry scenario runs the request-level fleet simulator
(``repro.traffic``) over a plan sweep on one shared world; the
saturation sweep then thins a high-rate envelope trace through the
single precomputed :class:`FleetSim` (one engine pass, one jit'd fleet
scan shape) to find each plan's max arrival rate under a
relative-headroom SLO (p90 TTFT within 3x and p90 TPOT within 2.5x of
the best plan's zero-load latency, <=5% drops) and a KV-slot budget.

    PYTHONPATH=src python -m benchmarks.run --fast --only traffic
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        rand_intra_cg_plan, rand_place_plan, sample_topology,
                        spacemoe_plan)
from repro.traffic import (SCENARIOS, SLO, build_ground_segment, format_table,
                           get_scenario, make_sim, run_scenario,
                           saturation_sweep)

from .common import PAPER_COMPUTE, Timer, emit


@functools.lru_cache(maxsize=None)
def _world(fast: bool, seed: int = 0):
    # Memoized: bench_admission and bench_fleet reuse the same world, so
    # a multi-bench smoke run builds the constellation/topology/ground
    # segment once.  Treat the returned objects as read-only.
    if fast:
        ccfg = ConstellationConfig.scaled(12, 16, n_slots=12)
        n_layers = 8
    else:
        ccfg = ConstellationConfig.scaled(17, 16, n_slots=20)
        n_layers = 16
    con = Constellation(ccfg)
    link = LinkConfig()
    topo = sample_topology(con, link, np.random.default_rng(seed))
    activ = ActivationModel.zipf(n_layers, 8, 2, seed=seed)
    wl = MoEWorkload.llama_moe_3p5b()
    ground = build_ground_segment(con, link, min_elevation_deg=10.0)
    return con, topo, activ, wl, PAPER_COMPUTE, ground


def _plans(con, topo, activ, seed: int = 3):
    cfg = con.cfg
    return [
        spacemoe_plan(con, topo, activ),
        rand_intra_cg_plan(cfg, activ.n_layers, activ.n_experts,
                           np.random.default_rng(seed)),
        rand_place_plan(cfg, activ.n_layers, activ.n_experts,
                        np.random.default_rng(seed)),
    ]


def run(fast: bool = True, json_path: str | None = None) -> dict:
    """Emits CSV rows + a human table; returns the JSON-able summary."""
    con, topo, activ, wl, comp, ground = _world(fast)
    plans = _plans(con, topo, activ)
    rows: list[dict] = []
    out: dict = {"fast": fast, "plans": [p.name for p in plans]}

    # ---- plans x scenarios SLO table ----------------------------------
    for name in sorted(SCENARIOS):
        sc = get_scenario(name)
        if fast:
            sc = dataclasses.replace(
                sc, horizon_s=min(sc.horizon_s, 60.0), tail_s=60.0,
                failure_at_s=(30.0 if sc.failure_at_s is not None else None))
        with Timer() as t:
            res = run_scenario(sc, plans, topo, activ, wl, comp,
                               np.random.default_rng(11), ground=ground,
                               constellation=con)
        scen_rows = res.result.table(sc.slo, scenario=sc.name)
        if res.post_failure is not None:
            scen_rows += res.post_failure.table(sc.slo,
                                                scenario=f"{sc.name}(post)")
            out.setdefault("migration_bytes", {}).update(
                res.storm.migration_bytes)
        rows += scen_rows
        derived = ";".join(
            f"{r['plan']}:goodput={r['goodput_tok_s']};"
            f"ttft_p99={r['ttft_p99_s']};drop={r['drop_rate']}"
            for r in scen_rows if r["scenario"] == sc.name)
        emit(f"traffic/{sc.name}", t.seconds * 1e6, derived)

    # ---- saturation sweep: max sustained rate under SLO + KV budget ----
    # The binding resource is KV-cache memory: each in-flight request
    # pins a KV slot for its whole (placement-dependent) lifetime, so by
    # Little's law a plan's admissible rate is kv_slots / E2E — longer
    # network paths burn capacity.  Latency budgets (relative to the
    # best plan's zero-load quantiles) guard the queueing side.
    sweep_sc = dataclasses.replace(
        get_scenario("smoke"), horizon_s=60.0 if fast else 120.0,
        tail_s=60.0, kv_slots=8)
    envelope = 8.0           # x base rate; spans under- to over-saturated
    sweep_plans = plans[:2]  # SpaceMoE vs RandIntra-CG
    with Timer() as t_sweep:
        sim = make_sim(sweep_sc, sweep_plans, topo, activ, wl, comp,
                       np.random.default_rng(13), ground=ground,
                       constellation=con, rate_scale=envelope)
        base = sim.run(zero_load=True)
        ttft0 = min(p.quantile("ttft", 0.9) for p in base.plans)
        tpot0 = min(p.quantile("tpot", 0.9) for p in base.plans)
        slo = SLO(ttft_s=3.0 * ttft0, tpot_s=2.5 * tpot0, quantile=0.9,
                  max_drop=0.05)
        fractions = np.array([0.1, 0.15, 0.2, 0.25, 0.3, 0.35, 0.4, 0.5,
                              0.6, 0.8, 1.0])
        sat = saturation_sweep(sim, slo, np.random.default_rng(17),
                               fractions=fractions)
    ratio = sat.capacity_ratio("SpaceMoE", "RandIntra-CG")
    out["slo"] = slo.describe()
    out["tested_rps"] = [round(float(r), 4) for r in sat.tested_rps]
    out["slo_met_by_rate"] = {k: [bool(b) for b in v]
                              for k, v in sat.met.items()}
    out["sustained_rps"] = {k: round(v, 4)
                            for k, v in sat.sustained_rps.items()}
    out["capacity_ratio_spacemoe_over_randintra_cg"] = (
        round(ratio, 3) if np.isfinite(ratio) else None)
    out["table"] = rows

    print(format_table(rows, prefix="# "))
    print(f"# saturation SLO: {slo.describe()}")
    print("# sustained capacity (rps): " + ", ".join(
        f"{k}={v:.3f}" for k, v in sat.sustained_rps.items()))
    print(f"# SpaceMoE vs RandIntra-CG sustained-capacity ratio: "
          f"{ratio:.2f}x")
    emit("traffic/saturation_sweep", t_sweep.seconds * 1e6,
         ";".join(f"{k}_rps={v:.3f}" for k, v in sat.sustained_rps.items())
         + f";capacity_ratio={ratio:.2f}")

    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    run()
