"""Adaptive-admission frontier: goodput vs p99 TTFT, AIMD controller vs static KV cap.

One overloaded ``regional-hotspot`` trace is served under two admission
regimes on the same world, plans and random draws:

* **static** — the PR-2 ``kv_slots`` cap, swept over slot budgets
  (reacts to the in-flight count: load is shed only after the backlog —
  and the SLO — have already blown up);
* **aimd** — the latency-target controller of
  :mod:`repro.traffic.admission`, swept over TTFT targets expressed as
  multiples of the zero-load p99 TTFT (sheds *before* the target is
  crossed; rejected requests retry at the next-best visible gateway).

Each run contributes one (goodput, p99 TTFT, shed/drop) frontier point
per plan; the JSON summary (``BENCH_admission.json`` in CI) holds the
full frontier so the controller's dominance over the static cap is
tracked across PRs.  Both sweeps share one engine pass per regime: the
KV cap is host post-processing (per-budget runs reuse the compiled
fused fixed point), and the AIMD target sweep is one ``run_many``
launch batched over the target axis.

    PYTHONPATH=src python -m benchmarks.run --fast --only admission
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.traffic import (AdmissionConfig, FleetSim, format_table,
                           get_scenario)

from .bench_traffic import _plans, _world
from .common import Timer, emit

#: TTFT targets tested, as multiples of the zero-load p99 TTFT.
TARGET_SCALES = (1.5, 2.0, 3.0, 5.0)
#: Static KV-slot budgets tested.
KV_BUDGETS = (4, 8, 16, 32)


def _round(x: float, digits: int) -> float | None:
    """Round for JSON; non-finite (nothing served) becomes null."""
    return round(float(x), digits) if np.isfinite(x) else None


def _frontier_row(policy: str, knob: float, plan) -> dict:
    """One frontier point: knob setting -> goodput/latency/shedding."""
    return {
        "policy": policy,
        "knob": knob,
        "plan": plan.plan_name,
        "goodput_tok_s": _round(plan.goodput_tok_s, 3),
        "ttft_p99_s": _round(plan.quantile("ttft", 0.99), 3),
        "shed_rate": round(plan.shed_rate, 4),
        "retry_rate": round(plan.retry_rate, 4),
        "drop_rate": round(plan.drop_rate, 4),
    }


def run(fast: bool = True, json_path: str | None = None,
        rate_scale: float = 6.0) -> dict:
    """Sweep latency targets and KV budgets; emit the goodput-p99 frontier.

    Args:
        fast: CI-sized world and horizon when True.
        json_path: Optional path for the JSON frontier summary.
        rate_scale: Overload multiplier on the hotspot scenario's base
            arrival rate (the frontier is only interesting past
            saturation).

    Returns:
        JSON-able dict with the frontier rows and the per-policy best
        goodput at the tightest common latency bound.
    """
    con, topo, activ, wl, comp, ground = _world(fast)
    plans = _plans(con, topo, activ)[:2]          # SpaceMoE vs RandIntra-CG
    sc = get_scenario("regional-hotspot")
    horizon = 60.0 if fast else sc.horizon_s
    sc = dataclasses.replace(sc, horizon_s=horizon, tail_s=60.0)
    requests = sc.requests(np.random.default_rng(21), ground.n_stations,
                           rate_scale=rate_scale)
    slot_period = con.cfg.orbital_period_s / topo.n_slots

    def make(qcfg_kw: dict) -> FleetSim:
        qcfg = dataclasses.replace(sc.queue_config(slot_period), **qcfg_kw)
        return FleetSim(plans, topo, activ, wl, comp, requests,
                        np.random.default_rng(23), qcfg=qcfg, ground=ground)

    # One simulator per admission regime, one engine pass each.  The
    # static sweep calls run(kv_slots=...) per budget — the cap is host
    # post-processing, so every budget reuses the compiled fused fixed
    # point — and the AIMD target sweep is a single run_many launch over
    # the target axis.
    sim_static = make({})
    sim_aimd = make({"kv_slots": 0, "admission": AdmissionConfig()})

    # Zero-load reference anchors the target scales.
    base = sim_static.run(zero_load=True, kv_slots=0)
    ttft0_p99 = max(p.quantile("ttft", 0.99) for p in base.plans)

    rows: list[dict] = []
    with Timer() as t_static:
        # The cap is host post-processing, so per-budget runs replay a
        # cached compile — only the (cheap) launch itself repeats.
        for kv in KV_BUDGETS:
            res = sim_static.run(kv_slots=kv)
            rows += [_frontier_row("static", float(kv), p)
                     for p in res.plans]
    targets = np.asarray(TARGET_SCALES) * ttft0_p99
    with Timer() as t_aimd:
        every = np.ones((len(targets), requests.n_requests), dtype=bool)
        for target, res in zip(targets, sim_aimd.run_many(
                every, ttft_targets=targets)):
            rows += [_frontier_row("aimd", round(float(target), 3), p)
                     for p in res.plans]

    out = {
        "fast": fast,
        "plans": [p.name for p in plans],
        "offered_rps": round(requests.n_requests / horizon, 3),
        "zero_load_ttft_p99_s": round(ttft0_p99, 3),
        "target_scales": list(TARGET_SCALES),
        "kv_budgets": list(KV_BUDGETS),
        "frontier": rows,
    }
    # Best goodput each policy achieves while keeping p99 TTFT under the
    # loosest AIMD target — the headline "controller dominates" figure.
    bound = TARGET_SCALES[-1] * ttft0_p99
    for policy in ("static", "aimd"):
        ok = [r for r in rows if r["policy"] == policy
              and r["ttft_p99_s"] is not None and r["ttft_p99_s"] <= bound]
        out[f"best_goodput_{policy}"] = (
            max(r["goodput_tok_s"] or 0.0 for r in ok) if ok else 0.0)

    print(format_table(rows, prefix="# "))
    print(f"# zero-load p99 TTFT {ttft0_p99:.2f}s; p99<= {bound:.1f}s "
          f"goodput: static={out['best_goodput_static']:.2f} "
          f"aimd={out['best_goodput_aimd']:.2f} tok/s")
    emit("admission/static_sweep", t_static.seconds * 1e6,
         f"best_goodput={out['best_goodput_static']}")
    emit("admission/aimd_sweep", t_aimd.seconds * 1e6,
         f"best_goodput={out['best_goodput_aimd']}")

    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    run()
