"""Paper Sec. VIII open challenge: link-state-aware token routing.

The paper's placement assumes routing always sees the current topology.
This bench quantifies what stale link-state information costs: paths are
chosen from the topology ``s`` slots ago; where the network changed, the
token pays the worse path plus a re-route penalty (discovery/handshake,
one slot-scale RTT ~ 30 ms).  The gap between s=0 and s>0 is the value of
link-state-aware routing — and SpaceMoE's short routes make it the most
robust scheme (fewer links per path, fewer chances to be stale).
"""
from __future__ import annotations

import numpy as np

from repro.core import (rand_intra_cg_plan, simulate_token_generation,
                        spacemoe_plan)

from .common import N_EXPERTS, N_LAYERS, Timer, emit, paper_world

REROUTE_PENALTY_S = 0.030


def run(n_tokens: int = 250) -> dict:
    con, topo, activ, wl, comp = paper_world(seed=0, n_slots=60)
    plans = {
        "SpaceMoE": spacemoe_plan(con, topo, activ, wl, comp),
        "RandIntra-CG": rand_intra_cg_plan(
            con.cfg, N_LAYERS, N_EXPERTS, np.random.default_rng(3)),
    }
    out: dict = {}
    for scheme, plan in plans.items():
        for staleness in (0, 1, 5, 20):
            with Timer() as t:
                r = simulate_token_generation(
                    plan, topo, activ, wl, comp, np.random.default_rng(5),
                    n_tokens=n_tokens, route_staleness=staleness,
                    reroute_penalty_s=REROUTE_PENALTY_S,
                )
            out[(scheme, staleness)] = r.mean_s
            emit(f"linkstate/{scheme}/stale_{staleness}",
                 t.seconds * 1e6 / n_tokens,
                 f"s_per_token={r.mean_s:.4f};drop={r.drop_rate:.4f}")
        fresh = out[(scheme, 0)]
        worst = out[(scheme, 20)]
        emit(f"linkstate/{scheme}/staleness_cost", 0.0,
             f"overhead_at_20_slots={(worst/fresh-1)*100:.1f}%")
    return out


if __name__ == "__main__":
    run()
