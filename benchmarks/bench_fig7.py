"""Paper Fig. 7: effects of network parameters on E2E latency.

(a) orbital altitude        — latency increases monotonically (Eq. 5)
(b) constellation size      — SpaceMoE improves, random baselines degrade
(c) link survival prob      — latency decreases with milder space weather
(d) PAT angular-rate gate   — latency decreases as the threshold loosens

Calibration note (EXPERIMENTS.md §Fidelity): with honest orbital
mechanics at 550 km, co-rotating ISLs slew at ~1e-3 rad/s, so the paper's
0.12 rad/s operating point leaves the PAT gate non-binding; the (d) sweep
therefore spans the physically binding range [2e-4, 0.12] where the trend
the paper reports (larger threshold => lower latency) appears.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import (ConstellationConfig, rand_intra_cg_plan,
                        simulate_token_generation, spacemoe_plan)

from .common import (N_EXPERTS, N_LAYERS, PAPER_CONSTELLATION, Timer, emit,
                     paper_world)


def _latency(ccfg: ConstellationConfig, n_tokens: int, seed: int = 0):
    con, topo, activ, wl, comp = paper_world(seed=seed, cfg=ccfg)
    sm = simulate_token_generation(
        spacemoe_plan(con, topo, activ, wl, comp), topo, activ, wl, comp,
        np.random.default_rng(5), n_tokens=n_tokens)
    cg = simulate_token_generation(
        rand_intra_cg_plan(ccfg, N_LAYERS, N_EXPERTS,
                           np.random.default_rng(7)),
        topo, activ, wl, comp, np.random.default_rng(5), n_tokens=n_tokens)
    return sm.mean_s, cg.mean_s


def run(n_tokens: int = 250) -> dict:
    out: dict = {}

    # (a) altitude sweep
    for alt in (350.0, 550.0, 800.0, 1100.0):
        ccfg = dataclasses.replace(PAPER_CONSTELLATION, altitude_km=alt,
                                   n_slots=60)
        with Timer() as t:
            sm, cg = _latency(ccfg, n_tokens)
        out.setdefault("altitude", {})[alt] = (sm, cg)
        emit(f"fig7a/altitude_{int(alt)}km", t.seconds * 1e6 / n_tokens,
             f"spacemoe_s={sm:.4f};randintra_cg_s={cg:.4f}")

    # (b) constellation size sweep (N_y >= L = 32 layers must hold)
    for nx, ny in ((17, 32), (25, 32), (33, 32), (41, 40)):
        ccfg = ConstellationConfig.scaled(nx, ny, n_slots=60)
        with Timer() as t:
            sm, cg = _latency(ccfg, n_tokens)
        out.setdefault("size", {})[nx * ny] = (sm, cg)
        emit(f"fig7b/size_{nx}x{ny}", t.seconds * 1e6 / n_tokens,
             f"spacemoe_s={sm:.4f};randintra_cg_s={cg:.4f}")

    # (c) space-weather survival probability sweep
    for p in (0.80, 0.90, 0.95, 1.00):
        ccfg = dataclasses.replace(PAPER_CONSTELLATION, survival_prob=p,
                                   n_slots=60)
        with Timer() as t:
            sm, cg = _latency(ccfg, n_tokens)
        out.setdefault("survival", {})[p] = (sm, cg)
        emit(f"fig7c/survival_{p:.2f}", t.seconds * 1e6 / n_tokens,
             f"spacemoe_s={sm:.4f};randintra_cg_s={cg:.4f}")

    # (d) PAT angular-rate threshold sweep (physically binding range)
    for th in (2e-4, 5e-4, 1e-3, 3e-3, 0.12):
        ccfg = dataclasses.replace(PAPER_CONSTELLATION,
                                   angular_rate_threshold=th, n_slots=60)
        with Timer() as t:
            sm, cg = _latency(ccfg, n_tokens)
        out.setdefault("threshold", {})[th] = (sm, cg)
        emit(f"fig7d/threshold_{th:g}", t.seconds * 1e6 / n_tokens,
             f"spacemoe_s={sm:.4f};randintra_cg_s={cg:.4f}")
    return out


if __name__ == "__main__":
    run()
