"""Pallas kernel micro-benchmarks (CPU interpret mode, correctness-grade).

The derived column reports the roofline-relevant work per call.

On-TPU performance claims for these kernels are made via the §Roofline
analysis, not via CPU wall-clock; interpret mode executes the kernel body
in Python and is orders of magnitude slower than Mosaic on TPU.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import decode_attention, gmm
from repro.kernels.ref import decode_attention_ref, gmm_ref

from .common import emit


def _bench(fn, *args, iters: int = 3) -> float:
    fn(*args)                                   # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run() -> dict:
    out = {}
    # MoE grouped matmul: llama-moe-3.5b decode bucket shape
    e, c, k, n = 8, 64, 512, 344
    x = jax.random.normal(jax.random.PRNGKey(0), (e, c, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (e, k, n), jnp.float32)
    flops = 2 * e * c * k * n
    t_k = _bench(lambda a, b: gmm(a, b, interpret=True), x, w)
    t_r = _bench(gmm_ref, x, w)
    np.testing.assert_allclose(np.asarray(gmm(x, w, interpret=True)),
                               np.asarray(gmm_ref(x, w)), atol=1e-4)
    emit("kernels/moe_gmm_interp", t_k * 1e6,
         f"gflops_per_call={flops/1e9:.3f};ref_us={t_r*1e6:.1f};allclose=1")
    out["gmm"] = (t_k, t_r)

    # decode attention: 8 kv heads, G=4, 4k cache
    b, hkv, g, s, hd = 2, 8, 4, 4096, 128
    q = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, g, hd), jnp.float32)
    kc = jax.random.normal(jax.random.PRNGKey(3), (b, hkv, s, hd), jnp.float32)
    vc = jax.random.normal(jax.random.PRNGKey(4), (b, hkv, s, hd), jnp.float32)
    pos = jnp.full((b,), s - 1, jnp.int32)
    cache_bytes = 2 * b * hkv * s * hd * 4
    t_k = _bench(lambda *a: decode_attention(*a, interpret=True), q, kc, vc, pos)
    t_r = _bench(decode_attention_ref, q, kc, vc, pos)
    np.testing.assert_allclose(
        np.asarray(decode_attention(q, kc, vc, pos, interpret=True)),
        np.asarray(decode_attention_ref(q, kc, vc, pos)), atol=1e-4)
    emit("kernels/decode_attn_interp", t_k * 1e6,
         f"cache_mb_per_call={cache_bytes/1e6:.1f};ref_us={t_r*1e6:.1f};"
         f"allclose=1")
    out["decode_attn"] = (t_k, t_r)
    return out


if __name__ == "__main__":
    run()
