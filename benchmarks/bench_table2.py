"""Paper Table II: s/token of the four placement schemes, eight workloads.

Token-generation latency of the four placement schemes on the
LLaMA-MoE-3.5B model across eight
language-understanding workloads.

Datasets differ only by RNG stream (per-question topology snapshot +
activation draws): the paper's own numbers are dataset-insensitive (+-1%),
which this reproduces.  The headline claim checked downstream: SpaceMoE
achieves >= 3x lower latency than every baseline.
"""
from __future__ import annotations

import numpy as np

from repro.core import (rand_intra_cg_plan, rand_intra_plan, rand_place_plan,
                        simulate_token_generation, spacemoe_plan)

from .common import (DATASETS, N_EXPERTS, N_LAYERS, Timer, emit, paper_world)


def run(n_tokens: int = 400, n_slots: int | None = None,
        seed: int = 0) -> dict:
    con, topo, activ, wl, comp = paper_world(seed=seed, n_slots=n_slots)
    ccfg = con.cfg
    plans = {
        "SpaceMoE": spacemoe_plan(con, topo, activ, wl, comp),
        "RandPlace": rand_place_plan(ccfg, N_LAYERS, N_EXPERTS,
                                     np.random.default_rng(seed + 1)),
        "RandIntra": rand_intra_plan(ccfg, N_LAYERS, N_EXPERTS,
                                     np.random.default_rng(seed + 2)),
        "RandIntra-CG": rand_intra_cg_plan(ccfg, N_LAYERS, N_EXPERTS,
                                           np.random.default_rng(seed + 3)),
    }
    table: dict[str, dict[str, float]] = {}
    rows = []
    for scheme, plan in plans.items():
        table[scheme] = {}
        for d_i, ds in enumerate(DATASETS):
            with Timer() as t:
                res = simulate_token_generation(
                    plan, topo, activ, wl, comp,
                    np.random.default_rng(1000 + d_i), n_tokens=n_tokens,
                )
            table[scheme][ds] = res.mean_s
            rows.append(emit(
                f"table2/{scheme}/{ds}",
                t.seconds / n_tokens * 1e6,
                f"s_per_token={res.mean_s:.4f};p99={res.p99_s:.4f};"
                f"drop={res.drop_rate:.4f}",
            ))
    # headline ratios
    sm = np.mean(list(table["SpaceMoE"].values()))
    for scheme in ("RandPlace", "RandIntra", "RandIntra-CG"):
        ratio = np.mean(list(table[scheme].values())) / sm
        rows.append(emit(f"table2/ratio/{scheme}_over_SpaceMoE", 0.0,
                         f"ratio={ratio:.3f}"))
    return {"table": table, "rows": rows}


if __name__ == "__main__":
    run()
