"""Shared benchmark setup: the paper's experimental configuration
(Sec. VII-A) and a fast variant for CI-style runs."""
from __future__ import annotations

import dataclasses
import functools
import time

import numpy as np

from repro.core import (ActivationModel, ComputeConfig, Constellation,
                        ConstellationConfig, LinkConfig, MoEWorkload,
                        sample_topology)

# Paper Sec. VII-A: 33 planes x 32 sats, F=13, 550 km, 87 deg, 200 slots,
# 0.12 rad/s PAT threshold, survival 0.95, >=100 Gbps ISLs, SBC-2A72 at 70%.
PAPER_CONSTELLATION = ConstellationConfig()
PAPER_LINK = LinkConfig(token_dim=4096, bits_per_value=16, isl_rate_gbps=100.0)
PAPER_COMPUTE = ComputeConfig(peak_gflops=10.4, utilization=0.7)

# LLaMA-MoE-3.5B: 32 layers x 8 experts, top-2.
N_LAYERS, N_EXPERTS, TOP_K = 32, 8, 2

DATASETS = ["OpenBookQA", "PIQA", "ARC-E", "ARC-C", "WinoGrande", "BoolQ",
            "SciQ", "HellaSwag"]


@functools.lru_cache(maxsize=4)
def _paper_world_cached(seed: int, n_slots: int | None,
                        cfg: ConstellationConfig):
    ccfg = cfg if n_slots is None \
        else dataclasses.replace(cfg, n_slots=n_slots)
    con = Constellation(ccfg)
    topo = sample_topology(con, PAPER_LINK, np.random.default_rng(seed))
    activ = ActivationModel.zipf(N_LAYERS, N_EXPERTS, TOP_K, seed=seed)
    wl = MoEWorkload.llama_moe_3p5b()
    return con, topo, activ, wl, PAPER_COMPUTE


def paper_world(seed: int = 0, n_slots: int | None = None,
                cfg: ConstellationConfig | None = None):
    """(constellation, topology, activation, workload, compute).

    Memoized on (seed, n_slots, cfg) — ConstellationConfig is a frozen
    dataclass, so identical worlds across a multi-bench smoke run share
    one constellation + topology build.  The cache is small (4) so
    parameter-sweep benches that build many distinct worlds don't pin
    them all for the process lifetime.  Treat the returned objects as
    read-only.
    """
    return _paper_world_cached(seed, n_slots, cfg or PAPER_CONSTELLATION)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0


def emit(name: str, us_per_call: float, derived: str) -> str:
    row = f"{name},{us_per_call:.3f},{derived}"
    print(row)
    return row
